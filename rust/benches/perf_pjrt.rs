//! Performance bench for the real-execution path: wall-clock bandwidth
//! of the AOT gather/scatter artifacts on PJRT-CPU, compared against a
//! plain memcpy-style upper bound measured on this host.
//!
//! §Perf target: stride-1 gather through the `ref` artifact within 2x
//! of the host's sequential-read bandwidth (the kernel is a pure
//! stream), and the `pallas` artifact within 4x of `ref` (it carries
//! the interpret-mode grid structure).

use std::time::Instant;

use spatter::backends::{Backend, PjrtBackend};
use spatter::pattern::{Kernel, Pattern};

/// Rough host sequential-read bandwidth (GB/s) via a summation sweep.
fn host_read_gbs() -> f64 {
    let n = 1 << 24; // 128 MB of f64
    let data: Vec<f64> = (0..n).map(|i| i as f64).collect();
    // warm
    let mut acc = 0.0;
    for &x in &data {
        acc += x;
    }
    let t0 = Instant::now();
    let reps = 3;
    for _ in 0..reps {
        let mut s = 0.0;
        for &x in &data {
            s += x;
        }
        acc += s;
    }
    let secs = t0.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    (reps * n * 8) as f64 / secs / 1e9
}

fn main() {
    println!("== perf_pjrt: real-execution path ==");
    let host = host_read_gbs();
    println!("host sequential read: {host:.2} GB/s");

    let mut pjrt = match PjrtBackend::open_default() {
        Ok(b) => b,
        Err(e) => {
            println!("SKIPPED: {e} (run `make artifacts`)");
            return;
        }
    };
    pjrt.runs = 5;

    let stream = Pattern::parse("UNIFORM:8:1")
        .unwrap()
        .with_delta(8)
        .with_count(1 << 20);
    let r = pjrt.run(&stream, Kernel::Gather).unwrap();
    let bw = r.bandwidth_gbs();
    println!(
        "pjrt stride-1 gather (ref artifact): {bw:.2} GB/s ({:.2}x of host read)",
        host / bw
    );

    let strided = Pattern::parse("UNIFORM:8:8")
        .unwrap()
        .with_delta(64)
        .with_count(1 << 20);
    let r8 = pjrt.run(&strided, Kernel::Gather).unwrap();
    println!("pjrt stride-8 gather: {:.2} GB/s", r8.bandwidth_gbs());

    let v16 = spatter::pattern::table5::by_name("LULESH-G2")
        .unwrap()
        .to_pattern(1 << 20);
    let rv = pjrt.run(&v16, Kernel::Gather).unwrap();
    println!("pjrt LULESH-G2 (v16): {:.2} GB/s", rv.bandwidth_gbs());

    let sc = spatter::pattern::table5::by_name("LULESH-S1")
        .unwrap()
        .to_pattern(1 << 18);
    let rs = pjrt.run(&sc, Kernel::Scatter).unwrap();
    println!("pjrt LULESH-S1 scatter: {:.2} GB/s", rs.bandwidth_gbs());

    if bw * 2.0 < host {
        println!(
            "stride-1 gather is more than 2x below host read — see \
             EXPERIMENTS.md §Perf"
        );
    } else {
        println!("stride-1 gather within 2x of host read: target met");
    }
}
