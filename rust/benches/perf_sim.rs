//! Performance microbench for the L3 hot path: simulated accesses per
//! second through the CPU and GPU engines.
//!
//! This is the §Perf target tracker (EXPERIMENTS.md): a full figure
//! sweep should take seconds, which needs >= ~10^7-10^8 simulated
//! accesses/s. Run before/after each optimization.

use std::time::Instant;

use spatter::pattern::{Kernel, Pattern};
use spatter::platforms;
use spatter::sim::cpu::{CpuEngine, CpuSimOptions};
use spatter::sim::gpu::GpuEngine;

fn bench_cpu(name: &str, pattern: &Pattern, kernel: Kernel) -> f64 {
    let p = platforms::by_name("skx").unwrap();
    let mut e = CpuEngine::with_options(
        &p,
        CpuSimOptions {
            max_sim_accesses: 1 << 22,
            ..Default::default()
        },
    );
    // warm once (engine allocation, page-in)
    e.run(pattern, kernel).unwrap();
    let t0 = Instant::now();
    let reps = 3;
    let mut accesses = 0u64;
    for _ in 0..reps {
        let r = e.run(pattern, kernel).unwrap();
        accesses += r.counters.accesses;
    }
    let rate = accesses as f64 / t0.elapsed().as_secs_f64();
    println!("cpu-engine  {name:<28} {:.2} M acc/s", rate / 1e6);
    rate
}

fn bench_gpu(name: &str, pattern: &Pattern, kernel: Kernel) -> f64 {
    let p = platforms::gpu_by_name("p100").unwrap();
    let mut e = GpuEngine::new(&p);
    e.run(pattern, kernel).unwrap();
    let t0 = Instant::now();
    let reps = 3;
    let mut accesses = 0u64;
    for _ in 0..reps {
        let r = e.run(pattern, kernel).unwrap();
        accesses += r.counters.accesses;
    }
    let rate = accesses as f64 / t0.elapsed().as_secs_f64();
    println!("gpu-engine  {name:<28} {:.2} M acc/s", rate / 1e6);
    rate
}

fn main() {
    println!("== perf_sim: simulator hot-path throughput ==");
    let stream = Pattern::parse("UNIFORM:8:1")
        .unwrap()
        .with_delta(8)
        .with_count(1 << 22);
    let strided = Pattern::parse("UNIFORM:8:64")
        .unwrap()
        .with_delta(512)
        .with_count(1 << 22);
    let amg = spatter::pattern::table5::by_name("AMG-G0")
        .unwrap()
        .to_pattern(1 << 20);
    let pennant = spatter::pattern::table5::by_name("PENNANT-G12")
        .unwrap()
        .to_pattern(1 << 20);

    let mut rates = Vec::new();
    rates.push(bench_cpu("stride-1 (cache hits)", &stream, Kernel::Gather));
    rates.push(bench_cpu("stride-64 (dram misses)", &strided, Kernel::Gather));
    rates.push(bench_cpu("AMG-G0 (cached app)", &amg, Kernel::Gather));
    rates.push(bench_cpu("PENNANT-G12 (tlb-bound)", &pennant, Kernel::Gather));
    rates.push(bench_cpu("stride-1 scatter (stream)", &stream, Kernel::Scatter));

    let gstream = Pattern::parse("UNIFORM:256:1")
        .unwrap()
        .with_delta(256)
        .with_count(1 << 14);
    rates.push(bench_gpu("stride-1", &gstream, Kernel::Gather));
    rates.push(bench_gpu("stride-1 scatter", &gstream, Kernel::Scatter));

    let worst = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("\nworst case: {:.2} M acc/s (target >= 10 M acc/s)", worst / 1e6);
    if worst < 10e6 {
        println!("BELOW TARGET — see EXPERIMENTS.md §Perf for the iteration log");
    }
}
