//! Perf-trajectory bench (plain `std::time::Instant` harness, no
//! external deps): times the fast `ustride` CPU sweep and a
//! 256-iteration LULESH-S3 scatter, each A/B'd twice — steady-state
//! loop closure on vs off, and the batch-compiled access plan on vs
//! off (the `plan-*` records) — plus the scheduler/memo/stream
//! campaign legs, the `dram-bank` pow2-vs-odd conflict cell, the
//! `simd-regime` scalar-vs-native vectorization ladder, and the
//! `numa-remote` all-local vs all-remote cliff endpoints, and emits
//! `BENCH_sim.json` (`{"suite": ..., "wall_ms": ...}` records) so the
//! repo's perf numbers accumulate run over run.
//!
//! Run via `scripts/bench.sh` (or `cargo bench --bench sweep`); the
//! output path can be overridden with the `BENCH_SIM_JSON` env var.

use std::hint::black_box;
use std::time::Instant;

use spatter::backends::{Backend, OpenMpSim};
use spatter::coordinator::{
    parse_config_text, run_configs_jobs_memo, run_configs_stream,
    stream_config_reader,
};
use spatter::json::{self, obj, Value};
use spatter::pattern::{table5, Kernel, Pattern};
use spatter::platforms::{self, VectorRegime};
use spatter::sim::cpu::{CpuEngine, CpuSimOptions};
use spatter::sim::NumaPlacement;
use spatter::suite::{cpu_ustride, ratio_pattern, STRIDES};

/// Engine options with closure pinned explicitly (independent of the
/// `SPATTER_NO_CLOSURE` env var, so both arms run in one process).
fn opts(closure_enabled: bool) -> CpuSimOptions {
    CpuSimOptions {
        closure_enabled,
        ..Default::default()
    }
}

/// Engine options for the plan A/B: the plan pinned per arm
/// (independent of `SPATTER_NO_PLAN`) and closure pinned *off*, so
/// every iteration actually walks the per-access path the plan
/// compiles — with closure on, the analytic fast-forward hides most
/// of the work being measured.
fn opts_plan(plan_enabled: bool) -> CpuSimOptions {
    CpuSimOptions {
        plan_enabled,
        closure_enabled: false,
        ..Default::default()
    }
}

/// The `--suite ustride --fast` workload: SKX + BDW, gather + scatter,
/// strides 1..128 at the fast-mode count.
fn ustride_fast_sweep(closure: bool) {
    let count = 1 << 16;
    for name in ["skx", "bdw"] {
        let p = platforms::by_name(name).unwrap();
        let mut e = CpuEngine::with_options(&p, opts(closure));
        for kernel in [Kernel::Gather, Kernel::Scatter] {
            for &s in STRIDES {
                let r = e.run(&cpu_ustride(s, count), kernel).unwrap();
                black_box(r.bandwidth_gbs());
            }
        }
    }
}

/// 512 repetitions of a 256-iteration LULESH-S3 scatter — the paper's
/// delta-0 coherence-storm proxy, where closure collapses nearly the
/// whole run.
fn lulesh_s3_256(closure: bool) {
    let s3 = table5::by_name("LULESH-S3").unwrap().to_pattern(256);
    let p = platforms::by_name("skx").unwrap();
    let mut e = CpuEngine::with_options(&p, opts(closure));
    for _ in 0..512 {
        let r = e.run(&s3, Kernel::Scatter).unwrap();
        black_box(r.seconds);
    }
}

/// The ustride fast sweep again, plan on/off (closure pinned off; see
/// `opts_plan`).
fn ustride_fast_sweep_plan(plan: bool) {
    let count = 1 << 16;
    for name in ["skx", "bdw"] {
        let p = platforms::by_name(name).unwrap();
        let mut e = CpuEngine::with_options(&p, opts_plan(plan));
        for kernel in [Kernel::Gather, Kernel::Scatter] {
            for &s in STRIDES {
                let r = e.run(&cpu_ustride(s, count), kernel).unwrap();
                black_box(r.bandwidth_gbs());
            }
        }
    }
}

/// The 256-iteration LULESH-S3 scatter again, plan on/off. Delta-0
/// revisits make every line a same-line run, so this is the plan's
/// best case: the coalesced bulk updates replace nearly every scalar
/// cache probe.
fn lulesh_s3_256_plan(plan: bool) {
    let s3 = table5::by_name("LULESH-S3").unwrap().to_pattern(256);
    let p = platforms::by_name("skx").unwrap();
    let mut e = CpuEngine::with_options(&p, opts_plan(plan));
    for _ in 0..512 {
        let r = e.run(&s3, Kernel::Scatter).unwrap();
        black_box(r.seconds);
    }
}

fn time_ms(f: impl FnOnce()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64() * 1e3
}

/// Worker-pool backend source for the scheduler/memo benchmarks.
fn skx_factory() -> spatter::error::Result<Box<dyn Backend>> {
    Ok(Box::new(OpenMpSim::new(&platforms::by_name("skx").unwrap())))
}

/// `copies` copies of 8 distinct gather configs — the memo cache's
/// natural prey (cross-platform grids re-run identical cells).
fn dup_campaign(copies: usize) -> String {
    let mut runs = Vec::new();
    for _ in 0..copies {
        for s in [1, 2, 4, 8, 16, 32, 64, 128] {
            runs.push(format!(
                "{{\"kernel\": \"Gather\", \"pattern\": \"UNIFORM:8:{s}\", \
                 \"delta\": {}, \"count\": 65536}}",
                8 * s
            ));
        }
    }
    format!("[{}]", runs.join(","))
}

/// `n` configs with pairwise-distinct fingerprints (a stride sweep) —
/// zero cache hits by construction, so any memo/scheduler overhead
/// shows up undamped.
fn unique_campaign(n: usize) -> String {
    let runs: Vec<String> = (1..=n)
        .map(|s| {
            format!(
                "{{\"kernel\": \"Gather\", \"pattern\": \"UNIFORM:8:{s}\", \
                 \"delta\": {}, \"count\": 65536}}",
                8 * s
            )
        })
        .collect();
    format!("[{}]", runs.join(","))
}

/// Peak resident set (KiB) from /proc/self/status; `None` off Linux.
fn vm_hwm_kib() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("VmHWM:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

fn main() {
    let mut records: Vec<Value> = Vec::new();
    // A/B harness over a boolean engine knob ("closure" or "plan"):
    // times both arms, prints one line, and records each arm plus a
    // `<knob>_speedup` figure.
    let mut bench = |suite: &str, knob: &str, f: fn(bool)| {
        let on_ms = time_ms(|| f(true));
        let off_ms = time_ms(|| f(false));
        println!(
            "{suite}: {knob} on {on_ms:.1} ms, off {off_ms:.1} ms \
             ({:.2}x)",
            off_ms / on_ms
        );
        for (on, wall_ms) in [(true, on_ms), (false, off_ms)] {
            records.push(obj(&[
                ("suite", Value::from(suite)),
                (knob, Value::Bool(on)),
                ("wall_ms", Value::from(wall_ms)),
            ]));
        }
        let speedup_key = format!("{knob}_speedup");
        records.push(obj(&[
            ("suite", Value::from(suite)),
            (speedup_key.as_str(), Value::from(off_ms / on_ms)),
        ]));
    };

    bench("ustride-fast", "closure", ustride_fast_sweep);
    bench("lulesh-s3-256", "closure", lulesh_s3_256);
    bench("plan-ustride-fast", "plan", ustride_fast_sweep_plan);
    bench("plan-lulesh-s3-256", "plan", lulesh_s3_256_plan);

    // --- Campaign-scale scheduler benchmarks (work-stealing pool,
    // memo cache, streaming run mode). The stream leg runs FIRST so
    // its VmHWM reading isn't inflated by the batch legs' allocations.
    let dup_text = dup_campaign(32); // 256 configs, 8 distinct
    let hwm_kib = {
        let before = vm_hwm_kib();
        let wall_ms = time_ms(|| {
            let src = stream_config_reader(std::io::Cursor::new(
                dup_text.as_bytes(),
            ));
            let mut emitted = 0usize;
            run_configs_stream(&skx_factory, src, 4, true, |chunk| {
                emitted += chunk.len();
                Ok(())
            })
            .unwrap();
            black_box(emitted);
        });
        let after = vm_hwm_kib();
        println!(
            "stream-dup256: {wall_ms:.1} ms, peak RSS {} KiB",
            after.map(|k| k.to_string()).unwrap_or_else(|| "?".into())
        );
        records.push(obj(&[
            ("suite", Value::from("stream-dup256")),
            ("wall_ms", Value::from(wall_ms)),
            (
                "vm_hwm_before_kib",
                before.map(|k| Value::from(k as usize)).unwrap_or(Value::Null),
            ),
            (
                "vm_hwm_after_kib",
                after.map(|k| Value::from(k as usize)).unwrap_or(Value::Null),
            ),
        ]));
        after
    };
    let _ = hwm_kib;

    let dup_cfgs = parse_config_text(&dup_text).unwrap();
    let dup_off = time_ms(|| {
        black_box(
            run_configs_jobs_memo(&skx_factory, &dup_cfgs, 4, false).unwrap(),
        );
    });
    let t0 = Instant::now();
    let (dup_recs, memo_stats) =
        run_configs_jobs_memo(&skx_factory, &dup_cfgs, 4, true).unwrap();
    let dup_on = t0.elapsed().as_secs_f64() * 1e3;
    black_box(dup_recs);
    println!(
        "memo-dup256: memo off {dup_off:.1} ms, on {dup_on:.1} ms \
         ({:.2}x, hit rate {:.0}%)",
        dup_off / dup_on,
        memo_stats.hit_rate() * 100.0
    );
    records.push(obj(&[
        ("suite", Value::from("memo-dup256")),
        ("memo", Value::Bool(false)),
        ("wall_ms", Value::from(dup_off)),
    ]));
    records.push(obj(&[
        ("suite", Value::from("memo-dup256")),
        ("memo", Value::Bool(true)),
        ("wall_ms", Value::from(dup_on)),
        ("hit_rate", Value::from(memo_stats.hit_rate())),
    ]));
    records.push(obj(&[
        ("suite", Value::from("memo-dup256")),
        ("memo_speedup", Value::from(dup_off / dup_on)),
    ]));

    let uniq_cfgs = parse_config_text(&unique_campaign(64)).unwrap();
    let uniq_j1 = time_ms(|| {
        black_box(
            run_configs_jobs_memo(&skx_factory, &uniq_cfgs, 1, false).unwrap(),
        );
    });
    let uniq_j4 = time_ms(|| {
        black_box(
            run_configs_jobs_memo(&skx_factory, &uniq_cfgs, 4, false).unwrap(),
        );
    });
    let uniq_j4_memo = time_ms(|| {
        black_box(
            run_configs_jobs_memo(&skx_factory, &uniq_cfgs, 4, true).unwrap(),
        );
    });
    println!(
        "sched-unique64: jobs=1 {uniq_j1:.1} ms, jobs=4 {uniq_j4:.1} ms \
         ({:.2}x), jobs=4+memo {uniq_j4_memo:.1} ms",
        uniq_j1 / uniq_j4
    );
    for (label, jobs, memo, ms) in [
        ("sched-unique64", 1usize, false, uniq_j1),
        ("sched-unique64", 4, false, uniq_j4),
        ("sched-unique64", 4, true, uniq_j4_memo),
    ] {
        records.push(obj(&[
            ("suite", Value::from(label)),
            ("jobs", Value::from(jobs)),
            ("memo", Value::Bool(memo)),
            ("wall_ms", Value::from(ms)),
        ]));
    }
    records.push(obj(&[
        ("suite", Value::from("sched-unique64")),
        ("sched_speedup", Value::from(uniq_j1 / uniq_j4)),
    ]));

    // --- Banked-DRAM microbench: the aliased pow2 row-stride ladder
    // vs its odd neighbour on a 64-bank part (KNL), prefetchers off so
    // the activation chain is the pattern's own (`--suite dram`'s
    // knee cell, timed).
    let dram_pat = |rows: usize| {
        let stride = rows * 256; // 2 KiB rows / 8-byte elements
        Pattern::parse(&format!("UNIFORM:8:{stride}"))
            .unwrap()
            .with_delta(8 * stride as i64)
            .with_count(1 << 14)
    };
    let knl = platforms::by_name("knl").unwrap();
    let mut walls = [0.0f64; 2];
    let mut rates = [0.0f64; 2];
    for (i, rows) in [16usize, 17].into_iter().enumerate() {
        let pat = dram_pat(rows);
        let mut e = OpenMpSim::without_prefetch(&knl);
        let t0 = Instant::now();
        let r = e.run(&pat, Kernel::Gather).unwrap();
        walls[i] = t0.elapsed().as_secs_f64() * 1e3;
        let c = &r.counters;
        let acts = c.dram_row_misses + c.dram_row_conflicts;
        if acts > 0 {
            rates[i] = c.dram_row_conflicts as f64 / acts as f64;
        }
        black_box(r.bandwidth_gbs());
    }
    println!(
        "dram-bank: knl rows=16 {:.1} ms (conflict rate {:.2}), \
         rows=17 {:.1} ms ({:.2})",
        walls[0], rates[0], walls[1], rates[1]
    );
    records.push(obj(&[
        ("suite", Value::from("dram-bank")),
        ("platform", Value::from("knl")),
        ("wall_ms_pow2", Value::from(walls[0])),
        ("wall_ms_odd", Value::from(walls[1])),
        ("conflict_rate_pow2", Value::from(rates[0])),
        ("conflict_rate_odd", Value::from(rates[1])),
    ]));

    // --- Vectorization-regime microbench: the fast KNL gather stride
    // ladder under the forced scalar regime vs the native hardware
    // G/S. The knob is pure analytic-timing dispatch, so the walls
    // should tie; the stride-1 bandwidth ratio is Fig 6's KNL pole
    // and is recorded so regressions in the regime model show up here.
    let regime_sweep = |regime: Option<VectorRegime>| -> (f64, f64) {
        let knl = platforms::by_name("knl").unwrap();
        let mut e = CpuEngine::with_options(
            &knl,
            CpuSimOptions {
                regime,
                ..Default::default()
            },
        );
        let mut s1_bw = 0.0f64;
        let t0 = Instant::now();
        for &s in STRIDES {
            let r = e.run(&cpu_ustride(s, 1 << 16), Kernel::Gather).unwrap();
            if s == 1 {
                s1_bw = r.bandwidth_gbs();
            }
            black_box(r.bandwidth_gbs());
        }
        (t0.elapsed().as_secs_f64() * 1e3, s1_bw)
    };
    let (native_ms, native_bw) = regime_sweep(None);
    let (scalar_ms, scalar_bw) = regime_sweep(Some(VectorRegime::Scalar));
    println!(
        "simd-regime: knl native {native_ms:.1} ms, scalar {scalar_ms:.1} ms, \
         stride-1 vector/scalar {:.2}x",
        native_bw / scalar_bw
    );
    records.push(obj(&[
        ("suite", Value::from("simd-regime")),
        ("platform", Value::from("knl")),
        ("wall_ms_native", Value::from(native_ms)),
        ("wall_ms_scalar", Value::from(scalar_ms)),
        ("s1_vector_over_scalar", Value::from(native_bw / scalar_bw)),
    ]));

    // --- NUMA microbench: the numa suite's engineered ratio pattern
    // at its all-local vs all-remote endpoints on the two-socket SKX
    // under interleave placement, prefetchers off (`--suite numa`'s
    // cliff endpoints, timed). The bandwidth ratio is the recorded
    // remote-access cliff; the walls catch topology-layer overhead.
    let skx2 = platforms::by_name("skx-2s").unwrap();
    let mut numa_walls = [0.0f64; 2];
    let mut numa_bw = [0.0f64; 2];
    for (i, remote_lanes) in [0usize, 16].into_iter().enumerate() {
        let pat = ratio_pattern(remote_lanes, 1 << 14);
        let mut e = OpenMpSim::without_prefetch(&skx2);
        e.set_numa_placement(Some(NumaPlacement::Interleave));
        let t0 = Instant::now();
        let r = e.run(&pat, Kernel::Gather).unwrap();
        numa_walls[i] = t0.elapsed().as_secs_f64() * 1e3;
        numa_bw[i] = r.bandwidth_gbs();
        black_box(r.seconds);
    }
    println!(
        "numa-remote: skx-2s local {:.1} ms ({:.1} GB/s), remote {:.1} ms \
         ({:.1} GB/s), cliff {:.2}x",
        numa_walls[0],
        numa_bw[0],
        numa_walls[1],
        numa_bw[1],
        numa_bw[0] / numa_bw[1]
    );
    records.push(obj(&[
        ("suite", Value::from("numa-remote")),
        ("platform", Value::from("skx-2s")),
        ("wall_ms_local", Value::from(numa_walls[0])),
        ("wall_ms_remote", Value::from(numa_walls[1])),
        ("local_gbs", Value::from(numa_bw[0])),
        ("remote_gbs", Value::from(numa_bw[1])),
        ("remote_cliff", Value::from(numa_bw[0] / numa_bw[1])),
    ]));

    let out = std::env::var("BENCH_SIM_JSON")
        .unwrap_or_else(|_| "BENCH_sim.json".to_string());
    let mut text = json::to_string_pretty(&Value::Array(records));
    text.push('\n');
    std::fs::write(&out, text).expect("write BENCH_sim.json");
    println!("wrote {out}");
}
