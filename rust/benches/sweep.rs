//! Perf-trajectory bench (plain `std::time::Instant` harness, no
//! external deps): times the fast `ustride` CPU sweep and a
//! 256-iteration LULESH-S3 scatter, each with steady-state loop
//! closure enabled and force-disabled, and emits `BENCH_sim.json`
//! (`{"suite": ..., "wall_ms": ...}` records) so the repo's perf
//! numbers accumulate run over run.
//!
//! Run via `scripts/bench.sh` (or `cargo bench --bench sweep`); the
//! output path can be overridden with the `BENCH_SIM_JSON` env var.

use std::hint::black_box;
use std::time::Instant;

use spatter::json::{self, obj, Value};
use spatter::pattern::{table5, Kernel};
use spatter::platforms;
use spatter::sim::cpu::{CpuEngine, CpuSimOptions};
use spatter::suite::{cpu_ustride, STRIDES};

/// Engine options with closure pinned explicitly (independent of the
/// `SPATTER_NO_CLOSURE` env var, so both arms run in one process).
fn opts(closure_enabled: bool) -> CpuSimOptions {
    CpuSimOptions {
        closure_enabled,
        ..Default::default()
    }
}

/// The `--suite ustride --fast` workload: SKX + BDW, gather + scatter,
/// strides 1..128 at the fast-mode count.
fn ustride_fast_sweep(closure: bool) {
    let count = 1 << 16;
    for name in ["skx", "bdw"] {
        let p = platforms::by_name(name).unwrap();
        let mut e = CpuEngine::with_options(&p, opts(closure));
        for kernel in [Kernel::Gather, Kernel::Scatter] {
            for &s in STRIDES {
                let r = e.run(&cpu_ustride(s, count), kernel).unwrap();
                black_box(r.bandwidth_gbs());
            }
        }
    }
}

/// 512 repetitions of a 256-iteration LULESH-S3 scatter — the paper's
/// delta-0 coherence-storm proxy, where closure collapses nearly the
/// whole run.
fn lulesh_s3_256(closure: bool) {
    let s3 = table5::by_name("LULESH-S3").unwrap().to_pattern(256);
    let p = platforms::by_name("skx").unwrap();
    let mut e = CpuEngine::with_options(&p, opts(closure));
    for _ in 0..512 {
        let r = e.run(&s3, Kernel::Scatter).unwrap();
        black_box(r.seconds);
    }
}

fn time_ms(f: impl FnOnce()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64() * 1e3
}

fn main() {
    let mut records: Vec<Value> = Vec::new();
    let mut bench = |suite: &str, f: fn(bool)| {
        let on_ms = time_ms(|| f(true));
        let off_ms = time_ms(|| f(false));
        println!(
            "{suite}: closure on {on_ms:.1} ms, off {off_ms:.1} ms \
             ({:.2}x)",
            off_ms / on_ms
        );
        for (closure, wall_ms) in [(true, on_ms), (false, off_ms)] {
            records.push(obj(&[
                ("suite", Value::from(suite)),
                ("closure", Value::Bool(closure)),
                ("wall_ms", Value::from(wall_ms)),
            ]));
        }
        records.push(obj(&[
            ("suite", Value::from(suite)),
            ("closure_speedup", Value::from(off_ms / on_ms)),
        ]));
    };

    bench("ustride-fast", ustride_fast_sweep);
    bench("lulesh-s3-256", lulesh_s3_256);

    let out = std::env::var("BENCH_SIM_JSON")
        .unwrap_or_else(|_| "BENCH_sim.json".to_string());
    let mut text = json::to_string_pretty(&Value::Array(records));
    text.push('\n');
    std::fs::write(&out, text).expect("write BENCH_sim.json");
    println!("wrote {out}");
}
