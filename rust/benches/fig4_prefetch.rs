//! Bench harness regenerating the paper's fig4 series.
//! Runs the suite experiment, prints the same rows the paper reports,
//! and writes the CSV series to bench_out/.

use std::path::Path;
use std::time::Instant;

use spatter::suite::{self, SuiteContext};

fn main() {
    let name = "fig4";
    let ctx = SuiteContext::new(Path::new("bench_out"));
    let t0 = Instant::now();
    match suite::run(name, &ctx) {
        Ok(report) => {
            println!("{report}");
            println!("[bench {name}] regenerated in {:.2}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("[bench {name}] FAILED: {e}");
            std::process::exit(1);
        }
    }
}
