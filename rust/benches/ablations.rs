//! Ablation studies over the simulator's design choices (DESIGN.md
//! calls these out): what each modelled mechanism contributes to the
//! reproduced curves. Each ablation switches ONE mechanism off (or
//! distorts one parameter) and reports the effect on the headline
//! numbers it is responsible for.

use spatter::backends::{Backend, CudaSim, OpenMpSim};
use spatter::pattern::{table5, Kernel, Pattern};
use spatter::platforms;
use spatter::sim::cpu::{CpuEngine, CpuSimOptions};
use spatter::sim::{PageSize, PrefetchKind, TlbGeometry};

fn cpu_ustride(stride: usize) -> Pattern {
    Pattern::parse(&format!("UNIFORM:8:{stride}"))
        .unwrap()
        .with_delta(8 * stride as i64)
        .with_count(1 << 18)
}

fn main() {
    println!("== ablations: one mechanism at a time ==\n");

    // 1. Prefetcher kind drives the Fig 3 divergence: replace BDW's
    //    adjacent-line prefetcher with none / next-line and watch the
    //    stride-32 vs stride-64 relationship change.
    println!("[1] BDW prefetcher ablation (gather GB/s at strides 32/64)");
    let bdw = platforms::by_name("bdw").unwrap();
    for (label, kind) in [
        ("adjacent-line (model)", bdw.prefetch),
        ("none", PrefetchKind::None),
        ("next-line deg1 (skx-like)", PrefetchKind::NextLine { degree: 1 }),
        ("stride deg4 (naples-like)", PrefetchKind::Stride { degree: 4 }),
    ] {
        let mut p = bdw.clone();
        p.prefetch = kind;
        let mut e = OpenMpSim::new(&p);
        let b32 = e.run(&cpu_ustride(32), Kernel::Gather).unwrap().bandwidth_gbs();
        let b64 = e.run(&cpu_ustride(64), Kernel::Gather).unwrap().bandwidth_gbs();
        println!(
            "    {label:<28} s32 {b32:>6.2}  s64 {b64:>6.2}  recovery {}",
            if b64 > b32 * 1.2 { "YES" } else { "no" }
        );
    }

    // 2. Warmup (min-of-10 semantics) drives the above-STREAM app
    //    numbers: without it, AMG looks like a cold stream.
    println!("\n[2] warmup ablation (SKX AMG-G0 gather GB/s)");
    let skx = platforms::by_name("skx").unwrap();
    let amg = table5::by_name("AMG-G0").unwrap().to_pattern(1 << 18);
    for (label, warmup) in [("warm (min-of-10 model)", 1 << 15), ("cold run", 0)] {
        let mut e = CpuEngine::with_options(
            &skx,
            CpuSimOptions {
                warmup_iterations: warmup,
                ..Default::default()
            },
        );
        let bw = e.run(&amg, Kernel::Gather).unwrap().bandwidth_gbs();
        println!("    {label:<28} {bw:>7.1}  (stream {:.1})", skx.stream_gbs);
    }

    // 3. GPU sector size is the whole Fig 5 K40-vs-Pascal story.
    println!("\n[3] GPU coalescing-granularity ablation (gather fraction of peak at stride-8)");
    for (label, sector) in [("32 B sectors (pascal)", 32u64), ("128 B lines (kepler)", 128u64)] {
        let mut g = platforms::gpu_by_name("p100").unwrap();
        g.sector_bytes = sector;
        let mut e = CudaSim::new(&g);
        let mk = |s: usize| {
            Pattern::parse(&format!("UNIFORM:256:{s}"))
                .unwrap()
                .with_delta(256 * s as i64)
                .with_count(1 << 12)
        };
        let b1 = e.run(&mk(1), Kernel::Gather).unwrap().bandwidth_gbs();
        let b8 = e.run(&mk(8), Kernel::Gather).unwrap().bandwidth_gbs();
        println!("    {label:<28} {:>6.3}", b8 / b1);
    }

    // 4. Coherence penalty is the LULESH-S3 story.
    println!("\n[4] coherence ablation (SKX LULESH-S3 scatter GB/s)");
    let s3 = table5::by_name("LULESH-S3").unwrap().to_pattern(1 << 16);
    for (label, coh) in [("modelled", skx.coherence_ns), ("disabled", 0.0)] {
        let mut p = skx.clone();
        p.coherence_ns = coh;
        let mut e = OpenMpSim::new(&p);
        let bw = e.run(&s3, Kernel::Scatter).unwrap().bandwidth_gbs();
        println!("    {label:<28} {bw:>7.1}");
    }

    // 5. TLB reach is the PENNANT large-delta story.
    println!("\n[5] TLB ablation (BDW PENNANT-G9 gather GB/s)");
    let g9 = table5::by_name("PENNANT-G9").unwrap().to_pattern(1 << 20);
    for (label, entries) in [("1536 entries (model)", 1536usize), ("huge (64k)", 65536)] {
        let mut p = bdw.clone();
        p.tlb.four_kb = TlbGeometry { entries, assoc: 4 };
        let mut e = OpenMpSim::new(&p);
        let bw = e.run(&g9, Kernel::Gather).unwrap().bandwidth_gbs();
        println!("    {label:<28} {bw:>7.2}");
    }

    // 6. Page size is the other half of the same story: large pages
    //    restore the huge-delta pattern to the DRAM roofline.
    println!("\n[6] page-size ablation (BDW PENNANT-G9 gather GB/s)");
    for page in [PageSize::FourKB, PageSize::TwoMB, PageSize::OneGB] {
        let mut e = OpenMpSim::with_page_size(&bdw, page);
        let r = e.run(&g9, Kernel::Gather).unwrap();
        println!(
            "    {:<28} {:>7.2}  (TLB miss rate {:.4})",
            page.name(),
            r.bandwidth_gbs(),
            r.counters.tlb.miss_rate().unwrap_or(0.0)
        );
    }

    println!("\nEach mechanism is individually responsible for its paper figure —");
    println!("removing it removes the corresponding effect (see DESIGN.md §2).");
}
