//! Differential tests pinning the GS (gather-scatter) kernel against
//! its component kernels — the direction-of-inequality layer that
//! keeps the dual-stream engine plumbing honest on every platform.
//!
//! Invariants:
//!
//! * **Bounded by components** — an indexed copy reads through its
//!   gather pattern *and* writes through its scatter pattern, so its
//!   payload bandwidth can never beat either half run alone:
//!   `bw(GS) <= min(bw(Gather side), bw(Scatter side))`.
//! * **Delta-0 contention** — a delta-0 GS hammers its write lines
//!   from every thread exactly like delta-0 scatter, so bandwidth
//!   *degrades* as `--threads` grows (except TX2, which absorbs
//!   repeated writes).

use spatter::backends::{Backend, CudaSim, OpenMpSim};
use spatter::pattern::{table5, Kernel, Pattern};
use spatter::platforms;
use spatter::sim::cpu::{CpuEngine, CpuSimOptions};

/// A GS pattern plus its two component patterns (same delta/count).
fn components(
    gather: Vec<i64>,
    scatter: Vec<i64>,
    delta: i64,
    count: usize,
) -> (Pattern, Pattern, Pattern) {
    let gs = Pattern::from_indices("gs", gather.clone())
        .with_gs_scatter(scatter.clone())
        .with_delta(delta)
        .with_count(count);
    let g = Pattern::from_indices("g", gather)
        .with_delta(delta)
        .with_count(count);
    let s = Pattern::from_indices("s", scatter)
        .with_delta(delta)
        .with_count(count);
    (gs, g, s)
}

/// The swept GS shapes: uniform/uniform at several stride pairs, a
/// broadcast read side, and the LULESH element→node copy.
fn cases(v: usize, count: usize) -> Vec<(String, Pattern, Pattern, Pattern)> {
    let uni = |s: usize| (0..v as i64).map(|j| j * s as i64).collect::<Vec<_>>();
    let mut out = Vec::new();
    for (gs, ss) in [(1usize, 1usize), (8, 1), (1, 8), (8, 8), (24, 1)] {
        let delta = (v * gs.max(ss)) as i64;
        let (p, g, s) = components(uni(gs), uni(ss), delta, count);
        out.push((format!("u{gs}/u{ss}"), p, g, s));
    }
    // Broadcast gather side feeding a stride-1 scatter (PENNANT-G4's
    // read shape).
    let bcast: Vec<i64> = (0..v as i64).map(|j| j / 4).collect();
    let (p, g, s) = components(bcast, uni(1), v as i64, count);
    out.push(("bcast/u1".to_string(), p, g, s));
    out
}

#[test]
fn gs_bandwidth_bounded_by_components_on_every_cpu() {
    let count = 1 << 13;
    for name in ["skx", "bdw", "clx", "naples", "tx2", "knl"] {
        let plat = platforms::by_name(name).unwrap();
        let mut e = OpenMpSim::new(&plat);
        for (tag, gs, g, s) in cases(8, count) {
            let bw_gs = e.run(&gs, Kernel::GS).unwrap().bandwidth_gbs();
            let bw_g = e.run(&g, Kernel::Gather).unwrap().bandwidth_gbs();
            let bw_s = e.run(&s, Kernel::Scatter).unwrap().bandwidth_gbs();
            assert!(
                bw_gs <= bw_g.min(bw_s) * 1.02,
                "{name}/{tag}: GS {bw_gs:.2} must not beat min(gather \
                 {bw_g:.2}, scatter {bw_s:.2})"
            );
            assert!(bw_gs > 0.0 && bw_gs.is_finite(), "{name}/{tag}");
        }
    }
}

#[test]
fn gs_bandwidth_bounded_by_components_on_every_gpu() {
    let count = 1 << 11;
    for name in ["k40c", "titanxp", "p100", "v100"] {
        let plat = platforms::gpu_by_name(name).unwrap();
        let mut e = CudaSim::new(&plat);
        for (tag, gs, g, s) in cases(256, count) {
            let bw_gs = e.run(&gs, Kernel::GS).unwrap().bandwidth_gbs();
            let bw_g = e.run(&g, Kernel::Gather).unwrap().bandwidth_gbs();
            let bw_s = e.run(&s, Kernel::Scatter).unwrap().bandwidth_gbs();
            assert!(
                bw_gs <= bw_g.min(bw_s) * 1.02,
                "{name}/{tag}: GS {bw_gs:.0} must not beat min(gather \
                 {bw_g:.0}, scatter {bw_s:.0})"
            );
        }
    }
}

#[test]
fn lulesh_class_gs_bounded_by_components() {
    // The app-derived pairing: LULESH-G3's stride-24 gather side
    // feeding a stride-1 write side (element→node copy).
    let app = table5::by_name("LULESH-G3").unwrap();
    let count = 1 << 13;
    let (gs, g, s) = components(
        app.indices.to_vec(),
        (0..app.indices.len() as i64).collect(),
        app.delta,
        count,
    );
    for name in ["skx", "tx2"] {
        let plat = platforms::by_name(name).unwrap();
        let mut e = OpenMpSim::new(&plat);
        let bw_gs = e.run(&gs, Kernel::GS).unwrap().bandwidth_gbs();
        let bw_g = e.run(&g, Kernel::Gather).unwrap().bandwidth_gbs();
        let bw_s = e.run(&s, Kernel::Scatter).unwrap().bandwidth_gbs();
        assert!(
            bw_gs <= bw_g.min(bw_s) * 1.02,
            "{name}: GS {bw_gs:.2} vs gather {bw_g:.2} / scatter {bw_s:.2}"
        );
    }
}

#[test]
fn delta0_gs_degrades_with_threads_like_scatter() {
    // LULESH-S3's write shape on the scatter side of an indexed copy:
    // the coherence storm scales with the sharer count, so adding
    // threads *hurts* — same direction as pure delta-0 scatter.
    let gs = Pattern::from_indices("gs-d0", (0..16i64).collect())
        .with_gs_scatter((0..16i64).map(|j| j * 24).collect())
        .with_delta(0)
        .with_count(1 << 14);
    let bw = |name: &str, t: usize| {
        let plat = platforms::by_name(name).unwrap();
        let mut e = CpuEngine::with_options(
            &plat,
            CpuSimOptions {
                threads: Some(t),
                ..Default::default()
            },
        );
        e.run(&gs, Kernel::GS).unwrap().bandwidth_gbs()
    };
    for name in ["skx", "bdw", "knl"] {
        let t1 = bw(name, 1);
        let t2 = bw(name, 2);
        let tmax = bw(name, platforms::by_name(name).unwrap().threads);
        assert!(
            t2 < t1,
            "{name}: contention must kick in at t=2: {t1:.2} -> {t2:.2}"
        );
        assert!(
            tmax < t2,
            "{name}: and keep degrading to the socket count: \
             {t2:.3} -> {tmax:.3}"
        );
    }
    // TX2 absorbs repeated writes: threads only help.
    let x1 = bw("tx2", 1);
    let x28 = bw("tx2", 28);
    assert!(x28 > x1, "tx2 must not collapse: {x1:.2} -> {x28:.2}");
}

#[test]
fn delta0_gs_and_scatter_share_the_coherence_bottleneck() {
    // At the socket count, both the pure scatter and the GS copy with
    // the same write side must be coherence-bound on SKX.
    let write_side: Vec<i64> = (0..16i64).map(|j| j * 24).collect();
    let scatter = Pattern::from_indices("s3", write_side.clone())
        .with_delta(0)
        .with_count(1 << 14);
    let gs = Pattern::from_indices("gs", (0..16i64).collect())
        .with_gs_scatter(write_side)
        .with_delta(0)
        .with_count(1 << 14);
    let plat = platforms::by_name("skx").unwrap();
    let mut e = OpenMpSim::new(&plat);
    let rs = e.run(&scatter, Kernel::Scatter).unwrap();
    let rgs = e.run(&gs, Kernel::GS).unwrap();
    assert_eq!(rs.breakdown.bottleneck(), "coherence");
    assert_eq!(rgs.breakdown.bottleneck(), "coherence");
    // Identical write-side contention: the coherence event counts match.
    assert_eq!(
        rs.counters.coherence_events, rgs.counters.coherence_events,
        "GS write side must contend exactly like the pure scatter"
    );
}
