//! Differential property test for the batch-compiled access plan
//! (ISSUE 8 tentpole): for randomized (platform, pattern, kernel,
//! threads, page-size, interleave, closure) configurations, the
//! engines must produce *exactly* the same `SimResult` — counters,
//! breakdown, seconds, bandwidth — with the plan force-disabled (the
//! scalar reference path) and force-enabled. The plan is an
//! optimization, never an approximation: same-line run coalescing,
//! batched TLB accounting, and the monomorphized hot loops may not
//! move a single counter.
//!
//! Loop closure is randomized (drawn once, equal in both arms) and
//! `closed_at_iteration` is compared too: plans must leave the
//! iteration-boundary state bit-identical, so closure fires at the
//! same iteration either way. The closure on/off axis itself is
//! pinned by `tests/closure_equivalence.rs`.

use spatter::pattern::{table5, Kernel, Pattern, StreamOp};
use spatter::platforms;
use spatter::prop::{check, Gen};
use spatter::sim::cpu::{CpuEngine, CpuSimOptions};
use spatter::sim::gpu::{GpuEngine, GpuSimOptions};
use spatter::sim::{InterleavePolicy, NumaPlacement, PageSize, SimResult};

fn assert_identical(planned: &SimResult, scalar: &SimResult, ctx: &str) {
    assert_eq!(planned.counters, scalar.counters, "{ctx}: counters");
    assert_eq!(planned.breakdown, scalar.breakdown, "{ctx}: breakdown");
    assert_eq!(planned.seconds, scalar.seconds, "{ctx}: seconds");
    assert_eq!(
        planned.bandwidth_gbs(),
        scalar.bandwidth_gbs(),
        "{ctx}: bandwidth"
    );
    assert_eq!(
        planned.simulated_iterations, scalar.simulated_iterations,
        "{ctx}: simulated iterations"
    );
    // The plan must preserve the closure fingerprint stream exactly:
    // closure fires at the same iteration (or not at all) either way.
    assert_eq!(
        planned.closed_at_iteration, scalar.closed_at_iteration,
        "{ctx}: closure must fire identically under the plan"
    );
}

/// The whole kernel family, GUPS included — its plan dispatch is a
/// no-op (the RNG stream can't be precompiled), and that no-op must
/// hold the contract too.
fn arbitrary_kernel(g: &mut Gen) -> Kernel {
    *g.choose(&[
        Kernel::Gather,
        Kernel::Scatter,
        Kernel::GS,
        Kernel::Stream(StreamOp::Copy),
        Kernel::Stream(StreamOp::Scale),
        Kernel::Stream(StreamOp::Add),
        Kernel::Stream(StreamOp::Triad),
        Kernel::Gups,
    ])
}

/// Shape the drawn pattern for the kernel (see
/// `tests/closure_equivalence.rs`, which this mirrors).
fn with_kernel_shape(g: &mut Gen, pat: Pattern, kernel: Kernel) -> Pattern {
    match kernel {
        Kernel::GS => {
            let v = pat.vector_len();
            let side = match g.usize_in(0, 2) {
                0 => {
                    let s = g.i64_in(1, 24);
                    (0..v as i64).map(|j| j * s).collect()
                }
                1 => vec![0; v],
                _ => (0..v).map(|_| g.i64_in(0, 2048)).collect(),
            };
            pat.with_gs_scatter(side)
        }
        Kernel::Stream(_) => {
            Pattern::dense(*g.choose(&[4usize, 8, 16, 32]), pat.count)
        }
        Kernel::Gups => Pattern::gups(1 << g.usize_in(10, 18), pat.count),
        _ => pat,
    }
}

/// Pattern families weighted toward the plan's interesting cases:
/// dense same-line runs (delta-0 revisits, stride-1), line-straddling
/// strides, page-walking deltas, irregular buffers (singleton runs
/// everywhere), and the Table-5 proxies.
fn arbitrary_pattern(g: &mut Gen, v_cap: usize) -> Pattern {
    match g.usize_in(0, 4) {
        0 => {
            // Delta-0: total revisit, maximal same-line runs.
            let v = g.usize_in(1, v_cap);
            Pattern::from_indices(
                "d0",
                (0..v as i64).map(|i| i * g.i64_in(1, 8)).collect(),
            )
            .with_delta(0)
        }
        1 => {
            let s = 1usize << g.usize_in(0, 6);
            let v = g.usize_in(1, v_cap);
            Pattern::from_indices(
                "ustride",
                (0..v as i64).map(|i| i * s as i64).collect(),
            )
            .with_delta((v * s) as i64)
        }
        2 => {
            // Huge delta: fresh pages every iteration (PENNANT shape).
            Pattern::from_indices(
                "huge",
                (0..16i64).map(|j| j * 512).collect(),
            )
            .with_delta(g.i64_in(1, 4) * 16384)
        }
        3 => {
            // Cycling delta list: the base walks through unaligned
            // residues, exercising the plan's scalar fallback (and its
            // flip back to the coalesced body when realigned).
            let v = g.usize_in(2, v_cap);
            let idx: Vec<i64> = (0..v).map(|_| g.i64_in(0, 2048)).collect();
            let jump = g.i64_in(0, 512);
            Pattern::from_indices("rand", idx).with_deltas(&[0, 3, 0, jump])
        }
        _ => {
            let name = *g.choose(&["AMG-G0", "LULESH-S1", "LULESH-S3"]);
            let app = table5::by_name(name).unwrap();
            Pattern::from_indices(app.name, app.indices.to_vec())
                .with_delta(app.delta)
        }
    }
}

#[test]
fn prop_cpu_plan_equivalence() {
    check("CPU: plan on == plan off, exactly", 20, |g| {
        // Two-socket variants and both placement policies ride along:
        // the plan's coalesced bulk paths route node classification
        // through the same single DRAM-facing hook as the scalar path,
        // and may not move a numa counter (ISSUE 10 tentpole).
        let mut plat = platforms::by_name(*g.choose(&[
            "skx", "bdw", "naples", "tx2", "knl", "clx", "skx-2s",
            "tx2-2s", "naples-2s",
        ]))
        .unwrap();
        plat.dram.interleave = *g.choose(InterleavePolicy::ALL);
        let numa_placement = *g.choose(NumaPlacement::ALL);
        let kernel = arbitrary_kernel(g);
        let page = *g.choose(&[PageSize::FourKB, PageSize::TwoMB]);
        let threads = if g.bool() {
            None
        } else {
            Some(g.usize_in(1, 8))
        };
        // The regime only rescales the analytic timing after counter
        // collection, so the plan contract must hold on every rung the
        // platform's ISA supports (drawn once, equal in both arms).
        let regime = if g.bool() {
            None
        } else {
            Some(*g.choose(&plat.supported_regimes()))
        };
        let prefetch_enabled = g.bool();
        let closure_enabled = g.bool();
        let pat = with_kernel_shape(
            g,
            arbitrary_pattern(g, 16).with_count(1 << g.usize_in(8, 13)),
            kernel,
        );
        let run = |plan_enabled: bool| {
            let mut e = CpuEngine::with_options(
                &plat,
                CpuSimOptions {
                    plan_enabled,
                    closure_enabled,
                    prefetch_enabled,
                    page_size: page,
                    threads,
                    regime,
                    numa_placement,
                    ..Default::default()
                },
            );
            e.run(&pat, kernel).unwrap()
        };
        let planned = run(true);
        let scalar = run(false);
        assert_identical(
            &planned,
            &scalar,
            &format!(
                "{} {:?} {} pf={prefetch_enabled} closure={closure_enabled} \
                 regime={regime:?} numa={}",
                plat.name,
                kernel,
                pat.spec,
                numa_placement.name()
            ),
        );
    });
}

#[test]
fn prop_gpu_plan_equivalence() {
    check("GPU: plan on == plan off, exactly", 14, |g| {
        let mut plat = platforms::gpu_by_name(
            *g.choose(&["k40c", "titanxp", "p100", "v100"]),
        )
        .unwrap();
        plat.dram.interleave = *g.choose(InterleavePolicy::ALL);
        let kernel = arbitrary_kernel(g);
        let page = *g.choose(&[PageSize::SixtyFourKB, PageSize::TwoMB]);
        let closure_enabled = g.bool();
        let pat = with_kernel_shape(
            g,
            arbitrary_pattern(g, 64).with_count(1 << g.usize_in(6, 11)),
            kernel,
        );
        let run = |plan_enabled: bool| {
            let mut e = GpuEngine::with_options(
                &plat,
                GpuSimOptions {
                    plan_enabled,
                    closure_enabled,
                    page_size: page,
                    ..Default::default()
                },
            );
            e.run(&pat, kernel).unwrap()
        };
        let planned = run(true);
        let scalar = run(false);
        assert_identical(
            &planned,
            &scalar,
            &format!(
                "{} {:?} {} closure={closure_enabled}",
                plat.name, kernel, pat.spec
            ),
        );
    });
}

/// Deterministic anchors for the two bench workloads the plan targets:
/// the plan must match the scalar path exactly on the duplicate-heavy
/// LULESH-S3 scatter and on a stride-1 gather (the maximal-coalescing
/// cases), on both a prefetching and a non-prefetching platform.
#[test]
fn plan_matches_scalar_on_bench_workloads() {
    let s3 = table5::by_name("LULESH-S3").unwrap().to_pattern(512);
    let stride1 = Pattern::from_indices("u1", (0..8i64).collect())
        .with_delta(8)
        .with_count(1 << 12);
    for plat_name in ["skx", "naples"] {
        let plat = platforms::by_name(plat_name).unwrap();
        for (pat, kernel) in
            [(&s3, Kernel::Scatter), (&stride1, Kernel::Gather)]
        {
            let run = |plan_enabled: bool| {
                let mut e = CpuEngine::with_options(
                    &plat,
                    CpuSimOptions {
                        plan_enabled,
                        closure_enabled: true,
                        ..Default::default()
                    },
                );
                e.run(pat, kernel).unwrap()
            };
            assert_identical(
                &run(true),
                &run(false),
                &format!("{plat_name} {kernel:?} {}", pat.spec),
            );
        }
    }
}

/// `SPATTER_NO_PLAN=1` must force-disable the plan through the default
/// options (the sibling of `SPATTER_NO_CLOSURE`/`SPATTER_NO_MEMO`).
/// Env mutation is race-safe here: the plan is bit-identical on or
/// off, so a concurrent test observing either default still passes.
#[test]
fn spatter_no_plan_env_disables_plan() {
    std::env::remove_var("SPATTER_NO_PLAN");
    assert!(
        CpuSimOptions::default().plan_enabled,
        "plan defaults on without the env var"
    );
    assert!(GpuSimOptions::default().plan_enabled);
    std::env::set_var("SPATTER_NO_PLAN", "1");
    assert!(!CpuSimOptions::default().plan_enabled);
    assert!(!GpuSimOptions::default().plan_enabled);
    std::env::remove_var("SPATTER_NO_PLAN");
    assert!(CpuSimOptions::default().plan_enabled);
}
