//! Differential tests for the NUMA topology subsystem (ISSUE 10
//! tentpole). Three contracts:
//!
//! 1. **Single-socket inertness** — on every single-socket CPU
//!    platform the topology is a pass-through: both placement policies
//!    produce bit-identical `SimResult`s and move no numa counters, so
//!    pre-NUMA numbers are reproduced exactly.
//! 2. **Monotone remote penalty** — on every two-socket platform,
//!    dialing the engineered pattern's remote fraction up under
//!    interleave placement strictly raises the remote access count and
//!    cuts bandwidth; the all-remote run always trails the all-local
//!    one.
//! 3. **Placement ordering** — on a contended delta-0 scatter whose
//!    shared footprint dwarfs the L3, first-touch (whole footprint
//!    homed on node 0) loses to interleave (pages spread across both
//!    memory controllers).
//!
//! Plus `--jobs` invariance of the records a NUMA sweep produces.

use spatter::backends::{Backend, OpenMpSim};
use spatter::coordinator::{render_json, run_configs_jobs, RunConfig};
use spatter::error::Result;
use spatter::pattern::{table5, Kernel, Pattern};
use spatter::platforms;
use spatter::sim::cpu::{CpuEngine, CpuSimOptions};
use spatter::sim::{NumaPlacement, SimResult};
use spatter::suite::{ratio_pattern, REMOTE_LANES};

const SINGLE_SOCKET: &[&str] = &["skx", "bdw", "naples", "tx2", "knl", "clx"];
const TWO_SOCKET: &[&str] = &["skx-2s", "tx2-2s", "naples-2s"];

fn assert_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.counters, b.counters, "{ctx}: counters");
    assert_eq!(a.breakdown, b.breakdown, "{ctx}: breakdown");
    assert_eq!(a.seconds, b.seconds, "{ctx}: seconds");
    assert_eq!(a.bandwidth_gbs(), b.bandwidth_gbs(), "{ctx}: bandwidth");
    assert_eq!(
        a.closed_at_iteration, b.closed_at_iteration,
        "{ctx}: closure"
    );
}

/// Workloads spanning the node-classification paths: a dense gather,
/// a shared (delta-0) scatter, a GS pair, and the GUPS table.
fn workloads() -> Vec<(Pattern, Kernel)> {
    vec![
        (
            Pattern::parse("UNIFORM:8:1")
                .unwrap()
                .with_delta(8)
                .with_count(1 << 12),
            Kernel::Gather,
        ),
        (
            table5::by_name("LULESH-S3").unwrap().to_pattern(1 << 12),
            Kernel::Scatter,
        ),
        (
            Pattern::parse("UNIFORM:8:4")
                .unwrap()
                .with_gs_scatter((0..8).collect())
                .with_delta(32)
                .with_count(1 << 12),
            Kernel::GS,
        ),
        (Pattern::gups(1 << 20, 1 << 10), Kernel::Gups),
    ]
}

fn run_with(
    name: &str,
    placement: NumaPlacement,
    pat: &Pattern,
    kernel: Kernel,
) -> SimResult {
    let plat = platforms::by_name(name).unwrap();
    let mut e = CpuEngine::with_options(
        &plat,
        CpuSimOptions {
            numa_placement: placement,
            ..Default::default()
        },
    );
    e.run(pat, kernel).unwrap()
}

#[test]
fn single_socket_platforms_are_placement_inert() {
    for &name in SINGLE_SOCKET {
        for (pat, kernel) in workloads() {
            let ft = run_with(name, NumaPlacement::FirstTouch, &pat, kernel);
            let il = run_with(name, NumaPlacement::Interleave, &pat, kernel);
            let ctx = format!("{name} {kernel:?} {}", pat.spec);
            assert_identical(&ft, &il, &ctx);
            // The pass-through moves no node counters at all, so
            // records keep the pre-NUMA JSON shape ("numa": null).
            assert_eq!(ft.counters.numa_local, 0, "{ctx}: local");
            assert_eq!(ft.counters.numa_remote, 0, "{ctx}: remote");
            assert_eq!(ft.counters.numa_contended, 0, "{ctx}: contended");
        }
    }
}

#[test]
fn remote_fraction_penalty_is_monotone_on_two_socket_platforms() {
    for &name in TWO_SOCKET {
        let plat = platforms::by_name(name).unwrap();
        let sweep: Vec<SimResult> = REMOTE_LANES
            .iter()
            .map(|&k| {
                let mut e = CpuEngine::with_options(
                    &plat,
                    CpuSimOptions {
                        prefetch_enabled: false,
                        numa_placement: NumaPlacement::Interleave,
                        ..Default::default()
                    },
                );
                e.run(&ratio_pattern(k, 1 << 12), Kernel::Gather).unwrap()
            })
            .collect();
        // Remote traffic rises strictly with the remote lane count,
        // and local traffic falls.
        for w in sweep.windows(2) {
            assert!(
                w[1].counters.numa_remote > w[0].counters.numa_remote,
                "{name}: remote must rise: {:?} -> {:?}",
                w[0].counters.numa_remote,
                w[1].counters.numa_remote
            );
            assert!(
                w[1].counters.numa_local < w[0].counters.numa_local,
                "{name}: local must fall"
            );
        }
        // Every partially- or fully-remote run trails the all-local
        // run; the endpoints (structurally identical: one page per
        // iteration, only the home node differs) order strictly.
        let bw: Vec<f64> =
            sweep.iter().map(|r| r.bandwidth_gbs()).collect();
        for (i, &b) in bw.iter().enumerate().skip(1) {
            assert!(
                b < bw[0],
                "{name}: remote fraction {i}/4 must trail all-local: \
                 {b:.3} vs {:.3}",
                bw[0]
            );
        }
        assert!(
            bw[bw.len() - 1] < bw[1],
            "{name}: all-remote must trail the lightest mixed run"
        );
    }
    // On skx-2s the sweep is DRAM-bound throughout, so the decline is
    // strictly monotone step by step.
    let plat = platforms::by_name("skx-2s").unwrap();
    let bw: Vec<f64> = REMOTE_LANES
        .iter()
        .map(|&k| {
            let mut e = CpuEngine::with_options(
                &plat,
                CpuSimOptions {
                    prefetch_enabled: false,
                    numa_placement: NumaPlacement::Interleave,
                    ..Default::default()
                },
            );
            e.run(&ratio_pattern(k, 1 << 12), Kernel::Gather)
                .unwrap()
                .bandwidth_gbs()
        })
        .collect();
    for w in bw.windows(2) {
        assert!(
            w[1] < w[0],
            "skx-2s: strictly monotone decline expected: {bw:?}"
        );
    }
}

#[test]
fn first_touch_loses_to_interleave_on_a_contended_scatter() {
    // Delta-0 shared scatter, 64 MiB footprint (past every L3), one
    // access per cache line: under first-touch the whole footprint is
    // homed on node 0 and both sockets fight for one memory
    // controller; interleave spreads the pages.
    let pat = Pattern::from_indices(
        "contended-scatter",
        (0..1i64 << 17).map(|i| i * 64).collect(),
    )
    .with_delta(0)
    .with_count(8);
    for &name in TWO_SOCKET {
        let ft = run_with(name, NumaPlacement::FirstTouch, &pat, Kernel::Scatter);
        let il = run_with(name, NumaPlacement::Interleave, &pat, Kernel::Scatter);
        assert!(
            ft.counters.numa_contended > 0,
            "{name}: first-touch must see the shared-footprint contention"
        );
        assert_eq!(
            il.counters.numa_contended, 0,
            "{name}: interleave spreads instead of contending"
        );
        assert!(
            ft.bandwidth_gbs() < il.bandwidth_gbs(),
            "{name}: first-touch {:.3} must trail interleave {:.3}",
            ft.bandwidth_gbs(),
            il.bandwidth_gbs()
        );
    }
}

#[test]
fn numa_records_are_jobs_invariant() {
    let plat = platforms::by_name("skx-2s").unwrap();
    let mut configs = Vec::new();
    for placement in [NumaPlacement::FirstTouch, NumaPlacement::Interleave] {
        for &k in REMOTE_LANES {
            configs.push(RunConfig {
                name: format!("{}/r{k}", placement.name()),
                kernel: Kernel::Gather,
                pattern: ratio_pattern(k, 1 << 10),
                page_size: None,
                threads: None,
                regime: None,
                placement: Some(placement),
            });
        }
    }
    let factory = || -> Result<Box<dyn Backend>> {
        Ok(Box::new(OpenMpSim::without_prefetch(&plat)))
    };
    let r1 = run_configs_jobs(&factory, &configs, 1).unwrap();
    let r3 = run_configs_jobs(&factory, &configs, 3).unwrap();
    assert_eq!(
        render_json(&r1),
        render_json(&r3),
        "numa records must be byte-identical for any --jobs"
    );
}
