//! Cross-module integration tests: pattern language → coordinator →
//! simulated backends → stats/report, plus the trace pipeline feeding
//! the simulator (the full §2 → §5.4 flow without hardware).

use std::path::Path;

use spatter::backends::{Backend, CudaSim, OpenMpSim, ScalarSim};
use spatter::coordinator::{self, Aggregate};
use spatter::pattern::{table5, Kernel, Pattern};
use spatter::platforms;
use spatter::stats;
use spatter::suite::{self, SuiteContext};
use spatter::trace::extract::extract_from_trace;
use spatter::trace::miniapps;

#[test]
fn json_config_to_simulated_run_to_aggregate() {
    let cfg = r#"[
      {"name": "stream", "kernel": "Gather", "pattern": "UNIFORM:8:1",
       "delta": 8, "count": 65536},
      {"name": "strided", "kernel": "Gather", "pattern": "UNIFORM:8:16",
       "delta": 128, "count": 65536},
      {"name": "lulesh", "kernel": "Scatter", "pattern": "LULESH-S1",
       "count": 65536},
      {"name": "laplacian", "kernel": "Gather",
       "pattern": "LAPLACIAN:2:1:100", "delta": 1, "count": 65536}
    ]"#;
    let configs = coordinator::parse_config_text(cfg).unwrap();
    let p = platforms::by_name("clx").unwrap();
    let mut backend = OpenMpSim::new(&p);
    let records = coordinator::run_configs(&mut backend, &configs).unwrap();
    assert_eq!(records.len(), 4);
    // stream >> strided
    assert!(records[0].bandwidth_gbs > 4.0 * records[1].bandwidth_gbs);
    // Laplacian with delta 1 has massive reuse: beats STREAM.
    assert!(records[3].bandwidth_gbs > p.stream_gbs);
    let agg = Aggregate::from_records(&records).unwrap();
    assert!(agg.min_gbs <= agg.harmonic_mean_gbs);
    assert!(agg.harmonic_mean_gbs <= agg.max_gbs);
}

#[test]
fn trace_extraction_feeds_simulator() {
    // Extract the top AMG pattern from the emulated trace and run it
    // through the SKX model — it must reproduce the above-STREAM
    // caching behaviour the paper reports for AMG (Table 4).
    let trace = miniapps::amg::matvec_out_of_place(1);
    let pats = extract_from_trace(&trace, 1);
    let pattern = pats[0].to_pattern("amg-extracted", 1 << 18);
    let p = platforms::by_name("skx").unwrap();
    let bw = OpenMpSim::new(&p)
        .run(&pattern, Kernel::Gather)
        .unwrap()
        .bandwidth_gbs();
    assert!(
        bw > p.stream_gbs,
        "extracted AMG pattern should exploit caches: {bw:.1} vs {:.1}",
        p.stream_gbs
    );
}

#[test]
fn every_table5_pattern_runs_on_every_platform() {
    // No pattern x platform combination may error or produce a
    // non-finite bandwidth.
    for pat in table5::all() {
        let runnable = pat.to_pattern(1 << 12);
        for cpu in platforms::cpus() {
            let bw = OpenMpSim::new(&cpu)
                .run(&runnable, pat.kernel)
                .unwrap()
                .bandwidth_gbs();
            assert!(bw.is_finite() && bw > 0.0, "{} on {}", pat.name, cpu.name);
        }
        for gpu in platforms::gpus() {
            let bw = CudaSim::new(&gpu)
                .run(&runnable, pat.kernel)
                .unwrap()
                .bandwidth_gbs();
            assert!(bw.is_finite() && bw > 0.0, "{} on {}", pat.name, gpu.name);
        }
    }
}

#[test]
fn fig6_directional_shape() {
    // The Fig 6 signs: KNL gains a lot from vector G/S, TX2 exactly
    // nothing, Naples nothing on scatter (no scatter instruction).
    let count = 1 << 16;
    let pat = Pattern::parse("UNIFORM:8:2")
        .unwrap()
        .with_delta(16)
        .with_count(count);
    let imp = |name: &str, kernel: Kernel| -> f64 {
        let p = platforms::by_name(name).unwrap();
        let bo = OpenMpSim::new(&p).run(&pat, kernel).unwrap().bandwidth_gbs();
        let bs = ScalarSim::new(&p).run(&pat, kernel).unwrap().bandwidth_gbs();
        (bo - bs) / bs * 100.0
    };
    assert!(imp("knl", Kernel::Gather) > 20.0);
    assert!(imp("tx2", Kernel::Gather).abs() < 1e-9);
    assert!(imp("tx2", Kernel::Scatter).abs() < 1e-9);
    assert!(imp("naples", Kernel::Scatter).abs() < 1e-9);
    // In DRAM-bound regimes the backends tie; the scatter-instruction
    // benefit shows where the issue rate binds (cache-resident
    // pattern: stride-2 with delta 1 -> heavy reuse).
    let cached = Pattern::parse("UNIFORM:8:2")
        .unwrap()
        .with_delta(1)
        .with_count(count);
    let p = platforms::by_name("skx").unwrap();
    let bo = OpenMpSim::new(&p)
        .run(&cached, Kernel::Scatter)
        .unwrap()
        .bandwidth_gbs();
    let bs = ScalarSim::new(&p)
        .run(&cached, Kernel::Scatter)
        .unwrap()
        .bandwidth_gbs();
    assert!(bo > bs, "SKX scatter instruction should win when issue-bound: {bo:.1} vs {bs:.1}");
}

#[test]
fn table4_shape_invariants() {
    // Condensed Table 4 checks: per-platform app h-means vs STREAM.
    // The count must be large enough that large-delta patterns'
    // touched-line footprints exceed the caches (the paper moves
    // >= 2 GB per pattern) — at small counts L3 residency would
    // legitimately inflate PENNANT.
    let count = 1 << 20;
    let hmean = |plat: &str, app: &str| -> f64 {
        let p = platforms::by_name(plat).unwrap();
        let bws: Vec<f64> = table5::by_app(app)
            .into_iter()
            .map(|pat| {
                OpenMpSim::new(&p)
                    .run(&pat.to_pattern(count), pat.kernel)
                    .unwrap()
                    .bandwidth_gbs()
            })
            .collect();
        stats::harmonic_mean(&bws).unwrap()
    };
    let skx = platforms::by_name("skx").unwrap();
    // AMG and Nekbone beat STREAM on SKX (caching).
    assert!(hmean("skx", "AMG") > skx.stream_gbs);
    assert!(hmean("skx", "Nekbone") > skx.stream_gbs);
    // LULESH collapses on SKX (S3) but not on TX2.
    let tx2 = platforms::by_name("tx2").unwrap();
    assert!(hmean("skx", "LULESH") < 0.5 * skx.stream_gbs);
    assert!(hmean("tx2", "LULESH") > 0.5 * tx2.stream_gbs);
    // PENNANT is far below STREAM everywhere (large deltas).
    assert!(hmean("skx", "PENNANT") < 0.6 * skx.stream_gbs);
    assert!(hmean("bdw", "PENNANT") < 0.6 * 43.885);
}

#[test]
fn suite_experiments_all_run_fast() {
    let dir = std::env::temp_dir().join("spatter-it-suite");
    let ctx = SuiteContext::fast(&dir);
    for name in suite::EXPERIMENTS {
        let report = suite::run(name, &ctx).unwrap();
        assert!(!report.is_empty(), "{name}");
    }
    // Every experiment must have written its CSV.
    for csv in [
        "fig3_cpu_ustride.csv",
        "fig4_prefetch.csv",
        "fig5_gpu_ustride.csv",
        "fig6_simd_scalar.csv",
        "fig7_radar_gather.csv",
        "fig8_radar_scatter.csv",
        "fig9_bwbw.csv",
        "table1_apps.csv",
        "table4_miniapps.csv",
        "pagesize_sweep.csv",
        "ustride.csv",
        "threadscale.csv",
        "prefetch.csv",
        "baselines.csv",
        "simd.csv",
    ] {
        assert!(dir.join(csv).exists(), "{csv}");
    }
    // The ustride, prefetch, baselines, and simd suites also emit JSON
    // documents.
    assert!(dir.join("ustride.json").exists());
    assert!(dir.join("prefetch.json").exists());
    assert!(dir.join("baselines.json").exists());
    assert!(dir.join("simd.json").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_binary_contract() {
    // The CLI grammar end-to-end through the library entry points
    // (the binary itself is exercised by `main.rs` unit tests).
    use spatter::cli::{parse_args, Command};
    let argv: Vec<String> = "-j cfg.json -a knl --json-out"
        .split_whitespace()
        .map(String::from)
        .collect();
    match parse_args(&argv).unwrap() {
        Command::Json { path, common } => {
            assert_eq!(path, "cfg.json");
            assert!(common.json_out);
            assert_eq!(common.platform, "knl");
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn config_failure_injection() {
    // Malformed configs must fail loudly, not run garbage.
    for bad in [
        r#"[{"kernel": "Gather", "pattern": "UNIFORM:0:1"}]"#,
        r#"[{"kernel": "Gather", "pattern": "MS1:8:9:1"}]"#,
        r#"[{"kernel": "Smear", "pattern": "UNIFORM:8:1"}]"#,
        r#"[{"kernel": "Gather", "pattern": [0, -5]}]"#,
        r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": -2}]"#,
        r#"[{"kernel": "GS", "pattern": "UNIFORM:8:1"}]"#,
        r#"[{"kernel": "GS", "pattern-gather": "UNIFORM:8:1"}]"#,
        r#"[{"kernel": "GS", "pattern-gather": "UNIFORM:8:1",
             "pattern-scatter": "UNIFORM:4:1"}]"#,
        r#"[{"kernel": "Scatter", "pattern": "UNIFORM:8:1",
             "pattern-gather": "UNIFORM:8:1"}]"#,
    ] {
        assert!(
            coordinator::parse_config_text(bad).is_err(),
            "should reject {bad}"
        );
    }
    // Missing file surfaces as a Config error with the path.
    let err = coordinator::parse_config_file(Path::new("/nonexistent/x.json"))
        .unwrap_err();
    assert!(err.to_string().contains("/nonexistent/x.json"));
}

#[test]
fn page_size_knob_cli_and_json_end_to_end() {
    use spatter::cli::{parse_args, Command};
    use spatter::sim::PageSize;

    // CLI: `spatter --page-size 2MB` parses into the common args and
    // builds an engine translating at 2 MiB.
    let argv: Vec<String> =
        "-k Gather -p UNIFORM:16:512 -d 16384 -l 16384 -a knl --page-size 2MB"
            .split_whitespace()
            .map(String::from)
            .collect();
    let (kernel, pattern, page) = match parse_args(&argv).unwrap() {
        Command::Run(r) => (r.kernel, r.pattern, r.common.page_size),
        other => panic!("{other:?}"),
    };
    assert_eq!(page, Some(PageSize::TwoMB));
    let knl = platforms::by_name("knl").unwrap();
    let mut b4k = OpenMpSim::new(&knl);
    let mut b2m = OpenMpSim::with_page_size(&knl, PageSize::TwoMB);
    let r4k = b4k.run(&pattern, kernel).unwrap();
    let r2m = b2m.run(&pattern, kernel).unwrap();
    let m4k = r4k.counters.tlb.miss_rate().unwrap();
    let m2m = r2m.counters.tlb.miss_rate().unwrap();
    assert!(
        m2m < 0.25 * m4k,
        "--page-size 2MB must cut the huge-delta TLB miss rate: \
         {m4k:.4} -> {m2m:.4}"
    );
    assert!(r2m.bandwidth_gbs() > r4k.bandwidth_gbs());

    // JSON: the `"page-size"` key drives the same mechanism through
    // the coordinator, per run.
    let cfg = r#"[
      {"name": "huge-4k", "kernel": "Gather", "pattern": "UNIFORM:16:512",
       "delta": 16384, "count": 16384},
      {"name": "huge-2m", "kernel": "Gather", "pattern": "UNIFORM:16:512",
       "delta": 16384, "count": 16384, "page-size": "2MB"}
    ]"#;
    let configs = coordinator::parse_config_text(cfg).unwrap();
    let mut backend = OpenMpSim::new(&knl);
    let recs = coordinator::run_configs(&mut backend, &configs).unwrap();
    assert_eq!(recs[0].page_size.as_deref(), Some("4KB"));
    assert_eq!(recs[1].page_size.as_deref(), Some("2MB"));
    let miss = |i: usize| 1.0 - recs[i].tlb_hit_rate.unwrap();
    assert!(
        miss(1) < 0.25 * miss(0),
        "JSON page-size must cut the miss rate: {:.4} -> {:.4}",
        miss(0),
        miss(1)
    );
    assert!(recs[1].bandwidth_gbs > recs[0].bandwidth_gbs);
    // The record JSON carries the knob for downstream tooling (output
    // schema is snake_case; the config-file input key is "page-size").
    let j = recs[1].to_json();
    assert_eq!(j.get("page_size").unwrap().as_str().unwrap(), "2MB");
}

#[test]
fn threads_knob_cli_and_json_end_to_end() {
    use spatter::cli::{parse_args, Command};

    // CLI: `--threads 1` parses into the common args and builds an
    // engine that cannot saturate DRAM on a stride-1 stream.
    let argv: Vec<String> =
        "-k Gather -p UNIFORM:8:1 -d 8 -l 65536 -a skx --threads 1"
            .split_whitespace()
            .map(String::from)
            .collect();
    let (kernel, pattern, threads) = match parse_args(&argv).unwrap() {
        Command::Run(r) => (r.kernel, r.pattern, r.common.threads),
        other => panic!("{other:?}"),
    };
    assert_eq!(threads, Some(1));
    let skx = platforms::by_name("skx").unwrap();
    let bw_1 = OpenMpSim::configured(&skx, None, threads)
        .run(&pattern, kernel)
        .unwrap()
        .bandwidth_gbs();
    let bw_full = OpenMpSim::new(&skx)
        .run(&pattern, kernel)
        .unwrap()
        .bandwidth_gbs();
    assert!(
        bw_1 < bw_full,
        "--threads 1 must not saturate: {bw_1:.1} vs {bw_full:.1}"
    );

    // JSON: the `"threads"` key drives the same mechanism per run, and
    // the record JSON reports the modelled count.
    let cfg = r#"[
      {"name": "full", "kernel": "Gather", "pattern": "UNIFORM:8:1",
       "delta": 8, "count": 65536},
      {"name": "one", "kernel": "Gather", "pattern": "UNIFORM:8:1",
       "delta": 8, "count": 65536, "threads": 1}
    ]"#;
    let configs = coordinator::parse_config_text(cfg).unwrap();
    let mut backend = OpenMpSim::new(&skx);
    let recs = coordinator::run_configs(&mut backend, &configs).unwrap();
    assert_eq!(recs[0].threads, Some(16));
    assert_eq!(recs[1].threads, Some(1));
    assert!(recs[1].bandwidth_gbs < recs[0].bandwidth_gbs);
    let j = recs[1].to_json();
    assert_eq!(j.get("threads").unwrap().as_usize().unwrap(), 1);
}

#[test]
fn jobs_scheduler_end_to_end_byte_identical() {
    // The --jobs contract at the outermost library layer: the same
    // config set rendered through the CLI's own table/JSON renderers
    // is byte-identical for serial and parallel execution.
    let cfg = r#"[
      {"name": "stream", "kernel": "Gather", "pattern": "UNIFORM:8:1",
       "delta": 8, "count": 65536},
      {"name": "strided", "kernel": "Gather", "pattern": "UNIFORM:8:16",
       "delta": 128, "count": 65536},
      {"name": "lulesh", "kernel": "Scatter", "pattern": "LULESH-S1",
       "count": 65536},
      {"name": "huge-2m", "kernel": "Gather", "pattern": "UNIFORM:16:512",
       "delta": 16384, "count": 16384, "page-size": "2MB"},
      {"name": "narrow", "kernel": "Gather", "pattern": "UNIFORM:8:1",
       "delta": 8, "count": 65536, "threads": 2}
    ]"#;
    let configs = coordinator::parse_config_text(cfg).unwrap();
    let factory = || -> spatter::Result<Box<dyn Backend>> {
        Ok(Box::new(OpenMpSim::new(&platforms::by_name("clx").unwrap())))
    };
    let serial = coordinator::run_configs_jobs(&factory, &configs, 1).unwrap();
    let parallel = coordinator::run_configs_jobs(&factory, &configs, 8).unwrap();
    assert_eq!(
        coordinator::render_table(&serial),
        coordinator::render_table(&parallel)
    );
    assert_eq!(
        coordinator::render_json(&serial),
        coordinator::render_json(&parallel)
    );
}

#[test]
fn gs_kernel_cli_and_json_end_to_end() {
    use spatter::cli::{parse_args, Command};

    // CLI: -k GS -g/-u parses into a dual-buffer pattern that runs on
    // both simulated engine families.
    let argv: Vec<String> =
        "-k GS -g UNIFORM:8:4 -u UNIFORM:8:1 -d 32 -l 16384 -a skx"
            .split_whitespace()
            .map(String::from)
            .collect();
    let (kernel, pattern) = match parse_args(&argv).unwrap() {
        Command::Run(r) => (r.kernel, r.pattern),
        other => panic!("{other:?}"),
    };
    assert_eq!(kernel, Kernel::GS);
    let skx = platforms::by_name("skx").unwrap();
    let r = OpenMpSim::new(&skx).run(&pattern, kernel).unwrap();
    assert!(r.bandwidth_gbs() > 0.0 && r.bandwidth_gbs().is_finite());
    let v100 = platforms::gpu_by_name("v100").unwrap();
    let gpu_pat = Pattern::parse("UNIFORM:256:4")
        .unwrap()
        .with_gs_scatter((0..256).collect())
        .with_delta(1024)
        .with_count(1 << 11);
    let rg = CudaSim::new(&v100).run(&gpu_pat, Kernel::GS).unwrap();
    assert!(rg.bandwidth_gbs() > 0.0 && rg.bandwidth_gbs().is_finite());

    // JSON: dual-pattern configs run through the coordinator (and the
    // --jobs pool) with full record plumbing.
    let cfg = r#"[
      {"name": "copy", "kernel": "GS", "pattern-gather": "UNIFORM:8:4",
       "pattern-scatter": "UNIFORM:8:1", "delta": 32, "count": 16384},
      {"name": "g-half", "kernel": "Gather", "pattern": "UNIFORM:8:4",
       "delta": 32, "count": 16384},
      {"name": "s-half", "kernel": "Scatter", "pattern": "UNIFORM:8:1",
       "delta": 32, "count": 16384}
    ]"#;
    let configs = coordinator::parse_config_text(cfg).unwrap();
    let factory = || -> spatter::Result<Box<dyn Backend>> {
        Ok(Box::new(OpenMpSim::new(&platforms::by_name("skx").unwrap())))
    };
    let serial = coordinator::run_configs_jobs(&factory, &configs, 1).unwrap();
    let par = coordinator::run_configs_jobs(&factory, &configs, 4).unwrap();
    assert_eq!(
        coordinator::render_table(&serial),
        coordinator::render_table(&par)
    );
    assert_eq!(
        coordinator::render_json(&serial),
        coordinator::render_json(&par)
    );
    // The copy is bounded by its halves, and the record reports both
    // stream payloads.
    assert!(
        serial[0].bandwidth_gbs
            <= serial[1].bandwidth_gbs.min(serial[2].bandwidth_gbs) * 1.02
    );
    let j = serial[0].to_json();
    assert_eq!(j.get("kernel").unwrap().as_str().unwrap(), "GS");
    let payload = (8 * 8 * 16384) as u64;
    assert_eq!(
        j.get("read_bytes").unwrap().as_usize().unwrap() as u64,
        payload
    );
    assert_eq!(
        j.get("write_bytes").unwrap().as_usize().unwrap() as u64,
        payload
    );
}

#[test]
fn gpu_vs_cpu_paper_headline() {
    // "GPUs typically outperform CPUs for these operations" (abstract):
    // absolute stride-1..8 bandwidths on V100 >> any CPU.
    let v100 = platforms::gpu_by_name("v100").unwrap();
    let skx = platforms::by_name("skx").unwrap();
    for stride in [1usize, 4, 8] {
        let gp = Pattern::parse(&format!("UNIFORM:256:{stride}"))
            .unwrap()
            .with_delta(256 * stride as i64)
            .with_count(1 << 12);
        let cp = Pattern::parse(&format!("UNIFORM:8:{stride}"))
            .unwrap()
            .with_delta(8 * stride as i64)
            .with_count(1 << 17);
        let g = CudaSim::new(&v100).run(&gp, Kernel::Gather).unwrap().bandwidth_gbs();
        let c = OpenMpSim::new(&skx).run(&cp, Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(g > 2.0 * c, "stride {stride}: gpu {g:.0} vs cpu {c:.0}");
    }
}
