//! Trace-pipeline invariants: the extractor's clustering is fully
//! deterministic across runs, and it recovers known Table-5
//! (indices, delta) pairs from every mini-app emulator — the §2
//! methodology validated against the paper's own ground truth.

use spatter::pattern::table5;
use spatter::trace::extract::extract_from_trace;
use spatter::trace::miniapps;

#[test]
fn extraction_is_deterministic_across_runs() {
    let a = miniapps::run_all(1);
    let b = miniapps::run_all(1);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.app, y.app);
        assert_eq!(x.kernels.len(), y.kernels.len(), "{}", x.app);
        for (kx, ky) in x.kernels.iter().zip(&y.kernels) {
            let px = extract_from_trace(kx, 0);
            let py = extract_from_trace(ky, 0);
            assert_eq!(px.len(), py.len(), "{}::{}", x.app, kx.kernel);
            for (p, q) in px.iter().zip(&py) {
                assert_eq!(p.kernel, q.kernel, "{}::{}", x.app, kx.kernel);
                assert_eq!(p.indices, q.indices, "{}::{}", x.app, kx.kernel);
                assert_eq!(p.delta, q.delta, "{}::{}", x.app, kx.kernel);
                assert_eq!(p.occurrences, q.occurrences);
                assert_eq!(p.bytes, q.bytes);
                assert_eq!(p.class, q.class);
            }
        }
    }
}

#[test]
fn extraction_ranking_is_by_bytes_descending() {
    for app in miniapps::run_all(1) {
        for k in &app.kernels {
            let pats = extract_from_trace(k, 0);
            assert!(
                pats.windows(2).all(|w| w[0].bytes >= w[1].bytes),
                "{}::{} not ranked by bytes",
                app.app,
                k.kernel
            );
        }
    }
}

#[test]
fn extraction_recovers_table5_pairs_from_every_app() {
    // For every mini-app, at least one extracted cluster must match a
    // Table-5 row exactly: same kernel, same index buffer, same delta.
    for app in miniapps::run_all(1) {
        let known = table5::by_app(app.app);
        assert!(!known.is_empty(), "no Table 5 rows for {}", app.app);
        let mut exact = 0usize;
        for k in &app.kernels {
            for p in extract_from_trace(k, 0) {
                if known.iter().any(|t| {
                    t.kernel == p.kernel
                        && t.indices == p.indices.as_slice()
                        && t.delta == p.delta
                }) {
                    exact += 1;
                }
            }
        }
        assert!(
            exact >= 1,
            "{}: no extracted (kernel, indices, delta) matches Table 5",
            app.app
        );
    }
}
