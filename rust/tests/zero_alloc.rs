//! Counting-allocator guard for the per-access hot path (ISSUE 8
//! satellite): the scratch-buffer invariant in `sim/mod.rs` — prefetch
//! target lists, warp coalescing lists, the pre-scaled byte-offset
//! table, and the compiled access plans all live in engine-owned
//! buffers that are rebuilt in place — is enforced here by a
//! `#[global_allocator]` wrapper, not just by review.
//!
//! Method: run each kernel family once to warm an engine (first runs
//! may legitimately grow scratch capacity), then measure the
//! allocation-event count across a second, identical run. Warm-run
//! allocations are O(log n) — hash-set doubling in the streaming
//! write-density probe, closure bookkeeping — so they stay under a
//! small constant bound, while a single allocation inside the
//! per-access path would show up tens of thousands of times (once per
//! simulated access). The bound below has ~30x headroom over the
//! worst legitimate run and is ~30x below the cheapest per-access
//! leak, so it cannot flake in either direction.
//!
//! This file holds exactly one `#[test]`: the event counter is
//! process-global, and concurrent tests in the same binary would
//! pollute each other's deltas.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use spatter::pattern::{Kernel, Pattern, StreamOp};
use spatter::platforms;
use spatter::sim::cpu::{CpuEngine, CpuSimOptions};
use spatter::sim::gpu::{GpuEngine, GpuSimOptions};

/// Counts allocation *events* (alloc/realloc/alloc_zeroed), not bytes:
/// a per-access leak is a per-access event regardless of size.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn events() -> u64 {
    ALLOC_EVENTS.load(Ordering::Relaxed)
}

/// Warm-run + steady-run allocation-event budget. Legitimate per-run
/// work (write-density hash sets, coherence probes) allocates O(log n)
/// events; anything in the per-access path would cost >= `MIN_ACCESSES`.
const MAX_STEADY_EVENTS: u64 = 2048;
const MIN_ACCESSES: u64 = 60_000;

/// One pattern per kernel family, sized so a run pushes well over
/// `MIN_ACCESSES` accesses through the hot path.
fn family_cases() -> Vec<(Pattern, Kernel)> {
    let count = 1 << 13;
    let ustride = |name: &str, s: i64| {
        Pattern::from_indices(name, (0..8i64).map(|i| i * s).collect())
            .with_delta(8 * s)
            .with_count(count)
    };
    vec![
        (ustride("u2-gather", 2), Kernel::Gather),
        (ustride("u2-scatter", 2), Kernel::Scatter),
        (
            ustride("gs", 1).with_gs_scatter((0..8i64).map(|j| j * 3).collect()),
            Kernel::GS,
        ),
        (Pattern::dense(8, count), Kernel::Stream(StreamOp::Triad)),
        (Pattern::gups(1 << 12, count), Kernel::Gups),
    ]
}

#[test]
fn per_access_path_is_allocation_free_once_warm() {
    // Closure off so every iteration actually executes the per-access
    // path (closure would fast-forward past it); plan pinned on so the
    // planned pass — the new hot path — is what gets audited. The
    // scalar path shares every scratch buffer it uses, so auditing the
    // default path covers both.
    let cpu_opts = CpuSimOptions {
        closure_enabled: false,
        plan_enabled: true,
        ..Default::default()
    };
    let gpu_opts = GpuSimOptions {
        closure_enabled: false,
        plan_enabled: true,
        ..Default::default()
    };
    let skx = platforms::by_name("skx").unwrap();
    let p100 = platforms::gpu_by_name("p100").unwrap();

    for (pat, kernel) in family_cases() {
        let mut e = CpuEngine::with_options(&skx, cpu_opts.clone());
        e.run(&pat, kernel).unwrap(); // warm: scratch grows to size
        let before = events();
        let r = e.run(&pat, kernel).unwrap();
        let delta = events() - before;
        assert!(
            r.counters.accesses >= MIN_ACCESSES,
            "cpu {kernel:?} {}: only {} accesses — too few for the \
             budget argument to hold",
            pat.spec,
            r.counters.accesses
        );
        assert!(
            delta <= MAX_STEADY_EVENTS,
            "cpu {kernel:?} {}: {delta} allocation events across a warm \
             run of {} accesses — something allocates per access",
            pat.spec,
            r.counters.accesses
        );
    }

    for (pat, kernel) in family_cases() {
        let mut e = GpuEngine::with_options(&p100, gpu_opts.clone());
        e.run(&pat, kernel).unwrap();
        let before = events();
        let r = e.run(&pat, kernel).unwrap();
        let delta = events() - before;
        assert!(
            r.counters.accesses >= MIN_ACCESSES,
            "gpu {kernel:?} {}: only {} accesses — too few for the \
             budget argument to hold",
            pat.spec,
            r.counters.accesses
        );
        assert!(
            delta <= MAX_STEADY_EVENTS,
            "gpu {kernel:?} {}: {delta} allocation events across a warm \
             run of {} accesses — something allocates per access",
            pat.spec,
            r.counters.accesses
        );
    }
}
