//! Differential tests over the vectorization-regime axis (paper §5.3,
//! Fig 6): per-platform regime orderings at small uniform strides, the
//! BDW microcoded-gather inversion, and byte-exact determinism of
//! regime-mixed campaigns across `--jobs` widths.

use spatter::backends::{Backend, OpenMpSim};
use spatter::coordinator::{
    parse_config_text, render_json, render_table, run_configs_jobs,
};
use spatter::error::Result;
use spatter::pattern::{table5, Kernel, Pattern};
use spatter::platforms::{self, VectorRegime};

const CPUS: &[&str] = &["knl", "bdw", "skx", "clx", "naples", "tx2"];

fn ustride(stride: usize, count: usize) -> Pattern {
    Pattern::parse(&format!("UNIFORM:8:{stride}"))
        .unwrap()
        .with_delta(8 * stride as i64)
        .with_count(count)
}

fn bw(
    backend: &mut OpenMpSim,
    regime: VectorRegime,
    pattern: &Pattern,
    kernel: Kernel,
) -> f64 {
    backend.set_vector_regime(Some(regime));
    let bw = backend.run(pattern, kernel).unwrap().bandwidth_gbs();
    backend.set_vector_regime(None);
    bw
}

/// Scalar <= EmulatedGather <= HardwareGS (and Scalar <= MaskedSve on
/// TX2) for gather at small strides, on every CPU except BDW — whose
/// microcoded gather is the paper's documented inversion, pinned in
/// [`bdw_scalar_beats_microcoded_gather`].
#[test]
fn gather_bandwidth_is_monotone_in_the_regime_ladder() {
    for &name in CPUS {
        if name == "bdw" {
            continue;
        }
        let p = platforms::by_name(name).unwrap();
        let mut b = OpenMpSim::new(&p);
        for &stride in &[1usize, 2, 4] {
            let pat = ustride(stride, 1 << 16);
            let ladder: Vec<f64> = p
                .supported_regimes()
                .iter()
                .map(|&r| bw(&mut b, r, &pat, Kernel::Gather))
                .collect();
            for w in ladder.windows(2) {
                assert!(
                    w[1] >= w[0] * (1.0 - 1e-9),
                    "{name} s{stride}: regime ladder must not descend: \
                     {ladder:?}"
                );
            }
        }
    }
}

/// Scatter never descends along the ladder on *any* CPU — platforms
/// without a hardware scatter instruction (BDW, Naples under
/// EmulatedGather) fall back to the scalar path exactly, so their
/// rungs tie rather than invert.
#[test]
fn scatter_bandwidth_is_monotone_on_every_cpu() {
    for &name in CPUS {
        let p = platforms::by_name(name).unwrap();
        let mut b = OpenMpSim::new(&p);
        let pat = ustride(2, 1 << 16);
        let ladder: Vec<f64> = p
            .supported_regimes()
            .iter()
            .map(|&r| bw(&mut b, r, &pat, Kernel::Scatter))
            .collect();
        for w in ladder.windows(2) {
            assert!(
                w[1] >= w[0] * (1.0 - 1e-9),
                "{name}: scatter ladder must not descend: {ladder:?}"
            );
        }
        // No-scatter-instruction ISAs tie exactly with scalar.
        if name == "bdw" || name == "naples" {
            assert_eq!(ladder[0], ladder[1], "{name}: {ladder:?}");
        }
    }
}

/// The Fig 6 BDW inversion through the backend trait: on the
/// cache-resident AMG-G0 gather, issue rate binds and the microcoded
/// AVX2 gather (2.8 cycles/elem) loses to plain scalar loads
/// (2.2 cycles/elem).
#[test]
fn bdw_scalar_beats_microcoded_gather() {
    let p = platforms::by_name("bdw").unwrap();
    let mut b = OpenMpSim::new(&p);
    let pat = table5::by_name("AMG-G0").unwrap().to_pattern(1 << 16);
    let emul = bw(&mut b, VectorRegime::EmulatedGather, &pat, Kernel::Gather);
    let scal = bw(&mut b, VectorRegime::Scalar, &pat, Kernel::Gather);
    assert!(
        scal > emul,
        "BDW scalar {scal:.2} must beat microcoded gather {emul:.2}"
    );
    // And KNL is the opposite pole: hardware G/S dwarfs scalar issue.
    let knl = platforms::by_name("knl").unwrap();
    let mut b = OpenMpSim::new(&knl);
    let pat = ustride(1, 1 << 16);
    let hw = bw(&mut b, VectorRegime::HardwareGS, &pat, Kernel::Gather);
    let scal = bw(&mut b, VectorRegime::Scalar, &pat, Kernel::Gather);
    assert!(hw > 1.3 * scal, "KNL {hw:.1} vs scalar {scal:.1}");
}

/// A campaign mixing per-run `"vector-regime"` overrides with default
/// runs renders byte-identically at every `--jobs` width, and each
/// record reports the regime it actually modelled.
#[test]
fn regime_mixed_campaign_is_jobs_deterministic() {
    let cfgs = parse_config_text(
        r#"[
          {"name": "native", "kernel": "Gather", "pattern": "UNIFORM:8:2",
           "delta": 16, "count": 16384},
          {"name": "sca", "kernel": "Gather", "pattern": "UNIFORM:8:2",
           "delta": 16, "count": 16384, "vector-regime": "scalar"},
          {"name": "emu", "kernel": "Gather", "pattern": "UNIFORM:8:2",
           "delta": 16, "count": 16384,
           "vector-regime": "emulated-gather"},
          {"name": "hw-t4", "kernel": "Scatter", "pattern": "UNIFORM:8:1",
           "delta": 8, "count": 16384, "threads": 4,
           "vector-regime": "hardware-gs"},
          {"name": "sca-again", "kernel": "Gather",
           "pattern": "UNIFORM:8:2", "delta": 16, "count": 16384,
           "vector-regime": "scalar"}
        ]"#,
    )
    .unwrap();
    let factory = || -> Result<Box<dyn Backend>> {
        Ok(Box::new(OpenMpSim::new(&platforms::by_name("skx").unwrap())))
    };
    let serial = run_configs_jobs(&factory, &cfgs, 1).unwrap();
    let regimes: Vec<Option<&str>> =
        serial.iter().map(|r| r.vector_regime.as_deref()).collect();
    assert_eq!(
        regimes,
        vec![
            Some("hardware-gs"),
            Some("scalar"),
            Some("emulated-gather"),
            Some("hardware-gs"),
            Some("scalar"),
        ]
    );
    // The duplicate scalar config memo-labels against its twin; the
    // native-regime run must NOT alias it (distinct fingerprints).
    assert_eq!(serial[4].memo, Some(1));
    assert_eq!(serial[1].memo, None);
    assert_eq!(serial[0].memo, None);
    for jobs in [2, 3, 8] {
        let par = run_configs_jobs(&factory, &cfgs, jobs).unwrap();
        assert_eq!(
            render_json(&serial),
            render_json(&par),
            "jobs={jobs}"
        );
        assert_eq!(render_table(&serial), render_table(&par), "jobs={jobs}");
    }
}
