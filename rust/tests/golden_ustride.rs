//! Golden snapshots for the `ustride` suite's fast-mode table and JSON
//! output, pinning the seed numerics: a refactor that silently shifts
//! the simulator's numbers fails here, not in a downstream figure.
//!
//! Protocol (see `tests/golden/README.md`): missing golden files are
//! blessed on first run (so a fresh checkout bootstraps itself);
//! existing files are compared byte-for-byte. Regenerate intentionally
//! with `SPATTER_UPDATE_GOLDEN=1 cargo test golden`.

use std::fs;
use std::path::PathBuf;

use spatter::suite::{self, SuiteContext};

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Compare `actual` against the committed snapshot, blessing it when
/// the snapshot is absent or `SPATTER_UPDATE_GOLDEN` is set.
fn check_golden(name: &str, actual: &str) {
    let path = golden_dir().join(name);
    let bless = std::env::var_os("SPATTER_UPDATE_GOLDEN").is_some();
    if bless || !path.exists() {
        fs::create_dir_all(golden_dir()).unwrap();
        fs::write(&path, actual).unwrap();
        eprintln!("golden: blessed {}", path.display());
        return;
    }
    let expected = fs::read_to_string(&path).unwrap();
    assert!(
        expected == actual,
        "golden mismatch for {name}: the suite's numerics shifted.\n\
         If intentional, regenerate with SPATTER_UPDATE_GOLDEN=1 and commit \
         the new snapshot.\n--- expected ---\n{expected}\n--- actual ---\n{actual}"
    );
}

#[test]
fn ustride_fast_table_and_json_snapshots() {
    let out = std::env::temp_dir().join("spatter-golden-ustride");
    // jobs = 1 here is arbitrary: output is jobs-invariant by the
    // scheduler contract (pinned separately by the determinism tests).
    let ctx = SuiteContext::fast(&out).with_jobs(1);
    let report = suite::run("ustride", &ctx).unwrap();
    let json = fs::read_to_string(out.join("ustride.json")).unwrap();
    let csv = fs::read_to_string(out.join("ustride.csv")).unwrap();

    check_golden("ustride_fast_table.txt", &report);
    check_golden("ustride_fast.json", &json);
    check_golden("ustride_fast.csv", &csv);

    // Re-running the suite must reproduce the bytes exactly — the
    // snapshot is meaningful only because the output is deterministic.
    let report2 = suite::run("ustride", &ctx).unwrap();
    assert_eq!(report, report2);
    let json2 = fs::read_to_string(out.join("ustride.json")).unwrap();
    assert_eq!(json, json2);
    fs::remove_dir_all(&out).ok();
}
