//! Property-based invariants over the coordinator substrates, using
//! the in-crate `prop` mini-framework (no proptest in the offline
//! vendor set).

use spatter::coordinator::{parse_config_text, RunConfig};
use spatter::json;
use spatter::pattern::{self, Kernel, Pattern};
use spatter::platforms;
use spatter::prop::{check, Gen};
use spatter::sim::cpu::CpuEngine;
use spatter::sim::Cache;
use spatter::stats;
use spatter::trace::extract::extract_patterns;
use spatter::trace::GsRecord;

// ---------------------------------------------------------------------------
// JSON: parse(write(v)) == v
// ---------------------------------------------------------------------------

fn arbitrary_json(g: &mut Gen, depth: usize) -> json::Value {
    use json::Value;
    let pick = if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) };
    match pick {
        0 => Value::Null,
        1 => Value::Bool(g.bool()),
        2 => {
            // representable numbers only (the writer normalizes ints)
            if g.bool() {
                Value::Number(g.i64_in(-1_000_000, 1_000_000) as f64)
            } else {
                Value::Number((g.i64_in(-1000, 1000) as f64) / 8.0)
            }
        }
        3 => {
            let len = g.usize_in(0, 8);
            let s: String = (0..len)
                .map(|_| char::from(g.usize_in(32, 126) as u8))
                .collect();
            Value::String(s)
        }
        4 => {
            let n = g.usize_in(0, 4);
            Value::Array((0..n).map(|_| arbitrary_json(g, depth - 1)).collect())
        }
        _ => {
            let n = g.usize_in(0, 4);
            let mut m = std::collections::BTreeMap::new();
            for i in 0..n {
                m.insert(format!("k{i}"), arbitrary_json(g, depth - 1));
            }
            Value::Object(m)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    check("json parse∘write == id", 200, |g| {
        let v = arbitrary_json(g, 3);
        let compact = json::parse(&json::to_string(&v)).unwrap();
        assert_eq!(compact, v);
        let pretty = json::parse(&json::to_string_pretty(&v)).unwrap();
        assert_eq!(pretty, v);
    });
}

// ---------------------------------------------------------------------------
// Pattern language
// ---------------------------------------------------------------------------

#[test]
fn prop_uniform_spec_roundtrip() {
    check("UNIFORM spec -> indices -> properties", 100, |g| {
        let n = g.usize_in(1, 64);
        let s = g.usize_in(1, 64);
        let idx = pattern::parse_spec(&format!("UNIFORM:{n}:{s}")).unwrap();
        assert_eq!(idx.len(), n);
        assert_eq!(idx[0], 0);
        assert!(idx.windows(2).all(|w| w[1] - w[0] == s as i64));
    });
}

#[test]
fn prop_custom_spec_roundtrip() {
    check("custom index list roundtrips through spec parsing", 100, |g| {
        let idx = g.vec_of(1, 24, |g| g.i64_in(0, 10_000));
        let spec = idx
            .iter()
            .map(|i| i.to_string())
            .collect::<Vec<_>>()
            .join(",");
        assert_eq!(pattern::parse_spec(&spec).unwrap(), idx);
    });
}

#[test]
fn prop_required_elements_bounds_addresses() {
    check("required_elements covers every generated address", 100, |g| {
        let idx = g.vec_of(1, 16, |g| g.i64_in(0, 512));
        let p = Pattern::from_indices("t", idx)
            .with_delta(g.i64_in(0, 64))
            .with_count(g.usize_in(1, 256));
        let n = p.required_elements() as i64;
        for i in [0, p.count / 2, p.count - 1] {
            for j in 0..p.vector_len() {
                let a = p.address(i, j);
                assert!(a < n, "addr {a} >= required {n}");
            }
        }
    });
}

#[test]
fn prop_classifier_is_total_and_stable() {
    check("classification is deterministic and total", 200, |g| {
        let idx = g.vec_of(1, 20, |g| g.i64_in(0, 100));
        let a = pattern::classify_indices(&idx);
        let b = pattern::classify_indices(&idx);
        assert_eq!(a, b);
    });
}

// ---------------------------------------------------------------------------
// RunConfig: parse(to_json(cfg)) == cfg for every pattern spec form
// ---------------------------------------------------------------------------

/// A random pattern spec string from each supported family.
fn arbitrary_spec(g: &mut Gen) -> String {
    match g.usize_in(0, 4) {
        0 => format!("UNIFORM:{}:{}", g.usize_in(1, 32), g.usize_in(1, 64)),
        1 => {
            let n = g.usize_in(4, 32);
            format!("MS1:{}:{}:{}", n, g.usize_in(1, n - 1), g.i64_in(2, 50))
        }
        2 => format!(
            "LAPLACIAN:{}:{}:{}",
            g.usize_in(1, 3),
            g.usize_in(1, 3),
            g.usize_in(8, 40)
        ),
        3 => format!(
            "RANDOM:{}:{}:{}",
            g.usize_in(1, 32),
            g.usize_in(1, 4096),
            g.usize_in(0, 1 << 16)
        ),
        _ => {
            let v = g.usize_in(1, 16);
            (0..v)
                .map(|_| g.i64_in(0, 512).to_string())
                .collect::<Vec<_>>()
                .join(",")
        }
    }
}

fn arbitrary_runconfig(g: &mut Gen) -> RunConfig {
    use spatter::pattern::StreamOp;
    let kernel = *g.choose(&[
        Kernel::Gather,
        Kernel::Scatter,
        Kernel::GS,
        Kernel::Stream(StreamOp::Copy),
        Kernel::Stream(StreamOp::Scale),
        Kernel::Stream(StreamOp::Add),
        Kernel::Stream(StreamOp::Triad),
        Kernel::Gups,
    ]);
    let mut pattern = if kernel.is_baseline() {
        // Dense baselines carry no index buffer: only the stream
        // width / table size and the count vary.
        match kernel {
            Kernel::Gups => Pattern::gups(1 << g.usize_in(10, 20), 1),
            _ => Pattern::dense(g.usize_in(1, 64), 1),
        }
    } else {
        let mut pattern = Pattern::parse(&arbitrary_spec(g)).unwrap();
        if kernel == Kernel::GS {
            // The scatter side must match the gather side's length;
            // draw its indices from another spec-built buffer, resized.
            let v = pattern.vector_len();
            let mut side = Pattern::parse(&arbitrary_spec(g)).unwrap().indices;
            side.resize(v, 0);
            pattern = pattern.with_gs_scatter(side);
        }
        if g.bool() {
            let cycle: Vec<i64> =
                (0..g.usize_in(2, 4)).map(|_| g.i64_in(0, 64)).collect();
            pattern = pattern.with_deltas(&cycle);
        } else {
            pattern = pattern.with_delta(g.i64_in(0, 256));
        }
        pattern
    };
    pattern = pattern.with_count(g.usize_in(1, 1 << 12));
    RunConfig {
        name: format!("cfg-{}", g.usize_in(0, 999)),
        kernel,
        pattern,
        page_size: if g.bool() {
            Some(*g.choose(spatter::sim::PageSize::ALL))
        } else {
            None
        },
        threads: if g.bool() { Some(g.usize_in(1, 64)) } else { None },
        regime: if g.bool() {
            Some(*g.choose(spatter::platforms::VectorRegime::ALL))
        } else {
            None
        },
        placement: if g.bool() {
            Some(*g.choose(spatter::sim::NumaPlacement::ALL))
        } else {
            None
        },
    }
}

#[test]
fn prop_runconfig_to_json_roundtrip() {
    check(
        "RunConfig: parse_config_text(to_json) reproduces every field",
        80,
        |g| {
            let cfg = arbitrary_runconfig(g);
            if cfg.pattern.validate_for(cfg.kernel).is_err() {
                // Address-space guard can trip on extreme draws; the
                // round-trip contract only covers valid configs.
                return;
            }
            let text = json::to_string(&json::Value::Array(vec![cfg.to_json()]));
            let back = parse_config_text(&text).unwrap();
            assert_eq!(back.len(), 1);
            let b = &back[0];
            assert_eq!(b.name, cfg.name);
            assert_eq!(b.kernel, cfg.kernel);
            assert_eq!(b.pattern.indices, cfg.pattern.indices);
            assert_eq!(
                b.pattern.scatter_indices,
                cfg.pattern.scatter_indices
            );
            assert_eq!(b.pattern.delta, cfg.pattern.delta);
            assert_eq!(b.pattern.deltas, cfg.pattern.deltas);
            assert_eq!(b.pattern.count, cfg.pattern.count);
            assert_eq!(b.page_size, cfg.page_size);
            assert_eq!(b.threads, cfg.threads);
            assert_eq!(b.regime, cfg.regime);
            // And serializing the parsed config is a fixed point.
            assert_eq!(
                json::to_string(&b.to_json()),
                json::to_string(&cfg.to_json())
            );
        },
    );
}

// ---------------------------------------------------------------------------
// Campaign scheduler: memo cache and streaming run mode
// ---------------------------------------------------------------------------

fn sim_factory()
-> spatter::error::Result<Box<dyn spatter::backends::Backend>> {
    Ok(Box::new(spatter::backends::OpenMpSim::new(
        &platforms::by_name("skx").unwrap(),
    )))
}

/// A small valid campaign with duplicates injected under fresh names,
/// so the memo cache always has work and the `memo` labels are
/// exercised alongside the first-occurrence paths.
fn arbitrary_campaign(g: &mut Gen) -> Vec<RunConfig> {
    let mut cfgs: Vec<RunConfig> = Vec::new();
    while cfgs.len() < 3 {
        let mut c = arbitrary_runconfig(g);
        // The campaign runs on skx, whose ISA has no masked-SVE
        // regime — an unsupported draw would (correctly) be a run
        // error, but these properties cover the happy path.
        if c.regime == Some(spatter::platforms::VectorRegime::MaskedSve) {
            c.regime = None;
        }
        if c.pattern.validate_for(c.kernel).is_ok() {
            cfgs.push(c);
        }
    }
    for _ in 0..g.usize_in(1, 3) {
        let i = g.usize_in(0, cfgs.len() - 1);
        let mut dup = cfgs[i].clone();
        dup.name = format!("{}-dup", dup.name);
        cfgs.push(dup);
    }
    cfgs
}

#[test]
fn prop_memo_cache_is_invisible_in_the_output() {
    use spatter::coordinator::{render_json, run_configs_jobs_memo};
    check("memo on/off emit identical JSON at any jobs width", 8, |g| {
        let cfgs = arbitrary_campaign(g);
        let jobs = g.usize_in(1, 5);
        let (off, off_stats) =
            run_configs_jobs_memo(&sim_factory, &cfgs, jobs, false).unwrap();
        let (on, on_stats) =
            run_configs_jobs_memo(&sim_factory, &cfgs, jobs, true).unwrap();
        assert_eq!(render_json(&off), render_json(&on));
        assert_eq!(off_stats.total(), 0, "disabled cache must not look up");
        assert!(
            on_stats.hits >= 1,
            "duplicates were injected, the cache must hit: {on_stats:?}"
        );
    });
}

#[test]
fn prop_stream_mode_matches_batch_byte_for_byte() {
    use spatter::coordinator::{
        render_json, run_configs_jobs_memo, run_configs_stream,
        stream_config_reader,
    };
    check("--stream == batch render_json for any jobs width", 8, |g| {
        let cfgs = arbitrary_campaign(g);
        let jobs = g.usize_in(1, 5);
        let memo = g.bool();
        let text = json::to_string(&json::Value::Array(
            cfgs.iter().map(|c| c.to_json()).collect(),
        ));
        // Batch leg re-parses the same serialized text the stream leg
        // reads, so both sides see identical inputs.
        let parsed = parse_config_text(&text).unwrap();
        let (recs, _) =
            run_configs_jobs_memo(&sim_factory, &parsed, jobs, memo).unwrap();
        let expect = render_json(&recs);
        let mut got = String::new();
        let src = stream_config_reader(std::io::Cursor::new(text.into_bytes()));
        let summary =
            run_configs_stream(&sim_factory, src, jobs, memo, |chunk| {
                got.push_str(chunk);
                Ok(())
            })
            .unwrap();
        assert_eq!(summary.records, parsed.len());
        assert_eq!(got, expect, "streamed document diverged from batch");
    });
}

// ---------------------------------------------------------------------------
// Built-in pattern builders (uniform / ms1 / laplacian / random)
// ---------------------------------------------------------------------------

#[test]
fn prop_uniform_builder_length_and_bounds() {
    check("uniform: length, zero-base, max_index", 100, |g| {
        let n = g.usize_in(1, 128);
        let stride = g.usize_in(1, 64);
        let idx = pattern::uniform(n, stride).unwrap();
        assert_eq!(idx.len(), n);
        assert!(idx.iter().all(|&i| i >= 0));
        let p = Pattern::from_indices("u", idx);
        assert_eq!(p.max_index(), ((n - 1) * stride) as i64);
    });
}

#[test]
fn prop_ms1_builder_length_and_bounds() {
    check("ms1: length, monotonicity, max_index", 100, |g| {
        let n = g.usize_in(2, 96);
        // Strictly increasing breaks in 1..n, random spacing.
        let mut breaks = Vec::new();
        let mut b = g.usize_in(1, n - 1);
        while b < n && breaks.len() < 6 {
            breaks.push(b);
            b += g.usize_in(1, 8);
        }
        let gap = g.i64_in(1, 100);
        let idx = pattern::ms1(n, &breaks, &[gap]).unwrap();
        assert_eq!(idx.len(), n, "requested length respected");
        assert_eq!(idx[0], 0);
        assert!(idx.windows(2).all(|w| w[1] > w[0]), "monotone: {idx:?}");
        assert!(idx.iter().all(|&i| i >= 0));
        // n-1 steps: breaks.len() jumps of `gap`, the rest +1.
        let expected_max = (n - 1) as i64 + breaks.len() as i64 * (gap - 1);
        let p = Pattern::from_indices("m", idx);
        assert_eq!(p.max_index(), expected_max);
    });
}

#[test]
fn prop_ms1_rejects_mismatched_breaks_and_gaps() {
    check("ms1: |gaps| must be 1 or |breaks|", 50, |g| {
        let n = g.usize_in(8, 64);
        let breaks = [1usize, 3, 5];
        // Any gap-list length other than 1 or |breaks| is rejected.
        let bad_len = *g.choose(&[0usize, 2, 4, 5]);
        let gaps: Vec<i64> = (0..bad_len).map(|_| g.i64_in(1, 9)).collect();
        assert!(
            pattern::ms1(n, &breaks, &gaps).is_err(),
            "3 breaks, {bad_len} gaps must be rejected"
        );
        // The two accepted shapes still work.
        assert!(pattern::ms1(n, &breaks, &[2]).is_ok());
        assert!(pattern::ms1(n, &breaks, &[2, 3, 4]).is_ok());
    });
}

#[test]
fn prop_laplacian_builder_length_and_bounds() {
    check("laplacian: point count, zero-base, max_index", 100, |g| {
        let dims = g.usize_in(1, 3);
        let branch = g.usize_in(1, 4);
        // size > branch keeps all 2*D*L+1 offsets distinct.
        let size = g.usize_in(branch + 1, 64);
        let idx = pattern::laplacian(dims, branch, size).unwrap();
        assert_eq!(idx.len(), 2 * dims * branch + 1, "stencil point count");
        assert_eq!(idx[0], 0, "zero-based");
        assert!(idx.windows(2).all(|w| w[1] > w[0]), "sorted unique");
        // Symmetric stencil: max = 2 * branch * size^(dims-1).
        let scale = (size as i64).pow(dims as u32 - 1);
        let p = Pattern::from_indices("l", idx);
        assert_eq!(p.max_index(), 2 * branch as i64 * scale);
    });
}

#[test]
fn prop_random_builder_length_and_bounds() {
    check("random: length, range bound, determinism", 100, |g| {
        let n = g.usize_in(1, 128);
        let range = g.usize_in(1, 10_000);
        let seed = g.usize_in(0, 1 << 20);
        let spec = format!("RANDOM:{n}:{range}:{seed}");
        let idx = pattern::parse_spec(&spec).unwrap();
        assert_eq!(idx.len(), n, "requested length respected");
        assert!(
            idx.iter().all(|&i| (0..range as i64).contains(&i)),
            "indices within [0, {range}): {idx:?}"
        );
        // Deterministic per seed.
        assert_eq!(pattern::parse_spec(&spec).unwrap(), idx);
    });
}

// ---------------------------------------------------------------------------
// Cache model
// ---------------------------------------------------------------------------

#[test]
fn prop_cache_hit_after_fill() {
    check("a filled line hits until evicted by its own set", 100, |g| {
        let assoc = g.usize_in(1, 8);
        let sets_pow = g.usize_in(1, 6);
        let cap = (1 << sets_pow) * assoc * 64;
        let mut c = Cache::new(cap, 64, assoc);
        let line = g.next_u64() % 10_000;
        c.fill(line, false, false);
        assert!(matches!(
            c.access(line, false),
            spatter::sim::Probe::Hit { .. }
        ));
    });
}

#[test]
fn prop_cache_occupancy_never_exceeds_capacity() {
    check("distinct resident lines <= capacity", 50, |g| {
        let assoc = g.usize_in(1, 4);
        let sets = 1 << g.usize_in(1, 4);
        let mut c = Cache::new(sets * assoc * 64, 64, assoc);
        let universe = g.usize_in(1, 512) as u64;
        for _ in 0..2000 {
            let line = g.next_u64() % universe;
            if c.access(line, g.bool()) == spatter::sim::Probe::Miss {
                c.fill(line, false, false);
            }
        }
        let resident = (0..universe).filter(|&l| c.contains(l)).count();
        assert!(resident <= sets * assoc, "{resident} > {}", sets * assoc);
    });
}

#[test]
fn prop_cache_stats_conserve() {
    check("hits + misses == accesses", 50, |g| {
        let mut c = Cache::new(4096, 64, 4);
        let mut accesses = 0u64;
        for _ in 0..1000 {
            let line = g.next_u64() % 256;
            accesses += 1;
            if c.access(line, false) == spatter::sim::Probe::Miss {
                c.fill(line, false, false);
            }
        }
        assert_eq!(c.hits + c.misses, accesses);
    });
}

// ---------------------------------------------------------------------------
// Simulator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_sim_deterministic_and_conserving() {
    check("engine determinism + access conservation", 12, |g| {
        let stride = 1 << g.usize_in(0, 5);
        let v = *g.choose(&[4usize, 8, 16]);
        let count = 1 << g.usize_in(8, 12);
        let pat = Pattern::from_indices(
            "p",
            (0..v as i64).map(|i| i * stride).collect(),
        )
        .with_delta(g.i64_in(0, 64))
        .with_count(count);
        let kernel = if g.bool() { Kernel::Gather } else { Kernel::Scatter };
        let plat = platforms::by_name(*g.choose(&["bdw", "skx", "naples", "tx2"])).unwrap();
        let a = CpuEngine::new(&plat).run(&pat, kernel).unwrap();
        let b = CpuEngine::new(&plat).run(&pat, kernel).unwrap();
        assert_eq!(a.counters, b.counters);
        let c = &a.counters;
        if c.streaming_store_lines == 0 {
            assert_eq!(
                c.accesses,
                c.l1_hits + c.l2_hits + c.l3_hits + c.dram_demand_lines
            );
        }
        assert!(a.seconds > 0.0 && a.seconds.is_finite());
    });
}

#[test]
fn prop_bandwidth_monotone_in_stride() {
    // Bandwidth never *increases* when stride doubles in the
    // prefetch-free regime (strictly-fewer useful bytes per line).
    check("no-prefetch bandwidth monotone non-increasing", 6, |g| {
        let plat = platforms::by_name(*g.choose(&["skx", "naples"])).unwrap();
        let mut e = CpuEngine::with_options(
            &plat,
            spatter::sim::cpu::CpuSimOptions {
                prefetch_enabled: false,
                ..Default::default()
            },
        );
        let mut last = f64::INFINITY;
        for stride in [1usize, 2, 4, 8, 16] {
            let pat = Pattern::parse(&format!("UNIFORM:8:{stride}"))
                .unwrap()
                .with_delta(8 * stride as i64)
                .with_count(1 << 16);
            let bw = e.run(&pat, Kernel::Gather).unwrap().bandwidth_gbs();
            assert!(
                bw <= last * 1.02,
                "stride {stride}: {bw:.2} > prior {last:.2}"
            );
            last = bw;
        }
    });
}

// ---------------------------------------------------------------------------
// Extraction
// ---------------------------------------------------------------------------

#[test]
fn prop_extraction_recovers_synthetic_pattern() {
    check("extractor inverts record generation", 60, |g| {
        let v = g.usize_in(2, 16);
        // Random normalized buffer containing 0.
        let mut idx: Vec<i64> = g.vec_of(v, v, |g| g.i64_in(0, 500));
        idx[0] = 0;
        let delta = g.i64_in(1, 1000);
        let count = g.usize_in(3, 100);
        let records: Vec<GsRecord> = (0..count as i64)
            .map(|i| GsRecord {
                kernel: Kernel::Gather,
                base: delta * i,
                offsets: idx.clone(),
            })
            .collect();
        let pats = extract_patterns(&records, 0);
        assert_eq!(pats.len(), 1);
        assert_eq!(pats[0].indices, idx);
        assert_eq!(pats[0].delta, delta);
        assert_eq!(pats[0].occurrences, count as u64);
    });
}

// ---------------------------------------------------------------------------
// Stats
// ---------------------------------------------------------------------------

#[test]
fn prop_hmean_bounds() {
    check("min <= hmean <= amean <= max", 200, |g| {
        let xs = g.vec_of(1, 20, |g| g.f64_in(0.1, 1000.0));
        let h = stats::harmonic_mean(&xs).unwrap();
        let a = stats::mean(&xs).unwrap();
        let (mn, mx) = stats::min_max(&xs).unwrap();
        assert!(mn - 1e-9 <= h && h <= a + 1e-9 && a <= mx + 1e-9);
    });
}

#[test]
fn prop_pearson_r_in_unit_interval() {
    check("|R| <= 1 and scale-invariant", 100, |g| {
        let n = g.usize_in(3, 20);
        let xs = g.vec_of(n, n, |g| g.f64_in(-100.0, 100.0));
        let ys = g.vec_of(n, n, |g| g.f64_in(-100.0, 100.0));
        if let Some(r) = stats::pearson_r(&xs, &ys) {
            assert!(r.abs() <= 1.0 + 1e-9, "{r}");
            // invariance under positive affine transform of x
            let xs2: Vec<f64> = xs.iter().map(|x| 3.5 * x + 11.0).collect();
            if let Some(r2) = stats::pearson_r(&xs2, &ys) {
                assert!((r - r2).abs() < 1e-6);
            }
        }
    });
}
