//! Differential pins for the banked DRAM model: power-of-two row
//! strides must never conflict *less* than their odd neighbors — the
//! bank-aliasing asymmetry the banked model exists to expose — and the
//! hit/miss/conflict taxonomy must stay internally consistent on every
//! platform and engine.

use spatter::backends::{Backend, CudaSim, OpenMpSim};
use spatter::pattern::{Kernel, Pattern};
use spatter::platforms;
use spatter::sim::SimCounters;

const CPUS: &[&str] = &["knl", "bdw", "skx", "clx", "tx2", "naples"];

/// A gather whose every access lands `rows` DRAM rows past the
/// previous one (2048-byte rows, 8-byte elements), so each access
/// opens a fresh row and the activation sequence is a pure row-stride
/// ladder — the same shape `--suite dram` sweeps.
fn row_stride_gather(rows: usize, count: usize) -> Pattern {
    let stride = rows * 256;
    Pattern::parse(&format!("UNIFORM:8:{stride}"))
        .unwrap()
        .with_delta(8 * stride as i64)
        .with_count(count)
}

fn activations(c: &SimCounters) -> u64 {
    c.dram_row_misses + c.dram_row_conflicts
}

/// Power-of-two row strides conflict at least as much as their odd
/// neighbors on every CPU platform, prefetchers and all: pow2 slot
/// advances can collapse onto one channel×bank-group while odd
/// advances always rotate (they are coprime to the pow2-sized channel
/// and bank counts — and on the six-channel parts neither side
/// aliases, so the sides tie at zero).
#[test]
fn pow2_stride_conflicts_dominate_odd_on_every_cpu() {
    let count = 1 << 12;
    for &name in CPUS {
        let plat = platforms::by_name(name).unwrap();
        for rows in [16usize, 64] {
            let run = |r: usize| {
                OpenMpSim::new(&plat)
                    .run(&row_stride_gather(r, count), Kernel::Gather)
                    .unwrap()
            };
            let pow2 = run(rows);
            let odd = run(rows + 1);
            assert!(
                pow2.counters.dram_row_conflicts
                    >= odd.counters.dram_row_conflicts,
                "{name} rows={rows}: pow2 {} < odd {}",
                pow2.counters.dram_row_conflicts,
                odd.counters.dram_row_conflicts
            );
        }
    }
}

/// On a 64-bank part the dominance is strict and nearly total: a
/// 16-row stride clears both the channel and bank-group rotation on
/// KNL (8ch × 2bg × 4bk), re-opening the same bank every access, while
/// 17 rows walks the channels.
#[test]
fn pow2_aliasing_is_strict_on_a_64_bank_part() {
    let knl = platforms::by_name("knl").unwrap();
    let count = 1 << 12;
    let run = |rows: usize| {
        OpenMpSim::without_prefetch(&knl)
            .run(&row_stride_gather(rows, count), Kernel::Gather)
            .unwrap()
    };
    let aliased = run(16);
    let rotated = run(17);
    assert!(
        aliased.counters.dram_row_conflicts
            > rotated.counters.dram_row_conflicts,
        "aliased {} vs rotated {}",
        aliased.counters.dram_row_conflicts,
        rotated.counters.dram_row_conflicts
    );
    // Nearly every aliased activation conflicts; the rotating run
    // stays essentially conflict-free.
    let acts = activations(&aliased.counters);
    assert!(
        aliased.counters.dram_row_conflicts * 10 >= acts * 9,
        "{:?}",
        aliased.counters
    );
    assert!(
        rotated.counters.dram_row_conflicts * 20
            <= activations(&rotated.counters),
        "{:?}",
        rotated.counters
    );
}

/// Taxonomy invariant on both engines: every row activation is
/// classified as exactly one of miss or conflict, and hits never
/// activate — so misses + conflicts == row_activations, with the
/// legacy activation counter unchanged in meaning.
#[test]
fn misses_plus_conflicts_equal_activations_everywhere() {
    let count = 1 << 12;
    for &name in CPUS {
        let plat = platforms::by_name(name).unwrap();
        for (kernel, pat) in [
            (Kernel::Gather, row_stride_gather(8, count)),
            (Kernel::Gups, Pattern::gups(1 << 16, 1024)),
        ] {
            let r = OpenMpSim::new(&plat).run(&pat, kernel).unwrap();
            let c = &r.counters;
            assert_eq!(
                c.dram_row_misses + c.dram_row_conflicts,
                c.row_activations,
                "{name} {kernel:?}: {c:?}"
            );
            assert!(c.row_activations > 0, "{name} {kernel:?} hit no DRAM");
        }
    }
    let gpu = platforms::gpu_by_name("p100").unwrap();
    let gpat = Pattern::parse("UNIFORM:256:64")
        .unwrap()
        .with_delta(256 * 64)
        .with_count(1 << 10);
    let r = CudaSim::new(&gpu).run(&gpat, Kernel::Gather).unwrap();
    let c = &r.counters;
    assert_eq!(
        c.dram_row_misses + c.dram_row_conflicts,
        c.row_activations,
        "gpu: {c:?}"
    );
    assert!(c.row_activations > 0, "gpu gather hit no DRAM");
}
