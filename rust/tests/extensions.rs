//! Tests for the extension features beyond the paper's §3 core:
//! RANDOM (GUPS-like) patterns (§6) and multi-delta temporal-locality
//! patterns (§7 future-work item 1).

use spatter::backends::{Backend, OpenMpSim};
use spatter::coordinator;
use spatter::pattern::{Kernel, Pattern};
use spatter::platforms;

#[test]
fn temporal_deltas_express_reuse() {
    // Same mean advance (8 elems/iter), different temporal structure:
    // [0,0,0,32] revisits each base three times — those revisits hit
    // L1, so the modelled bandwidth must be well above the uniform
    // delta-8 stream at the same stride.
    let p = platforms::by_name("skx").unwrap();
    let idx: Vec<i64> = (0..8).collect();
    let uniform = Pattern::from_indices("uniform-d8", idx.clone())
        .with_delta(8)
        .with_count(1 << 18);
    let temporal = Pattern::from_indices("temporal", idx)
        .with_deltas(&[0, 0, 0, 32])
        .with_count(1 << 18);
    let bw_u = OpenMpSim::new(&p)
        .run(&uniform, Kernel::Gather)
        .unwrap()
        .bandwidth_gbs();
    let bw_t = OpenMpSim::new(&p)
        .run(&temporal, Kernel::Gather)
        .unwrap()
        .bandwidth_gbs();
    assert!(
        bw_t > 1.7 * bw_u,
        "temporal revisits should look cached: {bw_t:.1} vs uniform {bw_u:.1}"
    );
}

#[test]
fn random_pattern_runs_slower_than_stride1() {
    // GUPS-like random gather: 256 random offsets within a 16 MB
    // window, window advancing fully each iteration — every access is
    // a fresh random DRAM line, far below stream.
    let p = platforms::by_name("bdw").unwrap();
    let rand = Pattern::parse("RANDOM:256:2097152")
        .unwrap()
        .with_delta(2_097_152)
        .with_count(1 << 12);
    let stream = Pattern::parse("UNIFORM:8:1")
        .unwrap()
        .with_delta(8)
        .with_count(1 << 18);
    let bw_r = OpenMpSim::new(&p)
        .run(&rand, Kernel::Gather)
        .unwrap()
        .bandwidth_gbs();
    let bw_s = OpenMpSim::new(&p)
        .run(&stream, Kernel::Gather)
        .unwrap()
        .bandwidth_gbs();
    assert!(
        bw_r < 0.5 * bw_s,
        "random gather {bw_r:.1} should sit far below stream {bw_s:.1}"
    );
}

#[test]
fn json_config_accepts_delta_lists() {
    let cfgs = coordinator::parse_config_text(
        r#"[
          {"kernel": "Gather", "pattern": "UNIFORM:8:1",
           "delta": [0, 0, 0, 16], "count": 4096},
          {"kernel": "Gather", "pattern": "RANDOM:16:4096:3",
           "delta": 16, "count": 1024}
        ]"#,
    )
    .unwrap();
    assert_eq!(cfgs[0].pattern.deltas, vec![0, 0, 0, 16]);
    assert_eq!(cfgs[1].pattern.vector_len(), 16);
    let p = platforms::by_name("clx").unwrap();
    let mut b = OpenMpSim::new(&p);
    let recs = coordinator::run_configs(&mut b, &cfgs).unwrap();
    assert!(recs.iter().all(|r| r.bandwidth_gbs > 0.0));
}

#[test]
fn cli_accepts_delta_lists() {
    use spatter::cli::{parse_args, Command};
    let argv: Vec<String> = "-k Gather -p UNIFORM:8:1 -d 0,0,0,16 -l 1024"
        .split_whitespace()
        .map(String::from)
        .collect();
    match parse_args(&argv).unwrap() {
        Command::Run(r) => {
            assert_eq!(r.pattern.deltas, vec![0, 0, 0, 16]);
            assert_eq!(r.pattern.count, 1024);
        }
        other => panic!("{other:?}"),
    }
    // Bad lists rejected.
    let bad: Vec<String> = "-k Gather -p UNIFORM:8:1 -d 1,,2"
        .split_whitespace()
        .map(String::from)
        .collect();
    assert!(parse_args(&bad).is_err());
}

#[test]
fn multi_delta_equivalence_when_constant() {
    // A constant delta list must model identically to the single
    // delta (engine-level equivalence of the two code paths).
    let p = platforms::by_name("naples").unwrap();
    let idx: Vec<i64> = (0..8).map(|i| i * 4).collect();
    let single = Pattern::from_indices("s", idx.clone())
        .with_delta(32)
        .with_count(1 << 16);
    let multi = Pattern::from_indices("m", idx)
        .with_deltas(&[32, 32])
        .with_count(1 << 16);
    let a = OpenMpSim::new(&p).run(&single, Kernel::Gather).unwrap();
    let b = OpenMpSim::new(&p).run(&multi, Kernel::Gather).unwrap();
    assert_eq!(a.counters, b.counters);
    assert!((a.seconds - b.seconds).abs() < 1e-12);
}
