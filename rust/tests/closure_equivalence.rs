//! Equivalence property test for steady-state loop closure (ISSUE 3
//! satellite): for randomized (platform, pattern, kernel, threads,
//! page-size) configurations, the engines must produce *exactly* the
//! same `SimResult` — counters, breakdown, seconds, bandwidth — with
//! loop closure force-disabled and force-enabled. Closure is an
//! optimization, never an approximation.
//!
//! The configurations randomize the DRAM address-interleave policy
//! too: the banked bank state (open rows + last activation domain) is
//! part of the closure fingerprint, and the counter comparison covers
//! the per-bank hit/miss/conflict tallies, so a digest that missed a
//! bank-state difference would fail here. They also randomize the
//! batch-compiled access plan on/off (ISSUE 8 satellite) — drawn once
//! and held equal across both closure arms — pinning the closure ×
//! plan × DRAM-model composition; the plan-vs-scalar axis itself is
//! pinned by `tests/plan_equivalence.rs`.

use spatter::pattern::{table5, Kernel, Pattern, StreamOp};
use spatter::platforms;
use spatter::prop::{check, Gen};
use spatter::sim::cpu::{CpuEngine, CpuSimOptions};
use spatter::sim::gpu::{GpuEngine, GpuSimOptions};
use spatter::sim::{InterleavePolicy, NumaPlacement, PageSize, SimResult};

fn assert_identical(on: &SimResult, off: &SimResult, ctx: &str) {
    assert_eq!(on.counters, off.counters, "{ctx}: counters");
    assert_eq!(on.breakdown, off.breakdown, "{ctx}: breakdown");
    assert_eq!(on.seconds, off.seconds, "{ctx}: seconds");
    assert_eq!(
        on.bandwidth_gbs(),
        off.bandwidth_gbs(),
        "{ctx}: bandwidth"
    );
    assert_eq!(
        on.simulated_iterations, off.simulated_iterations,
        "{ctx}: simulated iterations"
    );
    assert_eq!(off.closed_at_iteration, None, "{ctx}: off must not close");
}

/// A random kernel, the whole family included — GS is the dual-pattern
/// case, and the dense/random baselines (STREAM tetrad + GUPS) must
/// hold the same equivalence contract.
fn arbitrary_kernel(g: &mut Gen) -> Kernel {
    *g.choose(&[
        Kernel::Gather,
        Kernel::Scatter,
        Kernel::GS,
        Kernel::Stream(StreamOp::Copy),
        Kernel::Stream(StreamOp::Scale),
        Kernel::Stream(StreamOp::Add),
        Kernel::Stream(StreamOp::Triad),
        Kernel::Gups,
    ])
}

/// Shape the drawn pattern for the kernel: attach a random scatter
/// side for GS (uniform strides, repeated-write targets, and irregular
/// buffers all appear); replace it with a dense stream or a GUPS table
/// for the baselines (their shape is fixed by construction — only the
/// width/table size and count vary).
fn with_kernel_shape(g: &mut Gen, pat: Pattern, kernel: Kernel) -> Pattern {
    match kernel {
        Kernel::GS => {
            let v = pat.vector_len();
            let side = match g.usize_in(0, 2) {
                0 => {
                    let s = g.i64_in(1, 24);
                    (0..v as i64).map(|j| j * s).collect()
                }
                1 => vec![0; v],
                _ => (0..v).map(|_| g.i64_in(0, 2048)).collect(),
            };
            pat.with_gs_scatter(side)
        }
        Kernel::Stream(_) => {
            Pattern::dense(*g.choose(&[4usize, 8, 16, 32]), pat.count)
        }
        Kernel::Gups => Pattern::gups(1 << g.usize_in(10, 18), pat.count),
        _ => pat,
    }
}

/// A randomized pattern drawn from the families the paper sweeps:
/// delta-0 revisits, uniform strides, huge-delta page walkers, random
/// buffers with cycling delta lists, and Table-5 proxies.
fn arbitrary_pattern(g: &mut Gen, v_cap: usize) -> Pattern {
    match g.usize_in(0, 4) {
        0 => {
            // Delta-0: total revisit (the LULESH-S3 shape).
            let v = g.usize_in(1, v_cap);
            Pattern::from_indices(
                "d0",
                (0..v as i64).map(|i| i * g.i64_in(1, 8)).collect(),
            )
            .with_delta(0)
        }
        1 => {
            let s = 1usize << g.usize_in(0, 6);
            let v = g.usize_in(1, v_cap);
            Pattern::from_indices(
                "ustride",
                (0..v as i64).map(|i| i * s as i64).collect(),
            )
            .with_delta((v * s) as i64)
        }
        2 => {
            // Huge delta: fresh pages every iteration (PENNANT shape).
            Pattern::from_indices(
                "huge",
                (0..16i64).map(|j| j * 512).collect(),
            )
            .with_delta(g.i64_in(1, 4) * 16384)
        }
        3 => {
            let v = g.usize_in(2, v_cap);
            let idx: Vec<i64> = (0..v).map(|_| g.i64_in(0, 2048)).collect();
            let jump = g.i64_in(0, 512);
            Pattern::from_indices("rand", idx).with_deltas(&[0, 0, 0, jump])
        }
        _ => {
            let name = *g.choose(&["AMG-G0", "LULESH-S1", "LULESH-S3"]);
            let app = table5::by_name(name).unwrap();
            Pattern::from_indices(app.name, app.indices.to_vec())
                .with_delta(app.delta)
        }
    }
}

#[test]
fn prop_cpu_closure_equivalence() {
    check("CPU: closure on == closure off, exactly", 20, |g| {
        // The pool includes the two-socket variants: per-node DRAM
        // bank state and the first-touch rotation phase are part of
        // the closure fingerprint, so a digest that missed either
        // would fail here (ISSUE 10 tentpole).
        let mut plat = platforms::by_name(*g.choose(&[
            "skx", "bdw", "naples", "tx2", "knl", "clx", "skx-2s",
            "tx2-2s", "naples-2s",
        ]))
        .unwrap();
        plat.dram.interleave = *g.choose(InterleavePolicy::ALL);
        let numa_placement = *g.choose(NumaPlacement::ALL);
        let kernel = arbitrary_kernel(g);
        let page = *g.choose(&[PageSize::FourKB, PageSize::TwoMB]);
        let threads = if g.bool() {
            None
        } else {
            Some(g.usize_in(1, 8))
        };
        // The vectorization regime rescales analytic timing, not the
        // counter stream, so closure equivalence must hold on every
        // rung the ISA supports (drawn once, equal in both arms).
        let regime = if g.bool() {
            None
        } else {
            Some(*g.choose(&plat.supported_regimes()))
        };
        let pat = with_kernel_shape(
            g,
            arbitrary_pattern(g, 16).with_count(1 << g.usize_in(8, 13)),
            kernel,
        );
        let plan_enabled = g.bool();
        let run = |closure_enabled: bool| {
            let mut e = CpuEngine::with_options(
                &plat,
                CpuSimOptions {
                    closure_enabled,
                    plan_enabled,
                    page_size: page,
                    threads,
                    regime,
                    numa_placement,
                    ..Default::default()
                },
            );
            e.run(&pat, kernel).unwrap()
        };
        let on = run(true);
        let off = run(false);
        assert_identical(
            &on,
            &off,
            &format!(
                "{} {:?} {} regime={regime:?} numa={}",
                plat.name,
                kernel,
                pat.spec,
                numa_placement.name()
            ),
        );
    });
}

#[test]
fn prop_gpu_closure_equivalence() {
    check("GPU: closure on == closure off, exactly", 14, |g| {
        let mut plat = platforms::gpu_by_name(
            *g.choose(&["k40c", "titanxp", "p100", "v100"]),
        )
        .unwrap();
        plat.dram.interleave = *g.choose(InterleavePolicy::ALL);
        let kernel = arbitrary_kernel(g);
        let page = *g.choose(&[PageSize::SixtyFourKB, PageSize::TwoMB]);
        let pat = with_kernel_shape(
            g,
            arbitrary_pattern(g, 64).with_count(1 << g.usize_in(6, 11)),
            kernel,
        );
        let plan_enabled = g.bool();
        let run = |closure_enabled: bool| {
            let mut e = GpuEngine::with_options(
                &plat,
                GpuSimOptions {
                    closure_enabled,
                    plan_enabled,
                    page_size: page,
                    ..Default::default()
                },
            );
            e.run(&pat, kernel).unwrap()
        };
        let on = run(true);
        let off = run(false);
        assert_identical(
            &on,
            &off,
            &format!("{} {:?} {}", plat.name, kernel, pat.spec),
        );
    });
}

/// The test above would be vacuous if closure never fired; pin that it
/// does fire — and early — on the workloads it is built for.
#[test]
fn closure_fires_where_it_should() {
    let opts = CpuSimOptions {
        closure_enabled: true, // pin explicitly, independent of env
        ..Default::default()
    };
    let skx = platforms::by_name("skx").unwrap();
    let s3 = table5::by_name("LULESH-S3").unwrap().to_pattern(1 << 14);
    let r = CpuEngine::with_options(&skx, opts.clone())
        .run(&s3, Kernel::Scatter)
        .unwrap();
    let at = r.closed_at_iteration.expect("delta-0 scatter must close");
    assert!(at < 64, "delta-0 should close within a few iterations: {at}");

    let knl = platforms::by_name("knl").unwrap();
    let huge = Pattern::from_indices(
        "huge-delta",
        (0..16i64).map(|j| j * 512).collect(),
    )
    .with_delta(16384)
    .with_count(1 << 14);
    let r = CpuEngine::with_options(&knl, opts.clone())
        .run(&huge, Kernel::Gather)
        .unwrap();
    assert!(
        r.closed_at_iteration.is_some(),
        "huge-delta gather must close"
    );

    // Delta-0 GS (the paired LULESH shape): both streams revisit the
    // same lines every iteration, so closure must fire early too.
    let gs = Pattern::from_indices("gs-d0", (0..16i64).collect())
        .with_gs_scatter((0..16i64).map(|j| j * 24).collect())
        .with_delta(0)
        .with_count(1 << 14);
    let r = CpuEngine::with_options(&skx, opts)
        .run(&gs, Kernel::GS)
        .unwrap();
    let at = r.closed_at_iteration.expect("delta-0 GS must close");
    assert!(at < 64, "delta-0 GS should close within a few iterations: {at}");
}
