//! Differential tests pinning the dense/random baseline family
//! (STREAM tetrad + GUPS) against the indexed kernels on every
//! platform — the direction-of-inequality layer for ISSUE 5:
//!
//! * **Copy >= stride-1 gather** — a dense copy moves the same bytes
//!   per line as the stride-1 gather the engines are calibrated on,
//!   minus the indexed-issue cost, so its headline bandwidth can never
//!   fall below it.
//! * **GUPS <= huge-delta random-class gather** — every GUPS update
//!   does everything such a gather access does (fresh page, fresh
//!   row, deep miss) *plus* the read-modify-write traffic, so it can
//!   never beat the random gather.
//! * Seed determinism and closure on/off equivalence for the family.

use spatter::backends::{Backend, CudaSim, OpenMpSim};
use spatter::coordinator::parse_config_text;
use spatter::json;
use spatter::pattern::{Kernel, Pattern, StreamOp};
use spatter::platforms;

const CPUS: &[&str] = &["skx", "bdw", "clx", "naples", "tx2", "knl"];
const GPUS: &[&str] = &["k40c", "titanxp", "p100", "v100"];

#[test]
fn copy_at_least_stride1_gather_on_every_cpu() {
    // Large enough that both measured windows (which differ in length
    // — the copy simulates half as many iterations per access budget)
    // stay disjoint from the warm-up tail: neither side may be
    // flattered by cache residency.
    let count = 1 << 19;
    for name in CPUS {
        let p = platforms::by_name(name).unwrap();
        let mut e = OpenMpSim::new(&p);
        let dense = Pattern::dense(8, count);
        let bw_copy = e
            .run(&dense, Kernel::Stream(StreamOp::Copy))
            .unwrap()
            .bandwidth_gbs();
        let gather = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(count);
        let bw_g = e.run(&gather, Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(
            bw_copy >= 0.97 * bw_g,
            "{name}: Copy {bw_copy:.1} must not fall below stride-1 \
             gather {bw_g:.1}"
        );
    }
}

#[test]
fn copy_at_least_stride1_gather_on_every_gpu() {
    // Same sizing rule as the CPU variant: out-of-cache working sets.
    let count = 1 << 15;
    for name in GPUS {
        let p = platforms::gpu_by_name(name).unwrap();
        let mut e = CudaSim::new(&p);
        let bw_copy = e
            .run(&Pattern::dense(256, count), Kernel::Stream(StreamOp::Copy))
            .unwrap()
            .bandwidth_gbs();
        let gather = Pattern::parse("UNIFORM:256:1")
            .unwrap()
            .with_delta(256)
            .with_count(count);
        let bw_g = e.run(&gather, Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(
            bw_copy >= 0.97 * bw_g,
            "{name}: Copy {bw_copy:.0} must not fall below stride-1 \
             gather {bw_g:.0}"
        );
    }
}

/// The huge-delta random-class comparator: the same random index
/// buffer a GUPS table produces, with the base jumping far enough that
/// every access opens a fresh page and row (the PENNANT-G9 regime).
fn random_class_gather(v: usize, table: usize, count: usize) -> Pattern {
    let spec = format!("RANDOM:{v}:{table}:1");
    Pattern::parse(&spec)
        .unwrap()
        .with_delta(1 << 16)
        .with_count(count)
}

#[test]
fn gups_below_random_class_gather_on_every_cpu() {
    let count = 1 << 16;
    let table = 1 << 26;
    for name in CPUS {
        let p = platforms::by_name(name).unwrap();
        let mut e = OpenMpSim::new(&p);
        let bw_gups = e
            .run(&Pattern::gups(table, count), Kernel::Gups)
            .unwrap()
            .bandwidth_gbs();
        let bw_rand = e
            .run(&random_class_gather(8, table, count), Kernel::Gather)
            .unwrap()
            .bandwidth_gbs();
        assert!(
            bw_gups <= bw_rand * 1.02,
            "{name}: GUPS {bw_gups:.2} must not beat the random-class \
             gather {bw_rand:.2}"
        );
        assert!(bw_gups > 0.0 && bw_gups.is_finite(), "{name}");
    }
}

#[test]
fn gups_below_random_class_gather_on_every_gpu() {
    let count = 1 << 14;
    let table = 1 << 26;
    for name in GPUS {
        let p = platforms::gpu_by_name(name).unwrap();
        let mut e = CudaSim::new(&p);
        let bw_gups = e
            .run(&Pattern::gups(table, count), Kernel::Gups)
            .unwrap()
            .bandwidth_gbs();
        let bw_rand = e
            .run(&random_class_gather(256, table, count), Kernel::Gather)
            .unwrap()
            .bandwidth_gbs();
        assert!(
            bw_gups <= bw_rand * 1.02,
            "{name}: GUPS {bw_gups:.2} must not beat the random-class \
             gather {bw_rand:.2}"
        );
    }
}

#[test]
fn gups_seed_determinism_across_engines_and_reuse() {
    // Fresh engine, reused engine, and the trait object path all see
    // the same seeded update stream.
    let p = platforms::by_name("skx").unwrap();
    let pat = Pattern::gups(1 << 20, 1 << 12);
    let a = OpenMpSim::new(&p).run(&pat, Kernel::Gups).unwrap();
    let mut reused = OpenMpSim::new(&p);
    reused
        .run(&Pattern::dense(8, 1 << 12), Kernel::Stream(StreamOp::Triad))
        .unwrap();
    let b = reused.run(&pat, Kernel::Gups).unwrap();
    assert_eq!(a.counters, b.counters);
    assert_eq!(a.seconds, b.seconds);

    let g = platforms::gpu_by_name("v100").unwrap();
    let x = CudaSim::new(&g).run(&pat, Kernel::Gups).unwrap();
    let y = CudaSim::new(&g).run(&pat, Kernel::Gups).unwrap();
    assert_eq!(x.counters, y.counters);
    assert_eq!(x.seconds, y.seconds);
}

#[test]
fn tetrad_ordering_follows_stream_convention() {
    // Add/Triad move 24 B per element to Copy/Scale's 16: with DRAM
    // binding all four, the reported (per-convention) bandwidths stay
    // within a whisker of each other — exactly STREAM's behaviour on
    // bandwidth-bound machines.
    let p = platforms::by_name("skx").unwrap();
    let mut e = OpenMpSim::new(&p);
    let pat = Pattern::dense(8, 1 << 19);
    let bws: Vec<f64> = StreamOp::ALL
        .iter()
        .map(|op| {
            e.run(&pat, Kernel::Stream(*op)).unwrap().bandwidth_gbs()
        })
        .collect();
    let (min, max) = (
        bws.iter().cloned().fold(f64::INFINITY, f64::min),
        bws.iter().cloned().fold(0.0, f64::max),
    );
    assert!(
        max / min < 1.15,
        "tetrad should be tight on a DRAM-bound machine: {bws:?}"
    );
}

#[test]
fn baseline_runconfig_roundtrip_through_json() {
    // The explicit (non-property) round-trip for the new kernels: a
    // whole config set serializes and re-parses to the same patterns.
    let cfgs = parse_config_text(
        r#"[
          {"name": "c", "kernel": "Copy", "delta": 8, "count": 4096},
          {"name": "a", "kernel": "Add", "delta": 32, "count": 1024,
           "threads": 4},
          {"name": "t", "kernel": "Triad", "count": 2048,
           "page-size": "2MB"},
          {"name": "u", "kernel": "GUPS", "delta": 1048576, "count": 512}
        ]"#,
    )
    .unwrap();
    let text = json::to_string(&json::Value::Array(
        cfgs.iter().map(|c| c.to_json()).collect(),
    ));
    let back = parse_config_text(&text).unwrap();
    assert_eq!(back.len(), cfgs.len());
    for (a, b) in cfgs.iter().zip(&back) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.kernel, b.kernel);
        assert_eq!(a.pattern, b.pattern);
        assert_eq!(a.page_size, b.page_size);
        assert_eq!(a.threads, b.threads);
        assert_eq!(
            json::to_string(&a.to_json()),
            json::to_string(&b.to_json()),
            "serialization is a fixed point"
        );
    }
}

#[test]
fn baselines_run_through_the_backend_trait() {
    // The Backend trait path (what the CLI and the suites use) accepts
    // the whole family on both engine kinds and rejects nothing.
    let p = platforms::by_name("tx2").unwrap();
    let mut b: Box<dyn Backend> = Box::new(OpenMpSim::new(&p));
    for op in StreamOp::ALL {
        let r = b
            .run(&Pattern::dense(8, 1 << 12), Kernel::Stream(*op))
            .unwrap();
        assert!(r.bandwidth_gbs() > 0.0);
    }
    let r = b
        .run(&Pattern::gups(1 << 20, 1 << 10), Kernel::Gups)
        .unwrap();
    assert!(r.bandwidth_gbs() > 0.0);
}
