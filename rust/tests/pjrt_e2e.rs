//! End-to-end tests over the real-execution path: AOT artifacts →
//! PJRT-CPU → numerics vs host reference. Skipped (with a notice) when
//! artifacts are missing; `make artifacts` generates them.

use spatter::backends::{Backend, PjrtBackend};
use spatter::pattern::{table5, Kernel, Pattern};
use spatter::runtime::{default_artifact_dir, Runtime};

fn have_artifacts() -> bool {
    let ok = cfg!(feature = "xla")
        && default_artifact_dir().join("manifest.json").exists();
    if !ok {
        eprintln!(
            "pjrt_e2e: SKIP (needs the `xla` feature and artifacts from \
             `make artifacts`)"
        );
    }
    ok
}

/// Host oracle for the gather checksum.
fn host_checksum(src: &[f64], idx: &[i32], delta: i64, count: usize) -> f64 {
    let mut sum = 0.0;
    for i in 0..count {
        for &ix in idx {
            sum += src[(delta * i as i64 + ix as i64) as usize];
        }
    }
    sum
}

#[test]
fn gather_checksum_many_patterns() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::open_default().unwrap();
    let v = rt
        .manifest()
        .find("gather_checksum", "ref", 8, Some(64))
        .unwrap()
        .clone();
    let src: Vec<f64> = (0..v.n).map(|i| ((i * 31) % 509) as f64 * 0.25).collect();
    let sb = rt.stage_f64(&src).unwrap();

    // A spread of pattern shapes, all within the smoke geometry.
    let cases: Vec<(Vec<i32>, i64)> = vec![
        ((0..8).collect(), 8),                    // stride-1 stream
        ((0..8).map(|j| j * 4).collect(), 32),    // stride-4
        (vec![0, 0, 1, 1, 2, 2, 3, 3], 4),        // broadcast
        (vec![0, 1, 2, 3, 23, 24, 25, 26], 2),    // MS1:8:4:20
        (vec![5, 3, 9, 1, 7, 7, 2, 0], 0),        // irregular, delta 0
        (vec![0, 9, 1, 8, 2, 7, 3, 6], 13),       // zigzag
    ];
    for (idx, delta) in cases {
        let ib = rt.stage_i32(&idx).unwrap();
        let db = rt.stage_i32(&[delta as i32]).unwrap();
        let got = rt.execute_scalar(&v.name, &[&sb, &ib, &db]).unwrap();
        let want = host_checksum(&src, &idx, delta, v.count);
        assert!(
            (got - want).abs() <= 1e-9 * want.abs().max(1.0),
            "idx {idx:?} delta {delta}: {got} vs {want}"
        );
    }
}

#[test]
fn scatter_artifact_places_values() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::open_default().unwrap();
    let v = rt
        .manifest()
        .find("scatter", "ref", 8, Some(64))
        .unwrap()
        .clone();
    let vals: Vec<f64> = (0..v.count * 8).map(|i| 1000.0 + i as f64).collect();
    let idx: Vec<i32> = (0..8).collect();
    let delta = 8i32;
    let dst = vec![0.0f64; v.n];
    let vb = rt.stage_f64_2d(&vals, v.count, 8).unwrap();
    let ib = rt.stage_i32(&idx).unwrap();
    let db = rt.stage_i32(&[delta]).unwrap();
    let sb = rt.stage_f64(&dst).unwrap();
    let out = rt
        .execute(&v.name, &[&vb, &ib, &db, &sb])
        .unwrap()
        .to_vec::<f64>()
        .unwrap();
    // Disjoint stride-1 scatter == flattened vals in the prefix.
    for (i, &x) in out[..v.count * 8].iter().enumerate() {
        assert_eq!(x, 1000.0 + i as f64, "slot {i}");
    }
    assert!(out[v.count * 8..].iter().all(|&x| x == 0.0));
}

#[test]
fn pallas_family_matches_ref_family_on_table5_shapes() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::open_default().unwrap();
    // v16 smoke-sized comparison uses the big v16 variants (c4096);
    // compare pallas vs ref on one PENNANT buffer.
    let (vp, vr) = match (
        rt.manifest().find("gather", "pallas", 16, None).cloned(),
        rt.manifest().find("gather", "ref", 16, None).cloned(),
    ) {
        (Some(a), Some(b)) => (a, b),
        _ => {
            eprintln!("pjrt_e2e: no v16 variants, skip");
            return;
        }
    };
    assert_eq!(vp.count, vr.count);
    let src: Vec<f64> = (0..vr.n).map(|i| ((i * 7) % 8191) as f64).collect();
    let g4 = table5::by_name("PENNANT-G4").unwrap();
    let idx: Vec<i32> = g4.indices.iter().map(|&i| i as i32).collect();
    let sb = rt.stage_f64(&src).unwrap();
    let ib = rt.stage_i32(&idx).unwrap();
    let db = rt.stage_i32(&[4]).unwrap();
    let a = rt
        .execute(&vp.name, &[&sb, &ib, &db])
        .unwrap()
        .to_vec::<f64>()
        .unwrap();
    let b = rt
        .execute(&vr.name, &[&sb, &ib, &db])
        .unwrap()
        .to_vec::<f64>()
        .unwrap();
    assert_eq!(a, b);
    // Spot-check semantics against the host:
    // out[i, j] = src[4*i + idx[j]]; idx[0] = 0.
    assert_eq!(a[0], src[0]);
    assert_eq!(a[16], src[4]);
    assert_eq!(a[4], src[1]); // idx[4] = 1
}

#[test]
fn backend_bandwidth_sane() {
    if !have_artifacts() {
        return;
    }
    let mut b = PjrtBackend::open_default().unwrap();
    b.runs = 3;
    let pat = Pattern::parse("UNIFORM:8:1")
        .unwrap()
        .with_delta(8)
        .with_count(1 << 18);
    let r = b.run(&pat, Kernel::Gather).unwrap();
    let bw = r.bandwidth_gbs();
    // Real hardware: somewhere between 0.05 and 500 GB/s.
    assert!(bw > 0.05 && bw < 500.0, "{bw}");
}
