//! Unified typed virtual-memory subsystem shared by the CPU and GPU
//! engines: address newtypes, page sizes, a set-associative TLB, and a
//! radix page-table walker.
//!
//! # The model
//!
//! The simulated machines translate like a VIPT (virtually indexed,
//! physically tagged) hierarchy: the TLB is probed in parallel with the
//! L1 set index, so a TLB *hit* adds no time to an access, while a TLB
//! *miss* charges a page-table walk whose latency scales with the radix
//! depth of the page size (4-level for 4 KiB, 3-level for 2 MiB,
//! 2-level for 1 GiB; the GPU's native 64 KiB large page is calibrated
//! at the platform's measured walk cost, i.e. full depth). Walks can
//! additionally miss the cache hierarchy: one 64-byte PTE line covers
//! 64 consecutive pages, so when the access stream's mean advance
//! exceeds `64 × page_bytes` the walker's PTE fetches are themselves
//! cold DRAM accesses and the walk traffic shows up on the DRAM
//! bottleneck (the PENNANT huge-delta mechanism, paper §5.4).
//!
//! # Simplifications
//!
//! * **Identity mapping.** Translation is VA == PA — the simulator has
//!   no OS, so there is nothing to relocate. The
//!   [`VirtualAddress`]/[`PhysicalAddress`] newtypes still pay their
//!   way: cache/DRAM/row-model code takes only [`PhysicalAddress`], so
//!   an untranslated address cannot reach the memory system by
//!   construction, and a property test pins the identity invariant.
//! * **One unified TLB per engine** (no L1/L2 TLB split); entry counts
//!   and associativities come from cpuid-style per-page-size tables in
//!   [`TlbTable`] (`platforms/mod.rs` instantiates one per machine).
//! * **Same-page short-circuit.** Consecutive accesses overwhelmingly
//!   hit the same page; the TLB caches the last VPN and skips the set
//!   scan (and its LRU refresh) for repeats — preserved from the
//!   original CPU engine, §Perf.
//! * **No TLB shootdowns, no dirty/accessed bits, no multi-page-size
//!   mixing** within one run: a run models exactly one [`PageSize`].

use super::cache::{Cache, Probe};
use super::closure;
use crate::error::{Error, Result};

/// Bytes per cache line / PTE line (the model is 64-byte everywhere).
const LINE_BYTES: u64 = 64;

/// A byte address in the simulated *virtual* address space — what the
/// pattern generator produces. Must be translated (through [`Tlb`])
/// before it can touch caches or DRAM.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct VirtualAddress(pub u64);

impl VirtualAddress {
    /// The raw byte address.
    #[inline]
    pub fn byte(self) -> u64 {
        self.0
    }

    /// Virtual page number under `page` (the TLB tag).
    #[inline]
    pub fn page_number(self, page: PageSize) -> u64 {
        self.0 >> page.shift()
    }
}

/// A byte address in the simulated *physical* address space — the only
/// currency the cache hierarchy and the DRAM row model accept.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PhysicalAddress(pub u64);

impl PhysicalAddress {
    /// The raw byte address.
    #[inline]
    pub fn byte(self) -> u64 {
        self.0
    }

    /// 64-byte cache-line number.
    #[inline]
    pub fn line(self) -> u64 {
        self.0 / LINE_BYTES
    }

    /// Rebuild from a 64-byte line number (prefetch targets are
    /// generated at line granularity).
    #[inline]
    pub fn from_line(line: u64) -> PhysicalAddress {
        PhysicalAddress(line * LINE_BYTES)
    }
}

/// Translation page size. `FourKB`/`TwoMB`/`OneGB` are the x86-64 radix
/// sizes; `SixtyFourKB` is the GPU's native large page (the seed model
/// translated GPU sectors at 64 KiB granularity and the GPU platforms'
/// walk costs are calibrated at that size).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PageSize {
    FourKB,
    SixtyFourKB,
    TwoMB,
    OneGB,
}

impl PageSize {
    /// Every size, in ascending order (for sweeps and property tests).
    pub const ALL: &'static [PageSize] = &[
        PageSize::FourKB,
        PageSize::SixtyFourKB,
        PageSize::TwoMB,
        PageSize::OneGB,
    ];

    /// log2(page bytes).
    #[inline]
    pub fn shift(self) -> u32 {
        match self {
            PageSize::FourKB => 12,
            PageSize::SixtyFourKB => 16,
            PageSize::TwoMB => 21,
            PageSize::OneGB => 30,
        }
    }

    /// Page size in bytes.
    #[inline]
    pub fn bytes(self) -> u64 {
        1u64 << self.shift()
    }

    /// Radix page-walk depth: how many page-table levels a cold walk
    /// traverses. Larger pages terminate earlier (2 MiB at the PMD,
    /// 1 GiB at the PUD). 64 KiB is a full-depth walk: it is the unit
    /// the GPU platforms' walk latencies were calibrated against.
    #[inline]
    pub fn walk_levels(self) -> u32 {
        match self {
            PageSize::FourKB | PageSize::SixtyFourKB => 4,
            PageSize::TwoMB => 3,
            PageSize::OneGB => 2,
        }
    }

    /// Display name (also the CLI/JSON syntax).
    pub fn name(self) -> &'static str {
        match self {
            PageSize::FourKB => "4KB",
            PageSize::SixtyFourKB => "64KB",
            PageSize::TwoMB => "2MB",
            PageSize::OneGB => "1GB",
        }
    }

    /// Parse the CLI/JSON syntax (`--page-size 2MB`, `"page-size":
    /// "2MB"`). Case-insensitive; the `B` is optional.
    pub fn parse(s: &str) -> Result<PageSize> {
        match s.to_ascii_lowercase().as_str() {
            "4kb" | "4k" | "4096" => Ok(PageSize::FourKB),
            "64kb" | "64k" | "65536" => Ok(PageSize::SixtyFourKB),
            "2mb" | "2m" => Ok(PageSize::TwoMB),
            "1gb" | "1g" => Ok(PageSize::OneGB),
            _ => Err(Error::Config(format!(
                "unknown page size '{s}' (expected 4KB, 64KB, 2MB, or 1GB)"
            ))),
        }
    }
}

impl Default for PageSize {
    /// The architectural default (CPU base page).
    fn default() -> PageSize {
        PageSize::FourKB
    }
}

impl std::fmt::Display for PageSize {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Geometry of one TLB structure: entry count and associativity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TlbGeometry {
    pub entries: usize,
    pub assoc: usize,
}

/// Per-page-size TLB geometries for one machine — the cpuid-style
/// table that replaces the old single `tlb_entries` scalar. Real parts
/// size their TLBs very differently per page size (e.g. thousands of
/// 4 KiB entries but a handful of 1 GiB ones); the per-machine tables
/// live in `platforms/mod.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TlbTable {
    pub four_kb: TlbGeometry,
    pub sixty_four_kb: TlbGeometry,
    pub two_mb: TlbGeometry,
    pub one_gb: TlbGeometry,
}

impl TlbTable {
    /// The geometry used when translating at `page`.
    pub fn geometry(&self, page: PageSize) -> TlbGeometry {
        match page {
            PageSize::FourKB => self.four_kb,
            PageSize::SixtyFourKB => self.sixty_four_kb,
            PageSize::TwoMB => self.two_mb,
            PageSize::OneGB => self.one_gb,
        }
    }
}

/// Read/write-split TLB hit/miss counters. Both engines report their
/// translation statistics through this one type (the regression test
/// in this module pins that), and `SimCounters` embeds it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TlbStats {
    pub read_hits: u64,
    pub read_misses: u64,
    pub write_hits: u64,
    pub write_misses: u64,
}

impl TlbStats {
    /// Record one translation outcome.
    #[inline]
    pub fn record(&mut self, is_write: bool, hit: bool) {
        match (is_write, hit) {
            (false, true) => self.read_hits += 1,
            (false, false) => self.read_misses += 1,
            (true, true) => self.write_hits += 1,
            (true, false) => self.write_misses += 1,
        }
    }

    /// Record `reps` same-page hits at once (the batched accounting
    /// behind `sim::plan`'s same-line run coalescing — every follower
    /// of a run head takes the same-page short-circuit).
    #[inline]
    pub fn record_repeat(&mut self, is_write: bool, reps: u64) {
        if is_write {
            self.write_hits += reps;
        } else {
            self.read_hits += reps;
        }
    }

    pub fn hits(&self) -> u64 {
        self.read_hits + self.write_hits
    }

    pub fn misses(&self) -> u64 {
        self.read_misses + self.write_misses
    }

    pub fn accesses(&self) -> u64 {
        self.hits() + self.misses()
    }

    /// Hit fraction, `None` when nothing was translated (real-execution
    /// backends have no TLB model).
    pub fn hit_rate(&self) -> Option<f64> {
        let n = self.accesses();
        if n == 0 {
            None
        } else {
            Some(self.hits() as f64 / n as f64)
        }
    }

    /// Miss fraction, `None` when nothing was translated.
    pub fn miss_rate(&self) -> Option<f64> {
        self.hit_rate().map(|h| 1.0 - h)
    }
}

/// One translation outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Translation {
    pub physical: PhysicalAddress,
    /// Whether the TLB held the mapping (same-page repeats count as
    /// hits — the hardware would not even probe).
    pub hit: bool,
}

/// Set-associative LRU TLB over virtual page numbers, built on the
/// same [`Cache`] model as the data hierarchy (one "line" per page).
/// Replaces the two divergent ad-hoc TLBs the CPU and GPU engines used
/// to build by hand.
#[derive(Debug, Clone)]
pub struct Tlb {
    cache: Cache,
    page_size: PageSize,
    /// Same-page short-circuit (§Perf): consecutive accesses hit the
    /// same page almost always; skip the set scan for repeats.
    last_vpn: u64,
}

impl Tlb {
    pub fn new(geometry: TlbGeometry, page_size: PageSize) -> Tlb {
        // One entry == one 64-byte "line" in the underlying cache
        // model, so capacity = entries × 64 with 64-byte lines.
        Tlb {
            cache: Cache::new(
                geometry.entries * LINE_BYTES as usize,
                LINE_BYTES as usize,
                geometry.assoc,
            ),
            page_size,
            last_vpn: u64::MAX,
        }
    }

    pub fn page_size(&self) -> PageSize {
        self.page_size
    }

    /// Number of sets in the underlying structure (entries may round
    /// down to a power-of-two set count, matching the cache model).
    pub fn sets(&self) -> usize {
        self.cache.sets()
    }

    pub fn assoc(&self) -> usize {
        self.cache.assoc()
    }

    /// Translate `va`, recording the outcome into `stats`. The mapping
    /// is identity (see module docs); the value of the call is the
    /// hit/miss outcome and the type change — downstream memory-system
    /// code only accepts the result.
    ///
    /// `is_write` classifies the access for the split statistics; it
    /// does not affect TLB state (the model tracks no dirty bits).
    #[inline]
    pub fn translate(
        &mut self,
        va: VirtualAddress,
        is_write: bool,
        stats: &mut TlbStats,
    ) -> Translation {
        let vpn = va.page_number(self.page_size);
        let physical = PhysicalAddress(va.0);
        if vpn == self.last_vpn {
            stats.record(is_write, true);
            return Translation { physical, hit: true };
        }
        let hit = match self.cache.access(vpn, false) {
            Probe::Hit { .. } => true,
            Probe::Miss => {
                self.cache.fill_after_miss(vpn, false, false);
                false
            }
        };
        stats.record(is_write, hit);
        self.last_vpn = vpn;
        Translation { physical, hit }
    }

    /// Batched same-page accounting (`sim::plan`): `reps` repeat
    /// translations of an address on the page `translate` just primed.
    /// Each repeat would take the same-page short-circuit — a pure
    /// statistics hit with no TLB state change — so the whole run
    /// telescopes into one counter add. Debug-asserts the caller's
    /// same-page guarantee.
    #[inline]
    pub fn note_same_page_repeats(
        &self,
        va: VirtualAddress,
        is_write: bool,
        reps: u64,
        stats: &mut TlbStats,
    ) {
        debug_assert_eq!(
            va.page_number(self.page_size),
            self.last_vpn,
            "same-page repeats must follow a translate of the same page"
        );
        stats.record_repeat(is_write, reps);
    }

    /// Digest of the TLB's complete state relative to `base_vpn`
    /// (residency, LRU ages, and the same-page short-circuit), for the
    /// loop-closure fingerprint. O(1) via the incremental signature.
    pub fn state_digest(&self, base_vpn: u64, seed: u64) -> u64 {
        let rel = if self.last_vpn == u64::MAX {
            u64::MAX
        } else {
            self.last_vpn.wrapping_sub(base_vpn)
        };
        closure::fold(self.cache.state_digest(base_vpn, seed), rel)
    }

    /// Shift the whole TLB state forward by `delta_pages` virtual
    /// pages (loop-closure fast-forward; exact, see
    /// [`Cache::relocate`]).
    pub fn relocate(&mut self, delta_pages: u64) {
        if delta_pages == 0 {
            return;
        }
        self.cache.relocate(delta_pages);
        if self.last_vpn != u64::MAX {
            self.last_vpn = self.last_vpn.wrapping_add(delta_pages);
        }
    }

    /// Clear contents and the short-circuit state.
    pub fn reset(&mut self) {
        self.cache.reset();
        self.last_vpn = u64::MAX;
    }
}

/// Radix page-table walker: latency model for TLB misses, shared by
/// both engines. Replaces the inline `tlb_walk_ns / 2.0` heuristic the
/// CPU engine used to carry.
#[derive(Debug, Clone, Copy)]
pub struct PageTableWalker {
    /// Platform walk cost for a full-depth (4-level) walk, ns.
    base_walk_ns: f64,
    page: PageSize,
    /// How many walks proceed concurrently (CPU: ~2 per thread; GPU:
    /// the platform's walker MLP).
    overlap: f64,
}

impl PageTableWalker {
    pub fn new(base_walk_ns: f64, page: PageSize, overlap: f64) -> PageTableWalker {
        assert!(overlap > 0.0, "walker overlap must be positive");
        PageTableWalker {
            base_walk_ns,
            page,
            overlap,
        }
    }

    pub fn page_size(&self) -> PageSize {
        self.page
    }

    /// Depth of one walk for this page size.
    pub fn levels(&self) -> u32 {
        self.page.walk_levels()
    }

    /// Latency of one cold walk: the platform's measured full-depth
    /// cost scaled by radix depth (larger pages skip levels).
    pub fn walk_ns(&self) -> f64 {
        self.base_walk_ns * self.levels() as f64 / 4.0
    }

    /// Effective serialized cost per TLB miss once walk overlap is
    /// accounted for — what the bottleneck timing charges.
    pub fn ns_per_miss(&self) -> f64 {
        self.walk_ns() / self.overlap
    }

    /// Page-table lines a cold walk fetches from DRAM when the touched
    /// pages are sparse. The top two radix levels are tiny and stay hot
    /// in the cache hierarchy; deeper levels are one line per walk.
    pub fn uncached_lines_per_walk(&self) -> u64 {
        self.levels().saturating_sub(2) as u64
    }

    /// Address span covered by one 64-byte PTE line (64 entries × page
    /// size). When the access stream's mean advance exceeds this, every
    /// walk touches cold PTE lines and the walk traffic hits DRAM.
    pub fn pte_line_coverage_bytes(&self) -> f64 {
        64.0 * self.page.bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::{Kernel, Pattern};
    use crate::platforms;
    use crate::sim::cpu::CpuEngine;
    use crate::sim::gpu::GpuEngine;

    #[test]
    fn page_size_table() {
        assert_eq!(PageSize::FourKB.bytes(), 4096);
        assert_eq!(PageSize::SixtyFourKB.bytes(), 64 * 1024);
        assert_eq!(PageSize::TwoMB.bytes(), 2 * 1024 * 1024);
        assert_eq!(PageSize::OneGB.bytes(), 1024 * 1024 * 1024);
        assert_eq!(PageSize::FourKB.walk_levels(), 4);
        assert_eq!(PageSize::TwoMB.walk_levels(), 3);
        assert_eq!(PageSize::OneGB.walk_levels(), 2);
        assert_eq!(PageSize::default(), PageSize::FourKB);
    }

    #[test]
    fn page_size_parse_roundtrip() {
        for &p in PageSize::ALL {
            assert_eq!(PageSize::parse(p.name()).unwrap(), p);
            assert_eq!(PageSize::parse(&p.name().to_lowercase()).unwrap(), p);
        }
        assert_eq!(PageSize::parse("2m").unwrap(), PageSize::TwoMB);
        assert_eq!(PageSize::parse("4096").unwrap(), PageSize::FourKB);
        assert!(PageSize::parse("3MB").is_err());
        assert!(PageSize::parse("").is_err());
    }

    #[test]
    fn address_newtypes() {
        let va = VirtualAddress(2 * 1024 * 1024 + 4096 + 8);
        assert_eq!(va.page_number(PageSize::FourKB), 513);
        assert_eq!(va.page_number(PageSize::TwoMB), 1);
        let pa = PhysicalAddress(va.byte());
        assert_eq!(pa.line(), va.byte() / 64);
        assert_eq!(PhysicalAddress::from_line(pa.line()).byte(), pa.byte() & !63);
    }

    fn small_tlb(page: PageSize) -> Tlb {
        // 4 sets × 2 ways = 8 entries.
        Tlb::new(TlbGeometry { entries: 8, assoc: 2 }, page)
    }

    #[test]
    fn tlb_translation_is_identity() {
        let mut t = small_tlb(PageSize::FourKB);
        let mut stats = TlbStats::default();
        for addr in [0u64, 7, 4096, 1 << 30, u64::MAX >> 8] {
            let tr = t.translate(VirtualAddress(addr), false, &mut stats);
            assert_eq!(tr.physical.byte(), addr);
        }
        assert_eq!(stats.accesses(), 5);
    }

    #[test]
    fn tlb_set_indexing_keeps_distinct_sets_resident() {
        let mut t = small_tlb(PageSize::FourKB);
        let mut stats = TlbStats::default();
        // VPNs 0..4 map to the 4 different sets: all coexist.
        for vpn in 0..4u64 {
            let miss =
                !t.translate(VirtualAddress(vpn * 4096), false, &mut stats).hit;
            assert!(miss, "first touch of vpn {vpn} must miss");
        }
        for vpn in (0..4u64).rev() {
            assert!(
                t.translate(VirtualAddress(vpn * 4096), false, &mut stats).hit,
                "vpn {vpn} should still be resident"
            );
        }
    }

    #[test]
    fn tlb_lru_eviction_within_a_set() {
        let mut t = small_tlb(PageSize::FourKB);
        let mut st = TlbStats::default();
        // VPNs 0, 4, 8 all land in set 0 of the 4-set, 2-way TLB.
        let page = |vpn: u64| VirtualAddress(vpn * 4096);
        assert!(!t.translate(page(0), false, &mut st).hit);
        assert!(!t.translate(page(4), false, &mut st).hit);
        // Touch 0 so 4 becomes LRU; inserting 8 must evict 4.
        assert!(t.translate(page(0), false, &mut st).hit);
        assert!(!t.translate(page(8), false, &mut st).hit);
        assert!(t.translate(page(0), false, &mut st).hit, "0 was MRU");
        assert!(!t.translate(page(4), false, &mut st).hit, "4 was evicted");
        assert_eq!(st.misses(), 4);
    }

    /// `reps` scalar same-page translations and one
    /// `note_same_page_repeats` produce identical statistics and state
    /// (the batched accounting behind `sim::plan`).
    #[test]
    fn tlb_repeat_accounting_matches_scalar_translations() {
        for is_write in [false, true] {
            let mut scalar = small_tlb(PageSize::FourKB);
            let mut bulk = small_tlb(PageSize::FourKB);
            let mut ss = TlbStats::default();
            let mut bs = TlbStats::default();
            let va = VirtualAddress(4096 * 3 + 8);
            scalar.translate(va, is_write, &mut ss);
            bulk.translate(va, is_write, &mut bs);
            for _ in 0..6 {
                scalar.translate(VirtualAddress(va.byte() + 8), is_write, &mut ss);
            }
            bulk.note_same_page_repeats(
                VirtualAddress(va.byte() + 8),
                is_write,
                6,
                &mut bs,
            );
            assert_eq!(ss, bs, "write={is_write}");
            assert_eq!(
                scalar.state_digest(0, crate::sim::closure::SEED_A),
                bulk.state_digest(0, crate::sim::closure::SEED_A)
            );
        }
    }

    #[test]
    fn tlb_same_page_short_circuit_counts_hits() {
        let mut t = small_tlb(PageSize::FourKB);
        let mut stats = TlbStats::default();
        // 8 consecutive doubles on one page: 1 miss, 7 short-circuits.
        for j in 0..8u64 {
            t.translate(VirtualAddress(j * 8), false, &mut stats);
        }
        assert_eq!(stats.read_misses, 1);
        assert_eq!(stats.read_hits, 7);
        assert_eq!(stats.accesses(), 8);
        assert!((stats.hit_rate().unwrap() - 7.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn tlb_page_size_changes_reach() {
        // A 128 KiB-spaced stream: every access a new 4 KiB page, but
        // sixteen accesses per 2 MiB page.
        let count = 64u64;
        for (page, expect_misses) in
            [(PageSize::FourKB, count), (PageSize::TwoMB, count / 16)]
        {
            let mut t = Tlb::new(TlbGeometry { entries: 64, assoc: 4 }, page);
            let mut stats = TlbStats::default();
            for i in 0..count {
                t.translate(VirtualAddress(i * 128 * 1024), false, &mut stats);
            }
            assert_eq!(stats.misses(), expect_misses, "page {page}");
        }
    }

    #[test]
    fn tlb_reset_clears_residency() {
        let mut t = small_tlb(PageSize::FourKB);
        let mut st = TlbStats::default();
        assert!(!t.translate(VirtualAddress(0), false, &mut st).hit);
        assert!(t.translate(VirtualAddress(0), false, &mut st).hit);
        t.reset();
        assert!(!t.translate(VirtualAddress(0), false, &mut st).hit);
    }

    #[test]
    fn walker_latency_scales_with_depth() {
        let base = 80.0;
        let w4k = PageTableWalker::new(base, PageSize::FourKB, 2.0);
        let w64k = PageTableWalker::new(base, PageSize::SixtyFourKB, 2.0);
        let w2m = PageTableWalker::new(base, PageSize::TwoMB, 2.0);
        let w1g = PageTableWalker::new(base, PageSize::OneGB, 2.0);
        // The platform cost calibrates the full-depth walk.
        assert!((w4k.walk_ns() - base).abs() < 1e-12);
        assert!((w64k.walk_ns() - base).abs() < 1e-12);
        assert!((w2m.walk_ns() - base * 0.75).abs() < 1e-12);
        assert!((w1g.walk_ns() - base * 0.5).abs() < 1e-12);
        // Overlap divides the charged cost.
        assert!((w4k.ns_per_miss() - base / 2.0).abs() < 1e-12);
        // Deeper walks touch more cold PTE lines.
        assert_eq!(w4k.uncached_lines_per_walk(), 2);
        assert_eq!(w2m.uncached_lines_per_walk(), 1);
        assert_eq!(w1g.uncached_lines_per_walk(), 0);
        // One PTE line covers 64 pages.
        assert!((w4k.pte_line_coverage_bytes() - 64.0 * 4096.0).abs() < 1e-9);
    }

    #[test]
    fn tlb_table_selects_per_size_geometry() {
        let table = TlbTable {
            four_kb: TlbGeometry { entries: 1536, assoc: 4 },
            sixty_four_kb: TlbGeometry { entries: 1536, assoc: 4 },
            two_mb: TlbGeometry { entries: 32, assoc: 4 },
            one_gb: TlbGeometry { entries: 4, assoc: 4 },
        };
        assert_eq!(table.geometry(PageSize::FourKB).entries, 1536);
        assert_eq!(table.geometry(PageSize::TwoMB).entries, 32);
        assert_eq!(table.geometry(PageSize::OneGB).entries, 4);
    }

    /// Regression test for the old duplicated TLBs: both engines must
    /// report translation statistics through the one shared `TlbStats`
    /// type, with conserving counts.
    #[test]
    fn cpu_and_gpu_report_tlb_stats_through_the_same_type() {
        fn check_stats(stats: &TlbStats, accesses: u64) {
            assert_eq!(stats.hits() + stats.misses(), stats.accesses());
            assert!(stats.misses() <= accesses);
            let rate = stats.hit_rate().unwrap();
            assert!((0.0..=1.0).contains(&rate));
        }

        let cpu = platforms::by_name("skx").unwrap();
        let pat = Pattern::parse("UNIFORM:8:4")
            .unwrap()
            .with_delta(32)
            .with_count(1 << 14);
        let rc = CpuEngine::new(&cpu).run(&pat, Kernel::Gather).unwrap();
        check_stats(&rc.counters.tlb, rc.counters.accesses);
        // CPU translates once per access.
        assert_eq!(rc.counters.tlb.accesses(), rc.counters.accesses);

        let gpu = platforms::gpu_by_name("p100").unwrap();
        let gpat = Pattern::parse("UNIFORM:256:4")
            .unwrap()
            .with_delta(1024)
            .with_count(1 << 11);
        let rg = GpuEngine::new(&gpu).run(&gpat, Kernel::Scatter).unwrap();
        check_stats(&rg.counters.tlb, rg.counters.accesses);
        // GPU translates once per coalesced transaction.
        assert_eq!(rg.counters.tlb.accesses(), rg.counters.transactions);
    }

    #[test]
    fn tlb_digest_and_relocate_are_shift_exact() {
        use crate::sim::closure::SEED_A;
        // Two TLBs fed the same page stream shifted by a whole number
        // of pages digest identically relative to their bases, and
        // relocation reproduces the shifted history exactly.
        let d_pages = 1 << 12; // multiple of the set count
        let mut a = small_tlb(PageSize::FourKB);
        let mut b = small_tlb(PageSize::FourKB);
        let mut sa = TlbStats::default();
        let mut sb = TlbStats::default();
        for vpn in [0u64, 3, 3, 9, 1, 17, 3] {
            a.translate(VirtualAddress(vpn * 4096), false, &mut sa);
            b.translate(VirtualAddress((vpn + d_pages) * 4096), false, &mut sb);
        }
        assert_eq!(a.state_digest(0, SEED_A), b.state_digest(d_pages, SEED_A));
        a.relocate(d_pages);
        assert_eq!(a.state_digest(d_pages, SEED_A), b.state_digest(d_pages, SEED_A));
        // Identical behaviour from here on.
        for vpn in [3u64, 21, 9, 64, 17] {
            let va = VirtualAddress((vpn + d_pages) * 4096);
            assert_eq!(
                a.translate(va, false, &mut sa).hit,
                b.translate(va, false, &mut sb).hit,
                "vpn {vpn}"
            );
        }
    }

    #[test]
    fn gpu_engine_defaults_to_its_native_large_page() {
        let gpu = platforms::gpu_by_name("v100").unwrap();
        let e = GpuEngine::new(&gpu);
        assert_eq!(e.page_size(), PageSize::SixtyFourKB);
        let cpu = platforms::by_name("bdw").unwrap();
        let c = CpuEngine::new(&cpu);
        assert_eq!(c.page_size(), PageSize::FourKB);
    }
}
