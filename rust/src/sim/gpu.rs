//! GPU memory-system engine: warp-level sector coalescing + L2 + DRAM
//! row model + GPU TLB (the Fig 5 / Table 4 GPU mechanisms).
//!
//! Model of the paper's CUDA backend (§3.2): a thread block performs
//! one Spatter iteration; the index buffer sits in shared memory; each
//! warp of 32 threads issues 32 consecutive elements of the gather.
//! The memory system coalesces each warp's addresses into unique
//! *sectors* (32 B on Pascal+, 128 B line-transactions on Kepler — the
//! coalescing difference the paper observes between the K40c and the
//! newer parts).
//!
//! Timing is the same bottleneck-max style as the CPU engine:
//!
//! ```text
//! t = max( txn-issue, L2-bw, DRAM-bw (+row activations), TLB, write-contention )
//! ```
//!
//! Scatter pays a read-modify-write for partially covered sectors
//! (gather plateaus at 1/4 of peak, scatter at 1/8 — Fig 5), and
//! delta-0 scatters serialize on sector ownership (LULESH-S3).

use super::cache::{Cache, Probe};
use super::closure::{self, LoopCloser, Observation};
use super::dram::DramModel;
use super::memory::{
    PageSize, PageTableWalker, PhysicalAddress, Tlb, VirtualAddress,
};
use super::plan::GpuPlan;
use super::{SimCounters, SimResult, TimeBreakdown, XorShift64};
use crate::error::Result;
use crate::pattern::{Kernel, Pattern};
use crate::platforms::GpuPlatform;

/// Warp width (threads / elements per coalescing window).
const WARP: usize = 32;

/// Options for a simulated GPU run.
///
/// There is no vectorization-regime knob here (`CpuSimOptions::regime`
/// / `--vector-regime`): the GPU's SIMD story is warp-level sector
/// coalescing, not a scalar-vs-vector-ISA choice, so the CLI rejects
/// the flag on the `cuda` backend.
#[derive(Debug, Clone)]
pub struct GpuSimOptions {
    /// Cap on simulated accesses in the measured pass.
    pub max_sim_accesses: usize,
    /// Warmup iterations (min-of-10 protocol, warm L2/TLB).
    pub warmup_iterations: usize,
    /// Translation page size. GPUs translate at their native 64 KiB
    /// large page by default (the granularity the platforms' walk
    /// costs are calibrated at); `--page-size` overrides.
    pub page_size: PageSize,
    /// Steady-state loop closure (`sim::closure`) — same contract as
    /// the CPU engine: bit-identical counters, disable only for A/B
    /// benchmarking (`SPATTER_NO_CLOSURE`).
    pub closure_enabled: bool,
    /// Batch-compiled access plans (`sim::plan`) — same contract as
    /// the CPU engine: the run's warps and their coalesced sector
    /// lists are compiled once per `run()`, counters stay
    /// bit-identical to the scalar path, and `SPATTER_NO_PLAN`
    /// disables for A/B benchmarking.
    pub plan_enabled: bool,
}

impl Default for GpuSimOptions {
    fn default() -> Self {
        GpuSimOptions {
            max_sim_accesses: 1 << 21,
            warmup_iterations: 1 << 13,
            page_size: PageSize::SixtyFourKB,
            closure_enabled: std::env::var_os("SPATTER_NO_CLOSURE").is_none(),
            plan_enabled: std::env::var_os("SPATTER_NO_PLAN").is_none(),
        }
    }
}

/// The GPU engine. Reusable across runs.
pub struct GpuEngine {
    platform: GpuPlatform,
    opts: GpuSimOptions,
    /// L2 tracked at sector granularity.
    l2: Cache,
    /// Shared virtual-memory subsystem (same types as the CPU engine):
    /// per-transaction translation + parallel-walker latency model.
    tlb: Tlb,
    walker: PageTableWalker,
    /// Banked DRAM row-buffer model (`sim::dram`) at the platform's
    /// row size, shared by every operand stream with per-stream slot
    /// offsets (see the CPU engine).
    dram: DramModel,
    /// Scratch: sector ids of the current warp (cleared in place,
    /// never reallocated — see the scratch invariants in `sim`).
    warp_sectors: Vec<(u64, u32)>,
    /// Scratch: the index buffer pre-scaled to byte offsets, rebuilt
    /// once per pass.
    idx_bytes: Vec<u64>,
    /// Scratch: the GS scatter-side buffer pre-scaled to byte offsets
    /// including the write-region base (empty for single-buffer
    /// kernels).
    idx2_bytes: Vec<u64>,
    /// Batch-compiled access plan (`sim::plan`): every warp's offset
    /// slice and precomputed coalesced sector list, compiled once per
    /// `run()`. Engine-owned scratch, rebuilt in place.
    plan: GpuPlan,
}

impl GpuEngine {
    pub fn new(platform: &GpuPlatform) -> GpuEngine {
        GpuEngine::with_options(platform, GpuSimOptions::default())
    }

    pub fn with_options(platform: &GpuPlatform, opts: GpuSimOptions) -> GpuEngine {
        let p = platform.clone();
        let page = opts.page_size;
        GpuEngine {
            l2: Cache::new(p.l2_kb * 1024, p.sector_bytes as usize, p.l2_assoc),
            tlb: Tlb::new(p.tlb.geometry(page), page),
            walker: PageTableWalker::new(p.tlb_walk_ns, page, p.tlb_mlp),
            dram: DramModel::new(&p.dram, p.row_bytes),
            warp_sectors: Vec::with_capacity(WARP),
            idx_bytes: Vec::new(),
            idx2_bytes: Vec::new(),
            plan: GpuPlan::default(),
            platform: p,
            opts,
        }
    }

    pub fn platform(&self) -> &GpuPlatform {
        &self.platform
    }

    /// The page size the next run will model.
    pub fn page_size(&self) -> PageSize {
        self.tlb.page_size()
    }

    /// Reconfigure the translation page size: `Some` overrides, `None`
    /// restores the engine's configured default (64 KiB large pages).
    pub fn set_page_size(&mut self, page: Option<PageSize>) {
        let page = page.unwrap_or(self.opts.page_size);
        if page == self.page_size() {
            return;
        }
        self.tlb = Tlb::new(self.platform.tlb.geometry(page), page);
        self.walker = PageTableWalker::new(
            self.platform.tlb_walk_ns,
            page,
            self.platform.tlb_mlp,
        );
    }

    fn reset(&mut self) {
        self.l2.reset();
        self.tlb.reset();
        self.dram.reset();
    }

    /// Simulate one Spatter run on the GPU model.
    pub fn run(&mut self, pattern: &Pattern, kernel: Kernel) -> Result<SimResult> {
        pattern.validate_for(kernel)?;
        self.reset();
        debug_assert_eq!(
            self.tlb.page_size(),
            self.walker.page_size(),
            "TLB and walker must be rebuilt together (set_page_size)"
        );

        let v = pattern.vector_len();
        let cap_iters =
            (self.opts.max_sim_accesses / (v * kernel.streams())).max(1);
        let measured = pattern.count.min(cap_iters);

        // Warmup (tail iterations of the "previous" run). Closure
        // applies here too, fast-forwarding to the exact warm state.
        let warmup = pattern.count.min(self.opts.warmup_iterations);
        // Batch-compiled plan (`sim::plan`) — see the CPU engine; GUPS
        // draws addresses from a per-pass RNG and stays scalar.
        let use_plan = self.opts.plan_enabled && kernel != Kernel::Gups;
        if use_plan {
            let mut plan = std::mem::take(&mut self.plan);
            plan.build_gpu(pattern, kernel, self.platform.sector_bytes);
            self.plan = plan;
        }
        let mut scratch = SimCounters::default();
        if use_plan {
            self.pass_planned(
                pattern,
                pattern.count - warmup,
                pattern.count,
                &mut scratch,
            );
        } else {
            self.pass(
                pattern,
                pattern.count - warmup,
                pattern.count,
                kernel,
                true,
                &mut scratch,
            );
        }

        let mut counters = SimCounters::default();
        let closed_at = if use_plan {
            self.pass_planned(pattern, 0, measured, &mut counters)
        } else {
            self.pass(pattern, 0, measured, kernel, false, &mut counters)
        };

        let breakdown = self.timing(&counters, pattern, kernel, measured);
        let scale = pattern.count as f64 / measured as f64;
        // Useful bytes: the indexed-copy/update payload counted once,
        // except the STREAM tetrad, which counts every operand stream
        // (STREAM's own convention — see the CPU engine's note).
        Ok(SimResult {
            seconds: breakdown.total() * scale,
            useful_bytes: pattern.moved_bytes() as u64
                * kernel.payload_streams() as u64,
            counters,
            breakdown,
            simulated_iterations: measured,
            closed_at_iteration: closed_at,
        })
    }

    /// Simulate iterations [begin, end), with steady-state loop
    /// closure (see `sim::closure` and the CPU engine's `pass` — same
    /// exactness argument, minus the prefetcher and plus the sector
    /// granularity).
    fn pass(
        &mut self,
        pattern: &Pattern,
        begin: usize,
        end: usize,
        kernel: Kernel,
        warm: bool,
        c: &mut SimCounters,
    ) -> Option<usize> {
        if kernel == Kernel::Gups {
            return self.pass_gups(pattern, begin, end, warm, c);
        }
        let v = pattern.vector_len();
        let mut base = pattern.base(begin);
        let primary_write = kernel == Kernel::Scatter;
        let read_streams = kernel.read_streams();
        let mut idx = std::mem::take(&mut self.idx_bytes);
        idx.clear();
        match kernel {
            // Dense kernels: one contiguous operand array per read
            // stream, each its own span-sized 1 GiB-aligned allocation.
            Kernel::Stream(_) => {
                let region = pattern.dense_region_bytes();
                for r in 0..read_streams as u64 {
                    idx.extend(
                        pattern
                            .indices
                            .iter()
                            .map(|&i| r * region + i as u64 * 8),
                    );
                }
            }
            _ => idx.extend(pattern.indices.iter().map(|&i| i as u64 * 8)),
        }
        // Write side (GS scatter side / dense output stream): separate
        // write region, same per-iteration base advance (see the CPU
        // engine).
        let mut idx2 = std::mem::take(&mut self.idx2_bytes);
        idx2.clear();
        match kernel {
            Kernel::GS => {
                let dst = pattern.gs_scatter_base() as u64 * 8;
                idx2.extend(
                    pattern.scatter_indices.iter().map(|&i| dst + i as u64 * 8),
                );
            }
            Kernel::Stream(_) => {
                let dst = read_streams as u64 * pattern.dense_region_bytes();
                idx2.extend(
                    pattern.indices.iter().map(|&i| dst + i as u64 * 8),
                );
            }
            _ => {}
        }
        let period = pattern.deltas.len().max(1);
        let mut closer = if self.opts.closure_enabled && end > begin + 1 {
            Some(LoopCloser::new())
        } else {
            None
        };
        let mut closed_at = None;
        let mut i = begin;
        while i < end {
            let base_bytes = (base as u64) * 8;
            // Each warp covers 32 consecutive slots of one operand
            // stream (each read stream is `v` slots of the pre-scaled
            // buffer and owns its open-row slot).
            for (sid, stream) in idx.chunks(v).enumerate() {
                let mut j = 0;
                while j < stream.len() {
                    let hi = (j + WARP).min(stream.len());
                    self.warp(&stream[j..hi], base_bytes, primary_write, sid, c);
                    j = hi;
                }
            }
            // Write stream: the block reads the vector, then writes it
            // — warps re-coalesce over the write side.
            let mut j = 0;
            while j < idx2.len() {
                let hi = (j + WARP).min(idx2.len());
                self.warp(&idx2[j..hi], base_bytes, true, read_streams, c);
                j = hi;
            }
            base += pattern.delta_at(i);
            i += 1;
            if closer.is_some() && i < end {
                let key = self.pass_digest(base, i % period);
                let obs = closer.as_mut().unwrap().observe(key, i, base, c);
                match obs {
                    Observation::Recorded => {}
                    Observation::Saturated => closer = None,
                    Observation::Cycle(info) => {
                        let cycle = i - info.iter;
                        let reps = (end - i) / cycle;
                        // Report closure only when iterations were
                        // actually skipped (a cycle longer than the
                        // remaining tail closes nothing).
                        if reps > 0 {
                            closed_at = Some(i);
                            let d = c.delta_since(&info.counters);
                            c.add_scaled(&d, reps as u64);
                            let advance = (base - info.base) as u64;
                            let shift_elems = advance * reps as u64;
                            self.fast_forward(shift_elems);
                            base += shift_elems as i64;
                            i += cycle * reps;
                        }
                        closer = None;
                    }
                }
            }
        }
        self.idx_bytes = idx;
        self.idx2_bytes = idx2;
        closed_at
    }

    /// Planned pass (`sim::plan`): iterations [begin, end) replayed
    /// from the precompiled plan, under the same loop-closure protocol
    /// as the scalar [`GpuEngine::pass`]. When the iteration base is
    /// sector-aligned, each warp's dedupe + sort is skipped entirely
    /// and its precomputed coalesced transactions replay against the
    /// shifted base sector; otherwise the warp falls back to the
    /// scalar coalescer over the plan's offset slices. Counters are
    /// bit-identical either way (pinned by
    /// `tests/plan_equivalence.rs`).
    fn pass_planned(
        &mut self,
        pattern: &Pattern,
        begin: usize,
        end: usize,
        c: &mut SimCounters,
    ) -> Option<usize> {
        let plan = std::mem::take(&mut self.plan);
        let sector_b = self.platform.sector_bytes;
        let mut base = pattern.base(begin);
        let period = pattern.deltas.len().max(1);
        let mut closer = if self.opts.closure_enabled && end > begin + 1 {
            Some(LoopCloser::new())
        } else {
            None
        };
        let mut closed_at = None;
        let mut i = begin;
        while i < end {
            let base_bytes = (base as u64) * 8;
            if base_bytes % sector_b == 0 {
                // Sector-aligned base: relative sectors shift to
                // absolute ones without re-partitioning (see
                // `sim::plan`), so the coalescing work vanishes.
                let base_sector = base_bytes / sector_b;
                for w in &plan.warps {
                    c.accesses += (w.off_end - w.off_start) as u64;
                    for &(rel, elems) in &plan.sectors[w.sec_start..w.sec_end] {
                        self.sector_txn(base_sector + rel, elems, w.write, w.sid, c);
                    }
                }
            } else {
                for w in &plan.warps {
                    self.warp(
                        &plan.offsets[w.off_start..w.off_end],
                        base_bytes,
                        w.write,
                        w.sid,
                        c,
                    );
                }
            }
            base += pattern.delta_at(i);
            i += 1;
            if closer.is_some() && i < end {
                let key = self.pass_digest(base, i % period);
                let obs = closer.as_mut().unwrap().observe(key, i, base, c);
                match obs {
                    Observation::Recorded => {}
                    Observation::Saturated => closer = None,
                    Observation::Cycle(info) => {
                        let cycle = i - info.iter;
                        let reps = (end - i) / cycle;
                        if reps > 0 {
                            closed_at = Some(i);
                            let d = c.delta_since(&info.counters);
                            c.add_scaled(&d, reps as u64);
                            let advance = (base - info.base) as u64;
                            let shift_elems = advance * reps as u64;
                            self.fast_forward(shift_elems);
                            base += shift_elems as i64;
                            i += cycle * reps;
                        }
                        closer = None;
                    }
                }
            }
        }
        self.plan = plan;
        closed_at
    }

    /// GUPS pass: warps of seeded-xorshift random updates into the
    /// power-of-two table. Each warp's addresses coalesce (vacuously —
    /// random 64-bit addresses land in distinct sectors) and every
    /// partially-covered sector pays the read-modify-write, so GUPS
    /// exercises the TLB + DRAM-row worst case per transaction. The
    /// warm-up pass draws a disjoint seeded stream (`warm` — see the
    /// CPU engine); the xorshift never cycles, so loop closure has
    /// nothing to close and on/off is trivially bit-identical.
    fn pass_gups(
        &mut self,
        pattern: &Pattern,
        begin: usize,
        end: usize,
        warm: bool,
        c: &mut SimCounters,
    ) -> Option<usize> {
        let mask = pattern.gups_table_elems() - 1;
        let v = pattern.vector_len();
        let mut rng = XorShift64::seeded(begin, warm);
        // Reuse the index scratch as the per-warp address buffer.
        let mut buf = std::mem::take(&mut self.idx_bytes);
        for _ in begin..end {
            let mut done = 0;
            while done < v {
                let n = WARP.min(v - done);
                buf.clear();
                for _ in 0..n {
                    buf.push((rng.next_u64() & mask) * 8);
                }
                self.warp(&buf, 0, true, 0, c);
                done += n;
            }
        }
        self.idx_bytes = buf;
        None
    }

    /// 128-bit fingerprint of the engine state relative to the current
    /// base (L2 at sector granularity, TLB, banked DRAM rows) plus the
    /// base's page/span/sector alignment residues and the delta-cycle
    /// phase.
    fn pass_digest(&self, base: i64, phase: usize) -> u128 {
        let base_bytes = (base as u64) * 8;
        let sector_b = self.platform.sector_bytes;
        let page = self.tlb.page_size();
        let base_sector = base_bytes / sector_b;
        let base_vpn = base_bytes >> page.shift();
        let mut out = [0u64; 2];
        for (slot, seed) in [closure::SEED_A, closure::SEED_B].into_iter().enumerate()
        {
            let mut h = seed;
            h = closure::fold(h, self.l2.state_digest(base_sector, seed));
            h = closure::fold(h, self.tlb.state_digest(base_vpn, seed));
            // The banked DRAM digest embeds the base's bank-span
            // residue (a multiple of the row residue it replaces).
            h = closure::fold(h, self.dram.state_digest(base_bytes, seed));
            h = closure::fold(h, base_bytes % page.bytes());
            h = closure::fold(h, base_bytes % sector_b);
            h = closure::fold(h, phase as u64);
            out[slot] = h;
        }
        ((out[0] as u128) << 64) | out[1] as u128
    }

    /// Loop-closure fast-forward: shift the engine state by
    /// `shift_elems` elements. Exact — the shift is a multiple of the
    /// page, DRAM bank-span, and sector sizes (all embedded in the
    /// fingerprint residues).
    fn fast_forward(&mut self, shift_elems: u64) {
        let bytes = shift_elems * 8;
        if bytes == 0 {
            return;
        }
        self.l2.relocate(bytes / self.platform.sector_bytes);
        self.tlb.relocate(bytes >> self.tlb.page_size().shift());
        self.dram.relocate(bytes);
    }

    /// Coalesce one warp's addresses (pre-scaled byte offsets against
    /// `base_bytes`) into unique sectors and charge the memory system,
    /// tracking DRAM row locality against operand stream `sid`'s open
    /// row.
    fn warp(
        &mut self,
        offsets: &[u64],
        base_bytes: u64,
        is_write: bool,
        sid: usize,
        c: &mut SimCounters,
    ) {
        let sector_b = self.platform.sector_bytes;
        self.warp_sectors.clear();
        for &off in offsets {
            c.accesses += 1;
            let byte = base_bytes + off;
            let sector = byte / sector_b;
            // Count elements per unique sector (coverage for the
            // scatter RMW rule).
            match self
                .warp_sectors
                .iter_mut()
                .find(|(s, _)| *s == sector)
            {
                Some((_, n)) => *n += 1,
                None => self.warp_sectors.push((sector, 1)),
            }
        }
        // Keep row-locality realistic within a warp.
        self.warp_sectors.sort_unstable_by_key(|(s, _)| *s);

        // Engine scratch, indexed in place (disjoint borrows — no move
        // dance, no allocation once warm, §Perf).
        let mut k = 0;
        while k < self.warp_sectors.len() {
            let (sector, elems) = self.warp_sectors[k];
            k += 1;
            self.sector_txn(sector, elems, is_write, sid, c);
        }
    }

    /// Charge one coalesced transaction (`elems` elements of `sector`)
    /// to the memory system — the shared body of the scalar `warp`
    /// coalescer and the planned pass's precomputed replay.
    #[inline]
    fn sector_txn(
        &mut self,
        sector: u64,
        elems: u32,
        is_write: bool,
        sid: usize,
        c: &mut SimCounters,
    ) {
        let sector_b = self.platform.sector_bytes;
        c.transactions += 1;

        // Translate the sector's base address through the shared
        // TLB (one translation per coalesced transaction).
        let t = self.tlb.translate(
            VirtualAddress(sector * sector_b),
            is_write,
            &mut c.tlb,
        );
        let pa = t.physical;

        // Scatter: partially covered sectors read-modify-write
        // (Fig 5's 1/8 scatter plateau vs 1/4 gather plateau).
        let coverage = (elems as u64 * 8) as f64 / sector_b as f64;
        let needs_rmw = is_write && coverage < 0.5;

        match self.l2.access(sector, is_write) {
            Probe::Hit { .. } => {
                c.l2_hits += 1;
            }
            Probe::Miss => {
                // DRAM sector fetch (gather or scatter-RMW read) or
                // a pure write allocation for covered sectors.
                if !is_write || needs_rmw {
                    c.dram_demand_lines += 1; // unit = one sector
                }
                self.note_row(pa, sid, c);
                if is_write && !needs_rmw {
                    // Fully-covered sectors drain to DRAM at the
                    // write rate in steady state: charge the
                    // writeback at fill time and insert clean, so
                    // a short measured pass isn't flattered by
                    // whatever tail still sits dirty in L2. (A
                    // later re-write of the still-resident sector
                    // dirties it and drains once more on eviction;
                    // that second transfer stands in for the RFO
                    // read this covered path elides, keeping the
                    // DRAM byte total honest for repeated writes.)
                    c.writeback_lines += 1;
                    if self.l2.fill_after_miss(sector, false, false).is_some() {
                        c.writeback_lines += 1;
                    }
                } else if self
                    .l2
                    .fill_after_miss(sector, is_write, false)
                    .is_some()
                {
                    c.writeback_lines += 1;
                }
            }
        }
    }

    /// Banked DRAM row classification — DRAM-facing, so it accepts
    /// only translated [`PhysicalAddress`]es.
    #[inline]
    fn note_row(&mut self, pa: PhysicalAddress, sid: usize, c: &mut SimCounters) {
        self.dram.access(pa.byte(), sid, c);
    }

    fn timing(
        &self,
        c: &SimCounters,
        pattern: &Pattern,
        kernel: Kernel,
        measured: usize,
    ) -> TimeBreakdown {
        let p = &self.platform;
        let sector_b = p.sector_bytes as f64;

        // DRAM: demand sector reads (gather + scatter-RMW) + dirty
        // writebacks (dirty L2 sectors drain on eviction; in steady
        // state evictions match the write rate) + row activations.
        let dram_bytes = c.dram_demand_lines as f64 * sector_b
            + c.writeback_lines as f64 * sector_b
            + c.row_activations as f64 * p.row_activate_bytes
            + c.dram_row_conflicts as f64 * p.dram.conflict_penalty_bytes;
        let dram_s = dram_bytes / (p.stream_gbs * 1e9);

        // L2 bandwidth serves hits.
        let l2_s = c.l2_hits as f64 * sector_b / (p.l2_gbs * 1e9);

        // SM transaction issue rate.
        let issue_s = c.transactions as f64 / (p.txn_per_ns * 1e9);

        // TLB walks: depth-dependent latency from the shared walker,
        // divided by the walkers' parallelism.
        let tlb_s = c.tlb.misses() as f64 * self.walker.ns_per_miss() * 1e-9;

        // Same-sector write contention: delta-0 write streams (Scatter
        // and the scatter side of GS) make every block hammer the same
        // sectors; ownership serializes.
        let coherence_s = if kernel.writes() && pattern.delta == 0 {
            (measured * pattern.vector_len()) as f64 * p.write_contend_ns * 1e-9
        } else {
            0.0
        };

        TimeBreakdown {
            issue_s,
            l2_s,
            l3_s: 0.0,
            dram_s,
            latency_s: 0.0,
            tlb_s,
            coherence_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms;

    /// GPU-style uniform pattern: V=256 (paper's GPU index buffer).
    fn guniform(stride: usize, count: usize) -> Pattern {
        Pattern::parse(&format!("UNIFORM:256:{stride}"))
            .unwrap()
            .with_delta(256 * stride as i64)
            .with_count(count)
    }

    const N: usize = 1 << 13;

    #[test]
    fn stride1_gather_approximates_stream() {
        for name in ["k40c", "titanxp", "p100", "v100"] {
            let p = platforms::gpu_by_name(name).unwrap();
            let mut e = GpuEngine::new(&p);
            let bw = e.run(&guniform(1, N), Kernel::Gather).unwrap().bandwidth_gbs();
            assert!(
                (bw / p.stream_gbs - 1.0).abs() < 0.25,
                "{name}: {bw:.0} vs {:.0}",
                p.stream_gbs
            );
        }
    }

    #[test]
    fn gather_plateau_quarter_from_stride4_to_8() {
        // Fig 5a: P100/TitanXp hold ~1/4 of peak from stride-4 to
        // stride-8 (32 B sector coalescing).
        for name in ["p100", "titanxp"] {
            let p = platforms::gpu_by_name(name).unwrap();
            let mut e = GpuEngine::new(&p);
            let bw1 = e.run(&guniform(1, N), Kernel::Gather).unwrap().bandwidth_gbs();
            let bw4 = e.run(&guniform(4, N), Kernel::Gather).unwrap().bandwidth_gbs();
            let bw8 = e.run(&guniform(8, N), Kernel::Gather).unwrap().bandwidth_gbs();
            assert!(
                (bw4 / bw1 - 0.25).abs() < 0.06,
                "{name} stride-4 fraction {:.3}",
                bw4 / bw1
            );
            assert!(
                (bw8 / bw4 - 1.0).abs() < 0.15,
                "{name} should plateau 4->8: {bw4:.0} vs {bw8:.0}"
            );
        }
    }

    #[test]
    fn k40_coalesces_worse() {
        // Fig 5a: the K40c (128 B transactions) falls off harder at
        // stride-8 than the sectored GPUs.
        let k40 = platforms::gpu_by_name("k40c").unwrap();
        let p100 = platforms::gpu_by_name("p100").unwrap();
        let frac = |p: &platforms::GpuPlatform| {
            let mut e = GpuEngine::new(p);
            let bw1 = e.run(&guniform(1, N), Kernel::Gather).unwrap().bandwidth_gbs();
            let bw8 = e.run(&guniform(8, N), Kernel::Gather).unwrap().bandwidth_gbs();
            bw8 / bw1
        };
        assert!(
            frac(&k40) < 0.6 * frac(&p100),
            "k40 {:.3} vs p100 {:.3}",
            frac(&k40),
            frac(&p100)
        );
    }

    #[test]
    fn scatter_plateaus_at_one_eighth() {
        // Fig 5b: scatter plateaus at ~1/8 instead of 1/4 (RMW).
        let p = platforms::gpu_by_name("p100").unwrap();
        let mut e = GpuEngine::new(&p);
        let bw1 = e.run(&guniform(1, N), Kernel::Scatter).unwrap().bandwidth_gbs();
        let bw4 = e.run(&guniform(4, N), Kernel::Scatter).unwrap().bandwidth_gbs();
        let bw8 = e.run(&guniform(8, N), Kernel::Scatter).unwrap().bandwidth_gbs();
        assert!(
            (bw4 / bw1 - 0.125).abs() < 0.04,
            "scatter stride-4 fraction {:.3}",
            bw4 / bw1
        );
        assert!((bw8 / bw4 - 1.0).abs() < 0.2, "{bw4:.0} vs {bw8:.0}");
    }

    #[test]
    fn bandwidth_keeps_declining_at_large_strides() {
        // Fig 5: row-activation overhead keeps pulling bandwidth down
        // past the plateau.
        let p = platforms::gpu_by_name("p100").unwrap();
        let mut e = GpuEngine::new(&p);
        let bw8 = e.run(&guniform(8, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw128 = e.run(&guniform(128, N), Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(
            bw128 < 0.75 * bw8,
            "stride-128 {bw128:.0} should sit below stride-8 {bw8:.0}"
        );
    }

    #[test]
    fn broadcast_coalesces_perfectly() {
        // PENNANT-G4-style broadcast: 32 threads hitting 4 distinct
        // elements need very few transactions.
        let p = platforms::gpu_by_name("v100").unwrap();
        let mut e = GpuEngine::new(&p);
        let idx: Vec<i64> = (0..256).map(|j| (j / 64) as i64).collect();
        let pat = Pattern::from_indices("bcast", idx)
            .with_delta(4)
            .with_count(N);
        let r = e.run(&pat, Kernel::Gather).unwrap();
        // 8 warps x 1 sector each per iteration (4 elems span 32 B)
        let per_iter = r.counters.transactions as f64 / r.simulated_iterations as f64;
        assert!(per_iter <= 9.0, "broadcast txn/iter {per_iter}");
    }

    #[test]
    fn large_delta_hits_gpu_tlb() {
        // Fig 9a: GPUs handle large PENNANT deltas much worse in
        // relative terms (TLB + row misses).
        let p = platforms::gpu_by_name("p100").unwrap();
        let mut e = GpuEngine::new(&p);
        let g12 = crate::pattern::table5::by_name("PENNANT-G12")
            .unwrap()
            .to_pattern(N);
        let bw1 = e.run(&guniform(1, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw = e.run(&g12, Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(
            bw < 0.15 * bw1,
            "large-delta pattern {bw:.0} vs stride-1 {bw1:.0}"
        );
    }

    #[test]
    fn delta0_scatter_contends() {
        let p = platforms::gpu_by_name("titanxp").unwrap();
        let mut e = GpuEngine::new(&p);
        let s3 = crate::pattern::table5::by_name("LULESH-S3")
            .unwrap()
            .to_pattern(1 << 14);
        let r = e.run(&s3, Kernel::Scatter).unwrap();
        let bw = r.bandwidth_gbs();
        assert!(
            bw < 0.35 * p.stream_gbs,
            "delta-0 scatter should contend: {bw:.0}"
        );
        assert_eq!(r.breakdown.bottleneck(), "coherence");
    }

    #[test]
    fn cached_pattern_can_beat_stream_on_v100() {
        // Fig 7: V100 peeks above the 100%-of-stride-1 ring on cached
        // patterns; older GPUs largely cannot.
        let v100 = platforms::gpu_by_name("v100").unwrap();
        let amg = crate::pattern::table5::by_name("AMG-G0")
            .unwrap()
            .to_pattern(1 << 14);
        let bw = GpuEngine::new(&v100)
            .run(&amg, Kernel::Gather)
            .unwrap()
            .bandwidth_gbs();
        assert!(
            bw > 0.9 * v100.stream_gbs,
            "V100 cached AMG {bw:.0} vs stream {:.0}",
            v100.stream_gbs
        );
    }

    #[test]
    fn determinism_and_counter_consistency() {
        let p = platforms::gpu_by_name("p100").unwrap();
        let pat = guniform(4, 1 << 12);
        let a = GpuEngine::new(&p).run(&pat, Kernel::Gather).unwrap();
        let b = GpuEngine::new(&p).run(&pat, Kernel::Gather).unwrap();
        assert_eq!(a.counters, b.counters);
        let c = &a.counters;
        assert_eq!(c.accesses as usize, 256 * a.simulated_iterations);
        assert!(c.transactions <= c.accesses);
        assert_eq!(c.l2_hits + c.dram_demand_lines, c.transactions);
    }

    fn run_with_closure(
        p: &platforms::GpuPlatform,
        pat: &Pattern,
        kernel: Kernel,
        closure: bool,
    ) -> crate::sim::SimResult {
        let mut e = GpuEngine::with_options(
            p,
            GpuSimOptions {
                closure_enabled: closure,
                ..Default::default()
            },
        );
        e.run(pat, kernel).unwrap()
    }

    #[test]
    fn closure_is_bit_identical_and_fires_on_delta0() {
        let p = platforms::gpu_by_name("titanxp").unwrap();
        let s3 = crate::pattern::table5::by_name("LULESH-S3")
            .unwrap()
            .to_pattern(1 << 13);
        let on = run_with_closure(&p, &s3, Kernel::Scatter, true);
        let off = run_with_closure(&p, &s3, Kernel::Scatter, false);
        assert_eq!(on.counters, off.counters);
        assert_eq!(on.breakdown, off.breakdown);
        assert_eq!(on.seconds, off.seconds);
        assert_eq!(off.closed_at_iteration, None);
        let at = on.closed_at_iteration.expect("delta-0 must close");
        assert!(at < 64, "delta-0 should close early: {at}");
    }

    #[test]
    fn closure_is_bit_identical_on_strides() {
        let p = platforms::gpu_by_name("p100").unwrap();
        for kernel in [Kernel::Gather, Kernel::Scatter] {
            for stride in [1usize, 8, 128] {
                let pat = guniform(stride, 1 << 12);
                let on = run_with_closure(&p, &pat, kernel, true);
                let off = run_with_closure(&p, &pat, kernel, false);
                assert_eq!(on.counters, off.counters, "stride {stride}");
                assert_eq!(on.seconds, off.seconds, "stride {stride}");
            }
        }
    }

    #[test]
    fn engine_reuse_matches_fresh_engine() {
        let p = platforms::gpu_by_name("v100").unwrap();
        let mut reused = GpuEngine::new(&p);
        reused.run(&guniform(8, 1 << 11), Kernel::Scatter).unwrap();
        let warm = reused.run(&guniform(2, 1 << 12), Kernel::Gather).unwrap();
        let fresh = GpuEngine::new(&p)
            .run(&guniform(2, 1 << 12), Kernel::Gather)
            .unwrap();
        assert_eq!(warm.counters, fresh.counters);
        assert_eq!(warm.seconds, fresh.seconds);
    }

    /// GPU GS: 256-wide gather side at `gstride`, scatter side at
    /// `sstride`.
    fn gs_guniform(gstride: usize, sstride: usize, count: usize) -> Pattern {
        Pattern::parse(&format!("UNIFORM:256:{gstride}"))
            .unwrap()
            .with_gs_scatter((0..256).map(|j| j * sstride as i64).collect())
            .with_delta(256 * gstride.max(sstride) as i64)
            .with_count(count)
    }

    #[test]
    fn gs_runs_and_is_bounded_by_components() {
        let p = platforms::gpu_by_name("p100").unwrap();
        let mut e = GpuEngine::new(&p);
        for (gs, ss) in [(1usize, 1usize), (8, 1), (1, 8)] {
            let pat = gs_guniform(gs, ss, 1 << 12);
            let g_only = Pattern::from_indices("g", pat.indices.clone())
                .with_delta(pat.delta)
                .with_count(pat.count);
            let s_only =
                Pattern::from_indices("s", pat.scatter_indices.clone())
                    .with_delta(pat.delta)
                    .with_count(pat.count);
            let r = e.run(&pat, Kernel::GS).unwrap();
            // Both streams issue transactions: more than either side
            // alone would.
            assert_eq!(
                r.counters.accesses as usize,
                2 * 256 * r.simulated_iterations
            );
            let bw_gs = r.bandwidth_gbs();
            let bw_g = e.run(&g_only, Kernel::Gather).unwrap().bandwidth_gbs();
            let bw_s = e.run(&s_only, Kernel::Scatter).unwrap().bandwidth_gbs();
            assert!(
                bw_gs <= bw_g.min(bw_s) * 1.02,
                "GS {gs}/{ss}: {bw_gs:.0} vs gather {bw_g:.0} / scatter \
                 {bw_s:.0}"
            );
        }
    }

    #[test]
    fn gs_delta0_contends() {
        let p = platforms::gpu_by_name("titanxp").unwrap();
        let mut e = GpuEngine::new(&p);
        let pat = Pattern::from_indices("gs-d0", (0..256).collect())
            .with_gs_scatter((0..256).map(|j| j * 24).collect())
            .with_delta(0)
            .with_count(1 << 12);
        let r = e.run(&pat, Kernel::GS).unwrap();
        assert_eq!(r.breakdown.bottleneck(), "coherence");
    }

    #[test]
    fn stream_tetrad_lands_on_the_table3_anchor_gpu() {
        use crate::pattern::StreamOp;
        for name in ["k40c", "titanxp", "p100", "v100"] {
            let p = platforms::gpu_by_name(name).unwrap();
            let mut e = GpuEngine::new(&p);
            for op in StreamOp::ALL {
                let bw = e
                    .run(&Pattern::dense(256, N), Kernel::Stream(*op))
                    .unwrap()
                    .bandwidth_gbs();
                assert!(
                    (bw / p.stream_gbs - 1.0).abs() < 0.25,
                    "{name}/{}: {bw:.0} GB/s vs STREAM {:.0}",
                    op.name(),
                    p.stream_gbs
                );
            }
        }
    }

    #[test]
    fn gups_collapses_and_is_deterministic_on_gpu() {
        let p = platforms::gpu_by_name("p100").unwrap();
        let pat = Pattern::gups(1 << 26, 1 << 14);
        let a = GpuEngine::new(&p).run(&pat, Kernel::Gups).unwrap();
        let b = GpuEngine::new(&p).run(&pat, Kernel::Gups).unwrap();
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.seconds, b.seconds);
        let bw = a.bandwidth_gbs();
        assert!(
            bw < 0.05 * p.stream_gbs,
            "GPU GUPS must collapse: {bw:.1} vs {:.0}",
            p.stream_gbs
        );
        // Random sectors are partially covered: every update RMWs.
        assert!(a.counters.dram_demand_lines > 0);
        assert_eq!(a.closed_at_iteration, None);
    }

    #[test]
    fn gs_closure_is_bit_identical_on_gpu() {
        let p = platforms::gpu_by_name("p100").unwrap();
        for pat in [gs_guniform(1, 1, 1 << 11), gs_guniform(8, 1, 1 << 11)] {
            let on = run_with_closure(&p, &pat, Kernel::GS, true);
            let off = run_with_closure(&p, &pat, Kernel::GS, false);
            assert_eq!(on.counters, off.counters, "{}", pat.spec);
            assert_eq!(on.seconds, off.seconds, "{}", pat.spec);
        }
    }
}
