//! Hardware prefetcher models (the Fig 3 / Fig 4 mechanisms).
//!
//! The paper's uniform-stride study attributes each platform's curve to
//! its prefetcher behaviour:
//!
//! * **Broadwell** — an adjacent-line ("buddy") prefetcher pulls two
//!   cache lines for small strides but switches to a single line at
//!   stride-64 doubles (512 B), which is why BDW *recovers* at high
//!   strides and crosses above Skylake (§5.1.1).
//! * **Skylake** — "always brings in two cache lines, no matter the
//!   stride", giving the 1/16-of-peak floor.
//! * **ThunderX2** — an aggressive next-line streamer that keeps
//!   over-fetching far past stride-16, explaining its steep drop.
//! * **Naples** — a stride-detecting prefetcher that only issues
//!   *useful* prefetches (and stops at page boundaries), giving the
//!   flat 1/8 plateau after stride-8.
//!
//! Prefetchers observe demand L2 misses (line granularity) and return
//! the set of extra lines to fill.

/// Prefetcher configuration, one per simulated platform.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PrefetchKind {
    /// No prefetching (the MSR-disabled runs of Fig 4).
    None,
    /// Fetch the buddy line of each missed line (BDW): the other half
    /// of a 128-byte-aligned pair, but only while the observed access
    /// stride is below `disable_at_bytes`.
    AdjacentLine { disable_at_bytes: u64 },
    /// Always fetch the next `degree` sequential lines (SKX: degree 1,
    /// "always brings in two cache lines"; TX2: degree 2).
    NextLine { degree: usize },
    /// Detect a constant line stride and fetch `degree` lines ahead
    /// along it, stopping at 4 KiB page boundaries (Naples, KNL).
    /// Issues only useful prefetches by construction.
    Stride { degree: usize },
}

/// Stride-detection state shared by the kinds that need history.
#[derive(Debug, Clone)]
pub struct Prefetcher {
    pub kind: PrefetchKind,
    last_addr: Option<u64>,
    last_stride: i64,
    confidence: u32,
    /// Total prefetches issued (for reporting).
    pub issued: u64,
}

/// Lines per 4 KiB page (64 B lines).
const PAGE_LINES: u64 = 64;

impl Prefetcher {
    pub fn new(kind: PrefetchKind) -> Prefetcher {
        Prefetcher {
            kind,
            last_addr: None,
            last_stride: 0,
            confidence: 0,
            issued: 0,
        }
    }

    pub fn reset(&mut self) {
        self.last_addr = None;
        self.last_stride = 0;
        self.confidence = 0;
        self.issued = 0;
    }

    /// Advance the stride-detection state for a demand miss at
    /// `byte_addr` without issuing prefetches. The tracker runs for
    /// every kind — including [`PrefetchKind::None`], whose fill set
    /// is empty by construction — so the loop-closure state digest is
    /// regime-independent and `sim::plan`'s prefetch-off monomorphized
    /// arm can skip the fill loop exactly.
    #[inline]
    pub fn note_miss(&mut self, byte_addr: u64) {
        // Track the byte-stride of the demand stream for the
        // stride-sensitive kinds.
        let stride = match self.last_addr {
            Some(prev) => byte_addr as i64 - prev as i64,
            None => 0,
        };
        if stride != 0 && stride == self.last_stride {
            self.confidence = (self.confidence + 1).min(8);
        } else if stride != 0 {
            self.confidence = 0;
            self.last_stride = stride;
        }
        self.last_addr = Some(byte_addr);
    }

    /// Observe a demand miss at `byte_addr` (line `line`); return the
    /// extra lines the prefetcher fills.
    pub fn on_miss(&mut self, byte_addr: u64, line: u64, out: &mut Vec<u64>) {
        out.clear();
        self.note_miss(byte_addr);

        match self.kind {
            PrefetchKind::None => {}
            PrefetchKind::AdjacentLine { disable_at_bytes } => {
                // Buddy line of the 128-byte pair, unless the detected
                // stride is large (the BDW streamer takes over and
                // stops the over-fetch).
                let large_stride = self.confidence >= 2
                    && self.last_stride.unsigned_abs() >= disable_at_bytes;
                if !large_stride {
                    out.push(line ^ 1);
                }
            }
            PrefetchKind::NextLine { degree } => {
                for d in 1..=degree as u64 {
                    out.push(line + d);
                }
            }
            PrefetchKind::Stride { degree } => {
                // Only with confidence, only along the detected stride,
                // only within the 4 KiB page.
                if self.confidence >= 2 && self.last_stride != 0 {
                    let line_stride = self.last_stride / 64;
                    let step = if line_stride == 0 {
                        // sub-line stride: next line
                        1
                    } else {
                        line_stride
                    };
                    for d in 1..=degree as i64 {
                        let target = line as i64 + step * d;
                        if target >= 0
                            && (target as u64) / PAGE_LINES == line / PAGE_LINES
                        {
                            out.push(target as u64);
                        }
                    }
                }
            }
        }
        self.issued += out.len() as u64;
    }

    /// Digest of the stride-detection state relative to `base_byte`
    /// (the loop-closure fingerprint). The observed stride and
    /// confidence are shift-invariant already; the last miss address
    /// is taken relative to the base.
    pub fn state_digest(&self, base_byte: u64, seed: u64) -> u64 {
        let rel = match self.last_addr {
            Some(a) => a.wrapping_sub(base_byte),
            None => u64::MAX,
        };
        let h = super::closure::fold(seed, rel);
        let h = super::closure::fold(h, self.last_stride as u64);
        super::closure::fold(h, self.confidence as u64)
    }

    /// Shift the tracked miss address forward by `delta_bytes`
    /// (loop-closure fast-forward).
    pub fn relocate(&mut self, delta_bytes: u64) {
        if let Some(a) = self.last_addr {
            self.last_addr = Some(a.wrapping_add(delta_bytes));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(pf: &mut Prefetcher, addrs: &[u64]) -> Vec<Vec<u64>> {
        let mut all = Vec::new();
        let mut buf = Vec::new();
        for &a in addrs {
            pf.on_miss(a, a / 64, &mut buf);
            all.push(buf.clone());
        }
        all
    }

    #[test]
    fn none_never_prefetches() {
        let mut pf = Prefetcher::new(PrefetchKind::None);
        let outs = run(&mut pf, &[0, 64, 128, 4096]);
        assert!(outs.iter().all(|o| o.is_empty()));
        assert_eq!(pf.issued, 0);
    }

    #[test]
    fn adjacent_line_pairs() {
        let mut pf = Prefetcher::new(PrefetchKind::AdjacentLine {
            disable_at_bytes: 512,
        });
        let mut buf = Vec::new();
        pf.on_miss(0, 0, &mut buf);
        assert_eq!(buf, vec![1]); // buddy of line 0 is line 1
        pf.on_miss(128, 2, &mut buf);
        assert_eq!(buf, vec![3]); // buddy of line 2 is line 3
        pf.on_miss(192, 3, &mut buf);
        assert_eq!(buf, vec![2]); // buddy of line 3 is line 2
    }

    #[test]
    fn adjacent_line_disables_at_large_stride() {
        // BDW behaviour: stride-64 doubles = 512 B -> single line.
        let mut pf = Prefetcher::new(PrefetchKind::AdjacentLine {
            disable_at_bytes: 512,
        });
        let addrs: Vec<u64> = (0..8).map(|i| i * 512).collect();
        let outs = run(&mut pf, &addrs);
        // Needs 2 confirmations; after that, no buddy fetch.
        assert!(!outs[0].is_empty());
        assert!(outs[4].is_empty(), "{outs:?}");
        assert!(outs[7].is_empty());
        // Small stride keeps the buddy fetch on.
        let mut pf2 = Prefetcher::new(PrefetchKind::AdjacentLine {
            disable_at_bytes: 512,
        });
        let addrs2: Vec<u64> = (0..8).map(|i| i * 128).collect();
        let outs2 = run(&mut pf2, &addrs2);
        assert!(outs2.iter().all(|o| o.len() == 1), "{outs2:?}");
    }

    #[test]
    fn next_line_always_fetches() {
        // SKX: degree 1 regardless of stride.
        let mut pf = Prefetcher::new(PrefetchKind::NextLine { degree: 1 });
        let outs = run(&mut pf, &[0, 1024, 8192, 123 * 64]);
        for (o, &a) in outs.iter().zip(&[0u64, 1024, 8192, 123 * 64]) {
            assert_eq!(o, &vec![a / 64 + 1]);
        }
        let mut pf2 = Prefetcher::new(PrefetchKind::NextLine { degree: 2 });
        let mut buf = Vec::new();
        pf2.on_miss(0, 0, &mut buf);
        assert_eq!(buf, vec![1, 2]);
    }

    #[test]
    fn stride_detect_needs_confidence() {
        let mut pf = Prefetcher::new(PrefetchKind::Stride { degree: 2 });
        // First two misses establish the stride; no prefetch yet.
        let addrs: Vec<u64> = (0..6).map(|i| i * 128).collect();
        let outs = run(&mut pf, &addrs);
        assert!(outs[0].is_empty());
        assert!(outs[1].is_empty());
        // After confidence: prefetch along stride (2 lines per 128 B).
        assert_eq!(outs[4], vec![addrs[4] / 64 + 2, addrs[4] / 64 + 4]);
    }

    #[test]
    fn stride_detect_stops_at_page_boundary() {
        let mut pf = Prefetcher::new(PrefetchKind::Stride { degree: 4 });
        // Establish a 512 B stride near a page end.
        let addrs: Vec<u64> = (0..8).map(|i| 1024 + i * 512).collect();
        let outs = run(&mut pf, &addrs);
        let last = outs.last().unwrap();
        // All prefetches must stay within the same 4 KiB page as the
        // triggering miss.
        let trigger_page = (1024 + 7 * 512) / 4096;
        for &l in last {
            assert_eq!((l * 64) / 4096, trigger_page, "{last:?}");
        }
    }

    #[test]
    fn stride_detect_random_stream_stays_quiet() {
        let mut pf = Prefetcher::new(PrefetchKind::Stride { degree: 2 });
        // Irregular stream: confidence never builds.
        let outs = run(&mut pf, &[0, 640, 64, 9000, 333 * 64, 12]);
        assert!(outs.iter().all(|o| o.is_empty()), "{outs:?}");
    }

    /// `note_miss` advances exactly the state `on_miss` does — for
    /// `None`, where the fill set is empty by construction, the two
    /// are digest-identical (the `sim::plan` prefetch-off arm relies
    /// on this).
    #[test]
    fn note_miss_tracks_state_like_on_miss() {
        let mut a = Prefetcher::new(PrefetchKind::None);
        let mut b = Prefetcher::new(PrefetchKind::None);
        let mut buf = Vec::new();
        for &addr in &[0u64, 128, 256, 384, 9000] {
            a.on_miss(addr, addr / 64, &mut buf);
            b.note_miss(addr);
            assert!(buf.is_empty());
        }
        assert_eq!(a.state_digest(0, 7), b.state_digest(0, 7));
        assert_eq!(a.issued, b.issued);
    }

    #[test]
    fn reset_clears_history() {
        let mut pf = Prefetcher::new(PrefetchKind::Stride { degree: 1 });
        run(&mut pf, &[0, 128, 256, 384]);
        assert!(pf.issued > 0);
        pf.reset();
        assert_eq!(pf.issued, 0);
        let mut buf = Vec::new();
        pf.on_miss(512, 8, &mut buf);
        assert!(buf.is_empty()); // no confidence after reset
    }
}
