//! Set-associative, write-back, write-allocate cache model with LRU
//! replacement — the building block of the simulated memory hierarchy.
//!
//! Addresses are *line* addresses (byte address / line size); the
//! hierarchy layer does the conversion. Each line tracks a dirty bit
//! and whether it arrived via prefetch (for prefetch-accuracy
//! accounting in the Fig 4 study).
//!
//! Layout is struct-of-arrays (§Perf): the hit scan — the single
//! hottest loop in the simulator — touches only the packed tag plane,
//! as a branch-free compare pass over one or two host cache lines.
//! Invalid ways hold [`INVALID_TAG`], which no reachable line/page
//! number can equal (pattern validation caps the address space at
//! 2^49 bytes), so the tag compare needs no validity check.
//!
//! The cache also maintains an incremental [`StateSig`] over its
//! resident ways so the loop-closure layer (`sim::closure`) can
//! fingerprint the complete tag/LRU/dirty state in O(1) per outer
//! iteration instead of rehashing the arrays, and supports an exact
//! [`relocate`](Cache::relocate) that shifts the whole state by a
//! constant line delta (tags translated, sets rotated, stamps kept)
//! when a closed loop fast-forwards the simulation.

use super::closure::StateSig;

/// Result of a cache probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Probe {
    /// Line present. `was_prefetched` is true the first time a
    /// demand access touches a line that a prefetcher brought in.
    Hit { was_prefetched: bool },
    Miss,
}

const F_VALID: u8 = 1;
const F_DIRTY: u8 = 2;
const F_PREFETCHED: u8 = 4;

/// Tag sentinel for invalid ways (see module docs).
const INVALID_TAG: u64 = u64::MAX;

/// Pack a way's tag and flag bits into the signature coordinate. The
/// shift keeps the packing linear in the tag, which is what lets the
/// signature's power sums commute with address shifts.
#[inline]
fn sig_x(tag: u64, flags: u8) -> u64 {
    (tag << 3) | (flags & 0x7) as u64
}

/// Largest power of two <= n (n >= 1).
fn prev_power_of_two(n: usize) -> usize {
    1 << (usize::BITS - 1 - n.leading_zeros())
}

/// One cache level.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    assoc: usize,
    /// Tag plane; `INVALID_TAG` marks empty ways.
    tags: Vec<u64>,
    /// LRU timestamps (u32: capped sim lengths never approach wrap;
    /// reset per run).
    stamps: Vec<u32>,
    /// Bit 0 = valid, bit 1 = dirty, bit 2 = prefetched-untouched.
    flags: Vec<u8>,
    /// LRU clock.
    clock: u32,
    /// Incremental state signature over the resident ways.
    sig: StateSig,
    /// Statistics.
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
    pub prefetch_fills: u64,
    pub prefetch_hits: u64,
}

impl Cache {
    /// `capacity_bytes` / `line_bytes` / `assoc` must be power-of-two
    /// consistent; sets = capacity / (line * assoc).
    pub fn new(capacity_bytes: usize, line_bytes: usize, assoc: usize) -> Cache {
        assert!(capacity_bytes > 0 && line_bytes > 0 && assoc > 0);
        let lines = capacity_bytes / line_bytes;
        assert!(lines >= assoc, "capacity too small for associativity");
        // Round sets down to a power of two for mask indexing (real
        // parts with non-power-of-two capacity, e.g. 33 MB 11-way SKX
        // L3, are modelled slightly small rather than slightly large).
        let sets = prev_power_of_two((lines / assoc).max(1));
        let ways = sets * assoc;
        Cache {
            sets,
            assoc,
            tags: vec![INVALID_TAG; ways],
            stamps: vec![0; ways],
            flags: vec![0; ways],
            clock: 0,
            sig: StateSig::default(),
            hits: 0,
            misses: 0,
            writebacks: 0,
            prefetch_fills: 0,
            prefetch_hits: 0,
        }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    pub fn assoc(&self) -> usize {
        self.assoc
    }

    fn set_of(&self, line: u64) -> usize {
        (line as usize) & (self.sets - 1)
    }

    /// Branch-free tag-match pass over one set (§Perf): scans the
    /// packed tag plane without early exit or validity checks — the
    /// sentinel makes invalid ways unmatchable — so the loop compiles
    /// to straight-line compares.
    #[inline]
    fn find(&self, set: usize, line: u64) -> Option<usize> {
        let b = set * self.assoc;
        let tags = &self.tags[b..b + self.assoc];
        let mut found = usize::MAX;
        for (k, &t) in tags.iter().enumerate() {
            if t == line {
                found = k;
            }
        }
        if found == usize::MAX {
            None
        } else {
            Some(b + found)
        }
    }

    /// Issue a host software-prefetch for the set `line` maps to
    /// (§Perf: large simulated caches make every probe a host cache
    /// miss; hinting the three levels up front overlaps the misses).
    #[inline]
    pub fn prefetch_host(&self, line: u64) {
        #[cfg(target_arch = "x86_64")]
        unsafe {
            use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            let idx = self.set_of(line) * self.assoc;
            let tp = self.tags.as_ptr().add(idx) as *const i8;
            _mm_prefetch(tp, _MM_HINT_T0);
            // Tag sets larger than one host line: touch the tail too.
            if self.assoc > 8 {
                _mm_prefetch(tp.add(64), _MM_HINT_T0);
            }
            _mm_prefetch(self.stamps.as_ptr().add(idx) as *const i8, _MM_HINT_T0);
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = line;
    }

    /// Demand access. On hit, updates LRU and clears the prefetched
    /// flag (the prefetch has now been consumed). Does NOT fill on
    /// miss — the hierarchy decides fill policy.
    pub fn access(&mut self, line: u64, is_write: bool) -> Probe {
        self.clock += 1;
        let set = self.set_of(line);
        if let Some(i) = self.find(set, line) {
            let of = self.flags[i];
            let was_prefetched = of & F_PREFETCHED != 0;
            if was_prefetched {
                self.prefetch_hits += 1;
            }
            let nf = (of & !F_PREFETCHED) | if is_write { F_DIRTY } else { 0 };
            self.sig.remove(sig_x(line, of), self.stamps[i] as u64);
            self.flags[i] = nf;
            self.stamps[i] = self.clock;
            self.sig.insert(sig_x(line, nf), self.clock as u64);
            self.hits += 1;
            return Probe::Hit { was_prefetched };
        }
        self.misses += 1;
        Probe::Miss
    }

    /// Counted bulk hit: `reps` consecutive [`Cache::access`] calls to
    /// a line known to be resident, telescoped into O(1) updates
    /// (`sim::plan`'s same-line run coalescing). Exactly equivalent to
    /// the scalar sequence: the clock advances `reps` ticks, the
    /// line's stamp lands on the final tick, the flags settle after
    /// the first hit (`F_PREFETCHED` cleared, dirty merged — both
    /// idempotent), the prefetched credit is consumed at most once,
    /// and one signature remove/insert replaces the `reps` pairs
    /// (every intermediate pair cancels).
    pub fn hit_repeat(&mut self, line: u64, is_write: bool, reps: u32) {
        if reps == 0 {
            return;
        }
        self.clock += reps;
        let set = self.set_of(line);
        let i = self
            .find(set, line)
            .expect("hit_repeat caller guarantees residency");
        let of = self.flags[i];
        if of & F_PREFETCHED != 0 {
            self.prefetch_hits += 1;
        }
        let nf = (of & !F_PREFETCHED) | if is_write { F_DIRTY } else { 0 };
        self.sig.remove(sig_x(line, of), self.stamps[i] as u64);
        self.flags[i] = nf;
        self.stamps[i] = self.clock;
        self.sig.insert(sig_x(line, nf), self.clock as u64);
        self.hits += reps as u64;
    }

    /// Counted bulk miss: `reps` consecutive [`Cache::access`] probes
    /// that miss (the streaming-store repeat path, where nothing fills
    /// between probes). Only the clock and the miss counter move.
    pub fn miss_repeat(&mut self, reps: u32) {
        self.clock += reps;
        self.misses += reps as u64;
    }

    /// Probe without statistics or LRU update (used by prefetchers to
    /// avoid redundant fills).
    pub fn contains(&self, line: u64) -> bool {
        self.find(self.set_of(line), line).is_some()
    }

    /// Insert a line, evicting LRU if needed. Returns the evicted dirty
    /// line (for writeback accounting), if any.
    pub fn fill(&mut self, line: u64, is_write: bool, prefetched: bool) -> Option<u64> {
        // Already present (e.g. prefetch raced with demand): refresh.
        if let Some(i) = self.find(self.set_of(line), line) {
            self.clock += 1;
            let of = self.flags[i];
            let nf = of | if is_write { F_DIRTY } else { 0 };
            self.sig.remove(sig_x(line, of), self.stamps[i] as u64);
            self.flags[i] = nf;
            self.stamps[i] = self.clock;
            self.sig.insert(sig_x(line, nf), self.clock as u64);
            return None;
        }
        self.fill_after_miss(line, is_write, prefetched)
    }

    /// Insert a line the caller has just verified to be absent (the
    /// demand-miss path). Skips the presence re-scan that `fill` pays
    /// (§Perf: the miss path previously scanned every set twice).
    pub fn fill_after_miss(
        &mut self,
        line: u64,
        is_write: bool,
        prefetched: bool,
    ) -> Option<u64> {
        self.clock += 1;
        let set = self.set_of(line);
        debug_assert!(self.find(set, line).is_none());
        if prefetched {
            self.prefetch_fills += 1;
        }
        // Find invalid or LRU victim.
        let b = set * self.assoc;
        let mut victim = b;
        let mut best = u32::MAX;
        for i in b..b + self.assoc {
            if self.tags[i] == INVALID_TAG {
                victim = i;
                break;
            }
            if self.stamps[i] < best {
                best = self.stamps[i];
                victim = i;
            }
        }
        let evicted = if self.tags[victim] != INVALID_TAG {
            let vt = self.tags[victim];
            let vf = self.flags[victim];
            self.sig.remove(sig_x(vt, vf), self.stamps[victim] as u64);
            if vf & F_DIRTY != 0 {
                self.writebacks += 1;
                Some(vt)
            } else {
                None
            }
        } else {
            None
        };
        let nf = F_VALID
            | if is_write { F_DIRTY } else { 0 }
            | if prefetched { F_PREFETCHED } else { 0 };
        self.tags[victim] = line;
        self.stamps[victim] = self.clock;
        self.flags[victim] = nf;
        self.sig.insert(sig_x(line, nf), self.clock as u64);
        evicted
    }

    /// Fill only when absent, reporting whether an insert happened
    /// (fuses the `contains` + `fill` pair the prefetch path used to
    /// pay — §Perf). Returns `(inserted, evicted_dirty_line)`.
    pub fn fill_if_absent(
        &mut self,
        line: u64,
        is_write: bool,
        prefetched: bool,
    ) -> (bool, Option<u64>) {
        if self.find(self.set_of(line), line).is_some() {
            return (false, None);
        }
        (true, self.fill_after_miss(line, is_write, prefetched))
    }

    /// Invalidate a line (coherence). Returns true if it was present.
    pub fn invalidate(&mut self, line: u64) -> bool {
        if let Some(i) = self.find(self.set_of(line), line) {
            self.sig
                .remove(sig_x(self.tags[i], self.flags[i]), self.stamps[i] as u64);
            self.tags[i] = INVALID_TAG;
            self.stamps[i] = 0;
            self.flags[i] = 0;
            return true;
        }
        false
    }

    /// Digest of the cache's complete state *relative* to
    /// `shift_units` (a line/page number): the multiset of
    /// `(tag - shift, flags, clock - stamp)` per resident way. O(1) —
    /// derived from the incremental signature, not a state walk.
    pub fn state_digest(&self, shift_units: u64, seed: u64) -> u64 {
        self.sig.digest(shift_units << 3, self.clock as u64, seed)
    }

    /// Shift the whole state forward by `delta_units` lines/pages:
    /// every tag is translated and every set moves wholesale to its
    /// rotated index, preserving within-set way order and stamps. Used
    /// by loop closure to fast-forward over skipped cycles; the result
    /// is exactly the state full simulation would have reached (up to
    /// the absolute value of the LRU clock, which is unobservable).
    pub fn relocate(&mut self, delta_units: u64) {
        if delta_units == 0 {
            return;
        }
        let mask = self.sets - 1;
        let rot = (delta_units as usize) & mask;
        let ways = self.sets * self.assoc;
        let mut tags = vec![INVALID_TAG; ways];
        let mut stamps = vec![0u32; ways];
        let mut flags = vec![0u8; ways];
        let mut sig = StateSig::default();
        for s in 0..self.sets {
            let ns = (s + rot) & mask;
            for k in 0..self.assoc {
                let i = s * self.assoc + k;
                if self.tags[i] == INVALID_TAG {
                    continue;
                }
                let j = ns * self.assoc + k;
                let nt = self.tags[i].wrapping_add(delta_units);
                tags[j] = nt;
                stamps[j] = self.stamps[i];
                flags[j] = self.flags[i];
                sig.insert(sig_x(nt, self.flags[i]), self.stamps[i] as u64);
            }
        }
        self.tags = tags;
        self.stamps = stamps;
        self.flags = flags;
        self.sig = sig;
    }

    /// Clear contents and statistics.
    pub fn reset(&mut self) {
        self.tags.fill(INVALID_TAG);
        self.stamps.fill(0);
        self.flags.fill(0);
        self.clock = 0;
        self.sig.reset();
        self.hits = 0;
        self.misses = 0;
        self.writebacks = 0;
        self.prefetch_fills = 0;
        self.prefetch_hits = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::closure::SEED_A;

    fn small() -> Cache {
        // 4 sets x 2 ways x 64B lines = 512 B
        Cache::new(512, 64, 2)
    }

    #[test]
    fn geometry() {
        let c = small();
        assert_eq!(c.sets(), 4);
        assert_eq!(c.assoc(), 2);
        let big = Cache::new(32 * 1024, 64, 8);
        assert_eq!(big.sets(), 64);
    }

    #[test]
    fn miss_then_fill_then_hit() {
        let mut c = small();
        assert_eq!(c.access(5, false), Probe::Miss);
        c.fill(5, false, false);
        assert_eq!(c.access(5, false), Probe::Hit { was_prefetched: false });
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = small();
        // set 0 holds lines 0, 4, 8, ... (4 sets). Fill two ways.
        c.fill(0, false, false);
        c.fill(4, false, false);
        // touch 0 so 4 becomes LRU
        c.access(0, false);
        // fill 8 -> evicts 4
        c.fill(8, false, false);
        assert!(c.contains(0));
        assert!(!c.contains(4));
        assert!(c.contains(8));
    }

    #[test]
    fn dirty_eviction_counts_writeback() {
        let mut c = small();
        c.fill(0, true, false); // dirty
        c.fill(4, false, false);
        let evicted = c.fill(8, false, false); // evicts LRU = 0 (dirty)
        assert_eq!(evicted, Some(0));
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn clean_eviction_no_writeback() {
        let mut c = small();
        c.fill(0, false, false);
        c.fill(4, false, false);
        let evicted = c.fill(8, false, false);
        assert_eq!(evicted, None);
        assert_eq!(c.writebacks, 0);
    }

    #[test]
    fn write_hit_marks_dirty() {
        let mut c = small();
        c.fill(0, false, false);
        c.access(0, true); // write hit -> dirty
        c.fill(4, false, false);
        let evicted = c.fill(8, false, false);
        assert_eq!(evicted, Some(0));
    }

    #[test]
    fn prefetch_accounting() {
        let mut c = small();
        c.fill(3, false, true); // prefetched
        assert_eq!(c.prefetch_fills, 1);
        assert_eq!(c.access(3, false), Probe::Hit { was_prefetched: true });
        assert_eq!(c.prefetch_hits, 1);
        // second touch: no longer "prefetched"
        assert_eq!(c.access(3, false), Probe::Hit { was_prefetched: false });
        assert_eq!(c.prefetch_hits, 1);
    }

    #[test]
    fn refill_existing_line_is_idempotent() {
        let mut c = small();
        c.fill(0, false, false);
        assert_eq!(c.fill(0, true, false), None); // refresh, mark dirty
        c.fill(4, false, false);
        assert_eq!(c.fill(8, false, false), Some(0)); // 0 dirty via refill
    }

    #[test]
    fn invalidate() {
        let mut c = small();
        c.fill(0, true, false);
        assert!(c.invalidate(0));
        assert!(!c.contains(0));
        assert!(!c.invalidate(0));
    }

    #[test]
    fn reset_clears_everything() {
        let mut c = small();
        c.fill(0, false, false);
        c.access(0, false);
        c.access(1, false);
        c.reset();
        assert!(!c.contains(0));
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn different_sets_do_not_conflict() {
        let mut c = small();
        // lines 0..4 map to sets 0..4 — all coexist with assoc 2
        for l in 0..4 {
            c.fill(l, false, false);
        }
        for l in 0..4 {
            assert!(c.contains(l), "line {l}");
        }
    }

    #[test]
    fn associativity_respected() {
        let mut c = small(); // 2-way
        // three lines in set 0: 0, 4, 8 -> one must be evicted
        c.fill(0, false, false);
        c.fill(4, false, false);
        c.fill(8, false, false);
        let present = [0u64, 4, 8].iter().filter(|&&l| c.contains(l)).count();
        assert_eq!(present, 2);
    }

    /// Drive two caches with the same stream shifted by a constant:
    /// their state digests must agree relative to their shifts, and a
    /// later divergence in the streams must split the digests.
    #[test]
    fn state_digest_is_shift_invariant() {
        let mut a = Cache::new(4096, 64, 4);
        let mut b = Cache::new(4096, 64, 4);
        // Multiple of the set count (16) so both streams see the same
        // set conflicts — the precondition loop closure guarantees.
        let d = 4096u64;
        let stream = [0u64, 1, 5, 1, 64, 9, 5, 130, 0];
        for &l in &stream {
            if a.access(l, l % 3 == 0) == Probe::Miss {
                a.fill_after_miss(l, l % 3 == 0, false);
            }
            let m = l + d;
            if b.access(m, l % 3 == 0) == Probe::Miss {
                b.fill_after_miss(m, l % 3 == 0, false);
            }
        }
        assert_eq!(a.state_digest(0, SEED_A), b.state_digest(d, SEED_A));
        assert_eq!(a.state_digest(7, SEED_A), b.state_digest(7 + d, SEED_A));
        // Diverge: only b sees one more access.
        b.access(d, false);
        assert_ne!(a.state_digest(0, SEED_A), b.state_digest(d, SEED_A));
    }

    /// Relocation must be exactly equivalent to having simulated the
    /// shifted stream from the start: same probes, same evictions
    /// (shifted), same digest.
    #[test]
    fn relocate_matches_shifted_history() {
        let d = 1 << 20; // multiple of every power-of-two set count
        let mut a = Cache::new(2048, 64, 2);
        let mut shifted = Cache::new(2048, 64, 2);
        let warm = [3u64, 19, 3, 35, 7, 99, 3, 51];
        for &l in &warm {
            a.fill(l, l % 2 == 1, false);
            shifted.fill(l + d, l % 2 == 1, false);
        }
        a.relocate(d);
        assert_eq!(
            a.state_digest(d, SEED_A),
            shifted.state_digest(d, SEED_A),
            "relocated state must digest identically"
        );
        // And behave identically from here on.
        let tail = [3u64, 67, 19, 131, 7, 7, 99];
        for &l in &tail {
            let m = l + d;
            assert_eq!(a.access(m, false), shifted.access(m, false), "line {l}");
            if !a.contains(m) {
                assert_eq!(
                    a.fill_after_miss(m, true, false),
                    shifted.fill_after_miss(m, true, false)
                );
            }
        }
    }

    /// `reps` scalar hits and one `hit_repeat` must telescope to the
    /// same state digest and statistics — for reads, writes, and with
    /// an unconsumed prefetch credit on the line.
    #[test]
    fn hit_repeat_telescopes_scalar_hits() {
        for reps in [1u32, 2, 7] {
            for is_write in [false, true] {
                for prefetched in [false, true] {
                    let mut scalar = Cache::new(4096, 64, 4);
                    let mut bulk = Cache::new(4096, 64, 4);
                    for c in [&mut scalar, &mut bulk] {
                        c.fill(5, false, prefetched);
                        c.fill(21, true, false);
                    }
                    for _ in 0..reps {
                        scalar.access(5, is_write);
                    }
                    bulk.hit_repeat(5, is_write, reps);
                    assert_eq!(
                        scalar.state_digest(0, SEED_A),
                        bulk.state_digest(0, SEED_A),
                        "reps={reps} write={is_write} pf={prefetched}"
                    );
                    assert_eq!(scalar.hits, bulk.hits);
                    assert_eq!(scalar.misses, bulk.misses);
                    assert_eq!(scalar.prefetch_hits, bulk.prefetch_hits);
                }
            }
        }
    }

    /// `reps` scalar probe misses (nothing filling in between — the
    /// streaming-store repeat path) and one `miss_repeat` agree.
    #[test]
    fn miss_repeat_matches_scalar_probe_misses() {
        let mut scalar = Cache::new(2048, 64, 2);
        let mut bulk = Cache::new(2048, 64, 2);
        scalar.fill(3, false, false);
        bulk.fill(3, false, false);
        for _ in 0..5 {
            assert_eq!(scalar.access(77, false), Probe::Miss);
        }
        bulk.miss_repeat(5);
        assert_eq!(scalar.state_digest(0, SEED_A), bulk.state_digest(0, SEED_A));
        assert_eq!(scalar.misses, bulk.misses);
        assert_eq!(scalar.hits, bulk.hits);
    }
}
