//! Set-associative, write-back, write-allocate cache model with LRU
//! replacement — the building block of the simulated memory hierarchy.
//!
//! Addresses are *line* addresses (byte address / line size); the
//! hierarchy layer does the conversion. Each line tracks a dirty bit
//! and whether it arrived via prefetch (for prefetch-accuracy
//! accounting in the Fig 4 study).

/// Result of a cache probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Probe {
    /// Line present. `was_prefetched` is true the first time a
    /// demand access touches a line that a prefetcher brought in.
    Hit { was_prefetched: bool },
    Miss,
}

/// One way, packed to 16 bytes so a whole 16-way set spans 4 cache
/// lines of host memory (§Perf: set scans dominate the hot path).
#[derive(Debug, Clone, Copy)]
struct Way {
    tag: u64,
    /// LRU timestamp (wraps far beyond any simulated run length).
    stamp: u32,
    /// Bit 0 = valid, bit 1 = dirty, bit 2 = prefetched-untouched.
    flags: u8,
}

const F_VALID: u8 = 1;
const F_DIRTY: u8 = 2;
const F_PREFETCHED: u8 = 4;

impl Way {
    #[inline]
    fn valid(&self) -> bool {
        self.flags & F_VALID != 0
    }
    #[inline]
    fn dirty(&self) -> bool {
        self.flags & F_DIRTY != 0
    }
    #[inline]
    fn prefetched(&self) -> bool {
        self.flags & F_PREFETCHED != 0
    }
}

const EMPTY: Way = Way {
    tag: 0,
    stamp: 0,
    flags: 0,
};

/// Largest power of two <= n (n >= 1).
fn prev_power_of_two(n: usize) -> usize {
    1 << (usize::BITS - 1 - n.leading_zeros())
}

/// One cache level.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    assoc: usize,
    ways: Vec<Way>,
    /// LRU clock (u32: capped sim lengths never approach wrap; reset per run).
    clock: u32,
    /// Statistics.
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
    pub prefetch_fills: u64,
    pub prefetch_hits: u64,
}

impl Cache {
    /// `capacity_bytes` / `line_bytes` / `assoc` must be power-of-two
    /// consistent; sets = capacity / (line * assoc).
    pub fn new(capacity_bytes: usize, line_bytes: usize, assoc: usize) -> Cache {
        assert!(capacity_bytes > 0 && line_bytes > 0 && assoc > 0);
        let lines = capacity_bytes / line_bytes;
        assert!(lines >= assoc, "capacity too small for associativity");
        // Round sets down to a power of two for mask indexing (real
        // parts with non-power-of-two capacity, e.g. 33 MB 11-way SKX
        // L3, are modelled slightly small rather than slightly large).
        let sets = prev_power_of_two((lines / assoc).max(1));
        Cache {
            sets,
            assoc,
            ways: vec![EMPTY; sets * assoc],
            clock: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
            prefetch_fills: 0,
            prefetch_hits: 0,
        }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    pub fn assoc(&self) -> usize {
        self.assoc
    }

    fn set_of(&self, line: u64) -> usize {
        (line as usize) & (self.sets - 1)
    }

    fn slot_range(&self, set: usize) -> std::ops::Range<usize> {
        set * self.assoc..(set + 1) * self.assoc
    }

    /// Issue a host software-prefetch for the set `line` maps to
    /// (§Perf: large simulated caches make every probe a host cache
    /// miss; hinting the three levels up front overlaps the misses).
    #[inline]
    pub fn prefetch_host(&self, line: u64) {
        #[cfg(target_arch = "x86_64")]
        unsafe {
            use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            let idx = self.set_of(line) * self.assoc;
            let ptr = self.ways.as_ptr().add(idx) as *const i8;
            _mm_prefetch(ptr, _MM_HINT_T0);
            // Sets larger than one host line: touch the tail too.
            if self.assoc > 4 {
                _mm_prefetch(ptr.add(64), _MM_HINT_T0);
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = line;
    }

    /// Demand access. On hit, updates LRU and clears the prefetched
    /// flag (the prefetch has now been consumed). Does NOT fill on
    /// miss — the hierarchy decides fill policy.
    pub fn access(&mut self, line: u64, is_write: bool) -> Probe {
        self.clock += 1;
        let set = self.set_of(line);
        for i in self.slot_range(set) {
            let w = &mut self.ways[i];
            if w.valid() && w.tag == line {
                let was_prefetched = w.prefetched();
                if was_prefetched {
                    self.prefetch_hits += 1;
                }
                w.flags &= !F_PREFETCHED;
                w.stamp = self.clock;
                if is_write {
                    w.flags |= F_DIRTY;
                }
                self.hits += 1;
                return Probe::Hit { was_prefetched };
            }
        }
        self.misses += 1;
        Probe::Miss
    }

    /// Probe without statistics or LRU update (used by prefetchers to
    /// avoid redundant fills).
    pub fn contains(&self, line: u64) -> bool {
        let set = self.set_of(line);
        self.ways[self.slot_range(set)]
            .iter()
            .any(|w| w.valid() && w.tag == line)
    }

    /// Insert a line, evicting LRU if needed. Returns the evicted dirty
    /// line (for writeback accounting), if any.
    pub fn fill(&mut self, line: u64, is_write: bool, prefetched: bool) -> Option<u64> {
        let set = self.set_of(line);
        // Already present (e.g. prefetch raced with demand): refresh.
        for i in self.slot_range(set) {
            if self.ways[i].valid() && self.ways[i].tag == line {
                self.clock += 1;
                let clock = self.clock;
                let w = &mut self.ways[i];
                w.stamp = clock;
                if is_write {
                    w.flags |= F_DIRTY;
                }
                return None;
            }
        }
        self.fill_after_miss(line, is_write, prefetched)
    }

    /// Insert a line the caller has just verified to be absent (the
    /// demand-miss path). Skips the presence re-scan that `fill` pays
    /// (§Perf: the miss path previously scanned every set twice).
    pub fn fill_after_miss(
        &mut self,
        line: u64,
        is_write: bool,
        prefetched: bool,
    ) -> Option<u64> {
        self.clock += 1;
        let set = self.set_of(line);
        let range = self.slot_range(set);
        debug_assert!(!self.contains(line));
        if prefetched {
            self.prefetch_fills += 1;
        }
        // Find invalid or LRU victim.
        let mut victim = range.start;
        let mut best = u32::MAX;
        for i in range {
            let w = &self.ways[i];
            if !w.valid() {
                victim = i;
                break;
            }
            if w.stamp < best {
                best = w.stamp;
                victim = i;
            }
        }
        let evicted = {
            let w = &self.ways[victim];
            if w.valid() && w.dirty() {
                self.writebacks += 1;
                Some(w.tag)
            } else {
                None
            }
        };
        self.ways[victim] = Way {
            tag: line,
            stamp: self.clock,
            flags: F_VALID
                | if is_write { F_DIRTY } else { 0 }
                | if prefetched { F_PREFETCHED } else { 0 },
        };
        evicted
    }

    /// Fused demand access + fill-on-miss in a single set scan (§Perf:
    /// the miss path previously paid one scan to probe and another to
    /// pick the victim). On hit behaves exactly like [`access`]; on
    /// miss inserts the line and returns the evicted dirty line.
    pub fn access_fill(
        &mut self,
        line: u64,
        is_write: bool,
    ) -> (Probe, Option<u64>) {
        self.clock += 1;
        let set = self.set_of(line);
        let range = self.slot_range(set);
        let mut victim = range.start;
        let mut best = u32::MAX;
        for i in range {
            let w = &mut self.ways[i];
            if w.valid() {
                if w.tag == line {
                    let was_prefetched = w.prefetched();
                    if was_prefetched {
                        self.prefetch_hits += 1;
                    }
                    w.flags &= !F_PREFETCHED;
                    w.stamp = self.clock;
                    if is_write {
                        w.flags |= F_DIRTY;
                    }
                    self.hits += 1;
                    return (Probe::Hit { was_prefetched }, None);
                }
                if w.stamp < best {
                    best = w.stamp;
                    victim = i;
                }
            } else if best != 0 {
                // Remember the first invalid way (beats any LRU pick)
                // but keep scanning for a hit.
                best = 0;
                victim = i;
            }
        }
        self.misses += 1;
        let evicted = {
            let w = &self.ways[victim];
            if w.valid() && w.dirty() {
                self.writebacks += 1;
                Some(w.tag)
            } else {
                None
            }
        };
        self.ways[victim] = Way {
            tag: line,
            stamp: self.clock,
            flags: F_VALID | if is_write { F_DIRTY } else { 0 },
        };
        (Probe::Miss, evicted)
    }

    /// Fill only when absent, reporting whether an insert happened
    /// (fuses the `contains` + `fill` pair the prefetch path used to
    /// pay — §Perf). Returns `(inserted, evicted_dirty_line)`.
    pub fn fill_if_absent(
        &mut self,
        line: u64,
        is_write: bool,
        prefetched: bool,
    ) -> (bool, Option<u64>) {
        let set = self.set_of(line);
        for i in self.slot_range(set) {
            if self.ways[i].valid() && self.ways[i].tag == line {
                return (false, None);
            }
        }
        (true, self.fill_after_miss(line, is_write, prefetched))
    }

    /// Invalidate a line (coherence). Returns true if it was present.
    pub fn invalidate(&mut self, line: u64) -> bool {
        let set = self.set_of(line);
        for i in self.slot_range(set) {
            if self.ways[i].valid() && self.ways[i].tag == line {
                self.ways[i] = EMPTY;
                return true;
            }
        }
        false
    }

    /// Clear contents and statistics.
    pub fn reset(&mut self) {
        self.ways.fill(EMPTY);
        self.clock = 0;
        self.hits = 0;
        self.misses = 0;
        self.writebacks = 0;
        self.prefetch_fills = 0;
        self.prefetch_hits = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cache {
        // 4 sets x 2 ways x 64B lines = 512 B
        Cache::new(512, 64, 2)
    }

    #[test]
    fn geometry() {
        let c = small();
        assert_eq!(c.sets(), 4);
        assert_eq!(c.assoc(), 2);
        let big = Cache::new(32 * 1024, 64, 8);
        assert_eq!(big.sets(), 64);
    }

    #[test]
    fn miss_then_fill_then_hit() {
        let mut c = small();
        assert_eq!(c.access(5, false), Probe::Miss);
        c.fill(5, false, false);
        assert_eq!(c.access(5, false), Probe::Hit { was_prefetched: false });
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = small();
        // set 0 holds lines 0, 4, 8, ... (4 sets). Fill two ways.
        c.fill(0, false, false);
        c.fill(4, false, false);
        // touch 0 so 4 becomes LRU
        c.access(0, false);
        // fill 8 -> evicts 4
        c.fill(8, false, false);
        assert!(c.contains(0));
        assert!(!c.contains(4));
        assert!(c.contains(8));
    }

    #[test]
    fn dirty_eviction_counts_writeback() {
        let mut c = small();
        c.fill(0, true, false); // dirty
        c.fill(4, false, false);
        let evicted = c.fill(8, false, false); // evicts LRU = 0 (dirty)
        assert_eq!(evicted, Some(0));
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn clean_eviction_no_writeback() {
        let mut c = small();
        c.fill(0, false, false);
        c.fill(4, false, false);
        let evicted = c.fill(8, false, false);
        assert_eq!(evicted, None);
        assert_eq!(c.writebacks, 0);
    }

    #[test]
    fn write_hit_marks_dirty() {
        let mut c = small();
        c.fill(0, false, false);
        c.access(0, true); // write hit -> dirty
        c.fill(4, false, false);
        let evicted = c.fill(8, false, false);
        assert_eq!(evicted, Some(0));
    }

    #[test]
    fn prefetch_accounting() {
        let mut c = small();
        c.fill(3, false, true); // prefetched
        assert_eq!(c.prefetch_fills, 1);
        assert_eq!(c.access(3, false), Probe::Hit { was_prefetched: true });
        assert_eq!(c.prefetch_hits, 1);
        // second touch: no longer "prefetched"
        assert_eq!(c.access(3, false), Probe::Hit { was_prefetched: false });
        assert_eq!(c.prefetch_hits, 1);
    }

    #[test]
    fn refill_existing_line_is_idempotent() {
        let mut c = small();
        c.fill(0, false, false);
        assert_eq!(c.fill(0, true, false), None); // refresh, mark dirty
        c.fill(4, false, false);
        assert_eq!(c.fill(8, false, false), Some(0)); // 0 dirty via refill
    }

    #[test]
    fn invalidate() {
        let mut c = small();
        c.fill(0, true, false);
        assert!(c.invalidate(0));
        assert!(!c.contains(0));
        assert!(!c.invalidate(0));
    }

    #[test]
    fn reset_clears_everything() {
        let mut c = small();
        c.fill(0, false, false);
        c.access(0, false);
        c.access(1, false);
        c.reset();
        assert!(!c.contains(0));
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn different_sets_do_not_conflict() {
        let mut c = small();
        // lines 0..4 map to sets 0..4 — all coexist with assoc 2
        for l in 0..4 {
            c.fill(l, false, false);
        }
        for l in 0..4 {
            assert!(c.contains(l), "line {l}");
        }
    }

    #[test]
    fn associativity_respected() {
        let mut c = small(); // 2-way
        // three lines in set 0: 0, 4, 8 -> one must be evicted
        c.fill(0, false, false);
        c.fill(4, false, false);
        c.fill(8, false, false);
        let present = [0u64, 4, 8].iter().filter(|&&l| c.contains(l)).count();
        assert_eq!(present, 2);
    }
}
