//! CPU memory-system engine: L1/L2/L3 + TLB + prefetcher + a
//! bottleneck timing model.
//!
//! One engine simulates the union access stream of all OpenMP threads
//! through a representative private L1/L2 and the shared L3 (with the
//! paper's static chunked iteration distribution, every thread's
//! stream has identical locality structure, so the union stream seen
//! by one hierarchy is a faithful stand-in). Timing then splits
//! resources: per-thread issue rate and L2 bandwidth scale with the
//! thread count, while L3 and DRAM bandwidth are shared.
//!
//! The run time is the **max** over resource occupancies (a roofline /
//! bottleneck model):
//!
//! ```text
//! t = max( issue, L2-bw, L3-bw, DRAM-bw, miss-latency/MLP, TLB, coherence )
//! ```
//!
//! This is what makes the paper's curves emerge: at stride-1 DRAM
//! bandwidth binds (STREAM); at large strides DRAM still binds but the
//! traffic is inflated by unused line fragments and prefetch
//! over-fetch; for cache-resident app patterns the issue rate or L2
//! bandwidth binds (bandwidths above STREAM, §5.4); for huge deltas
//! the TLB binds (PENNANT); for delta-0 multi-thread scatter the
//! coherence penalty binds (LULESH-S3).

use std::collections::HashSet;

use super::cache::{Cache, Probe};
use super::closure::{self, LoopCloser, Observation};
use super::memory::{
    PageSize, PageTableWalker, PhysicalAddress, Tlb, VirtualAddress,
};
use super::plan::{AccessPlan, Segment};
use super::prefetch::Prefetcher;
use super::topology::{NumaPlacement, Topology};
use super::{PrefetchKind, SimCounters, SimResult, TimeBreakdown, XorShift64};
use crate::error::{Error, Result};
use crate::pattern::{Kernel, Pattern};
use crate::platforms::{CpuPlatform, VectorRegime};

/// Knobs for a simulated run.
#[derive(Debug, Clone)]
pub struct CpuSimOptions {
    /// Model hardware prefetching (the Fig 4 MSR toggle).
    pub prefetch_enabled: bool,
    /// Vectorization regime for the indexed inner loop (the
    /// `--vector-regime` knob, paper §5.3 / Fig 6). `None` = the
    /// platform's native compiler output
    /// ([`CpuPlatform::native_regime`]); the Scalar backend pins
    /// `Some(VectorRegime::Scalar)`. Running an unsupported regime is
    /// a config error ([`CpuPlatform::supported_regimes`]).
    pub regime: Option<VectorRegime>,
    /// Cap on simulated accesses in the measured pass; counts beyond
    /// this are extrapolated linearly (steady state).
    pub max_sim_accesses: usize,
    /// Warmup iterations before measurement (models the paper's
    /// min-of-10-runs protocol, where later runs find warm caches).
    pub warmup_iterations: usize,
    /// Translation page size (the `--page-size` knob). The per-size
    /// TLB geometry comes from the platform's [`TlbTable`]
    /// (`platforms/mod.rs`).
    ///
    /// [`TlbTable`]: super::memory::TlbTable
    pub page_size: PageSize,
    /// OpenMP thread count (the `--threads` knob, the paper's §3.1
    /// thread-scaling axis). `None` = the platform's single-socket
    /// default. Per-thread issue rate and L2 bandwidth scale with it;
    /// L3 and DRAM stay shared; the chunked-schedule coherence model
    /// is keyed off it.
    pub threads: Option<usize>,
    /// Steady-state loop closure (`sim::closure`): detect when the
    /// microarchitectural state cycles and close the remaining
    /// iterations analytically. Counters and timing are bit-identical
    /// either way (pinned by the equivalence property test); disabling
    /// is for A/B benchmarking. Default: on, unless the
    /// `SPATTER_NO_CLOSURE` environment variable is set.
    pub closure_enabled: bool,
    /// Batch-compiled access plans (`sim::plan`): compile the run's
    /// access stream once (pre-scaled offsets, per-stream flags,
    /// same-line run RLE) and drive monomorphized hot loops with
    /// counted bulk updates for provably-redundant repeats. Counters
    /// and timing are bit-identical to the scalar reference path
    /// (pinned by `tests/plan_equivalence.rs`); disabling is for A/B
    /// benchmarking and differential testing. Default: on, unless the
    /// `SPATTER_NO_PLAN` environment variable is set (sibling to
    /// `SPATTER_NO_CLOSURE` / `SPATTER_NO_MEMO`).
    pub plan_enabled: bool,
    /// NUMA page-placement policy (the `--numa-placement` knob).
    /// Inert on single-socket platforms; on multi-socket parts it
    /// decides each page's home node and therefore the local/remote
    /// split (`sim::topology`). Default: first-touch, the OS default.
    pub numa_placement: NumaPlacement,
}

impl Default for CpuSimOptions {
    fn default() -> Self {
        CpuSimOptions {
            prefetch_enabled: true,
            regime: None,
            max_sim_accesses: 1 << 21,
            warmup_iterations: 1 << 15,
            page_size: PageSize::FourKB,
            threads: None,
            closure_enabled: std::env::var_os("SPATTER_NO_CLOSURE").is_none(),
            plan_enabled: std::env::var_os("SPATTER_NO_PLAN").is_none(),
            numa_placement: NumaPlacement::FirstTouch,
        }
    }
}

const LINE: u64 = 64;

/// Page walks overlap about two deep per thread (the walker MLP the
/// timing model charges against).
const WALK_OVERLAP: f64 = 2.0;

/// Most operand streams any kernel issues (Add/Triad: two reads plus
/// one write) — sizes the per-stream DRAM open-row table.
const MAX_STREAMS: usize = 3;

/// The engine. Reusable across runs (state resets per run).
pub struct CpuEngine {
    platform: CpuPlatform,
    opts: CpuSimOptions,
    l1: Cache,
    l2: Cache,
    l3: Cache,
    /// Shared virtual-memory subsystem: set-associative TLB (with the
    /// same-page short-circuit) + radix page-table walker, both sized
    /// for the configured [`PageSize`].
    tlb: Tlb,
    walker: PageTableWalker,
    /// Per-operand-stream prefetchers: real stride detectors track
    /// each demand stream separately, so the interleaved multi-operand
    /// misses of GS / Add / Triad don't destroy each other's stride
    /// confidence (1 GiB-apart regions would otherwise alternate the
    /// observed stride every miss). Single-stream kernels use slot 0
    /// only — numerically identical to a lone prefetcher.
    prefetchers: [Prefetcher; MAX_STREAMS],
    /// Scratch: prefetch target lines, reused across `access` calls
    /// and runs (never reallocated — see the module-level
    /// scratch-buffer invariants in `sim`).
    pf_buf: Vec<u64>,
    /// Scratch: the pattern's index buffer pre-scaled to byte offsets,
    /// rebuilt once per pass and consumed by the demand path (no
    /// per-access multiply, no per-run allocation once warm).
    idx_bytes: Vec<u64>,
    /// Scratch: the write-side buffer pre-scaled to byte offsets
    /// *including* the write-region base (the GS scatter side or a
    /// dense kernel's output stream), rebuilt once per pass (empty for
    /// single-buffer kernels).
    idx2_bytes: Vec<u64>,
    /// Batch-compiled access plan (`sim::plan`): the run's full access
    /// stream — pre-scaled offsets, per-stream segments, same-line run
    /// RLE — compiled once per `run()` and replayed by the
    /// monomorphized planned pass. Engine-owned scratch, rebuilt in
    /// place (no per-run allocation once warm).
    plan: AccessPlan,
    /// NUMA topology (`sim::topology`): one banked DRAM row-buffer
    /// model (`sim::dram`) per socket — channels × ranks × bank groups
    /// × banks of open rows, with a per-stream slot offset so the
    /// 1 GiB-apart regions of multi-operand kernels don't alias onto
    /// one bank — plus the page-placement policy that classifies every
    /// DRAM-facing access local or remote. Single-socket platforms
    /// collapse to the flat PR-7 model bit-exactly.
    topo: Topology,
    /// Effective OpenMP thread count for the next run (resolved from
    /// `opts.threads` / the platform default; overridable per run via
    /// [`CpuEngine::set_threads`]).
    threads: usize,
    /// Effective vectorization regime for the next run (resolved from
    /// `opts.regime` / the platform's native regime; overridable per
    /// run via [`CpuEngine::set_vector_regime`]).
    regime: VectorRegime,
}

/// DRAM row-buffer size for the banked row model (2 KiB = 32 lines).
const ROW_LINES: u64 = 32;
/// Row-activation cost in equivalent bytes of transfer.
const ROW_PENALTY_BYTES: f64 = 64.0;

impl CpuEngine {
    pub fn new(platform: &CpuPlatform) -> CpuEngine {
        CpuEngine::with_options(platform, CpuSimOptions::default())
    }

    pub fn with_options(platform: &CpuPlatform, opts: CpuSimOptions) -> CpuEngine {
        let p = platform.clone();
        let page = opts.page_size;
        let pf_kind = if opts.prefetch_enabled {
            p.prefetch
        } else {
            PrefetchKind::None
        };
        CpuEngine {
            l1: Cache::new(p.l1_kb * 1024, LINE as usize, p.l1_assoc),
            l2: Cache::new(p.l2_kb * 1024, LINE as usize, p.l2_assoc),
            l3: Cache::new(p.l3_mb * 1024 * 1024, LINE as usize, p.l3_assoc),
            tlb: Tlb::new(p.tlb.geometry(page), page),
            walker: PageTableWalker::new(p.tlb_walk_ns, page, WALK_OVERLAP),
            prefetchers: std::array::from_fn(|_| Prefetcher::new(pf_kind)),
            threads: opts.threads.unwrap_or(p.threads).max(1),
            regime: opts.regime.unwrap_or(p.native_regime),
            topo: Topology::new(
                &p.numa,
                &p.dram,
                ROW_LINES * LINE,
                opts.numa_placement,
                page.shift(),
            ),
            platform: p,
            opts,
            pf_buf: Vec::with_capacity(8),
            idx_bytes: Vec::new(),
            idx2_bytes: Vec::new(),
            plan: AccessPlan::default(),
        }
    }

    pub fn platform(&self) -> &CpuPlatform {
        &self.platform
    }

    pub fn options(&self) -> &CpuSimOptions {
        &self.opts
    }

    /// The page size the next run will model.
    pub fn page_size(&self) -> PageSize {
        self.tlb.page_size()
    }

    /// Reconfigure the translation page size: `Some` overrides, `None`
    /// restores the engine's configured default. Rebuilds the TLB and
    /// walker from the platform's per-size table.
    pub fn set_page_size(&mut self, page: Option<PageSize>) {
        let page = page.unwrap_or(self.opts.page_size);
        if page == self.page_size() {
            return;
        }
        self.tlb = Tlb::new(self.platform.tlb.geometry(page), page);
        self.walker =
            PageTableWalker::new(self.platform.tlb_walk_ns, page, WALK_OVERLAP);
        // Home nodes are per-page: the topology tracks the same size.
        self.topo.set_page_shift(page.shift());
    }

    /// The OpenMP thread count the next run will model.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Reconfigure the simulated thread count: `Some` overrides, `None`
    /// restores the engine's configured default (the `--threads` CLI
    /// value or the platform's single-socket count).
    pub fn set_threads(&mut self, threads: Option<usize>) {
        self.threads = threads
            .unwrap_or_else(|| {
                self.opts.threads.unwrap_or(self.platform.threads)
            })
            .max(1);
    }

    /// The vectorization regime the next run will model.
    pub fn vector_regime(&self) -> VectorRegime {
        self.regime
    }

    /// Reconfigure the vectorization regime: `Some` overrides, `None`
    /// restores the engine's configured default (the `--vector-regime`
    /// CLI value or the platform's native regime). Support is checked
    /// at `run()` time, so an unsupported override surfaces as a
    /// config error rather than silently falling back.
    pub fn set_vector_regime(&mut self, regime: Option<VectorRegime>) {
        self.regime = regime
            .or(self.opts.regime)
            .unwrap_or(self.platform.native_regime);
    }

    /// The NUMA page-placement policy the next run will model.
    pub fn numa_placement(&self) -> NumaPlacement {
        self.topo.placement()
    }

    /// Reconfigure the NUMA placement policy: `Some` overrides, `None`
    /// restores the engine's configured default (the `--numa-placement`
    /// CLI value or first-touch). Inert on single-socket platforms.
    pub fn set_numa_placement(&mut self, placement: Option<NumaPlacement>) {
        self.topo
            .set_placement(placement.unwrap_or(self.opts.numa_placement));
    }

    fn reset(&mut self) {
        self.l1.reset();
        self.l2.reset();
        self.l3.reset();
        self.tlb.reset();
        for pf in &mut self.prefetchers {
            pf.reset();
        }
        self.topo.reset();
    }

    /// Classify a DRAM-facing access (fill, prefetch fill, or
    /// streaming store): route it through the NUMA topology — which
    /// decides the home node and the local/remote split — into the
    /// home node's banked row model for operand stream `sid`.
    /// DRAM-facing: only translated addresses may reach the row model.
    #[inline]
    fn note_row(&mut self, pa: PhysicalAddress, sid: usize, c: &mut SimCounters) {
        self.topo.access(pa.byte(), sid, c);
    }

    /// Simulate one Spatter run and return modelled time + counters.
    pub fn run(&mut self, pattern: &Pattern, kernel: Kernel) -> Result<SimResult> {
        pattern.validate_for(kernel)?;
        if !self.platform.supports_regime(self.regime) {
            return Err(Error::Config(format!(
                "platform '{}' does not support vector regime '{}' \
                 (supported: {})",
                self.platform.name,
                self.regime,
                self.platform
                    .supported_regimes()
                    .iter()
                    .map(|r| r.name())
                    .collect::<Vec<_>>()
                    .join("|"),
            )));
        }
        // Footprint sharing decides the first-touch placement path: a
        // delta-0 pattern (every thread re-walks the same window) and
        // the GUPS table (one table, all threads) are touched — and
        // first-touch placed — by whichever thread got there first;
        // everything else advances, so each thread's chunk is private.
        self.topo.set_shared(
            kernel == Kernel::Gups || pattern.mean_delta() == 0.0,
        );
        self.reset();
        debug_assert_eq!(
            self.tlb.page_size(),
            self.walker.page_size(),
            "TLB and walker must be rebuilt together (set_page_size)"
        );

        let v = pattern.vector_len();
        let cap_iters =
            (self.opts.max_sim_accesses / (v * kernel.streams())).max(1);
        let measured = pattern.count.min(cap_iters);
        // Streaming (non-temporal) store eligibility is a property of
        // the write-side stream: `indices` for Scatter, the scatter
        // side for GS. The STREAM tetrad's output covers whole lines
        // exactly once by construction (the classic NT-store path);
        // GUPS is a read-modify-write and must keep the cache.
        let streaming = match kernel {
            Kernel::Gather | Kernel::Gups => false,
            Kernel::Scatter => write_density(pattern, &pattern.indices) >= 0.99,
            Kernel::GS => {
                write_density(pattern, &pattern.scatter_indices) >= 0.99
            }
            Kernel::Stream(_) => true,
        };

        // Warmup pass: the paper reports the min of 10 runs, so the
        // measured run starts with caches/TLB warm from the *end* of
        // the previous run — simulate the tail iterations uncounted.
        // (Loop closure applies here too: once the warm-up state
        // cycles, it fast-forwards to the exact end-of-run state.)
        let warmup = pattern.count.min(self.opts.warmup_iterations);
        let wstart = pattern.count - warmup;
        // Batch-compiled plan (`sim::plan`): compile the per-iteration
        // access stream once and replay it through the monomorphized
        // planned pass. GUPS draws its addresses from a per-pass RNG,
        // so it has no per-run-constant stream to compile.
        let use_plan = self.opts.plan_enabled && kernel != Kernel::Gups;
        if use_plan {
            let mut plan = std::mem::take(&mut self.plan);
            plan.build_cpu(pattern, kernel, streaming);
            self.plan = plan;
        }
        let mut scratch = SimCounters::default();
        if use_plan {
            self.pass_planned(pattern, wstart, pattern.count, &mut scratch);
        } else {
            self.pass(
                pattern,
                wstart,
                pattern.count,
                kernel,
                streaming,
                true,
                &mut scratch,
            );
        }

        // Measured pass: iterations [0, measured) of the next run.
        let mut counters = SimCounters::default();
        let closed_at = if use_plan {
            self.pass_planned(pattern, 0, measured, &mut counters)
        } else {
            self.pass(pattern, 0, measured, kernel, streaming, false, &mut counters)
        };
        counters.coherence_events = self.coherence_events(pattern, kernel, measured);

        // Page walks miss the cache hierarchy when touched pages are
        // sparse (one PTE line covers 64 consecutive pages — 256 KiB
        // at 4 KiB pages, 128 MiB at 2 MiB): each walk then costs DRAM
        // accesses too.
        let sparse_walks =
            pattern.mean_delta() * 8.0 >= self.walker.pte_line_coverage_bytes();

        let breakdown = self.timing(&counters, kernel, sparse_walks);
        let scale = pattern.count as f64 / measured as f64;
        let seconds = breakdown.total() * scale;
        // Useful bytes: the indexed kernels and GUPS count the copied/
        // updated payload (8 * V * count) once — GS and GUPS charge
        // every stream to the memory system above, the record reports
        // per-side traffic, and the headline stays bounded by the
        // component kernels. The STREAM tetrad uses STREAM's own
        // convention and counts every operand stream (16 or 24 B/elem).
        Ok(SimResult {
            seconds,
            useful_bytes: pattern.moved_bytes() as u64
                * kernel.payload_streams() as u64,
            counters,
            breakdown,
            simulated_iterations: measured,
            closed_at_iteration: closed_at,
        })
    }

    /// Simulate iterations [begin, end) of the pattern, closing the
    /// loop analytically once the microarchitectural state cycles
    /// (`sim::closure`). Returns the iteration at which closure fired,
    /// if it did; counters in `c` are identical either way.
    fn pass(
        &mut self,
        pattern: &Pattern,
        begin: usize,
        end: usize,
        kernel: Kernel,
        streaming: bool,
        warm: bool,
        c: &mut SimCounters,
    ) -> Option<usize> {
        if kernel == Kernel::Gups {
            return self.pass_gups(pattern, begin, end, warm, c);
        }
        let v = pattern.vector_len();
        let mut last_stream_line = u64::MAX;
        let mut base = pattern.base(begin);
        // The primary stream(s): reads for Gather/GS/STREAM, writes
        // for Scatter.
        let primary_write = kernel == Kernel::Scatter;
        let primary_streaming = primary_write && streaming;
        let read_streams = kernel.read_streams();
        // Pre-scale the index buffers to byte offsets once per pass
        // (engine scratch; moved out for the loop's disjoint borrows).
        // Write sides bake in their region base, so every stream
        // advances with the same per-iteration base below.
        let mut idx = std::mem::take(&mut self.idx_bytes);
        idx.clear();
        match kernel {
            // One contiguous operand array per read stream, each its
            // own span-sized 1 GiB-aligned allocation.
            Kernel::Stream(_) => {
                let region = pattern.dense_region_bytes();
                for r in 0..read_streams as u64 {
                    idx.extend(
                        pattern
                            .indices
                            .iter()
                            .map(|&i| r * region + i as u64 * 8),
                    );
                }
            }
            _ => idx.extend(pattern.indices.iter().map(|&i| i as u64 * 8)),
        }
        let mut idx2 = std::mem::take(&mut self.idx2_bytes);
        idx2.clear();
        match kernel {
            Kernel::GS => {
                let dst = pattern.gs_scatter_base() as u64 * 8;
                idx2.extend(
                    pattern.scatter_indices.iter().map(|&i| dst + i as u64 * 8),
                );
            }
            Kernel::Stream(_) => {
                let dst = read_streams as u64 * pattern.dense_region_bytes();
                idx2.extend(
                    pattern.indices.iter().map(|&i| dst + i as u64 * 8),
                );
            }
            _ => {}
        }
        let period = pattern.deltas.len().max(1);
        let mut closer = if self.opts.closure_enabled && end > begin + 1 {
            Some(LoopCloser::new())
        } else {
            None
        };
        let mut closed_at = None;
        let mut i = begin;
        while i < end {
            let base_bytes = (base as u64) * 8;
            // Each read stream is `v` slots of the pre-scaled buffer
            // and owns its open-row slot (single-stream kernels: one
            // chunk, slot 0 — identical to a lone tracker).
            for (sid, stream) in idx.chunks(v).enumerate() {
                for &off in stream {
                    let va = VirtualAddress(base_bytes + off);
                    self.access(
                        va,
                        primary_write,
                        primary_streaming,
                        sid,
                        &mut last_stream_line,
                        c,
                    );
                }
            }
            // Write stream (the GS scatter side or a dense kernel's
            // output): the vectorized kernel reads the whole vector,
            // then writes it.
            for &off in &idx2 {
                let va = VirtualAddress(base_bytes + off);
                self.access(
                    va,
                    true,
                    streaming,
                    read_streams,
                    &mut last_stream_line,
                    c,
                );
            }
            base += pattern.delta_at(i);
            i += 1;
            if closer.is_some() && i < end {
                let key = self.pass_digest(base, i % period, last_stream_line);
                let obs = closer.as_mut().unwrap().observe(key, i, base, c);
                match obs {
                    Observation::Recorded => {}
                    Observation::Saturated => closer = None,
                    Observation::Cycle(info) => {
                        let cycle = i - info.iter;
                        let reps = (end - i) / cycle;
                        // Report closure only when iterations were
                        // actually skipped (a cycle longer than the
                        // remaining tail closes nothing).
                        if reps > 0 {
                            closed_at = Some(i);
                            // Per-cycle counter delta, multiplied over
                            // every whole remaining cycle; then shift
                            // the state to where full simulation would
                            // be and run only the sub-cycle tail.
                            let d = c.delta_since(&info.counters);
                            c.add_scaled(&d, reps as u64);
                            let advance = (base - info.base) as u64;
                            let shift_elems = advance * reps as u64;
                            self.fast_forward(shift_elems);
                            let shift_lines = shift_elems * 8 / LINE;
                            if last_stream_line != u64::MAX {
                                last_stream_line += shift_lines;
                            }
                            base += shift_elems as i64;
                            i += cycle * reps;
                        }
                        closer = None;
                    }
                }
            }
        }
        self.idx_bytes = idx;
        self.idx2_bytes = idx2;
        closed_at
    }

    /// Planned pass (`sim::plan`): iterations [begin, end) replayed
    /// from the precompiled access plan, under the same loop-closure
    /// protocol as the scalar [`CpuEngine::pass`]. Each segment's
    /// regime knobs (write / streaming / prefetch) select one
    /// monomorphized `seg_body` instantiation, and when the iteration
    /// base is line-aligned, same-line runs collapse into counted bulk
    /// updates. Counters and end-of-pass state are bit-identical to
    /// the scalar pass (pinned by `tests/plan_equivalence.rs`).
    fn pass_planned(
        &mut self,
        pattern: &Pattern,
        begin: usize,
        end: usize,
        c: &mut SimCounters,
    ) -> Option<usize> {
        let plan = std::mem::take(&mut self.plan);
        let mut last_stream_line = u64::MAX;
        let mut base = pattern.base(begin);
        // Regime knob hoisted out of the loop: every prefetcher shares
        // one kind, so one flag picks the PF arm for the whole pass.
        let pf = !matches!(self.prefetchers[0].kind, PrefetchKind::None);
        let period = pattern.deltas.len().max(1);
        let mut closer = if self.opts.closure_enabled && end > begin + 1 {
            Some(LoopCloser::new())
        } else {
            None
        };
        let mut closed_at = None;
        let mut i = begin;
        while i < end {
            let base_bytes = (base as u64) * 8;
            // Same-line runs only collapse when the base preserves the
            // offsets' line partition (see `sim::plan`); checked once
            // per iteration. Closure fast-forward shifts are page-size
            // multiples, so alignment is stable across a pass.
            let aligned = base_bytes % LINE == 0;
            for seg in &plan.segs {
                match (seg.write, seg.streaming, pf) {
                    (false, false, false) => self.seg_body::<false, false, false>(
                        &plan, seg, base_bytes, aligned, &mut last_stream_line, c,
                    ),
                    (false, false, true) => self.seg_body::<false, false, true>(
                        &plan, seg, base_bytes, aligned, &mut last_stream_line, c,
                    ),
                    (false, true, false) => self.seg_body::<false, true, false>(
                        &plan, seg, base_bytes, aligned, &mut last_stream_line, c,
                    ),
                    (false, true, true) => self.seg_body::<false, true, true>(
                        &plan, seg, base_bytes, aligned, &mut last_stream_line, c,
                    ),
                    (true, false, false) => self.seg_body::<true, false, false>(
                        &plan, seg, base_bytes, aligned, &mut last_stream_line, c,
                    ),
                    (true, false, true) => self.seg_body::<true, false, true>(
                        &plan, seg, base_bytes, aligned, &mut last_stream_line, c,
                    ),
                    (true, true, false) => self.seg_body::<true, true, false>(
                        &plan, seg, base_bytes, aligned, &mut last_stream_line, c,
                    ),
                    (true, true, true) => self.seg_body::<true, true, true>(
                        &plan, seg, base_bytes, aligned, &mut last_stream_line, c,
                    ),
                }
            }
            base += pattern.delta_at(i);
            i += 1;
            if closer.is_some() && i < end {
                let key = self.pass_digest(base, i % period, last_stream_line);
                let obs = closer.as_mut().unwrap().observe(key, i, base, c);
                match obs {
                    Observation::Recorded => {}
                    Observation::Saturated => closer = None,
                    Observation::Cycle(info) => {
                        let cycle = i - info.iter;
                        let reps = (end - i) / cycle;
                        if reps > 0 {
                            closed_at = Some(i);
                            let d = c.delta_since(&info.counters);
                            c.add_scaled(&d, reps as u64);
                            let advance = (base - info.base) as u64;
                            let shift_elems = advance * reps as u64;
                            self.fast_forward(shift_elems);
                            let shift_lines = shift_elems * 8 / LINE;
                            if last_stream_line != u64::MAX {
                                last_stream_line += shift_lines;
                            }
                            base += shift_elems as i64;
                            i += cycle * reps;
                        }
                        closer = None;
                    }
                }
            }
        }
        self.plan = plan;
        closed_at
    }

    /// One segment of the planned iteration, monomorphized over the
    /// regime knobs: `W` = write, `S` = streaming (non-temporal), `PF`
    /// = prefetchers active. `aligned` selects the run-coalesced body;
    /// otherwise the per-offset walk runs through the same
    /// monomorphized access path without bulk updates.
    #[inline]
    fn seg_body<const W: bool, const S: bool, const PF: bool>(
        &mut self,
        plan: &AccessPlan,
        seg: &Segment,
        base_bytes: u64,
        aligned: bool,
        last_stream_line: &mut u64,
        c: &mut SimCounters,
    ) {
        if aligned {
            for run in &plan.runs[seg.run_start..seg.run_end] {
                let va = VirtualAddress(base_bytes + run.off);
                let resident =
                    self.access_fast::<W, S, PF>(va, seg.sid, last_stream_line, c);
                if run.extra > 0 {
                    self.repeat_same_line::<W>(va, resident, run.extra, c);
                }
            }
        } else {
            for &off in &plan.offsets[seg.off_start..seg.off_end] {
                let va = VirtualAddress(base_bytes + off);
                self.access_fast::<W, S, PF>(va, seg.sid, last_stream_line, c);
            }
        }
    }

    /// Monomorphized twin of [`CpuEngine::access`] (`W` = write, `S` =
    /// streaming, `PF` = prefetchers active): identical state and
    /// counter effects, with the per-access regime branches resolved
    /// at compile time. Returns whether the line is L1-resident on
    /// return — same-line followers are then pure L1 hits; on the
    /// streaming-miss path (`false`) they are pure L1 probe misses
    /// (see `repeat_same_line`). The `PF = false` arm still advances
    /// the stride tracker (`Prefetcher::note_miss`) so the closure
    /// digest stays regime-independent — `PrefetchKind::None` issues
    /// no fills by construction, so skipping the fill loop is exact.
    #[inline]
    fn access_fast<const W: bool, const S: bool, const PF: bool>(
        &mut self,
        va: VirtualAddress,
        sid: usize,
        last_stream_line: &mut u64,
        c: &mut SimCounters,
    ) -> bool {
        c.accesses += 1;
        let t = self.tlb.translate(va, W, &mut c.tlb);
        let pa = t.physical;
        let line = pa.line();
        self.l1.prefetch_host(line);
        self.l2.prefetch_host(line);
        self.l3.prefetch_host(line);
        if S {
            if let Probe::Hit { .. } = self.l1.access(line, W) {
                c.l1_hits += 1;
                return true;
            }
            if line != *last_stream_line {
                c.streaming_store_lines += 1;
                self.note_row(pa, sid, c);
                *last_stream_line = line;
            }
            return false;
        }
        if let Probe::Hit { .. } = self.l1.access(line, W) {
            c.l1_hits += 1;
            return true;
        }
        match self.l2.access(line, W) {
            Probe::Hit { was_prefetched } => {
                c.l2_hits += 1;
                if was_prefetched {
                    c.prefetch_useful += 1;
                }
                self.fill_l1(line, W, c);
                return true;
            }
            Probe::Miss => {}
        }
        match self.l3.access(line, W) {
            Probe::Hit { was_prefetched } => {
                c.l3_hits += 1;
                if was_prefetched {
                    c.prefetch_useful += 1;
                }
                self.fill_l2(line, W, c);
                self.fill_l1(line, W, c);
                return true;
            }
            Probe::Miss => {}
        }
        c.dram_demand_lines += 1;
        self.note_row(pa, sid, c);
        if self.l3.fill_after_miss(line, false, false).is_some() {
            c.writeback_lines += 1;
        }
        self.fill_l2(line, W, c);
        self.fill_l1(line, W, c);
        if PF {
            self.prefetchers[sid].on_miss(pa.byte(), line, &mut self.pf_buf);
            let mut k = 0;
            while k < self.pf_buf.len() {
                let pl = self.pf_buf[k];
                k += 1;
                let (inserted_l2, ev) = self.l2.fill_if_absent(pl, false, true);
                if inserted_l2 {
                    if let Some(ev) = ev {
                        if self.l3.fill(ev, true, false).is_some() {
                            c.writeback_lines += 1;
                        }
                    }
                    let (inserted_l3, _) = self.l3.fill_if_absent(pl, false, true);
                    if inserted_l3 {
                        c.dram_prefetch_lines += 1;
                        self.note_row(PhysicalAddress::from_line(pl), sid, c);
                    }
                }
            }
        } else {
            self.prefetchers[sid].note_miss(pa.byte());
        }
        true
    }

    /// Counted bulk update for the `extra` same-line followers of a
    /// run head (`sim::plan`): each follower would translate through
    /// the TLB's same-page short-circuit (the head always primes
    /// `last_vpn`) and then hit — or, on the streaming miss path,
    /// probe-miss — L1, with no other state transition possible in
    /// between. The N scalar probe calls telescope into O(1) updates
    /// with identical final state and counters
    /// ([`Cache::hit_repeat`] / [`Cache::miss_repeat`] /
    /// [`Tlb::note_same_page_repeats`]).
    #[inline]
    fn repeat_same_line<const W: bool>(
        &mut self,
        va: VirtualAddress,
        resident: bool,
        extra: u32,
        c: &mut SimCounters,
    ) {
        let reps = extra as u64;
        c.accesses += reps;
        self.tlb.note_same_page_repeats(va, W, reps, &mut c.tlb);
        if resident {
            self.l1.hit_repeat(va.0 / LINE, W, extra);
            c.l1_hits += reps;
        } else {
            self.l1.miss_repeat(extra);
        }
    }

    /// GUPS pass: `V` seeded-xorshift random read-modify-writes per
    /// iteration into the power-of-two table (`table[x & mask] ^= v`:
    /// a load that misses deep plus a store that hits the just-filled
    /// L1 line and dirties it — RFO traffic in, writeback traffic
    /// out). The warm-up pass draws a disjoint seeded stream (`warm`),
    /// so a short run's warm-up never replays — and pre-caches — the
    /// measured addresses. The xorshift never cycles within a run, so
    /// loop closure has nothing to close: the pass runs in full either
    /// way, and closure on/off is trivially bit-identical.
    fn pass_gups(
        &mut self,
        pattern: &Pattern,
        begin: usize,
        end: usize,
        warm: bool,
        c: &mut SimCounters,
    ) -> Option<usize> {
        let mask = pattern.gups_table_elems() - 1;
        let v = pattern.vector_len();
        let mut rng = XorShift64::seeded(begin, warm);
        let mut last_stream_line = u64::MAX;
        for _ in begin..end {
            for _ in 0..v {
                let va = VirtualAddress((rng.next_u64() & mask) * 8);
                self.access(va, false, false, 0, &mut last_stream_line, c);
                self.access(va, true, false, 0, &mut last_stream_line, c);
            }
        }
        None
    }

    /// 128-bit fingerprint of the complete engine state *relative* to
    /// the current base address, plus the base's page-alignment
    /// residue and the delta-cycle phase — equal fingerprints mean the
    /// remaining simulation is an exact shifted replay (see
    /// `sim::closure`). O(1): every structure keeps an incremental
    /// signature.
    fn pass_digest(&self, base: i64, phase: usize, last_stream_line: u64) -> u128 {
        let base_bytes = (base as u64) * 8;
        let base_line = base_bytes / LINE;
        let page = self.tlb.page_size();
        let base_vpn = base_bytes >> page.shift();
        let rel = |v: u64, b: u64| {
            if v == u64::MAX {
                u64::MAX
            } else {
                v.wrapping_sub(b)
            }
        };
        let mut out = [0u64; 2];
        for (slot, seed) in [closure::SEED_A, closure::SEED_B].into_iter().enumerate()
        {
            let mut h = seed;
            h = closure::fold(h, self.l1.state_digest(base_line, seed));
            h = closure::fold(h, self.l2.state_digest(base_line, seed));
            h = closure::fold(h, self.l3.state_digest(base_line, seed));
            h = closure::fold(h, self.tlb.state_digest(base_vpn, seed));
            for pf in &self.prefetchers {
                h = closure::fold(h, pf.state_digest(base_bytes, seed));
            }
            // The topology digest folds every node's banked DRAM state
            // (which embeds the base's span residue — closure can only
            // match at bank-assignment-preserving shifts, `sim::dram`)
            // plus the placement-visible residues (`sim::topology`).
            h = closure::fold(h, self.topo.state_digest(base_bytes, seed));
            h = closure::fold(h, rel(last_stream_line, base_line));
            h = closure::fold(h, base_bytes % page.bytes());
            h = closure::fold(h, phase as u64);
            out[slot] = h;
        }
        ((out[0] as u128) << 64) | out[1] as u128
    }

    /// Shift the whole engine state forward by `shift_elems` elements
    /// — the loop-closure fast-forward. Exact because the shift is a
    /// multiple of the page size and of the DRAM bank span
    /// (fingerprints embed both residues), which every
    /// alignment-sensitive mechanism divides.
    fn fast_forward(&mut self, shift_elems: u64) {
        let bytes = shift_elems * 8;
        if bytes == 0 {
            return;
        }
        let lines = bytes / LINE;
        self.l1.relocate(lines);
        self.l2.relocate(lines);
        self.l3.relocate(lines);
        self.tlb.relocate(bytes >> self.tlb.page_size().shift());
        for pf in &mut self.prefetchers {
            pf.relocate(bytes);
        }
        self.topo.relocate(bytes);
    }

    #[inline]
    fn access(
        &mut self,
        va: VirtualAddress,
        is_write: bool,
        streaming: bool,
        sid: usize,
        last_stream_line: &mut u64,
        c: &mut SimCounters,
    ) {
        c.accesses += 1;

        // Translate first: everything below the TLB deals only in
        // physical addresses (the mapping is identity, so the line
        // id is unchanged — see sim::memory).
        let t = self.tlb.translate(va, is_write, &mut c.tlb);
        let pa = t.physical;
        let line = pa.line();

        // Overlap the host-memory misses of the three dependent set
        // scans (§Perf).
        self.l1.prefetch_host(line);
        self.l2.prefetch_host(line);
        self.l3.prefetch_host(line);

        // Non-temporal stores bypass the hierarchy entirely (the
        // stride-1 scatter / STREAM-store path): one DRAM line write
        // per line, no RFO, no fill.
        if streaming {
            if let Probe::Hit { .. } = self.l1.access(line, is_write) {
                c.l1_hits += 1;
                return;
            }
            if line != *last_stream_line {
                c.streaming_store_lines += 1;
                self.note_row(pa, sid, c);
                *last_stream_line = line;
            }
            return;
        }

        // L1. (Plain probe first: hit paths dominate most patterns and
        // the probe loop is cheaper than a fused probe+victim scan —
        // §Perf iteration 4 measured the fused variant 33% slower on
        // cache-resident patterns for a ~3% miss-path gain.)
        if let Probe::Hit { .. } = self.l1.access(line, is_write) {
            c.l1_hits += 1;
            return;
        }
        // L2.
        match self.l2.access(line, is_write) {
            Probe::Hit { was_prefetched } => {
                c.l2_hits += 1;
                if was_prefetched {
                    c.prefetch_useful += 1;
                }
                self.fill_l1(line, is_write, c);
                return;
            }
            Probe::Miss => {}
        }
        // L3.
        match self.l3.access(line, is_write) {
            Probe::Hit { was_prefetched } => {
                c.l3_hits += 1;
                if was_prefetched {
                    c.prefetch_useful += 1;
                }
                self.fill_l2(line, is_write, c);
                self.fill_l1(line, is_write, c);
                return;
            }
            Probe::Miss => {}
        }

        // DRAM demand fill (write-allocate for scatter).
        c.dram_demand_lines += 1;
        self.note_row(pa, sid, c);
        if self.l3.fill_after_miss(line, false, false).is_some() {
            c.writeback_lines += 1;
        }
        self.fill_l2(line, is_write, c);
        self.fill_l1(line, is_write, c);

        // Prefetch on the DRAM demand miss — against the triggering
        // stream's own tracker. Presence is resolved by the fused fill
        // (L2 first — the streamer's target; L1 copies are covered by
        // inclusion through L2/L3). `pf_buf` is engine scratch filled
        // in place — disjoint field borrows, no move dance, no
        // allocation once warm (§Perf).
        self.prefetchers[sid].on_miss(pa.byte(), line, &mut self.pf_buf);
        let mut k = 0;
        while k < self.pf_buf.len() {
            let pl = self.pf_buf[k];
            k += 1;
            let (inserted_l2, ev) = self.l2.fill_if_absent(pl, false, true);
            if inserted_l2 {
                if let Some(ev) = ev {
                    if self.l3.fill(ev, true, false).is_some() {
                        c.writeback_lines += 1;
                    }
                }
                let (inserted_l3, _) = self.l3.fill_if_absent(pl, false, true);
                if inserted_l3 {
                    c.dram_prefetch_lines += 1;
                    self.note_row(PhysicalAddress::from_line(pl), sid, c);
                }
            }
        }
    }

    /// Fill L1 after an L1 miss, propagating a dirty eviction into L2
    /// (and onward).
    #[inline]
    fn fill_l1(&mut self, line: u64, is_write: bool, c: &mut SimCounters) {
        if let Some(ev) = self.l1.fill_after_miss(line, is_write, false) {
            // Dirty L1 victim updates L2; if L2 doesn't have it (rare
            // with inclusive fills), it cascades to L3.
            if !self.l2.contains(ev) {
                if self.l3.fill(ev, true, false).is_some() {
                    c.writeback_lines += 1;
                }
            } else {
                self.l2.fill(ev, true, false);
            }
        }
    }

    /// Fill L2 after an L2 miss, propagating a dirty eviction into L3.
    #[inline]
    fn fill_l2(&mut self, line: u64, is_write: bool, c: &mut SimCounters) {
        if let Some(ev) = self.l2.fill_after_miss(line, is_write, false) {
            if self.l3.fill(ev, true, false).is_some() {
                c.writeback_lines += 1;
            }
        }
    }

    /// Cross-thread write-contention events (pattern-level model).
    ///
    /// With the chunked OpenMP schedule, thread t's scatter bases start
    /// `delta * count/T` elements apart. When the index-buffer span
    /// exceeds that thread stride, thread footprints overlap and every
    /// write into the overlap is a coherence transaction. delta = 0
    /// (LULESH-S3) is total overlap: every write contends. GS contends
    /// through its scatter-side buffer exactly like Scatter does —
    /// only the write stream participates in ownership traffic.
    fn coherence_events(
        &self,
        pattern: &Pattern,
        kernel: Kernel,
        measured: usize,
    ) -> u64 {
        if !kernel.writes()
            || self.threads <= 1
            || self.platform.absorbs_repeated_writes
        {
            return 0;
        }
        let write_max = if kernel == Kernel::GS {
            pattern.max_scatter_index()
        } else {
            pattern.max_index()
        };
        let idx_span = (write_max + 1) as f64;
        let chunk = (pattern.count as f64 / self.threads as f64).max(1.0);
        let thread_stride = pattern.mean_delta() * chunk;
        let overlap = if thread_stride <= 0.0 {
            1.0
        } else {
            ((idx_span - thread_stride) / idx_span).clamp(0.0, 1.0)
        };
        (measured as f64 * pattern.vector_len() as f64 * overlap) as u64
    }

    /// Bottleneck timing over the measured counters.
    fn timing(&self, c: &SimCounters, kernel: Kernel, sparse_walks: bool) -> TimeBreakdown {
        let p = &self.platform;
        let t = self.threads as f64;
        let hz = p.freq_ghz * 1e9;

        // Issue cost per element under the run's vectorization regime
        // (paper §5.3, Fig 6). Scalar is the `#pragma novec` build;
        // MaskedSve keeps the vector loop structure (vector-depth miss
        // parallelism, no scalar-stream DRAM penalty) but still issues
        // one scalar element access per lane; EmulatedGather has only
        // the gather instruction, so scatters — and GS, where the
        // compiler can't vectorize half an indexed copy — fall back to
        // the full scalar path; HardwareGS uses both instructions. GS
        // issues one gather element + one scatter element per access
        // pair and the `accesses` counter counts both sides, so its
        // per-access cost is the mean of the two.
        let dense = matches!(kernel, Kernel::Stream(_));
        let (cpe, mlp, scalar_issue) = if self.regime == VectorRegime::Scalar {
            (p.scalar_cycles_per_elem, p.mlp_scalar, true)
        } else if dense {
            // Unit-stride SIMD loads/stores need no G/S instruction
            // and retire `simd_lanes` elements per issued op — dense
            // streams are the cheap side of every vector ISA.
            (p.scalar_cycles_per_elem / p.simd_lanes, p.mlp_vector, false)
        } else if kernel == Kernel::Gups {
            // GUPS is a scalar indexed RMW on every ISA (random 64-bit
            // addresses defeat vector index generation).
            (p.scalar_cycles_per_elem, p.mlp_scalar, true)
        } else if self.regime == VectorRegime::MaskedSve {
            (p.scalar_cycles_per_elem, p.mlp_vector, false)
        } else {
            let vector_cpe = match kernel {
                Kernel::Gather => p.gather_cycles_per_elem,
                Kernel::Scatter if self.regime == VectorRegime::HardwareGS => {
                    p.scatter_cycles_per_elem
                }
                Kernel::GS if self.regime == VectorRegime::HardwareGS => {
                    match (p.gather_cycles_per_elem, p.scatter_cycles_per_elem)
                    {
                        (Some(g), Some(s)) => Some(0.5 * (g + s)),
                        _ => None,
                    }
                }
                _ => None,
            };
            match vector_cpe {
                Some(cost) => (cost, p.mlp_vector, false),
                None => (p.scalar_cycles_per_elem, p.mlp_scalar, true),
            }
        };
        // Scalar-issued request streams put more pressure on the
        // memory system per byte (paper §5.3); the platform factor
        // scales effective DRAM bandwidth. BDW's factor is > 1: its
        // microcoded AVX2 gather is the worse requester.
        let dram_eff = if scalar_issue {
            p.scalar_dram_efficiency
        } else {
            1.0
        };

        let issue_s = c.accesses as f64 * cpe / hz / t;
        let l2_s = c.l2_hits as f64 * LINE as f64
            / (p.l2_gbs_per_thread * 1e9)
            / t;
        let l3_s = c.l3_hits as f64 * LINE as f64 / (p.l3_gbs * 1e9);
        // DRAM occupancy: line traffic + row-activation overhead +
        // page-walk traffic when the walk itself misses the caches
        // (sparse pages — each walk is another random DRAM access).
        let walks = if sparse_walks { c.tlb.misses() } else { 0 };
        // A cold radix walk touches its deep page-table levels uncached
        // (2 lines for a 4-level walk), each a random DRAM access with
        // a row miss.
        let walk_bytes = walks as f64
            * self.walker.uncached_lines_per_walk() as f64
            * (64.0 + ROW_PENALTY_BYTES);
        // Same-domain back-to-back activations additionally expose
        // tFAW/tRRD_L serialization (`sim::dram` conflict class).
        let mut dram_bytes = (c.dram_read_bytes() + c.dram_write_bytes()) as f64
            + c.row_activations as f64 * ROW_PENALTY_BYTES
            + c.dram_row_conflicts as f64 * p.dram.conflict_penalty_bytes
            + walk_bytes;
        // NUMA (multi-socket platforms only; `sim::topology`). Remote
        // accesses pay the interconnect's bandwidth share in equivalent
        // bytes, and the concentration factor models how unevenly the
        // traffic loads the per-node memory channels: `stream_gbs` is
        // the machine aggregate, so traffic spread evenly across all
        // nodes sees factor 1.0, while a first-touch-contended shared
        // footprint funnels through one node's channels (factor ~=
        // sockets — one socket's worth of bandwidth).
        let mut conc_factor = 1.0;
        let mut link_latency_s = 0.0;
        if p.numa.sockets > 1 {
            dram_bytes += c.numa_remote as f64 * p.numa.link_penalty_bytes;
            link_latency_s =
                c.numa_remote as f64 * p.numa.link_latency_ns * 1e-9 / mlp / t;
            let total = (c.numa_local + c.numa_remote) as f64;
            if total > 0.0 {
                let s = p.numa.sockets as f64;
                let (concentrated, spread) = match self.topo.placement() {
                    // Interleaved pages spread over every node.
                    NumaPlacement::Interleave => (0.0, s),
                    // Private first-touch chunks live with their owning
                    // threads (spread over the occupied sockets);
                    // contended shared pages all sit on one node.
                    NumaPlacement::FirstTouch => (
                        c.numa_contended as f64,
                        self.threads.min(p.numa.sockets) as f64,
                    ),
                };
                let node0_frac =
                    (concentrated + (total - concentrated) / spread) / total;
                conc_factor = s * node0_frac;
            }
        }
        let dram_s =
            dram_bytes / (p.stream_gbs * 1e9 * dram_eff) * conc_factor;
        let latency_s = c.dram_demand_lines as f64 * p.dram_latency_ns * 1e-9
            / mlp
            / t
            + link_latency_s;
        // Depth-dependent walk latency from the shared walker model
        // (walks overlap WALK_OVERLAP deep per thread).
        let tlb_s = c.tlb.misses() as f64 * self.walker.ns_per_miss() * 1e-9 / t;
        // Contended writes do not parallelize: each one invalidates the
        // line's copies in up to t-1 peer caches and the invalidations
        // serialize at the line's home, so the per-event cost grows
        // with the sharer count while the t threads' storms overlap at
        // most t-deep. Net (t-1)/t scaling — zero on one thread,
        // approaching a full coherence_ns per event as threads grow:
        // the thread-scaling collapse of delta-0 scatter (LULESH-S3).
        let coherence_s =
            c.coherence_events as f64 * p.coherence_ns * 1e-9 * (t - 1.0) / t;

        TimeBreakdown {
            issue_s,
            l2_s,
            l3_s,
            dram_s,
            latency_s,
            tlb_s,
            coherence_s,
        }
    }
}

/// Streaming-store (non-temporal) eligibility: compilers/hardware use
/// NT stores when the scatter covers whole lines exactly once (the
/// STREAM-copy shape). Two conditions, estimated over up to 4096
/// iterations: (a) writes cover ~every byte of each touched line, and
/// (b) elements are not rewritten (temporal reuse wants the cache).
/// `write_indices` is the kernel's write-side buffer (`indices` for
/// Scatter, the scatter side for GS).
fn write_density(pattern: &Pattern, write_indices: &[i64]) -> f64 {
    let iters = pattern.count.min(4096);
    let mut elems: HashSet<i64> = HashSet::new();
    let mut lines: HashSet<i64> = HashSet::new();
    let mut writes = 0u64;
    for i in 0..iters {
        let base = pattern.base(i);
        for &idx in write_indices {
            let e = base + idx;
            elems.insert(e);
            lines.insert(e / 8);
            writes += 1;
        }
    }
    if lines.is_empty() {
        return 0.0;
    }
    let rewrite_ratio = writes as f64 / elems.len() as f64;
    if rewrite_ratio > 1.25 {
        return 0.0; // temporal reuse: keep writes in the cache
    }
    elems.len() as f64 / (lines.len() * 8) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms;

    fn uniform(stride: usize, count: usize) -> Pattern {
        Pattern::parse(&format!("UNIFORM:8:{stride}"))
            .unwrap()
            .with_delta(8 * stride as i64)
            .with_count(count)
    }

    const N: usize = 1 << 18;

    #[test]
    fn stride1_gather_approximates_stream() {
        // Fig 3 anchor: stride-1 gather == STREAM read bandwidth.
        for name in ["bdw", "skx", "clx", "naples", "tx2", "knl"] {
            let p = platforms::by_name(name).unwrap();
            let mut e = CpuEngine::new(&p);
            let r = e.run(&uniform(1, N), Kernel::Gather).unwrap();
            let bw = r.bandwidth_gbs();
            assert!(
                (bw / p.stream_gbs - 1.0).abs() < 0.25,
                "{name}: stride-1 {bw:.1} GB/s vs STREAM {:.1}",
                p.stream_gbs
            );
        }
    }

    #[test]
    fn bandwidth_halves_with_stride_doubling_small_strides() {
        // "as stride increases by a factor of 2, bandwidth should drop
        // by half" (until the line is exhausted at stride-8).
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        let bw1 = e.run(&uniform(1, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw2 = e.run(&uniform(2, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw4 = e.run(&uniform(4, N), Kernel::Gather).unwrap().bandwidth_gbs();
        assert!((bw1 / bw2 - 2.0).abs() < 0.35, "1->2 ratio {:.2}", bw1 / bw2);
        assert!((bw2 / bw4 - 2.0).abs() < 0.35, "2->4 ratio {:.2}", bw2 / bw4);
    }

    #[test]
    fn skx_floor_is_one_sixteenth() {
        // Fig 4: SKX always fetches two lines -> 1/16 of peak at
        // strides past the line size.
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        let bw1 = e.run(&uniform(1, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw32 = e.run(&uniform(32, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let frac = bw32 / bw1;
        assert!(
            (frac - 1.0 / 16.0).abs() < 0.02,
            "SKX stride-32 fraction {frac:.4} (want ~1/16)"
        );
    }

    #[test]
    fn bdw_recovers_at_stride_64() {
        // Fig 3: BDW increases at stride-64 (adjacent-line prefetch
        // shuts off at 512 B).
        let p = platforms::by_name("bdw").unwrap();
        let mut e = CpuEngine::new(&p);
        let bw32 = e.run(&uniform(32, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw64 = e.run(&uniform(64, N), Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(
            bw64 > bw32 * 1.5,
            "BDW should recover at stride-64: {bw32:.2} -> {bw64:.2}"
        );
    }

    #[test]
    fn bdw_without_prefetch_bottoms_at_stride8() {
        // Fig 4a: with prefetching off, no stride-64 bump — flat floor
        // from stride-8 onward (1 line per element).
        let p = platforms::by_name("bdw").unwrap();
        let opts = CpuSimOptions {
            prefetch_enabled: false,
            ..Default::default()
        };
        let mut e = CpuEngine::with_options(&p, opts);
        let bw8 = e.run(&uniform(8, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw64 = e.run(&uniform(64, N), Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(
            (bw8 / bw64 - 1.0).abs() < 0.25,
            "no-prefetch floor should be flat: {bw8:.2} vs {bw64:.2}"
        );
    }

    #[test]
    fn naples_flat_after_stride_8() {
        // Fig 3: Naples plateaus at 1/8 from stride-8 (useful-only
        // stride prefetcher).
        let p = platforms::by_name("naples").unwrap();
        let mut e = CpuEngine::new(&p);
        let bw1 = e.run(&uniform(1, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw8 = e.run(&uniform(8, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw32 = e.run(&uniform(32, N), Kernel::Gather).unwrap().bandwidth_gbs();
        assert!((bw8 / bw1 - 1.0 / 8.0).abs() < 0.03, "{:.3}", bw8 / bw1);
        assert!(
            (bw32 / bw8 - 1.0).abs() < 0.3,
            "Naples should be flat 8->32: {bw8:.2} vs {bw32:.2}"
        );
    }

    #[test]
    fn tx2_keeps_dropping() {
        // Fig 3: TX2 falls past 1/16 (degree-2 over-fetch).
        let p = platforms::by_name("tx2").unwrap();
        let mut e = CpuEngine::new(&p);
        let bw1 = e.run(&uniform(1, N), Kernel::Gather).unwrap().bandwidth_gbs();
        let bw64 = e.run(&uniform(64, N), Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(
            bw64 / bw1 < 1.0 / 16.0,
            "TX2 should drop below 1/16: {:.4}",
            bw64 / bw1
        );
    }

    #[test]
    fn cached_pattern_beats_stream() {
        // §5.4: AMG-like delta-1 patterns exceed STREAM via caching.
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        let amg = crate::pattern::table5::by_name("AMG-G0")
            .unwrap()
            .to_pattern(N);
        let bw = e.run(&amg, Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(
            bw > p.stream_gbs,
            "cached AMG pattern should beat STREAM: {bw:.1} vs {:.1}",
            p.stream_gbs
        );
    }

    #[test]
    fn huge_delta_tanks_bandwidth() {
        // §5.4.2 item 5: delta is a primary performance indicator.
        let p = platforms::by_name("bdw").unwrap();
        let mut e = CpuEngine::new(&p);
        let g4 = crate::pattern::table5::by_name("PENNANT-G4")
            .unwrap()
            .to_pattern(N); // delta 4
        // Count large enough that the touched-line footprint exceeds
        // the caches (at tiny counts the second run would legitimately
        // find everything in L3 — min-of-10 semantics).
        let g9 = crate::pattern::table5::by_name("PENNANT-G9")
            .unwrap()
            .to_pattern(1 << 21); // delta 388852
        let bw_small = e.run(&g4, Kernel::Gather).unwrap().bandwidth_gbs();
        let bw_large = e.run(&g9, Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(
            bw_small > 5.0 * bw_large,
            "large delta should tank: {bw_small:.1} vs {bw_large:.1}"
        );
    }

    #[test]
    fn delta0_scatter_collapses_except_tx2() {
        // LULESH-S3: delta-0 scatter triggers coherence storms on all
        // CPUs except TX2 (§5.4.2 item 1).
        let s3 = crate::pattern::table5::by_name("LULESH-S3")
            .unwrap()
            .to_pattern(1 << 16);
        let skx = platforms::by_name("skx").unwrap();
        let tx2 = platforms::by_name("tx2").unwrap();
        let bw_skx = CpuEngine::new(&skx)
            .run(&s3, Kernel::Scatter)
            .unwrap()
            .bandwidth_gbs();
        let bw_tx2 = CpuEngine::new(&tx2)
            .run(&s3, Kernel::Scatter)
            .unwrap()
            .bandwidth_gbs();
        assert!(
            bw_skx < 0.3 * skx.stream_gbs,
            "SKX S3 should collapse: {bw_skx:.1}"
        );
        assert!(
            bw_tx2 > 0.8 * tx2.stream_gbs,
            "TX2 should absorb S3: {bw_tx2:.1} vs stream {:.1}",
            tx2.stream_gbs
        );
    }

    #[test]
    fn stride1_scatter_uses_streaming_stores() {
        // Full-line writes go non-temporal: scatter stride-1 should be
        // near peak, not half (no RFO).
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        let r = e.run(&uniform(1, N), Kernel::Scatter).unwrap();
        assert!(r.counters.streaming_store_lines > 0);
        assert_eq!(r.counters.dram_demand_lines, 0);
        let bw = r.bandwidth_gbs();
        assert!(
            bw > 0.7 * p.stream_gbs,
            "streaming scatter {bw:.1} vs {:.1}",
            p.stream_gbs
        );
    }

    #[test]
    fn strided_scatter_pays_rfo() {
        // Partial-line scatter must read-for-ownership: DRAM traffic
        // roughly doubles vs the equivalent gather.
        let p = platforms::by_name("naples").unwrap();
        let mut e = CpuEngine::new(&p);
        let g = e.run(&uniform(8, N), Kernel::Gather).unwrap();
        let s = e.run(&uniform(8, N), Kernel::Scatter).unwrap();
        let gt = g.counters.dram_read_bytes() + g.counters.dram_write_bytes();
        let st = s.counters.dram_read_bytes() + s.counters.dram_write_bytes();
        let ratio = st as f64 / gt as f64;
        assert!(
            (1.4..=2.4).contains(&ratio),
            "scatter/gather DRAM traffic ratio {ratio:.2} (RFO + writeback \
             roughly doubles write traffic vs read-only gather)"
        );
    }

    #[test]
    fn scalar_backend_slower_on_simd_platforms() {
        // Fig 6 direction: KNL vectorized >> scalar at small strides.
        let p = platforms::by_name("knl").unwrap();
        let mut vec_e = CpuEngine::new(&p);
        let mut sca_e = CpuEngine::with_options(
            &p,
            CpuSimOptions {
                regime: Some(VectorRegime::Scalar),
                ..Default::default()
            },
        );
        let pat = uniform(1, N);
        let bv = vec_e.run(&pat, Kernel::Gather).unwrap().bandwidth_gbs();
        let bs = sca_e.run(&pat, Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(bv > 1.3 * bs, "KNL vector {bv:.1} vs scalar {bs:.1}");
    }

    #[test]
    fn bdw_gather_can_lose_to_scalar() {
        // Fig 6: BDW's microcoded AVX2 gather is often worse.
        let p = platforms::by_name("bdw").unwrap();
        let pat = {
            // cache-resident so the issue rate binds
            crate::pattern::table5::by_name("AMG-G0").unwrap().to_pattern(N)
        };
        let bv = CpuEngine::new(&p).run(&pat, Kernel::Gather).unwrap().bandwidth_gbs();
        let bs = CpuEngine::with_options(
            &p,
            CpuSimOptions {
                regime: Some(VectorRegime::Scalar),
                ..Default::default()
            },
        )
        .run(&pat, Kernel::Gather)
        .unwrap()
        .bandwidth_gbs();
        assert!(bs > bv, "BDW scalar {bs:.1} should beat gather {bv:.1}");
    }

    #[test]
    fn tx2_vector_equals_scalar() {
        // No G/S instructions: the OpenMP backend compiles to scalar.
        let p = platforms::by_name("tx2").unwrap();
        let pat = uniform(4, N);
        let bv = CpuEngine::new(&p).run(&pat, Kernel::Gather).unwrap().bandwidth_gbs();
        let bs = CpuEngine::with_options(
            &p,
            CpuSimOptions {
                regime: Some(VectorRegime::Scalar),
                ..Default::default()
            },
        )
        .run(&pat, Kernel::Gather)
        .unwrap()
        .bandwidth_gbs();
        assert!(
            (bv / bs - 1.0).abs() < 1e-9,
            "TX2 vector {bv:.2} == scalar {bs:.2}"
        );
    }

    #[test]
    fn extrapolation_is_linear() {
        // Doubling count beyond the cap should double time, keeping
        // bandwidth fixed.
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        let r1 = e.run(&uniform(4, 1 << 19), Kernel::Gather).unwrap();
        let r2 = e.run(&uniform(4, 1 << 20), Kernel::Gather).unwrap();
        assert!((r2.seconds / r1.seconds - 2.0).abs() < 0.1);
        assert!((r2.bandwidth_gbs() / r1.bandwidth_gbs() - 1.0).abs() < 0.05);
    }

    #[test]
    fn counters_are_consistent() {
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        let r = e.run(&uniform(2, 1 << 16), Kernel::Gather).unwrap();
        let c = &r.counters;
        assert_eq!(
            c.accesses,
            c.l1_hits + c.l2_hits + c.l3_hits + c.dram_demand_lines,
            "every access must resolve somewhere"
        );
        assert_eq!(c.tlb.accesses(), c.accesses, "one translation per access");
        assert!(c.tlb.misses() <= c.accesses);
    }

    #[test]
    fn large_pages_cut_huge_delta_tlb_misses() {
        // The PENNANT mechanism end-to-end: a gather advancing 128 KiB
        // per iteration touches a fresh 4 KiB page per access but
        // shares 2 MiB pages across iterations, so the miss rate must
        // collapse (and bandwidth must not get worse).
        let p = platforms::by_name("knl").unwrap();
        let idx: Vec<i64> = (0..16).map(|j| j * 512).collect();
        let pat = crate::pattern::Pattern::from_indices("huge-delta", idx)
            .with_delta(16384)
            .with_count(1 << 15);
        let run = |page: PageSize| {
            let mut e = CpuEngine::with_options(
                &p,
                CpuSimOptions {
                    page_size: page,
                    ..Default::default()
                },
            );
            e.run(&pat, Kernel::Gather).unwrap()
        };
        let r4k = run(PageSize::FourKB);
        let r2m = run(PageSize::TwoMB);
        let m4k = r4k.counters.tlb.miss_rate().unwrap();
        let m2m = r2m.counters.tlb.miss_rate().unwrap();
        assert!(
            m2m < 0.25 * m4k,
            "2MB miss rate {m2m:.4} should collapse vs 4KB {m4k:.4}"
        );
        assert!(
            r2m.bandwidth_gbs() > r4k.bandwidth_gbs(),
            "2MB {:.1} GB/s should beat 4KB {:.1} GB/s",
            r2m.bandwidth_gbs(),
            r4k.bandwidth_gbs()
        );
        // On KNL this flips the binding resource: translation-bound at
        // 4 KiB, DRAM-bound at 2 MiB.
        assert_eq!(r4k.breakdown.bottleneck(), "tlb");
        assert_eq!(r2m.breakdown.bottleneck(), "dram-bw");
    }

    #[test]
    fn set_threads_overrides_and_restores() {
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        assert_eq!(e.threads(), 16);
        e.set_threads(Some(4));
        assert_eq!(e.threads(), 4);
        e.set_threads(None);
        assert_eq!(e.threads(), 16);
        // A configured default survives the restore path.
        let mut e = CpuEngine::with_options(
            &p,
            CpuSimOptions {
                threads: Some(2),
                ..Default::default()
            },
        );
        assert_eq!(e.threads(), 2);
        e.set_threads(Some(8));
        e.set_threads(None);
        assert_eq!(e.threads(), 2);
        // Zero clamps to one.
        e.set_threads(Some(0));
        assert_eq!(e.threads(), 1);
    }

    #[test]
    fn default_threads_match_platform_numerics() {
        // threads: None must be numerically identical to the seed
        // behaviour (platform.threads).
        let p = platforms::by_name("bdw").unwrap();
        let pat = uniform(4, 1 << 16);
        let a = CpuEngine::new(&p).run(&pat, Kernel::Gather).unwrap();
        let mut e = CpuEngine::with_options(
            &p,
            CpuSimOptions {
                threads: Some(p.threads),
                ..Default::default()
            },
        );
        let b = e.run(&pat, Kernel::Gather).unwrap();
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.seconds, b.seconds);
    }

    #[test]
    fn stream_gather_scales_to_a_knee() {
        // The §3.1 thread-scaling axis: stride-1 gather rises with
        // threads until DRAM saturates, then stays flat at STREAM.
        let p = platforms::by_name("skx").unwrap();
        let pat = uniform(1, N);
        let bw = |t: usize| {
            let mut e = CpuEngine::with_options(
                &p,
                CpuSimOptions {
                    threads: Some(t),
                    ..Default::default()
                },
            );
            e.run(&pat, Kernel::Gather).unwrap().bandwidth_gbs()
        };
        let curve: Vec<f64> = [1, 2, 4, 8, 16].iter().map(|&t| bw(t)).collect();
        for w in curve.windows(2) {
            assert!(w[1] >= w[0] * 0.99, "monotone to the knee: {curve:?}");
        }
        assert!(
            curve[4] > 1.5 * curve[0],
            "one thread must not saturate DRAM: {curve:?}"
        );
        assert!(
            (curve[4] / p.stream_gbs - 1.0).abs() < 0.25,
            "saturated bandwidth ~STREAM: {:.1}",
            curve[4]
        );
    }

    #[test]
    fn delta0_scatter_contention_grows_with_threads() {
        // LULESH-S3 thread scaling: coherence cost grows with the
        // sharer count, so bandwidth *drops* as threads are added —
        // except on TX2, which absorbs repeated writes.
        let s3 = crate::pattern::table5::by_name("LULESH-S3")
            .unwrap()
            .to_pattern(1 << 16);
        let bw = |name: &str, t: usize| {
            let p = platforms::by_name(name).unwrap();
            let mut e = CpuEngine::with_options(
                &p,
                CpuSimOptions {
                    threads: Some(t),
                    ..Default::default()
                },
            );
            e.run(&s3, Kernel::Scatter).unwrap().bandwidth_gbs()
        };
        let skx1 = bw("skx", 1);
        let skx2 = bw("skx", 2);
        let skx16 = bw("skx", 16);
        assert!(skx2 < 0.5 * skx1, "contention kicks in: {skx1:.2} -> {skx2:.2}");
        assert!(skx16 < skx2, "and keeps growing: {skx2:.3} -> {skx16:.3}");
        // TX2 absorbs repeated writes: more threads only help.
        let tx1 = bw("tx2", 1);
        let tx28 = bw("tx2", 28);
        assert!(tx28 > tx1, "TX2 scales: {tx1:.1} -> {tx28:.1}");
    }

    #[test]
    fn coherence_cost_orders_by_thread_overlap() {
        // The (t-1)/t sharer scaling applies to every multi-thread
        // scatter with overlapping thread footprints, not only
        // delta-0: at a count small enough that the chunked schedule
        // overlaps (chunk < index span), bandwidth must order by
        // overlap — none (S1, delta 8) > partial (S2, delta 1) >
        // total (S3, delta 0).
        let p = platforms::by_name("skx").unwrap();
        let bw = |name: &str| {
            let pat = crate::pattern::table5::by_name(name)
                .unwrap()
                .to_pattern(1 << 12);
            CpuEngine::new(&p)
                .run(&pat, Kernel::Scatter)
                .unwrap()
                .bandwidth_gbs()
        };
        let s1 = bw("LULESH-S1");
        let s2 = bw("LULESH-S2");
        let s3 = bw("LULESH-S3");
        assert!(
            s1 > 2.0 * s2,
            "no-overlap should beat partial overlap: {s1:.2} vs {s2:.2}"
        );
        assert!(
            s2 > 1.5 * s3,
            "partial overlap should beat total overlap: {s2:.3} vs {s3:.3}"
        );
        assert!(s3 > 0.0 && s3.is_finite());
    }

    #[test]
    fn set_page_size_overrides_and_restores() {
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        assert_eq!(e.page_size(), PageSize::FourKB);
        e.set_page_size(Some(PageSize::TwoMB));
        assert_eq!(e.page_size(), PageSize::TwoMB);
        e.set_page_size(None);
        assert_eq!(e.page_size(), PageSize::FourKB);
    }

    #[test]
    fn set_vector_regime_overrides_and_restores() {
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        assert_eq!(e.vector_regime(), VectorRegime::HardwareGS);
        e.set_vector_regime(Some(VectorRegime::Scalar));
        assert_eq!(e.vector_regime(), VectorRegime::Scalar);
        e.set_vector_regime(None);
        assert_eq!(e.vector_regime(), VectorRegime::HardwareGS);
        // A configured default survives the restore path.
        let mut e = CpuEngine::with_options(
            &p,
            CpuSimOptions {
                regime: Some(VectorRegime::EmulatedGather),
                ..Default::default()
            },
        );
        assert_eq!(e.vector_regime(), VectorRegime::EmulatedGather);
        e.set_vector_regime(Some(VectorRegime::Scalar));
        e.set_vector_regime(None);
        assert_eq!(e.vector_regime(), VectorRegime::EmulatedGather);
    }

    #[test]
    fn unsupported_regime_is_a_config_error() {
        // TX2 has no G/S instructions: HardwareGS must be rejected at
        // run() time with the supported list in the message.
        let p = platforms::by_name("tx2").unwrap();
        let mut e = CpuEngine::with_options(
            &p,
            CpuSimOptions {
                regime: Some(VectorRegime::HardwareGS),
                ..Default::default()
            },
        );
        let err = e.run(&uniform(1, 1 << 12), Kernel::Gather).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("tx2"), "{msg}");
        assert!(msg.contains("hardware-gs"), "{msg}");
        assert!(msg.contains("masked-sve"), "{msg}");
        // BDW lacks scatter: HardwareGS is out, EmulatedGather is ok.
        let bdw = platforms::by_name("bdw").unwrap();
        let mut e = CpuEngine::with_options(
            &bdw,
            CpuSimOptions {
                regime: Some(VectorRegime::HardwareGS),
                ..Default::default()
            },
        );
        assert!(e.run(&uniform(1, 1 << 12), Kernel::Gather).is_err());
        e.set_vector_regime(Some(VectorRegime::EmulatedGather));
        assert!(e.run(&uniform(1, 1 << 12), Kernel::Gather).is_ok());
    }

    #[test]
    fn dense_issue_cost_scales_with_simd_lanes() {
        use crate::pattern::StreamOp;
        // The old model hardcoded 4 lanes for every ISA; the issue
        // cost of the dense STREAM inner loop must now differ across
        // ISA classes. Vary only the lane width on one platform (one
        // thread, where issue time is visible) and pin the 2x ratios.
        let pat = Pattern::dense(8, 1 << 16);
        let issue = |lanes: f64| {
            let mut p = platforms::by_name("skx").unwrap();
            p.simd_lanes = lanes;
            let mut e = CpuEngine::with_options(
                &p,
                CpuSimOptions {
                    threads: Some(1),
                    ..Default::default()
                },
            );
            e.run(&pat, Kernel::Stream(StreamOp::Triad))
                .unwrap()
                .breakdown
                .issue_s
        };
        let avx512 = issue(8.0);
        let avx2 = issue(4.0);
        let neon = issue(2.0);
        assert!((avx2 / avx512 - 2.0).abs() < 1e-9, "{avx2} vs {avx512}");
        assert!((neon / avx2 - 2.0).abs() < 1e-9, "{neon} vs {avx2}");
        // And the registry widths differ across the real ISA classes.
        assert_ne!(
            platforms::by_name("knl").unwrap().simd_lanes,
            platforms::by_name("bdw").unwrap().simd_lanes
        );
        assert_ne!(
            platforms::by_name("bdw").unwrap().simd_lanes,
            platforms::by_name("tx2").unwrap().simd_lanes
        );
    }

    #[test]
    fn masked_sve_is_numerically_scalar_on_tx2() {
        // TX2's masked-lane regime keeps the vector loop structure but
        // issues scalar element accesses; with mlp_vector == mlp_scalar
        // and unit DRAM efficiency it must land exactly on the scalar
        // build (Fig 6: TX2's flat 0% line).
        let p = platforms::by_name("tx2").unwrap();
        let pat = uniform(2, 1 << 16);
        let run = |r: VectorRegime| {
            let mut e = CpuEngine::with_options(
                &p,
                CpuSimOptions {
                    regime: Some(r),
                    ..Default::default()
                },
            );
            e.run(&pat, Kernel::Gather).unwrap()
        };
        let sve = run(VectorRegime::MaskedSve);
        let sca = run(VectorRegime::Scalar);
        assert_eq!(sve.counters, sca.counters);
        assert_eq!(sve.seconds, sca.seconds);
    }

    #[test]
    fn determinism() {
        let p = platforms::by_name("bdw").unwrap();
        let pat = uniform(16, 1 << 16);
        let a = CpuEngine::new(&p).run(&pat, Kernel::Gather).unwrap();
        let b = CpuEngine::new(&p).run(&pat, Kernel::Gather).unwrap();
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.seconds, b.seconds);
    }

    fn run_with_closure(
        p: &crate::platforms::CpuPlatform,
        pat: &Pattern,
        kernel: Kernel,
        closure: bool,
    ) -> SimResult {
        let mut e = CpuEngine::with_options(
            p,
            CpuSimOptions {
                closure_enabled: closure,
                ..Default::default()
            },
        );
        e.run(pat, kernel).unwrap()
    }

    #[test]
    fn closure_is_bit_identical_and_fires_on_delta0() {
        // LULESH-S3-style delta-0 scatter: the state cycles almost
        // immediately, so closure must fire early — and the counters,
        // timing, and bandwidth must be exactly those of the full run.
        let p = platforms::by_name("skx").unwrap();
        let s3 = crate::pattern::table5::by_name("LULESH-S3")
            .unwrap()
            .to_pattern(1 << 14);
        let on = run_with_closure(&p, &s3, Kernel::Scatter, true);
        let off = run_with_closure(&p, &s3, Kernel::Scatter, false);
        assert_eq!(on.counters, off.counters);
        assert_eq!(on.breakdown, off.breakdown);
        assert_eq!(on.seconds, off.seconds);
        assert_eq!(off.closed_at_iteration, None);
        let at = on.closed_at_iteration.expect("delta-0 must close");
        assert!(at < 64, "delta-0 should close within a few iterations: {at}");
    }

    #[test]
    fn closure_is_bit_identical_on_huge_delta() {
        // The PENNANT mechanism: 128 KiB advance per iteration drives
        // the TLB/caches into a short per-page cycle.
        let p = platforms::by_name("knl").unwrap();
        let idx: Vec<i64> = (0..16).map(|j| j * 512).collect();
        let pat = crate::pattern::Pattern::from_indices("huge-delta", idx)
            .with_delta(16384)
            .with_count(1 << 14);
        let on = run_with_closure(&p, &pat, Kernel::Gather, true);
        let off = run_with_closure(&p, &pat, Kernel::Gather, false);
        assert_eq!(on.counters, off.counters);
        assert_eq!(on.seconds, off.seconds);
        assert!(on.closed_at_iteration.is_some(), "huge delta must close");
    }

    #[test]
    fn closure_is_bit_identical_on_moving_strides() {
        // Uniform strides with and without streaming stores, cycling
        // delta lists, both kernels: closure may or may not fire, but
        // results must be exactly equal either way.
        let p = platforms::by_name("bdw").unwrap();
        for kernel in [Kernel::Gather, Kernel::Scatter] {
            for stride in [1usize, 8, 64] {
                let pat = uniform(stride, 1 << 14);
                let on = run_with_closure(&p, &pat, kernel, true);
                let off = run_with_closure(&p, &pat, kernel, false);
                assert_eq!(on.counters, off.counters, "stride {stride}");
                assert_eq!(on.seconds, off.seconds, "stride {stride}");
            }
        }
        let cycling = Pattern::from_indices("revisit", (0..8).collect())
            .with_deltas(&[0, 0, 0, 512])
            .with_count(1 << 13);
        let on = run_with_closure(&p, &cycling, Kernel::Gather, true);
        let off = run_with_closure(&p, &cycling, Kernel::Gather, false);
        assert_eq!(on.counters, off.counters);
        assert_eq!(on.seconds, off.seconds);
    }

    #[test]
    fn engine_reuse_matches_fresh_engine() {
        // The scratch buffers (pf_buf, idx_bytes) persist across runs;
        // a reused engine must produce exactly what a fresh one does.
        let p = platforms::by_name("skx").unwrap();
        let mut reused = CpuEngine::new(&p);
        reused
            .run(&uniform(4, 1 << 12), Kernel::Scatter)
            .unwrap();
        let warm = reused.run(&uniform(16, 1 << 13), Kernel::Gather).unwrap();
        let fresh = CpuEngine::new(&p)
            .run(&uniform(16, 1 << 13), Kernel::Gather)
            .unwrap();
        assert_eq!(warm.counters, fresh.counters);
        assert_eq!(warm.seconds, fresh.seconds);
    }

    /// Uniform-stride GS: gather side `UNIFORM:8:gstride`, scatter
    /// side `UNIFORM:8:sstride`, classic delta.
    fn gs_uniform(gstride: usize, sstride: usize, count: usize) -> Pattern {
        Pattern::parse(&format!("UNIFORM:8:{gstride}"))
            .unwrap()
            .with_gs_scatter((0..8).map(|j| j * sstride as i64).collect())
            .with_delta(8 * gstride.max(sstride) as i64)
            .with_count(count)
    }

    #[test]
    fn gs_runs_and_touches_both_streams() {
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        let pat = gs_uniform(8, 8, 1 << 14);
        let r = e.run(&pat, Kernel::GS).unwrap();
        let c = &r.counters;
        // Both streams translate and access: 2 accesses per element.
        assert_eq!(c.accesses as usize, 2 * 8 * r.simulated_iterations);
        assert_eq!(c.tlb.accesses(), c.accesses);
        // The write stream really writes (RFO/writeback or NT stores).
        assert!(c.writeback_lines + c.streaming_store_lines > 0);
        // And reads really read.
        assert!(c.dram_demand_lines > 0);
        assert!(r.bandwidth_gbs() > 0.0 && r.bandwidth_gbs().is_finite());
    }

    #[test]
    fn gs_bounded_by_component_kernels() {
        // The differential invariant at the engine level: an indexed
        // copy can't beat either of its halves run alone.
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        for (gs, ss) in [(1usize, 1usize), (8, 1), (1, 8), (8, 8)] {
            let pat = gs_uniform(gs, ss, 1 << 14);
            let g_only = Pattern::from_indices("g", pat.indices.clone())
                .with_delta(pat.delta)
                .with_count(pat.count);
            let s_only =
                Pattern::from_indices("s", pat.scatter_indices.clone())
                    .with_delta(pat.delta)
                    .with_count(pat.count);
            let bw_gs = e.run(&pat, Kernel::GS).unwrap().bandwidth_gbs();
            let bw_g = e.run(&g_only, Kernel::Gather).unwrap().bandwidth_gbs();
            let bw_s = e.run(&s_only, Kernel::Scatter).unwrap().bandwidth_gbs();
            assert!(
                bw_gs <= bw_g.min(bw_s) * 1.02,
                "GS {gs}/{ss}: {bw_gs:.2} vs gather {bw_g:.2} / scatter \
                 {bw_s:.2}"
            );
        }
    }

    #[test]
    fn gs_delta0_contends_like_scatter() {
        // Delta-0 GS hammers the same write lines from every thread:
        // the scatter-side coherence storm applies, so bandwidth must
        // degrade as threads are added (except TX2).
        let pat = Pattern::from_indices("gs-d0", (0..16).map(|j| j * 24).collect())
            .with_gs_scatter((0..16).map(|j| j * 24).collect())
            .with_delta(0)
            .with_count(1 << 14);
        let bw = |name: &str, t: usize| {
            let p = platforms::by_name(name).unwrap();
            let mut e = CpuEngine::with_options(
                &p,
                CpuSimOptions {
                    threads: Some(t),
                    ..Default::default()
                },
            );
            e.run(&pat, Kernel::GS).unwrap().bandwidth_gbs()
        };
        let t1 = bw("skx", 1);
        let t2 = bw("skx", 2);
        let t16 = bw("skx", 16);
        assert!(t2 < t1, "contention must kick in: {t1:.2} -> {t2:.2}");
        assert!(t16 < t2, "and keep growing: {t2:.3} -> {t16:.3}");
        let x1 = bw("tx2", 1);
        let x28 = bw("tx2", 28);
        assert!(x28 > x1, "TX2 absorbs repeated writes: {x1:.2} -> {x28:.2}");
    }

    #[test]
    fn gs_closure_is_bit_identical() {
        let p = platforms::by_name("skx").unwrap();
        for pat in [
            gs_uniform(1, 1, 1 << 13),
            gs_uniform(8, 1, 1 << 13),
            Pattern::from_indices("gs-d0", (0..8).collect())
                .with_gs_scatter((0..8).map(|j| j * 24).collect())
                .with_delta(0)
                .with_count(1 << 13),
        ] {
            let on = run_with_closure(&p, &pat, Kernel::GS, true);
            let off = run_with_closure(&p, &pat, Kernel::GS, false);
            assert_eq!(on.counters, off.counters, "{}", pat.spec);
            assert_eq!(on.seconds, off.seconds, "{}", pat.spec);
        }
    }

    #[test]
    fn stream_tetrad_lands_on_the_table3_anchor() {
        // The tentpole invariant: measured in-engine STREAM must land
        // on the Table-3 calibration anchor on every CPU — dense
        // streams are DRAM-bound, prefetch-covered, and NT-stored.
        use crate::pattern::StreamOp;
        for name in ["bdw", "skx", "clx", "naples", "tx2", "knl"] {
            let p = platforms::by_name(name).unwrap();
            let mut e = CpuEngine::new(&p);
            for op in StreamOp::ALL {
                let r = e
                    .run(&Pattern::dense(8, N), Kernel::Stream(*op))
                    .unwrap();
                let bw = r.bandwidth_gbs();
                assert!(
                    (bw / p.stream_gbs - 1.0).abs() < 0.25,
                    "{name}/{}: {bw:.1} GB/s vs STREAM {:.1}",
                    op.name(),
                    p.stream_gbs
                );
                assert_eq!(r.breakdown.bottleneck(), "dram-bw", "{name}/{}", op.name());
                // The write stream goes non-temporal (no RFO).
                assert!(r.counters.streaming_store_lines > 0);
            }
        }
    }

    #[test]
    fn stream_counts_every_operand_stream() {
        use crate::pattern::StreamOp;
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        let pat = Pattern::dense(8, 1 << 14);
        let copy = e.run(&pat, Kernel::Stream(StreamOp::Copy)).unwrap();
        let triad = e.run(&pat, Kernel::Stream(StreamOp::Triad)).unwrap();
        // STREAM convention: Copy 16 B/elem, Triad 24 B/elem.
        assert_eq!(copy.useful_bytes, 2 * pat.moved_bytes() as u64);
        assert_eq!(triad.useful_bytes, 3 * pat.moved_bytes() as u64);
        // Triad really issues three streams' accesses.
        assert_eq!(
            triad.counters.accesses as usize,
            3 * 8 * triad.simulated_iterations
        );
        assert_eq!(
            copy.counters.accesses as usize,
            2 * 8 * copy.simulated_iterations
        );
    }

    #[test]
    fn multi_stream_kernels_keep_per_stream_prefetch_coverage() {
        // Stride-detecting prefetchers (Naples, KNL) track each operand
        // stream separately: the interleaved 1 GiB-apart misses of a
        // Triad must not destroy stride confidence, so the read
        // streams stay prefetch-covered just like a lone dense stream.
        use crate::pattern::StreamOp;
        for name in ["naples", "knl"] {
            let p = platforms::by_name(name).unwrap();
            let mut e = CpuEngine::new(&p);
            let r = e
                .run(&Pattern::dense(8, 1 << 16), Kernel::Stream(StreamOp::Triad))
                .unwrap();
            assert!(
                r.counters.dram_prefetch_lines > 0,
                "{name}: Triad read streams must be prefetched"
            );
            assert!(
                r.counters.prefetch_useful > 0,
                "{name}: and the prefetches must be useful"
            );
        }
    }

    #[test]
    fn gups_is_the_tlb_dram_worst_case() {
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        let pat = Pattern::gups(1 << 26, 1 << 16);
        let r = e.run(&pat, Kernel::Gups).unwrap();
        let bw = r.bandwidth_gbs();
        assert!(
            bw < 0.1 * p.stream_gbs,
            "GUPS must collapse vs STREAM: {bw:.2} vs {:.1}",
            p.stream_gbs
        );
        // Random 64-bit addressing defeats the TLB almost completely.
        let hit = r.counters.tlb.hit_rate().unwrap();
        assert!(hit < 0.6, "GUPS TLB hit rate should collapse: {hit:.3}");
        // The RMW really writes: dirty lines drain as writebacks.
        assert!(r.counters.writeback_lines > 0);
        assert_eq!(r.counters.streaming_store_lines, 0);
        // And closure has nothing to close on an acyclic stream.
        assert_eq!(r.closed_at_iteration, None);
        // Short runs collapse too: the warm-up pass draws a disjoint
        // seeded stream, so even count <= warmup_iterations cannot
        // pre-cache the measured addresses.
        let short = e.run(&Pattern::gups(1 << 26, 1 << 12), Kernel::Gups).unwrap();
        assert!(
            short.bandwidth_gbs() < 0.1 * p.stream_gbs,
            "small-count GUPS must not be flattered by its own warm-up: \
             {:.2}",
            short.bandwidth_gbs()
        );
    }

    #[test]
    fn gups_is_seed_deterministic() {
        let p = platforms::by_name("bdw").unwrap();
        let pat = Pattern::gups(1 << 20, 1 << 12);
        let a = CpuEngine::new(&p).run(&pat, Kernel::Gups).unwrap();
        let b = CpuEngine::new(&p).run(&pat, Kernel::Gups).unwrap();
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.seconds, b.seconds);
        // A different table size draws a different address stream.
        let c = CpuEngine::new(&p)
            .run(&Pattern::gups(1 << 21, 1 << 12), Kernel::Gups)
            .unwrap();
        assert_ne!(a.counters, c.counters);
    }

    #[test]
    fn baseline_closure_is_bit_identical() {
        use crate::pattern::StreamOp;
        let p = platforms::by_name("skx").unwrap();
        for (pat, kernel) in [
            (Pattern::dense(8, 1 << 13), Kernel::Stream(StreamOp::Copy)),
            (Pattern::dense(8, 1 << 13), Kernel::Stream(StreamOp::Triad)),
            (Pattern::gups(1 << 18, 1 << 11), Kernel::Gups),
        ] {
            let on = run_with_closure(&p, &pat, kernel, true);
            let off = run_with_closure(&p, &pat, kernel, false);
            assert_eq!(on.counters, off.counters, "{}", pat.spec);
            assert_eq!(on.seconds, off.seconds, "{}", pat.spec);
        }
    }

    #[test]
    fn gs_rejects_malformed_buffers() {
        let p = platforms::by_name("skx").unwrap();
        let mut e = CpuEngine::new(&p);
        // Missing scatter side.
        let bare = uniform(1, 64);
        assert!(e.run(&bare, Kernel::GS).is_err());
        // Length mismatch.
        let bad = Pattern::from_indices("g", (0..8).collect())
            .with_gs_scatter((0..4).collect())
            .with_count(64);
        assert!(e.run(&bad, Kernel::GS).is_err());
        // Scatter side on a single-buffer kernel.
        let extra = Pattern::from_indices("g", (0..8).collect())
            .with_gs_scatter((0..8).collect())
            .with_count(64);
        assert!(e.run(&extra, Kernel::Gather).is_err());
    }
}
