//! Per-run access-plan compiler (§Perf): the batch-compiled front end
//! of both engines' hot loops.
//!
//! The scalar reference paths (`CpuEngine::access`, `GpuEngine::warp`)
//! re-decide per access what is invariant per run: kernel class,
//! stream id, read/write/streaming mode, and — for uniform patterns —
//! whole runs of accesses that provably land on the same cache line or
//! the same coalesced sector. An [`AccessPlan`] hoists all of that out
//! of the loop. It is built **once per `run()`** from
//! (pattern, kernel, options) and holds:
//!
//! * `offsets` — the pre-scaled byte offsets of every access of one
//!   iteration, in exact issue order (the generalization of the old
//!   `idx_bytes`/`idx2_bytes` scratch pair): primary stream(s) first,
//!   then the write side (GS scatter side / dense output stream).
//! * `segs` — one [`Segment`] per operand stream, carrying the
//!   per-access flags the scalar path recomputes (stream id, write,
//!   streaming). The engine dispatches each segment once into a
//!   monomorphized (const-generic) loop body, so the per-access
//!   branches disappear from the hot variants.
//! * `runs` — a run-length encoding of consecutive same-line offsets
//!   within each segment. When the iteration base is line-aligned
//!   (checked once per iteration), every member of a run hits the same
//!   cache line *and* the same page as its head access, and the
//!   intervening state provably cannot change: the repeats collapse to
//!   counted bulk updates ([`Cache::hit_repeat`] /
//!   [`Tlb::note_same_page_repeats`]) instead of N probe calls.
//!
//! The GPU analogue ([`GpuPlan`]) precomputes each warp's coalesced
//! (relative-sector, element-count) list: when the base is
//! sector-aligned, the per-warp dedupe + sort disappears entirely and
//! the engine replays the precomputed transactions against the shifted
//! base sector.
//!
//! Plans are an optimization, never an approximation: counters stay
//! bit-identical to the scalar reference on every platform / kernel /
//! page-size / threads combination (pinned by
//! `rust/tests/plan_equivalence.rs`), and `SPATTER_NO_PLAN=1`
//! force-disables them (sibling to `SPATTER_NO_CLOSURE` /
//! `SPATTER_NO_MEMO`) for A/B benchmarking and differential testing.
//!
//! # Same-line run validity
//!
//! Two offsets `a`, `b` with `a/64 == b/64` land on the same line for
//! base `B` iff `B % 64 == 0`: `B + a` and `B + b` then share
//! `(B + a) / 64` (wrapping arithmetic preserves this — a multiple of
//! 64 plus a multiple of 64 stays one modulo 2^64). A line never spans
//! a page, so same line implies same page and the TLB's `last_vpn`
//! short-circuit is guaranteed after the head access. The engines
//! check the alignment once per iteration and fall back to the scalar
//! per-offset walk (still monomorphized, still allocation-free) when
//! the base is misaligned. Fast-forward shifts from loop closure are
//! page-size multiples, so alignment is stable across a run.
//!
//! [`Cache::hit_repeat`]: super::cache::Cache::hit_repeat
//! [`Tlb::note_same_page_repeats`]: super::memory::Tlb::note_same_page_repeats

use crate::pattern::{Kernel, Pattern};

/// Cache-line bytes (the model is 64-byte everywhere).
const LINE: u64 = 64;

/// Warp width of the GPU engine (threads per coalescing window).
const WARP: usize = 32;

/// One same-line run: the head access's byte offset plus how many
/// immediately-following accesses of the segment land on the same line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunOp {
    /// Pre-scaled byte offset of the run's head access.
    pub off: u64,
    /// Accesses after the head that share its line (0 = singleton).
    pub extra: u32,
}

/// One operand stream of the compiled iteration: a contiguous slice of
/// `offsets` (and of `runs`) plus the per-access flags the scalar path
/// recomputes every call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub off_start: usize,
    pub off_end: usize,
    pub run_start: usize,
    pub run_end: usize,
    /// Operand stream id (open-row slot / prefetcher slot).
    pub sid: usize,
    /// Whether the segment's accesses write.
    pub write: bool,
    /// Whether the segment's writes are non-temporal (streaming).
    pub streaming: bool,
}

/// The CPU engine's compiled per-run access plan. Engine-owned scratch:
/// cleared and refilled in place once per run, never reallocated once
/// warm (see the scratch-buffer invariants in `sim`).
#[derive(Debug, Clone, Default)]
pub struct AccessPlan {
    pub offsets: Vec<u64>,
    pub runs: Vec<RunOp>,
    pub segs: Vec<Segment>,
}

impl AccessPlan {
    /// Compile the plan for one CPU run. The offset math mirrors the
    /// scalar pass exactly: primary stream(s) first (one `v`-wide
    /// chunk per read stream; the whole index buffer for Scatter),
    /// then the write side with its region base baked in.
    pub fn build_cpu(&mut self, pattern: &Pattern, kernel: Kernel, streaming: bool) {
        self.offsets.clear();
        self.runs.clear();
        self.segs.clear();
        debug_assert_ne!(kernel, Kernel::Gups, "GUPS never runs planned");

        let v = pattern.vector_len();
        let read_streams = kernel.read_streams();
        let primary_write = kernel == Kernel::Scatter;
        let primary_streaming = primary_write && streaming;

        match kernel {
            Kernel::Stream(_) => {
                let region = pattern.dense_region_bytes();
                for r in 0..read_streams as u64 {
                    self.offsets.extend(
                        pattern.indices.iter().map(|&i| r * region + i as u64 * 8),
                    );
                }
            }
            _ => self
                .offsets
                .extend(pattern.indices.iter().map(|&i| i as u64 * 8)),
        }
        let primary_len = self.offsets.len();
        match kernel {
            Kernel::GS => {
                let dst = pattern.gs_scatter_base() as u64 * 8;
                self.offsets.extend(
                    pattern.scatter_indices.iter().map(|&i| dst + i as u64 * 8),
                );
            }
            Kernel::Stream(_) => {
                let dst = read_streams as u64 * pattern.dense_region_bytes();
                self.offsets
                    .extend(pattern.indices.iter().map(|&i| dst + i as u64 * 8));
            }
            _ => {}
        }

        // Primary segments: one per v-wide chunk, exactly the chunks
        // the scalar pass enumerates.
        let mut start = 0;
        let mut sid = 0;
        while start < primary_len {
            let end = (start + v).min(primary_len);
            self.push_seg(start, end, sid, primary_write, primary_streaming);
            start = end;
            sid += 1;
        }
        // Write stream (GS scatter side / dense output stream).
        if self.offsets.len() > primary_len {
            let end = self.offsets.len();
            self.push_seg(primary_len, end, read_streams, true, streaming);
        }
    }

    /// Close a segment over `offsets[off_start..off_end]`, RLE-grouping
    /// consecutive offsets that share a 64-byte line.
    fn push_seg(
        &mut self,
        off_start: usize,
        off_end: usize,
        sid: usize,
        write: bool,
        streaming: bool,
    ) {
        let run_start = self.runs.len();
        let mut k = off_start;
        while k < off_end {
            let line = self.offsets[k] / LINE;
            let mut j = k + 1;
            while j < off_end && self.offsets[j] / LINE == line {
                j += 1;
            }
            self.runs.push(RunOp {
                off: self.offsets[k],
                extra: (j - k - 1) as u32,
            });
            k = j;
        }
        self.segs.push(Segment {
            off_start,
            off_end,
            run_start,
            run_end: self.runs.len(),
            sid,
            write,
            streaming,
        });
    }
}

/// One warp of the compiled GPU iteration: its slice of `offsets` (for
/// the misaligned fallback) and its precomputed coalesced sector list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WarpSpan {
    pub off_start: usize,
    pub off_end: usize,
    /// Slice of [`GpuPlan::sectors`]: the warp's unique relative
    /// sectors with element counts, sorted ascending.
    pub sec_start: usize,
    pub sec_end: usize,
    /// Operand stream id (open-row slot).
    pub sid: usize,
    /// Whether the warp's accesses write.
    pub write: bool,
}

/// The GPU engine's compiled per-run plan: every warp's offset slice
/// plus its coalesced (relative sector, element count) transactions.
/// Valid whenever the iteration base is sector-aligned — relative
/// sector ids then shift to absolute ones by adding the base sector,
/// preserving both the dedupe partition and the sort order.
#[derive(Debug, Clone, Default)]
pub struct GpuPlan {
    pub offsets: Vec<u64>,
    pub sectors: Vec<(u64, u32)>,
    pub warps: Vec<WarpSpan>,
}

impl GpuPlan {
    /// Compile the plan for one GPU run: the same offset layout as
    /// [`AccessPlan::build_cpu`], chunked into ≤32-element warps per
    /// operand stream, each with its coalesced sector list.
    pub fn build_gpu(&mut self, pattern: &Pattern, kernel: Kernel, sector_bytes: u64) {
        self.offsets.clear();
        self.sectors.clear();
        self.warps.clear();
        debug_assert_ne!(kernel, Kernel::Gups, "GUPS never runs planned");

        let v = pattern.vector_len();
        let read_streams = kernel.read_streams();
        let primary_write = kernel == Kernel::Scatter;

        match kernel {
            Kernel::Stream(_) => {
                let region = pattern.dense_region_bytes();
                for r in 0..read_streams as u64 {
                    self.offsets.extend(
                        pattern.indices.iter().map(|&i| r * region + i as u64 * 8),
                    );
                }
            }
            _ => self
                .offsets
                .extend(pattern.indices.iter().map(|&i| i as u64 * 8)),
        }
        let primary_len = self.offsets.len();
        match kernel {
            Kernel::GS => {
                let dst = pattern.gs_scatter_base() as u64 * 8;
                self.offsets.extend(
                    pattern.scatter_indices.iter().map(|&i| dst + i as u64 * 8),
                );
            }
            Kernel::Stream(_) => {
                let dst = read_streams as u64 * pattern.dense_region_bytes();
                self.offsets
                    .extend(pattern.indices.iter().map(|&i| dst + i as u64 * 8));
            }
            _ => {}
        }

        // Warps: each read stream is one v-wide chunk split into ≤32
        // element windows; then the write side re-coalesces the same
        // way — exactly the warps the scalar pass issues.
        let mut start = 0;
        let mut sid = 0;
        while start < primary_len {
            let chunk_end = (start + v).min(primary_len);
            self.push_warps(start, chunk_end, sid, primary_write, sector_bytes);
            start = chunk_end;
            sid += 1;
        }
        if self.offsets.len() > primary_len {
            let end = self.offsets.len();
            self.push_warps(primary_len, end, read_streams, true, sector_bytes);
        }
    }

    /// Split `offsets[chunk_start..chunk_end]` into warps and coalesce
    /// each into unique relative sectors with element counts. Sorted by
    /// sector id — sector ids are unique after the dedupe, so the sort
    /// order matches the scalar path's first-appearance-then-sort
    /// exactly.
    fn push_warps(
        &mut self,
        chunk_start: usize,
        chunk_end: usize,
        sid: usize,
        write: bool,
        sector_bytes: u64,
    ) {
        let mut j = chunk_start;
        while j < chunk_end {
            let hi = (j + WARP).min(chunk_end);
            let sec_start = self.sectors.len();
            for k in j..hi {
                let rel = self.offsets[k] / sector_bytes;
                match self.sectors[sec_start..].iter_mut().find(|(s, _)| *s == rel)
                {
                    Some((_, n)) => *n += 1,
                    None => self.sectors.push((rel, 1)),
                }
            }
            self.sectors[sec_start..].sort_unstable_by_key(|(s, _)| *s);
            self.warps.push(WarpSpan {
                off_start: j,
                off_end: hi,
                sec_start,
                sec_end: self.sectors.len(),
                sid,
                write,
            });
            j = hi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::StreamOp;

    fn ustride(stride: usize, v: usize) -> Pattern {
        Pattern::from_indices(
            "u",
            (0..v as i64).map(|i| i * stride as i64).collect(),
        )
        .with_delta((v * stride) as i64)
        .with_count(64)
    }

    #[test]
    fn stride1_coalesces_into_line_runs() {
        let mut plan = AccessPlan::default();
        plan.build_cpu(&ustride(1, 16), Kernel::Gather, false);
        // 16 8-byte elements = 2 lines of 8 elements each.
        assert_eq!(plan.segs.len(), 1);
        assert_eq!(plan.runs.len(), 2);
        assert_eq!(plan.runs[0], RunOp { off: 0, extra: 7 });
        assert_eq!(plan.runs[1], RunOp { off: 64, extra: 7 });
        let seg = plan.segs[0];
        assert_eq!((seg.off_start, seg.off_end), (0, 16));
        assert!(!seg.write && !seg.streaming);
        assert_eq!(seg.sid, 0);
    }

    #[test]
    fn stride8_has_no_runs_to_coalesce() {
        let mut plan = AccessPlan::default();
        plan.build_cpu(&ustride(8, 8), Kernel::Scatter, false);
        // One element per line: every run is a singleton.
        assert_eq!(plan.runs.len(), 8);
        assert!(plan.runs.iter().all(|r| r.extra == 0));
        assert!(plan.segs[0].write);
    }

    #[test]
    fn delta0_revisits_group_within_a_line() {
        // The LULESH-S3 shape: many elements share lines.
        let pat = Pattern::from_indices("d0", vec![0, 1, 2, 9, 10, 17])
            .with_delta(0)
            .with_count(16);
        let mut plan = AccessPlan::default();
        plan.build_cpu(&pat, Kernel::Scatter, false);
        // offsets 0,8,16 (line 0) | 72,80 (line 1) | 136 (line 2)
        assert_eq!(
            plan.runs,
            vec![
                RunOp { off: 0, extra: 2 },
                RunOp { off: 72, extra: 1 },
                RunOp { off: 136, extra: 0 },
            ]
        );
    }

    #[test]
    fn gs_gets_two_segments_with_correct_flags() {
        let pat = ustride(2, 8).with_gs_scatter((0..8).collect());
        let mut plan = AccessPlan::default();
        plan.build_cpu(&pat, Kernel::GS, true);
        assert_eq!(plan.segs.len(), 2);
        let (g, s) = (plan.segs[0], plan.segs[1]);
        assert!(!g.write && !g.streaming && g.sid == 0);
        assert!(s.write && s.streaming && s.sid == 1);
        // Scatter-side offsets carry the write-region base.
        let dst = pat.gs_scatter_base() as u64 * 8;
        assert_eq!(plan.offsets[s.off_start], dst);
    }

    #[test]
    fn triad_gets_three_streams() {
        let pat = Pattern::dense(8, 64);
        let mut plan = AccessPlan::default();
        plan.build_cpu(&pat, Kernel::Stream(StreamOp::Triad), true);
        assert_eq!(plan.segs.len(), 3);
        assert_eq!(
            plan.segs.iter().map(|s| s.sid).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert!(plan.segs[2].write && plan.segs[2].streaming);
        // Each stream lives in its own region: distinct head offsets.
        let heads: Vec<u64> =
            plan.segs.iter().map(|s| plan.offsets[s.off_start]).collect();
        assert!(heads[0] < heads[1] && heads[1] < heads[2]);
    }

    #[test]
    fn rebuild_reuses_buffers() {
        let mut plan = AccessPlan::default();
        plan.build_cpu(&ustride(1, 16), Kernel::Gather, false);
        let n = plan.offsets.len();
        plan.build_cpu(&ustride(1, 16), Kernel::Gather, false);
        assert_eq!(plan.offsets.len(), n);
        assert_eq!(plan.segs.len(), 1);
    }

    #[test]
    fn gpu_warp_dedupe_matches_scalar_coalescing() {
        // 64 elements hitting 4 distinct 32 B sectors (broadcast-ish).
        let idx: Vec<i64> = (0..64).map(|j| (j / 16) * 4).collect();
        let pat = Pattern::from_indices("bcast", idx)
            .with_delta(16)
            .with_count(8);
        let mut plan = GpuPlan::default();
        plan.build_gpu(&pat, Kernel::Gather, 32);
        assert_eq!(plan.warps.len(), 2);
        for w in &plan.warps {
            let secs = &plan.sectors[w.sec_start..w.sec_end];
            // Each warp covers 2 sectors x 16 elements.
            assert_eq!(secs.iter().map(|&(_, n)| n).sum::<u32>(), 32);
            assert!(secs.windows(2).all(|p| p[0].0 < p[1].0), "sorted unique");
        }
    }

    #[test]
    fn gpu_write_side_warps_follow_read_side() {
        let pat = ustride(1, 40).with_gs_scatter((0..40).collect());
        let mut plan = GpuPlan::default();
        plan.build_gpu(&pat, Kernel::GS, 32);
        // 40 gather elements = 2 warps (32 + 8), then 2 scatter warps.
        assert_eq!(plan.warps.len(), 4);
        assert!(!plan.warps[0].write && plan.warps[0].sid == 0);
        assert!(plan.warps[2].write && plan.warps[2].sid == 1);
        assert_eq!(plan.warps[1].off_end - plan.warps[1].off_start, 8);
    }
}
