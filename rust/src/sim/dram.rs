//! Banked DRAM row-buffer model (ISSUE 7 tentpole).
//!
//! Replaces the scalar open-row-per-operand-stream registers with a
//! DDR4-style bank state machine shared by both engines: `channels ×
//! ranks × bank groups × banks` of [`BankState`], each holding one open
//! row. Every DRAM-facing access (demand fill, prefetch fill,
//! streaming store) is classified against the bank array:
//!
//! - **row hit** — the access's row is already open in its bank; the
//!   column read rides the row buffer at full burst rate (tCAS only,
//!   already covered by the per-line transfer cost).
//! - **row miss** — the bank holds a different row (or none), but the
//!   previous activation landed in a *different* serialization domain
//!   (channel × bank group), so the precharge + activate overlaps with
//!   in-flight traffic. Charged the existing row-activation penalty.
//! - **row conflict** — the bank must open a new row *and* the
//!   immediately preceding activation used the same channel + bank
//!   group, so tRRD_L/tFAW-class serialization exposes the full
//!   precharge + activate latency. Charged the activation penalty plus
//!   the platform's `conflict_penalty_bytes`.
//!
//! Power-of-two strides whose row stride is a multiple of the bank
//! count alias every access onto one bank (conflict per access);
//! odd strides rotate through banks and channels (near-zero
//! conflicts) — the bank-conflict collapse the `--suite dram` sweep
//! measures.
//!
//! # Timing
//!
//! Bank timing is expressed in DDR4-2400 memory-clock cycles and
//! converted to the simulator's byte-equivalent cost model (the
//! engines account time as bytes moved at peak bandwidth):
//!
//! - `tRCD` ≈ [`T_RCD_CYCLES`] and `tRP` ≈ [`T_RP_CYCLES`]: one
//!   activate + precharge pair costs roughly a cache line of transfer
//!   time at burst rate — the engines' existing per-activation
//!   `ROW_PENALTY_BYTES` (64 B).
//! - `tCAS` ≈ [`T_CAS_CYCLES`]: column access overlaps the burst and
//!   is covered by the per-line transfer cost.
//! - `tFAW`/`tRRD_L` ≈ [`T_FAW_CYCLES`]: back-to-back activations in
//!   the same channel + bank group cannot overlap; the exposed extra
//!   latency is the per-platform `conflict_penalty_bytes`
//!   (≈ half a line on CPUs, less on HBM/GDDR parts with more
//!   channel-level parallelism).
//!
//! # Closure compatibility
//!
//! The model participates in steady-state loop closure exactly like
//! `Tlb` and `Prefetcher` (`sim/closure.rs`): [`DramModel::state_digest`]
//! folds every bank's open row *relative* to the base row plus the
//! base's span residue, and [`DramModel::relocate`] shifts the whole
//! array forward. Because the digest embeds `base % span_bytes`
//! (span = total banks × row bytes), two states can only match when
//! their bases differ by a whole number of spans — precisely the
//! shifts under which bank assignment and serialization domains are
//! preserved, so fast-forwarded cycles stay bit-identical.

use super::SimCounters;

/// DDR4-2400 `tRCD` in memory-clock cycles (activate to column).
pub const T_RCD_CYCLES: u32 = 16;
/// DDR4-2400 `tRP` in memory-clock cycles (precharge).
pub const T_RP_CYCLES: u32 = 16;
/// DDR4-2400 `tCAS` in memory-clock cycles (column access strobe).
pub const T_CAS_CYCLES: u32 = 16;
/// DDR4-2400 `tFAW` in memory-clock cycles (four-activate window);
/// with `tRRD_L`, the source of the same-bank-group conflict penalty.
pub const T_FAW_CYCLES: u32 = 26;

/// Which address bits select the channel/bank, i.e. how consecutive
/// DRAM rows spread across the bank array. A per-platform knob
/// (`platforms::CpuPlatform::dram` / `GpuPlatform::dram`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InterleavePolicy {
    /// `row : bank : channel` — the channel bits are lowest, so
    /// consecutive rows rotate channels first, then banks. Sequential
    /// streams spread across every channel (fine-grained interleave,
    /// the default on all modelled platforms).
    RowBankChannel,
    /// `row : channel : bank` — the bank bits are lowest, so
    /// consecutive rows walk the banks of one channel before moving
    /// on. Coarse-grained interleave: sequential row streams pay
    /// same-bank-group serialization.
    RowChannelBank,
}

impl InterleavePolicy {
    pub const ALL: &'static [InterleavePolicy] = &[
        InterleavePolicy::RowBankChannel,
        InterleavePolicy::RowChannelBank,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            InterleavePolicy::RowBankChannel => "row:bank:channel",
            InterleavePolicy::RowChannelBank => "row:channel:bank",
        }
    }
}

/// Per-platform DRAM geometry + conflict cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DramConfig {
    pub channels: u64,
    pub ranks: u64,
    pub bank_groups: u64,
    /// Banks per bank group.
    pub banks: u64,
    pub interleave: InterleavePolicy,
    /// Extra byte-equivalent cost of a same-domain (channel × bank
    /// group) back-to-back activation — the exposed tFAW/tRRD_L
    /// serialization (see the module docs).
    pub conflict_penalty_bytes: f64,
}

impl DramConfig {
    /// Total addressable banks: `channels × ranks × bank groups ×
    /// banks`.
    pub fn total_banks(&self) -> u64 {
        self.channels * self.ranks * self.bank_groups * self.banks
    }
}

/// One bank's row buffer: the open row id, or [`u64::MAX`] when the
/// bank is precharged (closed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BankState {
    pub open_row: u64,
}

impl BankState {
    const CLOSED: BankState = BankState { open_row: u64::MAX };
}

/// Slot offset per operand stream: multi-operand kernels (GS, the
/// STREAM tetrad) allocate their regions 1 GiB apart, which is a
/// multiple of every modelled span — without a per-stream offset the
/// lockstep streams of a Triad would alias onto one bank and thrash.
/// Real allocators break this alignment via physical-page scrambling;
/// a small per-stream slot rotation models the same decorrelation.
const SID_SLOT_SALT: u64 = 21;

/// The banked DRAM state machine. One instance per engine; owned rows
/// are global row ids (byte address / row bytes), so the model is
/// exact under `relocate` shifts.
#[derive(Clone, Debug)]
pub struct DramModel {
    cfg: DramConfig,
    row_bytes: u64,
    banks: Vec<BankState>,
    /// Serialization domain (channel × bank group) of the most recent
    /// activation; `u64::MAX` = none yet.
    last_act_domain: u64,
}

impl DramModel {
    pub fn new(cfg: &DramConfig, row_bytes: u64) -> DramModel {
        debug_assert!(row_bytes.is_power_of_two());
        debug_assert!(cfg.total_banks() > 0);
        DramModel {
            cfg: *cfg,
            row_bytes,
            banks: vec![BankState::CLOSED; cfg.total_banks() as usize],
            last_act_domain: u64::MAX,
        }
    }

    pub fn config(&self) -> &DramConfig {
        &self.cfg
    }

    /// Bytes per DRAM row (row-buffer size).
    pub fn row_bytes(&self) -> u64 {
        self.row_bytes
    }

    /// The address period over which bank assignment repeats: total
    /// banks × row bytes. Closure shifts must be multiples of this.
    pub fn span_bytes(&self) -> u64 {
        self.cfg.total_banks() * self.row_bytes
    }

    pub fn reset(&mut self) {
        self.banks.fill(BankState::CLOSED);
        self.last_act_domain = u64::MAX;
    }

    /// Bank index for a global row accessed by operand stream `sid`.
    #[inline]
    fn slot(&self, row: u64, sid: usize) -> u64 {
        (row + sid as u64 * SID_SLOT_SALT) % self.cfg.total_banks()
    }

    /// Serialization domain (channel × bank group) of a bank slot
    /// under the configured interleave policy.
    #[inline]
    fn domain(&self, slot: u64) -> u64 {
        let c = &self.cfg;
        match c.interleave {
            InterleavePolicy::RowBankChannel => {
                // Channel lowest, then rank, bank group, bank.
                let channel = slot % c.channels;
                let group = slot / (c.channels * c.ranks) % c.bank_groups;
                channel * c.bank_groups + group
            }
            InterleavePolicy::RowChannelBank => {
                // Bank lowest, then bank group, rank, channel.
                let group = slot / c.banks % c.bank_groups;
                let channel =
                    slot / (c.banks * c.bank_groups * c.ranks) % c.channels;
                channel * c.bank_groups + group
            }
        }
    }

    /// Classify one DRAM-facing access (only translated, DRAM-bound
    /// addresses may reach the model): updates the bank array and the
    /// row hit/miss/conflict counters. Every miss or conflict is also
    /// a `row_activations` tick, preserving the engines' existing
    /// activation-penalty accounting.
    #[inline]
    pub fn access(&mut self, byte_addr: u64, sid: usize, c: &mut SimCounters) {
        let row = byte_addr / self.row_bytes;
        let slot = self.slot(row, sid);
        let bank = &mut self.banks[slot as usize];
        if bank.open_row == row {
            c.dram_row_hits += 1;
            return;
        }
        bank.open_row = row;
        c.row_activations += 1;
        let domain = self.domain(slot);
        if domain == self.last_act_domain {
            c.dram_row_conflicts += 1;
        } else {
            c.dram_row_misses += 1;
        }
        self.last_act_domain = domain;
    }

    /// Closure digest of the full bank array *relative* to the base
    /// address, plus the base's span residue (see the module docs:
    /// equal digests imply a span-aligned shift, under which slots and
    /// domains are preserved exactly).
    pub fn state_digest(&self, base_bytes: u64, seed: u64) -> u64 {
        use super::closure::fold;
        let base_row = base_bytes / self.row_bytes;
        let mut h = seed;
        for bank in &self.banks {
            let rel = if bank.open_row == u64::MAX {
                u64::MAX
            } else {
                bank.open_row.wrapping_sub(base_row)
            };
            h = fold(h, rel);
        }
        h = fold(h, base_bytes % self.span_bytes());
        h = fold(h, self.last_act_domain);
        h
    }

    /// Shift every open row forward by `delta_bytes` — the closure
    /// fast-forward. Exact because closure shifts are span multiples
    /// (the digest embeds the span residue), so each bank's future
    /// accesses land on the same slot with uniformly shifted rows.
    pub fn relocate(&mut self, delta_bytes: u64) {
        debug_assert_eq!(
            delta_bytes % self.span_bytes(),
            0,
            "closure shifts must preserve bank assignment"
        );
        let delta_rows = delta_bytes / self.row_bytes;
        for bank in &mut self.banks {
            if bank.open_row != u64::MAX {
                bank.open_row += delta_rows;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(interleave: InterleavePolicy) -> DramConfig {
        DramConfig {
            channels: 2,
            ranks: 1,
            bank_groups: 2,
            banks: 2,
            interleave,
            conflict_penalty_bytes: 32.0,
        }
    }

    fn counts(c: &SimCounters) -> (u64, u64, u64, u64) {
        (
            c.dram_row_hits,
            c.dram_row_misses,
            c.dram_row_conflicts,
            c.row_activations,
        )
    }

    #[test]
    fn hit_miss_conflict_classification() {
        let mut m = DramModel::new(&cfg(InterleavePolicy::RowBankChannel), 2048);
        let mut c = SimCounters::default();
        // First touch activates (miss: no prior activation domain).
        m.access(0, 0, &mut c);
        assert_eq!(counts(&c), (0, 1, 0, 1));
        // Same row again: row-buffer hit, no activation.
        m.access(64, 0, &mut c);
        assert_eq!(counts(&c), (1, 1, 0, 1));
        // Same bank (row + total_banks rows away), different row,
        // immediately after an activation in that same bank: conflict.
        let span = m.span_bytes();
        m.access(span, 0, &mut c);
        assert_eq!(counts(&c), (1, 1, 1, 2));
        // Activations always split exactly into misses + conflicts.
        assert_eq!(c.dram_row_misses + c.dram_row_conflicts, c.row_activations);
    }

    #[test]
    fn interleave_policy_changes_adjacent_row_domains() {
        // Adjacent rows: fine-grained interleave rotates channels
        // (miss), coarse-grained walks banks within one channel + bank
        // group (conflict).
        let mut fine =
            DramModel::new(&cfg(InterleavePolicy::RowBankChannel), 2048);
        let mut c = SimCounters::default();
        fine.access(0, 0, &mut c);
        fine.access(2048, 0, &mut c);
        assert_eq!(counts(&c), (0, 2, 0, 2));

        let mut coarse =
            DramModel::new(&cfg(InterleavePolicy::RowChannelBank), 2048);
        let mut c = SimCounters::default();
        coarse.access(0, 0, &mut c);
        coarse.access(2048, 0, &mut c);
        assert_eq!(counts(&c), (0, 1, 1, 2));
    }

    #[test]
    fn pow2_alias_conflicts_odd_stride_rotates() {
        // Row stride == total banks: every access lands in one bank,
        // each with a new row — conflict per access after the first.
        let m_cfg = cfg(InterleavePolicy::RowBankChannel);
        let total = m_cfg.total_banks();
        let mut m = DramModel::new(&m_cfg, 2048);
        let mut c = SimCounters::default();
        for i in 0..16u64 {
            m.access(i * total * 2048, 0, &mut c);
        }
        assert_eq!(c.dram_row_conflicts, 15);

        // Co-prime row stride: banks and channels rotate, so no two
        // consecutive activations share a domain.
        let mut m = DramModel::new(&m_cfg, 2048);
        let mut c = SimCounters::default();
        for i in 0..16u64 {
            m.access(i * (total + 1) * 2048, 0, &mut c);
        }
        assert_eq!(c.dram_row_conflicts, 0);
        assert_eq!(c.dram_row_misses, 16);
    }

    #[test]
    fn per_stream_salt_decorrelates_span_aligned_regions() {
        // Lockstep operand streams 1 GiB apart (a multiple of every
        // modelled span) must settle into distinct banks, exactly like
        // the old per-stream open-row registers.
        let mut m = DramModel::new(&cfg(InterleavePolicy::RowBankChannel), 2048);
        let mut c = SimCounters::default();
        for round in 0..4u64 {
            for sid in 0..3usize {
                m.access((sid as u64) << 30 | round * 64, sid, &mut c);
            }
        }
        // Three activations (one per stream), everything else hits.
        assert_eq!(c.row_activations, 3);
        assert_eq!(c.dram_row_hits, 9);
    }

    #[test]
    fn digest_and_relocate_model_a_shifted_replay() {
        // History at base 0 + relocate(span) must be indistinguishable
        // from the same history run pre-shifted by one span.
        let span = cfg(InterleavePolicy::RowChannelBank).total_banks() * 2048;
        let addrs = [0u64, 2048, 4096, 9 * 2048, 2048, 64];
        let mut a = DramModel::new(&cfg(InterleavePolicy::RowChannelBank), 2048);
        let mut b = DramModel::new(&cfg(InterleavePolicy::RowChannelBank), 2048);
        let (mut ca, mut cb) = (SimCounters::default(), SimCounters::default());
        for &addr in &addrs {
            a.access(addr, 0, &mut ca);
            b.access(addr + span, 0, &mut cb);
        }
        assert_eq!(counts(&ca), counts(&cb), "span shift preserves classes");
        a.relocate(span);
        for seed in [1u64, 0x9E37_79B1_85EB_CA87] {
            assert_eq!(
                a.state_digest(span, seed),
                b.state_digest(span, seed),
                "relocated state must digest-match the shifted replay"
            );
        }
        // Non-span-aligned bases must not match (span residue differs).
        assert_ne!(a.state_digest(span, 1), a.state_digest(span + 2048, 1));
    }
}
