//! NUMA socket topology: per-socket local DRAM, an interconnect link
//! model, and the page-placement policy that decides which socket a
//! translated page calls home.
//!
//! # The model
//!
//! A [`Topology`] holds one banked [`DramModel`] per socket (the PR-7
//! row-buffer machinery, instantiated per node) plus the placement
//! policy. The engine simulates the union access stream of all threads
//! through one representative hierarchy whose core sits on **socket
//! 0**; threads are distributed round-robin across sockets (thread `t`
//! runs on socket `t % sockets`), and every DRAM-touching access is
//! classified *local* or *remote* from the machine-wide mix that
//! round-robin distribution produces:
//!
//! * **`interleave`** — pages are placed round-robin by virtual page
//!   number (`vpn % sockets`), the OS `numactl --interleave` policy.
//!   An access is local iff its page's home node is socket 0, and it
//!   is routed to the home node's DRAM banks — traffic spreads across
//!   every node's channels.
//! * **`first-touch`** — the default OS policy: a page lives on the
//!   socket of the thread that touched it first. A *private* footprint
//!   (the pattern advances every iteration, so each thread's chunk is
//!   touched — and therefore placed — by its owner) is all-local. A
//!   *shared* footprint (a delta-0 pattern or the GUPS table, where
//!   every thread hammers the same pages) is **contended**: the pages
//!   all landed on one node, so machine-wide only `1/sockets` of the
//!   accesses are local and every node's traffic funnels through the
//!   home node's channels (the bandwidth concentration the timing
//!   model charges).
//!
//! Remote accesses pay the platform's interconnect link cost
//! ([`NumaConfig::link_latency_ns`] added to the latency bottleneck,
//! [`NumaConfig::link_penalty_bytes`] of equivalent DRAM traffic added
//! to the bandwidth bottleneck).
//!
//! Single-socket topologies are the identity: every access routes to
//! node 0 exactly as the flat PR-7 model did, no counters move, and
//! the timing terms are untouched — `tests/numa_differential.rs` pins
//! bit-exactness against the pre-NUMA behaviour on every platform.
//!
//! Loop-closure compatibility: [`Topology::state_digest`] folds every
//! node's DRAM digest plus the placement-visible residues (the
//! first-touch rotation phase and the base page's home-node phase), so
//! a detected cycle implies the classification sequence repeats too;
//! [`Topology::relocate`] shifts every node for the fast-forward path.
//! See `docs/ARCHITECTURE.md` for where this sits in the stack.

use super::closure;
use super::dram::{DramConfig, DramModel};
use super::SimCounters;
use crate::error::{Error, Result};

/// NUMA page-placement policy (the `--numa-placement` knob and the
/// `"numa-placement"` JSON config key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NumaPlacement {
    /// Pages live on the socket of the first-touching thread (OS
    /// default).
    FirstTouch,
    /// Pages round-robin across sockets by virtual page number
    /// (`numactl --interleave`).
    Interleave,
}

impl NumaPlacement {
    /// Every policy (for sweeps and property tests).
    pub const ALL: &'static [NumaPlacement] =
        &[NumaPlacement::FirstTouch, NumaPlacement::Interleave];

    /// Display name (also the CLI/JSON syntax).
    pub fn name(&self) -> &'static str {
        match self {
            NumaPlacement::FirstTouch => "first-touch",
            NumaPlacement::Interleave => "interleave",
        }
    }

    /// Parse the CLI/JSON syntax (case-insensitive).
    pub fn parse(s: &str) -> Result<NumaPlacement> {
        match s.to_ascii_lowercase().as_str() {
            "first-touch" | "firsttouch" | "ft" => {
                Ok(NumaPlacement::FirstTouch)
            }
            "interleave" | "il" => Ok(NumaPlacement::Interleave),
            _ => Err(Error::Config(format!(
                "unknown NUMA placement '{s}' (first-touch|interleave)"
            ))),
        }
    }
}

impl Default for NumaPlacement {
    /// The OS default policy.
    fn default() -> NumaPlacement {
        NumaPlacement::FirstTouch
    }
}

impl std::fmt::Display for NumaPlacement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-platform socket geometry and interconnect link cost
/// (`platforms` instantiates one per machine; single-socket parts use
/// [`NumaConfig::single`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NumaConfig {
    /// Socket count; 1 disables the whole subsystem.
    pub sockets: usize,
    /// Extra serialized latency of a remote (cross-socket) access, ns
    /// — the QPI/UPI/xGMI hop, charged on the latency bottleneck.
    pub link_latency_ns: f64,
    /// Bandwidth cost of a remote access in equivalent DRAM bytes —
    /// the link's share of the bandwidth bottleneck (protocol overhead
    /// plus the narrower cross-socket path).
    pub link_penalty_bytes: f64,
}

impl NumaConfig {
    /// A flat single-socket machine (no link, no remote accesses).
    pub const fn single() -> NumaConfig {
        NumaConfig {
            sockets: 1,
            link_latency_ns: 0.0,
            link_penalty_bytes: 0.0,
        }
    }
}

/// Engine-side NUMA state: one banked [`DramModel`] per socket plus
/// the placement policy and the per-run shared-footprint flag.
#[derive(Debug, Clone)]
pub struct Topology {
    cfg: NumaConfig,
    placement: NumaPlacement,
    /// log2(page bytes) — home nodes are assigned at page granularity,
    /// tracking the engine's translation page size.
    page_shift: u32,
    /// Whether the current run's footprint is shared by all threads
    /// (delta-0 patterns, the GUPS table). Decides the first-touch
    /// contended path; set once per run by the engine.
    shared: bool,
    /// Rotation phase of the first-touch contended classification:
    /// consecutive accesses to the shared footprint come from threads
    /// walking the sockets round-robin, so `rr % sockets == 0` marks
    /// the local ones. Only `rr % sockets` is semantically meaningful
    /// (the digest folds exactly that).
    rr: u64,
    nodes: Vec<DramModel>,
}

impl Topology {
    pub fn new(
        cfg: &NumaConfig,
        dram: &DramConfig,
        row_bytes: u64,
        placement: NumaPlacement,
        page_shift: u32,
    ) -> Topology {
        assert!(cfg.sockets >= 1, "a machine has at least one socket");
        Topology {
            cfg: *cfg,
            placement,
            page_shift,
            shared: false,
            rr: 0,
            nodes: (0..cfg.sockets)
                .map(|_| DramModel::new(dram, row_bytes))
                .collect(),
        }
    }

    pub fn sockets(&self) -> usize {
        self.nodes.len()
    }

    pub fn config(&self) -> &NumaConfig {
        &self.cfg
    }

    pub fn placement(&self) -> NumaPlacement {
        self.placement
    }

    pub fn set_placement(&mut self, placement: NumaPlacement) {
        self.placement = placement;
    }

    /// Track the engine's translation page size (home nodes are
    /// per-page).
    pub fn set_page_shift(&mut self, page_shift: u32) {
        self.page_shift = page_shift;
    }

    /// Mark the current run's footprint shared (first-touch contended
    /// path) or private. The engine decides once per run, before the
    /// warmup pass.
    pub fn set_shared(&mut self, shared: bool) {
        self.shared = shared;
    }

    /// Clear all per-run state (node row buffers, rotation phase). The
    /// shared flag survives — the engine sets it per run right before
    /// resetting.
    pub fn reset(&mut self) {
        for n in &mut self.nodes {
            n.reset();
        }
        self.rr = 0;
    }

    /// Route one DRAM-touching access (demand fill, prefetch fill, or
    /// streaming store): classify it local/remote under the placement
    /// policy, count it, and run it through the home node's banked
    /// row-buffer model. Single-socket topologies route to node 0 with
    /// no classification — bit-exact with the flat pre-NUMA model.
    #[inline]
    pub fn access(&mut self, byte_addr: u64, sid: usize, c: &mut SimCounters) {
        let s = self.nodes.len() as u64;
        if s == 1 {
            self.nodes[0].access(byte_addr, sid, c);
            return;
        }
        let node = match self.placement {
            NumaPlacement::Interleave => {
                let home = (byte_addr >> self.page_shift) % s;
                if home == 0 {
                    c.numa_local += 1;
                } else {
                    c.numa_remote += 1;
                }
                home as usize
            }
            NumaPlacement::FirstTouch => {
                if self.shared {
                    // Shared pages all landed on one node; the threads
                    // walking the sockets round-robin make 1/sockets of
                    // the machine-wide accesses local.
                    c.numa_contended += 1;
                    if self.rr % s == 0 {
                        c.numa_local += 1;
                    } else {
                        c.numa_remote += 1;
                    }
                    self.rr = self.rr.wrapping_add(1);
                } else {
                    // Private chunks were first-touched by their owning
                    // thread: every access finds its page at home.
                    c.numa_local += 1;
                }
                0
            }
        };
        self.nodes[node].access(byte_addr, sid, c);
    }

    /// Digest of the complete topology state relative to `base_bytes`,
    /// for the loop-closure fingerprint: every node's DRAM digest plus
    /// the placement-visible residues — the first-touch rotation phase
    /// and the base page's home-node phase (an interleave cycle only
    /// repeats if the shift preserves `vpn % sockets`). On a
    /// single-socket topology both residues are constant zero, so the
    /// collision structure is exactly the flat model's.
    pub fn state_digest(&self, base_bytes: u64, seed: u64) -> u64 {
        let s = self.nodes.len() as u64;
        let mut h = seed;
        for n in &self.nodes {
            h = closure::fold(h, n.state_digest(base_bytes, seed));
        }
        h = closure::fold(h, self.rr % s);
        closure::fold(h, (base_bytes >> self.page_shift) % s)
    }

    /// Shift every node's state forward by `delta_bytes` (loop-closure
    /// fast-forward). The rotation phase needs no shift: a matched
    /// digest already implies `rr % sockets` is back in phase.
    pub fn relocate(&mut self, delta_bytes: u64) {
        for n in &mut self.nodes {
            n.relocate(delta_bytes);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms;
    use crate::sim::closure::{SEED_A, SEED_B};

    const ROW_BYTES: u64 = 2048;

    fn dram() -> DramConfig {
        platforms::by_name("skx").unwrap().dram
    }

    fn two_socket() -> NumaConfig {
        NumaConfig {
            sockets: 2,
            link_latency_ns: 70.0,
            link_penalty_bytes: 96.0,
        }
    }

    #[test]
    fn placement_names_parse_and_roundtrip() {
        for &p in NumaPlacement::ALL {
            assert_eq!(NumaPlacement::parse(p.name()).unwrap(), p);
            assert_eq!(
                NumaPlacement::parse(&p.name().to_uppercase()).unwrap(),
                p
            );
            assert_eq!(format!("{p}"), p.name());
        }
        assert_eq!(
            NumaPlacement::parse("ft").unwrap(),
            NumaPlacement::FirstTouch
        );
        assert_eq!(NumaPlacement::default(), NumaPlacement::FirstTouch);
        assert!(NumaPlacement::parse("nearest").is_err());
        assert!(NumaPlacement::parse("").is_err());
    }

    #[test]
    fn single_socket_is_transparent() {
        // One node, no classification: counters stay zero and the
        // banked model sees exactly the flat access stream.
        let mut topo = Topology::new(
            &NumaConfig::single(),
            &dram(),
            ROW_BYTES,
            NumaPlacement::Interleave,
            12,
        );
        let mut flat = DramModel::new(&dram(), ROW_BYTES);
        let mut ct = SimCounters::default();
        let mut cf = SimCounters::default();
        for i in 0..512u64 {
            let addr = i * 4096 * 3 + (i % 7) * 64;
            topo.access(addr, (i % 3) as usize, &mut ct);
            flat.access(addr, (i % 3) as usize, &mut cf);
        }
        assert_eq!(ct, cf, "flat and single-socket counters must match");
        assert_eq!(ct.numa_local, 0);
        assert_eq!(ct.numa_remote, 0);
        assert_eq!(ct.numa_contended, 0);
        for seed in [SEED_A, SEED_B] {
            assert_eq!(
                topo.nodes[0].state_digest(0, seed),
                flat.state_digest(0, seed)
            );
        }
    }

    #[test]
    fn interleave_classifies_by_page_parity() {
        let mut topo = Topology::new(
            &two_socket(),
            &dram(),
            ROW_BYTES,
            NumaPlacement::Interleave,
            12,
        );
        let mut c = SimCounters::default();
        // Even 4 KiB pages are home to socket 0 (local), odd pages to
        // socket 1 (remote).
        for page in 0..16u64 {
            topo.access(page * 4096, 0, &mut c);
        }
        assert_eq!(c.numa_local, 8);
        assert_eq!(c.numa_remote, 8);
        assert_eq!(c.numa_contended, 0, "contention is a first-touch notion");
        // The page size matters: at 2 MiB pages the same byte stream
        // is 16 pages' worth of one 2 MiB page — all local.
        let mut big = Topology::new(
            &two_socket(),
            &dram(),
            ROW_BYTES,
            NumaPlacement::Interleave,
            21,
        );
        let mut cb = SimCounters::default();
        for page in 0..16u64 {
            big.access(page * 4096, 0, &mut cb);
        }
        assert_eq!(cb.numa_local, 16);
        assert_eq!(cb.numa_remote, 0);
    }

    #[test]
    fn first_touch_private_is_all_local() {
        let mut topo = Topology::new(
            &two_socket(),
            &dram(),
            ROW_BYTES,
            NumaPlacement::FirstTouch,
            12,
        );
        topo.set_shared(false);
        let mut c = SimCounters::default();
        for page in 0..32u64 {
            topo.access(page * 4096, 0, &mut c);
        }
        assert_eq!(c.numa_local, 32);
        assert_eq!(c.numa_remote, 0);
        assert_eq!(c.numa_contended, 0);
    }

    #[test]
    fn first_touch_shared_rotates_and_concentrates() {
        let mut topo = Topology::new(
            &two_socket(),
            &dram(),
            ROW_BYTES,
            NumaPlacement::FirstTouch,
            12,
        );
        topo.set_shared(true);
        let mut c = SimCounters::default();
        for i in 0..32u64 {
            topo.access((i % 4) * 4096, 0, &mut c);
        }
        // Two sockets: exactly half the machine-wide accesses to the
        // shared pages are local, and all of them are contended.
        assert_eq!(c.numa_local, 16);
        assert_eq!(c.numa_remote, 16);
        assert_eq!(c.numa_contended, 32);
        // reset() clears the rotation phase.
        topo.reset();
        let mut c2 = SimCounters::default();
        topo.access(0, 0, &mut c2);
        assert_eq!(c2.numa_local, 1, "rotation restarts local-first");
    }

    #[test]
    fn digest_and_relocate_are_shift_exact() {
        // Two 2-socket topologies fed the same stream shifted by a
        // span-aligned, home-phase-preserving offset digest identically
        // relative to their bases, and relocation reproduces the
        // shifted history.
        let mk = || {
            Topology::new(
                &two_socket(),
                &dram(),
                ROW_BYTES,
                NumaPlacement::Interleave,
                12,
            )
        };
        let mut a = mk();
        let mut b = mk();
        // A shift that is a multiple of every node's span and of
        // sockets * page bytes keeps both the bank slots and the
        // home-node phase aligned.
        let span = ROW_BYTES * dram().total_banks() as u64;
        let shift = span * 4096 * 2;
        let mut ca = SimCounters::default();
        let mut cb = SimCounters::default();
        for i in 0..256u64 {
            let addr = i * 8192 + (i % 5) * 64;
            a.access(addr, (i % 3) as usize, &mut ca);
            b.access(addr + shift, (i % 3) as usize, &mut cb);
        }
        assert_eq!(ca, cb, "classification must be shift-invariant");
        for seed in [SEED_A, SEED_B] {
            assert_eq!(a.state_digest(0, seed), b.state_digest(shift, seed));
        }
        a.relocate(shift);
        for seed in [SEED_A, SEED_B] {
            assert_eq!(
                a.state_digest(shift, seed),
                b.state_digest(shift, seed)
            );
        }
        // A home-phase-breaking shift (odd page count) must not digest
        // equal: vpn % sockets flips.
        let mut d = mk();
        let mut cd = SimCounters::default();
        d.access(4096, 0, &mut cd);
        let mut e = mk();
        let mut ce = SimCounters::default();
        e.access(0, 0, &mut ce);
        assert_ne!(
            d.state_digest(4096, SEED_A),
            e.state_digest(0, SEED_A),
            "odd-page shifts flip the home phase"
        );
    }

    #[test]
    fn rotation_phase_reaches_the_digest() {
        let mut a = Topology::new(
            &two_socket(),
            &dram(),
            ROW_BYTES,
            NumaPlacement::FirstTouch,
            12,
        );
        a.set_shared(true);
        let mut b = a.clone();
        let mut ca = SimCounters::default();
        let mut cb = SimCounters::default();
        // Same DRAM state, rotation phases differing by one access.
        a.access(0, 0, &mut ca);
        b.access(0, 0, &mut cb);
        b.access(0, 0, &mut cb);
        assert_ne!(
            a.state_digest(0, SEED_A),
            b.state_digest(0, SEED_A),
            "an out-of-phase rotation is a different state"
        );
        a.access(0, 0, &mut ca);
        assert_eq!(a.state_digest(0, SEED_A), b.state_digest(0, SEED_A));
    }
}
