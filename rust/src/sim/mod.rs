//! Memory-hierarchy timing simulator — the substitute substrate for the
//! paper's ten physical machines (DESIGN.md §2).
//!
//! The paper's evaluation figures are *explained* by micro-architectural
//! mechanisms the authors name explicitly: cache-line granularity,
//! Broadwell's adjacent-line prefetcher, Skylake's always-two-lines
//! fetch, GPU warp coalescing at sector granularity, write-allocate
//! traffic for scatter, coherence storms on delta-0 scatter, and TLB
//! pressure at large deltas. This module models exactly those
//! mechanisms:
//!
//! * [`cache`] — set-associative LRU write-back caches.
//! * [`memory`] — the shared virtual-memory subsystem: typed
//!   virtual/physical addresses, configurable page sizes, one
//!   set-associative [`Tlb`] and one [`PageTableWalker`] used by both
//!   engines (TLB pressure at large deltas, §5.4).
//! * [`prefetch`] — per-platform prefetcher models (Figs 3/4).
//! * [`cpu`] — the CPU engine: L1/L2/L3 + TLB + prefetcher + a
//!   bottleneck ("roofline-max") timing model over issue rate, cache
//!   bandwidths, DRAM traffic, miss latency, and coherence.
//! * [`gpu`] — the GPU engine: warp-level sector coalescing, an L2
//!   cache, DRAM row-activation overhead, and a GPU TLB (Fig 5).
//!
//! Absolute GB/s are calibrated to the Table 3 STREAM column; curve
//! *shapes* (who wins, crossover strides, plateau fractions) are the
//! reproduction target.

pub mod cache;
pub mod cpu;
pub mod gpu;
pub mod memory;
pub mod prefetch;

pub use cache::{Cache, Probe};
pub use cpu::{CpuEngine, CpuSimOptions};
pub use gpu::GpuEngine;
pub use memory::{
    PageSize, PageTableWalker, PhysicalAddress, Tlb, TlbGeometry, TlbStats,
    TlbTable, VirtualAddress,
};
pub use prefetch::{PrefetchKind, Prefetcher};

/// Event counters from one simulated pattern run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimCounters {
    /// Demand accesses simulated (gathers or scatters × index length).
    pub accesses: u64,
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub l3_hits: u64,
    /// Demand line fills from DRAM.
    pub dram_demand_lines: u64,
    /// Prefetched line fills from DRAM.
    pub dram_prefetch_lines: u64,
    /// Demand accesses that landed on a line a prefetcher brought in.
    pub prefetch_useful: u64,
    /// Dirty lines written back to DRAM.
    pub writeback_lines: u64,
    /// Non-temporal (streaming) store lines sent straight to DRAM.
    pub streaming_store_lines: u64,
    /// Read/write-split TLB statistics, the same [`TlbStats`] type for
    /// both engines (CPU: one translation per access; GPU: one per
    /// coalesced transaction).
    pub tlb: TlbStats,
    /// Cross-thread contended writes (coherence model).
    pub coherence_events: u64,
    /// GPU: memory transactions (sectors) issued.
    pub transactions: u64,
    /// GPU: DRAM row activations.
    pub row_activations: u64,
}

impl SimCounters {
    /// Total DRAM read traffic in bytes (64-byte lines).
    pub fn dram_read_bytes(&self) -> u64 {
        (self.dram_demand_lines + self.dram_prefetch_lines) * 64
    }

    /// Total DRAM write traffic in bytes.
    pub fn dram_write_bytes(&self) -> u64 {
        (self.writeback_lines + self.streaming_store_lines) * 64
    }
}

/// Where the modelled time went (seconds, per bottleneck resource).
/// The run time is the max over these (bottleneck model) — see
/// `cpu::CpuEngine::timing`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TimeBreakdown {
    pub issue_s: f64,
    pub l2_s: f64,
    pub l3_s: f64,
    pub dram_s: f64,
    pub latency_s: f64,
    pub tlb_s: f64,
    pub coherence_s: f64,
}

impl TimeBreakdown {
    /// The binding bottleneck.
    pub fn total(&self) -> f64 {
        [
            self.issue_s,
            self.l2_s,
            self.l3_s,
            self.dram_s,
            self.latency_s,
            self.tlb_s,
            self.coherence_s,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }

    /// Name of the binding bottleneck (for reports). Real-execution
    /// backends have no modelled breakdown: "measured".
    pub fn bottleneck(&self) -> &'static str {
        if self.total() == 0.0 {
            return "measured";
        }
        let items = [
            (self.issue_s, "issue"),
            (self.l2_s, "l2-bw"),
            (self.l3_s, "l3-bw"),
            (self.dram_s, "dram-bw"),
            (self.latency_s, "latency"),
            (self.tlb_s, "tlb"),
            (self.coherence_s, "coherence"),
        ];
        items
            .into_iter()
            .max_by(|a, b| a.0.total_cmp(&b.0))
            .map(|(_, n)| n)
            .unwrap_or("none")
    }
}

/// Result of one simulated Spatter run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Modelled wall time for the *full* pattern (scaled from the
    /// simulated sample when count exceeds the simulation cap).
    pub seconds: f64,
    /// Useful bytes (the paper's bandwidth numerator).
    pub useful_bytes: u64,
    pub counters: SimCounters,
    pub breakdown: TimeBreakdown,
    /// Iterations actually simulated (<= pattern count).
    pub simulated_iterations: usize,
}

impl SimResult {
    /// The paper's reported metric: useful bytes / min time, in GB/s
    /// (decimal GB, matching STREAM's MB/s convention).
    pub fn bandwidth_gbs(&self) -> f64 {
        self.useful_bytes as f64 / self.seconds / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_is_max() {
        let b = TimeBreakdown {
            issue_s: 0.5,
            dram_s: 2.0,
            latency_s: 1.0,
            ..Default::default()
        };
        assert_eq!(b.total(), 2.0);
        assert_eq!(b.bottleneck(), "dram-bw");
    }

    #[test]
    fn counters_traffic_math() {
        let c = SimCounters {
            dram_demand_lines: 10,
            dram_prefetch_lines: 5,
            writeback_lines: 3,
            streaming_store_lines: 2,
            ..Default::default()
        };
        assert_eq!(c.dram_read_bytes(), 15 * 64);
        assert_eq!(c.dram_write_bytes(), 5 * 64);
    }

    #[test]
    fn bandwidth_units() {
        let r = SimResult {
            seconds: 1.0,
            useful_bytes: 43_885_000_000,
            counters: SimCounters::default(),
            breakdown: TimeBreakdown::default(),
            simulated_iterations: 1,
        };
        assert!((r.bandwidth_gbs() - 43.885).abs() < 1e-9);
    }
}
