//! Memory-hierarchy timing simulator — the substitute substrate for the
//! paper's ten physical machines (DESIGN.md §2).
//!
//! The paper's evaluation figures are *explained* by micro-architectural
//! mechanisms the authors name explicitly: cache-line granularity,
//! Broadwell's adjacent-line prefetcher, Skylake's always-two-lines
//! fetch, GPU warp coalescing at sector granularity, write-allocate
//! traffic for scatter, coherence storms on delta-0 scatter, and TLB
//! pressure at large deltas. This module models exactly those
//! mechanisms:
//!
//! * [`cache`] — set-associative LRU write-back caches.
//! * [`memory`] — the shared virtual-memory subsystem: typed
//!   virtual/physical addresses, configurable page sizes, one
//!   set-associative [`Tlb`] and one [`PageTableWalker`] used by both
//!   engines (TLB pressure at large deltas, §5.4).
//! * [`prefetch`] — per-platform prefetcher models (Figs 3/4).
//! * [`closure`] — steady-state detection and loop closure: once a
//!   run's microarchitectural state provably cycles, the remaining
//!   iterations are closed analytically with bit-identical counters
//!   (§Perf; the `closed_at_iteration` diagnostic and the
//!   `SPATTER_NO_CLOSURE` switch are documented there and in the
//!   README's Performance section).
//! * [`plan`] — per-run access-plan compiler: the run's access stream
//!   (pre-scaled offsets, per-stream flags, same-line/warp-sector run
//!   coalescing) compiled once per `run()` and replayed through
//!   monomorphized hot loops with counted bulk updates — bit-identical
//!   to the scalar reference paths, which stay available behind
//!   `SPATTER_NO_PLAN=1` (§Perf).
//! * [`topology`] — NUMA socket topology: one banked DRAM model per
//!   node, local/remote access classification under a page-placement
//!   policy (`--numa-placement`), and the interconnect link cost the
//!   timing model charges remote traffic.
//! * [`cpu`] — the CPU engine: L1/L2/L3 + TLB + prefetcher + a
//!   bottleneck ("roofline-max") timing model over issue rate, cache
//!   bandwidths, DRAM traffic, miss latency, and coherence.
//! * [`gpu`] — the GPU engine: warp-level sector coalescing, an L2
//!   cache, DRAM row-activation overhead, and a GPU TLB (Fig 5).
//!
//! Absolute GB/s are calibrated to the Table 3 STREAM column; curve
//! *shapes* (who wins, crossover strides, plateau fractions) are the
//! reproduction target.
//!
//! A top-down map of how these pieces compose — backends over engines
//! over the cache/TLB/DRAM/plan/closure substrate — lives in
//! `docs/ARCHITECTURE.md`, with the pinning test for each invariant.
//!
//! # Scratch-buffer invariants (§Perf)
//!
//! Both engines keep their per-access temporaries — the prefetch
//! target list, the warp coalescing list, the pre-scaled index
//! byte-offset tables, and the compiled access plans — as engine-owned
//! scratch that is cleared and refilled in place: plans are built once
//! per `run()`, the rest once per pass, and nothing is reallocated
//! once warm. Code touching the hot paths must preserve this: no
//! allocation, no `clone`, and no `mem::take` churn inside the
//! per-access path. The invariant is enforced by the counting-
//! allocator test in `rust/tests/zero_alloc.rs`, not just by review.

pub mod cache;
pub mod closure;
pub mod cpu;
pub mod dram;
pub mod gpu;
pub mod memory;
pub mod plan;
pub mod prefetch;
pub mod topology;

pub use cache::{Cache, Probe};
pub use cpu::{CpuEngine, CpuSimOptions};
pub use dram::{BankState, DramConfig, DramModel, InterleavePolicy};
pub use gpu::GpuEngine;
pub use memory::{
    PageSize, PageTableWalker, PhysicalAddress, Tlb, TlbGeometry, TlbStats,
    TlbTable, VirtualAddress,
};
pub use plan::{AccessPlan, GpuPlan};
pub use prefetch::{PrefetchKind, Prefetcher};
pub use topology::{NumaConfig, NumaPlacement, Topology};

/// Fixed seed of the GUPS random-update stream (both engines): runs
/// are deterministic, and the same pattern produces the same update
/// sequence on every backend.
pub const GUPS_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

/// The seeded 64-bit xorshift driving GUPS update streams. Period
/// 2^64-1 — the sequence never cycles within a run, so steady-state
/// loop closure correctly never fires on GUPS (and on/off stays
/// trivially bit-identical).
#[derive(Debug, Clone)]
pub struct XorShift64(u64);

impl XorShift64 {
    /// Seeded per pass: the measured pass always draws the same
    /// sequence; warm-up passes draw a disjoint stream (the `warm`
    /// salt), so a short run's warm-up can never replay the measured
    /// pass's addresses and fake cache residency.
    pub fn seeded(begin: usize, warm: bool) -> XorShift64 {
        let mut s =
            GUPS_SEED ^ (begin as u64).wrapping_mul(0xA076_1D64_78BD_642F);
        if warm {
            s ^= 0x94D0_49BB_1331_11EB;
        }
        XorShift64(if s == 0 { GUPS_SEED } else { s })
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
}

/// Event counters from one simulated pattern run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimCounters {
    /// Demand accesses simulated (gathers or scatters × index length).
    pub accesses: u64,
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub l3_hits: u64,
    /// Demand line fills from DRAM.
    pub dram_demand_lines: u64,
    /// Prefetched line fills from DRAM.
    pub dram_prefetch_lines: u64,
    /// Demand accesses that landed on a line a prefetcher brought in.
    pub prefetch_useful: u64,
    /// Dirty lines written back to DRAM.
    pub writeback_lines: u64,
    /// Non-temporal (streaming) store lines sent straight to DRAM.
    pub streaming_store_lines: u64,
    /// Read/write-split TLB statistics, the same [`TlbStats`] type for
    /// both engines (CPU: one translation per access; GPU: one per
    /// coalesced transaction).
    pub tlb: TlbStats,
    /// Cross-thread contended writes (coherence model).
    pub coherence_events: u64,
    /// GPU: memory transactions (sectors) issued.
    pub transactions: u64,
    /// DRAM row activations (bank row opened: miss or conflict).
    pub row_activations: u64,
    /// DRAM accesses served from an already-open row buffer
    /// ([`dram::DramModel`]).
    pub dram_row_hits: u64,
    /// Row activations whose precharge/activate overlapped other
    /// channels or bank groups (pipelined).
    pub dram_row_misses: u64,
    /// Row activations serialized behind the previous activation in
    /// the same channel + bank group (tFAW/tRRD_L-class stall).
    pub dram_row_conflicts: u64,
    /// DRAM-touching accesses whose page was home to the accessing
    /// socket ([`topology::Topology`]; zero on single-socket parts).
    pub numa_local: u64,
    /// DRAM-touching accesses that crossed the socket interconnect.
    pub numa_remote: u64,
    /// First-touch accesses to a shared (all-threads) footprint whose
    /// pages concentrated on one node — the traffic the timing model's
    /// bandwidth-concentration factor is built from.
    pub numa_contended: u64,
}

impl SimCounters {
    /// Total DRAM read traffic in bytes (64-byte lines).
    pub fn dram_read_bytes(&self) -> u64 {
        (self.dram_demand_lines + self.dram_prefetch_lines) * 64
    }

    /// Total DRAM write traffic in bytes.
    pub fn dram_write_bytes(&self) -> u64 {
        (self.writeback_lines + self.streaming_store_lines) * 64
    }

    /// Field-wise difference against an earlier snapshot of the same
    /// run (all counters are monotone). Loop closure uses this as the
    /// per-cycle delta.
    pub fn delta_since(&self, earlier: &SimCounters) -> SimCounters {
        SimCounters {
            accesses: self.accesses - earlier.accesses,
            l1_hits: self.l1_hits - earlier.l1_hits,
            l2_hits: self.l2_hits - earlier.l2_hits,
            l3_hits: self.l3_hits - earlier.l3_hits,
            dram_demand_lines: self.dram_demand_lines - earlier.dram_demand_lines,
            dram_prefetch_lines: self.dram_prefetch_lines
                - earlier.dram_prefetch_lines,
            prefetch_useful: self.prefetch_useful - earlier.prefetch_useful,
            writeback_lines: self.writeback_lines - earlier.writeback_lines,
            streaming_store_lines: self.streaming_store_lines
                - earlier.streaming_store_lines,
            tlb: TlbStats {
                read_hits: self.tlb.read_hits - earlier.tlb.read_hits,
                read_misses: self.tlb.read_misses - earlier.tlb.read_misses,
                write_hits: self.tlb.write_hits - earlier.tlb.write_hits,
                write_misses: self.tlb.write_misses - earlier.tlb.write_misses,
            },
            coherence_events: self.coherence_events - earlier.coherence_events,
            transactions: self.transactions - earlier.transactions,
            row_activations: self.row_activations - earlier.row_activations,
            dram_row_hits: self.dram_row_hits - earlier.dram_row_hits,
            dram_row_misses: self.dram_row_misses - earlier.dram_row_misses,
            dram_row_conflicts: self.dram_row_conflicts
                - earlier.dram_row_conflicts,
            numa_local: self.numa_local - earlier.numa_local,
            numa_remote: self.numa_remote - earlier.numa_remote,
            numa_contended: self.numa_contended - earlier.numa_contended,
        }
    }

    /// Accumulate `reps` repetitions of a per-cycle delta — the loop
    /// closure fast-forward (exact: every skipped cycle produces the
    /// identical delta).
    pub fn add_scaled(&mut self, d: &SimCounters, reps: u64) {
        self.accesses += d.accesses * reps;
        self.l1_hits += d.l1_hits * reps;
        self.l2_hits += d.l2_hits * reps;
        self.l3_hits += d.l3_hits * reps;
        self.dram_demand_lines += d.dram_demand_lines * reps;
        self.dram_prefetch_lines += d.dram_prefetch_lines * reps;
        self.prefetch_useful += d.prefetch_useful * reps;
        self.writeback_lines += d.writeback_lines * reps;
        self.streaming_store_lines += d.streaming_store_lines * reps;
        self.tlb.read_hits += d.tlb.read_hits * reps;
        self.tlb.read_misses += d.tlb.read_misses * reps;
        self.tlb.write_hits += d.tlb.write_hits * reps;
        self.tlb.write_misses += d.tlb.write_misses * reps;
        self.coherence_events += d.coherence_events * reps;
        self.transactions += d.transactions * reps;
        self.row_activations += d.row_activations * reps;
        self.dram_row_hits += d.dram_row_hits * reps;
        self.dram_row_misses += d.dram_row_misses * reps;
        self.dram_row_conflicts += d.dram_row_conflicts * reps;
        self.numa_local += d.numa_local * reps;
        self.numa_remote += d.numa_remote * reps;
        self.numa_contended += d.numa_contended * reps;
    }
}

/// Where the modelled time went (seconds, per bottleneck resource).
/// The run time is the max over these (bottleneck model) — see
/// `cpu::CpuEngine::timing`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TimeBreakdown {
    pub issue_s: f64,
    pub l2_s: f64,
    pub l3_s: f64,
    pub dram_s: f64,
    pub latency_s: f64,
    pub tlb_s: f64,
    pub coherence_s: f64,
}

impl TimeBreakdown {
    /// The binding bottleneck.
    pub fn total(&self) -> f64 {
        [
            self.issue_s,
            self.l2_s,
            self.l3_s,
            self.dram_s,
            self.latency_s,
            self.tlb_s,
            self.coherence_s,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }

    /// Name of the binding bottleneck (for reports). Real-execution
    /// backends have no modelled breakdown: "measured".
    pub fn bottleneck(&self) -> &'static str {
        if self.total() == 0.0 {
            return "measured";
        }
        let items = [
            (self.issue_s, "issue"),
            (self.l2_s, "l2-bw"),
            (self.l3_s, "l3-bw"),
            (self.dram_s, "dram-bw"),
            (self.latency_s, "latency"),
            (self.tlb_s, "tlb"),
            (self.coherence_s, "coherence"),
        ];
        items
            .into_iter()
            .max_by(|a, b| a.0.total_cmp(&b.0))
            .map(|(_, n)| n)
            .unwrap_or("none")
    }
}

/// Result of one simulated Spatter run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Modelled wall time for the *full* pattern (scaled from the
    /// simulated sample when count exceeds the simulation cap).
    pub seconds: f64,
    /// Useful bytes (the paper's bandwidth numerator).
    pub useful_bytes: u64,
    pub counters: SimCounters,
    pub breakdown: TimeBreakdown,
    /// Iterations actually simulated (<= pattern count).
    pub simulated_iterations: usize,
    /// Iteration of the measured pass at which steady-state loop
    /// closure kicked in (`None`: the pass ran in full — closure
    /// disabled, or no cycle within the tracking budget). Counters are
    /// identical either way; this is the observability hook for the
    /// speedup (`"sim-closure"` in record JSON, stderr in the CLI).
    pub closed_at_iteration: Option<usize>,
}

impl SimResult {
    /// The paper's reported metric: useful bytes / min time, in GB/s
    /// (decimal GB, matching STREAM's MB/s convention).
    pub fn bandwidth_gbs(&self) -> f64 {
        self.useful_bytes as f64 / self.seconds / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_is_max() {
        let b = TimeBreakdown {
            issue_s: 0.5,
            dram_s: 2.0,
            latency_s: 1.0,
            ..Default::default()
        };
        assert_eq!(b.total(), 2.0);
        assert_eq!(b.bottleneck(), "dram-bw");
    }

    #[test]
    fn counter_delta_and_scale() {
        let base = SimCounters {
            accesses: 10,
            l1_hits: 4,
            writeback_lines: 1,
            tlb: TlbStats {
                read_hits: 3,
                read_misses: 1,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut later = base.clone();
        later.add_scaled(&base, 1); // later = 2 * base
        let d = later.delta_since(&base);
        assert_eq!(d, base, "one-cycle delta recovers the increment");
        let mut ff = base.clone();
        ff.add_scaled(&d, 3);
        assert_eq!(ff.accesses, 40);
        assert_eq!(ff.tlb.read_hits, 12);
        assert_eq!(ff.writeback_lines, 4);
    }

    #[test]
    fn counters_traffic_math() {
        let c = SimCounters {
            dram_demand_lines: 10,
            dram_prefetch_lines: 5,
            writeback_lines: 3,
            streaming_store_lines: 2,
            ..Default::default()
        };
        assert_eq!(c.dram_read_bytes(), 15 * 64);
        assert_eq!(c.dram_write_bytes(), 5 * 64);
    }

    #[test]
    fn xorshift_is_deterministic_and_seed_sensitive() {
        let draw = |begin: usize, warm: bool| -> Vec<u64> {
            let mut r = XorShift64::seeded(begin, warm);
            (0..64).map(|_| r.next_u64()).collect()
        };
        assert_eq!(draw(0, false), draw(0, false), "same seed, same sequence");
        assert_ne!(
            draw(0, false),
            draw(7, false),
            "different pass start, different sequence"
        );
        // The warm-up salt gives a disjoint stream even at begin 0 —
        // a short run's warm-up must not replay the measured pass.
        assert_ne!(draw(0, false), draw(0, true), "warm salt applies");
        assert!(
            draw(0, false).iter().all(|&x| x != 0),
            "xorshift never emits zero"
        );
    }

    #[test]
    fn bandwidth_units() {
        let r = SimResult {
            seconds: 1.0,
            useful_bytes: 43_885_000_000,
            counters: SimCounters::default(),
            breakdown: TimeBreakdown::default(),
            simulated_iterations: 1,
            closed_at_iteration: None,
        };
        assert!((r.bandwidth_gbs() - 43.885).abs() < 1e-9);
    }
}
