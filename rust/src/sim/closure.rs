//! Steady-state detection and loop closure, shared by the CPU and GPU
//! engines.
//!
//! # Why it is exact
//!
//! Both engines advance a periodic index pattern through a
//! deterministic state machine (caches, TLB, prefetcher, banked DRAM
//! row buffers). The machine's evolution is *equivariant under address
//! shifts*: adding a constant to every resident tag, the base address,
//! and the access stream produces the same hit/miss/eviction sequence
//! with every address shifted by that constant — set indices rotate
//! uniformly, LRU decisions depend only on stamp order, and the
//! alignment-sensitive mechanisms (page crossings, DRAM rows and bank
//! assignment, buddy lines, the 4 KiB prefetch fence) are preserved as
//! long as the shift is a multiple of the page size and of the DRAM
//! bank span (each digest embeds its own alignment residue, so a
//! fingerprint match implies a compatible shift).
//!
//! So the engines fingerprint their state *relative to the current
//! base address* after every outer iteration, together with the base's
//! page-alignment residue and the delta-cycle phase. When a
//! fingerprint repeats, the machine is in a cycle: every subsequent
//! cycle produces the identical per-cycle counter delta. The engine
//! then multiplies that delta across the remaining whole cycles,
//! relocates its state forward by the skipped address advance (an
//! exact shift: tags translated, sets rotated, stamps untouched), and
//! simulates only the sub-cycle tail — producing counters and final
//! state identical to full simulation.
//!
//! # The incremental signature
//!
//! Rehashing a 33 MB simulated L3 every iteration would dwarf the
//! iteration itself, so [`StateSig`] maintains *power sums* of each
//! structure's `(tag, stamp)` pairs under wrapping arithmetic,
//! updated O(1) per mutation. Power sums commute with shifts via the
//! binomial theorem, so the shift-*relative* digest is computable in
//! O(1) at fingerprint time from the absolute sums — no rehash, no
//! walk.
//!
//! A false cycle requires two different states to agree on *every*
//! maintained moment of *every* structure simultaneously: per cache
//! nine wrapping moments — tag power sums to degree 4 (degree-3
//! Prouhet–Tarry–Escott tag sets exist, degree-4 agreement needs
//! far larger coordinated sets), stamp sums, and two joint
//! (tag, stamp) moments that pin the pairing — folded across L1, L2,
//! L3, TLB, prefetcher, row/stream trackers, residues, and phase.
//! The two seeds re-mix the same moment vector (they widen the key,
//! not the underlying information), so the honest bound is "all
//! moments of all structures collide at matching residue and phase"
//! — engineered collisions are conceivable, accidental ones
//! negligible against the ~2^16 fingerprints a pass can record, and
//! the equivalence property suite cross-checks closure against full
//! simulation on every CI run.

use std::collections::HashMap;

use super::SimCounters;

/// Fingerprint seeds for the two independent digest halves (xxh
/// primes; any odd constants work).
pub const SEED_A: u64 = 0x9E37_79B1_85EB_CA87;
pub const SEED_B: u64 = 0xC2B2_AE3D_27D4_EB4F;

/// SplitMix64 finalizer — the mixing primitive for digests.
#[inline]
pub fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fold one value into a running digest.
#[inline]
pub fn fold(h: u64, v: u64) -> u64 {
    splitmix(h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// A 128-bit streaming digest over the closure-fingerprint primitives:
/// two independent [`fold`] chains (seeded with [`SEED_A`] /
/// [`SEED_B`]) collapsed into one `u128`. This is the config-level
/// companion to the loop-closure state fingerprint — the coordinator
/// keys its result-memo cache on it, so two run configs with the same
/// digest are treated as the same simulation.
///
/// Collisions would silently alias two different configs onto one
/// cached result, which is why the digest is 128 bits wide (the same
/// budget the loop-closure layer uses for state signatures): with two
/// independently-seeded halves, accidental collision over campaign
/// scales (≤ millions of configs) is negligible.
#[derive(Debug, Clone)]
pub struct Fingerprinter {
    a: u64,
    b: u64,
}

impl Fingerprinter {
    pub fn new() -> Fingerprinter {
        Fingerprinter {
            a: SEED_A,
            b: SEED_B,
        }
    }

    /// Fold one word into both halves.
    #[inline]
    pub fn push(&mut self, v: u64) {
        self.a = fold(self.a, v);
        self.b = fold(self.b, v);
    }

    #[inline]
    pub fn push_i64(&mut self, v: i64) {
        self.push(v as u64);
    }

    /// Fold a string, length-prefixed so concatenation ambiguities
    /// ("ab"+"c" vs "a"+"bc") cannot alias.
    pub fn push_str(&mut self, s: &str) {
        self.push(s.len() as u64);
        for chunk in s.as_bytes().chunks(8) {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            self.push(u64::from_le_bytes(w));
        }
    }

    pub fn finish(&self) -> u128 {
        ((self.a as u128) << 64) | self.b as u128
    }
}

impl Default for Fingerprinter {
    fn default() -> Fingerprinter {
        Fingerprinter::new()
    }
}

/// Incremental, shift-invariant signature of a set of `(x, stamp)`
/// pairs (one per resident cache way / TLB entry), where `x` packs the
/// tag and its flag bits.
///
/// Maintained as wrapping power sums so that:
/// * insert/remove/update are O(1) (a handful of multiplies), and
/// * the digest of the multiset `{(x - shift, clock - stamp)}` is
///   computable in O(1) from the absolute sums (binomial expansion) —
///   the shift- and clock-relative view the loop-closure layer needs.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StateSig {
    n: u64,
    sx1: u64,
    sx2: u64,
    sx3: u64,
    sx4: u64,
    ss1: u64,
    ss2: u64,
    sxs: u64,
    sx2s: u64,
}

impl StateSig {
    /// Account a new `(x, stamp)` pair.
    #[inline]
    pub fn insert(&mut self, x: u64, stamp: u64) {
        let x2 = x.wrapping_mul(x);
        let x3 = x2.wrapping_mul(x);
        self.n = self.n.wrapping_add(1);
        self.sx1 = self.sx1.wrapping_add(x);
        self.sx2 = self.sx2.wrapping_add(x2);
        self.sx3 = self.sx3.wrapping_add(x3);
        self.sx4 = self.sx4.wrapping_add(x3.wrapping_mul(x));
        self.ss1 = self.ss1.wrapping_add(stamp);
        self.ss2 = self.ss2.wrapping_add(stamp.wrapping_mul(stamp));
        self.sxs = self.sxs.wrapping_add(x.wrapping_mul(stamp));
        self.sx2s = self.sx2s.wrapping_add(x2.wrapping_mul(stamp));
    }

    /// Remove a previously-inserted `(x, stamp)` pair.
    #[inline]
    pub fn remove(&mut self, x: u64, stamp: u64) {
        let x2 = x.wrapping_mul(x);
        let x3 = x2.wrapping_mul(x);
        self.n = self.n.wrapping_sub(1);
        self.sx1 = self.sx1.wrapping_sub(x);
        self.sx2 = self.sx2.wrapping_sub(x2);
        self.sx3 = self.sx3.wrapping_sub(x3);
        self.sx4 = self.sx4.wrapping_sub(x3.wrapping_mul(x));
        self.ss1 = self.ss1.wrapping_sub(stamp);
        self.ss2 = self.ss2.wrapping_sub(stamp.wrapping_mul(stamp));
        self.sxs = self.sxs.wrapping_sub(x.wrapping_mul(stamp));
        self.sx2s = self.sx2s.wrapping_sub(x2.wrapping_mul(stamp));
    }

    /// Forget everything.
    pub fn reset(&mut self) {
        *self = StateSig::default();
    }

    /// Digest of the *relative* multiset `{(x - shift, clock - stamp)}`
    /// under `seed`. Derived from the absolute sums via the binomial
    /// theorem — no per-entry work.
    pub fn digest(&self, shift: u64, clock: u64, seed: u64) -> u64 {
        let n = self.n;
        let b = shift;
        let b2 = b.wrapping_mul(b);
        let b3 = b2.wrapping_mul(b);
        // I_k = sum (x - b)^k, to degree 4 (degree-3 tag-multiset
        // collisions — Prouhet–Tarry–Escott sets — are cheap to hit
        // by accident; degree-4 agreement is not).
        let i1 = self.sx1.wrapping_sub(n.wrapping_mul(b));
        let i2 = self
            .sx2
            .wrapping_sub(self.sx1.wrapping_mul(b).wrapping_mul(2))
            .wrapping_add(n.wrapping_mul(b2));
        let i3 = self
            .sx3
            .wrapping_sub(self.sx2.wrapping_mul(b).wrapping_mul(3))
            .wrapping_add(self.sx1.wrapping_mul(b2).wrapping_mul(3))
            .wrapping_sub(n.wrapping_mul(b3));
        let i4 = self
            .sx4
            .wrapping_sub(self.sx3.wrapping_mul(b).wrapping_mul(4))
            .wrapping_add(self.sx2.wrapping_mul(b2).wrapping_mul(6))
            .wrapping_sub(self.sx1.wrapping_mul(b3).wrapping_mul(4))
            .wrapping_add(n.wrapping_mul(b3.wrapping_mul(b)));
        // J_k = sum (clock - stamp)^k.
        let j1 = n.wrapping_mul(clock).wrapping_sub(self.ss1);
        let j2 = n
            .wrapping_mul(clock.wrapping_mul(clock))
            .wrapping_sub(self.ss1.wrapping_mul(clock).wrapping_mul(2))
            .wrapping_add(self.ss2);
        // K_1 = sum (x - b)(clock - stamp) and
        // K_2 = sum (x - b)^2 (clock - stamp) — the joint moments
        // that distinguish re-paired (tag, stamp) assignments.
        let k1 = self
            .sx1
            .wrapping_mul(clock)
            .wrapping_sub(self.sxs)
            .wrapping_sub(b.wrapping_mul(n).wrapping_mul(clock))
            .wrapping_add(b.wrapping_mul(self.ss1));
        let k2 = self
            .sx2
            .wrapping_mul(clock)
            .wrapping_sub(self.sx2s)
            .wrapping_sub(
                b.wrapping_mul(
                    self.sx1.wrapping_mul(clock).wrapping_sub(self.sxs),
                )
                .wrapping_mul(2),
            )
            .wrapping_add(
                b2.wrapping_mul(n.wrapping_mul(clock).wrapping_sub(self.ss1)),
            );
        let mut h = seed;
        for v in [n, i1, i2, i3, i4, j1, j2, k1, k2] {
            h = fold(h, v);
        }
        h
    }
}

/// What a fingerprint observation concluded.
#[derive(Debug, Clone)]
pub enum Observation {
    /// New fingerprint: recorded, keep simulating.
    Recorded,
    /// Tracking budget exhausted without a repeat: the transient is
    /// too long, stop fingerprinting for this pass.
    Saturated,
    /// The fingerprint repeats: the engine is in a steady-state cycle
    /// that started at the recorded iteration.
    Cycle(CycleInfo),
}

/// The matched earlier observation of a detected cycle.
#[derive(Debug, Clone)]
pub struct CycleInfo {
    /// Iteration index of the earlier, identical state.
    pub iter: usize,
    /// Base element address at that iteration.
    pub base: i64,
    /// Counter snapshot at that iteration (the per-cycle delta is the
    /// current counters minus these).
    pub counters: SimCounters,
}

/// Longest transient the closer tracks before giving up. Steady-state
/// cycles of the modelled mechanisms are short (at most
/// page-size / per-iteration-advance iterations); the cap bounds the
/// fingerprint map and stops the digest overhead on passes that never
/// converge.
const MAX_TRACKED: usize = 1 << 16;

#[derive(Debug, Clone)]
struct Snapshot {
    iter: usize,
    base: i64,
    counters: SimCounters,
}

/// Per-pass fingerprint log: maps state digests to the iteration where
/// they were first seen. One instance per simulated pass.
#[derive(Debug, Clone, Default)]
pub struct LoopCloser {
    map: HashMap<u128, Snapshot>,
}

impl LoopCloser {
    pub fn new() -> LoopCloser {
        LoopCloser::default()
    }

    /// Record the post-iteration fingerprint `key` for iteration
    /// `iter`; report a cycle if the key was seen before.
    pub fn observe(
        &mut self,
        key: u128,
        iter: usize,
        base: i64,
        counters: &SimCounters,
    ) -> Observation {
        if let Some(s) = self.map.get(&key) {
            return Observation::Cycle(CycleInfo {
                iter: s.iter,
                base: s.base,
                counters: s.counters.clone(),
            });
        }
        if self.map.len() >= MAX_TRACKED {
            return Observation::Saturated;
        }
        self.map.insert(
            key,
            Snapshot {
                iter,
                base,
                counters: counters.clone(),
            },
        );
        Observation::Recorded
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sig_order_independent() {
        let mut a = StateSig::default();
        let mut b = StateSig::default();
        a.insert(10, 1);
        a.insert(20, 2);
        a.insert(30, 3);
        b.insert(30, 3);
        b.insert(10, 1);
        b.insert(20, 2);
        assert_eq!(a, b);
        assert_eq!(a.digest(0, 10, SEED_A), b.digest(0, 10, SEED_A));
    }

    #[test]
    fn sig_remove_inverts_insert() {
        let mut a = StateSig::default();
        a.insert(7, 3);
        a.insert(1000, 40);
        a.remove(7, 3);
        let mut b = StateSig::default();
        b.insert(1000, 40);
        assert_eq!(a, b);
    }

    #[test]
    fn sig_digest_is_shift_invariant() {
        // {(x + d, s + e)} digested relative to (shift + d, clock + e)
        // must equal {(x, s)} relative to (shift, clock).
        let pairs = [(8u64, 1u64), (640, 7), (72, 2), (8192, 31)];
        let (d, e) = (4096u64, 100u64);
        let mut a = StateSig::default();
        let mut b = StateSig::default();
        for &(x, s) in &pairs {
            a.insert(x, s);
            b.insert(x + d, s + e);
        }
        for seed in [SEED_A, SEED_B] {
            assert_eq!(a.digest(0, 50, seed), b.digest(d, 50 + e, seed));
            assert_eq!(a.digest(8, 64, seed), b.digest(8 + d, 64 + e, seed));
        }
        // And a genuinely different multiset must (overwhelmingly)
        // differ.
        let mut c = StateSig::default();
        for &(x, s) in &pairs {
            c.insert(x + 1, s);
        }
        assert_ne!(a.digest(0, 50, SEED_A), c.digest(0, 50, SEED_A));
    }

    #[test]
    fn sig_separates_degree3_moment_collisions() {
        // {0,4,7,11} and {1,2,9,10} agree on power sums up to degree
        // 3 (a Prouhet–Tarry–Escott pair); the degree-4 moment must
        // separate them — this is what makes accidental fingerprint
        // collisions implausible rather than merely unlikely.
        let mut a = StateSig::default();
        let mut b = StateSig::default();
        for x in [0u64, 4, 7, 11] {
            a.insert(x, 5);
        }
        for x in [1u64, 2, 9, 10] {
            b.insert(x, 5);
        }
        assert_ne!(a.digest(0, 9, SEED_A), b.digest(0, 9, SEED_A));
        assert_ne!(a.digest(0, 9, SEED_B), b.digest(0, 9, SEED_B));
    }

    #[test]
    fn sig_distinguishes_swapped_pairings() {
        // Same marginal tag and stamp multisets, different pairing:
        // the joint moment must separate them.
        let mut a = StateSig::default();
        a.insert(100, 1);
        a.insert(200, 2);
        let mut b = StateSig::default();
        b.insert(100, 2);
        b.insert(200, 1);
        assert_ne!(a.digest(0, 5, SEED_A), b.digest(0, 5, SEED_A));
    }

    #[test]
    fn closer_detects_repeat() {
        let mut cl = LoopCloser::new();
        let c0 = SimCounters::default();
        let c1 = SimCounters {
            accesses: 8,
            ..Default::default()
        };
        assert!(matches!(cl.observe(42, 1, 0, &c0), Observation::Recorded));
        assert!(matches!(cl.observe(43, 2, 8, &c1), Observation::Recorded));
        match cl.observe(42, 3, 16, &c1) {
            Observation::Cycle(info) => {
                assert_eq!(info.iter, 1);
                assert_eq!(info.base, 0);
                assert_eq!(info.counters.accesses, 0);
            }
            other => panic!("expected cycle, got {other:?}"),
        }
    }

    #[test]
    fn fingerprinter_is_deterministic_and_order_sensitive() {
        let mut a = Fingerprinter::new();
        a.push(1);
        a.push(2);
        let mut b = Fingerprinter::new();
        b.push(1);
        b.push(2);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fingerprinter::new();
        c.push(2);
        c.push(1);
        assert_ne!(a.finish(), c.finish());
        // The two halves are independent chains, not mirrored words.
        let f = a.finish();
        assert_ne!((f >> 64) as u64, f as u64);
    }

    #[test]
    fn fingerprinter_strings_are_length_prefixed() {
        let digest = |parts: &[&str]| {
            let mut f = Fingerprinter::new();
            for p in parts {
                f.push_str(p);
            }
            f.finish()
        };
        assert_eq!(digest(&["ab", "c"]), digest(&["ab", "c"]));
        assert_ne!(digest(&["ab", "c"]), digest(&["a", "bc"]));
        assert_ne!(digest(&["ab"]), digest(&["ab\0"]));
    }
}
