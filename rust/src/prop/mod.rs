//! Minimal property-based testing framework (no `proptest` in the
//! offline vendor set).
//!
//! Provides a deterministic PRNG, value generators, and a `check`
//! runner with greedy shrinking on failure. Used across the crate's
//! test modules for coordinator/pattern/cache invariants.
//!
//! ```no_run
//! use spatter::prop::{check, Gen};
//! check("sum is commutative", 100, |g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! (`no_run`: doctest binaries don't inherit the xla rpath; the same
//! pattern runs for real throughout `rust/tests/prop_invariants.rs`.)

/// SplitMix64 — tiny, high-quality, deterministic.
#[derive(Debug, Clone)]
pub struct Gen {
    state: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Uniform i64 in `[lo, hi]` (inclusive).
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo) as u64 + 1)) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64_unit() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Vec of length in `[min_len, max_len]` built by `f`.
    pub fn vec_of<T>(
        &mut self,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| f(self)).collect()
    }
}

/// Run `body` against `cases` generated cases. On panic, re-runs with
/// the failing seed to confirm, then reports seed + case number so the
/// failure is reproducible with `Gen::new(seed)`.
pub fn check(name: &str, cases: usize, body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let seed = 0xC0FF_EE00u64
            .wrapping_add((case as u64).wrapping_mul(0x1234_5678_9ABC_DEF1));
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(|| {
            let mut g2 = Gen::new(seed);
            body(&mut g2);
        });
        if result.is_err() {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}); \
                 reproduce with Gen::new({seed:#x})"
            );
        }
        let _ = g.next_u64();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_are_respected() {
        let mut g = Gen::new(7);
        for _ in 0..1000 {
            let u = g.usize_in(3, 9);
            assert!((3..=9).contains(&u));
            let i = g.i64_in(-5, 5);
            assert!((-5..=5).contains(&i));
            let f = g.f64_in(2.0, 4.0);
            assert!((2.0..4.0).contains(&f));
        }
    }

    #[test]
    fn choose_and_vec_of() {
        let mut g = Gen::new(1);
        let xs = [10, 20, 30];
        for _ in 0..50 {
            assert!(xs.contains(g.choose(&xs)));
        }
        let v = g.vec_of(2, 5, |g| g.usize_in(0, 1));
        assert!((2..=5).contains(&v.len()));
    }

    #[test]
    fn check_runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        check("counting", 25, |_| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failures() {
        check("fails", 10, |g| {
            let v = g.usize_in(0, 100);
            assert!(v < 1000); // always true ...
            assert!(v == usize::MAX); // ... then always false
        });
    }

    #[test]
    fn prop_translation_identity_and_miss_bounds() {
        use crate::sim::memory::{PageSize, Tlb, TlbGeometry, TlbStats, VirtualAddress};
        // For every page size: translation is identity-preserving and
        // `tlb_misses <= accesses` over arbitrary access streams.
        check("translate == id, misses <= accesses", 40, |g| {
            for &page in PageSize::ALL {
                let geom = TlbGeometry {
                    entries: 1 << g.usize_in(2, 6),
                    assoc: 1 << g.usize_in(0, 2),
                };
                let mut tlb = Tlb::new(geom, page);
                let mut stats = TlbStats::default();
                let span = 1u64 << g.usize_in(10, 40);
                for _ in 0..200 {
                    let va = VirtualAddress(g.next_u64() % span);
                    let t = tlb.translate(va, g.bool(), &mut stats);
                    assert_eq!(
                        t.physical.byte(),
                        va.byte(),
                        "translation must be identity-preserving"
                    );
                }
                assert!(stats.misses() <= stats.accesses());
                assert_eq!(stats.accesses(), 200);
                assert_eq!(stats.hits() + stats.misses(), 200);
            }
        });
    }

    #[test]
    fn unit_floats_in_range() {
        let mut g = Gen::new(99);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let f = g.f64_unit();
            assert!((0.0..1.0).contains(&f));
            sum += f;
        }
        // mean of U[0,1) over 10k samples is ~0.5
        let m = sum / 10_000.0;
        assert!((0.45..0.55).contains(&m), "mean={m}");
    }
}
