//! Run execution + aggregation (paper §3.5 output protocol), plus the
//! shared table/JSON renderers — one formatting path for the CLI, the
//! suites, and the determinism tests, so `--jobs N` output can be
//! byte-compared against serial output.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::backends::Backend;
use crate::error::Result;
use crate::json::{self, obj, Value};
use crate::pattern::{Kernel, Pattern};
use crate::report::Table;
use crate::sim::SimResult;
use crate::stats;

use super::memo::{self, MemoCache, MemoCell, MemoStats, Reservation};
use super::schedule::{parallel_map_with, parallel_stream_with, stream_window};
use super::RunConfig;

/// Fills a reserved memo cell on *every* exit path of the leader's
/// compute, including an unwinding panic inside `Backend::run`. A cell
/// left pending blocks each duplicate config forever (`MemoCell::wait`
/// has no timeout), so the fill must not depend on the leader reaching
/// its happy-path statement: dropping the guard publishes whatever is
/// in `value` — `None` (the poison marker, waking waiters into
/// recomputation) unless the leader stored a result first.
struct FillOnDrop {
    cell: std::sync::Arc<MemoCell>,
    value: Option<SimResult>,
}

impl Drop for FillOnDrop {
    fn drop(&mut self) {
        self.cell.fill(self.value.take());
    }
}

/// Process-wide tally of simulated accesses across every recorded run
/// (memo-served records replay their run's accesses — the tally is a
/// campaign-level diagnostic, not a per-engine one). The CLI divides
/// it by wall-clock time for the per-sweep host-throughput stderr
/// line.
static SIM_ACCESSES: AtomicU64 = AtomicU64::new(0);

/// Total simulated accesses recorded so far in this process (see
/// [`SIM_ACCESSES`]). Sample before and after a sweep and divide the
/// delta by the elapsed wall clock for a host-throughput figure.
pub fn sim_accesses_total() -> u64 {
    SIM_ACCESSES.load(Ordering::Relaxed)
}

/// The outcome of one pattern run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub name: String,
    pub kernel: Kernel,
    pub spec: String,
    pub delta: i64,
    pub count: usize,
    pub vector_len: usize,
    pub seconds: f64,
    pub bandwidth_gbs: f64,
    /// Useful payload bytes moved by the read stream(s): the per-
    /// stream payload times the kernel's read-stream count (Gather/GS/
    /// GUPS/Copy/Scale 1x, Add/Triad 2x, Scatter 0).
    pub read_bytes: u64,
    /// Useful payload bytes moved by the write stream: the per-stream
    /// payload for every kernel except Gather (0). GS and GUPS move
    /// their payload on *both* streams while the headline
    /// `bandwidth_gbs` counts it once (bounded by the component
    /// kernels); the STREAM tetrad's headline counts every operand
    /// stream, per STREAM's own convention.
    pub write_bytes: u64,
    /// Which simulated resource bound the run ("dram-bw", "tlb", ...);
    /// empty for real-execution backends.
    pub bottleneck: String,
    /// Translation page size the run modelled ("4KB", "2MB", ...);
    /// `None` for backends without a virtual-memory model.
    pub page_size: Option<String>,
    /// TLB hit fraction over the run's translations; `None` when the
    /// backend translated nothing (real execution).
    pub tlb_hit_rate: Option<f64>,
    /// Simulated OpenMP thread count the run modelled; `None` for
    /// backends without a thread model (GPU, real execution).
    pub threads: Option<usize>,
    /// Vectorization regime the run modelled ("scalar",
    /// "emulated-gather", "hardware-gs", "masked-sve"); `None` for
    /// backends without a vector-ISA model (GPU, real execution).
    pub vector_regime: Option<String>,
    /// Measured-pass iteration at which the engine's steady-state
    /// loop closure fired (`None`: full simulation — closure disabled,
    /// no cycle found, or a real-execution backend). Diagnostic only:
    /// counters and bandwidths are identical either way.
    pub closed_at: Option<usize>,
    /// Simulated accesses per modelled second (the run's access count
    /// over its modelled time breakdown) — a deterministic throughput
    /// diagnostic that is byte-identical across `--jobs`, memo, and
    /// plan modes, unlike host wall-clock throughput (which goes to
    /// stderr instead). `0.0` when the backend models no time.
    pub sim_rate: f64,
    /// Input index of the earliest config with the same physics
    /// fingerprint (`None`: this record is the first occurrence). A
    /// pure function of the config list — independent of schedule,
    /// `--jobs` width, and whether the memo cache answered — so output
    /// stays byte-identical across all execution modes.
    pub memo: Option<usize>,
    /// DRAM accesses that found their row already open in the bank's
    /// row buffer (banked model, `sim::dram`). All three DRAM counters
    /// are zero for real-execution backends, which model no DRAM.
    pub dram_row_hits: u64,
    /// Row activations that landed on a different channel×bank-group
    /// than the immediately previous activation — pipelined, cheap.
    pub dram_row_misses: u64,
    /// Row activations serialized behind the previous activation in
    /// the same channel×bank-group — the tRC-limited expensive case.
    pub dram_row_conflicts: u64,
    /// DRAM-touching accesses served by the accessing socket's local
    /// memory (`sim::topology`). Both NUMA counters are zero on
    /// single-socket platforms and for backends without a NUMA model.
    pub numa_local: u64,
    /// DRAM-touching accesses that crossed the socket interconnect.
    pub numa_remote: u64,
}

impl RunRecord {
    /// Machine-readable form.
    pub fn to_json(&self) -> Value {
        obj(&[
            ("name", Value::from(self.name.clone())),
            ("kernel", Value::from(self.kernel.name())),
            ("pattern", Value::from(self.spec.clone())),
            ("delta", Value::from(self.delta)),
            ("count", Value::from(self.count)),
            ("vector_len", Value::from(self.vector_len)),
            ("seconds", Value::from(self.seconds)),
            ("bandwidth_gbs", Value::from(self.bandwidth_gbs)),
            ("read_bytes", Value::from(self.read_bytes as usize)),
            ("write_bytes", Value::from(self.write_bytes as usize)),
            ("bottleneck", Value::from(self.bottleneck.clone())),
            (
                "page_size",
                match &self.page_size {
                    Some(p) => Value::from(p.clone()),
                    None => Value::Null,
                },
            ),
            (
                "tlb_hit_rate",
                match self.tlb_hit_rate {
                    Some(r) => Value::from(r),
                    None => Value::Null,
                },
            ),
            (
                "threads",
                match self.threads {
                    Some(t) => Value::from(t),
                    None => Value::Null,
                },
            ),
            (
                "vector_regime",
                match &self.vector_regime {
                    Some(r) => Value::from(r.clone()),
                    None => Value::Null,
                },
            ),
            (
                "sim-closure",
                match self.closed_at {
                    Some(i) => Value::from(i),
                    None => Value::Null,
                },
            ),
            ("sim-rate", Value::from(self.sim_rate)),
            (
                "memo",
                match self.memo {
                    Some(i) => Value::from(i),
                    None => Value::Null,
                },
            ),
            (
                "dram",
                obj(&[
                    ("row_hits", Value::from(self.dram_row_hits as usize)),
                    (
                        "row_misses",
                        Value::from(self.dram_row_misses as usize),
                    ),
                    (
                        "row_conflicts",
                        Value::from(self.dram_row_conflicts as usize),
                    ),
                ]),
            ),
            (
                "numa",
                // Null on single-socket platforms and NUMA-less
                // backends (nothing was classified), mirroring the
                // other capability-gated keys.
                if self.numa_local + self.numa_remote == 0 {
                    Value::Null
                } else {
                    obj(&[
                        ("local", Value::from(self.numa_local as usize)),
                        ("remote", Value::from(self.numa_remote as usize)),
                    ])
                },
            ),
        ])
    }
}

/// Build the record for a finished (or cache-served) simulation. The
/// backend is consulted only for per-run environment (page size /
/// thread / vector-regime overrides already applied via the setters),
/// so a cached `SimResult` produces the byte-identical record a fresh
/// run would.
fn record_from_sim(
    backend: &dyn Backend,
    name: &str,
    pattern: &Pattern,
    kernel: Kernel,
    r: &SimResult,
    memo: Option<usize>,
) -> RunRecord {
    let payload = pattern.moved_bytes() as u64;
    SIM_ACCESSES.fetch_add(r.counters.accesses, Ordering::Relaxed);
    let modelled = r.breakdown.total();
    RunRecord {
        name: name.to_string(),
        kernel,
        spec: pattern.spec.clone(),
        delta: pattern.delta,
        count: pattern.count,
        vector_len: pattern.vector_len(),
        seconds: r.seconds,
        bandwidth_gbs: r.bandwidth_gbs(),
        read_bytes: payload * kernel.read_streams() as u64,
        write_bytes: payload * kernel.write_streams() as u64,
        bottleneck: r.breakdown.bottleneck().to_string(),
        page_size: backend.page_size().map(|p| p.name().to_string()),
        tlb_hit_rate: r.counters.tlb.hit_rate(),
        threads: backend.threads(),
        vector_regime: backend.vector_regime().map(|r| r.name().to_string()),
        closed_at: r.closed_at_iteration,
        sim_rate: if modelled > 0.0 {
            r.counters.accesses as f64 / modelled
        } else {
            0.0
        },
        memo,
        dram_row_hits: r.counters.dram_row_hits,
        dram_row_misses: r.counters.dram_row_misses,
        dram_row_conflicts: r.counters.dram_row_conflicts,
        numa_local: r.counters.numa_local,
        numa_remote: r.counters.numa_remote,
    }
}

/// Execute one pattern on a backend.
pub fn run_one(
    backend: &mut dyn Backend,
    name: &str,
    pattern: &Pattern,
    kernel: Kernel,
) -> Result<RunRecord> {
    let r = backend.run(pattern, kernel)?;
    Ok(record_from_sim(&*backend, name, pattern, kernel, &r, None))
}

/// Execute one config, applying its overrides and consulting the memo
/// cache when one is supplied *and* the backend is deterministic (real
/// execution must actually run — timings vary run to run). Errors are
/// never served from the cache: a failed leader poisons its cell and
/// every duplicate recomputes, reproducing the exact uncached error.
fn run_one_cached(
    backend: &mut dyn Backend,
    c: &RunConfig,
    fp: u128,
    dup: Option<usize>,
    cache: Option<&MemoCache>,
) -> Result<RunRecord> {
    backend.set_page_size(c.page_size);
    backend.set_threads(c.threads);
    backend.set_vector_regime(c.regime);
    backend.set_numa_placement(c.placement);
    let Some(cache) = cache.filter(|_| backend.deterministic()) else {
        let r = backend.run(&c.pattern, c.kernel)?;
        return Ok(record_from_sim(
            &*backend, &c.name, &c.pattern, c.kernel, &r, dup,
        ));
    };
    let sim = match cache.get_or_reserve(fp) {
        Reservation::Ready(r) => r,
        Reservation::Poisoned => backend.run(&c.pattern, c.kernel)?,
        Reservation::Owner(cell) => {
            // The guard drops — and fills — on success, on the `?`
            // error return, and on a panicking backend alike; only the
            // success path upgrades the published value from poison to
            // a result.
            let mut fill = FillOnDrop { cell, value: None };
            let r = backend.run(&c.pattern, c.kernel)?;
            fill.value = Some(r.clone());
            r
        }
    };
    Ok(record_from_sim(
        &*backend, &c.name, &c.pattern, c.kernel, &sim, dup,
    ))
}

/// Execute a whole JSON config set on one backend. Each config's
/// `"page-size"` / `"threads"` / `"vector-regime"` /
/// `"numa-placement"` override is applied before its run; configs
/// without one run at the backend's configured default.
pub fn run_configs(
    backend: &mut dyn Backend,
    configs: &[RunConfig],
) -> Result<Vec<RunRecord>> {
    let labels = memo::dup_labels(configs);
    configs
        .iter()
        .zip(&labels)
        .map(|(c, &(_, dup))| {
            backend.set_page_size(c.page_size);
            backend.set_threads(c.threads);
            backend.set_vector_regime(c.regime);
            backend.set_numa_placement(c.placement);
            let r = backend.run(&c.pattern, c.kernel)?;
            Ok(record_from_sim(
                &*backend, &c.name, &c.pattern, c.kernel, &r, dup,
            ))
        })
        .collect()
}

/// A thread-safe source of backends for parallel sweeps. Engines are
/// stateful and not `Send`, so every worker builds its own.
pub type BackendFactory<'a> =
    &'a (dyn Fn() -> Result<Box<dyn Backend>> + Sync);

/// Execute a config set on a worker pool (the `--jobs` knob).
///
/// Configs are claimed dynamically off a shared queue; every worker
/// runs them on its own backend built from `factory`, and results land
/// in config order. Because each simulated run resets its engine
/// state, the records — and therefore the rendered table/JSON/CSV
/// output — are byte-identical to serial execution for any `jobs`.
pub fn run_configs_jobs(
    factory: BackendFactory,
    configs: &[RunConfig],
    jobs: usize,
) -> Result<Vec<RunRecord>> {
    run_configs_jobs_stats(factory, configs, jobs).map(|(r, _)| r)
}

/// [`run_configs_jobs`] plus the memo-cache hit/miss counters. The
/// cache obeys the `SPATTER_NO_MEMO=1` escape hatch.
pub fn run_configs_jobs_stats(
    factory: BackendFactory,
    configs: &[RunConfig],
    jobs: usize,
) -> Result<(Vec<RunRecord>, MemoStats)> {
    run_configs_jobs_memo(factory, configs, jobs, memo::memo_enabled_from_env())
}

/// The fully explicit pool entry point: `use_memo` toggles the
/// closure-memo result cache (benchmarks and the determinism property
/// tests drive both sides). Records — and therefore every rendered
/// output — are byte-identical with the cache on or off: a cache hit
/// replays the leader's `SimResult`, which a deterministic backend
/// would have recomputed bit-for-bit anyway.
pub fn run_configs_jobs_memo(
    factory: BackendFactory,
    configs: &[RunConfig],
    jobs: usize,
    use_memo: bool,
) -> Result<(Vec<RunRecord>, MemoStats)> {
    let labels = memo::dup_labels(configs);
    let cache = MemoCache::new();
    let cache_ref = if use_memo { Some(&cache) } else { None };
    let records = parallel_map_with(configs, jobs, factory, |backend, c, i| {
        let (fp, dup) = labels[i];
        run_one_cached(backend.as_mut(), c, fp, dup, cache_ref)
    })?;
    Ok((records, cache.stats()))
}

/// Render records as the CLI table plus the paper's aggregate line —
/// the one formatting path shared by `main`, the suites, and the
/// `--jobs` determinism tests.
pub fn render_table(records: &[RunRecord]) -> String {
    let mut t = Table::new(&[
        "name", "kernel", "V", "delta", "count", "page", "thr", "vec",
        "time (s)", "GB/s", "MiB r/w", "TLB hit%", "DRAM cfl", "loc%",
        "bound by",
    ]);
    let mib = |b: u64| b as f64 / (1u64 << 20) as f64;
    for r in records {
        t.row(&[
            r.name.clone(),
            r.kernel.name().to_string(),
            r.vector_len.to_string(),
            r.delta.to_string(),
            r.count.to_string(),
            r.page_size.clone().unwrap_or_else(|| "-".to_string()),
            r.threads.map(|n| n.to_string()).unwrap_or_else(|| "-".to_string()),
            r.vector_regime.clone().unwrap_or_else(|| "-".to_string()),
            format!("{:.6}", r.seconds),
            format!("{:.2}", r.bandwidth_gbs),
            format!("{:.0}/{:.0}", mib(r.read_bytes), mib(r.write_bytes)),
            match r.tlb_hit_rate {
                Some(rate) => format!("{:.1}", rate * 100.0),
                None => "-".to_string(),
            },
            // Backends without a DRAM model (real execution) touch no
            // bank counter at all; render "-" rather than a bogus 0.
            if r.dram_row_hits + r.dram_row_misses + r.dram_row_conflicts
                == 0
            {
                "-".to_string()
            } else {
                r.dram_row_conflicts.to_string()
            },
            // Local fraction of the NUMA-classified traffic; "-" on
            // single-socket platforms and NUMA-less backends.
            if r.numa_local + r.numa_remote == 0 {
                "-".to_string()
            } else {
                format!(
                    "{:.1}",
                    r.numa_local as f64 * 100.0
                        / (r.numa_local + r.numa_remote) as f64
                )
            },
            r.bottleneck.clone(),
        ]);
    }
    let mut out = t.render();
    if records.len() > 1 {
        if let Some(agg) = Aggregate::from_records(records) {
            out.push_str(&format!(
                "aggregate over {} configs: min {:.2} GB/s, max {:.2} GB/s, \
                 harmonic mean {:.2} GB/s\n",
                agg.runs, agg.min_gbs, agg.max_gbs, agg.harmonic_mean_gbs
            ));
        }
    }
    out
}

/// Incremental writer of the `--json-out` document. The emitted chunks
/// concatenate to exactly what [`render_json`] produces for the same
/// records — [`render_json`] itself drives this writer, so the batch
/// and `--stream` paths cannot drift — while holding only the running
/// aggregate folds, not the records. The `"runs"` array comes first
/// and `"aggregate"` last, which is what makes the document streamable
/// at all: the aggregate isn't known until the final record retires.
struct JsonDocWriter {
    n: usize,
    min: f64,
    max: f64,
    /// In-order sum of 1/bandwidth — the same left-to-right fold
    /// `stats::harmonic_mean` performs, so the streamed aggregate is
    /// bit-exact against the batch one.
    inv_sum: f64,
    /// `stats::harmonic_mean` refuses sets with a non-positive member;
    /// mirror that by omitting the aggregate entirely.
    any_nonpositive: bool,
}

impl JsonDocWriter {
    fn new() -> JsonDocWriter {
        JsonDocWriter {
            n: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            inv_sum: 0.0,
            any_nonpositive: false,
        }
    }

    /// The chunk for `rec` (document opener included on the first
    /// call), folding the record into the running aggregate.
    fn record_chunk(&mut self, rec: &RunRecord) -> String {
        let mut out = String::new();
        if self.n == 0 {
            out.push_str("{\n  \"runs\": [");
        } else {
            out.push(',');
        }
        out.push_str("\n    ");
        out.push_str(&json::to_string_pretty_at(&rec.to_json(), 2));
        let bw = rec.bandwidth_gbs;
        self.min = self.min.min(bw);
        self.max = self.max.max(bw);
        if bw <= 0.0 {
            self.any_nonpositive = true;
        } else {
            self.inv_sum += 1.0 / bw;
        }
        self.n += 1;
        out
    }

    /// Close the array, append the aggregate (when every bandwidth was
    /// positive, matching [`Aggregate::from_records`]), close the
    /// document.
    fn finish(&self) -> String {
        if self.n == 0 {
            return "{\n  \"runs\": []\n}\n".to_string();
        }
        let mut out = String::from("\n  ]");
        if !self.any_nonpositive {
            let agg = Aggregate {
                runs: self.n,
                min_gbs: self.min,
                max_gbs: self.max,
                harmonic_mean_gbs: self.n as f64 / self.inv_sum,
            };
            out.push_str(",\n  \"aggregate\": ");
            out.push_str(&json::to_string_pretty_at(&agg.to_json(), 1));
        }
        out.push_str("\n}\n");
        out
    }
}

/// Render records as the machine-readable JSON document (`--json-out`).
pub fn render_json(records: &[RunRecord]) -> String {
    let mut w = JsonDocWriter::new();
    let mut out = String::new();
    for r in records {
        out.push_str(&w.record_chunk(r));
    }
    out.push_str(&w.finish());
    out
}

/// What a [`run_configs_stream`] campaign reports besides the chunks
/// it emitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamSummary {
    /// Records emitted (== configs executed on success).
    pub records: usize,
    /// Memo-cache counters (zero when the cache was off).
    pub memo: MemoStats,
}

/// The `--stream` run mode: execute configs as `source` yields them,
/// emitting JSON-document chunks in input order through `emit_chunk`.
/// Memory is O(jobs + reorder window) — the config list, the records,
/// and the output document are never materialized — yet the
/// concatenated chunks are byte-identical to [`render_json`] over the
/// batch-executed config list, and duplicate labels + memo behavior
/// match the batch path exactly (labeling happens on the producer side,
/// in input order, before any scheduling nondeterminism).
///
/// On a mid-stream failure the chunks already emitted stand (a partial
/// document) and the lowest-index error is returned.
pub fn run_configs_stream<S, E>(
    factory: BackendFactory,
    source: S,
    jobs: usize,
    use_memo: bool,
    mut emit_chunk: E,
) -> Result<StreamSummary>
where
    S: Iterator<Item = Result<RunConfig>> + Send,
    E: FnMut(&str) -> Result<()>,
{
    let cache = MemoCache::new();
    let cache_ref = if use_memo { Some(&cache) } else { None };
    // Label on the producer thread as items are pulled: first-seen
    // fingerprint indices accumulate in input order, so the `"memo"`
    // key is identical to what batch `dup_labels` would compute.
    let mut first: HashMap<u128, usize> = HashMap::new();
    let mut next_index = 0usize;
    let labeled = source.map(move |r| {
        r.map(|c| {
            let fp = memo::config_fingerprint(&c);
            let i = next_index;
            next_index += 1;
            let dup = match first.entry(fp) {
                Entry::Occupied(e) => Some(*e.get()),
                Entry::Vacant(e) => {
                    e.insert(i);
                    None
                }
            };
            (c, fp, dup)
        })
    });
    let mut writer = JsonDocWriter::new();
    let emitted = parallel_stream_with(
        labeled,
        jobs,
        stream_window(jobs),
        factory,
        |backend, (c, fp, dup), _| {
            run_one_cached(backend.as_mut(), c, *fp, *dup, cache_ref)
        },
        |_, rec| emit_chunk(&writer.record_chunk(&rec)),
    )?;
    emit_chunk(&writer.finish())?;
    Ok(StreamSummary {
        records: emitted,
        memo: cache.stats(),
    })
}

/// The paper's multi-run aggregate: min/max bandwidth and the harmonic
/// mean across configurations.
#[derive(Debug, Clone, PartialEq)]
pub struct Aggregate {
    pub runs: usize,
    pub min_gbs: f64,
    pub max_gbs: f64,
    pub harmonic_mean_gbs: f64,
}

impl Aggregate {
    pub fn from_records(records: &[RunRecord]) -> Option<Aggregate> {
        let bws: Vec<f64> = records.iter().map(|r| r.bandwidth_gbs).collect();
        let (min, max) = stats::min_max(&bws)?;
        Some(Aggregate {
            runs: records.len(),
            min_gbs: min,
            max_gbs: max,
            harmonic_mean_gbs: stats::harmonic_mean(&bws)?,
        })
    }

    pub fn to_json(&self) -> Value {
        obj(&[
            ("runs", Value::from(self.runs)),
            ("min_gbs", Value::from(self.min_gbs)),
            ("max_gbs", Value::from(self.max_gbs)),
            ("harmonic_mean_gbs", Value::from(self.harmonic_mean_gbs)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::OpenMpSim;
    use crate::coordinator::parse_config_text;
    use crate::platforms;

    fn backend() -> OpenMpSim {
        OpenMpSim::new(&platforms::by_name("skx").unwrap())
    }

    #[test]
    fn run_one_produces_record() {
        let mut b = backend();
        let p = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(1 << 16);
        let r = run_one(&mut b, "stream-like", &p, Kernel::Gather).unwrap();
        assert_eq!(r.name, "stream-like");
        assert!(r.bandwidth_gbs > 10.0);
        assert_eq!(r.vector_len, 8);
        assert_eq!(r.bottleneck, "dram-bw");
        assert_eq!(r.page_size.as_deref(), Some("4KB"));
        let rate = r.tlb_hit_rate.unwrap();
        assert!((0.0..=1.0).contains(&rate));
    }

    #[test]
    fn per_run_page_size_applies_and_resets() {
        // A huge-delta gather at 2 MiB must report fewer TLB misses
        // than the identical 4 KiB run, and a following config without
        // the key must run at the default again.
        let cfgs = parse_config_text(
            r#"[
              {"name": "huge-4k", "kernel": "Gather",
               "pattern": "UNIFORM:16:512", "delta": 16384,
               "count": 16384},
              {"name": "huge-2m", "kernel": "Gather",
               "pattern": "UNIFORM:16:512", "delta": 16384,
               "count": 16384, "page-size": "2MB"},
              {"name": "huge-again-4k", "kernel": "Gather",
               "pattern": "UNIFORM:16:512", "delta": 16384,
               "count": 16384}
            ]"#,
        )
        .unwrap();
        let mut b = backend();
        let recs = run_configs(&mut b, &cfgs).unwrap();
        assert_eq!(recs[0].page_size.as_deref(), Some("4KB"));
        assert_eq!(recs[1].page_size.as_deref(), Some("2MB"));
        assert_eq!(recs[2].page_size.as_deref(), Some("4KB"));
        let hit = |i: usize| recs[i].tlb_hit_rate.unwrap();
        assert!(
            hit(1) > hit(0) + 0.5,
            "2MB hit rate {:.3} should dwarf 4KB {:.3}",
            hit(1),
            hit(0)
        );
        assert!((hit(0) - hit(2)).abs() < 1e-9, "default must be restored");
    }

    #[test]
    fn config_set_runs_and_aggregates() {
        let cfgs = parse_config_text(
            r#"[
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 65536},
              {"kernel": "Gather", "pattern": "UNIFORM:8:8", "delta": 64,
               "count": 65536}
            ]"#,
        )
        .unwrap();
        let mut b = backend();
        let recs = run_configs(&mut b, &cfgs).unwrap();
        assert_eq!(recs.len(), 2);
        // stride-1 beats stride-8
        assert!(recs[0].bandwidth_gbs > recs[1].bandwidth_gbs);
        let agg = Aggregate::from_records(&recs).unwrap();
        assert_eq!(agg.runs, 2);
        assert!(agg.min_gbs <= agg.harmonic_mean_gbs);
        assert!(agg.harmonic_mean_gbs <= agg.max_gbs);
    }

    #[test]
    fn record_json_shape() {
        let mut b = backend();
        let p = Pattern::parse("UNIFORM:4:2")
            .unwrap()
            .with_delta(8)
            .with_count(1024);
        let r = run_one(&mut b, "x", &p, Kernel::Scatter).unwrap();
        let j = r.to_json();
        assert_eq!(j.get("kernel").unwrap().as_str().unwrap(), "Scatter");
        assert!(j.get("bandwidth_gbs").unwrap().as_f64().unwrap() > 0.0);
        // The thread-count column rides along (SKX default: 16).
        assert_eq!(j.get("threads").unwrap().as_usize().unwrap(), 16);
        // So does the vector regime (SKX native: AVX-512 G/S).
        assert_eq!(
            j.get("vector_regime").unwrap().as_str().unwrap(),
            "hardware-gs"
        );
        // The closure diagnostic rides along too (Null when the pass
        // ran in full — either way the key is present).
        assert!(j.get("sim-closure").is_some());
        // The NUMA object is present but Null on a single-socket
        // platform: nothing was classified local or remote.
        assert_eq!(j.get("numa").unwrap(), &Value::Null);
    }

    #[test]
    fn numa_record_fields_on_a_two_socket_platform() {
        let p = platforms::by_name("skx-2s").unwrap();
        let mut b = OpenMpSim::new(&p);
        // A DRAM-heavy gather under interleave splits pages across the
        // two nodes: both classes show up in the record and the JSON.
        b.set_numa_placement(Some(crate::sim::NumaPlacement::Interleave));
        let pat = Pattern::parse("UNIFORM:8:8")
            .unwrap()
            .with_delta(64)
            .with_count(1 << 16);
        let r = run_one(&mut b, "interleaved", &pat, Kernel::Gather).unwrap();
        assert!(r.numa_local > 0, "{r:?}");
        assert!(r.numa_remote > 0, "{r:?}");
        let j = r.to_json();
        let numa = j.get("numa").unwrap();
        assert_eq!(
            numa.get("local").unwrap().as_usize().unwrap() as u64,
            r.numa_local
        );
        assert_eq!(
            numa.get("remote").unwrap().as_usize().unwrap() as u64,
            r.numa_remote
        );
        let expected_cell = format!(
            "{:.1}",
            r.numa_local as f64 * 100.0
                / (r.numa_local + r.numa_remote) as f64
        );
        let table = render_table(&[r]);
        assert!(table.contains("| loc% "), "{table}");
        assert!(table.contains(&expected_cell), "{table}");
    }

    fn skx_factory() -> crate::error::Result<Box<dyn crate::backends::Backend>>
    {
        Ok(Box::new(backend()))
    }

    #[test]
    fn parallel_jobs_match_serial_records() {
        let cfgs = parse_config_text(
            r#"[
              {"name": "a", "kernel": "Gather", "pattern": "UNIFORM:8:1",
               "delta": 8, "count": 16384},
              {"name": "b", "kernel": "Gather", "pattern": "UNIFORM:8:8",
               "delta": 64, "count": 16384},
              {"name": "c", "kernel": "Scatter", "pattern": "UNIFORM:8:2",
               "delta": 16, "count": 16384, "threads": 4},
              {"name": "d", "kernel": "Gather", "pattern": "UNIFORM:16:512",
               "delta": 16384, "count": 8192, "page-size": "2MB"}
            ]"#,
        )
        .unwrap();
        let serial = run_configs_jobs(&skx_factory, &cfgs, 1).unwrap();
        let par = run_configs_jobs(&skx_factory, &cfgs, 8).unwrap();
        assert_eq!(render_table(&serial), render_table(&par));
        assert_eq!(render_json(&serial), render_json(&par));
        // And both match the legacy single-backend path.
        let mut b = backend();
        let legacy = run_configs(&mut b, &cfgs).unwrap();
        assert_eq!(render_table(&legacy), render_table(&serial));
    }

    #[test]
    fn per_run_threads_applies_and_resets() {
        let cfgs = parse_config_text(
            r#"[
              {"name": "t-default", "kernel": "Gather",
               "pattern": "UNIFORM:8:1", "delta": 8, "count": 16384},
              {"name": "t-1", "kernel": "Gather", "pattern": "UNIFORM:8:1",
               "delta": 8, "count": 16384, "threads": 1},
              {"name": "t-default-again", "kernel": "Gather",
               "pattern": "UNIFORM:8:1", "delta": 8, "count": 16384}
            ]"#,
        )
        .unwrap();
        let mut b = backend();
        let recs = run_configs(&mut b, &cfgs).unwrap();
        assert_eq!(recs[0].threads, Some(16));
        assert_eq!(recs[1].threads, Some(1));
        assert_eq!(recs[2].threads, Some(16), "default must be restored");
        // One thread cannot saturate DRAM: stream gather is slower.
        assert!(recs[1].bandwidth_gbs < recs[0].bandwidth_gbs);
        assert_eq!(recs[0].bandwidth_gbs, recs[2].bandwidth_gbs);
    }

    #[test]
    fn per_run_vector_regime_applies_and_resets() {
        // A scalar override at small stride must lose to the backend's
        // native AVX-512 G/S path, and the following config without
        // the key must run at the native regime again.
        let cfgs = parse_config_text(
            r#"[
              {"name": "r-default", "kernel": "Gather",
               "pattern": "UNIFORM:8:2", "delta": 16, "count": 16384},
              {"name": "r-scalar", "kernel": "Gather",
               "pattern": "UNIFORM:8:2", "delta": 16, "count": 16384,
               "vector-regime": "scalar"},
              {"name": "r-default-again", "kernel": "Gather",
               "pattern": "UNIFORM:8:2", "delta": 16, "count": 16384}
            ]"#,
        )
        .unwrap();
        let mut b = backend();
        let recs = run_configs(&mut b, &cfgs).unwrap();
        assert_eq!(recs[0].vector_regime.as_deref(), Some("hardware-gs"));
        assert_eq!(recs[1].vector_regime.as_deref(), Some("scalar"));
        assert_eq!(
            recs[2].vector_regime.as_deref(),
            Some("hardware-gs"),
            "default must be restored"
        );
        assert!(recs[1].bandwidth_gbs < recs[0].bandwidth_gbs);
        assert_eq!(recs[0].bandwidth_gbs, recs[2].bandwidth_gbs);
        // The pool path agrees byte-for-byte with the serial one.
        let serial = run_configs_jobs(&skx_factory, &cfgs, 1).unwrap();
        let par = run_configs_jobs(&skx_factory, &cfgs, 4).unwrap();
        assert_eq!(render_json(&serial), render_json(&par));
        assert_eq!(render_table(&recs), render_table(&par));
    }

    #[test]
    fn render_table_has_thread_and_page_columns() {
        let mut b = backend();
        let p = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(4096);
        let r = run_one(&mut b, "row", &p, Kernel::Gather).unwrap();
        let table = render_table(&[r.clone()]);
        assert!(table.contains("| thr "), "{table}");
        assert!(table.contains("| vec "), "{table}");
        assert!(table.contains("hardware-gs"), "{table}");
        assert!(table.contains("| page "), "{table}");
        assert!(table.contains("| MiB r/w "), "{table}");
        assert!(table.contains("| DRAM cfl "), "{table}");
        assert!(table.contains("| loc% "), "{table}");
        assert!(table.contains("| 16 "), "{table}");
        assert!(!table.contains("aggregate over"), "single run: no aggregate");
        // A simulated run always opens at least one DRAM row, so the
        // conflict cell is numeric; a record with no DRAM activity at
        // all (real execution) renders "-" instead of a bogus zero.
        assert!(r.dram_row_hits + r.dram_row_misses > 0);
        let mut blank = r;
        blank.dram_row_hits = 0;
        blank.dram_row_misses = 0;
        blank.dram_row_conflicts = 0;
        let table = render_table(&[blank]);
        assert!(table.contains(" - "), "{table}");
    }

    #[test]
    fn per_side_bytes_follow_the_kernel() {
        let mut b = backend();
        let p = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(4096);
        let payload = p.moved_bytes() as u64;
        let g = run_one(&mut b, "g", &p, Kernel::Gather).unwrap();
        assert_eq!((g.read_bytes, g.write_bytes), (payload, 0));
        let s = run_one(&mut b, "s", &p, Kernel::Scatter).unwrap();
        assert_eq!((s.read_bytes, s.write_bytes), (0, payload));
        let gs_pat = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_gs_scatter((0..8).collect())
            .with_delta(8)
            .with_count(4096);
        let gs = run_one(&mut b, "gs", &gs_pat, Kernel::GS).unwrap();
        assert_eq!((gs.read_bytes, gs.write_bytes), (payload, payload));
        // Baseline kernels: per-operand payloads ride along too.
        use crate::pattern::StreamOp;
        let dense = Pattern::dense(8, 4096);
        let dp = dense.moved_bytes() as u64;
        let copy =
            run_one(&mut b, "c", &dense, Kernel::Stream(StreamOp::Copy))
                .unwrap();
        assert_eq!((copy.read_bytes, copy.write_bytes), (dp, dp));
        let triad =
            run_one(&mut b, "t", &dense, Kernel::Stream(StreamOp::Triad))
                .unwrap();
        assert_eq!((triad.read_bytes, triad.write_bytes), (2 * dp, dp));
        let gups_pat = Pattern::gups(1 << 16, 1024);
        let gup = run_one(&mut b, "u", &gups_pat, Kernel::Gups).unwrap();
        let up = gups_pat.moved_bytes() as u64;
        assert_eq!((gup.read_bytes, gup.write_bytes), (up, up));
        // And the JSON record carries both sides.
        let j = gs.to_json();
        assert_eq!(j.get("kernel").unwrap().as_str().unwrap(), "GS");
        assert_eq!(
            j.get("read_bytes").unwrap().as_usize().unwrap() as u64,
            payload
        );
        assert_eq!(
            j.get("write_bytes").unwrap().as_usize().unwrap() as u64,
            payload
        );
    }

    #[test]
    fn gs_configs_run_through_the_jobs_pool_byte_identically() {
        let cfgs = parse_config_text(
            r#"[
              {"name": "gs-u", "kernel": "GS",
               "pattern-gather": "UNIFORM:8:4",
               "pattern-scatter": "UNIFORM:8:1", "delta": 32,
               "count": 8192},
              {"name": "g", "kernel": "Gather", "pattern": "UNIFORM:8:4",
               "delta": 32, "count": 8192},
              {"name": "gs-d0", "kernel": "GS",
               "pattern-gather": [0, 1, 2, 3],
               "pattern-scatter": [0, 24, 48, 72], "delta": 0,
               "count": 4096, "threads": 4}
            ]"#,
        )
        .unwrap();
        let serial = run_configs_jobs(&skx_factory, &cfgs, 1).unwrap();
        let par = run_configs_jobs(&skx_factory, &cfgs, 8).unwrap();
        assert_eq!(render_table(&serial), render_table(&par));
        assert_eq!(render_json(&serial), render_json(&par));
        // The GS run is slower than its gather half alone.
        assert!(serial[0].bandwidth_gbs <= serial[1].bandwidth_gbs * 1.02);
    }

    /// 6 configs, 3 distinct fingerprints: [A, B, A', C, B, A] where
    /// A' is A under a different display name (still a cache twin).
    const DUP_HEAVY: &str = r#"[
      {"name": "a0", "kernel": "Gather", "pattern": "UNIFORM:8:1",
       "delta": 8, "count": 16384},
      {"name": "b0", "kernel": "Scatter", "pattern": "UNIFORM:8:2",
       "delta": 16, "count": 16384},
      {"name": "a-renamed", "kernel": "Gather", "pattern": "UNIFORM:8:1",
       "delta": 8, "count": 16384},
      {"name": "c0", "kernel": "Gather", "pattern": "UNIFORM:16:512",
       "delta": 16384, "count": 8192, "page-size": "2MB"},
      {"name": "b0", "kernel": "Scatter", "pattern": "UNIFORM:8:2",
       "delta": 16, "count": 16384},
      {"name": "a0", "kernel": "Gather", "pattern": "UNIFORM:8:1",
       "delta": 8, "count": 16384}
    ]"#;

    #[test]
    fn memo_on_off_and_jobs_widths_are_byte_identical() {
        let cfgs = parse_config_text(DUP_HEAVY).unwrap();
        let (off, s_off) =
            run_configs_jobs_memo(&skx_factory, &cfgs, 1, false).unwrap();
        let (on1, s_on1) =
            run_configs_jobs_memo(&skx_factory, &cfgs, 1, true).unwrap();
        let (on8, s_on8) =
            run_configs_jobs_memo(&skx_factory, &cfgs, 8, true).unwrap();
        assert_eq!(s_off, MemoStats::default(), "cache off counts nothing");
        assert_eq!(render_json(&off), render_json(&on1));
        assert_eq!(render_json(&off), render_json(&on8));
        assert_eq!(render_table(&off), render_table(&on8));
        // Every config performs exactly one lookup; each of the 3
        // distinct fingerprints misses once (its leader), the other 3
        // lookups hit — deterministically, at any width.
        for s in [s_on1, s_on8] {
            assert_eq!((s.hits, s.misses), (3, 3), "{s:?}");
            assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        }
        // Duplicate labels point at the earliest twin, cache on or off.
        let memos: Vec<Option<usize>> = off.iter().map(|r| r.memo).collect();
        assert_eq!(
            memos,
            vec![None, None, Some(0), None, Some(1), Some(0)]
        );
        assert_eq!(memos, on8.iter().map(|r| r.memo).collect::<Vec<_>>());
        // Twins share physics but keep their own display name.
        assert_eq!(off[2].bandwidth_gbs, off[0].bandwidth_gbs);
        assert_eq!(off[2].name, "a-renamed");
    }

    #[test]
    fn record_json_carries_the_memo_key() {
        let cfgs = parse_config_text(DUP_HEAVY).unwrap();
        let (recs, _) =
            run_configs_jobs_memo(&skx_factory, &cfgs, 2, true).unwrap();
        assert_eq!(
            recs[0].to_json().get("memo").unwrap(),
            &Value::Null,
            "first occurrence"
        );
        assert_eq!(
            recs[5].to_json().get("memo").unwrap().as_usize().unwrap(),
            0,
            "duplicate points at its earliest twin"
        );
    }

    #[test]
    fn render_json_streams_runs_first_then_aggregate() {
        assert_eq!(render_json(&[]), "{\n  \"runs\": []\n}\n");
        let cfgs = parse_config_text(DUP_HEAVY).unwrap();
        let recs = run_configs_jobs(&skx_factory, &cfgs, 2).unwrap();
        let doc = render_json(&recs);
        assert!(doc.starts_with("{\n  \"runs\": ["), "{doc}");
        assert!(doc.ends_with("\n}\n"), "{doc}");
        // Still a valid document with the same values the old
        // BTreeMap-ordered renderer carried.
        let v = crate::json::parse(&doc).unwrap();
        assert_eq!(v.get("runs").unwrap().as_array().unwrap().len(), 6);
        let agg = Aggregate::from_records(&recs).unwrap();
        assert_eq!(
            v.get("aggregate")
                .unwrap()
                .get("harmonic_mean_gbs")
                .unwrap()
                .as_f64()
                .unwrap(),
            agg.harmonic_mean_gbs
        );
        // runs precede the aggregate in the byte stream.
        assert!(
            doc.find("\"runs\"").unwrap() < doc.find("\"aggregate\"").unwrap()
        );
    }

    #[test]
    fn stream_mode_is_byte_identical_to_batch() {
        let cfgs = parse_config_text(DUP_HEAVY).unwrap();
        let expect = render_json(&run_configs_jobs(&skx_factory, &cfgs, 1).unwrap());
        for jobs in [1, 2, 5] {
            for use_memo in [false, true] {
                let src = crate::coordinator::stream_config_reader(
                    std::io::Cursor::new(DUP_HEAVY),
                );
                let mut out = String::new();
                let sum = run_configs_stream(
                    &skx_factory,
                    src,
                    jobs,
                    use_memo,
                    |chunk| {
                        out.push_str(chunk);
                        Ok(())
                    },
                )
                .unwrap();
                assert_eq!(out, expect, "jobs={jobs} memo={use_memo}");
                assert_eq!(sum.records, cfgs.len());
                if use_memo {
                    assert_eq!((sum.memo.hits, sum.memo.misses), (3, 3));
                } else {
                    assert_eq!(sum.memo, MemoStats::default());
                }
            }
        }
    }

    /// A backend whose `run` announces itself on a channel, waits for
    /// the gate, then panics — a stand-in for a backend bug striking
    /// the memo leader mid-compute.
    struct PanickingBackend<'a> {
        started: std::sync::mpsc::Sender<()>,
        gate: &'a std::sync::Barrier,
    }

    impl Backend for PanickingBackend<'_> {
        fn name(&self) -> &str {
            "panicking-mock"
        }

        fn run(
            &mut self,
            _pattern: &Pattern,
            _kernel: Kernel,
        ) -> Result<SimResult> {
            self.started.send(()).unwrap();
            self.gate.wait();
            panic!("injected backend bug");
        }
    }

    /// Regression: a leader that *panicked* inside `Backend::run` never
    /// reached `MemoCell::fill`, leaving the cell pending and every
    /// duplicate config blocked on it forever (`MemoCell::wait` has no
    /// timeout). The fill guard must poison the cell during unwind so
    /// blocked waiters wake and recompute. Pre-fix, this test hangs at
    /// `waiter.join()`.
    #[test]
    fn leader_panic_poisons_the_cell_and_wakes_waiters() {
        let cfgs = parse_config_text(
            r#"[{"name": "dup", "kernel": "Gather",
                 "pattern": "UNIFORM:8:1", "delta": 8, "count": 4096}]"#,
        )
        .unwrap();
        let c = &cfgs[0];
        let fp = memo::config_fingerprint(c);
        let cache = MemoCache::new();
        let gate = std::sync::Barrier::new(2);
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        std::thread::scope(|s| {
            let (gate, cache) = (&gate, &cache);
            let leader = s.spawn(move || {
                let mut b = PanickingBackend {
                    started: started_tx,
                    gate,
                };
                run_one_cached(&mut b, c, fp, None, Some(cache))
            });
            // Once run() has announced itself the leader owns the
            // cell, so the waiter spawned now can only block on it.
            started_rx.recv().unwrap();
            let waiter = s.spawn(move || {
                let mut b = backend();
                run_one_cached(&mut b, c, fp, None, Some(cache))
            });
            // Give the waiter time to park on the pending cell, then
            // release the leader into its panic.
            std::thread::sleep(std::time::Duration::from_millis(50));
            gate.wait();
            assert!(leader.join().is_err(), "leader must have panicked");
            let rec = waiter.join().unwrap().unwrap();
            assert_eq!(rec.name, "dup");
            assert!(rec.bandwidth_gbs > 0.0, "waiter recomputed after poison");
        });
        // Leader reservation + waiter's poisoned rerun: two misses,
        // and the panic cached nothing a later twin could hit.
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 2), "{s:?}");
    }

    #[test]
    fn stream_failure_keeps_the_emitted_prefix_and_lowest_error() {
        let text = r#"[
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
           "count": 4096},
          {"kernel": "Gather"},
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
           "count": 4096}
        ]"#;
        let src = crate::coordinator::stream_config_reader(
            std::io::Cursor::new(text),
        );
        let mut out = String::new();
        let err = run_configs_stream(&skx_factory, src, 2, true, |chunk| {
            out.push_str(chunk);
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("run 1"), "{err}");
        // The record below the failure made it out; the document is
        // left partial (no closing brace).
        assert!(out.starts_with("{\n  \"runs\": ["), "{out}");
        assert!(out.contains("UNIFORM:8:1"), "{out}");
        assert!(!out.ends_with("\n}\n"), "{out}");
    }
}
