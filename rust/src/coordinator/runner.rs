//! Run execution + aggregation (paper §3.5 output protocol), plus the
//! shared table/JSON renderers — one formatting path for the CLI, the
//! suites, and the determinism tests, so `--jobs N` output can be
//! byte-compared against serial output.

use crate::backends::Backend;
use crate::error::Result;
use crate::json::{self, obj, Value};
use crate::pattern::{Kernel, Pattern};
use crate::report::Table;
use crate::stats;

use super::schedule::parallel_map_with;
use super::RunConfig;

/// The outcome of one pattern run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub name: String,
    pub kernel: Kernel,
    pub spec: String,
    pub delta: i64,
    pub count: usize,
    pub vector_len: usize,
    pub seconds: f64,
    pub bandwidth_gbs: f64,
    /// Useful payload bytes moved by the read stream(s): the per-
    /// stream payload times the kernel's read-stream count (Gather/GS/
    /// GUPS/Copy/Scale 1x, Add/Triad 2x, Scatter 0).
    pub read_bytes: u64,
    /// Useful payload bytes moved by the write stream: the per-stream
    /// payload for every kernel except Gather (0). GS and GUPS move
    /// their payload on *both* streams while the headline
    /// `bandwidth_gbs` counts it once (bounded by the component
    /// kernels); the STREAM tetrad's headline counts every operand
    /// stream, per STREAM's own convention.
    pub write_bytes: u64,
    /// Which simulated resource bound the run ("dram-bw", "tlb", ...);
    /// empty for real-execution backends.
    pub bottleneck: String,
    /// Translation page size the run modelled ("4KB", "2MB", ...);
    /// `None` for backends without a virtual-memory model.
    pub page_size: Option<String>,
    /// TLB hit fraction over the run's translations; `None` when the
    /// backend translated nothing (real execution).
    pub tlb_hit_rate: Option<f64>,
    /// Simulated OpenMP thread count the run modelled; `None` for
    /// backends without a thread model (GPU, real execution).
    pub threads: Option<usize>,
    /// Measured-pass iteration at which the engine's steady-state
    /// loop closure fired (`None`: full simulation — closure disabled,
    /// no cycle found, or a real-execution backend). Diagnostic only:
    /// counters and bandwidths are identical either way.
    pub closed_at: Option<usize>,
}

impl RunRecord {
    /// Machine-readable form.
    pub fn to_json(&self) -> Value {
        obj(&[
            ("name", Value::from(self.name.clone())),
            ("kernel", Value::from(self.kernel.name())),
            ("pattern", Value::from(self.spec.clone())),
            ("delta", Value::from(self.delta)),
            ("count", Value::from(self.count)),
            ("vector_len", Value::from(self.vector_len)),
            ("seconds", Value::from(self.seconds)),
            ("bandwidth_gbs", Value::from(self.bandwidth_gbs)),
            ("read_bytes", Value::from(self.read_bytes as usize)),
            ("write_bytes", Value::from(self.write_bytes as usize)),
            ("bottleneck", Value::from(self.bottleneck.clone())),
            (
                "page_size",
                match &self.page_size {
                    Some(p) => Value::from(p.clone()),
                    None => Value::Null,
                },
            ),
            (
                "tlb_hit_rate",
                match self.tlb_hit_rate {
                    Some(r) => Value::from(r),
                    None => Value::Null,
                },
            ),
            (
                "threads",
                match self.threads {
                    Some(t) => Value::from(t),
                    None => Value::Null,
                },
            ),
            (
                "sim-closure",
                match self.closed_at {
                    Some(i) => Value::from(i),
                    None => Value::Null,
                },
            ),
        ])
    }
}

/// Execute one pattern on a backend.
pub fn run_one(
    backend: &mut dyn Backend,
    name: &str,
    pattern: &Pattern,
    kernel: Kernel,
) -> Result<RunRecord> {
    let r = backend.run(pattern, kernel)?;
    let payload = pattern.moved_bytes() as u64;
    Ok(RunRecord {
        name: name.to_string(),
        kernel,
        spec: pattern.spec.clone(),
        delta: pattern.delta,
        count: pattern.count,
        vector_len: pattern.vector_len(),
        seconds: r.seconds,
        bandwidth_gbs: r.bandwidth_gbs(),
        read_bytes: payload * kernel.read_streams() as u64,
        write_bytes: payload * kernel.write_streams() as u64,
        bottleneck: r.breakdown.bottleneck().to_string(),
        page_size: backend.page_size().map(|p| p.name().to_string()),
        tlb_hit_rate: r.counters.tlb.hit_rate(),
        threads: backend.threads(),
        closed_at: r.closed_at_iteration,
    })
}

/// Execute a whole JSON config set on one backend. Each config's
/// `"page-size"` / `"threads"` override is applied before its run;
/// configs without one run at the backend's configured default.
pub fn run_configs(
    backend: &mut dyn Backend,
    configs: &[RunConfig],
) -> Result<Vec<RunRecord>> {
    configs
        .iter()
        .map(|c| {
            backend.set_page_size(c.page_size);
            backend.set_threads(c.threads);
            run_one(backend, &c.name, &c.pattern, c.kernel)
        })
        .collect()
}

/// A thread-safe source of backends for parallel sweeps. Engines are
/// stateful and not `Send`, so every worker builds its own.
pub type BackendFactory<'a> =
    &'a (dyn Fn() -> Result<Box<dyn Backend>> + Sync);

/// Execute a config set on a worker pool (the `--jobs` knob).
///
/// Configs are claimed dynamically off a shared queue; every worker
/// runs them on its own backend built from `factory`, and results land
/// in config order. Because each simulated run resets its engine
/// state, the records — and therefore the rendered table/JSON/CSV
/// output — are byte-identical to serial execution for any `jobs`.
pub fn run_configs_jobs(
    factory: BackendFactory,
    configs: &[RunConfig],
    jobs: usize,
) -> Result<Vec<RunRecord>> {
    parallel_map_with(configs, jobs, factory, |backend, c, _| {
        backend.set_page_size(c.page_size);
        backend.set_threads(c.threads);
        run_one(backend.as_mut(), &c.name, &c.pattern, c.kernel)
    })
}

/// Render records as the CLI table plus the paper's aggregate line —
/// the one formatting path shared by `main`, the suites, and the
/// `--jobs` determinism tests.
pub fn render_table(records: &[RunRecord]) -> String {
    let mut t = Table::new(&[
        "name", "kernel", "V", "delta", "count", "page", "thr", "time (s)",
        "GB/s", "MiB r/w", "TLB hit%", "bound by",
    ]);
    let mib = |b: u64| b as f64 / (1u64 << 20) as f64;
    for r in records {
        t.row(&[
            r.name.clone(),
            r.kernel.name().to_string(),
            r.vector_len.to_string(),
            r.delta.to_string(),
            r.count.to_string(),
            r.page_size.clone().unwrap_or_else(|| "-".to_string()),
            r.threads.map(|n| n.to_string()).unwrap_or_else(|| "-".to_string()),
            format!("{:.6}", r.seconds),
            format!("{:.2}", r.bandwidth_gbs),
            format!("{:.0}/{:.0}", mib(r.read_bytes), mib(r.write_bytes)),
            match r.tlb_hit_rate {
                Some(rate) => format!("{:.1}", rate * 100.0),
                None => "-".to_string(),
            },
            r.bottleneck.clone(),
        ]);
    }
    let mut out = t.render();
    if records.len() > 1 {
        if let Some(agg) = Aggregate::from_records(records) {
            out.push_str(&format!(
                "aggregate over {} configs: min {:.2} GB/s, max {:.2} GB/s, \
                 harmonic mean {:.2} GB/s\n",
                agg.runs, agg.min_gbs, agg.max_gbs, agg.harmonic_mean_gbs
            ));
        }
    }
    out
}

/// Render records as the machine-readable JSON document (`--json-out`).
pub fn render_json(records: &[RunRecord]) -> String {
    let arr: Vec<Value> = records.iter().map(|r| r.to_json()).collect();
    let mut doc = vec![("runs".to_string(), Value::Array(arr))];
    if let Some(agg) = Aggregate::from_records(records) {
        doc.push(("aggregate".to_string(), agg.to_json()));
    }
    let obj = Value::Object(doc.into_iter().collect());
    let mut out = json::to_string_pretty(&obj);
    out.push('\n');
    out
}

/// The paper's multi-run aggregate: min/max bandwidth and the harmonic
/// mean across configurations.
#[derive(Debug, Clone, PartialEq)]
pub struct Aggregate {
    pub runs: usize,
    pub min_gbs: f64,
    pub max_gbs: f64,
    pub harmonic_mean_gbs: f64,
}

impl Aggregate {
    pub fn from_records(records: &[RunRecord]) -> Option<Aggregate> {
        let bws: Vec<f64> = records.iter().map(|r| r.bandwidth_gbs).collect();
        let (min, max) = stats::min_max(&bws)?;
        Some(Aggregate {
            runs: records.len(),
            min_gbs: min,
            max_gbs: max,
            harmonic_mean_gbs: stats::harmonic_mean(&bws)?,
        })
    }

    pub fn to_json(&self) -> Value {
        obj(&[
            ("runs", Value::from(self.runs)),
            ("min_gbs", Value::from(self.min_gbs)),
            ("max_gbs", Value::from(self.max_gbs)),
            ("harmonic_mean_gbs", Value::from(self.harmonic_mean_gbs)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::OpenMpSim;
    use crate::coordinator::parse_config_text;
    use crate::platforms;

    fn backend() -> OpenMpSim {
        OpenMpSim::new(&platforms::by_name("skx").unwrap())
    }

    #[test]
    fn run_one_produces_record() {
        let mut b = backend();
        let p = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(1 << 16);
        let r = run_one(&mut b, "stream-like", &p, Kernel::Gather).unwrap();
        assert_eq!(r.name, "stream-like");
        assert!(r.bandwidth_gbs > 10.0);
        assert_eq!(r.vector_len, 8);
        assert_eq!(r.bottleneck, "dram-bw");
        assert_eq!(r.page_size.as_deref(), Some("4KB"));
        let rate = r.tlb_hit_rate.unwrap();
        assert!((0.0..=1.0).contains(&rate));
    }

    #[test]
    fn per_run_page_size_applies_and_resets() {
        // A huge-delta gather at 2 MiB must report fewer TLB misses
        // than the identical 4 KiB run, and a following config without
        // the key must run at the default again.
        let cfgs = parse_config_text(
            r#"[
              {"name": "huge-4k", "kernel": "Gather",
               "pattern": "UNIFORM:16:512", "delta": 16384,
               "count": 16384},
              {"name": "huge-2m", "kernel": "Gather",
               "pattern": "UNIFORM:16:512", "delta": 16384,
               "count": 16384, "page-size": "2MB"},
              {"name": "huge-again-4k", "kernel": "Gather",
               "pattern": "UNIFORM:16:512", "delta": 16384,
               "count": 16384}
            ]"#,
        )
        .unwrap();
        let mut b = backend();
        let recs = run_configs(&mut b, &cfgs).unwrap();
        assert_eq!(recs[0].page_size.as_deref(), Some("4KB"));
        assert_eq!(recs[1].page_size.as_deref(), Some("2MB"));
        assert_eq!(recs[2].page_size.as_deref(), Some("4KB"));
        let hit = |i: usize| recs[i].tlb_hit_rate.unwrap();
        assert!(
            hit(1) > hit(0) + 0.5,
            "2MB hit rate {:.3} should dwarf 4KB {:.3}",
            hit(1),
            hit(0)
        );
        assert!((hit(0) - hit(2)).abs() < 1e-9, "default must be restored");
    }

    #[test]
    fn config_set_runs_and_aggregates() {
        let cfgs = parse_config_text(
            r#"[
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 65536},
              {"kernel": "Gather", "pattern": "UNIFORM:8:8", "delta": 64,
               "count": 65536}
            ]"#,
        )
        .unwrap();
        let mut b = backend();
        let recs = run_configs(&mut b, &cfgs).unwrap();
        assert_eq!(recs.len(), 2);
        // stride-1 beats stride-8
        assert!(recs[0].bandwidth_gbs > recs[1].bandwidth_gbs);
        let agg = Aggregate::from_records(&recs).unwrap();
        assert_eq!(agg.runs, 2);
        assert!(agg.min_gbs <= agg.harmonic_mean_gbs);
        assert!(agg.harmonic_mean_gbs <= agg.max_gbs);
    }

    #[test]
    fn record_json_shape() {
        let mut b = backend();
        let p = Pattern::parse("UNIFORM:4:2")
            .unwrap()
            .with_delta(8)
            .with_count(1024);
        let r = run_one(&mut b, "x", &p, Kernel::Scatter).unwrap();
        let j = r.to_json();
        assert_eq!(j.get("kernel").unwrap().as_str().unwrap(), "Scatter");
        assert!(j.get("bandwidth_gbs").unwrap().as_f64().unwrap() > 0.0);
        // The thread-count column rides along (SKX default: 16).
        assert_eq!(j.get("threads").unwrap().as_usize().unwrap(), 16);
        // The closure diagnostic rides along too (Null when the pass
        // ran in full — either way the key is present).
        assert!(j.get("sim-closure").is_some());
    }

    fn skx_factory() -> crate::error::Result<Box<dyn crate::backends::Backend>>
    {
        Ok(Box::new(backend()))
    }

    #[test]
    fn parallel_jobs_match_serial_records() {
        let cfgs = parse_config_text(
            r#"[
              {"name": "a", "kernel": "Gather", "pattern": "UNIFORM:8:1",
               "delta": 8, "count": 16384},
              {"name": "b", "kernel": "Gather", "pattern": "UNIFORM:8:8",
               "delta": 64, "count": 16384},
              {"name": "c", "kernel": "Scatter", "pattern": "UNIFORM:8:2",
               "delta": 16, "count": 16384, "threads": 4},
              {"name": "d", "kernel": "Gather", "pattern": "UNIFORM:16:512",
               "delta": 16384, "count": 8192, "page-size": "2MB"}
            ]"#,
        )
        .unwrap();
        let serial = run_configs_jobs(&skx_factory, &cfgs, 1).unwrap();
        let par = run_configs_jobs(&skx_factory, &cfgs, 8).unwrap();
        assert_eq!(render_table(&serial), render_table(&par));
        assert_eq!(render_json(&serial), render_json(&par));
        // And both match the legacy single-backend path.
        let mut b = backend();
        let legacy = run_configs(&mut b, &cfgs).unwrap();
        assert_eq!(render_table(&legacy), render_table(&serial));
    }

    #[test]
    fn per_run_threads_applies_and_resets() {
        let cfgs = parse_config_text(
            r#"[
              {"name": "t-default", "kernel": "Gather",
               "pattern": "UNIFORM:8:1", "delta": 8, "count": 16384},
              {"name": "t-1", "kernel": "Gather", "pattern": "UNIFORM:8:1",
               "delta": 8, "count": 16384, "threads": 1},
              {"name": "t-default-again", "kernel": "Gather",
               "pattern": "UNIFORM:8:1", "delta": 8, "count": 16384}
            ]"#,
        )
        .unwrap();
        let mut b = backend();
        let recs = run_configs(&mut b, &cfgs).unwrap();
        assert_eq!(recs[0].threads, Some(16));
        assert_eq!(recs[1].threads, Some(1));
        assert_eq!(recs[2].threads, Some(16), "default must be restored");
        // One thread cannot saturate DRAM: stream gather is slower.
        assert!(recs[1].bandwidth_gbs < recs[0].bandwidth_gbs);
        assert_eq!(recs[0].bandwidth_gbs, recs[2].bandwidth_gbs);
    }

    #[test]
    fn render_table_has_thread_and_page_columns() {
        let mut b = backend();
        let p = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(4096);
        let r = run_one(&mut b, "row", &p, Kernel::Gather).unwrap();
        let table = render_table(&[r]);
        assert!(table.contains("| thr "), "{table}");
        assert!(table.contains("| page "), "{table}");
        assert!(table.contains("| MiB r/w "), "{table}");
        assert!(table.contains("| 16 "), "{table}");
        assert!(!table.contains("aggregate over"), "single run: no aggregate");
    }

    #[test]
    fn per_side_bytes_follow_the_kernel() {
        let mut b = backend();
        let p = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(4096);
        let payload = p.moved_bytes() as u64;
        let g = run_one(&mut b, "g", &p, Kernel::Gather).unwrap();
        assert_eq!((g.read_bytes, g.write_bytes), (payload, 0));
        let s = run_one(&mut b, "s", &p, Kernel::Scatter).unwrap();
        assert_eq!((s.read_bytes, s.write_bytes), (0, payload));
        let gs_pat = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_gs_scatter((0..8).collect())
            .with_delta(8)
            .with_count(4096);
        let gs = run_one(&mut b, "gs", &gs_pat, Kernel::GS).unwrap();
        assert_eq!((gs.read_bytes, gs.write_bytes), (payload, payload));
        // Baseline kernels: per-operand payloads ride along too.
        use crate::pattern::StreamOp;
        let dense = Pattern::dense(8, 4096);
        let dp = dense.moved_bytes() as u64;
        let copy =
            run_one(&mut b, "c", &dense, Kernel::Stream(StreamOp::Copy))
                .unwrap();
        assert_eq!((copy.read_bytes, copy.write_bytes), (dp, dp));
        let triad =
            run_one(&mut b, "t", &dense, Kernel::Stream(StreamOp::Triad))
                .unwrap();
        assert_eq!((triad.read_bytes, triad.write_bytes), (2 * dp, dp));
        let gups_pat = Pattern::gups(1 << 16, 1024);
        let gup = run_one(&mut b, "u", &gups_pat, Kernel::Gups).unwrap();
        let up = gups_pat.moved_bytes() as u64;
        assert_eq!((gup.read_bytes, gup.write_bytes), (up, up));
        // And the JSON record carries both sides.
        let j = gs.to_json();
        assert_eq!(j.get("kernel").unwrap().as_str().unwrap(), "GS");
        assert_eq!(
            j.get("read_bytes").unwrap().as_usize().unwrap() as u64,
            payload
        );
        assert_eq!(
            j.get("write_bytes").unwrap().as_usize().unwrap() as u64,
            payload
        );
    }

    #[test]
    fn gs_configs_run_through_the_jobs_pool_byte_identically() {
        let cfgs = parse_config_text(
            r#"[
              {"name": "gs-u", "kernel": "GS",
               "pattern-gather": "UNIFORM:8:4",
               "pattern-scatter": "UNIFORM:8:1", "delta": 32,
               "count": 8192},
              {"name": "g", "kernel": "Gather", "pattern": "UNIFORM:8:4",
               "delta": 32, "count": 8192},
              {"name": "gs-d0", "kernel": "GS",
               "pattern-gather": [0, 1, 2, 3],
               "pattern-scatter": [0, 24, 48, 72], "delta": 0,
               "count": 4096, "threads": 4}
            ]"#,
        )
        .unwrap();
        let serial = run_configs_jobs(&skx_factory, &cfgs, 1).unwrap();
        let par = run_configs_jobs(&skx_factory, &cfgs, 8).unwrap();
        assert_eq!(render_table(&serial), render_table(&par));
        assert_eq!(render_json(&serial), render_json(&par));
        // The GS run is slower than its gather half alone.
        assert!(serial[0].bandwidth_gbs <= serial[1].bandwidth_gbs * 1.02);
    }
}
