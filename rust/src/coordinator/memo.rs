//! Closure-memo result cache: campaign-scale sweeps repeat
//! byte-identical configs (the paper's Fig. 3 grid alone re-runs the
//! same stride/delta cells across platforms and suites mine
//! overlapping proxy patterns), and a simulated run is a pure function
//! of its config, so a repeated config can cost a hash lookup instead
//! of a simulation.
//!
//! The key is a 128-bit [`Fingerprinter`] digest over every field
//! that reaches the engine: kernel, gather/scatter index buffers,
//! delta(s), count, and the per-run page-size / thread /
//! vector-regime / numa-placement overrides. The
//! display name and pattern spec string are deliberately *excluded* —
//! `"custom[3]"` vs `"custom[7]"` or differently-named twins share
//! physics, so they share the cache line. Backend identity is uniform
//! within a campaign (one factory), so it is not part of the key; a
//! backend whose `Backend::deterministic` is false (real execution)
//! bypasses the cache entirely.
//!
//! Errors are never cached: a failed leader poisons its cell and every
//! duplicate recomputes, so the campaign reports the exact error the
//! uncached run would have (and the lowest-index-error contract is
//! untouched).

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::sim::closure::Fingerprinter;
use crate::sim::SimResult;

use super::RunConfig;

/// Digest of everything that determines a config's simulation outcome.
pub fn config_fingerprint(c: &RunConfig) -> u128 {
    let mut f = Fingerprinter::new();
    f.push_str(c.kernel.name());
    f.push(c.pattern.indices.len() as u64);
    for &i in &c.pattern.indices {
        f.push_i64(i);
    }
    f.push(c.pattern.scatter_indices.len() as u64);
    for &i in &c.pattern.scatter_indices {
        f.push_i64(i);
    }
    f.push_i64(c.pattern.delta);
    f.push(c.pattern.deltas.len() as u64);
    for &d in &c.pattern.deltas {
        f.push_i64(d);
    }
    f.push(c.pattern.count as u64);
    match c.page_size {
        Some(p) => {
            f.push(1);
            f.push_str(p.name());
        }
        None => f.push(0),
    }
    match c.threads {
        Some(t) => {
            f.push(1);
            f.push(t as u64);
        }
        None => f.push(0),
    }
    match c.regime {
        Some(r) => {
            f.push(1);
            f.push_str(r.name());
        }
        None => f.push(0),
    }
    match c.placement {
        Some(p) => {
            f.push(1);
            f.push_str(p.name());
        }
        None => f.push(0),
    }
    f.finish()
}

/// Input-order duplicate labels: for each config, its fingerprint and
/// the index of the earliest config with the same fingerprint (`None`
/// for first occurrences). A pure function of the input — independent
/// of schedule, worker count, and whether caching is on — which is
/// what keeps the `"memo"` record key byte-identical across `--jobs`
/// widths, memo on/off, and stream vs batch mode.
pub fn dup_labels(configs: &[RunConfig]) -> Vec<(u128, Option<usize>)> {
    let mut first: HashMap<u128, usize> = HashMap::new();
    configs
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let fp = config_fingerprint(c);
            match first.entry(fp) {
                Entry::Occupied(e) => (fp, Some(*e.get())),
                Entry::Vacant(e) => {
                    e.insert(i);
                    (fp, None)
                }
            }
        })
        .collect()
}

/// The `SPATTER_NO_MEMO=1` escape hatch (mirrors `SPATTER_NO_CLOSURE`
/// for the engine-level optimization): any other value — or the
/// variable being unset — leaves the cache on.
pub fn memo_enabled_from_env() -> bool {
    std::env::var("SPATTER_NO_MEMO").map(|v| v != "1").unwrap_or(true)
}

enum CellState {
    Pending,
    Done(SimResult),
    Failed,
}

/// One cache line: the leader computes while duplicates block here.
pub struct MemoCell {
    slot: Mutex<CellState>,
    cv: Condvar,
}

impl MemoCell {
    fn new() -> MemoCell {
        MemoCell {
            slot: Mutex::new(CellState::Pending),
            cv: Condvar::new(),
        }
    }

    /// Publish the leader's outcome (`None`: the run failed — wake
    /// waiters into recomputation, never cache the error). Every
    /// [`Reservation::Owner`] MUST call this exactly once; a cell left
    /// pending would block its duplicates forever.
    pub fn fill(&self, r: Option<SimResult>) {
        let mut s = self.slot.lock().unwrap();
        *s = match r {
            Some(v) => CellState::Done(v),
            None => CellState::Failed,
        };
        self.cv.notify_all();
    }

    fn wait(&self) -> Option<SimResult> {
        let mut s = self.slot.lock().unwrap();
        loop {
            match &*s {
                CellState::Pending => s = self.cv.wait(s).unwrap(),
                CellState::Done(v) => return Some(v.clone()),
                CellState::Failed => return None,
            }
        }
    }
}

/// What [`MemoCache::get_or_reserve`] hands back.
pub enum Reservation {
    /// First arrival for this key: compute, then [`MemoCell::fill`].
    Owner(Arc<MemoCell>),
    /// A twin already completed: the cached result.
    Ready(SimResult),
    /// The leader for this key failed. Recompute locally — the rerun
    /// reproduces the leader's exact error (or an earlier one).
    Poisoned,
}

/// A per-campaign concurrent result cache. Duplicate suppression is
/// exact: at most one simulation runs per distinct fingerprint; late
/// twins either wait on the in-flight leader or read the finished
/// result.
pub struct MemoCache {
    map: Mutex<HashMap<u128, Arc<MemoCell>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoStats {
    /// Lookups answered from the cache (including waits on a leader).
    pub hits: u64,
    /// Lookups that had to simulate (first arrivals + poisoned keys).
    pub misses: u64,
}

impl MemoStats {
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction over all lookups (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }
}

impl MemoCache {
    pub fn new() -> MemoCache {
        MemoCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look `key` up, reserving it when absent. Blocks (off the map
    /// lock) while a leader is in flight.
    pub fn get_or_reserve(&self, key: u128) -> Reservation {
        let cell = {
            let mut map = self.map.lock().unwrap();
            match map.entry(key) {
                Entry::Occupied(e) => Arc::clone(e.get()),
                Entry::Vacant(e) => {
                    let cell = Arc::new(MemoCell::new());
                    e.insert(Arc::clone(&cell));
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    return Reservation::Owner(cell);
                }
            }
        };
        match cell.wait() {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Reservation::Ready(r)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Reservation::Poisoned
            }
        }
    }

    pub fn stats(&self) -> MemoStats {
        MemoStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

impl Default for MemoCache {
    fn default() -> MemoCache {
        MemoCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::parse_config_text;

    fn cfgs(text: &str) -> Vec<RunConfig> {
        parse_config_text(text).unwrap()
    }

    #[test]
    fn fingerprint_ignores_display_names_but_not_physics() {
        let c = cfgs(r#"[
          {"name": "alpha", "kernel": "Gather", "pattern": "UNIFORM:8:1",
           "delta": 8, "count": 4096},
          {"name": "beta", "kernel": "Gather", "pattern": "UNIFORM:8:1",
           "delta": 8, "count": 4096},
          {"name": "alpha", "kernel": "Scatter", "pattern": "UNIFORM:8:1",
           "delta": 8, "count": 4096},
          {"name": "alpha", "kernel": "Gather", "pattern": "UNIFORM:8:1",
           "delta": 16, "count": 4096},
          {"name": "alpha", "kernel": "Gather", "pattern": "UNIFORM:8:1",
           "delta": 8, "count": 8192},
          {"name": "alpha", "kernel": "Gather", "pattern": "UNIFORM:8:1",
           "delta": 8, "count": 4096, "page-size": "2MB"},
          {"name": "alpha", "kernel": "Gather", "pattern": "UNIFORM:8:1",
           "delta": 8, "count": 4096, "threads": 4},
          {"name": "alpha", "kernel": "Gather", "pattern": "UNIFORM:8:1",
           "delta": 8, "count": 4096, "vector-regime": "scalar"},
          {"name": "alpha", "kernel": "Gather", "pattern": "UNIFORM:8:1",
           "delta": 8, "count": 4096, "numa-placement": "interleave"}
        ]"#);
        let base = config_fingerprint(&c[0]);
        assert_eq!(base, config_fingerprint(&c[1]), "name is display-only");
        for (i, other) in c.iter().enumerate().skip(2) {
            assert_ne!(
                base,
                config_fingerprint(other),
                "config {i} differs in physics and must not alias"
            );
        }
    }

    #[test]
    fn vector_regime_is_physics_not_display() {
        // Regression for the dead-`vectorized` era: two configs that
        // differ only in their vector regime must not share a cache
        // line — a false hit would hand the scalar run the vectorized
        // result (or vice versa) with a bogus `"memo"` provenance.
        let c = cfgs(r#"[
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
           "count": 4096, "vector-regime": "scalar"},
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
           "count": 4096, "vector-regime": "hardware-gs"}
        ]"#);
        assert_ne!(config_fingerprint(&c[0]), config_fingerprint(&c[1]));
        let dups: Vec<Option<usize>> =
            dup_labels(&c).iter().map(|(_, d)| *d).collect();
        assert_eq!(dups, vec![None, None], "both are first occurrences");
    }

    #[test]
    fn custom_index_lists_alias_by_content_not_position() {
        // Custom arrays are spec'd "custom[{run index}]" — the digest
        // must see through the position-dependent display string.
        let c = cfgs(r#"[
          {"kernel": "Gather", "pattern": [0, 3, 5], "delta": 8,
           "count": 1024},
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
           "count": 1024},
          {"kernel": "Gather", "pattern": [0, 3, 5], "delta": 8,
           "count": 1024},
          {"kernel": "Gather", "pattern": [0, 3, 6], "delta": 8,
           "count": 1024}
        ]"#);
        assert_ne!(c[0].pattern.spec, c[2].pattern.spec);
        assert_eq!(config_fingerprint(&c[0]), config_fingerprint(&c[2]));
        assert_ne!(config_fingerprint(&c[0]), config_fingerprint(&c[3]));
    }

    #[test]
    fn dup_labels_point_at_the_first_twin() {
        let c = cfgs(r#"[
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
           "count": 1024},
          {"kernel": "Gather", "pattern": "UNIFORM:8:2", "delta": 16,
           "count": 1024},
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
           "count": 1024},
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
           "count": 1024}
        ]"#);
        let labels = dup_labels(&c);
        let dups: Vec<Option<usize>> =
            labels.iter().map(|(_, d)| *d).collect();
        assert_eq!(dups, vec![None, None, Some(0), Some(0)]);
    }

    #[test]
    fn cache_counts_hits_and_poisons_failures() {
        let cache = MemoCache::new();
        let sim = SimResult {
            seconds: 1.0,
            useful_bytes: 8,
            counters: Default::default(),
            breakdown: Default::default(),
            simulated_iterations: 1,
            closed_at_iteration: None,
        };
        match cache.get_or_reserve(7) {
            Reservation::Owner(cell) => cell.fill(Some(sim.clone())),
            _ => panic!("first arrival must own the cell"),
        }
        match cache.get_or_reserve(7) {
            Reservation::Ready(r) => assert_eq!(r.useful_bytes, 8),
            _ => panic!("second arrival must hit"),
        }
        match cache.get_or_reserve(9) {
            Reservation::Owner(cell) => cell.fill(None),
            _ => panic!("new key must own"),
        }
        assert!(matches!(cache.get_or_reserve(9), Reservation::Poisoned));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 3));
        assert!((s.hit_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn waiters_block_until_the_leader_fills() {
        let cache = MemoCache::new();
        let Reservation::Owner(cell) = cache.get_or_reserve(1) else {
            panic!("must own");
        };
        let sim = SimResult {
            seconds: 2.0,
            useful_bytes: 16,
            counters: Default::default(),
            breakdown: Default::default(),
            simulated_iterations: 1,
            closed_at_iteration: None,
        };
        std::thread::scope(|s| {
            let cache = &cache;
            let waiter = s.spawn(move || match cache.get_or_reserve(1) {
                Reservation::Ready(r) => r.useful_bytes,
                _ => 0,
            });
            std::thread::sleep(std::time::Duration::from_millis(10));
            cell.fill(Some(sim));
            assert_eq!(waiter.join().unwrap(), 16);
        });
    }
}
