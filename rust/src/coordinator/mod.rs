//! The run coordinator: Spatter's L3 contribution — turn parsed
//! configurations into executed runs with the paper's measurement
//! protocol, schedule them across a worker pool (`--jobs`), and
//! aggregate + render the results.

mod config;
mod memo;
mod runner;
mod schedule;

pub use config::{
    parse_config_file, parse_config_text, stream_config_file,
    stream_config_reader, ConfigStream, RunConfig,
};
pub use memo::{
    config_fingerprint, dup_labels, memo_enabled_from_env, MemoCache,
    MemoStats, Reservation,
};
pub use runner::{
    render_json, render_table, run_configs, run_configs_jobs,
    run_configs_jobs_memo, run_configs_jobs_stats, run_configs_stream,
    run_one, sim_accesses_total, Aggregate, BackendFactory, RunRecord,
    StreamSummary,
};
pub use schedule::{
    default_jobs, parallel_map_with, parallel_stream_with, stream_window,
};
