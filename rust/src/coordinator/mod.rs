//! The run coordinator: Spatter's L3 contribution — turn parsed
//! configurations into executed runs with the paper's measurement
//! protocol, schedule them across a worker pool (`--jobs`), and
//! aggregate + render the results.

mod config;
mod runner;
mod schedule;

pub use config::{parse_config_file, parse_config_text, RunConfig};
pub use runner::{
    render_json, render_table, run_configs, run_configs_jobs, run_one,
    Aggregate, BackendFactory, RunRecord,
};
pub use schedule::{default_jobs, parallel_map_with};
