//! The run coordinator: Spatter's L3 contribution — turn parsed
//! configurations into executed runs with the paper's measurement
//! protocol, and aggregate the results.

mod config;
mod runner;

pub use config::{parse_config_file, parse_config_text, RunConfig};
pub use runner::{run_configs, run_one, Aggregate, RunRecord};
