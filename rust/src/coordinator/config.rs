//! JSON multi-configuration input (paper §3.3 "JSON Specification").
//!
//! A config file is an array of run objects:
//!
//! ```json
//! [
//!   {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
//!    "count": 16777216},
//!   {"name": "lulesh-s1", "kernel": "Scatter",
//!    "pattern": [0, 24, 48], "delta": 8, "count": 1048576}
//! ]
//! ```
//!
//! `pattern` is either a spec string (builtin or Table-5 name) or an
//! explicit index array. Spatter "will parse this file and allocate
//! memory once for all tests" — the analogue here: patterns are
//! validated and sized up front, before any backend runs.

use std::path::Path;

use crate::error::{Error, Result};
use crate::json::{self, obj, Value};
use crate::pattern::{table5, Kernel, Pattern};
use crate::platforms::VectorRegime;
use crate::sim::{NumaPlacement, PageSize};

/// One entry of a JSON config file.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub name: String,
    pub kernel: Kernel,
    pub pattern: Pattern,
    /// Optional `"page-size"` override for this run (`"4KB"`,
    /// `"64KB"`, `"2MB"`, `"1GB"`); `None` keeps the backend's
    /// configured default.
    pub page_size: Option<PageSize>,
    /// Optional `"threads"` override for this run (simulated OpenMP
    /// thread count, the paper's §3.1 concurrency axis); `None` keeps
    /// the backend's configured default. Ignored by backends without a
    /// thread model (GPU, real execution).
    pub threads: Option<usize>,
    /// Optional `"vector-regime"` override for this run (paper §5.3 /
    /// Fig 6 vectorization axis: `"scalar"`, `"emulated-gather"`,
    /// `"hardware-gs"`, `"masked-sve"`); `None` keeps the backend's
    /// configured default. Ignored by backends without a CPU issue
    /// model (GPU, real execution); an unsupported regime on a CPU
    /// platform is a run-time config error.
    pub regime: Option<VectorRegime>,
    /// Optional `"numa-placement"` override for this run
    /// (`"first-touch"`, `"interleave"`); `None` keeps the backend's
    /// configured default. Ignored by backends without a NUMA model
    /// and inert on single-socket platforms (`sim::topology`).
    pub placement: Option<NumaPlacement>,
}

impl RunConfig {
    /// Serialize back to the config-file schema. `parse_config_text`
    /// of the serialized form reproduces this config (round-trip).
    /// GS configs serialize their two index buffers under the
    /// `"pattern-gather"` / `"pattern-scatter"` keys; single-buffer
    /// kernels keep `"pattern"`; the dense baselines (STREAM tetrad +
    /// GUPS) have no index buffer at all — `"delta"`/`"count"` size
    /// the streams.
    pub fn to_json(&self) -> Value {
        let index_array = |idx: &[i64]| {
            Value::Array(idx.iter().map(|&i| Value::from(i)).collect())
        };
        let mut pairs: Vec<(&str, Value)> = vec![
            ("name", Value::from(self.name.clone())),
            ("kernel", Value::from(self.kernel.name())),
            ("count", Value::from(self.pattern.count)),
        ];
        if self.kernel == Kernel::GS {
            pairs.push(("pattern-gather", index_array(&self.pattern.indices)));
            pairs.push((
                "pattern-scatter",
                index_array(&self.pattern.scatter_indices),
            ));
        } else if !self.kernel.is_baseline() {
            pairs.push(("pattern", index_array(&self.pattern.indices)));
        }
        if self.pattern.deltas.len() > 1 {
            pairs.push((
                "delta",
                Value::Array(
                    self.pattern.deltas.iter().map(|&d| Value::from(d)).collect(),
                ),
            ));
        } else {
            pairs.push(("delta", Value::from(self.pattern.delta)));
        }
        if let Some(page) = self.page_size {
            pairs.push(("page-size", Value::from(page.name())));
        }
        if let Some(threads) = self.threads {
            pairs.push(("threads", Value::from(threads)));
        }
        if let Some(regime) = self.regime {
            pairs.push(("vector-regime", Value::from(regime.name())));
        }
        if let Some(placement) = self.placement {
            pairs.push(("numa-placement", Value::from(placement.name())));
        }
        obj(&pairs)
    }
}

/// Parse a config file from disk.
pub fn parse_config_file(path: &Path) -> Result<Vec<RunConfig>> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        Error::Config(format!("cannot read {} ({e})", path.display()))
    })?;
    parse_config_text(&text)
}

/// Parse config JSON text.
pub fn parse_config_text(text: &str) -> Result<Vec<RunConfig>> {
    let root = json::parse(text)?;
    let arr = root.as_array().map_err(|_| {
        Error::Config("config root must be an array of run objects".into())
    })?;
    if arr.is_empty() {
        return Err(Error::Config("config contains no runs".into()));
    }
    arr.iter().enumerate().map(|(i, v)| parse_one(i, v)).collect()
}

/// Streaming config source (`--stream`): yields `RunConfig`s one at a
/// time from an incrementally parsed JSON array. Memory stays bounded
/// by one read chunk plus the largest single element — independent of
/// campaign length — while the yielded configs are identical to what
/// [`parse_config_text`] would produce for the whole document.
///
/// Iteration stops after the first error (the underlying byte stream
/// is no longer trustworthy past a malformed element).
pub struct ConfigStream<R: std::io::Read> {
    inner: json::ArrayStream<R>,
    index: usize,
    failed: bool,
}

impl<R: std::io::Read> Iterator for ConfigStream<R> {
    type Item = Result<RunConfig>;

    fn next(&mut self) -> Option<Result<RunConfig>> {
        if self.failed {
            return None;
        }
        match self.inner.next() {
            Some(Ok(v)) => {
                let i = self.index;
                self.index += 1;
                let cfg = parse_one(i, &v);
                if cfg.is_err() {
                    self.failed = true;
                }
                Some(cfg)
            }
            Some(Err(e)) => {
                self.failed = true;
                Some(Err(e))
            }
            None => {
                if self.index == 0 {
                    // Same contract as the batch parser: an empty
                    // campaign is a config error, not a silent no-op.
                    self.failed = true;
                    return Some(Err(Error::Config(
                        "config contains no runs".into(),
                    )));
                }
                None
            }
        }
    }
}

/// Open `path` as a streaming config source.
pub fn stream_config_file(
    path: &Path,
) -> Result<ConfigStream<std::fs::File>> {
    let f = std::fs::File::open(path).map_err(|e| {
        Error::Config(format!("cannot read {} ({e})", path.display()))
    })?;
    Ok(stream_config_reader(f))
}

/// Wrap any reader (file, pipe, in-memory cursor) as a streaming
/// config source.
pub fn stream_config_reader<R: std::io::Read>(r: R) -> ConfigStream<R> {
    ConfigStream {
        inner: json::ArrayStream::new(r),
        index: 0,
        failed: false,
    }
}

/// One side of a pattern key: a spec string (builtin or Table-5 name)
/// or an explicit index array. Returns `(display name, indices,
/// app default delta)` — the delta is `Some` only for Table-5 ids,
/// which carry their own base advance.
fn parse_index_value(
    i: usize,
    key: &str,
    v: &Value,
) -> Result<(String, Vec<i64>, Option<i64>)> {
    match v {
        Value::String(spec) => {
            if let Some(app) = table5::by_name(spec) {
                Ok((app.name.to_string(), app.indices.to_vec(), Some(app.delta)))
            } else {
                Ok((spec.clone(), crate::pattern::parse_spec(spec)?, None))
            }
        }
        Value::Array(items) => {
            let idx: Result<Vec<i64>> =
                items.iter().map(|x| x.as_i64()).collect();
            Ok((format!("custom[{i}]"), idx?, None))
        }
        other => Err(Error::Config(format!(
            "run {i}: {key} must be a string or array, got {}",
            other.kind()
        ))),
    }
}

fn parse_one(i: usize, v: &Value) -> Result<RunConfig> {
    let kernel = Kernel::parse(v.get("kernel")?.as_str()?)?;
    let mut pattern = if kernel.is_baseline() {
        // Dense baselines (STREAM tetrad + GUPS): no index buffer —
        // "delta" (stream width / GUPS table size) and "count" size
        // the streams.
        for key in ["pattern", "pattern-gather", "pattern-scatter"] {
            if v.get_opt(key).is_some() {
                return Err(Error::Config(format!(
                    "run {i}: kernel {} is a dense baseline and takes no \
                     \"{key}\" (\"delta\"/\"count\" size the streams)",
                    kernel.name()
                )));
            }
        }
        let d = match v.get_opt("delta") {
            None => None,
            Some(Value::Array(_)) => {
                return Err(Error::Config(format!(
                    "run {i}: kernel {} takes a single \"delta\" (cycling \
                     lists apply to indexed kernels)",
                    kernel.name()
                )))
            }
            Some(x) => Some(x.as_i64().map_err(|e| {
                Error::Config(format!("run {i}: delta: {e}"))
            })?),
        };
        if let Some(d) = d {
            if d <= 0 {
                return Err(Error::Config(format!(
                    "run {i}: delta must be > 0 for {}, got {d}",
                    kernel.name()
                )));
            }
        }
        if kernel == Kernel::Gups {
            Pattern::gups(
                d.unwrap_or(crate::pattern::GUPS_DEFAULT_TABLE_ELEMS as i64)
                    as usize,
                1,
            )
        } else {
            let width = d.unwrap_or(8);
            if width > 1 << 20 {
                return Err(Error::Config(format!(
                    "run {i}: stream width (delta) must be <= 2^20, got \
                     {width}"
                )));
            }
            Pattern::dense(width as usize, 1)
        }
    } else if kernel == Kernel::GS {
        // GS: dual index buffers under "pattern-gather" /
        // "pattern-scatter" (dst[scatter[j]] = src[gather[j]]).
        if v.get_opt("pattern").is_some() {
            return Err(Error::Config(format!(
                "run {i}: GS configs use \"pattern-gather\" and \
                 \"pattern-scatter\", not \"pattern\""
            )));
        }
        let gv = v.get("pattern-gather").map_err(|_| {
            Error::Config(format!(
                "run {i}: kernel GS needs a \"pattern-gather\" key"
            ))
        })?;
        let sv = v.get("pattern-scatter").map_err(|_| {
            Error::Config(format!(
                "run {i}: kernel GS needs a \"pattern-scatter\" key"
            ))
        })?;
        let (gname, gidx, gdelta) = parse_index_value(i, "pattern-gather", gv)?;
        let (sname, sidx, _) = parse_index_value(i, "pattern-scatter", sv)?;
        let mut p = Pattern::from_indices(&format!("{gname}>{sname}"), gidx)
            .with_gs_scatter(sidx);
        // A Table-5 gather side carries the app's default delta, same
        // as the single-kernel path (a "delta" key still overrides).
        if let Some(d) = gdelta {
            p = p.with_delta(d);
        }
        p
    } else {
        for key in ["pattern-gather", "pattern-scatter"] {
            if v.get_opt(key).is_some() {
                return Err(Error::Config(format!(
                    "run {i}: \"{key}\" applies to the GS kernel; kernel {} \
                     takes a single \"pattern\"",
                    kernel.name()
                )));
            }
        }
        match v.get("pattern")? {
            Value::String(spec) => {
                // Table-5 names are accepted anywhere a spec is, and
                // carry their own default delta.
                if let Some(app) = table5::by_name(spec) {
                    Pattern::from_indices(
                        &app.name.to_string(),
                        app.indices.to_vec(),
                    )
                    .with_delta(app.delta)
                } else {
                    Pattern::parse(spec)?
                }
            }
            other => parse_index_value(i, "pattern", other)
                .map(|(name, idx, _)| Pattern::from_indices(&name, idx))?,
        }
    };
    // "delta" accepts a number or a cycling list (temporal-locality
    // extension): {"delta": [0, 0, 0, 16]}. Baseline kernels consumed
    // it above (stream width / table size) — don't reapply it as a
    // base advance.
    if !kernel.is_baseline() {
        if let Some(d) = v.get_opt("delta") {
            match d {
                Value::Array(items) => {
                    let list: Result<Vec<i64>> =
                        items.iter().map(|x| x.as_i64()).collect();
                    pattern = pattern.with_deltas(&list?);
                }
                other => pattern = pattern.with_delta(other.as_i64()?),
            }
        }
    }
    let count = match v.get_opt("count") {
        Some(c) => c.as_usize()?,
        None => 1 << 20,
    };
    pattern = pattern.with_count(count);
    pattern
        .validate_for(kernel)
        .map_err(|e| Error::Config(format!("run {i}: {e}")))?;
    let page_size = match v.get_opt("page-size") {
        Some(ps) => Some(
            PageSize::parse(ps.as_str()?)
                .map_err(|e| Error::Config(format!("run {i}: {e}")))?,
        ),
        None => None,
    };
    let threads = match v.get_opt("threads") {
        Some(t) => {
            let n = t
                .as_usize()
                .map_err(|e| Error::Config(format!("run {i}: threads: {e}")))?;
            if n == 0 {
                return Err(Error::Config(format!(
                    "run {i}: threads must be > 0"
                )));
            }
            Some(n)
        }
        None => None,
    };
    let regime = match v.get_opt("vector-regime") {
        Some(r) => Some(
            VectorRegime::parse(r.as_str()?)
                .map_err(|e| Error::Config(format!("run {i}: {e}")))?,
        ),
        None => None,
    };
    let placement = match v.get_opt("numa-placement") {
        Some(p) => Some(
            NumaPlacement::parse(p.as_str()?)
                .map_err(|e| Error::Config(format!("run {i}: {e}")))?,
        ),
        None => None,
    };
    let name = match v.get_opt("name") {
        Some(n) => n.as_str()?.to_string(),
        None => pattern.spec.clone(),
    };
    Ok(RunConfig {
        name,
        kernel,
        pattern,
        page_size,
        threads,
        regime,
        placement,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_config() {
        let cfgs = parse_config_text(
            r#"[
              {"kernel": "Gather", "pattern": "UNIFORM:8:2", "delta": 16,
               "count": 4096},
              {"name": "mine", "kernel": "Scatter", "pattern": [0, 24, 48],
               "delta": 1, "count": 128},
              {"kernel": "Gather", "pattern": "PENNANT-G4", "count": 64}
            ]"#,
        )
        .unwrap();
        assert_eq!(cfgs.len(), 3);
        assert_eq!(cfgs[0].kernel, Kernel::Gather);
        assert_eq!(cfgs[0].pattern.indices, vec![0, 2, 4, 6, 8, 10, 12, 14]);
        assert_eq!(cfgs[0].pattern.delta, 16);
        assert_eq!(cfgs[1].name, "mine");
        assert_eq!(cfgs[1].pattern.indices, vec![0, 24, 48]);
        // Table-5 name resolves with its own delta.
        assert_eq!(cfgs[2].pattern.delta, 4);
        assert_eq!(cfgs[2].pattern.vector_len(), 16);
    }

    #[test]
    fn table5_delta_can_be_overridden() {
        let cfgs = parse_config_text(
            r#"[{"kernel": "Gather", "pattern": "PENNANT-G4", "delta": 99,
                 "count": 10}]"#,
        )
        .unwrap();
        assert_eq!(cfgs[0].pattern.delta, 99);
    }

    #[test]
    fn default_count_applied() {
        let cfgs = parse_config_text(
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:4:1", "delta": 4}]"#,
        )
        .unwrap();
        assert_eq!(cfgs[0].pattern.count, 1 << 20);
    }

    #[test]
    fn page_size_key_parses_and_roundtrips() {
        let cfgs = parse_config_text(
            r#"[
              {"kernel": "Gather", "pattern": "UNIFORM:16:512",
               "delta": 16384, "count": 1024, "page-size": "2MB"},
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 64}
            ]"#,
        )
        .unwrap();
        assert_eq!(cfgs[0].page_size, Some(PageSize::TwoMB));
        assert_eq!(cfgs[1].page_size, None);

        // Round-trip: serialize the whole set and parse it again.
        let text = json::to_string(&Value::Array(
            cfgs.iter().map(|c| c.to_json()).collect(),
        ));
        let back = parse_config_text(&text).unwrap();
        assert_eq!(back.len(), cfgs.len());
        for (a, b) in cfgs.iter().zip(&back) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.pattern.indices, b.pattern.indices);
            assert_eq!(a.pattern.delta, b.pattern.delta);
            assert_eq!(a.pattern.deltas, b.pattern.deltas);
            assert_eq!(a.pattern.count, b.pattern.count);
            assert_eq!(a.page_size, b.page_size);
            assert_eq!(a.threads, b.threads);
        }
    }

    #[test]
    fn threads_key_parses_and_roundtrips() {
        let cfgs = parse_config_text(
            r#"[
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 1024, "threads": 4},
              {"kernel": "Scatter", "pattern": "LULESH-S3", "count": 512,
               "threads": 28, "page-size": "2MB"},
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 64}
            ]"#,
        )
        .unwrap();
        assert_eq!(cfgs[0].threads, Some(4));
        assert_eq!(cfgs[1].threads, Some(28));
        assert_eq!(cfgs[1].page_size, Some(PageSize::TwoMB));
        assert_eq!(cfgs[2].threads, None);

        let text = json::to_string(&Value::Array(
            cfgs.iter().map(|c| c.to_json()).collect(),
        ));
        let back = parse_config_text(&text).unwrap();
        for (a, b) in cfgs.iter().zip(&back) {
            assert_eq!(a.threads, b.threads);
            assert_eq!(a.page_size, b.page_size);
        }
    }

    #[test]
    fn vector_regime_key_parses_and_roundtrips() {
        let cfgs = parse_config_text(
            r#"[
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 1024, "vector-regime": "scalar"},
              {"kernel": "Gather", "pattern": "UNIFORM:8:2", "delta": 16,
               "count": 512, "vector-regime": "hardware-gs", "threads": 4},
              {"kernel": "Scatter", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 256, "vector-regime": "Emulated-Gather"},
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 64}
            ]"#,
        )
        .unwrap();
        assert_eq!(cfgs[0].regime, Some(VectorRegime::Scalar));
        assert_eq!(cfgs[1].regime, Some(VectorRegime::HardwareGS));
        assert_eq!(cfgs[1].threads, Some(4));
        // Case-insensitive, like the platform lookup.
        assert_eq!(cfgs[2].regime, Some(VectorRegime::EmulatedGather));
        assert_eq!(cfgs[3].regime, None);

        let text = json::to_string(&Value::Array(
            cfgs.iter().map(|c| c.to_json()).collect(),
        ));
        let back = parse_config_text(&text).unwrap();
        for (a, b) in cfgs.iter().zip(&back) {
            assert_eq!(a.regime, b.regime);
            assert_eq!(a.threads, b.threads);
            assert_eq!(a.page_size, b.page_size);
        }
    }

    #[test]
    fn numa_placement_key_parses_and_roundtrips() {
        let cfgs = parse_config_text(
            r#"[
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 1024, "numa-placement": "interleave"},
              {"kernel": "Scatter", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 512, "numa-placement": "First-Touch", "threads": 4},
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 64}
            ]"#,
        )
        .unwrap();
        assert_eq!(cfgs[0].placement, Some(NumaPlacement::Interleave));
        // Case-insensitive, like the other knob keys.
        assert_eq!(cfgs[1].placement, Some(NumaPlacement::FirstTouch));
        assert_eq!(cfgs[1].threads, Some(4));
        assert_eq!(cfgs[2].placement, None);

        let text = json::to_string(&Value::Array(
            cfgs.iter().map(|c| c.to_json()).collect(),
        ));
        let back = parse_config_text(&text).unwrap();
        for (a, b) in cfgs.iter().zip(&back) {
            assert_eq!(a.placement, b.placement);
            assert_eq!(a.threads, b.threads);
            assert_eq!(a.page_size, b.page_size);
        }
    }

    #[test]
    fn bad_numa_placement_rejected_with_run_index() {
        for bad in [
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1",
                 "numa-placement": "nearest"}]"#,
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1",
                 "numa-placement": 2}]"#,
        ] {
            let err = parse_config_text(bad).unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains("run 0") || msg.contains("string"),
                "{bad}: {msg}"
            );
        }
    }

    #[test]
    fn bad_vector_regime_rejected_with_run_index() {
        for bad in [
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1",
                 "vector-regime": "avx9"}]"#,
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1",
                 "vector-regime": 512}]"#,
        ] {
            let err = parse_config_text(bad).unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains("run 0") || msg.contains("string"),
                "{bad}: {msg}"
            );
        }
    }

    #[test]
    fn bad_threads_rejected_with_run_index() {
        for bad in [
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1", "threads": 0}]"#,
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1",
                 "threads": "many"}]"#,
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1",
                 "threads": -4}]"#,
        ] {
            let err = parse_config_text(bad).unwrap_err();
            assert!(err.to_string().contains("run 0"), "{bad}: {err}");
        }
    }

    #[test]
    fn delta_list_roundtrips_through_to_json() {
        let cfgs = parse_config_text(
            r#"[{"name": "t", "kernel": "Gather", "pattern": [0, 1],
                 "delta": [0, 0, 0, 16], "count": 32,
                 "page-size": "1GB"}]"#,
        )
        .unwrap();
        let text = json::to_string(&cfgs[0].to_json());
        let back = parse_config_text(&format!("[{text}]")).unwrap();
        assert_eq!(back[0].pattern.deltas, vec![0, 0, 0, 16]);
        assert_eq!(back[0].page_size, Some(PageSize::OneGB));
    }

    #[test]
    fn bad_page_size_rejected_with_run_index() {
        let err = parse_config_text(
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1",
                 "page-size": "3MB"}]"#,
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("run 0") && msg.contains("3MB"), "{msg}");
    }

    #[test]
    fn rejects_bad_configs() {
        for bad in [
            "{}",
            "[]",
            r#"[{"pattern": "UNIFORM:8:1"}]"#,
            r#"[{"kernel": "Gather"}]"#,
            r#"[{"kernel": "Gather", "pattern": 42}]"#,
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1", "count": 0}]"#,
            r#"[{"kernel": "Gather", "pattern": [-1, 2]}]"#,
        ] {
            assert!(parse_config_text(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn baseline_configs_parse_and_roundtrip() {
        use crate::pattern::{StreamOp, GUPS_DEFAULT_TABLE_ELEMS};
        let cfgs = parse_config_text(
            r#"[
              {"name": "copy", "kernel": "Copy", "count": 4096},
              {"name": "triad16", "kernel": "Triad", "delta": 16,
               "count": 1024, "threads": 4},
              {"name": "gups", "kernel": "GUPS", "count": 2048},
              {"name": "gups-small", "kernel": "GUPS", "delta": 1000000,
               "count": 512, "page-size": "2MB"}
            ]"#,
        )
        .unwrap();
        assert_eq!(cfgs[0].kernel, Kernel::Stream(StreamOp::Copy));
        assert_eq!(cfgs[0].pattern.indices, (0..8).collect::<Vec<i64>>());
        assert_eq!(cfgs[1].kernel, Kernel::Stream(StreamOp::Triad));
        assert_eq!(cfgs[1].pattern.vector_len(), 16);
        assert_eq!(cfgs[1].pattern.delta, 16);
        assert_eq!(cfgs[1].threads, Some(4));
        assert_eq!(cfgs[2].kernel, Kernel::Gups);
        assert_eq!(
            cfgs[2].pattern.gups_table_elems() as usize,
            GUPS_DEFAULT_TABLE_ELEMS
        );
        // Non-pow2 table sizes round up at parse time, so the
        // round-trip below is a fixed point.
        assert_eq!(cfgs[3].pattern.gups_table_elems(), 1 << 20);
        assert_eq!(cfgs[3].page_size, Some(PageSize::TwoMB));

        let text = json::to_string(&Value::Array(
            cfgs.iter().map(|c| c.to_json()).collect(),
        ));
        assert!(!text.contains("\"pattern\""), "{text}");
        let back = parse_config_text(&text).unwrap();
        for (a, b) in cfgs.iter().zip(&back) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.pattern, b.pattern);
            assert_eq!(a.page_size, b.page_size);
            assert_eq!(a.threads, b.threads);
        }
    }

    #[test]
    fn baseline_config_shape_errors_carry_run_index() {
        for bad in [
            // Patterns don't apply to the dense baselines.
            r#"[{"kernel": "Copy", "pattern": "UNIFORM:8:1"}]"#,
            r#"[{"kernel": "GUPS", "pattern": [0, 1]}]"#,
            r#"[{"kernel": "Triad", "pattern-gather": "UNIFORM:8:1"}]"#,
            // Neither do cycling delta lists or non-positive sizes.
            r#"[{"kernel": "Add", "delta": [0, 0, 16]}]"#,
            r#"[{"kernel": "GUPS", "delta": 0}]"#,
            r#"[{"kernel": "Scale", "delta": -8}]"#,
        ] {
            let err = parse_config_text(bad).unwrap_err();
            assert!(err.to_string().contains("run 0"), "{bad}: {err}");
        }
    }

    #[test]
    fn gs_config_parses_specs_arrays_and_table5() {
        let cfgs = parse_config_text(
            r#"[
              {"name": "gs-spec", "kernel": "GS",
               "pattern-gather": "UNIFORM:8:4",
               "pattern-scatter": "UNIFORM:8:1", "delta": 32, "count": 256},
              {"name": "gs-arr", "kernel": "GS",
               "pattern-gather": [0, 24, 48],
               "pattern-scatter": [0, 1, 2], "delta": 1, "count": 64},
              {"name": "gs-app", "kernel": "GS",
               "pattern-gather": "LULESH-G3",
               "pattern-scatter": "UNIFORM:16:1", "count": 64},
              {"name": "gs-app-override", "kernel": "GS",
               "pattern-gather": "LULESH-G3",
               "pattern-scatter": "UNIFORM:16:1", "delta": 16, "count": 64}
            ]"#,
        )
        .unwrap();
        assert_eq!(cfgs[0].kernel, Kernel::GS);
        assert_eq!(
            cfgs[0].pattern.indices,
            vec![0, 4, 8, 12, 16, 20, 24, 28]
        );
        assert_eq!(
            cfgs[0].pattern.scatter_indices,
            (0..8).collect::<Vec<i64>>()
        );
        assert_eq!(cfgs[0].pattern.delta, 32);
        assert_eq!(cfgs[1].pattern.indices, vec![0, 24, 48]);
        assert_eq!(cfgs[1].pattern.scatter_indices, vec![0, 1, 2]);
        assert_eq!(cfgs[2].pattern.vector_len(), 16);
        assert_eq!(cfgs[2].pattern.scatter_indices.len(), 16);
        assert_eq!(cfgs[2].pattern.spec, "LULESH-G3>UNIFORM:16:1");
        // A Table-5 gather side carries the app's default delta
        // (LULESH-G3: 8); an explicit "delta" key overrides it.
        assert_eq!(cfgs[2].pattern.delta, 8);
        assert_eq!(cfgs[3].pattern.delta, 16);
    }

    #[test]
    fn gs_config_roundtrips_through_to_json() {
        let cfgs = parse_config_text(
            r#"[
              {"name": "gs", "kernel": "GS",
               "pattern-gather": "UNIFORM:8:4",
               "pattern-scatter": "UNIFORM:8:1",
               "delta": [0, 0, 32], "count": 256, "page-size": "2MB",
               "threads": 4}
            ]"#,
        )
        .unwrap();
        let text = json::to_string(&Value::Array(
            cfgs.iter().map(|c| c.to_json()).collect(),
        ));
        let back = parse_config_text(&text).unwrap();
        assert_eq!(back[0].kernel, Kernel::GS);
        assert_eq!(back[0].name, cfgs[0].name);
        assert_eq!(back[0].pattern.indices, cfgs[0].pattern.indices);
        assert_eq!(
            back[0].pattern.scatter_indices,
            cfgs[0].pattern.scatter_indices
        );
        assert_eq!(back[0].pattern.deltas, vec![0, 0, 32]);
        assert_eq!(back[0].pattern.count, 256);
        assert_eq!(back[0].page_size, Some(PageSize::TwoMB));
        assert_eq!(back[0].threads, Some(4));
    }

    #[test]
    fn stream_matches_batch_parse() {
        let text = r#"[
          {"kernel": "Gather", "pattern": "UNIFORM:8:2", "delta": 16,
           "count": 4096},
          {"name": "mine", "kernel": "Scatter", "pattern": [0, 24, 48],
           "delta": 1, "count": 128},
          {"name": "gs", "kernel": "GS", "pattern-gather": "UNIFORM:8:4",
           "pattern-scatter": "UNIFORM:8:1", "delta": 32, "count": 256},
          {"kernel": "GUPS", "count": 64},
          {"kernel": "Gather", "pattern": "PENNANT-G4", "count": 64,
           "page-size": "2MB", "threads": 4, "vector-regime": "scalar",
           "numa-placement": "interleave"}
        ]"#;
        let batch = parse_config_text(text).unwrap();
        let streamed: Result<Vec<RunConfig>> =
            stream_config_reader(std::io::Cursor::new(text)).collect();
        let streamed = streamed.unwrap();
        assert_eq!(streamed.len(), batch.len());
        assert_eq!(batch[4].regime, Some(VectorRegime::Scalar));
        assert_eq!(batch[4].placement, Some(NumaPlacement::Interleave));
        for (a, b) in batch.iter().zip(&streamed) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.pattern, b.pattern);
            assert_eq!(a.page_size, b.page_size);
            assert_eq!(a.threads, b.threads);
            assert_eq!(a.regime, b.regime);
            assert_eq!(a.placement, b.placement);
        }
    }

    #[test]
    fn stream_rejects_empty_array_like_batch() {
        let mut s = stream_config_reader(std::io::Cursor::new("[]"));
        let err = s.next().unwrap().unwrap_err();
        assert!(err.to_string().contains("no runs"), "{err}");
        assert!(s.next().is_none());
    }

    #[test]
    fn stream_stops_after_first_bad_element() {
        let text = r#"[
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "count": 64},
          {"kernel": "Gather"},
          {"kernel": "Gather", "pattern": "UNIFORM:8:1", "count": 64}
        ]"#;
        let mut s = stream_config_reader(std::io::Cursor::new(text));
        assert!(s.next().unwrap().is_ok());
        let err = s.next().unwrap().unwrap_err();
        assert!(err.to_string().contains("run 1"), "{err}");
        assert!(s.next().is_none());
    }

    #[test]
    fn stream_surfaces_malformed_json_with_element_index() {
        let text = r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1"}, {oops}]"#;
        let results: Vec<Result<RunConfig>> =
            stream_config_reader(std::io::Cursor::new(text)).collect();
        assert_eq!(results.len(), 2);
        assert!(results[0].is_ok());
        let err = results[1].as_ref().unwrap_err();
        assert!(
            err.to_string().contains("config stream element 1"),
            "{err}"
        );
    }

    #[test]
    fn gs_config_shape_errors_carry_run_index() {
        for bad in [
            // GS with a single "pattern".
            r#"[{"kernel": "GS", "pattern": "UNIFORM:8:1"}]"#,
            // Missing either side.
            r#"[{"kernel": "GS", "pattern-gather": "UNIFORM:8:1"}]"#,
            r#"[{"kernel": "GS", "pattern-scatter": "UNIFORM:8:1"}]"#,
            // Mismatched side lengths.
            r#"[{"kernel": "GS", "pattern-gather": "UNIFORM:8:1",
                 "pattern-scatter": "UNIFORM:4:1"}]"#,
            // Dual keys on a single-buffer kernel.
            r#"[{"kernel": "Gather", "pattern": "UNIFORM:8:1",
                 "pattern-scatter": "UNIFORM:8:1"}]"#,
            r#"[{"kernel": "Scatter", "pattern": "UNIFORM:8:1",
                 "pattern-gather": "UNIFORM:8:1"}]"#,
        ] {
            let err = parse_config_text(bad).unwrap_err();
            assert!(err.to_string().contains("run 0"), "{bad}: {err}");
        }
    }
}
