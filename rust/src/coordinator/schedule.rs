//! Order-preserving parallel work scheduler (the `--jobs` machinery).
//!
//! Large sweeps (ustride × pagesize × threads × apps) are
//! embarrassingly parallel: every simulated run resets its engine
//! state, so runs are independent and can execute on any worker in any
//! order. What must NOT change with the worker count is the *output*:
//! results are collected into the slot of their input index, so table /
//! CSV / JSON output is byte-identical to serial execution.
//!
//! The pool is a dynamic self-scheduling ("work-stealing") queue: idle
//! workers claim the next unclaimed item off a shared atomic cursor,
//! so a slow item (huge count, cold platform) never stalls the rest of
//! the sweep behind a static partition.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};

/// Default worker count for `--jobs`: the machine's available
/// parallelism (1 if it cannot be determined).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `work` over `items` on up to `jobs` worker threads, preserving
/// input order in the output.
///
/// Each worker lazily builds its own context with `init` (engines are
/// stateful and neither `Send` nor `Sync`; the context never crosses a
/// thread boundary) and then claims items off a shared queue. The
/// result vector is ordered by input index regardless of which worker
/// ran what, and the returned error (if any) is the lowest-index
/// failure — exactly what serial execution would have reported.
pub fn parallel_map_with<C, T, R, I, W>(
    items: &[T],
    jobs: usize,
    init: I,
    work: W,
) -> Result<Vec<R>>
where
    T: Sync,
    R: Send,
    I: Fn() -> Result<C> + Sync,
    W: Fn(&mut C, &T, usize) -> Result<R> + Sync,
{
    let jobs = jobs.clamp(1, items.len().max(1));
    if jobs == 1 {
        let mut ctx = init()?;
        return items
            .iter()
            .enumerate()
            .map(|(i, t)| work(&mut ctx, t, i))
            .collect();
    }

    let next = AtomicUsize::new(0);
    // First failure flips the flag; workers finish their in-flight
    // item but stop claiming, so a fast-fail stays fast instead of
    // draining the whole queue. Claims are monotone, so every index
    // below the failed one has already been claimed and will complete
    // — the lowest-index-error contract survives cancellation.
    let cancelled = AtomicBool::new(false);
    let slots: Mutex<Vec<Option<Result<R>>>> =
        Mutex::new((0..items.len()).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| {
                let mut ctx: Option<C> = None;
                loop {
                    if cancelled.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let out = match &mut ctx {
                        Some(c) => work(c, &items[i], i),
                        None => match init() {
                            Ok(mut c) => {
                                let r = work(&mut c, &items[i], i);
                                ctx = Some(c);
                                r
                            }
                            Err(e) => {
                                // A worker that cannot build its
                                // context marks its claimed item and
                                // retires.
                                cancelled.store(true, Ordering::Relaxed);
                                slots.lock().unwrap()[i] = Some(Err(e));
                                break;
                            }
                        },
                    };
                    if out.is_err() {
                        cancelled.store(true, Ordering::Relaxed);
                    }
                    slots.lock().unwrap()[i] = Some(out);
                }
            });
        }
    });

    let slots = slots.into_inner().unwrap();
    let mut out = Vec::with_capacity(slots.len());
    for (i, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(Ok(r)) => out.push(r),
            Some(Err(e)) => return Err(e),
            // Unreachable unless every worker died on `init`, and then
            // an earlier slot already carried that error.
            None => {
                return Err(Error::Runtime(format!(
                    "scheduler: item {i} was never executed"
                )))
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn preserves_input_order_at_any_width() {
        let items: Vec<usize> = (0..100).collect();
        let serial =
            parallel_map_with(&items, 1, || Ok(()), |_, &x, i| Ok(x * 10 + i))
                .unwrap();
        for jobs in [2, 3, 8, 64] {
            let par = parallel_map_with(
                &items,
                jobs,
                || Ok(()),
                |_, &x, i| Ok(x * 10 + i),
            )
            .unwrap();
            assert_eq!(par, serial, "jobs={jobs}");
        }
    }

    #[test]
    fn builds_at_most_one_context_per_worker() {
        let inits = AtomicUsize::new(0);
        let items: Vec<usize> = (0..40).collect();
        let out = parallel_map_with(
            &items,
            4,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                Ok(0usize)
            },
            |c, &x, _| {
                *c += 1;
                Ok(x)
            },
        )
        .unwrap();
        assert_eq!(out, items);
        let n = inits.load(Ordering::SeqCst);
        assert!((1..=4).contains(&n), "{n} inits for 4 workers");
    }

    #[test]
    fn work_actually_spreads_across_threads() {
        let ids: Mutex<HashSet<std::thread::ThreadId>> =
            Mutex::new(HashSet::new());
        let items: Vec<usize> = (0..16).collect();
        parallel_map_with(
            &items,
            4,
            || Ok(()),
            |_, &x, _| {
                ids.lock().unwrap().insert(std::thread::current().id());
                std::thread::sleep(std::time::Duration::from_millis(5));
                Ok(x)
            },
        )
        .unwrap();
        assert!(
            ids.lock().unwrap().len() >= 2,
            "expected concurrent workers, got {:?}",
            ids.lock().unwrap().len()
        );
    }

    #[test]
    fn lowest_index_error_wins() {
        let items: Vec<usize> = (0..20).collect();
        let err = parallel_map_with(
            &items,
            4,
            || Ok(()),
            |_, &x, _| {
                if x >= 7 {
                    Err(Error::Runtime(format!("boom {x}")))
                } else {
                    Ok(x)
                }
            },
        )
        .unwrap_err();
        assert_eq!(err.to_string(), "runtime error: boom 7");
    }

    #[test]
    fn failure_cancels_remaining_queue() {
        // After the first error, workers stop claiming: a fast-fail
        // must not drain the whole queue. Item 0 errors immediately;
        // the other items sleep, so by the time any worker finishes
        // one of them the cancel flag is long set.
        let executed = AtomicUsize::new(0);
        let items: Vec<usize> = (0..64).collect();
        let err = parallel_map_with(
            &items,
            4,
            || Ok(()),
            |_, &x, _| {
                executed.fetch_add(1, Ordering::SeqCst);
                if x == 0 {
                    return Err(Error::Runtime("fail fast".into()));
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
                Ok(x)
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("fail fast"));
        let n = executed.load(Ordering::SeqCst);
        assert!(n < items.len(), "queue should not drain fully: {n}");
    }

    #[test]
    fn init_failure_surfaces() {
        let items: Vec<usize> = (0..5).collect();
        let err = parallel_map_with(
            &items,
            3,
            || -> Result<()> { Err(Error::Runtime("no backend".into())) },
            |_, &x, _| Ok(x),
        )
        .unwrap_err();
        assert!(err.to_string().contains("no backend"));
    }

    #[test]
    fn empty_input_and_oversubscription() {
        let none: Vec<usize> = Vec::new();
        let out =
            parallel_map_with(&none, 8, || Ok(()), |_, &x, _| Ok(x)).unwrap();
        assert!(out.is_empty());
        // More workers than items must not panic or duplicate.
        let two: Vec<usize> = vec![1, 2];
        let out =
            parallel_map_with(&two, 16, || Ok(()), |_, &x, _| Ok(x * 2)).unwrap();
        assert_eq!(out, vec![2, 4]);
    }

    #[test]
    fn default_jobs_is_positive() {
        assert!(default_jobs() >= 1);
    }
}
