//! Order-preserving parallel work scheduler (the `--jobs` machinery).
//!
//! Large sweeps (ustride × pagesize × threads × apps) are
//! embarrassingly parallel: every simulated run resets its engine
//! state, so runs are independent and can execute on any worker in any
//! order. What must NOT change with the worker count is the *output*:
//! results are collected into the slot of their input index, so table /
//! CSV / JSON output is byte-identical to serial execution.
//!
//! The pool is a work-stealing scheduler: input indices are seeded
//! round-robin into per-worker deques; an owner pops from the LIFO end
//! of its own deque (its lowest remaining index), and a worker whose
//! deque runs dry steals from the FIFO end of a victim's (the victim's
//! highest remaining index). Owner and thief therefore touch opposite
//! ends, a slow item (huge count, cold platform) never stalls the rest
//! of the sweep behind a static partition, and the queue tail stays
//! utilized even when run lengths are heavily skewed. Results land in
//! per-slot cells — each index is popped exactly once, so result
//! writes are wait-free instead of funnelling through one global
//! mutex.
//!
//! Campaigns too large to materialize go through
//! [`parallel_stream_with`]: a producer thread pulls configs from an
//! iterator under backpressure (it may run at most a reorder-window
//! ahead of the emission watermark), workers drain a bounded queue,
//! and the caller's emit hook receives results in input order as the
//! contiguous prefix completes — memory stays O(jobs + window)
//! instead of O(campaign).

use std::cell::UnsafeCell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use crate::error::{Error, Result};

/// Default worker count for `--jobs`: the machine's available
/// parallelism (1 if it cannot be determined).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Reorder-window size for streaming runs: enough look-ahead to keep
/// `jobs` workers busy past a straggler without unbounded buffering.
pub fn stream_window(jobs: usize) -> usize {
    (4 * jobs).max(64)
}

/// Per-slot result cells. Each input index is popped from exactly one
/// deque exactly once, so at most one worker ever writes a given cell,
/// and nothing reads the cells until every worker has joined. That
/// single-writer discipline is what lets the pool drop the old global
/// `Mutex<Vec<Option<..>>>`: result writes are wait-free.
struct Slots<R> {
    cells: Vec<UnsafeCell<Option<Result<R>>>>,
}

// SAFETY: disjoint single-writer access per cell (each index is popped
// once), and reads happen only after the thread scope joins.
unsafe impl<R: Send> Sync for Slots<R> {}

impl<R> Slots<R> {
    fn new(n: usize) -> Slots<R> {
        Slots {
            cells: (0..n).map(|_| UnsafeCell::new(None)).collect(),
        }
    }

    /// SAFETY: the caller must guarantee index `i` is written at most
    /// once and never read concurrently (upheld by pop-once deques).
    unsafe fn put(&self, i: usize, r: Result<R>) {
        *self.cells[i].get() = Some(r);
    }

    fn into_results(self) -> Vec<Option<Result<R>>> {
        self.cells.into_iter().map(|c| c.into_inner()).collect()
    }
}

/// The per-worker deques. Index `i` is seeded into deque `i % jobs`,
/// pushed in descending order so the owner's LIFO end (`pop_back`)
/// yields its lowest index first; thieves take `pop_front` (the
/// victim's highest remaining index), keeping the two ends disjoint.
struct Deques {
    queues: Vec<Mutex<VecDeque<usize>>>,
}

impl Deques {
    fn seed(n: usize, jobs: usize) -> Deques {
        let mut queues: Vec<VecDeque<usize>> =
            (0..jobs).map(|_| VecDeque::new()).collect();
        for i in (0..n).rev() {
            queues[i % jobs].push_back(i);
        }
        Deques {
            queues: queues.into_iter().map(Mutex::new).collect(),
        }
    }

    /// Owner pop, falling back to stealing from victims in ring order.
    /// `None` means every deque is empty: since indices are never
    /// re-queued, the pool is done.
    fn pop(&self, id: usize) -> Option<usize> {
        if let Some(i) = self.queues[id].lock().unwrap().pop_back() {
            return Some(i);
        }
        let n = self.queues.len();
        for k in 1..n {
            let victim = (id + k) % n;
            if let Some(i) = self.queues[victim].lock().unwrap().pop_front() {
                return Some(i);
            }
        }
        None
    }
}

/// Map `work` over `items` on up to `jobs` worker threads, preserving
/// input order in the output.
///
/// Each worker lazily builds its own context with `init` (engines are
/// stateful and neither `Send` nor `Sync`; the context never crosses a
/// thread boundary) and then drains its own deque, stealing from
/// victims once it runs dry. The result vector is ordered by input
/// index regardless of which worker ran what, and the returned error
/// (if any) is the lowest-index failure — exactly what serial
/// execution would have reported.
///
/// Fail-fast: the first error at index `e` cancels every index above
/// `e` (those pops drain without executing), while indices below `e`
/// still run — one of them may fail and lower the bar, so the
/// lowest-index-error contract survives cancellation.
pub fn parallel_map_with<C, T, R, I, W>(
    items: &[T],
    jobs: usize,
    init: I,
    work: W,
) -> Result<Vec<R>>
where
    T: Sync,
    R: Send,
    I: Fn() -> Result<C> + Sync,
    W: Fn(&mut C, &T, usize) -> Result<R> + Sync,
{
    let jobs = jobs.clamp(1, items.len().max(1));
    if jobs == 1 {
        let mut ctx = init()?;
        return items
            .iter()
            .enumerate()
            .map(|(i, t)| work(&mut ctx, t, i))
            .collect();
    }

    let deques = Deques::seed(items.len(), jobs);
    let slots = Slots::new(items.len());
    // Lowest failed index so far (usize::MAX: none). The bar only
    // descends (fetch_min), so an index skipped at some instant is
    // above the *final* bar too — every slot below the final bar is
    // guaranteed to be filled.
    let min_err = AtomicUsize::new(usize::MAX);

    std::thread::scope(|s| {
        for id in 0..jobs {
            let deques = &deques;
            let slots = &slots;
            let min_err = &min_err;
            let init = &init;
            let work = &work;
            s.spawn(move || {
                let mut ctx: Option<C> = None;
                while let Some(i) = deques.pop(id) {
                    if i > min_err.load(Ordering::Relaxed) {
                        continue; // cancelled tail: drain, don't run
                    }
                    let out = match &mut ctx {
                        Some(c) => work(c, &items[i], i),
                        None => match init() {
                            Ok(mut c) => {
                                let r = work(&mut c, &items[i], i);
                                ctx = Some(c);
                                r
                            }
                            Err(e) => {
                                // A worker that cannot build its
                                // context marks its popped item and
                                // retires; the rest of its deque is
                                // stolen by surviving workers.
                                min_err.fetch_min(i, Ordering::Relaxed);
                                // SAFETY: `i` was popped exactly once.
                                unsafe { slots.put(i, Err(e)) };
                                break;
                            }
                        },
                    };
                    if out.is_err() {
                        min_err.fetch_min(i, Ordering::Relaxed);
                    }
                    // SAFETY: `i` was popped exactly once.
                    unsafe { slots.put(i, out) };
                }
            });
        }
    });

    let mut out = Vec::with_capacity(items.len());
    for (i, slot) in slots.into_results().into_iter().enumerate() {
        match slot {
            Some(Ok(r)) => out.push(r),
            Some(Err(e)) => return Err(e),
            // Unreachable: skips only happen above the final error
            // bar, and the walk returns at the bar's own slot first.
            // Kept as a defensive error rather than a panic.
            None => {
                return Err(Error::Runtime(format!(
                    "scheduler: item {i} was never executed"
                )))
            }
        }
    }
    Ok(out)
}

/// Shared state of a streaming run. One mutex guards the whole
/// pipeline (item queue, reorder buffer, watermarks); the three
/// condvars separate the who-waits-on-what so wakeups stay targeted.
struct StreamState<T, R> {
    /// Items produced but not yet popped by a worker.
    queue: VecDeque<(usize, T)>,
    /// Completed results awaiting in-order emission.
    results: BTreeMap<usize, Result<R>>,
    /// Next index to emit.
    emitted: usize,
    /// Items yielded by the source so far.
    produced: usize,
    /// Source still running (not exhausted, errored, or cancelled).
    producing: bool,
    /// Lowest failed index (usize::MAX: none) — the fail-fast bar.
    min_err: usize,
}

struct StreamShared<T, R> {
    state: Mutex<StreamState<T, R>>,
    /// Workers wait here for queue items.
    work_cv: Condvar,
    /// The emitter waits here for the next in-order result.
    done_cv: Condvar,
    /// The producer waits here for the emission watermark to advance.
    space_cv: Condvar,
}

/// Run `work` over the items of `source` on `jobs` workers, emitting
/// results to `emit` in input order as the contiguous prefix
/// completes. Returns the number of results emitted.
///
/// The source is consumed on a dedicated producer thread under
/// backpressure: item `i` is pulled only once `i < emitted + window`,
/// so at most `window` items exist between the source and the sink at
/// any instant — memory is O(jobs + window) regardless of how many
/// items the source yields. A source error, work error, or emit error
/// stops the pipeline with the lowest-index failure after the prefix
/// below it has been emitted.
pub fn parallel_stream_with<C, T, R, S, I, W, E>(
    source: S,
    jobs: usize,
    window: usize,
    init: I,
    work: W,
    mut emit: E,
) -> Result<usize>
where
    T: Send,
    R: Send,
    S: Iterator<Item = Result<T>> + Send,
    I: Fn() -> Result<C> + Sync,
    W: Fn(&mut C, &T, usize) -> Result<R> + Sync,
    E: FnMut(usize, R) -> Result<()>,
{
    let jobs = jobs.max(1);
    let window = window.max(jobs);
    let shared: StreamShared<T, R> = StreamShared {
        state: Mutex::new(StreamState {
            queue: VecDeque::new(),
            results: BTreeMap::new(),
            emitted: 0,
            produced: 0,
            producing: true,
            min_err: usize::MAX,
        }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
        space_cv: Condvar::new(),
    };

    std::thread::scope(|s| {
        let sh = &shared;

        // Producer: pulls the source forward only while the emission
        // watermark allows it, and winds the pipeline down on source
        // exhaustion, source error, or a downstream failure.
        s.spawn(move || {
            let mut source = source;
            loop {
                let i = {
                    let mut st = sh.state.lock().unwrap();
                    while st.min_err == usize::MAX
                        && st.produced >= st.emitted + window
                    {
                        st = sh.space_cv.wait(st).unwrap();
                    }
                    if st.min_err != usize::MAX {
                        st.producing = false;
                        sh.work_cv.notify_all();
                        sh.done_cv.notify_all();
                        return;
                    }
                    st.produced
                };
                // The (possibly slow) pull runs outside the lock.
                match source.next() {
                    Some(Ok(item)) => {
                        let mut st = sh.state.lock().unwrap();
                        st.produced += 1;
                        st.queue.push_back((i, item));
                        sh.work_cv.notify_one();
                    }
                    Some(Err(e)) => {
                        let mut st = sh.state.lock().unwrap();
                        st.produced += 1;
                        st.results.insert(i, Err(e));
                        st.min_err = st.min_err.min(i);
                        st.producing = false;
                        sh.work_cv.notify_all();
                        sh.done_cv.notify_all();
                        return;
                    }
                    None => {
                        let mut st = sh.state.lock().unwrap();
                        st.producing = false;
                        sh.work_cv.notify_all();
                        sh.done_cv.notify_all();
                        return;
                    }
                }
            }
        });

        // Workers: pop the oldest queued item, run it, park the
        // result in the reorder buffer.
        for _ in 0..jobs {
            let init = &init;
            let work = &work;
            s.spawn(move || {
                let mut ctx: Option<C> = None;
                loop {
                    let claimed = {
                        let mut st = sh.state.lock().unwrap();
                        loop {
                            if let Some((i, item)) = st.queue.pop_front() {
                                if i > st.min_err {
                                    continue; // cancelled tail
                                }
                                break Some((i, item));
                            }
                            if !st.producing {
                                break None;
                            }
                            st = sh.work_cv.wait(st).unwrap();
                        }
                    };
                    let Some((i, item)) = claimed else { return };
                    let out = match &mut ctx {
                        Some(c) => work(c, &item, i),
                        None => match init() {
                            Ok(mut c) => {
                                let r = work(&mut c, &item, i);
                                ctx = Some(c);
                                r
                            }
                            Err(e) => {
                                let mut st = sh.state.lock().unwrap();
                                st.min_err = st.min_err.min(i);
                                st.results.insert(i, Err(e));
                                sh.done_cv.notify_all();
                                sh.space_cv.notify_all();
                                return;
                            }
                        },
                    };
                    let mut st = sh.state.lock().unwrap();
                    if out.is_err() {
                        st.min_err = st.min_err.min(i);
                        sh.space_cv.notify_all();
                    }
                    st.results.insert(i, out);
                    sh.done_cv.notify_all();
                }
            });
        }

        // Emitter (the calling thread): release results in input
        // order. The emit hook runs outside the lock.
        let mut emitted_total = 0usize;
        loop {
            let next = {
                let mut st = sh.state.lock().unwrap();
                loop {
                    if let Some(r) = st.results.remove(&st.emitted) {
                        st.emitted += 1;
                        sh.space_cv.notify_all();
                        break Some(r);
                    }
                    if !st.producing && st.emitted >= st.produced {
                        break None;
                    }
                    st = sh.done_cv.wait(st).unwrap();
                }
            };
            match next {
                None => break Ok(emitted_total),
                Some(Ok(r)) => {
                    let idx = emitted_total;
                    emitted_total += 1;
                    if let Err(e) = emit(idx, r) {
                        // The sink failed: raise the bar so the
                        // producer stops and workers drain fast.
                        let mut st = sh.state.lock().unwrap();
                        st.min_err = st.min_err.min(idx);
                        sh.space_cv.notify_all();
                        sh.work_cv.notify_all();
                        break Err(e);
                    }
                }
                Some(Err(e)) => break Err(e),
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::{HashMap, HashSet};
    use std::sync::atomic::AtomicUsize;
    use std::sync::Barrier;
    use std::time::Duration;

    #[test]
    fn preserves_input_order_at_any_width() {
        let items: Vec<usize> = (0..100).collect();
        let serial =
            parallel_map_with(&items, 1, || Ok(()), |_, &x, i| Ok(x * 10 + i))
                .unwrap();
        for jobs in [2, 3, 8, 64] {
            let par = parallel_map_with(
                &items,
                jobs,
                || Ok(()),
                |_, &x, i| Ok(x * 10 + i),
            )
            .unwrap();
            assert_eq!(par, serial, "jobs={jobs}");
        }
    }

    #[test]
    fn builds_at_most_one_context_per_worker() {
        let inits = AtomicUsize::new(0);
        let items: Vec<usize> = (0..40).collect();
        let out = parallel_map_with(
            &items,
            4,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                Ok(0usize)
            },
            |c, &x, _| {
                *c += 1;
                Ok(x)
            },
        )
        .unwrap();
        assert_eq!(out, items);
        let n = inits.load(Ordering::SeqCst);
        assert!((1..=4).contains(&n), "{n} inits for 4 workers");
    }

    #[test]
    fn work_actually_spreads_across_threads() {
        // Deterministic spread proof, no sleeps: every worker's first
        // work call waits at a barrier sized to the worker count, so
        // the pool completes only if all four workers popped at least
        // one item. (While any worker is parked at the barrier its
        // popped item is in flight, and 16 - 3 items still sit in the
        // deques, so the remaining worker always finds work — the
        // barrier provably releases.)
        let jobs = 4;
        let barrier = Barrier::new(jobs);
        let ids: Mutex<HashSet<std::thread::ThreadId>> =
            Mutex::new(HashSet::new());
        let items: Vec<usize> = (0..16).collect();
        parallel_map_with(
            &items,
            jobs,
            || Ok(true),
            |first, &x, _| {
                if *first {
                    barrier.wait();
                    *first = false;
                    ids.lock().unwrap().insert(std::thread::current().id());
                }
                Ok(x)
            },
        )
        .unwrap();
        assert_eq!(ids.lock().unwrap().len(), jobs);
    }

    #[test]
    fn skewed_run_lengths_keep_the_tail_utilized() {
        // One pathologically long item at index 0 must not strand the
        // rest of its owner's deque: item 0 blocks until every other
        // even index (seeded into the same deque) has been executed —
        // which can only happen if the other worker steals them. A
        // start barrier pins each worker to its own deque's first item
        // so the roles are deterministic.
        let n = 10usize;
        let items: Vec<usize> = (0..n).collect();
        let barrier = Barrier::new(2);
        let executed: Mutex<HashSet<usize>> = Mutex::new(HashSet::new());
        let done_cv = Condvar::new();
        let thread_of: Mutex<HashMap<usize, std::thread::ThreadId>> =
            Mutex::new(HashMap::new());
        parallel_map_with(
            &items,
            2,
            || Ok(true),
            |first, _, i| {
                if *first {
                    barrier.wait();
                    *first = false;
                }
                thread_of.lock().unwrap().insert(i, std::thread::current().id());
                if i == 0 {
                    let mut done = executed.lock().unwrap();
                    while done.len() < n - 1 {
                        let (d, t) = done_cv
                            .wait_timeout(done, Duration::from_secs(10))
                            .unwrap();
                        done = d;
                        assert!(
                            !t.timed_out(),
                            "tail was never stolen: {:?}",
                            done.len()
                        );
                    }
                } else {
                    executed.lock().unwrap().insert(i);
                    done_cv.notify_all();
                }
                Ok(i)
            },
        )
        .unwrap();
        let map = thread_of.lock().unwrap();
        let blocked = map[&0];
        for j in (2..n).step_by(2) {
            assert_ne!(
                map[&j], blocked,
                "even index {j} should have been stolen from the blocked \
                 worker's deque"
            );
        }
    }

    #[test]
    fn lowest_index_error_wins() {
        let items: Vec<usize> = (0..20).collect();
        let err = parallel_map_with(
            &items,
            4,
            || Ok(()),
            |_, &x, _| {
                if x >= 7 {
                    Err(Error::Runtime(format!("boom {x}")))
                } else {
                    Ok(x)
                }
            },
        )
        .unwrap_err();
        assert_eq!(err.to_string(), "runtime error: boom 7");
    }

    #[test]
    fn failure_cancels_remaining_queue() {
        // After the first error, higher-index pops drain without
        // executing: a fast-fail must not run the whole queue. Item 0
        // errors immediately; the other items sleep, so by the time
        // any worker finishes one of them the bar is long set.
        let executed = AtomicUsize::new(0);
        let items: Vec<usize> = (0..64).collect();
        let err = parallel_map_with(
            &items,
            4,
            || Ok(()),
            |_, &x, _| {
                executed.fetch_add(1, Ordering::SeqCst);
                if x == 0 {
                    return Err(Error::Runtime("fail fast".into()));
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
                Ok(x)
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("fail fast"));
        let n = executed.load(Ordering::SeqCst);
        assert!(n < items.len(), "queue should not drain fully: {n}");
    }

    #[test]
    fn init_failure_surfaces() {
        let items: Vec<usize> = (0..5).collect();
        let err = parallel_map_with(
            &items,
            3,
            || -> Result<()> { Err(Error::Runtime("no backend".into())) },
            |_, &x, _| Ok(x),
        )
        .unwrap_err();
        assert!(err.to_string().contains("no backend"));
    }

    #[test]
    fn empty_input_and_oversubscription() {
        let none: Vec<usize> = Vec::new();
        let out =
            parallel_map_with(&none, 8, || Ok(()), |_, &x, _| Ok(x)).unwrap();
        assert!(out.is_empty());
        // More workers than items must not panic or duplicate.
        let two: Vec<usize> = vec![1, 2];
        let out =
            parallel_map_with(&two, 16, || Ok(()), |_, &x, _| Ok(x * 2))
                .unwrap();
        assert_eq!(out, vec![2, 4]);
    }

    #[test]
    fn default_jobs_is_positive() {
        assert!(default_jobs() >= 1);
    }

    #[test]
    fn stream_emits_in_input_order_at_any_width() {
        for jobs in [1, 2, 4, 8] {
            let mut got: Vec<(usize, usize)> = Vec::new();
            let n = parallel_stream_with(
                (0..50usize).map(Ok::<usize, Error>),
                jobs,
                8,
                || Ok(()),
                |_, &x, i| Ok(x * 10 + i),
                |i, r| {
                    got.push((i, r));
                    Ok(())
                },
            )
            .unwrap();
            assert_eq!(n, 50);
            let want: Vec<(usize, usize)> =
                (0..50).map(|i| (i, i * 10 + i)).collect();
            assert_eq!(got, want, "jobs={jobs}");
        }
    }

    #[test]
    fn stream_production_is_window_bounded() {
        // The producer may pull item i only once i < emitted + window.
        // The atomic emission counter trails the internal watermark by
        // at most the one in-flight emit call, hence the +1 slack.
        let window = 4usize;
        let emitted = AtomicUsize::new(0);
        let n = parallel_stream_with(
            (0..200usize).map(|i| {
                assert!(
                    i < emitted.load(Ordering::SeqCst) + window + 1,
                    "producer ran {i} items ahead of emission"
                );
                Ok(i)
            }),
            2,
            window,
            || Ok(()),
            |_, &x, _| Ok(x),
            |i, _| {
                emitted.store(i + 1, Ordering::SeqCst);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(n, 200);
    }

    #[test]
    fn stream_lowest_index_error_wins_after_the_prefix() {
        let mut got: Vec<usize> = Vec::new();
        let err = parallel_stream_with(
            (0..40usize).map(Ok::<usize, Error>),
            4,
            8,
            || Ok(()),
            |_, &x, _| {
                if x >= 11 {
                    Err(Error::Runtime(format!("boom {x}")))
                } else {
                    Ok(x)
                }
            },
            |_, r| {
                got.push(r);
                Ok(())
            },
        )
        .unwrap_err();
        assert_eq!(err.to_string(), "runtime error: boom 11");
        assert_eq!(got, (0..11).collect::<Vec<_>>());
    }

    #[test]
    fn stream_source_error_propagates() {
        let src = (0..10usize).map(|i| {
            if i == 5 {
                Err(Error::Json("bad element".into()))
            } else {
                Ok(i)
            }
        });
        let mut got: Vec<usize> = Vec::new();
        let err = parallel_stream_with(
            src,
            2,
            4,
            || Ok(()),
            |_, &x, _| Ok(x),
            |_, r| {
                got.push(r);
                Ok(())
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("bad element"));
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn stream_empty_source_emits_nothing() {
        let n = parallel_stream_with(
            std::iter::empty::<Result<usize>>(),
            4,
            8,
            || Ok(()),
            |_, &x, _| Ok(x),
            |_, _: usize| Ok(()),
        )
        .unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn stream_emit_failure_stops_the_pipeline() {
        let err = parallel_stream_with(
            (0..100usize).map(Ok::<usize, Error>),
            2,
            4,
            || Ok(()),
            |_, &x, _| Ok(x),
            |i, _| {
                if i == 3 {
                    Err(Error::Runtime("sink full".into()))
                } else {
                    Ok(())
                }
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("sink full"));
    }
}
