//! The paper's evaluation platforms (Table 3), expressed as simulator
//! configurations.
//!
//! Peak DRAM bandwidths are the Table 3 STREAM / BabelStream column
//! (the paper's own calibration anchor); micro-architectural knobs
//! (cache geometry, prefetcher kind, gather/scatter issue costs, TLB
//! reach, coherence penalty) are set from the mechanisms the paper
//! identifies per platform plus public spec sheets.

use crate::error::{Error, Result};
use crate::sim::{
    DramConfig, InterleavePolicy, NumaConfig, PrefetchKind, TlbGeometry,
    TlbTable,
};

/// A compiler/ISA vectorization regime for gather/scatter (paper §5.3,
/// Fig 6): how the indexed inner loop is issued on a CPU.
///
/// Each platform declares which regimes its ISA supports and which one
/// its native compiler emits ([`CpuPlatform::supported_regimes`] /
/// [`CpuPlatform::native_regime`]); a run picks one via the
/// `--vector-regime` CLI flag or the `"vector-regime"` JSON key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VectorRegime {
    /// `#pragma novec`: scalar loads/stores, scalar-issue DRAM
    /// efficiency.
    Scalar,
    /// AVX2-class: a (possibly microcoded) gather instruction exists;
    /// scatter falls back to scalar stores.
    EmulatedGather,
    /// AVX-512-class: hardware gather *and* scatter instructions.
    HardwareGS,
    /// SVE/NEON-class masked lanes (TX2): vector loop structure with
    /// per-lane scalar element access — no dedicated G/S instruction.
    MaskedSve,
}

impl VectorRegime {
    /// Every regime, registry order.
    pub const ALL: &'static [VectorRegime] = &[
        VectorRegime::Scalar,
        VectorRegime::EmulatedGather,
        VectorRegime::HardwareGS,
        VectorRegime::MaskedSve,
    ];

    /// Kebab-case name used by the CLI, JSON configs, and reports.
    pub fn name(&self) -> &'static str {
        match self {
            VectorRegime::Scalar => "scalar",
            VectorRegime::EmulatedGather => "emulated-gather",
            VectorRegime::HardwareGS => "hardware-gs",
            VectorRegime::MaskedSve => "masked-sve",
        }
    }

    /// Case-insensitive parse of [`VectorRegime::name`].
    pub fn parse(s: &str) -> Result<VectorRegime> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(VectorRegime::Scalar),
            "emulated-gather" => Ok(VectorRegime::EmulatedGather),
            "hardware-gs" => Ok(VectorRegime::HardwareGS),
            "masked-sve" => Ok(VectorRegime::MaskedSve),
            _ => Err(Error::Cli(format!(
                "unknown vector regime '{s}' \
                 (scalar|emulated-gather|hardware-gs|masked-sve)"
            ))),
        }
    }
}

impl std::fmt::Display for VectorRegime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A simulated CPU platform (the paper's OpenMP/Scalar targets).
#[derive(Debug, Clone)]
pub struct CpuPlatform {
    /// Short name used on the CLI and in reports ("bdw", "skx", ...).
    pub name: &'static str,
    /// Table 3 description.
    pub full_name: &'static str,
    /// Threads used by the paper's single-socket OpenMP protocol.
    pub threads: usize,
    pub freq_ghz: f64,
    pub l1_kb: usize,
    pub l1_assoc: usize,
    pub l2_kb: usize,
    pub l2_assoc: usize,
    pub l3_mb: usize,
    pub l3_assoc: usize,
    /// STREAM bandwidth from Table 3 (GB/s) — DRAM calibration anchor.
    pub stream_gbs: f64,
    /// Per-thread L2 bandwidth (GB/s) and shared L3 bandwidth.
    pub l2_gbs_per_thread: f64,
    pub l3_gbs: f64,
    pub dram_latency_ns: f64,
    /// Outstanding-miss parallelism with vector G/S vs scalar loads.
    pub mlp_vector: f64,
    pub mlp_scalar: f64,
    pub prefetch: PrefetchKind,
    /// Issue cost of one element through a hardware gather, in cycles
    /// per element per thread. `None` = no gather instruction (TX2).
    pub gather_cycles_per_elem: Option<f64>,
    /// Same for scatter. `None` = no scatter instruction (Naples AVX2,
    /// BDW AVX2, TX2).
    pub scatter_cycles_per_elem: Option<f64>,
    /// Scalar load/store issue cost, cycles per element per thread.
    pub scalar_cycles_per_elem: f64,
    /// Relative DRAM efficiency of scalar-issued request streams vs
    /// hardware G/S (paper §5.3: vector G/S "reduces overall unique
    /// instruction count and overall request pressure on the memory
    /// system"). < 1: scalar wastes bandwidth; > 1: the platform's
    /// microcoded G/S is itself the less efficient requester (BDW).
    pub scalar_dram_efficiency: f64,
    /// Doubles retired per vector op in the dense (STREAM) inner loop:
    /// 8 for AVX-512, 4 for AVX2, 2 for TX2 NEON.
    pub simd_lanes: f64,
    /// The regime the platform's native compiler emits at `-O3`
    /// (what Fig 6 calls the "vectorized" build).
    pub native_regime: VectorRegime,
    /// Per-page-size TLB geometries (cpuid-style table) and the cost
    /// of a full-depth page walk.
    pub tlb: TlbTable,
    pub tlb_walk_ns: f64,
    /// Cost per contended (cross-thread) write, ns.
    pub coherence_ns: f64,
    /// TX2's observed ability to absorb repeated overwrites of the same
    /// lines (paper §5.4.2 item 1).
    pub absorbs_repeated_writes: bool,
    /// Socket geometry and interconnect link cost (`sim::topology`).
    /// Every Table 3 part is measured single-socket; the derived
    /// `*-2s` variants in [`multi_socket_cpus`] set two sockets plus
    /// their link model.
    pub numa: NumaConfig,
    /// Banked DRAM geometry, address-interleave policy, and conflict
    /// cost (`sim::dram`) — per socket; `sim::topology` instantiates
    /// one banked model per node.
    pub dram: DramConfig,
}

impl CpuPlatform {
    /// Regimes this platform's ISA can actually issue, registry order.
    ///
    /// `Scalar` is always available (`#pragma novec` compiles
    /// everywhere); `EmulatedGather` needs a gather instruction,
    /// `HardwareGS` needs gather *and* scatter, and `MaskedSve` is the
    /// masked-lane structure only the SVE/NEON platform natively has.
    pub fn supported_regimes(&self) -> Vec<VectorRegime> {
        let mut regimes = vec![VectorRegime::Scalar];
        if self.gather_cycles_per_elem.is_some() {
            regimes.push(VectorRegime::EmulatedGather);
        }
        if self.gather_cycles_per_elem.is_some()
            && self.scatter_cycles_per_elem.is_some()
        {
            regimes.push(VectorRegime::HardwareGS);
        }
        if self.native_regime == VectorRegime::MaskedSve {
            regimes.push(VectorRegime::MaskedSve);
        }
        regimes
    }

    /// Whether `regime` can run on this platform.
    pub fn supports_regime(&self, regime: VectorRegime) -> bool {
        self.supported_regimes().contains(&regime)
    }

    /// The paper's §3.1 thread-scaling axis for this platform: powers
    /// of two from 1 up to, and always including, the single-socket
    /// thread count (e.g. TX2: 1, 2, 4, 8, 16, 28).
    pub fn thread_sweep(&self) -> Vec<usize> {
        let max = self.threads.max(1);
        let mut sweep = Vec::new();
        let mut t = 1;
        while t < max {
            sweep.push(t);
            t *= 2;
        }
        sweep.push(max);
        sweep
    }
}

/// A simulated GPU platform (the paper's CUDA targets).
#[derive(Debug, Clone)]
pub struct GpuPlatform {
    pub name: &'static str,
    pub full_name: &'static str,
    /// BabelStream bandwidth from Table 3 (GB/s).
    pub stream_gbs: f64,
    /// Memory-transaction granularity in bytes: 32 (sectored, Maxwell+)
    /// or 128 (K40-era, L1-line transactions) — the Fig 5 coalescing
    /// difference.
    pub sector_bytes: u64,
    /// DRAM row size and activation overhead (expressed as equivalent
    /// bytes of transfer) — drives the slow decline past stride-8.
    pub row_bytes: u64,
    pub row_activate_bytes: f64,
    /// L2 cache (bytes) and line size.
    pub l2_kb: usize,
    pub l2_assoc: usize,
    /// Effective L2 bandwidth (GB/s) — caps in-cache reuse bandwidth.
    pub l2_gbs: f64,
    /// Per-page-size TLB geometries (64 KiB native large pages are
    /// the default translation granularity), full-depth walk cost in
    /// ns, and the miss-level parallelism of the walkers.
    pub tlb: TlbTable,
    pub tlb_walk_ns: f64,
    pub tlb_mlp: f64,
    /// Write serialization cost for same-sector contention (delta-0
    /// scatter), ns per write.
    pub write_contend_ns: f64,
    /// Aggregate memory-issue rate: transactions per nanosecond the
    /// SMs can generate (caps small-stride in-cache patterns).
    pub txn_per_ns: f64,
    /// Banked DRAM geometry, address-interleave policy, and conflict
    /// cost (`sim::dram`).
    pub dram: DramConfig,
}

/// CPU registry, Table 3 order (plus Naples which appears in Figs 3/6
/// and Table 4 with STREAM 97 GB/s).
pub fn cpus() -> Vec<CpuPlatform> {
    vec![
        CpuPlatform {
            name: "knl",
            full_name: "Knights Landing (cache mode)",
            threads: 64,
            freq_ghz: 1.4,
            l1_kb: 32, l1_assoc: 8,
            l2_kb: 512, l2_assoc: 16,
            l3_mb: 16, l3_assoc: 16, // MCDRAM direct-mapped cache stand-in
            stream_gbs: 249.313,
            l2_gbs_per_thread: 18.0,
            l3_gbs: 380.0,
            dram_latency_ns: 150.0,
            mlp_vector: 24.0,
            mlp_scalar: 6.0,
            prefetch: PrefetchKind::Stride { degree: 2 },
            // 2 AVX-512 VPUs but slow cores: vector G/S is the only way
            // to keep the memory system busy (Fig 6: biggest win, best
            // at small strides) — yet the gather itself is microcoded
            // and port-bound, so cache-resident patterns stay far from
            // the MCDRAM roofline (Table 4: KNL's AMG/Nekbone columns
            // sit *below* its STREAM, decorrelating CPU R-values).
            gather_cycles_per_elem: Some(3.2),
            scatter_cycles_per_elem: Some(4.0),
            // In-order-ish Silvermont-derived cores: scalar indexed
            // loads are very slow — the Fig 6 "vectorize or starve".
            scalar_cycles_per_elem: 6.0,
            scalar_dram_efficiency: 0.50,
            simd_lanes: 8.0, // AVX-512
            native_regime: VectorRegime::HardwareGS,
            tlb: TlbTable {
                // KNL: 256-entry uTLB class; modest 2M/1G arrays.
                four_kb: TlbGeometry { entries: 256, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 256, assoc: 4 },
                two_mb: TlbGeometry { entries: 128, assoc: 4 },
                one_gb: TlbGeometry { entries: 16, assoc: 4 },
            },
            tlb_walk_ns: 120.0,
            coherence_ns: 260.0,
            absorbs_repeated_writes: false,
            numa: NumaConfig::single(),
            // MCDRAM: 8 channels, flat-ish bank structure.
            dram: DramConfig {
                channels: 8,
                ranks: 1,
                bank_groups: 2,
                banks: 4,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 32.0,
            },
        },
        CpuPlatform {
            name: "bdw",
            full_name: "Broadwell (E5-2695 v4, one socket)",
            threads: 16,
            freq_ghz: 2.4,
            l1_kb: 32, l1_assoc: 8,
            l2_kb: 256, l2_assoc: 8,
            l3_mb: 40, l3_assoc: 16,
            stream_gbs: 43.885,
            l2_gbs_per_thread: 24.0,
            l3_gbs: 180.0,
            dram_latency_ns: 90.0,
            mlp_vector: 10.0,
            mlp_scalar: 8.0,
            // Adjacent-line pair fetch that shuts off at 512 B strides
            // (the §5.1.1 finding: two lines at small strides, one at
            // stride-64).
            prefetch: PrefetchKind::AdjacentLine { disable_at_bytes: 512 },
            // AVX2 gather is microcoded on BDW: slower than scalar
            // loads per element (Fig 6: negative improvement).
            gather_cycles_per_elem: Some(2.8),
            scatter_cycles_per_elem: None, // AVX2 has no scatter
            scalar_cycles_per_elem: 2.2,
            scalar_dram_efficiency: 1.10,
            simd_lanes: 4.0, // AVX2
            native_regime: VectorRegime::EmulatedGather,
            tlb: TlbTable {
                // BDW STLB: 1536 x 4K; small dedicated 2M/1G DTLBs.
                four_kb: TlbGeometry { entries: 1536, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 1536, assoc: 4 },
                two_mb: TlbGeometry { entries: 32, assoc: 4 },
                one_gb: TlbGeometry { entries: 4, assoc: 4 },
            },
            tlb_walk_ns: 70.0,
            coherence_ns: 220.0,
            absorbs_repeated_writes: false,
            numa: NumaConfig::single(),
            // 4-channel DDR4-2400, 4 bank groups x 4 banks per rank.
            dram: DramConfig {
                channels: 4,
                ranks: 1,
                bank_groups: 4,
                banks: 4,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 32.0,
            },
        },
        CpuPlatform {
            name: "skx",
            full_name: "Skylake (Platinum 8160, one socket)",
            threads: 16,
            freq_ghz: 2.1,
            l1_kb: 32, l1_assoc: 8,
            l2_kb: 1024, l2_assoc: 16,
            l3_mb: 33, l3_assoc: 11,
            stream_gbs: 97.163,
            l2_gbs_per_thread: 42.0,
            l3_gbs: 300.0,
            dram_latency_ns: 85.0,
            mlp_vector: 16.0,
            mlp_scalar: 10.0,
            // "always brings in two cache lines, no matter the stride"
            prefetch: PrefetchKind::NextLine { degree: 1 },
            gather_cycles_per_elem: Some(0.95),
            scatter_cycles_per_elem: Some(1.6),
            scalar_cycles_per_elem: 2.0,
            scalar_dram_efficiency: 0.78,
            simd_lanes: 8.0, // AVX-512
            native_regime: VectorRegime::HardwareGS,
            tlb: TlbTable {
                // SKX STLB shares 1536 entries for 4K/2M; 16 x 1G.
                four_kb: TlbGeometry { entries: 1536, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 1536, assoc: 4 },
                two_mb: TlbGeometry { entries: 1536, assoc: 4 },
                one_gb: TlbGeometry { entries: 16, assoc: 4 },
            },
            tlb_walk_ns: 55.0,
            coherence_ns: 240.0,
            absorbs_repeated_writes: false,
            numa: NumaConfig::single(),
            // 6-channel DDR4-2666: the odd channel count decorrelates
            // power-of-two row strides (see `--suite dram`).
            dram: DramConfig {
                channels: 6,
                ranks: 1,
                bank_groups: 4,
                banks: 4,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 32.0,
            },
        },
        CpuPlatform {
            name: "clx",
            full_name: "Cascade Lake (Platinum 8260L, one socket)",
            threads: 12,
            freq_ghz: 2.4,
            l1_kb: 32, l1_assoc: 8,
            l2_kb: 1024, l2_assoc: 16,
            l3_mb: 36, l3_assoc: 11,
            stream_gbs: 66.661,
            l2_gbs_per_thread: 46.0,
            l3_gbs: 320.0,
            dram_latency_ns: 80.0,
            mlp_vector: 18.0,
            mlp_scalar: 10.0,
            prefetch: PrefetchKind::NextLine { degree: 1 },
            gather_cycles_per_elem: Some(0.9),
            // CLX tweaks help hard-to-optimize scatters (§5.4.2 item 4)
            scatter_cycles_per_elem: Some(1.3),
            scalar_cycles_per_elem: 2.0,
            scalar_dram_efficiency: 0.80,
            simd_lanes: 8.0, // AVX-512
            native_regime: VectorRegime::HardwareGS,
            tlb: TlbTable {
                // CLX STLB shares 1536 entries for 4K/2M; 16 x 1G.
                four_kb: TlbGeometry { entries: 1536, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 1536, assoc: 4 },
                two_mb: TlbGeometry { entries: 1536, assoc: 4 },
                one_gb: TlbGeometry { entries: 16, assoc: 4 },
            },
            tlb_walk_ns: 50.0,
            coherence_ns: 190.0,
            absorbs_repeated_writes: false,
            numa: NumaConfig::single(),
            // 6-channel DDR4-2933 (same interleave shape as SKX).
            dram: DramConfig {
                channels: 6,
                ranks: 1,
                bank_groups: 4,
                banks: 4,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 32.0,
            },
        },
        CpuPlatform {
            name: "tx2",
            full_name: "ThunderX2 (28-core ARM, one socket)",
            threads: 28,
            freq_ghz: 2.2,
            l1_kb: 32, l1_assoc: 8,
            l2_kb: 256, l2_assoc: 8,
            l3_mb: 32, l3_assoc: 16,
            stream_gbs: 120.0,
            l2_gbs_per_thread: 22.0,
            l3_gbs: 260.0,
            dram_latency_ns: 110.0,
            mlp_vector: 12.0,
            mlp_scalar: 12.0,
            // Aggressive next-2-lines streamer: keeps over-fetching far
            // past stride-16 (the paper's steep-drop suspicion).
            prefetch: PrefetchKind::NextLine { degree: 2 },
            gather_cycles_per_elem: None, // no G/S support at all
            scatter_cycles_per_elem: None,
            scalar_cycles_per_elem: 1.4,
            scalar_dram_efficiency: 1.0,
            simd_lanes: 2.0, // NEON 128-bit
            native_regime: VectorRegime::MaskedSve,
            tlb: TlbTable {
                // TX2: large unified L2 TLB for 4K/2M (64K native too).
                four_kb: TlbGeometry { entries: 2048, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 2048, assoc: 4 },
                two_mb: TlbGeometry { entries: 2048, assoc: 4 },
                one_gb: TlbGeometry { entries: 16, assoc: 4 },
            },
            tlb_walk_ns: 80.0,
            coherence_ns: 200.0,
            // §5.4.2 item 1: handles writing the same location over and
            // over very well.
            absorbs_repeated_writes: true,
            numa: NumaConfig::single(),
            // 8-channel DDR4-2666 (TX2's wide memory system).
            dram: DramConfig {
                channels: 8,
                ranks: 1,
                bank_groups: 2,
                banks: 4,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 32.0,
            },
        },
        CpuPlatform {
            name: "naples",
            full_name: "AMD Naples (EPYC 7601, one socket)",
            threads: 16,
            freq_ghz: 2.2,
            l1_kb: 32, l1_assoc: 8,
            l2_kb: 512, l2_assoc: 8,
            // Victim L3 split across CCXs: model a smaller effective
            // shared capacity with modest bandwidth (the Fig 9 "cache
            // architecture much less capable" observation).
            l3_mb: 8, l3_assoc: 16,
            stream_gbs: 97.0,
            l2_gbs_per_thread: 28.0,
            l3_gbs: 140.0,
            dram_latency_ns: 105.0,
            mlp_vector: 14.0,
            mlp_scalar: 9.0,
            // Stride prefetcher: useful prefetches only, page-bounded —
            // the flat 1/8 plateau after stride-8 in Fig 3.
            prefetch: PrefetchKind::Stride { degree: 4 },
            gather_cycles_per_elem: Some(1.5),
            scatter_cycles_per_elem: None, // AVX2: no scatter insn
            scalar_cycles_per_elem: 2.0,
            scalar_dram_efficiency: 0.85,
            simd_lanes: 4.0, // AVX2
            native_regime: VectorRegime::EmulatedGather,
            tlb: TlbTable {
                // Naples L2 TLB holds 4K and 2M; 16 x 1G.
                four_kb: TlbGeometry { entries: 1536, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 1536, assoc: 4 },
                two_mb: TlbGeometry { entries: 1536, assoc: 4 },
                one_gb: TlbGeometry { entries: 16, assoc: 4 },
            },
            tlb_walk_ns: 75.0,
            coherence_ns: 320.0,
            absorbs_repeated_writes: false,
            numa: NumaConfig::single(),
            // Per-die 2-channel DDR4 x 2 dies feeding one socket's
            // sweep: modelled as 4 channels of 4x4 banks.
            dram: DramConfig {
                channels: 4,
                ranks: 1,
                bank_groups: 4,
                banks: 4,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 32.0,
            },
        },
    ]
}

/// Derive a two-socket variant of a Table 3 part: double the threads,
/// aggregate the DRAM and L3 bandwidth across both sockets' channels,
/// raise the coherence cost (cross-socket snoops travel the link), and
/// attach the interconnect model. Per-socket structures — caches, TLB,
/// and the banked DRAM geometry — keep the base part's shape;
/// `sim::topology` instantiates one banked DRAM model per node.
fn dual_socket(
    base: &str,
    name: &'static str,
    full_name: &'static str,
    link_latency_ns: f64,
    link_penalty_bytes: f64,
) -> CpuPlatform {
    let mut p = cpus()
        .into_iter()
        .find(|p| p.name == base)
        .expect("multi-socket variants derive from Table 3 parts");
    p.name = name;
    p.full_name = full_name;
    p.threads *= 2;
    p.stream_gbs *= 2.0;
    p.l3_gbs *= 2.0;
    p.coherence_ns *= 1.5;
    p.numa = NumaConfig {
        sockets: 2,
        link_latency_ns,
        link_penalty_bytes,
    };
    p
}

/// Derived two-socket variants for the NUMA studies (`--suite numa`).
/// They are not part of the Table 3 registry ([`cpus`]/[`all`] are
/// unchanged — the paper's protocol is single-socket); [`by_name`]
/// resolves them, so `-a skx-2s` and JSON configs reach them directly.
pub fn multi_socket_cpus() -> Vec<CpuPlatform> {
    vec![
        dual_socket(
            "skx",
            "skx-2s",
            "Skylake (Platinum 8160, two sockets, UPI)",
            70.0,
            96.0,
        ),
        dual_socket(
            "tx2",
            "tx2-2s",
            "ThunderX2 (two sockets, CCPI2)",
            80.0,
            112.0,
        ),
        dual_socket(
            "naples",
            "naples-2s",
            "AMD Naples (EPYC 7601, two sockets, xGMI)",
            90.0,
            128.0,
        ),
    ]
}

/// GPU registry, Table 3 order.
pub fn gpus() -> Vec<GpuPlatform> {
    vec![
        GpuPlatform {
            name: "k40c",
            full_name: "Kepler K40c",
            stream_gbs: 193.855,
            // Kepler global loads move full 128 B L1 lines — the "less
            // able to coalesce" curve of Fig 5.
            sector_bytes: 128,
            row_bytes: 1024,
            row_activate_bytes: 64.0,
            l2_kb: 1536, l2_assoc: 16,
            l2_gbs: 450.0,
            tlb: TlbTable {
                // 64 KiB native large pages; 4 KiB modelled at the same
                // entry count, bigger sizes with fewer entries.
                four_kb: TlbGeometry { entries: 512, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 512, assoc: 4 },
                two_mb: TlbGeometry { entries: 64, assoc: 4 },
                one_gb: TlbGeometry { entries: 16, assoc: 4 },
            },
            tlb_walk_ns: 600.0,
            tlb_mlp: 8.0,
            write_contend_ns: 9.0,
            txn_per_ns: 12.0,
            // GDDR5: 12 channels x 16 banks, no bank groups.
            dram: DramConfig {
                channels: 12,
                ranks: 1,
                bank_groups: 1,
                banks: 16,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 16.0,
            },
        },
        GpuPlatform {
            name: "titanxp",
            full_name: "Titan Xp (Pascal)",
            stream_gbs: 443.533,
            sector_bytes: 32,
            row_bytes: 2048,
            row_activate_bytes: 48.0,
            l2_kb: 3072, l2_assoc: 16,
            l2_gbs: 1100.0,
            tlb: TlbTable {
                // 64 KiB native large pages; 4 KiB modelled at the same
                // entry count, bigger sizes with fewer entries.
                four_kb: TlbGeometry { entries: 2048, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 2048, assoc: 4 },
                two_mb: TlbGeometry { entries: 256, assoc: 4 },
                one_gb: TlbGeometry { entries: 16, assoc: 4 },
            },
            tlb_walk_ns: 450.0,
            tlb_mlp: 16.0,
            write_contend_ns: 4.0,
            txn_per_ns: 28.0,
            // GDDR5X: 12 channels x 16 banks.
            dram: DramConfig {
                channels: 12,
                ranks: 1,
                bank_groups: 1,
                banks: 16,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 12.0,
            },
        },
        GpuPlatform {
            name: "p100",
            full_name: "Pascal P100 (HBM2)",
            stream_gbs: 541.835,
            sector_bytes: 32,
            row_bytes: 2048,
            row_activate_bytes: 40.0,
            l2_kb: 4096, l2_assoc: 16,
            l2_gbs: 1400.0,
            tlb: TlbTable {
                // 64 KiB native large pages; 4 KiB modelled at the same
                // entry count, bigger sizes with fewer entries.
                four_kb: TlbGeometry { entries: 2048, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 2048, assoc: 4 },
                two_mb: TlbGeometry { entries: 256, assoc: 4 },
                one_gb: TlbGeometry { entries: 16, assoc: 4 },
            },
            tlb_walk_ns: 400.0,
            tlb_mlp: 16.0,
            write_contend_ns: 3.5,
            txn_per_ns: 32.0,
            // HBM2: 16 pseudo-channels x 16 banks, cheap activations.
            dram: DramConfig {
                channels: 16,
                ranks: 1,
                bank_groups: 1,
                banks: 16,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 8.0,
            },
        },
        GpuPlatform {
            name: "v100",
            full_name: "Volta V100 (HBM2)",
            stream_gbs: 868.0,
            sector_bytes: 32,
            row_bytes: 2048,
            row_activate_bytes: 32.0,
            // Big unified L1 + 6 MB L2: the Fig 7 "V100 peeks above the
            // 100% ring" caching behaviour.
            l2_kb: 6144, l2_assoc: 16,
            l2_gbs: 2400.0,
            tlb: TlbTable {
                // 64 KiB native large pages; 4 KiB modelled at the same
                // entry count, bigger sizes with fewer entries.
                four_kb: TlbGeometry { entries: 4096, assoc: 4 },
                sixty_four_kb: TlbGeometry { entries: 4096, assoc: 4 },
                two_mb: TlbGeometry { entries: 512, assoc: 4 },
                one_gb: TlbGeometry { entries: 16, assoc: 4 },
            },
            tlb_walk_ns: 350.0,
            tlb_mlp: 24.0,
            write_contend_ns: 2.5,
            txn_per_ns: 80.0,
            // HBM2: 16 pseudo-channels x 16 banks, cheap activations.
            dram: DramConfig {
                channels: 16,
                ranks: 1,
                bank_groups: 1,
                banks: 16,
                interleave: InterleavePolicy::RowBankChannel,
                conflict_penalty_bytes: 8.0,
            },
        },
    ]
}

/// Either kind of platform, as stored in the registry.
#[derive(Debug, Clone)]
pub enum Platform {
    Cpu(CpuPlatform),
    Gpu(GpuPlatform),
}

impl Platform {
    pub fn name(&self) -> &'static str {
        match self {
            Platform::Cpu(c) => c.name,
            Platform::Gpu(g) => g.name,
        }
    }

    pub fn full_name(&self) -> &'static str {
        match self {
            Platform::Cpu(c) => c.full_name,
            Platform::Gpu(g) => g.full_name,
        }
    }

    pub fn stream_gbs(&self) -> f64 {
        match self {
            Platform::Cpu(c) => c.stream_gbs,
            Platform::Gpu(g) => g.stream_gbs,
        }
    }

    pub fn is_gpu(&self) -> bool {
        matches!(self, Platform::Gpu(_))
    }
}

/// Full registry (CPUs then GPUs, Table 3 order).
pub fn all() -> Vec<Platform> {
    cpus()
        .into_iter()
        .map(Platform::Cpu)
        .chain(gpus().into_iter().map(Platform::Gpu))
        .collect()
}

/// Look up a CPU platform by short name (Table 3 parts plus the
/// derived [`multi_socket_cpus`] variants).
pub fn by_name(name: &str) -> Result<CpuPlatform> {
    cpus()
        .into_iter()
        .chain(multi_socket_cpus())
        .find(|p| p.name.eq_ignore_ascii_case(name))
        .ok_or_else(|| Error::UnknownPlatform(name.to_string()))
}

/// Look up a GPU platform by short name.
pub fn gpu_by_name(name: &str) -> Result<GpuPlatform> {
    gpus()
        .into_iter()
        .find(|p| p.name.eq_ignore_ascii_case(name))
        .ok_or_else(|| Error::UnknownPlatform(name.to_string()))
}

/// Look up either kind.
pub fn any_by_name(name: &str) -> Result<Platform> {
    all()
        .into_iter()
        .find(|p| p.name().eq_ignore_ascii_case(name))
        .ok_or_else(|| Error::UnknownPlatform(name.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_table3() {
        assert_eq!(cpus().len(), 6);
        assert_eq!(gpus().len(), 4);
        assert_eq!(all().len(), 10);
        // Table 3 STREAM anchors
        assert!((by_name("knl").unwrap().stream_gbs - 249.313).abs() < 1e-9);
        assert!((by_name("bdw").unwrap().stream_gbs - 43.885).abs() < 1e-9);
        assert!((by_name("skx").unwrap().stream_gbs - 97.163).abs() < 1e-9);
        assert!((by_name("clx").unwrap().stream_gbs - 66.661).abs() < 1e-9);
        assert!((by_name("tx2").unwrap().stream_gbs - 120.0).abs() < 1e-9);
        assert!((gpu_by_name("k40c").unwrap().stream_gbs - 193.855).abs() < 1e-9);
        assert!((gpu_by_name("v100").unwrap().stream_gbs - 868.0).abs() < 1e-9);
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert!(by_name("SKX").is_ok());
        assert!(gpu_by_name("P100").is_ok());
        assert!(any_by_name("Naples").is_ok());
        assert!(by_name("epyc2").is_err());
    }

    #[test]
    fn paper_isa_facts() {
        // TX2 has no G/S support at all (Fig 6 flat 0%).
        let tx2 = by_name("tx2").unwrap();
        assert!(tx2.gather_cycles_per_elem.is_none());
        assert!(tx2.scatter_cycles_per_elem.is_none());
        assert!(tx2.absorbs_repeated_writes);
        // Naples and BDW lack scatter instructions.
        assert!(by_name("naples").unwrap().scatter_cycles_per_elem.is_none());
        assert!(by_name("bdw").unwrap().scatter_cycles_per_elem.is_none());
        // SKX/CLX/KNL have both.
        for n in ["skx", "clx", "knl"] {
            let p = by_name(n).unwrap();
            assert!(p.gather_cycles_per_elem.is_some(), "{n}");
            assert!(p.scatter_cycles_per_elem.is_some(), "{n}");
        }
        // BDW gather is slower than its scalar loads (Fig 6 negative).
        let bdw = by_name("bdw").unwrap();
        assert!(bdw.gather_cycles_per_elem.unwrap() > bdw.scalar_cycles_per_elem);
    }

    #[test]
    fn regime_support_follows_isa() {
        use VectorRegime::*;
        // AVX-512 platforms: scalar, emulated gather, hardware G/S.
        for n in ["knl", "skx", "clx"] {
            let p = by_name(n).unwrap();
            assert_eq!(
                p.supported_regimes(),
                vec![Scalar, EmulatedGather, HardwareGS],
                "{n}"
            );
            assert_eq!(p.native_regime, HardwareGS, "{n}");
        }
        // AVX2 platforms: gather exists, scatter does not.
        for n in ["bdw", "naples"] {
            let p = by_name(n).unwrap();
            assert_eq!(p.supported_regimes(), vec![Scalar, EmulatedGather], "{n}");
            assert_eq!(p.native_regime, EmulatedGather, "{n}");
            assert!(!p.supports_regime(HardwareGS), "{n}");
            assert!(!p.supports_regime(MaskedSve), "{n}");
        }
        // TX2: masked lanes only, no G/S instruction at all.
        let tx2 = by_name("tx2").unwrap();
        assert_eq!(tx2.supported_regimes(), vec![Scalar, MaskedSve]);
        assert_eq!(tx2.native_regime, MaskedSve);
        // Every platform supports its own native regime and Scalar.
        for p in cpus() {
            assert!(p.supports_regime(p.native_regime), "{}", p.name);
            assert!(p.supports_regime(Scalar), "{}", p.name);
        }
    }

    #[test]
    fn simd_lanes_per_isa_class() {
        // AVX-512 retires 8 doubles per op, AVX2 4, TX2 NEON 2 — the
        // Fig 6 lane widths that the dense STREAM issue model uses.
        for n in ["knl", "skx", "clx"] {
            assert_eq!(by_name(n).unwrap().simd_lanes, 8.0, "{n}");
        }
        for n in ["bdw", "naples"] {
            assert_eq!(by_name(n).unwrap().simd_lanes, 4.0, "{n}");
        }
        assert_eq!(by_name("tx2").unwrap().simd_lanes, 2.0);
    }

    #[test]
    fn regime_names_parse_and_roundtrip() {
        for &r in VectorRegime::ALL {
            assert_eq!(VectorRegime::parse(r.name()).unwrap(), r);
            // Case-insensitive, and Display matches name().
            let upper = r.name().to_ascii_uppercase();
            assert_eq!(VectorRegime::parse(&upper).unwrap(), r);
            assert_eq!(format!("{r}"), r.name());
        }
        let err = VectorRegime::parse("avx9").unwrap_err();
        assert!(err.to_string().contains("avx9"));
        assert!(err.to_string().contains("hardware-gs"));
    }

    #[test]
    fn prefetcher_kinds_per_paper() {
        assert!(matches!(
            by_name("bdw").unwrap().prefetch,
            PrefetchKind::AdjacentLine { .. }
        ));
        assert!(matches!(
            by_name("skx").unwrap().prefetch,
            PrefetchKind::NextLine { degree: 1 }
        ));
        assert!(matches!(
            by_name("tx2").unwrap().prefetch,
            PrefetchKind::NextLine { degree: 2 }
        ));
        assert!(matches!(
            by_name("naples").unwrap().prefetch,
            PrefetchKind::Stride { .. }
        ));
    }

    #[test]
    fn k40_coalesces_at_line_granularity() {
        assert_eq!(gpu_by_name("k40c").unwrap().sector_bytes, 128);
        assert_eq!(gpu_by_name("p100").unwrap().sector_bytes, 32);
    }

    #[test]
    fn tlb_tables_are_cpuid_shaped() {
        use crate::sim::PageSize;
        // Per-size tables: no machine has more huge-page than base-page
        // entries, and every size has a usable geometry.
        for p in cpus() {
            let t = p.tlb;
            assert!(t.two_mb.entries <= t.four_kb.entries, "{}", p.name);
            assert!(t.one_gb.entries <= t.two_mb.entries, "{}", p.name);
            for &size in PageSize::ALL {
                let g = t.geometry(size);
                assert!(g.entries >= g.assoc, "{} {size}", p.name);
            }
        }
        for p in gpus() {
            let t = p.tlb;
            assert!(t.one_gb.entries <= t.sixty_four_kb.entries, "{}", p.name);
        }
        // The 4 KiB geometries match the seed model's dTLB reach.
        assert_eq!(by_name("skx").unwrap().tlb.four_kb.entries, 1536);
        assert_eq!(by_name("knl").unwrap().tlb.four_kb.entries, 256);
        assert_eq!(gpu_by_name("v100").unwrap().tlb.sixty_four_kb.entries, 4096);
        // BDW keeps only small dedicated huge-page DTLBs.
        assert_eq!(by_name("bdw").unwrap().tlb.two_mb.entries, 32);
    }

    #[test]
    fn dram_geometry_is_sane() {
        // Every platform carries a usable banked-DRAM config, and the
        // shipped default is fine-grained channel interleave (the
        // calibration anchors were measured under it).
        for p in cpus() {
            assert!(p.dram.total_banks() >= 16, "{}", p.name);
            assert_eq!(
                p.dram.interleave,
                InterleavePolicy::RowBankChannel,
                "{}",
                p.name
            );
            assert!(p.dram.conflict_penalty_bytes > 0.0, "{}", p.name);
        }
        for p in gpus() {
            assert!(p.dram.total_banks() >= 64, "{}", p.name);
            assert!(
                p.dram.conflict_penalty_bytes
                    <= cpus()[0].dram.conflict_penalty_bytes,
                "{}: GPU parts have more bank-level parallelism",
                p.name
            );
        }
        // SKX/CLX: six channels — the odd channel count that breaks
        // power-of-two aliasing in the dram suite.
        assert_eq!(by_name("skx").unwrap().dram.channels, 6);
        assert_eq!(by_name("clx").unwrap().dram.total_banks(), 96);
    }

    #[test]
    fn thread_sweep_shapes() {
        assert_eq!(
            by_name("skx").unwrap().thread_sweep(),
            vec![1, 2, 4, 8, 16]
        );
        assert_eq!(
            by_name("tx2").unwrap().thread_sweep(),
            vec![1, 2, 4, 8, 16, 28]
        );
        assert_eq!(
            by_name("knl").unwrap().thread_sweep(),
            vec![1, 2, 4, 8, 16, 32, 64]
        );
        // Every sweep is strictly increasing and ends at the max.
        for p in cpus() {
            let s = p.thread_sweep();
            assert_eq!(*s.first().unwrap(), 1, "{}", p.name);
            assert_eq!(*s.last().unwrap(), p.threads, "{}", p.name);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "{}", p.name);
        }
    }

    #[test]
    fn multi_socket_variants_resolve_and_derive() {
        // The Table 3 registry is untouched: every part there is
        // single-socket, and the counts pinned above still hold.
        for p in cpus() {
            assert_eq!(p.numa, NumaConfig::single(), "{}", p.name);
        }
        let variants = multi_socket_cpus();
        assert_eq!(variants.len(), 3);
        for p in &variants {
            assert_eq!(p.numa.sockets, 2, "{}", p.name);
            assert!(p.numa.link_latency_ns > 0.0, "{}", p.name);
            assert!(p.numa.link_penalty_bytes > 0.0, "{}", p.name);
            let base =
                by_name(p.name.strip_suffix("-2s").unwrap()).unwrap();
            // Aggregate resources double; per-socket structures keep
            // the base geometry.
            assert_eq!(p.threads, 2 * base.threads, "{}", p.name);
            assert!(
                (p.stream_gbs - 2.0 * base.stream_gbs).abs() < 1e-9,
                "{}",
                p.name
            );
            assert!((p.l3_gbs - 2.0 * base.l3_gbs).abs() < 1e-9);
            assert_eq!(p.l2_kb, base.l2_kb, "{}", p.name);
            assert_eq!(p.dram.channels, base.dram.channels, "{}", p.name);
            assert!(p.coherence_ns > base.coherence_ns, "{}", p.name);
            assert_eq!(p.native_regime, base.native_regime, "{}", p.name);
        }
        // by_name resolves them, case-insensitively; cpus()/all() do
        // not grow.
        assert_eq!(by_name("skx-2s").unwrap().numa.sockets, 2);
        assert_eq!(by_name("TX2-2S").unwrap().threads, 56);
        assert!(by_name("bdw-2s").is_err());
        assert!(!all().iter().any(|p| p.name().ends_with("-2s")));
    }

    #[test]
    fn platform_enum_accessors() {
        let p = any_by_name("v100").unwrap();
        assert!(p.is_gpu());
        assert_eq!(p.name(), "v100");
        assert!(p.stream_gbs() > 800.0);
        let c = any_by_name("bdw").unwrap();
        assert!(!c.is_gpu());
        assert!(c.full_name().contains("Broadwell"));
    }
}
