//! Spatter's command-line interface (no `clap` in the offline vendor
//! set — a getopt-style parser that mirrors the original tool's flags).
//!
//! ```text
//! spatter -k Gather -p UNIFORM:8:1 -d 8 -l 16777216 [-b openmp] [-a skx]
//! spatter -j config.json [-a skx]
//! spatter --list-platforms | --list-patterns
//! spatter --suite fig3 [--out bench_out/]
//! ```

use crate::error::{Error, Result};
use crate::pattern::{Kernel, Pattern};
use crate::platforms::VectorRegime;
use crate::sim::{NumaPlacement, PageSize};

/// Which backend executes the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Simulated multi-core CPU (paper's OpenMP backend).
    OpenMp,
    /// Simulated GPU (paper's CUDA backend).
    Cuda,
    /// Simulated scalar (non-vectorized) CPU baseline.
    Scalar,
    /// Real execution through PJRT-CPU of the AOT'd L1/L2 kernels.
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "openmp" | "omp" => Ok(BackendKind::OpenMp),
            "cuda" => Ok(BackendKind::Cuda),
            "scalar" => Ok(BackendKind::Scalar),
            "pjrt" | "native" => Ok(BackendKind::Pjrt),
            _ => Err(Error::Cli(format!(
                "unknown backend '{s}' (openmp|cuda|scalar|pjrt)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::OpenMp => "openmp",
            BackendKind::Cuda => "cuda",
            BackendKind::Scalar => "scalar",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// A parsed invocation.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    /// Run a single pattern (-k -p -d -l).
    Run(RunArgs),
    /// Run every configuration in a JSON file (-j).
    Json { path: String, common: CommonArgs },
    /// Regenerate a paper experiment (--suite fig3 ...).
    Suite {
        name: String,
        out_dir: String,
        /// Worker threads for the run queue (--jobs).
        jobs: usize,
        /// Reduced-count CI mode (--fast).
        fast: bool,
    },
    /// Informational listings.
    ListPlatforms,
    ListPatterns,
    Help,
}

/// Flags shared by run modes.
#[derive(Debug, Clone, PartialEq)]
pub struct CommonArgs {
    /// Simulated platform name (-a / --arch), default "skx".
    pub platform: String,
    /// Backend (-b), default OpenMP.
    pub backend: BackendKind,
    /// Runs per pattern (--runs), default 10 per the paper.
    pub runs: usize,
    /// Validate numerics through the PJRT path (--validate).
    pub validate: bool,
    /// Emit JSON instead of a table (--json-out).
    pub json_out: bool,
    /// Translation page size (--page-size). `None` keeps each
    /// backend's default (4 KiB CPU, 64 KiB GPU large pages).
    pub page_size: Option<PageSize>,
    /// Simulated OpenMP thread count (--threads). `None` keeps each
    /// CPU platform's single-socket default; GPU and real-execution
    /// backends reject the flag.
    pub threads: Option<usize>,
    /// Vectorization regime (--vector-regime). `None` keeps each CPU
    /// platform's native regime (its ISA's best gather/scatter path);
    /// GPU, scalar, and real-execution backends reject the flag.
    pub vector_regime: Option<VectorRegime>,
    /// NUMA page-placement policy (--numa-placement). `None` keeps the
    /// default (first-touch). Only changes results on multi-socket
    /// platforms; single-socket runs are placement-inert by
    /// construction.
    pub numa_placement: Option<NumaPlacement>,
    /// Worker threads for multi-config sweeps (--jobs). Default: the
    /// machine's available parallelism. Output is byte-identical for
    /// any value (order-preserving scheduler).
    pub jobs: usize,
    /// Bounded-memory run mode (--stream): parse the -j config array
    /// incrementally and emit the JSON document chunk-by-chunk as the
    /// in-order result prefix completes. Requires --json-out (the
    /// table renderer needs the full record set for column widths).
    pub stream: bool,
}

impl Default for CommonArgs {
    fn default() -> Self {
        CommonArgs {
            platform: "skx".to_string(),
            backend: BackendKind::OpenMp,
            runs: crate::stats::RUNS_PER_PATTERN,
            validate: false,
            json_out: false,
            page_size: None,
            threads: None,
            vector_regime: None,
            numa_placement: None,
            jobs: crate::coordinator::default_jobs(),
            stream: false,
        }
    }
}

/// Arguments for a single-pattern run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunArgs {
    pub kernel: Kernel,
    pub pattern: Pattern,
    pub common: CommonArgs,
}

/// Parse argv (excluding argv[0]).
pub fn parse_args(args: &[String]) -> Result<Command> {
    let mut kernel: Option<Kernel> = None;
    let mut pattern_spec: Option<String> = None;
    let mut gather_spec: Option<String> = None;
    let mut scatter_spec: Option<String> = None;
    let mut deltas: Option<Vec<i64>> = None;
    let mut count: Option<usize> = None;
    let mut json_path: Option<String> = None;
    let mut suite: Option<String> = None;
    let mut out_dir = "bench_out".to_string();
    let mut fast = false;
    let mut jobs_set = false;
    let mut common = CommonArgs::default();

    let mut it = args.iter().peekable();
    while let Some(arg) = it.next() {
        let mut take = |name: &str| -> Result<String> {
            it.next()
                .cloned()
                .ok_or_else(|| Error::Cli(format!("{name} requires a value")))
        };
        match arg.as_str() {
            "-k" | "--kernel" => kernel = Some(Kernel::parse(&take("-k")?)?),
            "-p" | "--pattern" => pattern_spec = Some(take("-p")?),
            "-g" | "--pattern-gather" => gather_spec = Some(take("-g")?),
            "-u" | "--pattern-scatter" => scatter_spec = Some(take("-u")?),
            "-d" | "--delta" => {
                // Single delta or a comma-separated cycling list (the
                // temporal-locality extension, paper §7 item 1).
                let v = take("-d")?;
                let list: std::result::Result<Vec<i64>, _> =
                    v.split(',').map(|t| t.trim().parse::<i64>()).collect();
                let list = list
                    .map_err(|_| Error::Cli(format!("bad delta '{v}'")))?;
                if list.is_empty() {
                    return Err(Error::Cli("empty delta list".into()));
                }
                deltas = Some(list);
            }
            "-l" | "--count" => {
                let v = take("-l")?;
                count = Some(parse_count(&v)?);
            }
            "-j" | "--json" => json_path = Some(take("-j")?),
            "-a" | "--arch" | "--platform" => common.platform = take("-a")?,
            "-b" | "--backend" => common.backend = BackendKind::parse(&take("-b")?)?,
            "--runs" => {
                let v = take("--runs")?;
                common.runs = v
                    .parse()
                    .map_err(|_| Error::Cli(format!("bad --runs '{v}'")))?;
                if common.runs == 0 {
                    return Err(Error::Cli("--runs must be > 0".into()));
                }
            }
            "--page-size" => {
                common.page_size =
                    Some(PageSize::parse(&take("--page-size")?)?)
            }
            "--threads" => {
                let v = take("--threads")?;
                let t: usize = v
                    .parse()
                    .map_err(|_| Error::Cli(format!("bad --threads '{v}'")))?;
                if t == 0 {
                    return Err(Error::Cli("--threads must be > 0".into()));
                }
                common.threads = Some(t);
            }
            "--vector-regime" => {
                common.vector_regime =
                    Some(VectorRegime::parse(&take("--vector-regime")?)?)
            }
            "--numa-placement" => {
                common.numa_placement =
                    Some(NumaPlacement::parse(&take("--numa-placement")?)?)
            }
            "--jobs" => {
                let v = take("--jobs")?;
                common.jobs = v
                    .parse()
                    .map_err(|_| Error::Cli(format!("bad --jobs '{v}'")))?;
                if common.jobs == 0 {
                    return Err(Error::Cli("--jobs must be > 0".into()));
                }
                jobs_set = true;
            }
            "--fast" => fast = true,
            "--stream" => common.stream = true,
            "--validate" => common.validate = true,
            "--json-out" => common.json_out = true,
            "--suite" => suite = Some(take("--suite")?),
            "--out" => out_dir = take("--out")?,
            "--list-platforms" => return Ok(Command::ListPlatforms),
            "--list-patterns" => return Ok(Command::ListPatterns),
            "-h" | "--help" => return Ok(Command::Help),
            other => {
                return Err(Error::Cli(format!("unknown argument '{other}'")))
            }
        }
    }

    if let Some(name) = suite {
        if common.threads.is_some() {
            return Err(Error::Cli(
                "--threads does not apply to suites (threadscale sweeps the \
                 thread axis itself); use it with -k/-p or -j runs"
                    .into(),
            ));
        }
        if common.vector_regime.is_some() {
            return Err(Error::Cli(
                "--vector-regime does not apply to suites (simd sweeps the \
                 regime axis itself); use it with -k/-p or -j runs"
                    .into(),
            ));
        }
        if common.numa_placement.is_some() {
            return Err(Error::Cli(
                "--numa-placement does not apply to suites (numa sweeps the \
                 placement axis itself); use it with -k/-p or -j runs"
                    .into(),
            ));
        }
        return Ok(Command::Suite {
            name,
            out_dir,
            jobs: common.jobs,
            fast,
        });
    }
    if fast {
        return Err(Error::Cli(
            "--fast only applies to --suite runs".into(),
        ));
    }
    if json_path.is_none() && jobs_set {
        return Err(Error::Cli(
            "--jobs needs a run queue: use it with -j CONFIG.json or --suite"
                .into(),
        ));
    }
    if common.stream {
        if json_path.is_none() {
            return Err(Error::Cli(
                "--stream reads a config array incrementally: use it with \
                 -j CONFIG.json"
                    .into(),
            ));
        }
        if !common.json_out {
            return Err(Error::Cli(
                "--stream requires --json-out (the table renderer needs the \
                 whole record set for column widths)"
                    .into(),
            ));
        }
    }
    if let Some(path) = json_path {
        return Ok(Command::Json { path, common });
    }
    if args.is_empty() {
        return Ok(Command::Help);
    }

    let kernel = kernel.ok_or_else(|| {
        Error::Cli(
            "missing -k Gather|Scatter|GS|Copy|Scale|Add|Triad|GUPS".into(),
        )
    })?;
    let mut pattern = if kernel.is_baseline() {
        // Dense baselines (STREAM tetrad + GUPS) take no pattern:
        // -d and -l size the streams.
        if pattern_spec.is_some() || gather_spec.is_some() || scatter_spec.is_some()
        {
            return Err(Error::Cli(format!(
                "-k {} is a dense baseline kernel: it takes no pattern \
                 (-p/-g/-u); -d and -l size the streams",
                kernel.name()
            )));
        }
        let d = match deltas.take() {
            None => None,
            Some(list) if list.len() == 1 => Some(list[0]),
            Some(_) => {
                return Err(Error::Cli(format!(
                    "-k {}: -d takes a single value (cycling delta lists \
                     apply to indexed kernels)",
                    kernel.name()
                )))
            }
        };
        if kernel == Kernel::Gups {
            // -d = table size in elements (default 2^26 = 512 MiB of
            // doubles), rounded up to a power of two.
            let table = d.unwrap_or(crate::pattern::GUPS_DEFAULT_TABLE_ELEMS as i64);
            if table <= 0 {
                return Err(Error::Cli(format!(
                    "-k GUPS: table size (-d) must be > 0, got {table}"
                )));
            }
            Pattern::gups(table as usize, 1)
        } else {
            // -d = elements per iteration per operand stream
            // (default 8); the streams are -d * -l elements long.
            let width = d.unwrap_or(8);
            if !(1..=1 << 20).contains(&width) {
                return Err(Error::Cli(format!(
                    "-k {}: stream width (-d) must be in [1, 2^20], got \
                     {width}",
                    kernel.name()
                )));
            }
            Pattern::dense(width as usize, 1)
        }
    } else if kernel == Kernel::GS {
        // GS takes two spec strings: -g (gather/read side) and -u
        // (scatter/write side), mirroring the original tool's
        // --pattern-gather / --pattern-scatter flags.
        if pattern_spec.is_some() {
            return Err(Error::Cli(
                "-k GS takes -g GATHER_PATTERN and -u SCATTER_PATTERN, \
                 not -p"
                    .into(),
            ));
        }
        let g = gather_spec.ok_or_else(|| {
            Error::Cli("missing -g GATHER_PATTERN (required by -k GS)".into())
        })?;
        let u = scatter_spec.ok_or_else(|| {
            Error::Cli("missing -u SCATTER_PATTERN (required by -k GS)".into())
        })?;
        let (gidx, gdelta) = side_indices(&g)?;
        let (uidx, _) = side_indices(&u)?;
        let mut p = Pattern::from_indices(&format!("{g}>{u}"), gidx)
            .with_gs_scatter(uidx);
        // A Table-5 gather side carries the app's default delta, same
        // as the single-kernel path (-d still overrides below).
        if let Some(d) = gdelta {
            p = p.with_delta(d);
        }
        p
    } else {
        if gather_spec.is_some() || scatter_spec.is_some() {
            return Err(Error::Cli(format!(
                "-g/-u apply to -k GS; kernel {} takes a single -p PATTERN",
                kernel.name()
            )));
        }
        let spec = pattern_spec
            .ok_or_else(|| Error::Cli("missing -p PATTERN".into()))?;
        // Table-5 pattern ids are accepted anywhere a spec is; they
        // carry their own default delta.
        match crate::pattern::table5::by_name(&spec) {
            Some(app) => Pattern::from_indices(app.name, app.indices.to_vec())
                .with_delta(app.delta),
            None => Pattern::parse(&spec)?,
        }
    };
    if let Some(d) = deltas {
        pattern = pattern.with_deltas(&d);
    }
    pattern = pattern.with_count(count.unwrap_or(1 << 20));
    pattern.validate_for(kernel)?;
    Ok(Command::Run(RunArgs {
        kernel,
        pattern,
        common,
    }))
}

/// Resolve one side of a GS pattern: a Table-5 id (which also carries
/// the app's default delta) or any `parse_spec` string.
fn side_indices(spec: &str) -> Result<(Vec<i64>, Option<i64>)> {
    match crate::pattern::table5::by_name(spec) {
        Some(app) => Ok((app.indices.to_vec(), Some(app.delta))),
        None => Ok((crate::pattern::parse_spec(spec)?, None)),
    }
}

/// Counts accept plain integers or `2^N`.
fn parse_count(s: &str) -> Result<usize> {
    if let Some(exp) = s.strip_prefix("2^") {
        let e: u32 = exp
            .parse()
            .map_err(|_| Error::Cli(format!("bad count '{s}'")))?;
        if e >= 48 {
            return Err(Error::Cli(format!("count 2^{e} too large")));
        }
        return Ok(1usize << e);
    }
    s.parse()
        .map_err(|_| Error::Cli(format!("bad count '{s}'")))
}

/// Usage text for `--help`.
pub const USAGE: &str = "\
spatter — gather/scatter memory benchmark (paper reproduction)

USAGE:
  spatter -k Gather|Scatter -p PATTERN -d DELTA -l COUNT [options]
  spatter -k GS -g GATHER_PATTERN -u SCATTER_PATTERN -d DELTA -l COUNT
  spatter -k Copy|Scale|Add|Triad [-d WIDTH] -l COUNT   dense STREAM baseline
  spatter -k GUPS [-d TABLE] -l COUNT      random read-modify-write baseline
  spatter -j CONFIG.json [options]
  spatter --suite NAME [--out DIR]     regenerate a paper experiment
  spatter --list-platforms | --list-patterns

PATTERN:
  UNIFORM:N:STRIDE        e.g. UNIFORM:8:1
  MS1:N:BREAKS:GAPS       e.g. MS1:8:4:20
  LAPLACIAN:D:L:SIZE      e.g. LAPLACIAN:2:2:100
  RANDOM:N:RANGE[:SEED]   GUPS-like random indices
  idx0,idx1,...           custom index buffer
  or a Table-5 name, e.g. PENNANT-G5 (with --list-patterns)

OPTIONS:
  -a, --arch NAME      simulated platform (default skx; --list-platforms)
  -b, --backend B      openmp | cuda | scalar | pjrt (default openmp)
  -g, --pattern-gather P   read-side pattern of the GS indexed copy
                       (dst[u[i]] = src[g[i]]); requires -k GS and -u
  -u, --pattern-scatter P  write-side pattern of the GS indexed copy;
                       must have the same index length as -g
  -d, --delta D        base advance; a comma list cycles (temporal
                       locality extension), e.g. -d 0,0,0,16. Dense
                       baselines read it differently: elements per
                       iteration for Copy/Scale/Add/Triad (default 8),
                       table elements for GUPS (default 2^26, rounded
                       up to a power of two)
  -l, --count N        gathers/scatters to perform (accepts 2^N)
      --runs N         runs per pattern (default 10, paper protocol)
      --page-size P    translation page size: 4KB | 64KB | 2MB | 1GB
                       (default: 4KB on CPUs, 64KB native large pages
                       on GPUs); e.g. --page-size 2MB shows huge-delta
                       gathers flipping from TLB-bound to DRAM-bound
      --threads N      simulated OpenMP thread count (CPU backends;
                       default: the platform's single-socket count,
                       e.g. 16 on skx). JSON configs may override per
                       run with a \"threads\" key
      --vector-regime R  vectorization regime for CPU simulation:
                       scalar | emulated-gather | hardware-gs |
                       masked-sve (default: the platform's native
                       regime, e.g. hardware-gs on skx). Platforms
                       reject regimes their ISA lacks. JSON configs may
                       override per run with a \"vector-regime\" key
      --numa-placement P  NUMA page-placement policy for multi-socket
                       platforms (e.g. skx-2s): first-touch | interleave
                       (default first-touch). Single-socket platforms
                       ignore it. JSON configs may override per run with
                       a \"numa-placement\" key
      --jobs N         worker threads for multi-config sweeps and
                       suites (default: available parallelism). Output
                       is byte-identical for any N: results are
                       collected in config order
      --fast           reduced-count suite mode (CI smoke runs)
      --stream         bounded-memory run mode for -j: parse the config
                       array incrementally and emit JSON chunks as the
                       in-order result prefix completes (requires
                       --json-out; output is byte-identical to batch)
      --validate       cross-check numerics through the PJRT path
      --json-out       machine-readable output
      --suite NAME     fig3|fig4|fig5|fig6|fig7|fig8|fig9|table1|table4|
                       pagesize|ustride|threadscale|prefetch|baselines|
                       dram|simd|numa|all
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn paper_example_invocation() {
        // ./spatter -k Gather -p UNIFORM:8:1 -d 8 -l $((2**24))
        let cmd = parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8 -l 2^24")).unwrap();
        match cmd {
            Command::Run(r) => {
                assert_eq!(r.kernel, Kernel::Gather);
                assert_eq!(r.pattern.indices, (0..8).collect::<Vec<i64>>());
                assert_eq!(r.pattern.delta, 8);
                assert_eq!(r.pattern.count, 1 << 24);
            }
            other => panic!("expected Run, got {other:?}"),
        }
    }

    #[test]
    fn gs_invocation() {
        // ./spatter -k GS -g UNIFORM:8:4 -u UNIFORM:8:1 -d 32 -l 1024
        let cmd =
            parse_args(&argv("-k GS -g UNIFORM:8:4 -u UNIFORM:8:1 -d 32 -l 1024"))
                .unwrap();
        match cmd {
            Command::Run(r) => {
                assert_eq!(r.kernel, Kernel::GS);
                assert_eq!(
                    r.pattern.indices,
                    (0..8).map(|i| i * 4).collect::<Vec<i64>>()
                );
                assert_eq!(
                    r.pattern.scatter_indices,
                    (0..8).collect::<Vec<i64>>()
                );
                assert_eq!(r.pattern.delta, 32);
                assert_eq!(r.pattern.count, 1024);
                assert_eq!(r.pattern.spec, "UNIFORM:8:4>UNIFORM:8:1");
            }
            other => panic!("{other:?}"),
        }
        // Table-5 ids work as GS sides, and the gather side carries
        // the app's default delta (LULESH-G3: 8) when -d is omitted.
        match parse_args(&argv("-k GS -g LULESH-G3 -u UNIFORM:16:1 -l 64"))
            .unwrap()
        {
            Command::Run(r) => {
                assert_eq!(r.pattern.vector_len(), 16);
                assert_eq!(r.pattern.scatter_indices.len(), 16);
                assert_eq!(r.pattern.delta, 8, "app default delta applies");
            }
            other => panic!("{other:?}"),
        }
        // ... and -d still overrides it.
        match parse_args(&argv("-k GS -g LULESH-G3 -u UNIFORM:16:1 -d 16 -l 64"))
            .unwrap()
        {
            Command::Run(r) => assert_eq!(r.pattern.delta, 16),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn gs_flag_errors() {
        // GS without either side.
        assert!(parse_args(&argv("-k GS -g UNIFORM:8:1 -l 64")).is_err());
        assert!(parse_args(&argv("-k GS -u UNIFORM:8:1 -l 64")).is_err());
        // GS with -p instead of -g/-u.
        assert!(parse_args(&argv("-k GS -p UNIFORM:8:1 -l 64")).is_err());
        // -g/-u on single-buffer kernels.
        assert!(parse_args(&argv("-k Gather -g UNIFORM:8:1 -l 64")).is_err());
        assert!(
            parse_args(&argv("-k Scatter -p 0,1 -u UNIFORM:8:1 -l 64")).is_err()
        );
        // Mismatched side lengths fail validation.
        assert!(
            parse_args(&argv("-k GS -g UNIFORM:8:1 -u UNIFORM:4:1 -l 64"))
                .is_err()
        );
    }

    #[test]
    fn baseline_kernel_invocations() {
        use crate::pattern::{StreamOp, GUPS_DEFAULT_TABLE_ELEMS};
        // Dense STREAM kernels: no pattern; -d is the stream width.
        match parse_args(&argv("-k Triad -l 2^20")).unwrap() {
            Command::Run(r) => {
                assert_eq!(r.kernel, Kernel::Stream(StreamOp::Triad));
                assert_eq!(r.pattern.indices, (0..8).collect::<Vec<i64>>());
                assert_eq!(r.pattern.delta, 8);
                assert_eq!(r.pattern.count, 1 << 20);
            }
            other => panic!("{other:?}"),
        }
        match parse_args(&argv("-k Copy -d 16 -l 1024")).unwrap() {
            Command::Run(r) => {
                assert_eq!(r.pattern.indices.len(), 16);
                assert_eq!(r.pattern.delta, 16);
            }
            other => panic!("{other:?}"),
        }
        // GUPS: -d is the table size, rounded up to a power of two.
        match parse_args(&argv("-k GUPS -l 4096")).unwrap() {
            Command::Run(r) => {
                assert_eq!(r.kernel, Kernel::Gups);
                assert_eq!(
                    r.pattern.gups_table_elems() as usize,
                    GUPS_DEFAULT_TABLE_ELEMS
                );
            }
            other => panic!("{other:?}"),
        }
        match parse_args(&argv("-k gups -d 1000000 -l 64")).unwrap() {
            Command::Run(r) => {
                assert_eq!(r.pattern.gups_table_elems(), 1 << 20)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn baseline_kernel_flag_errors() {
        // Patterns don't apply to the dense baselines.
        assert!(parse_args(&argv("-k Copy -p UNIFORM:8:1 -l 64")).is_err());
        assert!(parse_args(&argv("-k GUPS -p 0,1,2 -l 64")).is_err());
        assert!(parse_args(&argv("-k Triad -g UNIFORM:8:1 -l 64")).is_err());
        // Cycling delta lists don't either.
        assert!(parse_args(&argv("-k Add -d 0,0,16 -l 64")).is_err());
        assert!(parse_args(&argv("-k GUPS -d 1,2 -l 64")).is_err());
        // Zero/negative sizes rejected.
        assert!(parse_args(&argv("-k Scale -d 0 -l 64")).is_err());
        assert!(parse_args(&argv("-k GUPS -d 0 -l 64")).is_err());
    }

    #[test]
    fn custom_pattern_invocation() {
        let cmd = parse_args(&argv("-k Scatter -p 0,24,48 -d 1 -l 100")).unwrap();
        match cmd {
            Command::Run(r) => {
                assert_eq!(r.kernel, Kernel::Scatter);
                assert_eq!(r.pattern.indices, vec![0, 24, 48]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn json_mode() {
        let cmd = parse_args(&argv("-j cfg.json -a bdw -b scalar")).unwrap();
        match cmd {
            Command::Json { path, common } => {
                assert_eq!(path, "cfg.json");
                assert_eq!(common.platform, "bdw");
                assert_eq!(common.backend, BackendKind::Scalar);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn suite_mode() {
        match parse_args(&argv("--suite fig3 --out outdir")).unwrap() {
            Command::Suite {
                name,
                out_dir,
                jobs,
                fast,
            } => {
                assert_eq!(name, "fig3");
                assert_eq!(out_dir, "outdir");
                assert!(jobs >= 1, "default jobs = available parallelism");
                assert!(!fast);
            }
            other => panic!("{other:?}"),
        }
        match parse_args(&argv("--suite threadscale --jobs 2 --fast")).unwrap()
        {
            Command::Suite {
                name, jobs, fast, ..
            } => {
                assert_eq!(name, "threadscale");
                assert_eq!(jobs, 2);
                assert!(fast);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn threads_and_jobs_flags() {
        let cmd = parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8 --threads 4"))
            .unwrap();
        match cmd {
            Command::Run(r) => assert_eq!(r.common.threads, Some(4)),
            other => panic!("{other:?}"),
        }
        match parse_args(&argv("-j c.json --threads 4 --jobs 3")).unwrap() {
            Command::Json { common, .. } => {
                assert_eq!(common.threads, Some(4));
                assert_eq!(common.jobs, 3);
            }
            other => panic!("{other:?}"),
        }
        // Defaults: no thread override, jobs >= 1.
        match parse_args(&argv("-j c.json")).unwrap() {
            Command::Json { common, .. } => {
                assert_eq!(common.threads, None);
                assert!(common.jobs >= 1);
            }
            other => panic!("{other:?}"),
        }
        // Zero and junk rejected.
        assert!(parse_args(&argv("-j c.json --threads 0")).is_err());
        assert!(parse_args(&argv("-j c.json --jobs 0")).is_err());
        assert!(parse_args(&argv("-j c.json --threads x")).is_err());
        assert!(parse_args(&argv("-j c.json --jobs")).is_err());
        // Flags that would be silently dropped are rejected instead.
        assert!(parse_args(&argv("--suite threadscale --threads 4")).is_err());
        assert!(parse_args(&argv("-j c.json --fast")).is_err());
        assert!(parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8 --fast")).is_err());
        assert!(parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8 --jobs 8")).is_err());
    }

    #[test]
    fn vector_regime_flag() {
        let cmd = parse_args(&argv(
            "-k Gather -p UNIFORM:8:1 -d 8 --vector-regime scalar",
        ))
        .unwrap();
        match cmd {
            Command::Run(r) => assert_eq!(
                r.common.vector_regime,
                Some(VectorRegime::Scalar)
            ),
            other => panic!("{other:?}"),
        }
        // Case-insensitive, and it rides along with -j runs.
        match parse_args(&argv("-j c.json --vector-regime Hardware-GS"))
            .unwrap()
        {
            Command::Json { common, .. } => assert_eq!(
                common.vector_regime,
                Some(VectorRegime::HardwareGS)
            ),
            other => panic!("{other:?}"),
        }
        // Default: the platform's native regime.
        match parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8")).unwrap() {
            Command::Run(r) => assert_eq!(r.common.vector_regime, None),
            other => panic!("{other:?}"),
        }
        // Junk and missing values rejected; suites sweep the axis
        // themselves, so the flag is rejected rather than dropped.
        assert!(parse_args(&argv("-j c.json --vector-regime avx9")).is_err());
        assert!(parse_args(&argv("-j c.json --vector-regime")).is_err());
        let err = parse_args(&argv("--suite simd --vector-regime scalar"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not apply to suites"), "{err}");
    }

    #[test]
    fn numa_placement_flag() {
        let cmd = parse_args(&argv(
            "-k Gather -p UNIFORM:8:1 -d 8 --numa-placement interleave",
        ))
        .unwrap();
        match cmd {
            Command::Run(r) => assert_eq!(
                r.common.numa_placement,
                Some(NumaPlacement::Interleave)
            ),
            other => panic!("{other:?}"),
        }
        // Case-insensitive (and the short alias), rides along with -j.
        match parse_args(&argv("-j c.json --numa-placement First-Touch"))
            .unwrap()
        {
            Command::Json { common, .. } => assert_eq!(
                common.numa_placement,
                Some(NumaPlacement::FirstTouch)
            ),
            other => panic!("{other:?}"),
        }
        // Default: the configured first-touch policy (no override).
        match parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8")).unwrap() {
            Command::Run(r) => assert_eq!(r.common.numa_placement, None),
            other => panic!("{other:?}"),
        }
        // Junk and missing values rejected; the numa suite sweeps the
        // placement axis itself, so suites reject the flag.
        assert!(parse_args(&argv("-j c.json --numa-placement nearest")).is_err());
        assert!(parse_args(&argv("-j c.json --numa-placement")).is_err());
        let err = parse_args(&argv("--suite numa --numa-placement interleave"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not apply to suites"), "{err}");
    }

    #[test]
    fn stream_flag() {
        match parse_args(&argv("-j c.json --stream --json-out --jobs 2"))
            .unwrap()
        {
            Command::Json { common, .. } => {
                assert!(common.stream);
                assert!(common.json_out);
                assert_eq!(common.jobs, 2);
            }
            other => panic!("{other:?}"),
        }
        // Default: off.
        match parse_args(&argv("-j c.json --json-out")).unwrap() {
            Command::Json { common, .. } => assert!(!common.stream),
            other => panic!("{other:?}"),
        }
        // --stream needs a config queue and machine-readable output.
        assert!(parse_args(&argv("--stream")).is_err());
        assert!(
            parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8 --stream")).is_err()
        );
        let err =
            parse_args(&argv("-j c.json --stream")).unwrap_err().to_string();
        assert!(err.contains("--json-out"), "{err}");
    }

    #[test]
    fn listings_and_help() {
        assert_eq!(parse_args(&argv("--list-platforms")).unwrap(), Command::ListPlatforms);
        assert_eq!(parse_args(&argv("--list-patterns")).unwrap(), Command::ListPatterns);
        assert_eq!(parse_args(&argv("-h")).unwrap(), Command::Help);
        assert_eq!(parse_args(&[]).unwrap(), Command::Help);
    }

    #[test]
    fn errors() {
        assert!(parse_args(&argv("-k Gather")).is_err()); // missing -p
        assert!(parse_args(&argv("-p UNIFORM:8:1")).is_err()); // missing -k
        assert!(parse_args(&argv("-k Gather -p UNIFORM:8:1 -d")).is_err());
        assert!(parse_args(&argv("--bogus")).is_err());
        assert!(parse_args(&argv("-k Gather -p UNIFORM:8:1 -l 2^60")).is_err());
        assert!(parse_args(&argv("-k Gather -p UNIFORM:8:1 --runs 0")).is_err());
        assert!(parse_args(&argv("-b warp -k G -p 0,1")).is_err());
    }

    #[test]
    fn page_size_flag() {
        let cmd =
            parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8 --page-size 2MB"))
                .unwrap();
        match cmd {
            Command::Run(r) => {
                assert_eq!(r.common.page_size, Some(PageSize::TwoMB))
            }
            other => panic!("{other:?}"),
        }
        // Default: no override (backends pick their native size).
        match parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8")).unwrap() {
            Command::Run(r) => assert_eq!(r.common.page_size, None),
            other => panic!("{other:?}"),
        }
        // Case-insensitive; bad values rejected.
        match parse_args(&argv("-j c.json --page-size 1gb")).unwrap() {
            Command::Json { common, .. } => {
                assert_eq!(common.page_size, Some(PageSize::OneGB))
            }
            other => panic!("{other:?}"),
        }
        assert!(parse_args(&argv("-j c.json --page-size 3MB")).is_err());
        assert!(parse_args(&argv("-j c.json --page-size")).is_err());
    }

    #[test]
    fn backend_parse() {
        assert_eq!(BackendKind::parse("OMP").unwrap(), BackendKind::OpenMp);
        assert_eq!(BackendKind::parse("cuda").unwrap(), BackendKind::Cuda);
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("sve").is_err());
    }

    #[test]
    fn default_count_applied() {
        let cmd = parse_args(&argv("-k Gather -p UNIFORM:8:1 -d 8")).unwrap();
        match cmd {
            Command::Run(r) => assert_eq!(r.pattern.count, 1 << 20),
            other => panic!("{other:?}"),
        }
    }
}
