//! Pattern spec string parsing: `UNIFORM:..`, `MS1:..`, `LAPLACIAN:..`,
//! or a custom comma-separated index list (paper §3.3.4).

use super::builtin::{laplacian, ms1, random, uniform};
use crate::error::{Error, Result};

/// Parse a pattern spec into an index buffer.
pub fn parse_spec(spec: &str) -> Result<Vec<i64>> {
    let s = spec.trim();
    if s.is_empty() {
        return Err(Error::PatternParse("empty pattern spec".into()));
    }
    let upper = s.to_ascii_uppercase();
    if upper.starts_with("UNIFORM:") {
        let parts = tail_parts(s, 2, "UNIFORM:N:STRIDE")?;
        return uniform(parse_num(&parts[0])?, parse_num(&parts[1])?);
    }
    if upper.starts_with("MS1:") {
        let parts = tail_parts(s, 3, "MS1:N:BREAKS:GAPS")?;
        let n: usize = parse_num(&parts[0])?;
        let breaks = parse_list::<usize>(&parts[1])?;
        let gaps = parse_list::<i64>(&parts[2])?;
        return ms1(n, &breaks, &gaps);
    }
    if upper.starts_with("LAPLACIAN:") {
        let parts = tail_parts(s, 3, "LAPLACIAN:D:L:SIZE")?;
        return laplacian(
            parse_num(&parts[0])?,
            parse_num(&parts[1])?,
            parse_num(&parts[2])?,
        );
    }
    if upper.starts_with("RANDOM:") {
        // RANDOM:N:RANGE or RANDOM:N:RANGE:SEED
        let tail = &s[s.find(':').unwrap() + 1..];
        let parts: Vec<&str> = tail.split(':').map(|p| p.trim()).collect();
        if parts.iter().any(|p| p.is_empty()) {
            return Err(Error::PatternParse(format!(
                "empty ':' segment in '{s}' (expected RANDOM:N:RANGE[:SEED])"
            )));
        }
        if parts.len() == 2 {
            return random(parse_num(parts[0])?, parse_num(parts[1])?, 0);
        }
        if parts.len() == 3 {
            return random(
                parse_num(parts[0])?,
                parse_num(parts[1])?,
                parse_num(parts[2])?,
            );
        }
        return Err(Error::PatternParse(format!(
            "expected RANDOM:N:RANGE[:SEED], got '{s}'"
        )));
    }
    // Custom: comma-separated index list. Reject empty segments first
    // so a trailing or doubled ',' gets a structural error rather than
    // a number-parse complaint about ''.
    if s.split(',').any(|t| t.trim().is_empty()) {
        return Err(Error::PatternParse(format!(
            "empty ',' segment in custom pattern '{s}' (trailing or \
             doubled comma?)"
        )));
    }
    let idx: Result<Vec<i64>> = s
        .split(',')
        .map(|t| {
            t.trim().parse::<i64>().map_err(|_| {
                Error::PatternParse(format!("bad index '{}' in custom pattern", t.trim()))
            })
        })
        .collect();
    let idx = idx?;
    if idx.is_empty() {
        return Err(Error::PatternParse("empty custom pattern".into()));
    }
    Ok(idx)
}

/// Split `KIND:a:b:...` after the first ':' into exactly `n` fields.
/// Empty segments (a trailing or doubled ':') get their own error so
/// `UNIFORM:8:` fails structurally instead of with a confusing
/// number-parse message downstream.
fn tail_parts(s: &str, n: usize, usage: &str) -> Result<Vec<String>> {
    let tail = &s[s.find(':').unwrap() + 1..];
    let parts: Vec<String> = tail.split(':').map(|p| p.trim().to_string()).collect();
    if parts.iter().any(|p| p.is_empty()) {
        return Err(Error::PatternParse(format!(
            "empty ':' segment in '{s}' (expected {usage})"
        )));
    }
    if parts.len() != n {
        return Err(Error::PatternParse(format!(
            "expected {usage}, got '{s}'"
        )));
    }
    Ok(parts)
}

fn parse_num<T: std::str::FromStr>(s: &str) -> Result<T> {
    s.parse::<T>()
        .map_err(|_| Error::PatternParse(format!("bad number '{s}'")))
}

fn parse_list<T: std::str::FromStr>(s: &str) -> Result<Vec<T>> {
    s.split(',').map(|t| parse_num::<T>(t.trim())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_spec() {
        assert_eq!(parse_spec("UNIFORM:4:4").unwrap(), vec![0, 4, 8, 12]);
        assert_eq!(parse_spec("uniform:2:1").unwrap(), vec![0, 1]);
    }

    #[test]
    fn ms1_spec() {
        assert_eq!(
            parse_spec("MS1:8:4:20").unwrap(),
            vec![0, 1, 2, 3, 23, 24, 25, 26]
        );
        // list forms
        assert_eq!(
            parse_spec("MS1:6:2,4:5,7").unwrap(),
            vec![0, 1, 6, 7, 14, 15]
        );
    }

    #[test]
    fn laplacian_spec() {
        assert_eq!(
            parse_spec("LAPLACIAN:2:2:100").unwrap(),
            vec![0, 100, 198, 199, 200, 201, 202, 300, 400]
        );
    }

    #[test]
    fn custom_spec() {
        assert_eq!(parse_spec("0,24,48").unwrap(), vec![0, 24, 48]);
        assert_eq!(parse_spec(" 1 , 2 ,3 ").unwrap(), vec![1, 2, 3]);
        // Table 5 PENNANT-G4 broadcast buffer
        assert_eq!(
            parse_spec("0,0,0,0,1,1,1,1").unwrap(),
            vec![0, 0, 0, 0, 1, 1, 1, 1]
        );
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "UNIFORM", "UNIFORM:8", "UNIFORM:8:1:2", "UNIFORM:x:1",
            "MS1:8:4", "MS1:8:4:20:1", "LAPLACIAN:2:2", "0,,2", "a,b",
            "UNIFORM::1", "MS1:8::20",
        ] {
            assert!(parse_spec(bad).is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn trailing_empty_segments_get_structural_errors() {
        // A trailing/empty ':' segment must be named as such, not
        // surface as a "bad number ''" parse complaint.
        for bad in [
            "UNIFORM:8:", "UNIFORM::1", "MS1:8::20", "MS1:8:4:",
            "LAPLACIAN:2:2:", "RANDOM:8:", "RANDOM:8:100:", "RANDOM::100",
        ] {
            let msg = parse_spec(bad).unwrap_err().to_string();
            assert!(
                msg.contains("empty ':' segment"),
                "{bad:?}: want a structural error, got: {msg}"
            );
            assert!(
                !msg.contains("bad number ''"),
                "{bad:?}: confusing number-parse error: {msg}"
            );
        }
        // Same for trailing commas in custom lists.
        for bad in ["0,24,", ",0,24", "0,,24"] {
            let msg = parse_spec(bad).unwrap_err().to_string();
            assert!(
                msg.contains("empty ',' segment"),
                "{bad:?}: want a structural error, got: {msg}"
            );
        }
        // The well-formed neighbours still parse.
        assert!(parse_spec("UNIFORM:8:1").is_ok());
        assert!(parse_spec("RANDOM:8:100").is_ok());
        assert!(parse_spec("RANDOM:8:100:7").is_ok());
        assert!(parse_spec("0,24,48").is_ok());
    }
}
