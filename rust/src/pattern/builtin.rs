//! Built-in parameterized index buffers (paper §3.3.1–3.3.3).

use crate::error::{Error, Result};

/// `UNIFORM:N:STRIDE` — N indices, uniform stride.
/// Paper example: `UNIFORM:8:4` → `[0,4,8,12,...]` (their text shows the
/// first four of eight).
pub fn uniform(n: usize, stride: usize) -> Result<Vec<i64>> {
    if n == 0 {
        return Err(Error::PatternParse("UNIFORM: N must be > 0".into()));
    }
    if stride == 0 {
        return Err(Error::PatternParse("UNIFORM: stride must be > 0".into()));
    }
    Ok((0..n).map(|i| (i * stride) as i64).collect())
}

/// `MS1:N:BREAKS:GAPS` — mostly-stride-1: runs of consecutive indices
/// with jumps at positions BREAKS of sizes GAPS.
///
/// Paper example: `MS1:8:4:20` → `[0,1,2,3,23,24,25,26]`: at position 4
/// the index jumps by 20 instead of 1.
///
/// BREAKS and GAPS may be comma-separated lists of equal length (or a
/// single gap shared across all breaks).
pub fn ms1(n: usize, breaks: &[usize], gaps: &[i64]) -> Result<Vec<i64>> {
    if n == 0 {
        return Err(Error::PatternParse("MS1: N must be > 0".into()));
    }
    if breaks.is_empty() {
        return Err(Error::PatternParse("MS1: need at least one break".into()));
    }
    if gaps.len() != breaks.len() && gaps.len() != 1 {
        return Err(Error::PatternParse(format!(
            "MS1: {} breaks but {} gaps (need equal or a single gap)",
            breaks.len(),
            gaps.len()
        )));
    }
    for (k, &b) in breaks.iter().enumerate() {
        if b == 0 || b >= n {
            return Err(Error::PatternParse(format!(
                "MS1: break {b} out of range 1..{n}"
            )));
        }
        if k > 0 && breaks[k - 1] >= b {
            return Err(Error::PatternParse(
                "MS1: breaks must be strictly increasing".into(),
            ));
        }
    }
    if gaps.iter().any(|&g| g < 1) {
        return Err(Error::PatternParse("MS1: gaps must be >= 1".into()));
    }
    let mut out = Vec::with_capacity(n);
    let mut cur: i64 = 0;
    let mut bk = 0usize;
    for i in 0..n {
        if i > 0 {
            let jump = if bk < breaks.len() && breaks[bk] == i {
                let g = if gaps.len() == 1 { gaps[0] } else { gaps[bk] };
                bk += 1;
                g
            } else {
                1
            };
            cur += jump;
        }
        out.push(cur);
    }
    Ok(out)
}

/// `LAPLACIAN:D:L:SIZE` — D-dimensional Laplacian stencil with branch
/// length L on a SIZE^D problem (paper §3.3.3).
///
/// Offsets are `{0} ∪ {± l * SIZE^d : d < D, 1 <= l <= L}`, shifted so
/// the smallest is zero (Spatter buffers are zero-based).
///
/// Paper example: `LAPLACIAN:2:2:100` →
/// `[0,100,198,199,200,201,202,300,400]`
/// (the zero-based form of `[-200,-100,-2,-1,0,1,2,100,200]`).
pub fn laplacian(dims: usize, branch: usize, size: usize) -> Result<Vec<i64>> {
    if !(1..=3).contains(&dims) {
        return Err(Error::PatternParse(format!(
            "LAPLACIAN: D must be 1, 2, or 3 (got {dims})"
        )));
    }
    if branch == 0 {
        return Err(Error::PatternParse("LAPLACIAN: L must be > 0".into()));
    }
    if size == 0 {
        return Err(Error::PatternParse("LAPLACIAN: SIZE must be > 0".into()));
    }
    let mut offsets: Vec<i64> = vec![0];
    let mut scale: i64 = 1;
    for _ in 0..dims {
        for l in 1..=branch as i64 {
            offsets.push(l * scale);
            offsets.push(-l * scale);
        }
        scale = scale
            .checked_mul(size as i64)
            .ok_or_else(|| Error::PatternParse("LAPLACIAN: size overflow".into()))?;
    }
    offsets.sort_unstable();
    offsets.dedup();
    let min = *offsets.first().unwrap();
    Ok(offsets.into_iter().map(|o| o - min).collect())
}

/// `RANDOM:N:RANGE[:SEED]` — N uniform-random indices in `[0, RANGE)`,
/// deterministic per seed. Extension covering the paper's §6 remark
/// that Spatter "contains kernels for modeling random access"
/// (GUPS/RandomAccess-like streams).
pub fn random(n: usize, range: usize, seed: u64) -> Result<Vec<i64>> {
    if n == 0 {
        return Err(Error::PatternParse("RANDOM: N must be > 0".into()));
    }
    if range == 0 {
        return Err(Error::PatternParse("RANDOM: RANGE must be > 0".into()));
    }
    let mut g = crate::prop::Gen::new(seed ^ 0x5747_7445_5221_4e44);
    Ok((0..n).map(|_| g.i64_in(0, range as i64 - 1)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_matches_paper() {
        assert_eq!(uniform(4, 4).unwrap(), vec![0, 4, 8, 12]);
        assert_eq!(uniform(8, 1).unwrap(), (0..8).collect::<Vec<i64>>());
        assert!(uniform(0, 1).is_err());
        assert!(uniform(8, 0).is_err());
    }

    #[test]
    fn ms1_matches_paper() {
        // MS1:8:4:20 -> [0,1,2,3,23,24,25,26]
        assert_eq!(
            ms1(8, &[4], &[20]).unwrap(),
            vec![0, 1, 2, 3, 23, 24, 25, 26]
        );
    }

    #[test]
    fn ms1_multiple_breaks() {
        // breaks at 2 and 5, gaps 10 and 100
        assert_eq!(
            ms1(7, &[2, 5], &[10, 100]).unwrap(),
            vec![0, 1, 11, 12, 13, 113, 114]
        );
        // single shared gap
        assert_eq!(
            ms1(6, &[2, 4], &[5]).unwrap(),
            vec![0, 1, 6, 7, 12, 13]
        );
    }

    #[test]
    fn ms1_rejects_bad_params() {
        assert!(ms1(0, &[1], &[2]).is_err());
        assert!(ms1(8, &[], &[2]).is_err());
        assert!(ms1(8, &[0], &[2]).is_err());
        assert!(ms1(8, &[9], &[2]).is_err());
        assert!(ms1(8, &[4, 2], &[2, 2]).is_err());
        assert!(ms1(8, &[2, 4], &[2, 2, 2]).is_err());
        assert!(ms1(8, &[4], &[0]).is_err());
    }

    #[test]
    fn laplacian_matches_paper() {
        // LAPLACIAN:2:2:100 -> [0,100,198,199,200,201,202,300,400]
        assert_eq!(
            laplacian(2, 2, 100).unwrap(),
            vec![0, 100, 198, 199, 200, 201, 202, 300, 400]
        );
    }

    #[test]
    fn laplacian_1d_5point() {
        // classic 1-D 3-point: [-1,0,1] -> [0,1,2]
        assert_eq!(laplacian(1, 1, 50).unwrap(), vec![0, 1, 2]);
        // 2-D 5-point: [-100,-1,0,1,100] -> [0,99,100,101,200]
        assert_eq!(
            laplacian(2, 1, 100).unwrap(),
            vec![0, 99, 100, 101, 200]
        );
    }

    #[test]
    fn laplacian_3d_7point() {
        let idx = laplacian(3, 1, 10).unwrap();
        // offsets {-100,-10,-1,0,1,10,100} shifted +100
        assert_eq!(idx, vec![0, 90, 99, 100, 101, 110, 200]);
    }

    #[test]
    fn laplacian_rejects_bad_params() {
        assert!(laplacian(0, 1, 10).is_err());
        assert!(laplacian(4, 1, 10).is_err());
        assert!(laplacian(2, 0, 10).is_err());
        assert!(laplacian(2, 1, 0).is_err());
    }

    #[test]
    fn laplacian_dedups_small_sizes() {
        // size 1 collapses cross-dimension offsets; must stay sorted+unique
        let idx = laplacian(2, 1, 1).unwrap();
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(idx, sorted);
    }
}
