//! The Spatter pattern language (paper §3.3).
//!
//! A memory access pattern is `(kernel, index-buffer, delta, count)`:
//! at base address `delta*i` (elements, i.e. doubles), perform a gather
//! or scatter with the offsets in the index buffer.
//!
//! Built-in parameterized index buffers:
//!
//! * `UNIFORM:N:STRIDE` — N indices with uniform stride.
//! * `MS1:N:BREAKS:GAPS` — mostly-stride-1 with jumps at BREAKS of size
//!   GAPS (both may be comma-separated lists).
//! * `LAPLACIAN:D:L:SIZE` — D-dimensional Laplacian stencil, branch
//!   length L, problem size SIZE per dimension.
//! * custom — an explicit comma-separated index list.

mod builtin;
mod spec;
pub mod table5;

pub use builtin::{laplacian, ms1, uniform};
pub use spec::parse_spec;

use crate::error::{Error, Result};

/// One operation of the classical STREAM tetrad (the dense baseline
/// family): contiguous multi-operand kernels with no index buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StreamOp {
    /// `c[i] = a[i]` — one read stream, one write stream.
    Copy,
    /// `b[i] = q * c[i]` — one read stream, one write stream.
    Scale,
    /// `c[i] = a[i] + b[i]` — two read streams, one write stream.
    Add,
    /// `a[i] = b[i] + q * c[i]` — two read streams, one write stream.
    Triad,
}

impl StreamOp {
    /// The tetrad in STREAM's canonical order.
    pub const ALL: &'static [StreamOp] =
        &[StreamOp::Copy, StreamOp::Scale, StreamOp::Add, StreamOp::Triad];

    pub fn name(&self) -> &'static str {
        match self {
            StreamOp::Copy => "Copy",
            StreamOp::Scale => "Scale",
            StreamOp::Add => "Add",
            StreamOp::Triad => "Triad",
        }
    }

    /// Operand arrays read per element (Copy/Scale 1, Add/Triad 2).
    pub fn read_streams(&self) -> usize {
        match self {
            StreamOp::Copy | StreamOp::Scale => 1,
            StreamOp::Add | StreamOp::Triad => 2,
        }
    }
}

/// The kernels Spatter can issue: the paper's indexed family (Gather,
/// Scatter, and GS — the indexed copy `dst[scatter[i]] = src[gather[i]]`
/// of Algorithm 1 and experiments 2/3) plus the dense/random baseline
/// family the paper compares *against* (§5.4 / Fig 9): the STREAM
/// tetrad (contiguous multi-operand streams, no index buffer) and GUPS
/// (seeded-xorshift 64-bit random read-modify-write into a large
/// table — the TLB + DRAM-row worst case).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kernel {
    Gather,
    Scatter,
    GS,
    Stream(StreamOp),
    Gups,
}

impl Kernel {
    pub fn parse(s: &str) -> Result<Kernel> {
        match s.to_ascii_lowercase().as_str() {
            "gather" | "g" => Ok(Kernel::Gather),
            "scatter" | "s" => Ok(Kernel::Scatter),
            "gs" | "sg" | "gatherscatter" | "gather-scatter" => Ok(Kernel::GS),
            "copy" => Ok(Kernel::Stream(StreamOp::Copy)),
            "scale" => Ok(Kernel::Stream(StreamOp::Scale)),
            "add" => Ok(Kernel::Stream(StreamOp::Add)),
            "triad" => Ok(Kernel::Stream(StreamOp::Triad)),
            "gups" => Ok(Kernel::Gups),
            _ => Err(Error::PatternParse(format!(
                "unknown kernel '{s}' (expected Gather, Scatter, GS, \
                 Copy, Scale, Add, Triad, or GUPS)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Gather => "Gather",
            Kernel::Scatter => "Scatter",
            Kernel::GS => "GS",
            Kernel::Stream(op) => op.name(),
            Kernel::Gups => "GUPS",
        }
    }

    /// Distinct operand streams *read* per element.
    pub fn read_streams(&self) -> usize {
        match self {
            Kernel::Gather | Kernel::GS | Kernel::Gups => 1,
            Kernel::Scatter => 0,
            Kernel::Stream(op) => op.read_streams(),
        }
    }

    /// Distinct operand streams *written* per element (every kernel
    /// except Gather writes exactly one).
    pub fn write_streams(&self) -> usize {
        match self {
            Kernel::Gather => 0,
            _ => 1,
        }
    }

    /// Whether the kernel issues a *read* stream.
    pub fn reads(&self) -> bool {
        self.read_streams() > 0
    }

    /// Whether the kernel issues a *write* stream.
    pub fn writes(&self) -> bool {
        self.write_streams() > 0
    }

    /// Memory access streams per element (GS and the baselines touch
    /// memory on several operand streams per element).
    pub fn streams(&self) -> usize {
        self.read_streams() + self.write_streams()
    }

    /// Streams counted in the headline payload. The indexed kernels
    /// and GUPS count their copied/updated payload *once* (so GS stays
    /// bounded by its component kernels and GUPS by a random gather);
    /// the STREAM tetrad uses STREAM's byte-counting convention, which
    /// counts every operand stream (Copy/Scale 16 B, Add/Triad 24 B
    /// per element).
    pub fn payload_streams(&self) -> usize {
        match self {
            Kernel::Stream(_) => self.streams(),
            _ => 1,
        }
    }

    /// The dense/random baseline kernels (STREAM tetrad + GUPS): they
    /// take no pattern — `delta`/`count` size the streams.
    pub fn is_baseline(&self) -> bool {
        matches!(self, Kernel::Stream(_) | Kernel::Gups)
    }
}

/// The paper's taxonomy of observed G/S pattern classes (§2, Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PatternClass {
    /// Every index a fixed distance from its predecessor.
    UniformStride(usize),
    /// Some indices repeat (elements of a gather share an index).
    Broadcast,
    /// Runs of stride-1 with occasional jumps.
    MostlyStride1,
    /// Anything else.
    Complex,
}

impl PatternClass {
    pub fn name(&self) -> String {
        match self {
            PatternClass::UniformStride(1) => "Stride-1".to_string(),
            PatternClass::UniformStride(s) => format!("Stride-{s}"),
            PatternClass::Broadcast => "Broadcast".to_string(),
            PatternClass::MostlyStride1 => "Mostly Stride-1".to_string(),
            PatternClass::Complex => "Complex".to_string(),
        }
    }
}

/// A fully-specified Spatter run input.
#[derive(Debug, Clone, PartialEq)]
pub struct Pattern {
    /// Human-readable spec (what the user typed, or a pattern name).
    pub spec: String,
    /// The index buffer (element offsets, not bytes).
    pub indices: Vec<i64>,
    /// Elements between consecutive gather/scatter base addresses.
    pub delta: i64,
    /// Extension (paper §7 future work 1, "time delta patterns"):
    /// when non-empty, the base advance *cycles* through this list
    /// instead of using the single `delta` — e.g. `[0, 0, 0, 16]`
    /// revisits the same base three times before jumping, expressing
    /// temporal locality. Empty = classic single-delta behaviour.
    pub deltas: Vec<i64>,
    /// Number of gathers or scatters to perform (`-l` in the CLI).
    pub count: usize,
    /// Secondary index buffer for the GS (gather-scatter) kernel: the
    /// scatter (write) side of the indexed copy, addressed against a
    /// separate target region (see [`Pattern::gs_scatter_base`]).
    /// Empty for Gather/Scatter runs, where `indices` is the single
    /// buffer; for GS, `indices` is the gather (read) side and both
    /// buffers must have equal length.
    pub scatter_indices: Vec<i64>,
}

/// Element alignment of the GS write region: the scatter side is
/// modelled as a separate allocation placed after the gather side at
/// the next 1 GiB boundary, so the two streams never alias at any
/// translation page size (1 GiB = 2^27 doubles).
const GS_REGION_ALIGN_ELEMS: usize = 1 << 27;

/// Alignment quantum of the operand arrays of a dense STREAM-family
/// kernel: each operand is its own allocation starting on a 1 GiB
/// boundary past the previous one's span (see
/// [`Pattern::dense_region_bytes`]), so the streams never share a
/// line, DRAM row, or page at any page size — the same convention as
/// the GS write region.
pub const DENSE_REGION_ALIGN_BYTES: u64 = 1 << 30;

/// Default GUPS table size in elements (2^26 doubles = 512 MiB):
/// dwarfs every modelled cache and 4 KiB TLB reach, so each update is
/// the TLB + DRAM-row worst case.
pub const GUPS_DEFAULT_TABLE_ELEMS: usize = 1 << 26;

/// Smallest accepted GUPS table (tests use small cache-resident
/// tables; the power-of-two mask needs a sane floor).
pub const GUPS_MIN_TABLE_ELEMS: usize = 1 << 10;

/// Largest accepted GUPS table (2^40 doubles = 8 TiB of address
/// space); also the clamp [`Pattern::gups`] applies before rounding,
/// so absurd requests can't overflow `next_power_of_two`.
pub const GUPS_MAX_TABLE_ELEMS: usize = 1 << 40;

/// Random updates one GUPS "iteration" performs (the analogue of the
/// index-buffer length for the indexed kernels).
pub const GUPS_UPDATES_PER_ITER: usize = 8;

impl Pattern {
    /// Parse a pattern spec string (builtin or custom index list).
    /// Delta defaults to 0 gathers... callers set delta/count via the
    /// `with_*` builders or CLI flags.
    pub fn parse(spec: &str) -> Result<Pattern> {
        let indices = parse_spec(spec)?;
        Ok(Pattern {
            spec: spec.to_string(),
            indices,
            delta: 1,
            deltas: Vec::new(),
            count: 1,
            scatter_indices: Vec::new(),
        })
    }

    /// Build directly from an explicit index buffer.
    pub fn from_indices(name: &str, indices: Vec<i64>) -> Pattern {
        Pattern {
            spec: name.to_string(),
            indices,
            delta: 1,
            deltas: Vec::new(),
            count: 1,
            scatter_indices: Vec::new(),
        }
    }

    /// A dense STREAM-family pattern: `width` contiguous elements per
    /// iteration per operand stream (delta == width, so consecutive
    /// iterations are contiguous). Total stream length per operand is
    /// `width * count` elements.
    pub fn dense(width: usize, count: usize) -> Pattern {
        Pattern {
            spec: format!("DENSE:{width}"),
            indices: (0..width as i64).collect(),
            delta: width as i64,
            deltas: Vec::new(),
            count,
            scatter_indices: Vec::new(),
        }
    }

    /// A GUPS pattern: `count` iterations of
    /// [`GUPS_UPDATES_PER_ITER`] seeded-xorshift random 64-bit
    /// read-modify-writes into a table of `table_elems` doubles
    /// (clamped to [`GUPS_MIN_TABLE_ELEMS`]..[`GUPS_MAX_TABLE_ELEMS`]
    /// and rounded up to a power of two — the update mask needs one).
    /// The table size rides in `delta`, which the CLI/JSON already
    /// plumb end to end.
    pub fn gups(table_elems: usize, count: usize) -> Pattern {
        let table = table_elems
            .clamp(GUPS_MIN_TABLE_ELEMS, GUPS_MAX_TABLE_ELEMS)
            .next_power_of_two();
        Pattern {
            spec: format!("GUPS:{table}"),
            indices: (0..GUPS_UPDATES_PER_ITER as i64).collect(),
            delta: table as i64,
            deltas: Vec::new(),
            count,
            scatter_indices: Vec::new(),
        }
    }

    /// GUPS table size in elements (the `delta` field under its GUPS
    /// reading; validated as a power of two by `validate_for`).
    pub fn gups_table_elems(&self) -> u64 {
        self.delta as u64
    }

    /// Byte stride between the operand arrays of a dense STREAM-family
    /// kernel: the per-operand span rounded up to the next 1 GiB
    /// boundary (the same derivation as [`Pattern::gs_scatter_base`]),
    /// so operands behave as separate allocations that never alias —
    /// at any stream length, page size, or simulation window.
    pub fn dense_region_bytes(&self) -> u64 {
        let span = self.required_elements() as u64 * 8;
        span.div_ceil(DENSE_REGION_ALIGN_BYTES) * DENSE_REGION_ALIGN_BYTES
    }

    /// Attach the scatter (write) side of a GS pattern. `indices`
    /// becomes the gather (read) side; both buffers must have equal
    /// length for the pattern to validate under [`Kernel::GS`].
    pub fn with_gs_scatter(mut self, scatter_indices: Vec<i64>) -> Pattern {
        self.scatter_indices = scatter_indices;
        self
    }

    pub fn with_delta(mut self, delta: i64) -> Pattern {
        self.delta = delta;
        self.deltas.clear();
        self
    }

    /// Cycle through a list of deltas (temporal-locality extension).
    /// A single-element list degrades to `with_delta`.
    pub fn with_deltas(mut self, deltas: &[i64]) -> Pattern {
        if deltas.len() == 1 {
            return self.with_delta(deltas[0]);
        }
        self.deltas = deltas.to_vec();
        self.delta = if deltas.is_empty() { 1 } else { deltas[0] };
        self
    }

    /// Base element address of gather/scatter `i`.
    pub fn base(&self, i: usize) -> i64 {
        if self.deltas.len() <= 1 {
            return self.delta * i as i64;
        }
        let k = self.deltas.len();
        let cycle: i64 = self.deltas.iter().sum();
        let mut b = cycle * (i / k) as i64;
        for &d in &self.deltas[..i % k] {
            b += d;
        }
        b
    }

    /// The advance applied after gather/scatter `i` (for incremental
    /// base tracking in the hot loops).
    pub fn delta_at(&self, i: usize) -> i64 {
        if self.deltas.len() <= 1 {
            self.delta
        } else {
            self.deltas[i % self.deltas.len()]
        }
    }

    /// Average base advance per iteration (for pattern-level
    /// heuristics: TLB sparseness, coherence overlap).
    pub fn mean_delta(&self) -> f64 {
        if self.deltas.len() <= 1 {
            self.delta as f64
        } else {
            self.deltas.iter().sum::<i64>() as f64 / self.deltas.len() as f64
        }
    }

    pub fn with_count(mut self, count: usize) -> Pattern {
        self.count = count;
        self
    }

    pub fn with_name(mut self, name: &str) -> Pattern {
        self.spec = name.to_string();
        self
    }

    /// Index-buffer length (the paper's V / vector length).
    pub fn vector_len(&self) -> usize {
        self.indices.len()
    }

    /// Largest index in the (primary / gather-side) buffer.
    pub fn max_index(&self) -> i64 {
        self.indices.iter().copied().max().unwrap_or(0)
    }

    /// Largest index in the scatter-side buffer (GS patterns; 0 when
    /// there is no scatter side).
    pub fn max_scatter_index(&self) -> i64 {
        self.scatter_indices.iter().copied().max().unwrap_or(0)
    }

    /// Element offset of the scatter (write) region for GS patterns:
    /// the gather-side span rounded up to the next 1 GiB boundary, so
    /// the read and write target arrays behave as separate allocations
    /// that never share a line, row, or page at any page size. Zero
    /// when the pattern has no scatter side.
    pub fn gs_scatter_base(&self) -> i64 {
        if self.scatter_indices.is_empty() {
            return 0;
        }
        let src_span = self.gather_span_elements();
        let a = GS_REGION_ALIGN_ELEMS;
        (src_span.div_ceil(a) * a) as i64
    }

    /// Elements spanned by the gather-side stream alone.
    fn gather_span_elements(&self) -> usize {
        let last_base = self.base(self.count.saturating_sub(1)).max(0) as usize;
        last_base + self.max_index().max(0) as usize + 1
    }

    /// Number of data elements the target address space must hold:
    /// `base(count-1) + max(idx) + 1` (paper: "Spatter will determine
    /// the amount of memory required from these inputs"); GS patterns
    /// additionally hold the write region beyond `gs_scatter_base`.
    pub fn required_elements(&self) -> usize {
        let src = self.gather_span_elements();
        if self.scatter_indices.is_empty() {
            return src;
        }
        let last_base = self.base(self.count.saturating_sub(1)).max(0) as usize;
        self.gs_scatter_base() as usize
            + last_base
            + self.max_scatter_index().max(0) as usize
            + 1
    }

    /// Useful bytes moved by the whole run (the paper's bandwidth
    /// numerator): `sizeof(double) * len(index) * count`.
    pub fn moved_bytes(&self) -> usize {
        8 * self.indices.len() * self.count
    }

    /// Validate that the pattern is executable.
    pub fn validate(&self) -> Result<()> {
        if self.indices.is_empty() {
            return Err(Error::Config("empty index buffer".into()));
        }
        if self.count == 0 {
            return Err(Error::Config("count must be > 0".into()));
        }
        if let Some(&neg) = self.indices.iter().find(|&&i| i < 0) {
            return Err(Error::Config(format!(
                "negative index {neg} (index buffers are zero-based)"
            )));
        }
        if let Some(&neg) = self.scatter_indices.iter().find(|&&i| i < 0) {
            return Err(Error::Config(format!(
                "negative scatter-side index {neg} (index buffers are \
                 zero-based)"
            )));
        }
        if self.delta < 0 {
            return Err(Error::Config(format!("negative delta {}", self.delta)));
        }
        if let Some(&neg) = self.deltas.iter().find(|&&d| d < 0) {
            return Err(Error::Config(format!("negative delta {neg} in list")));
        }
        // Guard against address-space overflow in the simulators.
        let span = self.required_elements();
        if span.checked_mul(8).is_none() || span > (1usize << 46) {
            return Err(Error::Config(format!(
                "pattern spans {span} elements — address overflow"
            )));
        }
        Ok(())
    }

    /// Validate the pattern *for a specific kernel*: everything
    /// [`Pattern::validate`] checks, plus the buffer-shape contract —
    /// GS needs two equal-length index buffers, Gather/Scatter exactly
    /// one, the STREAM tetrad a contiguous dense shape, and GUPS a
    /// power-of-two table size (its `delta` reading, which skips the
    /// base-advance span math entirely — GUPS has no base advance).
    pub fn validate_for(&self, kernel: Kernel) -> Result<()> {
        if kernel == Kernel::Gups {
            if self.indices.is_empty() {
                return Err(Error::Config("empty index buffer".into()));
            }
            if self.count == 0 {
                return Err(Error::Config("count must be > 0".into()));
            }
            if !self.scatter_indices.is_empty() || !self.deltas.is_empty() {
                return Err(Error::Config(
                    "GUPS takes no scatter side and a single delta (the \
                     table size in elements)"
                        .into(),
                ));
            }
            let t = self.delta;
            if t < GUPS_MIN_TABLE_ELEMS as i64
                || !(t as u64).is_power_of_two()
                || t as u64 > GUPS_MAX_TABLE_ELEMS as u64
            {
                return Err(Error::Config(format!(
                    "GUPS table size (delta) must be a power of two in \
                     [{}, 2^40] elements, got {t} (use Pattern::gups / \
                     -d TABLE)",
                    GUPS_MIN_TABLE_ELEMS
                )));
            }
            return Ok(());
        }
        self.validate()?;
        if let Kernel::Stream(_) = kernel {
            let dense = self
                .indices
                .iter()
                .enumerate()
                .all(|(j, &i)| i == j as i64);
            if !dense
                || self.delta != self.indices.len() as i64
                || !self.deltas.is_empty()
                || !self.scatter_indices.is_empty()
            {
                return Err(Error::Config(format!(
                    "kernel {} is a dense STREAM baseline: it takes \
                     contiguous operand streams, no pattern (use \
                     Pattern::dense — delta/count size the streams)",
                    kernel.name()
                )));
            }
            return Ok(());
        }
        match kernel {
            Kernel::GS => {
                if self.scatter_indices.is_empty() {
                    return Err(Error::Config(
                        "the GS kernel needs a scatter-side index buffer \
                         (pattern-scatter / -u)"
                            .into(),
                    ));
                }
                if self.scatter_indices.len() != self.indices.len() {
                    return Err(Error::Config(format!(
                        "GS gather/scatter index buffers must have equal \
                         length (gather {} vs scatter {})",
                        self.indices.len(),
                        self.scatter_indices.len()
                    )));
                }
                Ok(())
            }
            _ if !self.scatter_indices.is_empty() => Err(Error::Config(format!(
                "kernel {} takes a single index buffer (a scatter-side \
                 buffer applies only to GS)",
                kernel.name()
            ))),
            _ => Ok(()),
        }
    }

    /// Classify the index buffer per the paper's taxonomy (§2).
    pub fn classify(&self) -> PatternClass {
        classify_indices(&self.indices)
    }

    /// The `(i, j) -> element address` map, materialized lazily.
    /// `addr = base(i) + idx[j]`.
    pub fn address(&self, i: usize, j: usize) -> i64 {
        self.base(i) + self.indices[j]
    }
}

/// Classify an index buffer per the paper's pattern taxonomy.
pub fn classify_indices(indices: &[i64]) -> PatternClass {
    if indices.len() < 2 {
        return PatternClass::UniformStride(1);
    }
    // Broadcast: any repeated index.
    let mut sorted = indices.to_vec();
    sorted.sort_unstable();
    if sorted.windows(2).any(|w| w[0] == w[1]) {
        return PatternClass::Broadcast;
    }
    // Uniform stride: constant positive difference.
    let d0 = indices[1] - indices[0];
    if d0 > 0 && indices.windows(2).all(|w| w[1] - w[0] == d0) {
        return PatternClass::UniformStride(d0 as usize);
    }
    // Mostly stride-1: >= half of the consecutive diffs are exactly 1
    // and the buffer is monotone increasing.
    let diffs: Vec<i64> = indices.windows(2).map(|w| w[1] - w[0]).collect();
    let ones = diffs.iter().filter(|&&d| d == 1).count();
    if diffs.iter().all(|&d| d > 0) && ones * 2 >= diffs.len() {
        return PatternClass::MostlyStride1;
    }
    PatternClass::Complex
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_parse() {
        assert_eq!(Kernel::parse("Gather").unwrap(), Kernel::Gather);
        assert_eq!(Kernel::parse("scatter").unwrap(), Kernel::Scatter);
        assert_eq!(Kernel::parse("G").unwrap(), Kernel::Gather);
        assert_eq!(Kernel::parse("GS").unwrap(), Kernel::GS);
        assert_eq!(Kernel::parse("gs").unwrap(), Kernel::GS);
        assert!(Kernel::parse("both").is_err());
    }

    #[test]
    fn kernel_stream_sides() {
        assert!(Kernel::Gather.reads() && !Kernel::Gather.writes());
        assert!(!Kernel::Scatter.reads() && Kernel::Scatter.writes());
        assert!(Kernel::GS.reads() && Kernel::GS.writes());
        assert_eq!(Kernel::Gather.streams(), 1);
        assert_eq!(Kernel::Scatter.streams(), 1);
        assert_eq!(Kernel::GS.streams(), 2);
        assert_eq!(Kernel::GS.name(), "GS");
    }

    #[test]
    fn baseline_kernel_parse_and_shapes() {
        assert_eq!(
            Kernel::parse("Copy").unwrap(),
            Kernel::Stream(StreamOp::Copy)
        );
        assert_eq!(
            Kernel::parse("triad").unwrap(),
            Kernel::Stream(StreamOp::Triad)
        );
        assert_eq!(Kernel::parse("GUPS").unwrap(), Kernel::Gups);
        assert_eq!(Kernel::parse("SCALE").unwrap().name(), "Scale");
        // Stream counts follow the STREAM convention.
        let copy = Kernel::Stream(StreamOp::Copy);
        let add = Kernel::Stream(StreamOp::Add);
        let triad = Kernel::Stream(StreamOp::Triad);
        assert_eq!((copy.read_streams(), copy.write_streams()), (1, 1));
        assert_eq!((add.read_streams(), add.write_streams()), (2, 1));
        assert_eq!(copy.streams(), 2);
        assert_eq!(triad.streams(), 3);
        // Headline payload: STREAM counts every operand stream; the
        // indexed kernels and GUPS count the payload once.
        assert_eq!(copy.payload_streams(), 2);
        assert_eq!(triad.payload_streams(), 3);
        assert_eq!(Kernel::GS.payload_streams(), 1);
        assert_eq!(Kernel::Gups.payload_streams(), 1);
        assert_eq!((Kernel::Gups.read_streams(), Kernel::Gups.write_streams()), (1, 1));
        assert!(copy.is_baseline() && Kernel::Gups.is_baseline());
        assert!(!Kernel::GS.is_baseline());
    }

    #[test]
    fn dense_pattern_shape_and_validation() {
        let p = Pattern::dense(8, 1 << 12);
        assert_eq!(p.indices, (0..8).collect::<Vec<i64>>());
        assert_eq!(p.delta, 8);
        assert_eq!(p.spec, "DENSE:8");
        for op in StreamOp::ALL {
            p.validate_for(Kernel::Stream(*op)).unwrap();
        }
        // Dense kernels reject indexed shapes…
        let strided = Pattern::parse("UNIFORM:8:2").unwrap().with_count(64);
        assert!(strided
            .validate_for(Kernel::Stream(StreamOp::Copy))
            .is_err());
        // …non-contiguous deltas…
        let gapped = Pattern::dense(8, 64).with_delta(16);
        assert!(gapped
            .validate_for(Kernel::Stream(StreamOp::Triad))
            .is_err());
        // …and scatter sides.
        let sided = Pattern::dense(8, 64).with_gs_scatter((0..8).collect());
        assert!(sided.validate_for(Kernel::Stream(StreamOp::Add)).is_err());
        // A dense pattern is still a valid stride-1 gather shape.
        p.validate_for(Kernel::Gather).unwrap();
    }

    #[test]
    fn dense_regions_never_alias() {
        // Short streams keep the minimal 1 GiB stride…
        let p = Pattern::dense(8, 1 << 12);
        assert_eq!(p.dense_region_bytes(), DENSE_REGION_ALIGN_BYTES);
        // …and streams longer than 1 GiB get a span-sized stride (the
        // gs_scatter_base convention), so operands still never alias.
        let long = Pattern::dense(8, 1 << 28); // 2 GiB per operand
        let region = long.dense_region_bytes();
        assert_eq!(region % DENSE_REGION_ALIGN_BYTES, 0);
        assert!(region >= long.required_elements() as u64 * 8);
    }

    #[test]
    fn gups_pattern_table_semantics() {
        let p = Pattern::gups(1 << 20, 1 << 14);
        assert_eq!(p.gups_table_elems(), 1 << 20);
        assert_eq!(p.vector_len(), GUPS_UPDATES_PER_ITER);
        p.validate_for(Kernel::Gups).unwrap();
        // Non-power-of-two tables round up; tiny ones clamp to the floor.
        assert_eq!(Pattern::gups(1_000_000, 1).gups_table_elems(), 1 << 20);
        assert_eq!(
            Pattern::gups(3, 1).gups_table_elems() as usize,
            GUPS_MIN_TABLE_ELEMS
        );
        // Huge table + huge count: no span overflow (GUPS skips the
        // base-advance span math — it has none).
        Pattern::gups(GUPS_DEFAULT_TABLE_ELEMS, 1 << 24)
            .validate_for(Kernel::Gups)
            .unwrap();
        // Absurd table requests clamp to the cap instead of
        // overflowing next_power_of_two; the result still validates.
        let huge = Pattern::gups(usize::MAX, 1);
        assert_eq!(huge.gups_table_elems() as usize, GUPS_MAX_TABLE_ELEMS);
        huge.validate_for(Kernel::Gups).unwrap();
        // A hand-built non-pow2 delta is rejected for GUPS.
        let bad = Pattern::dense(8, 64).with_delta(1000000);
        assert!(bad.validate_for(Kernel::Gups).is_err());
        // An indexed pattern's small delta is rejected too.
        assert!(Pattern::dense(8, 64).validate_for(Kernel::Gups).is_err());
    }

    #[test]
    fn gs_pattern_shape_validation() {
        let gs = Pattern::from_indices("g", vec![0, 8, 16])
            .with_gs_scatter(vec![0, 1, 2])
            .with_delta(8)
            .with_count(64);
        gs.validate_for(Kernel::GS).unwrap();
        // Mismatched lengths rejected.
        let bad = Pattern::from_indices("g", vec![0, 8])
            .with_gs_scatter(vec![0, 1, 2]);
        assert!(bad.validate_for(Kernel::GS).is_err());
        // GS without a scatter side rejected.
        let single = Pattern::from_indices("g", vec![0, 8]);
        assert!(single.validate_for(Kernel::GS).is_err());
        single.validate_for(Kernel::Gather).unwrap();
        // A scatter side on a single-buffer kernel rejected.
        assert!(gs.validate_for(Kernel::Scatter).is_err());
        assert!(gs.validate_for(Kernel::Gather).is_err());
        // Negative scatter-side indices rejected outright.
        let neg = Pattern::from_indices("g", vec![0])
            .with_gs_scatter(vec![-1]);
        assert!(neg.validate().is_err());
    }

    #[test]
    fn gs_regions_never_alias() {
        let gs = Pattern::from_indices("g", (0..8).collect())
            .with_gs_scatter((0..8).map(|i| i * 24).collect())
            .with_delta(8)
            .with_count(1 << 10);
        let base = gs.gs_scatter_base();
        // The write region starts at a 1 GiB element boundary past the
        // read span.
        assert_eq!(base % (1 << 27), 0);
        assert!(base as usize >= 8 * ((1 << 10) - 1) + 7 + 1);
        // required_elements covers the write region too.
        let last_base = 8 * ((1 << 10) - 1) as usize;
        assert_eq!(
            gs.required_elements(),
            base as usize + last_base + 7 * 24 + 1
        );
        // No scatter side: offset is zero and sizing is unchanged.
        let g = Pattern::from_indices("g", (0..8).collect())
            .with_delta(8)
            .with_count(1 << 10);
        assert_eq!(g.gs_scatter_base(), 0);
        assert_eq!(g.required_elements(), last_base + 7 + 1);
    }

    #[test]
    fn stream_like_sizing() {
        // Paper §3.4: ./spatter -k Gather -p UNIFORM:8:1 -d 8 -l 2^24
        let p = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(1 << 24);
        assert_eq!(p.vector_len(), 8);
        assert_eq!(p.moved_bytes(), 8 * 8 * (1 << 24));
        assert_eq!(p.required_elements(), 8 * ((1 << 24) - 1) + 7 + 1);
        p.validate().unwrap();
    }

    #[test]
    fn address_map() {
        let p = Pattern::from_indices("t", vec![0, 4, 8])
            .with_delta(2)
            .with_count(4);
        assert_eq!(p.address(0, 0), 0);
        assert_eq!(p.address(3, 2), 6 + 8);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        assert!(Pattern::from_indices("e", vec![])
            .with_count(1)
            .validate()
            .is_err());
        assert!(Pattern::from_indices("n", vec![-1])
            .validate()
            .is_err());
        assert!(Pattern::from_indices("z", vec![0])
            .with_count(0)
            .validate()
            .is_err());
        assert!(Pattern::from_indices("d", vec![0])
            .with_delta(-3)
            .validate()
            .is_err());
    }

    #[test]
    fn delta_zero_is_valid() {
        // LULESH-S3 is a scatter with delta 0 — must be accepted.
        let p = Pattern::from_indices("s3", vec![0, 24, 48])
            .with_delta(0)
            .with_count(100);
        p.validate().unwrap();
        assert_eq!(p.required_elements(), 49);
    }

    #[test]
    fn classify_taxonomy() {
        assert_eq!(
            classify_indices(&[0, 1, 2, 3]),
            PatternClass::UniformStride(1)
        );
        assert_eq!(
            classify_indices(&[0, 24, 48, 72]),
            PatternClass::UniformStride(24)
        );
        assert_eq!(
            classify_indices(&[0, 0, 1, 1]),
            PatternClass::Broadcast
        );
        assert_eq!(
            classify_indices(&[0, 1, 2, 3, 23, 24, 25, 26]),
            PatternClass::MostlyStride1
        );
        assert_eq!(
            classify_indices(&[4, 8, 12, 0, 20, 24, 28, 16]),
            PatternClass::Complex
        );
    }

    #[test]
    fn multi_delta_base_cycles() {
        // deltas [0, 0, 0, 16]: three revisits, then a jump.
        let p = Pattern::from_indices("t", vec![0, 1])
            .with_deltas(&[0, 0, 0, 16])
            .with_count(9);
        let bases: Vec<i64> = (0..9).map(|i| p.base(i)).collect();
        assert_eq!(bases, vec![0, 0, 0, 0, 16, 16, 16, 16, 32]);
        assert_eq!(p.delta_at(3), 16);
        assert_eq!(p.delta_at(4), 0);
        assert!((p.mean_delta() - 4.0).abs() < 1e-12);
        // count must not be reset by with_deltas; with_count preserved.
        assert_eq!(p.count, 9);
        p.validate().unwrap();
    }

    #[test]
    fn multi_delta_required_elements() {
        let p = Pattern::from_indices("t", vec![0, 7])
            .with_deltas(&[2, 10])
            .with_count(4);
        // bases: 0, 2, 12, 14 -> last base 14, max idx 7 -> 22 elems
        assert_eq!(p.required_elements(), 22);
    }

    #[test]
    fn single_element_delta_list_degrades() {
        let a = Pattern::from_indices("t", vec![0]).with_deltas(&[5]);
        let b = Pattern::from_indices("t", vec![0]).with_delta(5);
        assert_eq!(a, b);
        assert!(a.deltas.is_empty());
    }

    #[test]
    fn negative_delta_in_list_rejected() {
        let p = Pattern::from_indices("t", vec![0])
            .with_deltas(&[1, -2])
            .with_count(4);
        assert!(p.validate().is_err());
    }

    #[test]
    fn random_spec_is_deterministic_and_bounded() {
        let a = parse_spec("RANDOM:32:1000").unwrap();
        let b = parse_spec("RANDOM:32:1000").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 32);
        assert!(a.iter().all(|&i| (0..1000).contains(&i)));
        // different seed -> different buffer (overwhelmingly)
        let c = parse_spec("RANDOM:32:1000:7").unwrap();
        assert_ne!(a, c);
        assert!(parse_spec("RANDOM:0:10").is_err());
        assert!(parse_spec("RANDOM:8:0").is_err());
        assert!(parse_spec("RANDOM:8").is_err());
    }

    #[test]
    fn classify_names() {
        assert_eq!(PatternClass::UniformStride(1).name(), "Stride-1");
        assert_eq!(PatternClass::UniformStride(24).name(), "Stride-24");
        assert_eq!(PatternClass::Broadcast.name(), "Broadcast");
    }
}
