//! Table 5 of the paper: the application-derived G/S proxy patterns
//! extracted from AMG, LULESH, Nekbone, and PENNANT.
//!
//! These are the exact index buffers and deltas printed in the paper's
//! appendix. They are both (a) the inputs for Table 4 / Figs 7–9 and
//! (b) the ground truth the trace-extraction pipeline (`trace::extract`)
//! must recover from the mini-app emulators.

use super::{Kernel, Pattern};

/// One Table 5 row.
#[derive(Debug, Clone)]
pub struct AppPattern {
    /// Paper's pattern id, e.g. "PENNANT-G0".
    pub name: &'static str,
    /// Source mini-app, e.g. "PENNANT".
    pub app: &'static str,
    pub kernel: Kernel,
    pub indices: &'static [i64],
    pub delta: i64,
    /// Paper's "Type" column (empty where the paper leaves it blank).
    pub class: &'static str,
}

impl AppPattern {
    /// Materialize as a runnable Pattern with the given count.
    pub fn to_pattern(&self, count: usize) -> Pattern {
        Pattern::from_indices(self.name, self.indices.to_vec())
            .with_delta(self.delta)
            .with_count(count)
    }
}

const P16_BCAST: &[i64] = &[0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3];
const P16_QUAD: &[i64] = &[4, 8, 12, 0, 20, 24, 28, 16, 36, 40, 44, 32, 52, 56, 60, 48];
const P16_QUAD2: &[i64] = &[6, 0, 2, 4, 14, 8, 10, 12, 22, 16, 18, 20, 30, 24, 26, 28];
const P16_EDGE: &[i64] = &[482, 0, 2, 484, 484, 2, 4, 486, 486, 4, 6, 488, 488, 6, 8, 490];
const STRIDE1_16: &[i64] = &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15];
const STRIDE4_16: &[i64] = &[0, 4, 8, 12, 16, 20, 24, 28, 32, 36, 40, 44, 48, 52, 56, 60];
const STRIDE8_16: &[i64] = &[0, 8, 16, 24, 32, 40, 48, 56, 64, 72, 80, 88, 96, 104, 112, 120];
const STRIDE24_16: &[i64] = &[
    0, 24, 48, 72, 96, 120, 144, 168, 192, 216, 240, 264, 288, 312, 336, 360,
];
const STRIDE6_16: &[i64] = &[0, 6, 12, 18, 24, 30, 36, 42, 48, 54, 60, 66, 72, 78, 84, 90];

/// All gather patterns of Table 5, in paper order.
pub const GATHER_PATTERNS: &[AppPattern] = &[
    AppPattern { name: "PENNANT-G0", app: "PENNANT", kernel: Kernel::Gather,
        indices: &[2, 484, 482, 0, 4, 486, 484, 2, 6, 488, 486, 4, 8, 490, 488, 6],
        delta: 2, class: "" },
    AppPattern { name: "PENNANT-G1", app: "PENNANT", kernel: Kernel::Gather,
        indices: &[0, 2, 484, 482, 2, 4, 486, 484, 4, 6, 488, 486, 6, 8, 490, 488],
        delta: 2, class: "" },
    AppPattern { name: "PENNANT-G2", app: "PENNANT", kernel: Kernel::Gather,
        indices: STRIDE4_16, delta: 2, class: "Stride-4" },
    AppPattern { name: "PENNANT-G3", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_QUAD, delta: 2, class: "" },
    AppPattern { name: "PENNANT-G4", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_BCAST, delta: 4, class: "Broadcast" },
    AppPattern { name: "PENNANT-G5", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_QUAD, delta: 4, class: "" },
    AppPattern { name: "PENNANT-G6", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_EDGE, delta: 480, class: "" },
    AppPattern { name: "PENNANT-G7", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_EDGE, delta: 482, class: "" },
    AppPattern { name: "PENNANT-G8", app: "PENNANT", kernel: Kernel::Gather,
        indices: &[2, 0, 0, 0, 2, 0, 0, 0, 2, 0, 0, 0, 2, 0, 0, 0],
        delta: 129_608, class: "" },
    AppPattern { name: "PENNANT-G9", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_BCAST, delta: 388_852, class: "Broadcast" },
    AppPattern { name: "PENNANT-G10", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_BCAST, delta: 388_848, class: "Broadcast" },
    AppPattern { name: "PENNANT-G11", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_BCAST, delta: 388_848, class: "Broadcast" },
    AppPattern { name: "PENNANT-G12", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_QUAD2, delta: 518_408, class: "" },
    AppPattern { name: "PENNANT-G13", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_QUAD2, delta: 518_408, class: "" },
    AppPattern { name: "PENNANT-G14", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_QUAD2, delta: 1_036_816, class: "" },
    AppPattern { name: "PENNANT-G15", app: "PENNANT", kernel: Kernel::Gather,
        indices: P16_BCAST, delta: 1_882_384, class: "Broadcast" },
    AppPattern { name: "LULESH-G0", app: "LULESH", kernel: Kernel::Gather,
        indices: STRIDE1_16, delta: 1, class: "Stride-1" },
    AppPattern { name: "LULESH-G1", app: "LULESH", kernel: Kernel::Gather,
        indices: STRIDE1_16, delta: 8, class: "Stride-1" },
    AppPattern { name: "LULESH-G2", app: "LULESH", kernel: Kernel::Gather,
        indices: STRIDE8_16, delta: 1, class: "Stride-8" },
    AppPattern { name: "LULESH-G3", app: "LULESH", kernel: Kernel::Gather,
        indices: STRIDE24_16, delta: 8, class: "Stride-24" },
    AppPattern { name: "LULESH-G4", app: "LULESH", kernel: Kernel::Gather,
        indices: STRIDE24_16, delta: 4, class: "Stride-24" },
    AppPattern { name: "LULESH-G5", app: "LULESH", kernel: Kernel::Gather,
        indices: STRIDE24_16, delta: 1, class: "Stride-24" },
    AppPattern { name: "LULESH-G6", app: "LULESH", kernel: Kernel::Gather,
        indices: STRIDE24_16, delta: 8, class: "Stride-24" },
    AppPattern { name: "LULESH-G7", app: "LULESH", kernel: Kernel::Gather,
        indices: STRIDE1_16, delta: 41, class: "Stride-1" },
    AppPattern { name: "NEKBONE-G0", app: "Nekbone", kernel: Kernel::Gather,
        indices: STRIDE6_16, delta: 3, class: "Stride-6" },
    AppPattern { name: "NEKBONE-G1", app: "Nekbone", kernel: Kernel::Gather,
        indices: STRIDE6_16, delta: 8, class: "Stride-6" },
    AppPattern { name: "NEKBONE-G2", app: "Nekbone", kernel: Kernel::Gather,
        indices: STRIDE6_16, delta: 8, class: "Stride-6" },
    AppPattern { name: "AMG-G0", app: "AMG", kernel: Kernel::Gather,
        indices: &[1333, 0, 1, 36, 37, 72, 73, 1296, 1297, 1332, 1368, 1369,
                   2592, 2593, 2628, 2629],
        delta: 1, class: "Mostly Stride-1" },
    AppPattern { name: "AMG-G1", app: "AMG", kernel: Kernel::Gather,
        indices: &[1333, 0, 1, 2, 36, 37, 38, 72, 73, 74, 1296, 1297, 1298,
                   1332, 1334, 1368],
        delta: 1, class: "Mostly Stride-1" },
];

/// All scatter patterns of Table 5, in paper order.
/// LULESH-S3 (scatter, delta 0) is discussed throughout §5.4 even though
/// the appendix row list visible in the text cuts off at S2; it is the
/// S1 index buffer with delta 0.
pub const SCATTER_PATTERNS: &[AppPattern] = &[
    AppPattern { name: "PENNANT-S0", app: "PENNANT", kernel: Kernel::Scatter,
        indices: STRIDE4_16, delta: 1, class: "Stride-4" },
    AppPattern { name: "LULESH-S0", app: "LULESH", kernel: Kernel::Scatter,
        indices: STRIDE8_16, delta: 1, class: "Stride-8" },
    AppPattern { name: "LULESH-S1", app: "LULESH", kernel: Kernel::Scatter,
        indices: STRIDE24_16, delta: 8, class: "Stride-24" },
    AppPattern { name: "LULESH-S2", app: "LULESH", kernel: Kernel::Scatter,
        indices: STRIDE24_16, delta: 1, class: "Stride-24" },
    AppPattern { name: "LULESH-S3", app: "LULESH", kernel: Kernel::Scatter,
        indices: STRIDE24_16, delta: 0, class: "Stride-24" },
];

/// Every Table 5 pattern (gathers then scatters, paper order).
pub fn all() -> Vec<&'static AppPattern> {
    GATHER_PATTERNS.iter().chain(SCATTER_PATTERNS.iter()).collect()
}

/// Patterns belonging to one mini-app, e.g. "LULESH".
pub fn by_app(app: &str) -> Vec<&'static AppPattern> {
    all()
        .into_iter()
        .filter(|p| p.app.eq_ignore_ascii_case(app))
        .collect()
}

/// Look up a single pattern by its paper id, e.g. "PENNANT-G5".
pub fn by_name(name: &str) -> Option<&'static AppPattern> {
    all().into_iter().find(|p| p.name.eq_ignore_ascii_case(name))
}

/// The mini-app names, in paper order.
pub const APPS: &[&str] = &["AMG", "Nekbone", "LULESH", "PENNANT"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::{classify_indices, PatternClass};

    #[test]
    fn counts_match_paper() {
        assert_eq!(GATHER_PATTERNS.len(), 29); // 16 PENNANT + 8 LULESH + 3 Nekbone + 2 AMG
        assert_eq!(SCATTER_PATTERNS.len(), 5);
        assert_eq!(all().len(), 34);
    }

    #[test]
    fn all_buffers_have_16_indices() {
        for p in all() {
            assert_eq!(p.indices.len(), 16, "{}", p.name);
        }
    }

    #[test]
    fn classifications_match_paper_type_column() {
        for p in all() {
            let c = classify_indices(p.indices);
            match p.class {
                "Stride-1" => assert_eq!(c, PatternClass::UniformStride(1), "{}", p.name),
                "Stride-4" => assert_eq!(c, PatternClass::UniformStride(4), "{}", p.name),
                "Stride-6" => assert_eq!(c, PatternClass::UniformStride(6), "{}", p.name),
                "Stride-8" => assert_eq!(c, PatternClass::UniformStride(8), "{}", p.name),
                "Stride-24" => assert_eq!(c, PatternClass::UniformStride(24), "{}", p.name),
                "Broadcast" => assert_eq!(c, PatternClass::Broadcast, "{}", p.name),
                "Mostly Stride-1" => {
                    // AMG buffers start with an out-of-order 1333; the
                    // paper still calls them mostly-stride-1. Our strict
                    // classifier sees Complex — both are acceptable here.
                    assert!(
                        c == PatternClass::MostlyStride1 || c == PatternClass::Complex,
                        "{}", p.name
                    );
                }
                "" => {} // paper leaves type blank
                other => panic!("unexpected class {other}"),
            }
        }
    }

    #[test]
    fn lookup_by_name_and_app() {
        assert_eq!(by_name("PENNANT-G5").unwrap().delta, 4);
        assert_eq!(by_name("lulesh-s3").unwrap().delta, 0);
        assert!(by_name("NOPE-G9").is_none());
        assert_eq!(by_app("LULESH").len(), 12);
        assert_eq!(by_app("AMG").len(), 2);
        assert_eq!(by_app("Nekbone").len(), 3);
        assert_eq!(by_app("PENNANT").len(), 17);
    }

    #[test]
    fn to_pattern_materializes() {
        let p = by_name("NEKBONE-G0").unwrap().to_pattern(100);
        assert_eq!(p.vector_len(), 16);
        assert_eq!(p.delta, 3);
        assert_eq!(p.count, 100);
        p.validate().unwrap();
    }

    #[test]
    fn pennant_deltas_partition_small_and_large() {
        // §5.4.2 item (5): patterns before G5 have delta <= 4; G6+ have
        // delta >= 400. (G5 itself is the boundary with delta 4.)
        for p in GATHER_PATTERNS.iter().filter(|p| p.app == "PENNANT") {
            let n: usize = p.name["PENNANT-G".len()..].parse().unwrap();
            if n <= 5 {
                assert!(p.delta <= 4, "{}", p.name);
            } else {
                assert!(p.delta >= 400, "{}", p.name);
            }
        }
    }
}
