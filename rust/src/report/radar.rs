//! Radar-plot data (Figs 7 and 8): per pattern, each platform's
//! bandwidth as a percentage of that platform's stride-1 bandwidth.
//! Values above 100% mean the pattern exploits caching (the paper's
//! "inner circle" interpretation).

use crate::json::{obj, Value};

/// One spoke: a platform's relative performance on a pattern.
#[derive(Debug, Clone, PartialEq)]
pub struct RadarSpoke {
    pub platform: String,
    pub is_gpu: bool,
    /// Pattern bandwidth / platform stride-1 bandwidth, as a fraction
    /// (1.0 == the "100%" ring).
    pub relative: f64,
}

/// One radar circle: a single pattern across all platforms.
#[derive(Debug, Clone)]
pub struct RadarChart {
    pub pattern: String,
    pub spokes: Vec<RadarSpoke>,
}

impl RadarChart {
    pub fn new(pattern: &str) -> RadarChart {
        RadarChart {
            pattern: pattern.to_string(),
            spokes: Vec::new(),
        }
    }

    pub fn add(&mut self, platform: &str, is_gpu: bool, pattern_gbs: f64, stride1_gbs: f64) {
        let relative = if stride1_gbs > 0.0 {
            pattern_gbs / stride1_gbs
        } else {
            0.0
        };
        self.spokes.push(RadarSpoke {
            platform: platform.to_string(),
            is_gpu,
            relative,
        });
    }

    /// Platforms that beat their own stride-1 bandwidth (caching).
    pub fn above_ring(&self) -> Vec<&RadarSpoke> {
        self.spokes.iter().filter(|s| s.relative > 1.0).collect()
    }

    /// Render as a compact text "radar": one bar per spoke, the `|`
    /// marks the 100% ring.
    pub fn render_text(&self) -> String {
        let mut out = format!("{}\n", self.pattern);
        for s in &self.spokes {
            let frac = s.relative.min(2.0);
            let filled = (frac * 20.0).round() as usize;
            let mut bar = String::new();
            for i in 0..40 {
                if i == 20 {
                    bar.push('|');
                }
                bar.push(if i < filled { '#' } else { ' ' });
            }
            out.push_str(&format!(
                "  {:>8} [{}] {:5.1}%{}\n",
                s.platform,
                bar,
                s.relative * 100.0,
                if s.is_gpu { " (gpu)" } else { "" },
            ));
        }
        out
    }

    pub fn to_json(&self) -> Value {
        let spokes: Vec<Value> = self
            .spokes
            .iter()
            .map(|s| {
                obj(&[
                    ("platform", Value::from(s.platform.clone())),
                    ("is_gpu", Value::from(s.is_gpu)),
                    ("relative", Value::from(s.relative)),
                ])
            })
            .collect();
        obj(&[
            ("pattern", Value::from(self.pattern.clone())),
            ("spokes", Value::Array(spokes)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_math() {
        let mut r = RadarChart::new("AMG-G0");
        r.add("skx", false, 328.0, 97.163);
        r.add("k40c", true, 108.0, 193.855);
        assert!(r.spokes[0].relative > 3.0);
        assert!(r.spokes[1].relative < 1.0);
        assert_eq!(r.above_ring().len(), 1);
        assert_eq!(r.above_ring()[0].platform, "skx");
    }

    #[test]
    fn text_render_marks_ring() {
        let mut r = RadarChart::new("p");
        r.add("a", false, 50.0, 100.0);
        let s = r.render_text();
        assert!(s.contains('|'));
        assert!(s.contains("50.0%"));
    }

    #[test]
    fn zero_stride1_is_safe() {
        let mut r = RadarChart::new("p");
        r.add("a", false, 50.0, 0.0);
        assert_eq!(r.spokes[0].relative, 0.0);
    }

    #[test]
    fn json_shape() {
        let mut r = RadarChart::new("p");
        r.add("a", true, 10.0, 20.0);
        let j = r.to_json();
        assert_eq!(
            j.get("spokes").unwrap().as_array().unwrap()[0]
                .get("relative")
                .unwrap()
                .as_f64()
                .unwrap(),
            0.5
        );
    }
}
