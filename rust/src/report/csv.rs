//! CSV series output — one file per paper figure, consumable by any
//! plotting tool.

use std::io::Write as _;
use std::path::Path;

use crate::error::Result;

/// A CSV document under construction.
#[derive(Debug, Clone, Default)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Csv {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Csv {
        let owned: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&owned)
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&escape_row(&self.header));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&escape_row(row));
            out.push('\n');
        }
        out
    }

    /// Write to `dir/name`, creating the directory if needed.
    pub fn write(&self, dir: &Path, name: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(name))?;
        f.write_all(self.render().as_bytes())?;
        Ok(())
    }
}

fn escape_row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_escapes() {
        let mut c = Csv::new(&["name", "gbs"]);
        c.row_display(&[&"plain", &43.885]);
        c.row_display(&[&"with,comma", &1]);
        c.row_display(&[&"with\"quote", &2]);
        let s = c.render();
        assert!(s.starts_with("name,gbs\n"));
        assert!(s.contains("plain,43.885"));
        assert!(s.contains("\"with,comma\",1"));
        assert!(s.contains("\"with\"\"quote\",2"));
    }

    #[test]
    fn writes_to_disk() {
        let dir = std::env::temp_dir().join("spatter-csv-test");
        let mut c = Csv::new(&["a"]);
        c.row_display(&[&7]);
        c.write(&dir, "t.csv").unwrap();
        let read = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert_eq!(read, "a\n7\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
