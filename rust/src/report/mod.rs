//! Result presentation: ASCII tables, CSV series, and the data behind
//! the paper's radar plots (Figs 7/8) and bandwidth-bandwidth plots
//! (Fig 9).

mod bwbw;
mod csv;
mod radar;
mod table;

pub use bwbw::{BwBwPoint, BwBwSeries};
pub use csv::Csv;
pub use radar::{RadarChart, RadarSpoke};
pub use table::Table;
