//! Bandwidth-bandwidth plot data (Fig 9): pattern bandwidth as a
//! function of the platform's stride-1 bandwidth. Stride-1 sits on the
//! x = y diagonal; a point's vertical distance from the diagonal is the
//! platform's bandwidth-utilization on that pattern; unit-slope lines
//! are constant fractional bandwidth.

use crate::json::{obj, Value};

/// One point: (platform stride-1 bandwidth, pattern bandwidth).
#[derive(Debug, Clone, PartialEq)]
pub struct BwBwPoint {
    pub platform: String,
    pub is_gpu: bool,
    pub stride1_gbs: f64,
    pub pattern_gbs: f64,
}

impl BwBwPoint {
    /// Fraction of available bandwidth the pattern achieves (distance
    /// below the diagonal, as a ratio).
    pub fn fraction(&self) -> f64 {
        if self.stride1_gbs > 0.0 {
            self.pattern_gbs / self.stride1_gbs
        } else {
            0.0
        }
    }
}

/// All platforms' points for one pattern.
#[derive(Debug, Clone)]
pub struct BwBwSeries {
    pub pattern: String,
    pub points: Vec<BwBwPoint>,
}

impl BwBwSeries {
    pub fn new(pattern: &str) -> BwBwSeries {
        BwBwSeries {
            pattern: pattern.to_string(),
            points: Vec::new(),
        }
    }

    pub fn add(&mut self, platform: &str, is_gpu: bool, stride1: f64, bw: f64) {
        self.points.push(BwBwPoint {
            platform: platform.to_string(),
            is_gpu,
            stride1_gbs: stride1,
            pattern_gbs: bw,
        });
    }

    /// The paper's Fig 9 comparisons: relative slope between two
    /// platforms — > 1 means `a` is better in *relative* terms too.
    pub fn relative_slope(&self, a: &str, b: &str) -> Option<f64> {
        let pa = self.points.iter().find(|p| p.platform == a)?;
        let pb = self.points.iter().find(|p| p.platform == b)?;
        if pb.fraction() == 0.0 {
            return None;
        }
        Some(pa.fraction() / pb.fraction())
    }

    pub fn to_json(&self) -> Value {
        let pts: Vec<Value> = self
            .points
            .iter()
            .map(|p| {
                obj(&[
                    ("platform", Value::from(p.platform.clone())),
                    ("is_gpu", Value::from(p.is_gpu)),
                    ("stride1_gbs", Value::from(p.stride1_gbs)),
                    ("pattern_gbs", Value::from(p.pattern_gbs)),
                    ("fraction", Value::from(p.fraction())),
                ])
            })
            .collect();
        obj(&[
            ("pattern", Value::from(self.pattern.clone())),
            ("points", Value::Array(pts)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fraction_and_slope() {
        let mut s = BwBwSeries::new("PENNANT-G12");
        s.add("clx", false, 66.0, 16.5); // 1/4 of peak
        s.add("bdw", false, 43.9, 2.74); // 1/16 of peak
        assert!((s.points[0].fraction() - 0.25).abs() < 1e-9);
        // CLX better in relative terms (the Fig 9a observation).
        let slope = s.relative_slope("clx", "bdw").unwrap();
        assert!(slope > 1.0, "{slope}");
        assert!(s.relative_slope("clx", "nope").is_none());
    }

    #[test]
    fn json_has_fraction() {
        let mut s = BwBwSeries::new("x");
        s.add("v100", true, 868.0, 86.8);
        let j = s.to_json();
        let f = j.get("points").unwrap().as_array().unwrap()[0]
            .get("fraction")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((f - 0.1).abs() < 1e-9);
    }
}
