//! ASCII table rendering for terminal reports.

/// A simple left-aligned table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Table {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Render with column auto-sizing.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].chars().count());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for w in &widths {
                out.push('+');
                for _ in 0..w + 2 {
                    out.push('-');
                }
            }
            out.push_str("+\n");
        };
        let line = |out: &mut String, cells: &[String]| {
            for (c, cell) in cells.iter().enumerate() {
                out.push_str("| ");
                out.push_str(cell);
                for _ in cell.chars().count()..widths[c] + 1 {
                    out.push(' ');
                }
            }
            out.push_str("|\n");
        };
        sep(&mut out);
        line(&mut out, &self.header);
        sep(&mut out);
        for row in &self.rows {
            line(&mut out, row);
        }
        sep(&mut out);
        out
    }
}

/// Format a bandwidth like the paper's tables (integer GB/s for large
/// values, one decimal under 10).
pub fn fmt_gbs(bw: f64) -> String {
    if bw >= 10.0 {
        format!("{bw:.0}")
    } else {
        format!("{bw:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Platform", "GB/s"]);
        t.row_strs(&["bdw", "43.9"]);
        t.row_strs(&["skylake-long-name", "97"]);
        let s = t.render();
        assert!(s.contains("| Platform"));
        assert!(s.contains("| skylake-long-name"));
        // all lines same width
        let widths: Vec<usize> =
            s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn gbs_formatting() {
        assert_eq!(fmt_gbs(123.4), "123");
        assert_eq!(fmt_gbs(6.25), "6.2");
        assert_eq!(fmt_gbs(0.53), "0.5");
    }

    #[test]
    fn len_and_empty() {
        let mut t = Table::new(&["x"]);
        assert!(t.is_empty());
        t.row_strs(&["1"]);
        assert_eq!(t.len(), 1);
    }
}
