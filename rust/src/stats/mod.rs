//! Statistics for Spatter runs (paper §3.5):
//! minimum time over N runs, the bandwidth formula, harmonic mean over
//! configurations, and Pearson's R for the STREAM-correlation study
//! (Table 4, Eq. 1).

/// The paper's run protocol: report the minimum time over 10 runs.
pub const RUNS_PER_PATTERN: usize = 10;

/// Bandwidth in bytes/second per paper §3.5:
/// `(sizeof(double) * len(index) * n) / time`.
/// "the rate at which the processor is able to consume data for each
/// pattern" — cache reuse may push this above DRAM bandwidth.
pub fn bandwidth_bytes_per_sec(index_len: usize, n: usize, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return f64::INFINITY;
    }
    (8 * index_len * n) as f64 / seconds
}

/// Summary over the per-run times of one pattern.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSummary {
    pub min_seconds: f64,
    pub max_seconds: f64,
    pub mean_seconds: f64,
    pub runs: usize,
}

impl RunSummary {
    /// Summarize a set of run times; the paper reports min.
    pub fn from_times(times: &[f64]) -> Option<RunSummary> {
        if times.is_empty() {
            return None;
        }
        let min = times.iter().copied().fold(f64::INFINITY, f64::min);
        let max = times.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        Some(RunSummary {
            min_seconds: min,
            max_seconds: max,
            mean_seconds: mean,
            runs: times.len(),
        })
    }
}

/// Harmonic mean — the paper's aggregate for JSON multi-config runs and
/// the per-app columns of Table 4. Zero/negative entries are rejected
/// (bandwidths are strictly positive).
pub fn harmonic_mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return None;
    }
    Some(xs.len() as f64 / xs.iter().map(|x| 1.0 / x).sum::<f64>())
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        None
    } else {
        Some(xs.iter().sum::<f64>() / xs.len() as f64)
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> Option<f64> {
    let m = mean(xs)?;
    Some(
        (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
            .sqrt(),
    )
}

/// Population covariance of two equal-length series.
pub fn covariance(xs: &[f64], ys: &[f64]) -> Option<f64> {
    if xs.len() != ys.len() || xs.is_empty() {
        return None;
    }
    let mx = mean(xs)?;
    let my = mean(ys)?;
    Some(
        xs.iter()
            .zip(ys)
            .map(|(x, y)| (x - mx) * (y - my))
            .sum::<f64>()
            / xs.len() as f64,
    )
}

/// Pearson's correlation coefficient (paper Eq. 1):
/// `R = cov(X, STREAM) / (std(X) * std(STREAM))`.
/// Returns None for degenerate series (zero variance or length < 2).
pub fn pearson_r(xs: &[f64], ys: &[f64]) -> Option<f64> {
    if xs.len() < 2 {
        return None;
    }
    let sx = std_dev(xs)?;
    let sy = std_dev(ys)?;
    if sx == 0.0 || sy == 0.0 {
        return None;
    }
    Some(covariance(xs, ys)? / (sx * sy))
}

/// Min and max over a series (for the JSON-run aggregate report).
pub fn min_max(xs: &[f64]) -> Option<(f64, f64)> {
    if xs.is_empty() {
        return None;
    }
    let mn = xs.iter().copied().fold(f64::INFINITY, f64::min);
    let mx = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    Some((mn, mx))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * b.abs().max(1.0)
    }

    #[test]
    fn bandwidth_formula_matches_paper() {
        // 8 bytes * 8 indices * 2^24 gathers in 1 second
        let bw = bandwidth_bytes_per_sec(8, 1 << 24, 1.0);
        assert!(close(bw, (8 * 8 * (1 << 24)) as f64));
        assert!(bandwidth_bytes_per_sec(8, 1, 0.0).is_infinite());
    }

    #[test]
    fn run_summary_min_of_10() {
        let times = [5.0, 3.0, 4.0, 3.5, 9.0, 3.2, 3.1, 3.05, 3.9, 4.2];
        let s = RunSummary::from_times(&times).unwrap();
        assert!(close(s.min_seconds, 3.0));
        assert!(close(s.max_seconds, 9.0));
        assert_eq!(s.runs, 10);
        assert!(RunSummary::from_times(&[]).is_none());
    }

    #[test]
    fn harmonic_mean_properties() {
        assert!(close(harmonic_mean(&[2.0, 2.0, 2.0]).unwrap(), 2.0));
        // hmean of {1, 3} = 1.5 — dominated by the small value
        assert!(close(harmonic_mean(&[1.0, 3.0]).unwrap(), 1.5));
        assert!(harmonic_mean(&[]).is_none());
        assert!(harmonic_mean(&[1.0, 0.0]).is_none());
        assert!(harmonic_mean(&[1.0, -2.0]).is_none());
        // hmean <= amean always
        let xs = [3.0, 7.0, 11.0, 2.0];
        assert!(harmonic_mean(&xs).unwrap() <= mean(&xs).unwrap());
    }

    #[test]
    fn pearson_r_known_values() {
        // perfectly correlated
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [10.0, 20.0, 30.0, 40.0];
        assert!(close(pearson_r(&x, &y).unwrap(), 1.0));
        // perfectly anti-correlated
        let y2 = [40.0, 30.0, 20.0, 10.0];
        assert!(close(pearson_r(&x, &y2).unwrap(), -1.0));
        // independent-ish: R of orthogonal series is 0
        let x3 = [1.0, -1.0, 1.0, -1.0];
        let y3 = [1.0, 1.0, -1.0, -1.0];
        assert!(pearson_r(&x3, &y3).unwrap().abs() < 1e-12);
    }

    #[test]
    fn pearson_r_degenerate() {
        assert!(pearson_r(&[1.0], &[2.0]).is_none());
        assert!(pearson_r(&[1.0, 1.0], &[2.0, 3.0]).is_none());
        assert!(pearson_r(&[1.0, 2.0], &[3.0, 3.0]).is_none());
        assert!(pearson_r(&[1.0, 2.0], &[3.0]).is_none());
    }

    #[test]
    fn min_max_works() {
        assert_eq!(min_max(&[3.0, 1.0, 2.0]), Some((1.0, 3.0)));
        assert!(min_max(&[]).is_none());
    }
}
