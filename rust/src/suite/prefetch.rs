//! `--suite prefetch` — the paper's prefetching-regime experiment
//! (Fig 4 / §5.1.1) generalized into a depth sweep, and extended to
//! the GS indexed copy.
//!
//! For every swept CPU platform the suite runs three workload families
//! under several prefetcher regimes — depth 0 (the MSR-off runs of
//! Fig 4), the platform's native depth, and a doubled depth:
//!
//! * `g` — uniform-stride gather, strides 1..128: the Fig 4 curve.
//! * `gs` — uniform-stride GS (gather side at the swept stride,
//!   scatter side stride-1): the paired-pattern case — the write
//!   stream interleaves with the gather misses and disturbs the
//!   stride detectors, so coverage of the *gather side* is what the
//!   sweep isolates.
//! * `lulesh-gs` — a LULESH-class indexed copy (stride-24 gather side
//!   feeding a stride-1 scatter side, the element→node shape) at one
//!   fixed configuration per regime.
//!
//! The report states, per platform and family, the **prefetch-coverage
//! knee**: the smallest stride at which the native-depth run loses ≥5%
//! bandwidth versus depth 0. While the prefetcher covers the gather
//! side its fetches are lines the stream was about to demand anyway
//! (same DRAM traffic, same bandwidth-bound roofline — the regimes
//! tie); once the stride outruns it, every prefetch is pure over-fetch
//! and the on-regime pays for lines nobody reads. The knee is the
//! stride where that flip happens. Results go to `prefetch.csv` and
//! `prefetch.json`; everything runs through the `--jobs` pool and is
//! byte-identical for any worker count.

use super::ustride::cpu_ustride;
use super::{SuiteContext, STRIDES};
use crate::backends::{Backend, OpenMpSim};
use crate::coordinator::{run_configs_jobs, RunConfig, RunRecord};
use crate::error::Result;
use crate::json::{self, obj, Value};
use crate::pattern::{table5, Kernel, Pattern};
use crate::platforms::{self, CpuPlatform};
use crate::report::{Csv, Table};
use crate::sim::PrefetchKind;

/// The CPUs whose prefetchers the paper singles out (§5.1.1): BDW's
/// adjacent-line pair, SKX's unconditional next-line, Naples' useful-
/// only stride detector, TX2's aggressive streamer.
const PLATFORMS: &[&str] = &["bdw", "skx", "naples", "tx2"];

/// Bandwidth loss factor versus the depth-0 run at which a stride
/// counts as uncovered: prefetches that still cover the stream are
/// lines it was about to demand anyway (the regimes tie); a ≥5% loss
/// means the prefetcher is fetching lines nobody reads.
const COVERAGE_LOSS: f64 = 1.05;

/// The platform's native prefetch depth (lines fetched ahead); 0 when
/// it ships none.
fn native_depth(p: &CpuPlatform) -> usize {
    match p.prefetch {
        PrefetchKind::None => 0,
        PrefetchKind::AdjacentLine { .. } => 1,
        PrefetchKind::NextLine { degree } => degree,
        PrefetchKind::Stride { degree } => degree,
    }
}

/// The platform with its prefetcher rescaled to `depth` lines ahead.
/// Depth 0 disables it (the Fig 4 MSR toggle); the adjacent-line kind
/// has no depth axis and keeps its pair fetch for any depth > 0.
fn with_depth(p: &CpuPlatform, depth: usize) -> CpuPlatform {
    let mut q = p.clone();
    q.prefetch = if depth == 0 {
        PrefetchKind::None
    } else {
        match p.prefetch {
            PrefetchKind::None => PrefetchKind::None,
            PrefetchKind::AdjacentLine { disable_at_bytes } => {
                PrefetchKind::AdjacentLine { disable_at_bytes }
            }
            PrefetchKind::NextLine { .. } => {
                PrefetchKind::NextLine { degree: depth }
            }
            PrefetchKind::Stride { .. } => {
                PrefetchKind::Stride { degree: depth }
            }
        }
    };
    q
}

/// The depth regimes swept for a platform: off, native, doubled —
/// keeping only depths whose prefetcher configuration actually
/// differs (BDW's adjacent-line pair has no depth axis, so its
/// doubled regime would be a byte-identical duplicate of native).
fn depth_sweep(p: &CpuPlatform) -> Vec<usize> {
    let n = native_depth(p).max(1);
    let mut depths = Vec::new();
    let mut seen: Vec<PrefetchKind> = Vec::new();
    for d in [0, n, 2 * n] {
        let kind = with_depth(p, d).prefetch;
        if !seen.contains(&kind) {
            seen.push(kind);
            depths.push(d);
        }
    }
    depths
}

/// Uniform-stride GS: gather side at `stride`, scatter side stride-1,
/// no inter-iteration reuse on either side.
fn gs_ustride(stride: usize, count: usize) -> Pattern {
    cpu_ustride(stride, count)
        .with_gs_scatter((0..8).collect())
        .with_name(&format!("UNIFORM:8:{stride}>UNIFORM:8:1"))
}

/// LULESH-class GS: the element→node indexed copy — a stride-24
/// gather side (LULESH-G3's buffer) feeding a stride-1 scatter side.
fn lulesh_gs(count: usize) -> Pattern {
    let app = table5::by_name("LULESH-G3").expect("LULESH-G3 in Table 5");
    Pattern::from_indices("LULESH-G3>UNIFORM:16:1", app.indices.to_vec())
        .with_gs_scatter((0..16).collect())
        .with_delta(app.delta)
        .with_count(count)
}

/// The per-depth run queue for one platform.
fn configs_for(name: &str, depth: usize, count: usize) -> Vec<RunConfig> {
    let mut configs = Vec::new();
    for &s in STRIDES {
        configs.push(RunConfig {
            name: format!("{name}/pf{depth}/g/s{s}"),
            kernel: Kernel::Gather,
            pattern: cpu_ustride(s, count),
            page_size: None,
            threads: None,
            regime: None,
            placement: None,
        });
        configs.push(RunConfig {
            name: format!("{name}/pf{depth}/gs/s{s}"),
            kernel: Kernel::GS,
            pattern: gs_ustride(s, count),
            page_size: None,
            threads: None,
            regime: None,
            placement: None,
        });
    }
    configs.push(RunConfig {
        name: format!("{name}/pf{depth}/lulesh-gs"),
        kernel: Kernel::GS,
        pattern: lulesh_gs(count),
        page_size: None,
        threads: None,
        regime: None,
        placement: None,
    });
    configs
}

/// Per-stride bandwidths of one workload family at one depth, in
/// `STRIDES` order. Families interleave in `configs_for`: index
/// `2 * si` is the gather, `2 * si + 1` the GS run.
fn family_curve(records: &[RunRecord], family_offset: usize) -> Vec<f64> {
    (0..STRIDES.len())
        .map(|si| records[2 * si + family_offset].bandwidth_gbs)
        .collect()
}

/// Smallest stride at which the native-depth run loses a
/// `COVERAGE_LOSS` factor versus depth 0 (its fetches became pure
/// over-fetch) — `None` if the prefetcher covers the whole sweep.
fn coverage_knee(on: &[f64], off: &[f64]) -> Option<usize> {
    STRIDES
        .iter()
        .zip(on.iter().zip(off))
        .find(|(_, (on_bw, off_bw))| **on_bw * COVERAGE_LOSS <= **off_bw)
        .map(|(&s, _)| s)
}

pub fn prefetch_suite(ctx: &SuiteContext) -> Result<String> {
    let count = ctx.ustride_count();
    let mut csv = Csv::new(&[
        "platform", "depth", "workload", "stride", "gbs", "bottleneck",
    ]);
    let mut report = String::from(
        "== prefetch: prefetcher depth/regime sweep (gather + GS) ==\n",
    );
    let mut json_platforms: Vec<(String, Value)> = Vec::new();
    for &name in PLATFORMS {
        let platform = platforms::by_name(name)?;
        let depths = depth_sweep(&platform);
        let native = native_depth(&platform).max(1);
        // One pool dispatch per depth regime (each regime needs its own
        // engine configuration); record order is deterministic, so the
        // report is byte-identical for any --jobs value.
        let mut per_depth: Vec<(usize, Vec<RunRecord>)> = Vec::new();
        for &depth in &depths {
            let plat = with_depth(&platform, depth);
            let factory = || -> Result<Box<dyn Backend>> {
                Ok(Box::new(OpenMpSim::new(&plat)))
            };
            let configs = configs_for(name, depth, count);
            let records = run_configs_jobs(&factory, &configs, ctx.jobs)?;
            for (c, r) in configs.iter().zip(&records) {
                let (workload, stride) = match c.name.rsplit_once('/') {
                    Some((_, last)) if last.starts_with('s') => {
                        let wl = if c.kernel == Kernel::GS { "gs" } else { "g" };
                        (wl, last[1..].to_string())
                    }
                    _ => ("lulesh-gs", "-".to_string()),
                };
                csv.row_display(&[
                    &name,
                    &depth,
                    &workload,
                    &stride,
                    &format!("{:.3}", r.bandwidth_gbs),
                    &r.bottleneck,
                ]);
            }
            per_depth.push((depth, records));
        }

        // Table: one row per stride, one bandwidth column per
        // (family, depth).
        let header: Vec<String> = std::iter::once("stride".to_string())
            .chain(depths.iter().map(|d| format!("g pf{d}")))
            .chain(depths.iter().map(|d| format!("gs pf{d}")))
            .collect();
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&header_refs);
        for (si, &s) in STRIDES.iter().enumerate() {
            let mut row = vec![s.to_string()];
            for family in [0usize, 1] {
                for (_, records) in &per_depth {
                    row.push(format!(
                        "{:.2}",
                        records[2 * si + family].bandwidth_gbs
                    ));
                }
            }
            table.row(&row);
        }

        // Coverage knees: native depth vs depth 0, per family.
        let off = &per_depth[0].1;
        let native_records = per_depth
            .iter()
            .find(|(d, _)| *d == native)
            .map(|(_, r)| r)
            .unwrap_or(off);
        let mut knees: Vec<(&str, Option<usize>)> = Vec::new();
        for (family, offset) in [("g", 0usize), ("gs", 1)] {
            let on_curve = family_curve(native_records, offset);
            let off_curve = family_curve(off, offset);
            knees.push((family, coverage_knee(&on_curve, &off_curve)));
        }
        let knee_text: Vec<String> = knees
            .iter()
            .map(|(f, k)| match k {
                Some(s) => format!("{f}: stride {s}"),
                None => format!("{f}: covered through stride {}",
                    STRIDES.last().unwrap()),
            })
            .collect();
        // LULESH-class GS coverage at the fixed configuration.
        let lg_on = native_records.last().unwrap().bandwidth_gbs;
        let lg_off = off.last().unwrap().bandwidth_gbs;
        report.push_str(&format!(
            "-- {name} (native depth {native}) --\n{}prefetch-coverage \
             knee: {}; lulesh-gs native/off: {:.2}x\n",
            table.render(),
            knee_text.join(", "),
            lg_on / lg_off.max(1e-12)
        ));

        json_platforms.push((
            name.to_string(),
            obj(&[
                (
                    "depths",
                    Value::Array(
                        depths.iter().map(|&d| Value::from(d)).collect(),
                    ),
                ),
                (
                    "knees",
                    obj(&knees
                        .iter()
                        .map(|(f, k)| {
                            (
                                *f,
                                match k {
                                    Some(s) => Value::from(*s),
                                    None => Value::Null,
                                },
                            )
                        })
                        .collect::<Vec<_>>()),
                ),
                (
                    "runs",
                    Value::Array(
                        per_depth
                            .iter()
                            .flat_map(|(_, rs)| rs.iter().map(|r| r.to_json()))
                            .collect(),
                    ),
                ),
            ]),
        ));
    }
    csv.write(&ctx.out_dir, "prefetch.csv")?;
    let doc = Value::Object(json_platforms.into_iter().collect());
    let mut text = json::to_string_pretty(&doc);
    text.push('\n');
    std::fs::write(ctx.out_dir.join("prefetch.json"), text)?;
    report.push_str(
        "Takeaway check: at small strides every prefetcher covers the \
         gather side (its fetches are lines the stream demands anyway, \
         so the regimes tie); past the knee the fetches are unread \
         over-fetch and the on-regime loses bandwidth — SKX's \
         unconditional next-line pays hardest while Naples' useful-only \
         detector never over-fetches (no knee). The GS write stream has \
         its own stride tracker and open row (per-operand-stream \
         state), so the GS knees reflect each stream's own coverage \
         rather than cross-stream interleaving noise.\n",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx(tag: &str) -> SuiteContext {
        SuiteContext::fast(
            &Path::new("/tmp").join(format!("spatter-prefetch-{tag}")),
        )
    }

    #[test]
    fn depth_plumbing() {
        let bdw = platforms::by_name("bdw").unwrap();
        assert_eq!(native_depth(&bdw), 1);
        assert_eq!(with_depth(&bdw, 0).prefetch, PrefetchKind::None);
        let tx2 = platforms::by_name("tx2").unwrap();
        assert_eq!(native_depth(&tx2), 2);
        assert_eq!(
            with_depth(&tx2, 4).prefetch,
            PrefetchKind::NextLine { degree: 4 }
        );
        assert_eq!(depth_sweep(&tx2), vec![0, 2, 4]);
        // BDW's adjacent-line kind has no depth axis: the doubled
        // regime would duplicate native and is dropped.
        assert_eq!(depth_sweep(&bdw), vec![0, 1]);
    }

    #[test]
    fn coverage_knee_picks_first_uncovered_stride() {
        // Covered strides tie with depth 0; from stride 4 on the
        // prefetcher over-fetches and the on-regime loses bandwidth.
        let off = vec![1.0; STRIDES.len()];
        let mut on = vec![1.0, 0.99];
        on.resize(STRIDES.len(), 0.5);
        assert_eq!(coverage_knee(&on, &off), Some(4));
        // Ties (or gains) across the whole sweep: fully covered.
        let covered = vec![1.0; STRIDES.len()];
        assert_eq!(coverage_knee(&covered, &off), None);
    }

    #[test]
    fn report_csv_json_written_and_knees_reported() {
        let c = ctx("run");
        let report = prefetch_suite(&c).unwrap();
        assert!(report.contains("prefetch-coverage knee"), "{report}");
        assert!(report.contains("lulesh-gs native/off"), "{report}");
        assert!(c.out_dir.join("prefetch.csv").exists());
        let j = std::fs::read_to_string(c.out_dir.join("prefetch.json"))
            .unwrap();
        let doc = json::parse(&j).unwrap();
        for plat in PLATFORMS {
            let entry = doc.get(plat).unwrap();
            assert!(entry.get("knees").unwrap().get_opt("g").is_some());
            assert!(!entry.get("runs").unwrap().as_array().unwrap().is_empty());
        }
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn prefetch_covers_small_strides_then_stops_on_skx() {
        // The mechanism straight off the engine: at stride 1 SKX's
        // next-line fetches are lines the stream demands anyway (the
        // regimes tie — covered); by stride 32 every prefetch is an
        // unread line, the on-regime moves ~2x the bytes, and the
        // sweep's knee fires.
        let skx = platforms::by_name("skx").unwrap();
        let count = 1 << 15;
        let bw = |depth: usize, stride: usize| {
            let plat = with_depth(&skx, depth);
            OpenMpSim::new(&plat)
                .run(&cpu_ustride(stride, count), Kernel::Gather)
                .unwrap()
                .bandwidth_gbs()
        };
        assert!(
            bw(1, 1) * COVERAGE_LOSS > bw(0, 1),
            "stride-1 must stay covered: {} vs {}",
            bw(1, 1),
            bw(0, 1)
        );
        assert!(
            bw(1, 32) * COVERAGE_LOSS <= bw(0, 32),
            "stride-32 must be uncovered: {} vs {}",
            bw(1, 32),
            bw(0, 32)
        );
    }

    #[test]
    fn jobs_invariant_output() {
        let c1 = ctx("j1").with_jobs(1);
        let c4 = ctx("j4").with_jobs(4);
        let r1 = prefetch_suite(&c1).unwrap();
        let r4 = prefetch_suite(&c4).unwrap();
        assert_eq!(r1, r4, "report must not depend on --jobs");
        let f = |c: &SuiteContext, n: &str| {
            std::fs::read_to_string(c.out_dir.join(n)).unwrap()
        };
        assert_eq!(f(&c1, "prefetch.csv"), f(&c4, "prefetch.csv"));
        assert_eq!(f(&c1, "prefetch.json"), f(&c4, "prefetch.json"));
        std::fs::remove_dir_all(&c1.out_dir).ok();
        std::fs::remove_dir_all(&c4.out_dir).ok();
    }
}
