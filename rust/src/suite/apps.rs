//! Application-pattern experiments: Table 1, Table 4, Figs 7, 8, 9.

use super::ustride::{cpu_ustride, gpu_ustride};
use super::SuiteContext;
use crate::backends::{Backend, CudaSim, OpenMpSim};
use crate::error::Result;
use crate::pattern::{table5, Kernel};
use crate::platforms::{self, Platform};
use crate::report::{BwBwSeries, Csv, RadarChart, Table};
use crate::stats;
use crate::trace::extract::extract_from_trace;
use crate::trace::miniapps;

/// Table 1: run the mini-app emulators through the trace pipeline and
/// report the paper's characterization columns.
pub fn table1_characterization(ctx: &SuiteContext) -> Result<String> {
    let mut csv = Csv::new(&[
        "app", "kernel", "gathers", "scatters", "gs_mb", "gs_pct", "top_pattern",
        "top_delta", "class",
    ]);
    let mut table = Table::new(&[
        "Application / Kernel", "Gathers", "Scatters", "G/S MB (%)", "Top pattern class",
    ]);
    for app in miniapps::run_all(ctx.trace_scale()) {
        for k in &app.kernels {
            let pats = extract_from_trace(k, 1);
            let top = pats.first();
            let mb = k.gs_bytes() as f64 / 1e6;
            let pct = k.gs_traffic_fraction() * 100.0;
            let (tp, td, tc) = top
                .map(|p| {
                    (
                        format!("{:?}", &p.indices[..p.indices.len().min(6)]),
                        p.delta.to_string(),
                        p.class.name(),
                    )
                })
                .unwrap_or_default();
            csv.row_display(&[
                &app.app,
                &k.kernel,
                &k.gather_count(),
                &k.scatter_count(),
                &format!("{mb:.1}"),
                &format!("{pct:.1}"),
                &tp,
                &td,
                &tc,
            ]);
            table.row(&[
                format!("{} {}", app.app, k.kernel),
                k.gather_count().to_string(),
                k.scatter_count().to_string(),
                format!("{mb:.1} ({pct:.1}%)"),
                tc,
            ]);
        }
    }
    csv.write(&ctx.out_dir, "table1_apps.csv")?;
    Ok(format!(
        "== Table 1: application G/S characterization ==\n{}\
         Takeaway check: gathers outnumber scatters; G/S reaches large \
         traffic fractions; uniform/broadcast/MS1/complex classes all occur.\n",
        table.render()
    ))
}

/// Iteration count for one app pattern: the paper moves >= 2 GB per
/// app-pattern measurement. Large deltas produce very large *address
/// spans*; the simulators never allocate the arrays, so the span is
/// fine — capping the count here would shrink the touched-line
/// footprint below cache capacity and fake cache residency.
fn app_pattern_count(_delta: i64, base: usize) -> usize {
    base
}

/// Bandwidth of one Table 5 pattern on one platform.
fn pattern_bw(platform: &Platform, pat: &table5::AppPattern, count: usize) -> Result<f64> {
    let p = pat.to_pattern(app_pattern_count(pat.delta, count));
    let bw = match platform {
        Platform::Cpu(c) => OpenMpSim::new(c).run(&p, pat.kernel)?.bandwidth_gbs(),
        Platform::Gpu(g) => CudaSim::new(g).run(&p, pat.kernel)?.bandwidth_gbs(),
    };
    Ok(bw)
}

/// Stride-1 reference bandwidth of a platform (the radar "100% ring").
fn stride1_bw(platform: &Platform, count: usize) -> Result<f64> {
    Ok(match platform {
        Platform::Cpu(c) => OpenMpSim::new(c)
            .run(&cpu_ustride(1, count), Kernel::Gather)?
            .bandwidth_gbs(),
        Platform::Gpu(g) => CudaSim::new(g)
            .run(&gpu_ustride(1, count / 8), Kernel::Gather)?
            .bandwidth_gbs(),
    })
}

/// Table 4: harmonic-mean bandwidth per app per platform, with STREAM
/// *measured in-engine* (the Triad figure, via the baselines family)
/// reported next to the Table-3 anchor, a per-platform
/// spatter-to-stream bandwidth ratio, and the Pearson correlation of
/// each app's column with the **measured** STREAM numbers (computed
/// separately for CPUs and GPUs, as in the paper — but no longer
/// assumed from hardcoded anchors).
pub fn table4_miniapps(ctx: &SuiteContext) -> Result<String> {
    let count = ctx.app_count();
    // Paper's Table 4 platform rows (CPUs then GPUs; V100 not listed).
    let plats: Vec<Platform> = ["bdw", "skx", "clx", "naples", "tx2", "knl"]
        .iter()
        .map(|n| platforms::any_by_name(n))
        .chain(["k40c", "titanxp", "p100"].iter().map(|n| platforms::any_by_name(n)))
        .collect::<Result<Vec<_>>>()?;

    let mut csv = Csv::new(&[
        "platform",
        "app",
        "hmean_gbs",
        "stream_measured_gbs",
        "stream_anchor_gbs",
        "spatter_stream_ratio",
    ]);
    let mut table = Table::new(&[
        "Platform",
        "AMG",
        "Nekbone",
        "LULESH",
        "PENNANT",
        "STREAM (meas)",
        "STREAM (T3)",
        "spatter/stream",
    ]);
    // app -> (cpu column, gpu column) for the R-values.
    let mut cols: Vec<(String, Vec<f64>, Vec<f64>)> = table5::APPS
        .iter()
        .map(|a| (a.to_string(), Vec::new(), Vec::new()))
        .collect();
    let mut stream_cpu = Vec::new();
    let mut stream_gpu = Vec::new();

    for plat in &plats {
        let measured = super::baselines::measured_stream_gbs(plat, count)?;
        let mut row = vec![plat.name().to_string()];
        let mut app_hmeans = Vec::new();
        for (ai, app) in table5::APPS.iter().enumerate() {
            let pats = table5::by_app(app);
            let mut bws = Vec::new();
            for pat in pats {
                bws.push(pattern_bw(plat, pat, count)?);
            }
            let h = stats::harmonic_mean(&bws).unwrap_or(0.0);
            app_hmeans.push(h);
            row.push(format!("{h:.0}"));
            if plat.is_gpu() {
                cols[ai].2.push(h);
            } else {
                cols[ai].1.push(h);
            }
        }
        // Per-platform spatter-to-stream ratio: the harmonic mean over
        // the app columns against the *measured* STREAM figure.
        let spatter = stats::harmonic_mean(&app_hmeans).unwrap_or(0.0);
        let ratio = spatter / measured;
        for (app, &h) in table5::APPS.iter().zip(&app_hmeans) {
            csv.row_display(&[
                &plat.name(),
                app,
                &format!("{h:.1}"),
                &format!("{measured:.1}"),
                &format!("{:.1}", plat.stream_gbs()),
                &format!("{ratio:.3}"),
            ]);
        }
        row.push(format!("{measured:.0}"));
        row.push(format!("{:.0}", plat.stream_gbs()));
        row.push(format!("{ratio:.2}"));
        table.row(&row);
        if plat.is_gpu() {
            stream_gpu.push(measured);
        } else {
            stream_cpu.push(measured);
        }
    }

    // R-value rows, correlated against the measured STREAM column.
    let mut r_cpu = vec!["R (CPU)".to_string()];
    let mut r_gpu = vec!["R (GPU)".to_string()];
    for (_, cpu_col, gpu_col) in &cols {
        r_cpu.push(
            stats::pearson_r(cpu_col, &stream_cpu)
                .map(|r| format!("{r:.2}"))
                .unwrap_or_else(|| "-".into()),
        );
        r_gpu.push(
            stats::pearson_r(gpu_col, &stream_gpu)
                .map(|r| format!("{r:.2}"))
                .unwrap_or_else(|| "-".into()),
        );
    }
    for r in [&mut r_cpu, &mut r_gpu] {
        r.extend([String::new(), String::new(), String::new()]);
    }
    table.row(&r_cpu);
    table.row(&r_gpu);

    csv.write(&ctx.out_dir, "table4_miniapps.csv")?;
    Ok(format!(
        "== Table 4: mini-app pattern bandwidths (harmonic mean, GB/s) ==\n{}\
         STREAM (meas) is the Triad figure measured through the same \
         engines (--suite baselines); STREAM (T3) is the hardcoded \
         Table-3 anchor the engines are calibrated against — the two \
         agree to within a few percent, and the R rows correlate app \
         columns against the *measured* numbers.\n\
         Takeaway check: AMG/Nekbone exceed STREAM on CPUs (caching); \
         LULESH collapses except on TX2 (delta-0 scatter); CPU R-values \
         are weak, GPU R-values stronger.\n",
        table.render()
    ))
}

/// Figs 7/8 shared machinery: radar data for a set of patterns.
fn radar(
    ctx: &SuiteContext,
    kernel: Kernel,
    csv_name: &str,
    title: &str,
) -> Result<String> {
    let count = ctx.app_count();
    let plats = platforms::all();
    // Per-platform stride-1 reference.
    let mut refs = Vec::new();
    for p in &plats {
        refs.push(stride1_bw(p, count)?);
    }
    let pats: Vec<&table5::AppPattern> = table5::all()
        .into_iter()
        .filter(|p| p.kernel == kernel)
        .collect();
    let mut csv = Csv::new(&["pattern", "platform", "is_gpu", "relative_pct"]);
    let mut report = format!("== {title} ==\n");
    let mut above_cpu = 0usize;
    let mut above_gpu = 0usize;
    for pat in pats {
        let mut chart = RadarChart::new(pat.name);
        for (p, &s1) in plats.iter().zip(&refs) {
            let bw = pattern_bw(p, pat, count)?;
            chart.add(p.name(), p.is_gpu(), bw, s1);
            csv.row_display(&[
                &pat.name,
                &p.name(),
                &p.is_gpu(),
                &format!("{:.1}", bw / s1 * 100.0),
            ]);
        }
        for s in chart.above_ring() {
            if s.is_gpu {
                above_gpu += 1;
            } else {
                above_cpu += 1;
            }
        }
        report.push_str(&chart.render_text());
    }
    csv.write(&ctx.out_dir, csv_name)?;
    report.push_str(&format!(
        "Spokes above the 100% ring: {above_cpu} CPU vs {above_gpu} GPU \
         (paper: CPUs exploit caches; GPUs largely cannot).\n"
    ));
    Ok(report)
}

/// Fig 7: app-derived gather patterns, relative to stride-1.
pub fn fig7_radar(ctx: &SuiteContext) -> Result<String> {
    radar(
        ctx,
        Kernel::Gather,
        "fig7_radar_gather.csv",
        "Fig 7: gather patterns (relative to stride-1)",
    )
}

/// Fig 8: app-derived scatter patterns, relative to stride-1.
pub fn fig8_radar(ctx: &SuiteContext) -> Result<String> {
    radar(
        ctx,
        Kernel::Scatter,
        "fig8_radar_scatter.csv",
        "Fig 8: scatter patterns (relative to stride-1)",
    )
}

/// Fig 9: bandwidth-bandwidth plots — selected PENNANT gathers (a) and
/// LULESH scatters (b), with stride-1 and stride-16 references.
/// Skylake omitted as in the paper (overlaps CLX).
pub fn fig9_bwbw(ctx: &SuiteContext) -> Result<String> {
    let count = ctx.app_count();
    let plats: Vec<Platform> = ["bdw", "clx", "naples", "tx2", "knl", "k40c", "titanxp", "p100", "v100"]
        .iter()
        .map(|n| platforms::any_by_name(n))
        .collect::<Result<Vec<_>>>()?;
    let mut refs = Vec::new();
    for p in &plats {
        refs.push(stride1_bw(p, count)?);
    }

    let selections: &[(&str, &[&str])] = &[
        ("PENNANT gathers", &["PENNANT-G2", "PENNANT-G5", "PENNANT-G9", "PENNANT-G12"]),
        ("LULESH scatters", &["LULESH-S1", "LULESH-S3"]),
    ];
    let mut csv = Csv::new(&["pattern", "platform", "is_gpu", "stride1_gbs", "pattern_gbs", "fraction"]);
    let mut report = String::from("== Fig 9: bandwidth-bandwidth plots ==\n");
    let mut clx_vs_bdw: Vec<f64> = Vec::new();
    for (title, names) in selections {
        report.push_str(&format!("-- {title} --\n"));
        let mut table = Table::new(&["pattern", "platform", "stride-1 GB/s", "pattern GB/s", "fraction"]);
        for name in *names {
            let pat = table5::by_name(name).unwrap();
            let mut series = BwBwSeries::new(name);
            for (p, &s1) in plats.iter().zip(&refs) {
                let bw = pattern_bw(p, pat, count)?;
                series.add(p.name(), p.is_gpu(), s1, bw);
                csv.row_display(&[
                    &name,
                    &p.name(),
                    &p.is_gpu(),
                    &format!("{s1:.1}"),
                    &format!("{bw:.2}"),
                    &format!("{:.4}", bw / s1),
                ]);
                table.row(&[
                    name.to_string(),
                    p.name().to_string(),
                    format!("{s1:.0}"),
                    format!("{bw:.1}"),
                    format!("{:.3}", bw / s1),
                ]);
            }
            if let Some(slope) = series.relative_slope("clx", "bdw") {
                clx_vs_bdw.push(slope);
            }
        }
        report.push_str(&table.render());
    }
    csv.write(&ctx.out_dir, "fig9_bwbw.csv")?;
    let improving = clx_vs_bdw.iter().filter(|&&s| s > 1.0).count();
    report.push_str(&format!(
        "CLX beats BDW in *relative* bandwidth on {improving}/{} selected \
         patterns (paper Fig 9a item 1).\n",
        clx_vs_bdw.len()
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx(tag: &str) -> SuiteContext {
        SuiteContext::fast(&Path::new("/tmp").join(format!("spatter-apps-{tag}")))
    }

    #[test]
    fn table1_runs() {
        let c = ctx("t1");
        let r = table1_characterization(&c).unwrap();
        assert!(r.contains("hypre_CSRMatrixMatvecOutOfPlace"));
        assert!(r.contains("ax_e"));
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn fig9_runs() {
        let c = ctx("f9");
        let r = fig9_bwbw(&c).unwrap();
        assert!(r.contains("PENNANT-G12"));
        assert!(c.out_dir.join("fig9_bwbw.csv").exists());
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn table4_reports_measured_stream_and_ratio() {
        let c = ctx("t4");
        let r = table4_miniapps(&c).unwrap();
        assert!(r.contains("STREAM (meas)"), "{r}");
        assert!(r.contains("spatter/stream"), "{r}");
        assert!(r.contains("measured through the same"), "{r}");
        // The CSV carries measured, anchor, and ratio columns.
        let csv =
            std::fs::read_to_string(c.out_dir.join("table4_miniapps.csv"))
                .unwrap();
        let header = csv.lines().next().unwrap();
        assert_eq!(
            header,
            "platform,app,hmean_gbs,stream_measured_gbs,stream_anchor_gbs,\
             spatter_stream_ratio"
        );
        // Measured STREAM tracks the anchor on a spot-checked row.
        let skx_row = csv
            .lines()
            .find(|l| l.starts_with("skx,AMG"))
            .expect("skx AMG row");
        let cells: Vec<&str> = skx_row.split(',').collect();
        let measured: f64 = cells[3].parse().unwrap();
        let anchor: f64 = cells[4].parse().unwrap();
        assert!(
            (measured / anchor - 1.0).abs() < 0.25,
            "measured {measured} vs anchor {anchor}"
        );
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn app_pattern_count_never_shrinks_footprint() {
        assert_eq!(app_pattern_count(1, 1 << 18), 1 << 18);
        // Large deltas must NOT shrink the count: the touched-line
        // footprint has to stay bigger than the caches.
        assert_eq!(app_pattern_count(1_882_384, 1 << 18), 1 << 18);
    }
}
