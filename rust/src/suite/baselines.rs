//! `--suite baselines`: the classical dense/random baselines measured
//! *in-engine* — the STREAM tetrad (Copy/Scale/Add/Triad) and GUPS on
//! every CPU and GPU platform, executed as a `RunConfig` queue through
//! the `--jobs` worker pool (output is byte-identical for any jobs
//! value).
//!
//! The paper's headline comparison (§5.4, Fig 9) positions Spatter's
//! indexed kernels *against* STREAM; before this suite the STREAM side
//! of that comparison was the hardcoded Table-3 anchor. Measuring the
//! tetrad through the same engines closes the loop: `table4` reports
//! the measured number next to the anchor, and the correlation study
//! runs on measured data.

use super::SuiteContext;
use crate::backends::{Backend, CudaSim, OpenMpSim};
use crate::coordinator::{render_table, run_configs_jobs, RunConfig};
use crate::error::Result;
use crate::json::{self, Value};
use crate::pattern::{Kernel, Pattern, StreamOp, GUPS_DEFAULT_TABLE_ELEMS};
use crate::platforms::{self, Platform};
use crate::report::Csv;

/// The baseline family in report order: the STREAM tetrad, then GUPS.
pub const BASELINE_KERNELS: &[Kernel] = &[
    Kernel::Stream(StreamOp::Copy),
    Kernel::Stream(StreamOp::Scale),
    Kernel::Stream(StreamOp::Add),
    Kernel::Stream(StreamOp::Triad),
    Kernel::Gups,
];

/// Stream width and iteration count for one platform, from a raw
/// suite count: 8-wide CPU iterations, 256-wide GPU thread blocks
/// (the uniform-stride conventions), with counts floored by STREAM's
/// sizing rule — the working set must be several times the largest
/// modelled cache, or the warm-start protocol (min-of-10 semantics)
/// would measure cache residency instead of DRAM. The floors keep the
/// measured window disjoint from the warm-up tail on every platform;
/// the simulation cost is capped by `max_sim_accesses` regardless.
/// Shared by the suite's run queue and [`measured_stream_gbs`], so
/// table4's measured column always mirrors the suite's sizing.
fn stream_shape(plat: &Platform, count: usize) -> (usize, usize) {
    if plat.is_gpu() {
        (256, (count / 32).max(1 << 15))
    } else {
        (8, count.max(1 << 21))
    }
}

/// The suite's run queue for one platform.
fn baseline_configs(plat: &Platform, ctx: &SuiteContext) -> Vec<RunConfig> {
    let (width, count) = stream_shape(plat, ctx.ustride_count());
    BASELINE_KERNELS
        .iter()
        .map(|&kernel| {
            let pattern = match kernel {
                Kernel::Gups => Pattern::gups(GUPS_DEFAULT_TABLE_ELEMS, count),
                _ => Pattern::dense(width, count),
            };
            RunConfig {
                name: format!("{}/{}", plat.name(), kernel.name()),
                kernel,
                pattern,
                page_size: None,
                threads: None,
                regime: None,
                placement: None,
            }
        })
        .collect()
}

/// Measured in-engine STREAM bandwidth of one platform: the Triad
/// figure, matching the convention of the Table-3 STREAM/BabelStream
/// anchors. `table4` reports this next to the anchor and computes its
/// correlation study from it. Sizing comes from [`stream_shape`], so
/// small suite counts can't turn the measurement into a
/// cache-residency test.
pub fn measured_stream_gbs(plat: &Platform, count: usize) -> Result<f64> {
    let kernel = Kernel::Stream(StreamOp::Triad);
    let (width, count) = stream_shape(plat, count);
    let pattern = Pattern::dense(width, count);
    Ok(match plat {
        Platform::Cpu(c) => {
            OpenMpSim::new(c).run(&pattern, kernel)?.bandwidth_gbs()
        }
        Platform::Gpu(g) => {
            CudaSim::new(g).run(&pattern, kernel)?.bandwidth_gbs()
        }
    })
}

/// `--suite baselines`: run the tetrad + GUPS on all ten platforms and
/// emit `baselines.csv` / `baselines.json`.
pub fn baselines_suite(ctx: &SuiteContext) -> Result<String> {
    let mut csv = Csv::new(&[
        "platform", "kernel", "gbs", "anchor_stream_gbs", "bottleneck",
    ]);
    let mut report = String::from(
        "== baselines: dense STREAM tetrad + GUPS (measured in-engine) ==\n",
    );
    let mut json_platforms: Vec<(String, Value)> = Vec::new();
    for plat in platforms::all() {
        let configs = baseline_configs(&plat, ctx);
        let factory = || -> Result<Box<dyn Backend>> {
            Ok(match &plat {
                Platform::Cpu(c) => Box::new(OpenMpSim::new(c)),
                Platform::Gpu(g) => Box::new(CudaSim::new(g)),
            })
        };
        let records = run_configs_jobs(&factory, &configs, ctx.jobs)?;
        for (c, r) in configs.iter().zip(&records) {
            csv.row_display(&[
                &plat.name(),
                &c.kernel.name(),
                &format!("{:.3}", r.bandwidth_gbs),
                &format!("{:.3}", plat.stream_gbs()),
                &r.bottleneck,
            ]);
        }
        report.push_str(&format!(
            "-- {} (Table-3 STREAM anchor {:.1} GB/s) --\n{}",
            plat.name(),
            plat.stream_gbs(),
            render_table(&records)
        ));
        json_platforms.push((
            plat.name().to_string(),
            Value::Array(records.iter().map(|r| r.to_json()).collect()),
        ));
    }
    csv.write(&ctx.out_dir, "baselines.csv")?;
    let doc = Value::Object(json_platforms.into_iter().collect());
    let mut text = json::to_string_pretty(&doc);
    text.push('\n');
    std::fs::write(ctx.out_dir.join("baselines.json"), text)?;
    report.push_str(
        "Takeaway check: Copy/Scale/Add/Triad all land near the Table-3 \
         STREAM anchor on every platform (dense streams are DRAM-bound, \
         prefetch-covered, and NT-stored); GUPS collapses one to two \
         orders below it (random 64-bit RMW: the TLB + DRAM-row worst \
         case the uniform-stride sweeps never reach).\n",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx(tag: &str) -> SuiteContext {
        SuiteContext::fast(
            &Path::new("/tmp").join(format!("spatter-baselines-{tag}")),
        )
    }

    #[test]
    fn suite_runs_and_emits_csv_and_json() {
        let c = ctx("run");
        let report = baselines_suite(&c).unwrap();
        assert!(report.contains("STREAM tetrad"), "{report}");
        assert!(report.contains("skx/Triad"), "{report}");
        assert!(report.contains("v100/GUPS"), "{report}");
        assert!(c.out_dir.join("baselines.csv").exists());
        let j =
            std::fs::read_to_string(c.out_dir.join("baselines.json")).unwrap();
        let doc = json::parse(&j).unwrap();
        for plat in ["skx", "bdw", "knl", "p100", "v100"] {
            let runs = doc.get(plat).unwrap().as_array().unwrap();
            assert_eq!(runs.len(), BASELINE_KERNELS.len(), "{plat}");
        }
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn jobs_invariant_output() {
        let c1 = ctx("j1").with_jobs(1);
        let c4 = ctx("j4").with_jobs(4);
        let r1 = baselines_suite(&c1).unwrap();
        let r4 = baselines_suite(&c4).unwrap();
        assert_eq!(r1, r4, "report must not depend on --jobs");
        let f = |c: &SuiteContext, n: &str| {
            std::fs::read_to_string(c.out_dir.join(n)).unwrap()
        };
        assert_eq!(f(&c1, "baselines.csv"), f(&c4, "baselines.csv"));
        assert_eq!(f(&c1, "baselines.json"), f(&c4, "baselines.json"));
        std::fs::remove_dir_all(&c1.out_dir).ok();
        std::fs::remove_dir_all(&c4.out_dir).ok();
    }

    #[test]
    fn measured_stream_tracks_the_anchor() {
        // The whole point: the measured tetrad reproduces the Table-3
        // calibration on both engine kinds.
        for name in ["skx", "tx2", "p100"] {
            let plat = platforms::any_by_name(name).unwrap();
            let m = measured_stream_gbs(&plat, 1 << 16).unwrap();
            assert!(
                (m / plat.stream_gbs() - 1.0).abs() < 0.25,
                "{name}: measured {m:.1} vs anchor {:.1}",
                plat.stream_gbs()
            );
        }
    }
}
