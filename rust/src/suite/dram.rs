//! `--suite dram` — the banked-DRAM bank-conflict study.
//!
//! The engines model DDR-style banked DRAM (`sim::dram`): every access
//! resolves to a bank via the platform's address-interleave policy, and
//! a row activation that lands in the same channel×bank-group as the
//! immediately previous activation serializes behind it (a *conflict*,
//! tRC-limited) instead of pipelining (a *miss*). This suite drives the
//! mechanism end to end, per CPU platform and per interleave policy:
//!
//! * `g` — row-grain uniform-stride gathers in matched pairs: a
//!   power-of-two row stride and its odd partner (stride+1). Every
//!   access opens a fresh row, so the pair isolates *where* the rows
//!   land: a pow2 row stride whose bank-slot advance collapses onto one
//!   channel×bank-group conflicts on every access, while the odd
//!   partner rotates across channels and almost never conflicts.
//! * `gups` — the random-update worst case, where conflicts are a
//!   domain-count lottery rather than a stride resonance.
//!
//! The report states, per platform and policy, the **bank-conflict
//! knee**: the smallest power-of-two row stride whose conflict fraction
//! crosses [`KNEE_RATE`] while its odd partner stays below. Parts with
//! a power-of-two total bank count (KNL/BDW/TX2/Naples, 64 banks) knee
//! once the slot advance clears the channel and bank-group rotation;
//! six-channel parts (SKX/CLX, 96 banks) never alias a pow2 stride —
//! `2^k mod 6 != 0` — and legitimately report no knee. Prefetchers are
//! disabled for the sweep so the activation chain is exactly the
//! pattern's own accesses. Results go to `dram.csv` / `dram.json`;
//! everything runs through the `--jobs` pool and is byte-identical for
//! any worker count.

use super::SuiteContext;
use crate::backends::{Backend, OpenMpSim};
use crate::coordinator::{run_configs_jobs, RunConfig, RunRecord};
use crate::error::Result;
use crate::json::{self, obj, Value};
use crate::pattern::{Kernel, Pattern};
use crate::platforms::{self, CpuPlatform};
use crate::report::{Csv, Table};
use crate::sim::InterleavePolicy;

/// Every simulated CPU platform (the GPU parts share the same DRAM
/// model; the CPU set already spans both bank-count classes).
const PLATFORMS: &[&str] = &["knl", "bdw", "skx", "clx", "tx2", "naples"];

/// Elements per DRAM row in the CPU engine (row bytes / 8-byte
/// elements; the engine's row is `ROW_LINES * LINE` = 2048 bytes).
const ROW_ELEMS: usize = 256;

/// The power-of-two row strides swept; each runs next to its odd
/// partner (`stride + 1`).
const ROW_STRIDES_POW2: &[usize] = &[2, 4, 8, 16, 32, 64, 128];

/// Conflict fraction (conflicts / activations) at which a stride
/// counts as bank-aliased. Aliased pow2 strides sit near 1.0 and
/// rotating odd strides near 0.0, so the threshold's exact value is
/// uncritical anywhere in between.
const KNEE_RATE: f64 = 0.25;

/// The odd partner of a power-of-two row stride.
fn odd_partner(rows: usize) -> usize {
    rows + 1
}

/// Short column/CSV tag for an interleave policy.
fn tag(pol: InterleavePolicy) -> &'static str {
    match pol {
        InterleavePolicy::RowBankChannel => "rbc",
        InterleavePolicy::RowChannelBank => "rcb",
    }
}

/// The platform with its DRAM address-interleave policy replaced.
fn with_policy(p: &CpuPlatform, pol: InterleavePolicy) -> CpuPlatform {
    let mut q = p.clone();
    q.dram.interleave = pol;
    q
}

/// A gather whose every access lands `rows` DRAM rows past the
/// previous one — within the vector and across the iteration boundary
/// alike — so each access opens a fresh row and the activation
/// sequence is a pure row-stride ladder.
fn row_stride_gather(rows: usize, count: usize) -> Pattern {
    let stride = rows * ROW_ELEMS;
    Pattern::parse(&format!("UNIFORM:8:{stride}"))
        .unwrap()
        .with_delta(8 * stride as i64)
        .with_count(count)
        .with_name(&format!("UNIFORM:8:{stride}"))
}

/// Iteration count for the sweep: the row-grain ladder touches DRAM on
/// every access, so it needs fewer iterations than the cache-assisted
/// uniform-stride studies for the same DRAM-event population.
fn dram_count(ctx: &SuiteContext) -> usize {
    ctx.ustride_count() >> 2
}

/// The run queue for one platform at one interleave policy: pow2/odd
/// stride pairs in `ROW_STRIDES_POW2` order, then one GUPS run —
/// record `2*si` is the pow2 gather, `2*si + 1` its odd partner, and
/// the last record is GUPS.
fn configs_for(
    name: &str,
    pol: InterleavePolicy,
    count: usize,
) -> Vec<RunConfig> {
    let mut configs = Vec::new();
    for &rows in ROW_STRIDES_POW2 {
        for r in [rows, odd_partner(rows)] {
            configs.push(RunConfig {
                name: format!("{name}/{}/g/r{r}", tag(pol)),
                kernel: Kernel::Gather,
                pattern: row_stride_gather(r, count),
                page_size: None,
                threads: None,
                regime: None,
                placement: None,
            });
        }
    }
    configs.push(RunConfig {
        name: format!("{name}/{}/gups", tag(pol)),
        kernel: Kernel::Gups,
        pattern: Pattern::gups(1 << 21, (count >> 4).max(256)),
        page_size: None,
        threads: None,
        regime: None,
        placement: None,
    });
    configs
}

/// Conflicts per row activation (0 when the run never activated a
/// row).
fn conflict_rate(r: &RunRecord) -> f64 {
    let acts = r.dram_row_misses + r.dram_row_conflicts;
    if acts == 0 {
        0.0
    } else {
        r.dram_row_conflicts as f64 / acts as f64
    }
}

/// Smallest pow2 row stride whose conflict fraction crosses
/// [`KNEE_RATE`] while its odd partner stays below — `None` when no
/// stride aliases (the six-channel parts).
fn conflict_knee(records: &[RunRecord]) -> Option<usize> {
    ROW_STRIDES_POW2
        .iter()
        .enumerate()
        .find(|&(si, _)| {
            conflict_rate(&records[2 * si]) >= KNEE_RATE
                && conflict_rate(&records[2 * si + 1]) < KNEE_RATE
        })
        .map(|(_, &rows)| rows)
}

pub fn dram_suite(ctx: &SuiteContext) -> Result<String> {
    let count = dram_count(ctx);
    let mut csv = Csv::new(&[
        "platform", "policy", "workload", "row_stride", "gbs", "row_hits",
        "row_misses", "row_conflicts", "conflict_rate",
    ]);
    let mut report = String::from(
        "== dram: banked-DRAM bank-conflict sweep (pow2 vs odd row \
         strides + GUPS) ==\n",
    );
    let mut json_platforms: Vec<(String, Value)> = Vec::new();
    for &name in PLATFORMS {
        let platform = platforms::by_name(name)?;
        // One pool dispatch per policy (each needs its own engine
        // configuration); record order is deterministic, so the report
        // is byte-identical for any --jobs value.
        let mut per_policy: Vec<(InterleavePolicy, Vec<RunRecord>)> =
            Vec::new();
        for &pol in InterleavePolicy::ALL {
            let plat = with_policy(&platform, pol);
            let factory = || -> Result<Box<dyn Backend>> {
                Ok(Box::new(OpenMpSim::without_prefetch(&plat)))
            };
            let configs = configs_for(name, pol, count);
            let records = run_configs_jobs(&factory, &configs, ctx.jobs)?;
            for (ri, r) in records.iter().enumerate() {
                let (workload, rows) = if ri + 1 == records.len() {
                    ("gups".to_string(), "-".to_string())
                } else {
                    let base = ROW_STRIDES_POW2[ri / 2];
                    let rows = if ri % 2 == 0 {
                        base
                    } else {
                        odd_partner(base)
                    };
                    let wl = if ri % 2 == 0 { "g-pow2" } else { "g-odd" };
                    (wl.to_string(), rows.to_string())
                };
                csv.row_display(&[
                    &name,
                    &tag(pol),
                    &workload,
                    &rows,
                    &format!("{:.3}", r.bandwidth_gbs),
                    &r.dram_row_hits,
                    &r.dram_row_misses,
                    &r.dram_row_conflicts,
                    &format!("{:.4}", conflict_rate(r)),
                ]);
            }
            per_policy.push((pol, records));
        }

        // Table: one row per stride pair, conflict fractions per
        // policy plus the pow2 bandwidth under the default policy.
        let header: Vec<String> = std::iter::once("rows".to_string())
            .chain(per_policy.iter().flat_map(|(pol, _)| {
                [format!("{} p2", tag(*pol)), format!("{} odd", tag(*pol))]
            }))
            .chain(std::iter::once("rbc p2 GB/s".to_string()))
            .collect();
        let header_refs: Vec<&str> =
            header.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&header_refs);
        for (si, &rows) in ROW_STRIDES_POW2.iter().enumerate() {
            let mut row = vec![rows.to_string()];
            for (_, records) in &per_policy {
                row.push(format!(
                    "{:.2}",
                    conflict_rate(&records[2 * si])
                ));
                row.push(format!(
                    "{:.2}",
                    conflict_rate(&records[2 * si + 1])
                ));
            }
            row.push(format!(
                "{:.2}",
                per_policy[0].1[2 * si].bandwidth_gbs
            ));
            table.row(&row);
        }

        let knee_text: Vec<String> = per_policy
            .iter()
            .map(|(pol, records)| match conflict_knee(records) {
                Some(rows) => format!(
                    "{}: row-stride {rows} ({} KiB)",
                    pol.name(),
                    rows * ROW_ELEMS * 8 / 1024
                ),
                None => format!("{}: none", pol.name()),
            })
            .collect();
        let gups_text: Vec<String> = per_policy
            .iter()
            .map(|(pol, records)| {
                format!(
                    "{} {:.3}",
                    tag(*pol),
                    conflict_rate(records.last().unwrap())
                )
            })
            .collect();
        report.push_str(&format!(
            "-- {name} ({} banks) --\n{}bank-conflict knee: {}; gups \
             conflict rate: {}\n",
            platform.dram.total_banks(),
            table.render(),
            knee_text.join(", "),
            gups_text.join(", ")
        ));

        json_platforms.push((
            name.to_string(),
            obj(&per_policy
                .iter()
                .map(|(pol, records)| {
                    (
                        pol.name(),
                        obj(&[
                            (
                                "knee",
                                match conflict_knee(records) {
                                    Some(rows) => Value::from(rows),
                                    None => Value::Null,
                                },
                            ),
                            (
                                "gups_conflict_rate",
                                Value::from(conflict_rate(
                                    records.last().unwrap(),
                                )),
                            ),
                            (
                                "runs",
                                Value::Array(
                                    records
                                        .iter()
                                        .map(|r| r.to_json())
                                        .collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect::<Vec<_>>()),
        ));
    }
    csv.write(&ctx.out_dir, "dram.csv")?;
    let doc = Value::Object(json_platforms.into_iter().collect());
    let mut text = json::to_string_pretty(&doc);
    text.push('\n');
    std::fs::write(ctx.out_dir.join("dram.json"), text)?;
    report.push_str(
        "Takeaway check: a power-of-two row stride whose bank-slot \
         advance collapses onto one channel×bank-group re-opens the \
         same bank every access and conflicts on nearly all of them, \
         while its odd partner walks the channels and stays \
         conflict-free — so the 64-bank parts knee at the stride that \
         clears their channel and bank-group rotation, and the \
         six-channel parts (96 banks) never alias a pow2 stride at \
         all. Under row:channel:bank interleave adjacent rows share a \
         channel, so conflicts arrive at far smaller strides — the \
         policy, not the pattern, sets the knee.\n",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx(tag: &str) -> SuiteContext {
        SuiteContext::fast(
            &Path::new("/tmp").join(format!("spatter-dram-{tag}")),
        )
    }

    #[test]
    fn row_stride_ladder_shape() {
        let p = row_stride_gather(16, 1024);
        assert_eq!(p.vector_len(), 8);
        // 16 rows x 256 elements: consecutive accesses are 16 rows
        // apart, and the delta continues the ladder across iterations.
        assert_eq!(p.indices[1] - p.indices[0], 16 * 256);
        assert_eq!(p.delta, 8 * 16 * 256);
        assert_eq!(odd_partner(16), 17);
    }

    #[test]
    fn pow2_aliases_and_odd_rotates_on_64_bank_parts() {
        // KNL has 64 banks (8ch x 2bg x 4bk): a 16-row stride clears
        // both the channel rotation (16 % 8 == 0) and the bank-group
        // rotation, re-opening the same bank every access; 17 rows
        // walks the channels and never conflicts.
        let knl = platforms::by_name("knl").unwrap();
        let count = 1 << 12;
        let run = |rows: usize| {
            OpenMpSim::without_prefetch(&knl)
                .run(&row_stride_gather(rows, count), Kernel::Gather)
                .unwrap()
        };
        let aliased = run(16);
        let rotated = run(17);
        let rate = |c: &crate::sim::SimCounters| {
            let acts = c.dram_row_misses + c.dram_row_conflicts;
            c.dram_row_conflicts as f64 / acts.max(1) as f64
        };
        assert!(
            rate(&aliased.counters) > 0.9,
            "pow2 stride must conflict: {:?}",
            aliased.counters
        );
        assert!(
            rate(&rotated.counters) < 0.05,
            "odd stride must rotate: {:?}",
            rotated.counters
        );
        // The serialization penalty is visible end to end: the
        // aliased run is slower than its odd partner.
        assert!(
            aliased.bandwidth_gbs() < rotated.bandwidth_gbs(),
            "aliased {:.2} vs rotated {:.2}",
            aliased.bandwidth_gbs(),
            rotated.bandwidth_gbs()
        );
    }

    #[test]
    fn six_channel_parts_never_alias_pow2_strides() {
        // 2^k mod 6 != 0: on SKX every pow2 row stride keeps rotating
        // channels, so no stride in the sweep aliases.
        let skx = platforms::by_name("skx").unwrap();
        let count = 1 << 12;
        for &rows in ROW_STRIDES_POW2 {
            let r = OpenMpSim::without_prefetch(&skx)
                .run(&row_stride_gather(rows, count), Kernel::Gather)
                .unwrap();
            let acts = r.counters.dram_row_misses
                + r.counters.dram_row_conflicts;
            let rate =
                r.counters.dram_row_conflicts as f64 / acts.max(1) as f64;
            assert!(rate < KNEE_RATE, "rows={rows} rate={rate}");
        }
    }

    #[test]
    fn report_csv_json_written_and_knees_reported() {
        let c = ctx("run");
        let report = dram_suite(&c).unwrap();
        assert!(report.contains("bank-conflict knee"), "{report}");
        // 64-bank parts knee at 16 rows under the default interleave;
        // six-channel parts report none.
        assert!(
            report.contains("-- knl (64 banks) --"),
            "{report}"
        );
        assert!(c.out_dir.join("dram.csv").exists());
        let j =
            std::fs::read_to_string(c.out_dir.join("dram.json")).unwrap();
        let doc = json::parse(&j).unwrap();
        let knee = |plat: &str| {
            doc.get(plat)
                .unwrap()
                .get("row:bank:channel")
                .unwrap()
                .get("knee")
                .unwrap()
                .clone()
        };
        for plat in ["knl", "bdw", "tx2", "naples"] {
            assert_eq!(
                knee(plat).as_usize().unwrap(),
                16,
                "{plat} must knee at 16 rows"
            );
        }
        for plat in ["skx", "clx"] {
            assert_eq!(knee(plat), Value::Null, "{plat} must not knee");
        }
        // Every run record carries the dram counters in its JSON.
        let runs = doc
            .get("knl")
            .unwrap()
            .get("row:bank:channel")
            .unwrap()
            .get("runs")
            .unwrap()
            .as_array()
            .unwrap();
        assert!(!runs.is_empty());
        assert!(runs[0].get("dram").unwrap().get_opt("row_conflicts").is_some());
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn jobs_invariant_output() {
        let c1 = ctx("j1").with_jobs(1);
        let c4 = ctx("j4").with_jobs(4);
        let r1 = dram_suite(&c1).unwrap();
        let r4 = dram_suite(&c4).unwrap();
        assert_eq!(r1, r4, "report must not depend on --jobs");
        let f = |c: &SuiteContext, n: &str| {
            std::fs::read_to_string(c.out_dir.join(n)).unwrap()
        };
        assert_eq!(f(&c1, "dram.csv"), f(&c4, "dram.csv"));
        assert_eq!(f(&c1, "dram.json"), f(&c4, "dram.json"));
        std::fs::remove_dir_all(&c1.out_dir).ok();
        std::fs::remove_dir_all(&c4.out_dir).ok();
    }
}
