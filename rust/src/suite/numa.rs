//! `--suite numa` — the NUMA remote-access bandwidth-cliff study.
//!
//! The engines model a multi-socket topology (`sim::topology`): every
//! DRAM-reaching access resolves to a home node under the configured
//! page-placement policy, and remote accesses pay the interconnect
//! link's latency plus a bandwidth penalty in equivalent bytes. This
//! suite drives the mechanism end to end on every two-socket platform:
//!
//! * **ratio sweep** — an engineered 16-lane pattern under `interleave`
//!   placement whose lanes split between an even page (node 0, local)
//!   and the adjacent odd page (node 1, remote). Sweeping the remote
//!   lane count through 0, 4, 8, 12, 16 dials the remote fraction
//!   through 0..1 in quarters; the per-iteration delta advances two
//!   whole pages, so the split is exact on every iteration. Runs cover
//!   Gather, Scatter, and GS.
//! * **placement A/B** — GUPS over a table far larger than any L3,
//!   run under both `first-touch` (one thread faults every page: the
//!   whole table lands on node 0 and every socket hammers it) and
//!   `interleave` (pages rotate across nodes and the sockets' memory
//!   controllers share the load).
//!
//! The report states, per platform, the **remote-access bandwidth
//! cliff**: the all-local to all-remote bandwidth ratio per kernel,
//! plus the GUPS placement split. Prefetchers are disabled so the
//! node-classified stream is exactly the pattern's own accesses.
//! Results go to `numa.csv` / `numa.json`; everything runs through the
//! `--jobs` pool and is byte-identical for any worker count.

use super::SuiteContext;
use crate::backends::{Backend, OpenMpSim};
use crate::coordinator::{run_configs_jobs, RunConfig, RunRecord};
use crate::error::Result;
use crate::json::{self, obj, Value};
use crate::pattern::{Kernel, Pattern};
use crate::platforms;
use crate::report::{Csv, Table};
use crate::sim::NumaPlacement;

/// Every two-socket platform (`platforms::multi_socket_cpus`).
const PLATFORMS: &[&str] = &["skx-2s", "tx2-2s", "naples-2s"];

/// Lanes in the engineered ratio pattern.
const LANES: usize = 16;

/// Remote lane counts swept (remote fraction 0, 1/4, 1/2, 3/4, 1).
pub const REMOTE_LANES: &[usize] = &[0, 4, 8, 12, 16];

/// Elements per 4 KiB translation page (the placement grain).
const PAGE_ELEMS: usize = 512;

/// Per-iteration advance: two whole pages, so every lane keeps its
/// page parity — and therefore its interleave home node — across the
/// entire run.
const DELTA_ELEMS: i64 = 2 * PAGE_ELEMS as i64;

/// The kernels of the ratio sweep, in sweep order.
const SWEEP_KERNELS: &[Kernel] = &[Kernel::Gather, Kernel::Scatter, Kernel::GS];

/// GUPS table for the placement A/B: 128 MiB of doubles, far past
/// every platform's L3, so the updates are DRAM traffic throughout.
const GUPS_TABLE_ELEMS: usize = 1 << 24;

/// The engineered ratio pattern: `LANES - remote` lanes on the even
/// page (interleave home node 0: local) and `remote` lanes on the odd
/// page (node 1: remote). Lanes sit a cache line apart, so each is one
/// distinct DRAM-classified access per iteration.
pub fn ratio_pattern(remote: usize, count: usize) -> Pattern {
    assert!(remote <= LANES, "at most {LANES} remote lanes");
    let local = LANES - remote;
    let idx: Vec<i64> = (0..local)
        .map(|j| (j * 8) as i64)
        .chain((0..remote).map(|j| (PAGE_ELEMS + j * 8) as i64))
        .collect();
    Pattern::from_indices(&format!("NUMA:{LANES}:r{remote}"), idx)
        .with_delta(DELTA_ELEMS)
        .with_count(count)
}

/// The GS variant: the same lane split on both sides. The scatter
/// region starts at the next 1 GiB boundary — an even page — so the
/// write side's page parity (and remote fraction) matches the read
/// side's.
fn ratio_gs(remote: usize, count: usize) -> Pattern {
    let p = ratio_pattern(remote, count);
    let side = p.indices.clone();
    p.with_gs_scatter(side)
}

/// Iteration count for the sweep: like the dram suite, every access is
/// a fresh line, so fewer iterations than the cache-assisted studies
/// produce the same DRAM-event population.
fn numa_count(ctx: &SuiteContext) -> usize {
    ctx.ustride_count() >> 2
}

fn remote_frac(remote: usize) -> f64 {
    remote as f64 / LANES as f64
}

/// Local fraction of the node-classified traffic (1.0 when the run
/// produced none).
fn local_frac(r: &RunRecord) -> f64 {
    let total = r.numa_local + r.numa_remote;
    if total == 0 {
        1.0
    } else {
        r.numa_local as f64 / total as f64
    }
}

/// The run queue for one platform: for each kernel of the ratio sweep
/// the five remote-lane counts under interleave placement, then the
/// GUPS placement A/B — record `ki * 5 + ri` is kernel `ki` at
/// `REMOTE_LANES[ri]`, and the last two records are GUPS under
/// first-touch and interleave.
fn configs_for(name: &str, count: usize) -> Vec<RunConfig> {
    let mut configs = Vec::new();
    for &kernel in SWEEP_KERNELS {
        for &k in REMOTE_LANES {
            let pattern = match kernel {
                Kernel::GS => ratio_gs(k, count),
                _ => ratio_pattern(k, count),
            };
            configs.push(RunConfig {
                name: format!("{name}/il/{}/r{k}", kernel.name()),
                kernel,
                pattern,
                page_size: None,
                threads: None,
                regime: None,
                placement: Some(NumaPlacement::Interleave),
            });
        }
    }
    for placement in [NumaPlacement::FirstTouch, NumaPlacement::Interleave] {
        configs.push(RunConfig {
            name: format!("{name}/{}/gups", placement.name()),
            kernel: Kernel::Gups,
            pattern: Pattern::gups(GUPS_TABLE_ELEMS, (count >> 4).max(256)),
            page_size: None,
            threads: None,
            regime: None,
            placement: Some(placement),
        });
    }
    configs
}

pub fn numa_suite(ctx: &SuiteContext) -> Result<String> {
    let count = numa_count(ctx);
    let nr = REMOTE_LANES.len();
    let mut csv = Csv::new(&[
        "platform", "kernel", "placement", "remote_frac", "gbs",
        "numa_local", "numa_remote", "local_frac",
    ]);
    let mut report = String::from(
        "== numa: remote-access bandwidth cliff (local:remote ratio \
         sweep + GUPS placement A/B) ==\n",
    );
    let mut json_platforms: Vec<(String, Value)> = Vec::new();
    for &name in PLATFORMS {
        let platform = platforms::by_name(name)?;
        let factory = || -> Result<Box<dyn Backend>> {
            Ok(Box::new(OpenMpSim::without_prefetch(&platform)))
        };
        let configs = configs_for(name, count);
        let records = run_configs_jobs(&factory, &configs, ctx.jobs)?;

        for (ri, r) in records.iter().enumerate() {
            let (kernel, placement, frac) = if ri < SWEEP_KERNELS.len() * nr {
                (
                    SWEEP_KERNELS[ri / nr].name(),
                    NumaPlacement::Interleave.name(),
                    format!("{:.2}", remote_frac(REMOTE_LANES[ri % nr])),
                )
            } else {
                let placement = if ri == SWEEP_KERNELS.len() * nr {
                    NumaPlacement::FirstTouch
                } else {
                    NumaPlacement::Interleave
                };
                ("GUPS", placement.name(), "-".to_string())
            };
            csv.row_display(&[
                &name,
                &kernel,
                &placement,
                &frac,
                &format!("{:.3}", r.bandwidth_gbs),
                &r.numa_local,
                &r.numa_remote,
                &format!("{:.4}", local_frac(r)),
            ]);
        }

        // Table: one row per remote fraction, bandwidth per kernel
        // plus the gather run's measured local fraction.
        let header: Vec<String> = std::iter::once("remote".to_string())
            .chain(
                SWEEP_KERNELS
                    .iter()
                    .map(|k| format!("{} GB/s", k.name())),
            )
            .chain(std::iter::once("gather loc%".to_string()))
            .collect();
        let header_refs: Vec<&str> =
            header.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&header_refs);
        for (ri, &k) in REMOTE_LANES.iter().enumerate() {
            let mut row = vec![format!("{:.2}", remote_frac(k))];
            for ki in 0..SWEEP_KERNELS.len() {
                row.push(format!(
                    "{:.2}",
                    records[ki * nr + ri].bandwidth_gbs
                ));
            }
            row.push(format!("{:.1}", local_frac(&records[ri]) * 100.0));
            table.row(&row);
        }

        // All-local over all-remote bandwidth, per kernel.
        let cliff = |ki: usize| {
            records[ki * nr].bandwidth_gbs
                / records[ki * nr + nr - 1].bandwidth_gbs
        };
        let cliff_text: Vec<String> = SWEEP_KERNELS
            .iter()
            .enumerate()
            .map(|(ki, k)| format!("{} {:.2}x", k.name(), cliff(ki)))
            .collect();
        let gups_ft = &records[SWEEP_KERNELS.len() * nr];
        let gups_il = &records[SWEEP_KERNELS.len() * nr + 1];
        report.push_str(&format!(
            "-- {name} ({} sockets) --\n{}remote-access bandwidth \
             cliff: {}; gups: first-touch {:.3} vs interleave {:.3} \
             GB/s\n",
            platform.numa.sockets,
            table.render(),
            cliff_text.join(", "),
            gups_ft.bandwidth_gbs,
            gups_il.bandwidth_gbs,
        ));

        json_platforms.push((
            name.to_string(),
            obj(&[
                ("sockets", Value::from(platform.numa.sockets)),
                (
                    "cliff",
                    obj(&SWEEP_KERNELS
                        .iter()
                        .enumerate()
                        .map(|(ki, k)| (k.name(), Value::from(cliff(ki))))
                        .collect::<Vec<_>>()),
                ),
                (
                    "gups",
                    obj(&[
                        (
                            NumaPlacement::FirstTouch.name(),
                            Value::from(gups_ft.bandwidth_gbs),
                        ),
                        (
                            NumaPlacement::Interleave.name(),
                            Value::from(gups_il.bandwidth_gbs),
                        ),
                    ]),
                ),
                (
                    "runs",
                    Value::Array(
                        records.iter().map(|r| r.to_json()).collect(),
                    ),
                ),
            ]),
        ));
    }
    csv.write(&ctx.out_dir, "numa.csv")?;
    let doc = Value::Object(json_platforms.into_iter().collect());
    let mut text = json::to_string_pretty(&doc);
    text.push('\n');
    std::fs::write(ctx.out_dir.join("numa.json"), text)?;
    report.push_str(
        "Takeaway check: under interleave placement every odd-page lane \
         crosses the socket link and pays its latency plus a \
         bandwidth-equivalent penalty, so bandwidth declines monotonely \
         as the remote fraction rises — the all-local to all-remote \
         ratio is the platform's remote-access cliff. On the shared \
         GUPS table, first-touch homes every page on node 0 and both \
         sockets contend for one memory controller, while interleave \
         spreads the pages and recovers the aggregate bandwidth — \
         placement, not the pattern, decides which regime the run \
         lands in.\n",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx(tag: &str) -> SuiteContext {
        SuiteContext::fast(
            &Path::new("/tmp").join(format!("spatter-numa-{tag}")),
        )
    }

    #[test]
    fn ratio_pattern_page_split() {
        let p = ratio_pattern(4, 1024);
        assert_eq!(p.vector_len(), LANES);
        assert_eq!(p.delta, DELTA_ELEMS);
        // 12 lanes on the even page, 4 on the odd page; a cache line
        // apart within each page.
        let page = |e: i64| (e * 8) >> 12;
        assert_eq!(
            p.indices.iter().filter(|&&e| page(e) % 2 == 0).count(),
            12
        );
        assert_eq!(
            p.indices.iter().filter(|&&e| page(e) % 2 == 1).count(),
            4
        );
        assert_eq!(p.indices[1] - p.indices[0], 8);
        // The delta preserves every lane's parity.
        assert_eq!(page(DELTA_ELEMS) % 2, 0);
        // The GS variant mirrors the split on its write side.
        let gs = ratio_gs(4, 1024);
        assert_eq!(gs.scatter_indices, gs.indices);
    }

    #[test]
    fn remote_lanes_raise_remote_traffic_and_cut_bandwidth() {
        let p = platforms::by_name("skx-2s").unwrap();
        let count = 1 << 12;
        let run = |remote: usize| {
            let mut b = OpenMpSim::without_prefetch(&p);
            b.set_numa_placement(Some(NumaPlacement::Interleave));
            b.run(&ratio_pattern(remote, count), Kernel::Gather).unwrap()
        };
        let local = run(0);
        let mixed = run(8);
        let far = run(16);
        assert!(local.counters.numa_remote == 0, "{:?}", local.counters);
        assert!(local.counters.numa_local > 0);
        assert!(
            mixed.counters.numa_remote > 0
                && far.counters.numa_remote > mixed.counters.numa_remote,
            "mixed {:?} far {:?}",
            mixed.counters,
            far.counters
        );
        // The link penalty is visible end to end.
        let bw = |r: &crate::sim::SimResult| r.bandwidth_gbs();
        assert!(
            bw(&far) < bw(&mixed) && bw(&mixed) < bw(&local),
            "local {:.2} mixed {:.2} far {:.2}",
            bw(&local),
            bw(&mixed),
            bw(&far)
        );
    }

    #[test]
    fn first_touch_concentrates_gups_on_one_node() {
        let p = platforms::by_name("skx-2s").unwrap();
        let pat = Pattern::gups(GUPS_TABLE_ELEMS, 1 << 10);
        let run = |placement: NumaPlacement| {
            let mut b = OpenMpSim::without_prefetch(&p);
            b.set_numa_placement(Some(placement));
            b.run(&pat, Kernel::Gups).unwrap()
        };
        let ft = run(NumaPlacement::FirstTouch);
        let il = run(NumaPlacement::Interleave);
        assert!(
            ft.bandwidth_gbs() < il.bandwidth_gbs(),
            "first-touch {:.3} must trail interleave {:.3}",
            ft.bandwidth_gbs(),
            il.bandwidth_gbs()
        );
    }

    #[test]
    fn report_csv_json_written_and_cliffs_reported() {
        let c = ctx("run");
        let report = numa_suite(&c).unwrap();
        assert!(report.contains("remote-access bandwidth cliff"), "{report}");
        assert!(report.contains("-- skx-2s (2 sockets) --"), "{report}");
        assert!(c.out_dir.join("numa.csv").exists());
        let j =
            std::fs::read_to_string(c.out_dir.join("numa.json")).unwrap();
        let doc = json::parse(&j).unwrap();
        for &plat in PLATFORMS {
            let node = doc.get(plat).unwrap();
            // All-local beats all-remote on every kernel.
            for k in ["Gather", "Scatter", "GS"] {
                let cliff =
                    node.get("cliff").unwrap().get(k).unwrap().as_f64().unwrap();
                assert!(cliff > 1.0, "{plat}/{k} cliff {cliff}");
            }
            // Interleave beats first-touch on the shared GUPS table.
            let gups = node.get("gups").unwrap();
            assert!(
                gups.get("interleave").unwrap().as_f64().unwrap()
                    > gups.get("first-touch").unwrap().as_f64().unwrap(),
                "{plat} gups"
            );
            // Every run record carries the numa counters in its JSON.
            let runs = node.get("runs").unwrap().as_array().unwrap();
            assert!(runs
                .iter()
                .any(|r| r.get("numa").unwrap().get_opt("remote").is_some()));
        }
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn jobs_invariant_output() {
        let c1 = ctx("j1").with_jobs(1);
        let c4 = ctx("j4").with_jobs(4);
        let r1 = numa_suite(&c1).unwrap();
        let r4 = numa_suite(&c4).unwrap();
        assert_eq!(r1, r4, "report must not depend on --jobs");
        let f = |c: &SuiteContext, n: &str| {
            std::fs::read_to_string(c.out_dir.join(n)).unwrap()
        };
        assert_eq!(f(&c1, "numa.csv"), f(&c4, "numa.csv"));
        assert_eq!(f(&c1, "numa.json"), f(&c4, "numa.json"));
        std::fs::remove_dir_all(&c1.out_dir).ok();
        std::fs::remove_dir_all(&c4.out_dir).ok();
    }
}
