//! `--suite simd` — the paper's Fig 6 vectorization study, end-to-end
//! through the `--vector-regime` knob and the parallel run queue.
//!
//! Fig 6 compares one vectorized backend against one scalar backend;
//! this suite sweeps the whole regime axis instead: every CPU platform
//! runs every regime its ISA supports — `scalar`, the AVX2-class
//! `emulated-gather`, the AVX-512-class `hardware-gs`, the TX2-class
//! `masked-sve` — over the uniform-stride gather/scatter grid and a
//! set of Table-5 app patterns, all as per-run `"vector-regime"`
//! overrides on the `--jobs` worker pool.
//!
//! The headline per platform is the **scalar-to-vector crossover**:
//! the smallest stride at which the native regime's gather lead over
//! scalar issue evaporates. KNL never crosses (its scalar loop
//! achieves half the DRAM efficiency of its G/S path); BDW crosses
//! immediately (the microcoded AVX2 gather loses to scalar issue,
//! §5.3); TX2 is flat (masked-SVE is numerically scalar).

use super::ustride::cpu_ustride;
use super::{SuiteContext, STRIDES};
use crate::backends::{Backend, OpenMpSim};
use crate::coordinator::{run_configs_jobs, RunConfig};
use crate::error::Result;
use crate::json::{self, Value};
use crate::pattern::{table5, Kernel};
use crate::platforms::{self, VectorRegime};
use crate::report::{Csv, Table};

/// Platforms the sweep reports (the paper's Fig 6 CPUs; CLX omitted as
/// it overlaps SKX).
const PLATFORMS: &[&str] = &["knl", "bdw", "skx", "naples", "tx2"];

/// Table-5 app patterns ridden through every regime: a cache-resident
/// gather where issue rate binds (the BDW microcode mechanism), a
/// DRAM-reaching gather, and a scatter.
const APPS: &[&str] = &["AMG-G0", "LULESH-G3", "LULESH-S3"];

/// The kernels of the uniform-stride grid, in sweep order.
const KERNELS: &[Kernel] = &[Kernel::Gather, Kernel::Scatter];

/// The run queue for one platform: for each supported regime, the
/// gather/scatter stride grid then the app patterns — a fixed block
/// layout the report indexes into arithmetically.
fn simd_configs(
    name: &str,
    regimes: &[VectorRegime],
    ctx: &SuiteContext,
) -> Vec<RunConfig> {
    let ucount = ctx.ustride_count();
    let mut configs = Vec::new();
    for &r in regimes {
        for &kernel in KERNELS {
            for &s in STRIDES {
                configs.push(RunConfig {
                    name: format!("{name}/{r}/{}/s{s}", kernel.name()),
                    kernel,
                    pattern: cpu_ustride(s, ucount),
                    page_size: None,
                    threads: None,
                    regime: Some(r),
                    placement: None,
                });
            }
        }
        for &app in APPS {
            let a = table5::by_name(app).expect("APPS are Table-5 ids");
            configs.push(RunConfig {
                name: format!("{name}/{r}/{app}"),
                kernel: a.kernel,
                pattern: a.to_pattern(ctx.app_count()),
                page_size: None,
                threads: None,
                regime: Some(r),
                placement: None,
            });
        }
    }
    configs
}

/// Smallest stride at which the native regime's gather bandwidth falls
/// within 2% of (or below) scalar issue — `None` when the vector lead
/// survives the whole sweep.
fn crossover(native: &[f64], scalar: &[f64]) -> Option<usize> {
    STRIDES
        .iter()
        .zip(native.iter().zip(scalar))
        .find(|(_, (&n, &s))| n <= 1.02 * s)
        .map(|(&stride, _)| stride)
}

pub fn simd_suite(ctx: &SuiteContext) -> Result<String> {
    let mut csv = Csv::new(&[
        "platform", "regime", "kernel", "workload", "gbs", "bottleneck",
    ]);
    let mut report = String::from(
        "== simd: vectorization-regime sweep (Fig 6 crossover) ==\n",
    );
    let mut json_platforms: Vec<(String, Value)> = Vec::new();
    for &name in PLATFORMS {
        let platform = platforms::by_name(name)?;
        let regimes = platform.supported_regimes();
        let block = KERNELS.len() * STRIDES.len() + APPS.len();
        let configs = simd_configs(name, &regimes, ctx);
        let factory = || -> Result<Box<dyn Backend>> {
            Ok(Box::new(OpenMpSim::new(&platform)))
        };
        let records = run_configs_jobs(&factory, &configs, ctx.jobs)?;
        let bw = |ri: usize, ki: usize, si: usize| {
            records[ri * block + ki * STRIDES.len() + si].bandwidth_gbs
        };
        let app_rec = |ri: usize, ai: usize| {
            &records[ri * block + KERNELS.len() * STRIDES.len() + ai]
        };
        for (ri, r) in regimes.iter().enumerate() {
            for (ki, kernel) in KERNELS.iter().enumerate() {
                for (si, &s) in STRIDES.iter().enumerate() {
                    let rec =
                        &records[ri * block + ki * STRIDES.len() + si];
                    csv.row_display(&[
                        &name,
                        &r,
                        &kernel.name(),
                        &format!("s{s}"),
                        &format!("{:.3}", rec.bandwidth_gbs),
                        &rec.bottleneck,
                    ]);
                }
            }
            for (ai, &app) in APPS.iter().enumerate() {
                let rec = app_rec(ri, ai);
                csv.row_display(&[
                    &name,
                    &r,
                    &rec.kernel.name(),
                    &app,
                    &format!("{:.3}", rec.bandwidth_gbs),
                    &rec.bottleneck,
                ]);
            }
        }
        // Per-kernel stride tables, one column per supported regime.
        let header: Vec<String> = std::iter::once("stride".to_string())
            .chain(regimes.iter().map(|r| format!("{r} GB/s")))
            .collect();
        let header_refs: Vec<&str> =
            header.iter().map(|s| s.as_str()).collect();
        report.push_str(&format!(
            "-- {name} (native {}, {}-wide SIMD) --\n",
            platform.native_regime, platform.simd_lanes as usize
        ));
        for (ki, kernel) in KERNELS.iter().enumerate() {
            let mut table = Table::new(&header_refs);
            for (si, &s) in STRIDES.iter().enumerate() {
                let mut row = vec![s.to_string()];
                for ri in 0..regimes.len() {
                    row.push(format!("{:.2}", bw(ri, ki, si)));
                }
                table.row(&row);
            }
            report.push_str(&format!(
                "{}:\n{}",
                kernel.name(),
                table.render()
            ));
        }
        let mut apps_header = vec!["pattern".to_string()];
        apps_header.extend(regimes.iter().map(|r| format!("{r} GB/s")));
        let apps_refs: Vec<&str> =
            apps_header.iter().map(|s| s.as_str()).collect();
        let mut apps_table = Table::new(&apps_refs);
        for (ai, &app) in APPS.iter().enumerate() {
            let mut row = vec![app.to_string()];
            for ri in 0..regimes.len() {
                row.push(format!("{:.2}", app_rec(ri, ai).bandwidth_gbs));
            }
            apps_table.row(&row);
        }
        report.push_str(&format!("apps:\n{}", apps_table.render()));
        // Crossover takeaway: native vs scalar gather across strides.
        // Scalar is always regimes[0]; the native regime is always
        // supported, so the position lookup cannot fail.
        let ni = regimes
            .iter()
            .position(|&r| r == platform.native_regime)
            .expect("native regime is always supported");
        let native_g: Vec<f64> =
            (0..STRIDES.len()).map(|si| bw(ni, 0, si)).collect();
        let scalar_g: Vec<f64> =
            (0..STRIDES.len()).map(|si| bw(0, 0, si)).collect();
        report.push_str(&match crossover(&native_g, &scalar_g) {
            Some(s) => format!(
                "{name}: scalar issue catches {} gather at the stride-{s} \
                 crossover\n",
                platform.native_regime
            ),
            None => format!(
                "{name}: no scalar-to-vector crossover — {} holds its \
                 gather lead at every swept stride\n",
                platform.native_regime
            ),
        });
        json_platforms.push((
            name.to_string(),
            Value::Array(records.iter().map(|r| r.to_json()).collect()),
        ));
    }
    csv.write(&ctx.out_dir, "simd.csv")?;
    let doc = Value::Object(json_platforms.into_iter().collect());
    let mut text = json::to_string_pretty(&doc);
    text.push('\n');
    std::fs::write(ctx.out_dir.join("simd.json"), text)?;
    report.push_str(
        "Takeaway check: KNL's hardware G/S never crosses (its scalar \
         loop reaches half the DRAM efficiency of its vector path); \
         BDW's microcoded emulated gather loses to scalar issue on the \
         cache-resident AMG-G0; TX2's masked-SVE column is numerically \
         identical to scalar (no G/S instructions).\n",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx(tag: &str) -> SuiteContext {
        SuiteContext::fast(
            &Path::new("/tmp").join(format!("spatter-simd-{tag}")),
        )
    }

    #[test]
    fn report_tables_and_files_written() {
        let c = ctx("run");
        let report = simd_suite(&c).unwrap();
        assert!(report.contains("vectorization-regime sweep"), "{report}");
        for name in PLATFORMS {
            assert!(report.contains(&format!("-- {name} ")), "{report}");
        }
        // Every platform gets a crossover verdict, and the regime axis
        // actually shows up in the column headers.
        assert!(report.contains("crossover"), "{report}");
        assert!(report.contains("hardware-gs GB/s"), "{report}");
        assert!(report.contains("masked-sve GB/s"), "{report}");
        assert!(c.out_dir.join("simd.csv").exists());
        let j = std::fs::read_to_string(c.out_dir.join("simd.json")).unwrap();
        let doc = json::parse(&j).unwrap();
        for name in PLATFORMS {
            let runs = doc.get(name).unwrap().as_array().unwrap();
            let regimes =
                platforms::by_name(name).unwrap().supported_regimes();
            let block = KERNELS.len() * STRIDES.len() + APPS.len();
            assert_eq!(runs.len(), regimes.len() * block, "{name}");
            // The per-run override is visible in the JSON records.
            assert_eq!(
                runs[0].get("vector_regime").unwrap().as_str().unwrap(),
                "scalar",
                "{name}: regimes[0] is always scalar"
            );
        }
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn fig6_poles_hold_in_the_emitted_json() {
        // The two Fig 6 poles plus the TX2 null result, read back from
        // the suite's own records: KNL's hardware G/S dwarfs its
        // scalar loop at stride 1, BDW's microcoded gather loses to
        // scalar issue on the cache-resident AMG-G0, and TX2's
        // masked-SVE column is bit-identical to scalar.
        let c = ctx("poles");
        simd_suite(&c).unwrap();
        let j = std::fs::read_to_string(c.out_dir.join("simd.json")).unwrap();
        let doc = json::parse(&j).unwrap();
        let bw = |plat: &str, run: &str| {
            doc.get(plat)
                .unwrap()
                .as_array()
                .unwrap()
                .iter()
                .find(|r| r.get("name").unwrap().as_str().unwrap() == run)
                .unwrap_or_else(|| panic!("{plat}: no run '{run}'"))
                .get("bandwidth_gbs")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let knl_v = bw("knl", "knl/hardware-gs/Gather/s1");
        let knl_s = bw("knl", "knl/scalar/Gather/s1");
        assert!(knl_v > 1.3 * knl_s, "KNL {knl_v:.1} vs {knl_s:.1}");
        let bdw_v = bw("bdw", "bdw/emulated-gather/AMG-G0");
        let bdw_s = bw("bdw", "bdw/scalar/AMG-G0");
        assert!(bdw_s > bdw_v, "BDW scalar {bdw_s:.1} vs gather {bdw_v:.1}");
        for s in STRIDES {
            let run = format!("Gather/s{s}");
            assert_eq!(
                bw("tx2", &format!("tx2/masked-sve/{run}")),
                bw("tx2", &format!("tx2/scalar/{run}")),
                "TX2 masked-sve must be numerically scalar at s{s}"
            );
        }
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn crossover_picks_smallest_qualifying_stride() {
        let flat = [10.0; 8];
        assert_eq!(crossover(&[20.0; 8], &flat), None);
        assert_eq!(crossover(&flat, &flat), Some(1));
        let mut fades = [20.0; 8];
        fades[5] = 10.0;
        fades[6] = 10.0;
        fades[7] = 10.0;
        assert_eq!(crossover(&fades, &flat), Some(STRIDES[5]));
    }

    #[test]
    fn simd_suite_is_jobs_invariant() {
        let c1 = ctx("j1").with_jobs(1);
        let c8 = ctx("j8").with_jobs(8);
        let r1 = simd_suite(&c1).unwrap();
        let r8 = simd_suite(&c8).unwrap();
        assert_eq!(r1, r8, "report must not depend on --jobs");
        let f = |c: &SuiteContext, n: &str| {
            std::fs::read_to_string(c.out_dir.join(n)).unwrap()
        };
        assert_eq!(f(&c1, "simd.csv"), f(&c8, "simd.csv"));
        assert_eq!(f(&c1, "simd.json"), f(&c8, "simd.json"));
        std::fs::remove_dir_all(&c1.out_dir).ok();
        std::fs::remove_dir_all(&c8.out_dir).ok();
    }
}
