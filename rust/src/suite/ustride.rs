//! Uniform-stride experiments: Figs 3, 4, 5, 6 — plus the page-size
//! sweep (a Fig 4-style ablation over the `--page-size` knob) and the
//! `ustride` suite, the same CPU sweep expressed as a `RunConfig`
//! queue and executed through the parallel scheduler.

use super::{SuiteContext, STRIDES};
use crate::backends::{Backend, CudaSim, OpenMpSim, ScalarSim};
use crate::coordinator::{render_table, run_configs_jobs, RunConfig};
use crate::error::Result;
use crate::json::{self, Value};
use crate::pattern::{Kernel, Pattern};
use crate::platforms;
use crate::report::{Csv, Table};
use crate::sim::PageSize;

/// CPU uniform-stride pattern: `UNIFORM:8:s` with delta `8s` (no data
/// reuse between gathers — footnote 1 of the paper).
pub fn cpu_ustride(stride: usize, count: usize) -> Pattern {
    Pattern::parse(&format!("UNIFORM:8:{stride}"))
        .unwrap()
        .with_delta(8 * stride as i64)
        .with_count(count)
        .with_name(&format!("UNIFORM:8:{stride}"))
}

/// GPU uniform-stride pattern: index buffer of 256 (footnote 2).
pub fn gpu_ustride(stride: usize, count: usize) -> Pattern {
    Pattern::parse(&format!("UNIFORM:256:{stride}"))
        .unwrap()
        .with_delta(256 * stride as i64)
        .with_count(count)
        .with_name(&format!("UNIFORM:256:{stride}"))
}

/// Fig 3: CPU gather + scatter bandwidth vs stride on the four CPUs the
/// paper plots (SKX, BDW, Naples, TX2; CLX omitted as it overlaps SKX).
pub fn fig3_cpu_ustride(ctx: &SuiteContext) -> Result<String> {
    let count = ctx.ustride_count();
    let mut csv = Csv::new(&["platform", "kernel", "stride", "gbs"]);
    let mut report = String::from("== Fig 3: CPU uniform-stride bandwidth ==\n");
    for kernel in [Kernel::Gather, Kernel::Scatter] {
        let mut table = Table::new(&[
            "stride", "skx", "bdw", "naples", "tx2",
        ]);
        let mut series: Vec<Vec<f64>> = Vec::new();
        for &name in &["skx", "bdw", "naples", "tx2"] {
            let p = platforms::by_name(name)?;
            let mut b = OpenMpSim::new(&p);
            let mut col = Vec::new();
            for &s in STRIDES {
                let bw = b.run(&cpu_ustride(s, count), kernel)?.bandwidth_gbs();
                csv.row_display(&[&name, &kernel.name(), &s, &format!("{bw:.3}")]);
                col.push(bw);
            }
            series.push(col);
        }
        for (i, &s) in STRIDES.iter().enumerate() {
            table.row(&[
                s.to_string(),
                format!("{:.2}", series[0][i]),
                format!("{:.2}", series[1][i]),
                format!("{:.2}", series[2][i]),
                format!("{:.2}", series[3][i]),
            ]);
        }
        report.push_str(&format!("-- {} --\n{}", kernel.name(), table.render()));
    }
    csv.write(&ctx.out_dir, "fig3_cpu_ustride.csv")?;
    report.push_str(
        "Takeaway check: bandwidth halves per stride doubling; Naples flat \
         after stride-8; BDW recovers at stride-64; TX2 keeps dropping.\n",
    );
    Ok(report)
}

/// Fig 4: BDW and SKX gather with prefetching on/off, absolute and
/// normalized to stride-1.
pub fn fig4_prefetch(ctx: &SuiteContext) -> Result<String> {
    let count = ctx.ustride_count();
    let mut csv = Csv::new(&["platform", "prefetch", "stride", "gbs", "normalized"]);
    let mut report = String::from("== Fig 4: prefetching on/off (gather) ==\n");
    for &name in &["bdw", "skx"] {
        let p = platforms::by_name(name)?;
        let mut table = Table::new(&["stride", "pf-on GB/s", "pf-off GB/s", "on/peak", "off/peak"]);
        let mut on = OpenMpSim::new(&p);
        let mut off = OpenMpSim::without_prefetch(&p);
        let peak_on = on
            .run(&cpu_ustride(1, count), Kernel::Gather)?
            .bandwidth_gbs();
        let peak_off = off
            .run(&cpu_ustride(1, count), Kernel::Gather)?
            .bandwidth_gbs();
        for &s in STRIDES {
            let bon = on.run(&cpu_ustride(s, count), Kernel::Gather)?.bandwidth_gbs();
            let boff = off
                .run(&cpu_ustride(s, count), Kernel::Gather)?
                .bandwidth_gbs();
            csv.row_display(&[&name, &"on", &s, &format!("{bon:.3}"), &format!("{:.4}", bon / peak_on)]);
            csv.row_display(&[&name, &"off", &s, &format!("{boff:.3}"), &format!("{:.4}", boff / peak_off)]);
            table.row(&[
                s.to_string(),
                format!("{bon:.2}"),
                format!("{boff:.2}"),
                format!("{:.3}", bon / peak_on),
                format!("{:.3}", boff / peak_off),
            ]);
        }
        report.push_str(&format!("-- {} --\n{}", name, table.render()));
    }
    csv.write(&ctx.out_dir, "fig4_prefetch.csv")?;
    report.push_str(
        "Takeaway check: BDW loses its stride-64 bump with prefetch off; \
         SKX's normalized floor is ~1/16 with prefetch on.\n",
    );
    Ok(report)
}

/// Fig 5: GPU gather + scatter bandwidth vs stride (K40c, Titan Xp,
/// P100 — the GPUs the paper plots).
pub fn fig5_gpu_ustride(ctx: &SuiteContext) -> Result<String> {
    let count = (ctx.ustride_count() / 64).max(1 << 10);
    let mut csv = Csv::new(&["platform", "kernel", "stride", "gbs"]);
    let mut report = String::from("== Fig 5: GPU uniform-stride bandwidth ==\n");
    for kernel in [Kernel::Gather, Kernel::Scatter] {
        let mut table = Table::new(&["stride", "k40c", "titanxp", "p100"]);
        let mut series: Vec<Vec<f64>> = Vec::new();
        for &name in &["k40c", "titanxp", "p100"] {
            let p = platforms::gpu_by_name(name)?;
            let mut b = CudaSim::new(&p);
            let mut col = Vec::new();
            for &s in STRIDES {
                let bw = b.run(&gpu_ustride(s, count), kernel)?.bandwidth_gbs();
                csv.row_display(&[&name, &kernel.name(), &s, &format!("{bw:.2}")]);
                col.push(bw);
            }
            series.push(col);
        }
        for (i, &s) in STRIDES.iter().enumerate() {
            table.row(&[
                s.to_string(),
                format!("{:.1}", series[0][i]),
                format!("{:.1}", series[1][i]),
                format!("{:.1}", series[2][i]),
            ]);
        }
        report.push_str(&format!("-- {} --\n{}", kernel.name(), table.render()));
    }
    csv.write(&ctx.out_dir, "fig5_gpu_ustride.csv")?;
    report.push_str(
        "Takeaway check: gather plateaus at ~1/4 of peak from stride-4 to \
         stride-8 on Pascal parts (coalescing), scatter at ~1/8; the K40c \
         falls off harder.\n",
    );
    Ok(report)
}

/// Fig 6: % improvement of the vectorized (OpenMP) backend over the
/// Scalar backend, per stride, gather and scatter.
pub fn fig6_simd_scalar(ctx: &SuiteContext) -> Result<String> {
    let count = ctx.ustride_count();
    let cpus = ["bdw", "skx", "knl", "naples", "tx2"];
    let mut csv = Csv::new(&["platform", "kernel", "stride", "improvement_pct"]);
    let mut report = String::from("== Fig 6: SIMD vs scalar backend ==\n");
    for kernel in [Kernel::Gather, Kernel::Scatter] {
        let mut table = Table::new(&["stride", "bdw", "skx", "knl", "naples", "tx2"]);
        let mut series: Vec<Vec<f64>> = Vec::new();
        for name in cpus {
            let p = platforms::by_name(name)?;
            let mut omp = OpenMpSim::new(&p);
            let mut sca = ScalarSim::new(&p);
            let mut col = Vec::new();
            for &s in STRIDES {
                let pat = cpu_ustride(s, count);
                let bo = omp.run(&pat, kernel)?.bandwidth_gbs();
                let bs = sca.run(&pat, kernel)?.bandwidth_gbs();
                let imp = (bo - bs) / bs * 100.0;
                csv.row_display(&[&name, &kernel.name(), &s, &format!("{imp:.1}")]);
                col.push(imp);
            }
            series.push(col);
        }
        for (i, &s) in STRIDES.iter().enumerate() {
            let mut row = vec![s.to_string()];
            for col in &series {
                row.push(format!("{:+.1}%", col[i]));
            }
            table.row(&row);
        }
        report.push_str(&format!("-- {} --\n{}", kernel.name(), table.render()));
    }
    csv.write(&ctx.out_dir, "fig6_simd_scalar.csv")?;
    report.push_str(
        "Takeaway check: KNL/SKX gain from G/S instructions (KNL most at \
         small strides), BDW often loses, Naples gains on gather only (no \
         scatter instruction), TX2 is ~0% (no G/S support).\n",
    );
    Ok(report)
}

/// The PENNANT-like huge-delta gather of the page-size sweep: sixteen
/// indices landing on sixteen different 4 KiB pages, base advancing
/// 128 KiB per iteration — every access is a fresh base page, but
/// 2 MiB pages are shared across sixteen iterations.
pub fn hugedelta_pattern(count: usize) -> Pattern {
    let idx: Vec<i64> = (0..16).map(|j| j * 512).collect();
    Pattern::from_indices("pennant-like-hugedelta", idx)
        .with_delta(16384)
        .with_count(count)
}

/// Page-size sweep (Fig 4-style ablation, §5.4 PENNANT mechanism): the
/// same huge-delta gather under 4 KiB / 2 MiB / 1 GiB translation.
/// On KNL the run flips from TLB-bound at 4 KiB to DRAM-bound at
/// 2 MiB; on SKX the miss rate collapses while DRAM keeps binding.
pub fn pagesize_sweep(ctx: &SuiteContext) -> Result<String> {
    let count = ctx.ustride_count();
    let pattern = hugedelta_pattern(count);
    let pages = [PageSize::FourKB, PageSize::TwoMB, PageSize::OneGB];
    let mut csv = Csv::new(&[
        "platform", "page", "gbs", "tlb_miss_rate", "bottleneck",
    ]);
    let mut report =
        String::from("== page-size sweep: huge-delta gather vs translation ==\n");
    for &name in &["knl", "skx"] {
        let p = platforms::by_name(name)?;
        let mut table =
            Table::new(&["page", "GB/s", "TLB miss%", "bound by"]);
        for &page in &pages {
            let mut b = OpenMpSim::with_page_size(&p, page);
            let r = b.run(&pattern, Kernel::Gather)?;
            let bw = r.bandwidth_gbs();
            let miss = r.counters.tlb.miss_rate().unwrap_or(0.0);
            let bound = r.breakdown.bottleneck();
            csv.row_display(&[
                &name,
                &page,
                &format!("{bw:.3}"),
                &format!("{miss:.4}"),
                &bound,
            ]);
            table.row(&[
                page.name().to_string(),
                format!("{bw:.2}"),
                format!("{:.1}", miss * 100.0),
                bound.to_string(),
            ]);
        }
        report.push_str(&format!("-- {} --\n{}", name, table.render()));
    }
    csv.write(&ctx.out_dir, "pagesize_sweep.csv")?;
    report.push_str(
        "Takeaway check: at 4 KiB every access opens a fresh page and the \
         TLB miss rate saturates (KNL: translation is the binding \
         resource); at 2 MiB sixteen iterations share one page, the miss \
         rate collapses, and the run returns to the DRAM roofline.\n",
    );
    Ok(report)
}

/// `--suite ustride`: the CPU uniform-stride sweep (SKX + BDW, gather
/// and scatter) expressed as a `RunConfig` queue and executed through
/// the `--jobs` worker pool. The report table and the `ustride.json`
/// document go through the same renderers as the CLI, so the suite
/// doubles as the golden-snapshot anchor pinning the seed numerics —
/// and its output is byte-identical for any `--jobs` value.
pub fn ustride_suite(ctx: &SuiteContext) -> Result<String> {
    let count = ctx.ustride_count();
    let mut csv =
        Csv::new(&["platform", "kernel", "stride", "gbs", "bottleneck"]);
    let mut report = String::from(
        "== ustride: CPU uniform-stride sweep (parallel run queue) ==\n",
    );
    let mut json_platforms: Vec<(String, Value)> = Vec::new();
    for &name in &["skx", "bdw"] {
        let platform = platforms::by_name(name)?;
        // `strides` rides alongside `configs` so the CSV rows below
        // can zip with `records` instead of re-deriving the ordering.
        let mut configs = Vec::new();
        let mut strides = Vec::new();
        for kernel in [Kernel::Gather, Kernel::Scatter] {
            for &s in STRIDES {
                configs.push(RunConfig {
                    name: format!("{name}/{}/s{s}", kernel.name()),
                    kernel,
                    pattern: cpu_ustride(s, count),
                    page_size: None,
                    threads: None,
                    regime: None,
                    placement: None,
                });
                strides.push(s);
            }
        }
        let factory = || -> Result<Box<dyn Backend>> {
            Ok(Box::new(OpenMpSim::new(&platform)))
        };
        let records = run_configs_jobs(&factory, &configs, ctx.jobs)?;
        for ((c, &s), r) in configs.iter().zip(&strides).zip(&records) {
            csv.row_display(&[
                &name,
                &c.kernel.name(),
                &s,
                &format!("{:.3}", r.bandwidth_gbs),
                &r.bottleneck,
            ]);
        }
        report.push_str(&format!("-- {name} --\n{}", render_table(&records)));
        json_platforms.push((
            name.to_string(),
            Value::Array(records.iter().map(|r| r.to_json()).collect()),
        ));
    }
    // Csv::write has already created ctx.out_dir.
    csv.write(&ctx.out_dir, "ustride.csv")?;
    let doc = Value::Object(json_platforms.into_iter().collect());
    let mut text = json::to_string_pretty(&doc);
    text.push('\n');
    std::fs::write(ctx.out_dir.join("ustride.json"), text)?;
    report.push_str(
        "Takeaway check: same numerics as fig3 (stride-1 == STREAM, halving \
         per stride doubling) through the RunConfig queue; table and JSON \
         are byte-identical for any --jobs value.\n",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx(tag: &str) -> SuiteContext {
        SuiteContext::fast(&Path::new("/tmp").join(format!("spatter-ustride-{tag}")))
    }

    #[test]
    fn fig3_runs_and_writes_csv() {
        let c = ctx("fig3");
        let report = fig3_cpu_ustride(&c).unwrap();
        assert!(report.contains("Fig 3"));
        assert!(c.out_dir.join("fig3_cpu_ustride.csv").exists());
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn fig4_shape_skx_floor() {
        let c = ctx("fig4");
        let report = fig4_prefetch(&c).unwrap();
        assert!(report.contains("skx"));
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn fig5_runs() {
        let c = ctx("fig5");
        let report = fig5_gpu_ustride(&c).unwrap();
        assert!(report.contains("k40c"));
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn pagesize_sweep_flips_knl_from_tlb_to_dram_bound() {
        let c = ctx("pagesize");
        let report = pagesize_sweep(&c).unwrap();
        assert!(report.contains("page-size sweep"));
        assert!(c.out_dir.join("pagesize_sweep.csv").exists());
        std::fs::remove_dir_all(&c.out_dir).ok();

        // The mechanism itself, directly: miss rate collapses and
        // bandwidth recovers when 2 MiB pages replace 4 KiB.
        let pat = hugedelta_pattern(1 << 15);
        let knl = platforms::by_name("knl").unwrap();
        let run = |page: PageSize| {
            OpenMpSim::with_page_size(&knl, page)
                .run(&pat, Kernel::Gather)
                .unwrap()
        };
        let r4k = run(PageSize::FourKB);
        let r2m = run(PageSize::TwoMB);
        let m4k = r4k.counters.tlb.miss_rate().unwrap();
        let m2m = r2m.counters.tlb.miss_rate().unwrap();
        assert!(m2m < 0.25 * m4k, "miss rate {m4k:.3} -> {m2m:.3}");
        assert!(r2m.bandwidth_gbs() > r4k.bandwidth_gbs());
        assert_eq!(r4k.breakdown.bottleneck(), "tlb");
        assert_eq!(r2m.breakdown.bottleneck(), "dram-bw");
    }

    #[test]
    fn ustride_suite_is_jobs_invariant() {
        let c1 = ctx("us-j1").with_jobs(1);
        let c8 = ctx("us-j8").with_jobs(8);
        let r1 = ustride_suite(&c1).unwrap();
        let r8 = ustride_suite(&c8).unwrap();
        assert_eq!(r1, r8, "report must not depend on --jobs");
        let j1 = std::fs::read_to_string(c1.out_dir.join("ustride.json")).unwrap();
        let j8 = std::fs::read_to_string(c8.out_dir.join("ustride.json")).unwrap();
        assert_eq!(j1, j8, "JSON must not depend on --jobs");
        let csv1 = std::fs::read_to_string(c1.out_dir.join("ustride.csv")).unwrap();
        let csv8 = std::fs::read_to_string(c8.out_dir.join("ustride.csv")).unwrap();
        assert_eq!(csv1, csv8, "CSV must not depend on --jobs");
        assert!(r1.contains("skx/Gather/s1"));
        std::fs::remove_dir_all(&c1.out_dir).ok();
        std::fs::remove_dir_all(&c8.out_dir).ok();
    }

    #[test]
    fn fig6_tx2_is_zero() {
        let c = ctx("fig6");
        let report = fig6_simd_scalar(&c).unwrap();
        // TX2 has no G/S instructions: improvement exactly +0.0%.
        assert!(report.contains("+0.0%"), "{report}");
        std::fs::remove_dir_all(&c.out_dir).ok();
    }
}
