//! The experiment suite: one entry per table/figure of the paper's
//! evaluation (§4–5). Each experiment runs the relevant backends,
//! writes a CSV series into the output directory, and returns a
//! human-readable report with the paper's takeaway checks.
//!
//! | name | paper artifact |
//! |---|---|
//! | `fig3` | CPU uniform-stride gather+scatter bandwidth |
//! | `fig4` | BDW/SKX gather with prefetching on/off |
//! | `fig5` | GPU uniform-stride gather+scatter bandwidth |
//! | `fig6` | SIMD vs scalar % improvement |
//! | `table1` | mini-app G/S characterization (trace pipeline) |
//! | `table4` | mini-app pattern bandwidths + STREAM correlation |
//! | `fig7` | radar, app-derived gather patterns |
//! | `fig8` | radar, app-derived scatter patterns |
//! | `fig9` | bandwidth-bandwidth plots |
//! | `pagesize` | huge-delta gather vs `--page-size` (TLB mechanism) |
//! | `ustride` | CPU uniform-stride sweep through the `--jobs` queue |
//! | `threadscale` | §3.1 thread-scaling: saturation knee + contention |
//! | `prefetch` | prefetcher depth/regime sweep, gather + GS coverage knee |
//! | `baselines` | STREAM tetrad + GUPS measured in-engine, all platforms |
//! | `dram` | banked-DRAM bank-conflict sweep, pow2 vs odd strides |
//! | `simd` | vectorization-regime sweep (Fig 6 crossover) |
//! | `numa` | NUMA remote-access cliff + placement A/B, 2-socket parts |
//! | `all` | everything above |

mod apps;
mod baselines;
mod dram;
mod numa;
mod prefetch;
mod simd;
mod threadscale;
mod ustride;

pub use apps::{fig7_radar, fig8_radar, fig9_bwbw, table1_characterization, table4_miniapps};
pub use baselines::{baselines_suite, measured_stream_gbs, BASELINE_KERNELS};
pub use dram::dram_suite;
pub use numa::{numa_suite, ratio_pattern, REMOTE_LANES};
pub use prefetch::prefetch_suite;
pub use simd::simd_suite;
pub use threadscale::threadscale_suite;
pub use ustride::{
    cpu_ustride, fig3_cpu_ustride, fig4_prefetch, fig5_gpu_ustride,
    fig6_simd_scalar, gpu_ustride, hugedelta_pattern, pagesize_sweep,
    ustride_suite,
};

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Shared experiment options.
#[derive(Debug, Clone)]
pub struct SuiteContext {
    /// Where CSV series land.
    pub out_dir: PathBuf,
    /// Reduce simulated counts (CI-speed runs). Shapes are preserved;
    /// absolute numbers get noisier.
    pub fast: bool,
    /// Worker threads for the run queue (`--jobs`). Reports are
    /// byte-identical for any value (order-preserving scheduler).
    pub jobs: usize,
}

impl SuiteContext {
    pub fn new(out_dir: &Path) -> SuiteContext {
        SuiteContext {
            out_dir: out_dir.to_path_buf(),
            fast: false,
            jobs: crate::coordinator::default_jobs(),
        }
    }

    pub fn fast(out_dir: &Path) -> SuiteContext {
        SuiteContext {
            out_dir: out_dir.to_path_buf(),
            fast: true,
            jobs: crate::coordinator::default_jobs(),
        }
    }

    /// Override the worker count (the `--jobs` CLI flag).
    pub fn with_jobs(mut self, jobs: usize) -> SuiteContext {
        self.jobs = jobs.max(1);
        self
    }

    /// Uniform-stride iteration count (paper: >= 8-16 GB of traffic;
    /// the simulator extrapolates past its measurement cap anyway).
    pub fn ustride_count(&self) -> usize {
        if self.fast {
            1 << 16
        } else {
            1 << 20
        }
    }

    /// App-pattern iteration count (paper: >= 2 GB of traffic).
    pub fn app_count(&self) -> usize {
        if self.fast {
            1 << 14
        } else {
            1 << 18
        }
    }

    /// Trace-emulator scale (sweeps per kernel).
    pub fn trace_scale(&self) -> usize {
        1
    }
}

/// The strides of the uniform-stride studies (1..128, powers of two).
pub const STRIDES: &[usize] = &[1, 2, 4, 8, 16, 32, 64, 128];

/// Run one experiment by name; returns the textual report.
pub fn run(name: &str, ctx: &SuiteContext) -> Result<String> {
    match name.to_ascii_lowercase().as_str() {
        "fig3" => fig3_cpu_ustride(ctx),
        "fig4" => fig4_prefetch(ctx),
        "fig5" => fig5_gpu_ustride(ctx),
        "fig6" => fig6_simd_scalar(ctx),
        "table1" => table1_characterization(ctx),
        "table4" => table4_miniapps(ctx),
        "fig7" => fig7_radar(ctx),
        "fig8" => fig8_radar(ctx),
        "fig9" => fig9_bwbw(ctx),
        "pagesize" => pagesize_sweep(ctx),
        "ustride" => ustride_suite(ctx),
        "threadscale" => threadscale_suite(ctx),
        "prefetch" => prefetch_suite(ctx),
        "baselines" => baselines_suite(ctx),
        "dram" => dram_suite(ctx),
        "simd" => simd_suite(ctx),
        "numa" => numa_suite(ctx),
        "all" => {
            let mut out = String::new();
            for n in [
                "table1", "fig3", "fig4", "fig5", "fig6", "baselines",
                "table4", "fig7", "fig8", "fig9", "pagesize", "ustride",
                "threadscale", "prefetch", "dram", "simd", "numa",
            ] {
                out.push_str(&run(n, ctx)?);
                out.push('\n');
            }
            Ok(out)
        }
        other => Err(Error::Cli(format!(
            "unknown suite '{other}' \
             (fig3|fig4|fig5|fig6|fig7|fig8|fig9|table1|table4|pagesize|\
             ustride|threadscale|prefetch|baselines|dram|simd|numa|all)"
        ))),
    }
}

/// Names of all experiments (for listings). Must stay in sync with the
/// dispatch table in [`run`] and the doc-comment table above.
pub const EXPERIMENTS: &[&str] = &[
    "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "table1",
    "table4", "pagesize", "ustride", "threadscale", "prefetch", "baselines",
    "dram", "simd", "numa",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_suite_errors() {
        let ctx = SuiteContext::fast(Path::new("/tmp/spatter-suite-x"));
        assert!(run("fig99", &ctx).is_err());
    }

    #[test]
    fn context_scaling() {
        let slow = SuiteContext::new(Path::new("x"));
        let fast = SuiteContext::fast(Path::new("x"));
        assert!(slow.ustride_count() > fast.ustride_count());
        assert!(slow.app_count() > fast.app_count());
    }
}
