//! `--suite threadscale` — the paper's §3.1/§5 thread-scaling axis,
//! end-to-end through the `--threads` knob and the parallel run queue.
//!
//! For every swept CPU platform, three workloads run at 1 → max
//! threads (powers of two plus the socket count):
//!
//! * `g-s1` — stride-1 gather: bandwidth rises with threads until DRAM
//!   saturates; the smallest thread count within 95% of peak is the
//!   platform's **saturation knee**.
//! * `g-s8` — stride-8 gather: the same knee shape at the line-
//!   granularity floor (1/8 of peak).
//! * `s-d0` — LULESH-S3, the delta-0 scatter: every thread writes the
//!   same lines, so the coherence cost grows with the sharer count and
//!   bandwidth **drops** as threads are added — except on TX2, which
//!   absorbs repeated writes (§5.4.2 item 1).

use super::ustride::cpu_ustride;
use super::SuiteContext;
use crate::backends::{Backend, OpenMpSim};
use crate::coordinator::{run_configs_jobs, RunConfig};
use crate::error::Result;
use crate::pattern::{table5, Kernel, Pattern};
use crate::platforms;
use crate::report::{Csv, Table};

/// Platforms the sweep reports (the paper's Fig 3 CPUs plus KNL, whose
/// 64 threads stretch the axis furthest).
const PLATFORMS: &[&str] = &["skx", "bdw", "tx2", "knl"];

/// One swept workload: short id + pattern + kernel.
struct Workload {
    id: &'static str,
    pattern: Pattern,
    kernel: Kernel,
}

fn workloads(ctx: &SuiteContext) -> Vec<Workload> {
    let ucount = ctx.ustride_count();
    let s3 = table5::by_name("LULESH-S3")
        .expect("LULESH-S3 in Table 5")
        .to_pattern(ctx.app_count());
    vec![
        Workload {
            id: "g-s1",
            pattern: cpu_ustride(1, ucount),
            kernel: Kernel::Gather,
        },
        Workload {
            id: "g-s8",
            pattern: cpu_ustride(8, ucount),
            kernel: Kernel::Gather,
        },
        Workload {
            id: "s-d0",
            pattern: s3,
            kernel: Kernel::Scatter,
        },
    ]
}

/// Smallest swept thread count whose bandwidth reaches 95% of the
/// sweep's peak — the saturation knee.
fn knee(sweep: &[usize], bws: &[f64]) -> usize {
    let peak = bws.iter().fold(0.0f64, |a, &b| a.max(b));
    sweep
        .iter()
        .zip(bws)
        .find(|(_, &bw)| bw >= 0.95 * peak)
        .map(|(&t, _)| t)
        .unwrap_or_else(|| *sweep.last().unwrap())
}

pub fn threadscale_suite(ctx: &SuiteContext) -> Result<String> {
    let loads = workloads(ctx);
    // Summary columns located by workload id, not by position, so
    // reordering or extending `workloads` cannot silently mislabel the
    // knee/contention stats.
    let knee_wi = loads
        .iter()
        .position(|w| w.id == "g-s1")
        .expect("g-s1 workload for the saturation knee");
    let d0_wi = loads
        .iter()
        .position(|w| w.id == "s-d0")
        .expect("s-d0 workload for the contention check");
    let mut csv = Csv::new(&[
        "platform", "workload", "threads", "gbs", "bottleneck",
    ]);
    let mut report = String::from(
        "== threadscale: bandwidth vs OpenMP thread count ==\n",
    );
    for &name in PLATFORMS {
        let platform = platforms::by_name(name)?;
        let sweep = platform.thread_sweep();
        // One RunConfig per (thread count, workload), executed on the
        // --jobs worker pool; order-preserving collection keeps the
        // report deterministic.
        let mut configs = Vec::new();
        for &t in &sweep {
            for w in &loads {
                configs.push(RunConfig {
                    name: format!("{name}/{}/t{t}", w.id),
                    kernel: w.kernel,
                    pattern: w.pattern.clone(),
                    page_size: None,
                    threads: Some(t),
                    regime: None,
                    placement: None,
                });
            }
        }
        let factory = || -> Result<Box<dyn Backend>> {
            Ok(Box::new(OpenMpSim::new(&platform)))
        };
        let records = run_configs_jobs(&factory, &configs, ctx.jobs)?;

        // Columns per workload, rows per thread count; the header is
        // derived from the workload list.
        let header: Vec<String> = std::iter::once("threads".to_string())
            .chain(loads.iter().map(|w| format!("{} GB/s", w.id)))
            .chain(std::iter::once(format!("{} bound by", loads[d0_wi].id)))
            .collect();
        let header_refs: Vec<&str> =
            header.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&header_refs);
        let mut cols: Vec<Vec<f64>> = vec![Vec::new(); loads.len()];
        for (ti, &t) in sweep.iter().enumerate() {
            let mut row = vec![t.to_string()];
            let mut d0_bound = String::new();
            for (wi, w) in loads.iter().enumerate() {
                let r = &records[ti * loads.len() + wi];
                cols[wi].push(r.bandwidth_gbs);
                csv.row_display(&[
                    &name,
                    &w.id,
                    &t,
                    &format!("{:.3}", r.bandwidth_gbs),
                    &r.bottleneck,
                ]);
                row.push(format!("{:.2}", r.bandwidth_gbs));
                if wi == d0_wi {
                    d0_bound = r.bottleneck.clone();
                }
            }
            row.push(d0_bound);
            table.row(&row);
        }
        let knee_t = knee(&sweep, &cols[knee_wi]);
        let d0 = &cols[d0_wi];
        let d0_peak = d0.iter().fold(0.0f64, |a, &b| a.max(b));
        let d0_last = *d0.last().unwrap();
        let contention = if d0_last < 0.5 * d0_peak {
            format!(
                "delta-0 scatter collapses {:.0}x from its best by t={} \
                 (coherence)",
                d0_peak / d0_last.max(1e-12),
                sweep.last().unwrap()
            )
        } else {
            "delta-0 scatter does not collapse (absorbs repeated writes)"
                .to_string()
        };
        report.push_str(&format!(
            "-- {name} --\n{}stride-1 saturation knee: t={knee_t}; \
             {contention}\n",
            table.render()
        ));
    }
    csv.write(&ctx.out_dir, "threadscale.csv")?;
    report.push_str(
        "Takeaway check: uniform-stride gather rises monotonically to a \
         platform-dependent knee where DRAM saturates; delta-0 scatter \
         drops as threads are added on every CPU except TX2.\n",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn ctx(tag: &str) -> SuiteContext {
        SuiteContext::fast(
            &Path::new("/tmp").join(format!("spatter-threadscale-{tag}")),
        )
    }

    #[test]
    fn report_and_csv_written() {
        let c = ctx("run");
        let report = threadscale_suite(&c).unwrap();
        assert!(report.contains("threadscale"));
        assert!(report.contains("saturation knee"));
        assert!(c.out_dir.join("threadscale.csv").exists());
        std::fs::remove_dir_all(&c.out_dir).ok();
    }

    #[test]
    fn knee_picks_smallest_saturating_count() {
        let sweep = [1, 2, 4, 8, 16];
        assert_eq!(knee(&sweep, &[10.0, 20.0, 40.0, 95.0, 97.0]), 8);
        assert_eq!(knee(&sweep, &[97.0, 97.0, 97.0, 97.0, 97.0]), 1);
        assert_eq!(knee(&sweep, &[1.0, 2.0, 3.0, 4.0, 5.0]), 16);
    }

    #[test]
    fn skx_knee_and_contention_mechanisms() {
        // The acceptance shapes, straight off the engine: monotone
        // stride-1 scaling to a knee below the socket count, and a
        // delta-0 scatter collapse at high thread counts.
        let c = ctx("mech");
        let loads = workloads(&c);
        let skx = platforms::by_name("skx").unwrap();
        let sweep = skx.thread_sweep();
        let bw = |w: &Workload, t: usize| {
            let mut b = OpenMpSim::new(&skx);
            b.set_threads(Some(t));
            b.run(&w.pattern, w.kernel).unwrap().bandwidth_gbs()
        };
        let s1: Vec<f64> = sweep.iter().map(|&t| bw(&loads[0], t)).collect();
        for w in s1.windows(2) {
            assert!(w[1] >= w[0] * 0.99, "monotone to the knee: {s1:?}");
        }
        assert!(s1.last().unwrap() > &(1.5 * s1[0]), "{s1:?}");
        let d0: Vec<f64> = sweep.iter().map(|&t| bw(&loads[2], t)).collect();
        let peak = d0.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(
            *d0.last().unwrap() < 0.5 * peak,
            "delta-0 scatter must collapse on SKX: {d0:?}"
        );
        // TX2 absorbs repeated writes: no collapse.
        let tx2 = platforms::by_name("tx2").unwrap();
        let tx_bw = |t: usize| {
            let mut b = OpenMpSim::new(&tx2);
            b.set_threads(Some(t));
            b.run(&loads[2].pattern, loads[2].kernel)
                .unwrap()
                .bandwidth_gbs()
        };
        let tx: Vec<f64> = tx2.thread_sweep().iter().map(|&t| tx_bw(t)).collect();
        let tx_peak = tx.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(
            *tx.last().unwrap() >= 0.9 * tx_peak,
            "TX2 must not collapse: {tx:?}"
        );
    }

    #[test]
    fn jobs_invariant_report() {
        let c1 = ctx("j1").with_jobs(1);
        let c4 = ctx("j4").with_jobs(4);
        let r1 = threadscale_suite(&c1).unwrap();
        let r4 = threadscale_suite(&c4).unwrap();
        assert_eq!(r1, r4);
        std::fs::remove_dir_all(&c1.out_dir).ok();
        std::fs::remove_dir_all(&c4.out_dir).ok();
    }
}
