//! Spatter's execution backends (paper §3.2).
//!
//! The paper ships OpenMP, CUDA, and Scalar backends; this reproduction
//! maps them onto the simulated platforms plus a fourth backend that
//! *really executes* the gather/scatter through the AOT-compiled
//! L1/L2 kernels on PJRT-CPU:
//!
//! | paper backend | here |
//! |---|---|
//! | OpenMP (vectorized) | [`OpenMpSim`] — CPU engine, vector G/S issue |
//! | Scalar (`#pragma novec`) | [`ScalarSim`] — CPU engine, scalar issue |
//! | CUDA | [`CudaSim`] — GPU engine |
//! | (n/a) | [`PjrtBackend`] — real execution + wall-clock timing |
//!
//! The simulated backends run with steady-state loop closure
//! (`sim::closure`) enabled: results are bit-identical to full
//! simulation, long runs cost O(warm-up) instead of O(iterations),
//! and each record carries a `closed_at` diagnostic (`"sim-closure"`
//! in JSON output). Set `SPATTER_NO_CLOSURE=1` to force full
//! simulation for A/B benchmarking (`scripts/bench.sh`).

mod pjrt;

pub use pjrt::PjrtBackend;

use crate::error::Result;
use crate::pattern::{Kernel, Pattern};
use crate::platforms::{CpuPlatform, GpuPlatform, VectorRegime};
use crate::sim::cpu::{CpuEngine, CpuSimOptions};
use crate::sim::gpu::{GpuEngine, GpuSimOptions};
use crate::sim::{NumaPlacement, PageSize, SimResult};

/// A Spatter execution backend: takes a fully-specified pattern, runs
/// (or models) it, and reports time + bandwidth.
pub trait Backend {
    /// Backend name for reports.
    fn name(&self) -> &str;

    /// Execute one pattern with the given kernel.
    fn run(&mut self, pattern: &Pattern, kernel: Kernel) -> Result<SimResult>;

    /// STREAM-equivalent peak (GB/s) for normalized plots, if known.
    fn stream_gbs(&self) -> Option<f64> {
        None
    }

    /// Reconfigure the translation page size before the next run:
    /// `Some` overrides, `None` restores the backend's configured
    /// default. Backends without a virtual-memory model (real
    /// execution) ignore the knob.
    fn set_page_size(&mut self, _page: Option<PageSize>) {}

    /// The page size the next run will model, if the backend has a
    /// virtual-memory model.
    fn page_size(&self) -> Option<PageSize> {
        None
    }

    /// Reconfigure the simulated OpenMP thread count before the next
    /// run: `Some` overrides, `None` restores the backend's configured
    /// default. Backends without a thread model (GPU, real execution)
    /// ignore the knob.
    fn set_threads(&mut self, _threads: Option<usize>) {}

    /// The thread count the next run will model, if the backend has a
    /// thread model.
    fn threads(&self) -> Option<usize> {
        None
    }

    /// Reconfigure the vectorization regime before the next run:
    /// `Some` overrides, `None` restores the backend's configured
    /// default. Backends without a CPU issue model (GPU, real
    /// execution) ignore the knob.
    fn set_vector_regime(&mut self, _regime: Option<VectorRegime>) {}

    /// The vectorization regime the next run will model, if the
    /// backend has a CPU issue model.
    fn vector_regime(&self) -> Option<VectorRegime> {
        None
    }

    /// Reconfigure the NUMA page-placement policy before the next run:
    /// `Some` overrides, `None` restores the backend's configured
    /// default. Backends without a NUMA model (GPU, real execution)
    /// ignore the knob; on single-socket CPU platforms it is accepted
    /// but inert (`sim::topology`).
    fn set_numa_placement(&mut self, _placement: Option<NumaPlacement>) {}

    /// The NUMA placement policy the next run will model, if the
    /// backend has a NUMA model.
    fn numa_placement(&self) -> Option<NumaPlacement> {
        None
    }

    /// Whether identical configs produce identical results on this
    /// backend. Simulators are pure functions of the config, so the
    /// coordinator may serve repeated configs from its memo cache;
    /// real-execution backends (PJRT) measure wall time and must
    /// return `false` to force every run to execute.
    fn deterministic(&self) -> bool {
        true
    }
}

/// The paper's OpenMP backend on a simulated CPU platform.
pub struct OpenMpSim {
    engine: CpuEngine,
    name: String,
}

impl OpenMpSim {
    pub fn new(platform: &CpuPlatform) -> OpenMpSim {
        OpenMpSim {
            engine: CpuEngine::new(platform),
            name: format!("openmp:{}", platform.name),
        }
    }

    /// With an explicit translation page size (the `--page-size` CLI
    /// knob).
    pub fn with_page_size(platform: &CpuPlatform, page: PageSize) -> OpenMpSim {
        OpenMpSim::configured(platform, Some(page), None)
    }

    /// Fully-configured constructor for the CLI knobs: translation
    /// page size (`--page-size`) and thread count (`--threads`);
    /// `None` keeps the platform defaults.
    pub fn configured(
        platform: &CpuPlatform,
        page: Option<PageSize>,
        threads: Option<usize>,
    ) -> OpenMpSim {
        OpenMpSim::configured_regime(platform, page, threads, None)
    }

    /// [`OpenMpSim::configured`] plus the `--vector-regime` knob. The
    /// regime lands in the engine's configured options — the restore
    /// target of [`Backend::set_vector_regime`] — so per-run configs
    /// without a `"vector-regime"` key fall back to the CLI value, not
    /// the platform default.
    pub fn configured_regime(
        platform: &CpuPlatform,
        page: Option<PageSize>,
        threads: Option<usize>,
        regime: Option<VectorRegime>,
    ) -> OpenMpSim {
        OpenMpSim::configured_numa(platform, page, threads, regime, None)
    }

    /// [`OpenMpSim::configured_regime`] plus the `--numa-placement`
    /// knob. The placement lands in the engine's configured options —
    /// the restore target of [`Backend::set_numa_placement`] — so
    /// per-run configs without a `"numa-placement"` key fall back to
    /// the CLI value, not the first-touch default.
    pub fn configured_numa(
        platform: &CpuPlatform,
        page: Option<PageSize>,
        threads: Option<usize>,
        regime: Option<VectorRegime>,
        placement: Option<NumaPlacement>,
    ) -> OpenMpSim {
        OpenMpSim {
            engine: CpuEngine::with_options(
                platform,
                CpuSimOptions {
                    page_size: page.unwrap_or(PageSize::FourKB),
                    threads,
                    regime,
                    numa_placement: placement.unwrap_or_default(),
                    ..Default::default()
                },
            ),
            name: format!("openmp:{}", platform.name),
        }
    }

    /// With prefetching disabled (the Fig 4 MSR study).
    pub fn without_prefetch(platform: &CpuPlatform) -> OpenMpSim {
        OpenMpSim {
            engine: CpuEngine::with_options(
                platform,
                CpuSimOptions {
                    prefetch_enabled: false,
                    ..Default::default()
                },
            ),
            name: format!("openmp-nopf:{}", platform.name),
        }
    }

    pub fn engine(&self) -> &CpuEngine {
        &self.engine
    }
}

impl Backend for OpenMpSim {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, pattern: &Pattern, kernel: Kernel) -> Result<SimResult> {
        self.engine.run(pattern, kernel)
    }

    fn stream_gbs(&self) -> Option<f64> {
        Some(self.engine.platform().stream_gbs)
    }

    fn set_page_size(&mut self, page: Option<PageSize>) {
        self.engine.set_page_size(page);
    }

    fn page_size(&self) -> Option<PageSize> {
        Some(self.engine.page_size())
    }

    fn set_threads(&mut self, threads: Option<usize>) {
        self.engine.set_threads(threads);
    }

    fn threads(&self) -> Option<usize> {
        Some(self.engine.threads())
    }

    fn set_vector_regime(&mut self, regime: Option<VectorRegime>) {
        self.engine.set_vector_regime(regime);
    }

    fn vector_regime(&self) -> Option<VectorRegime> {
        Some(self.engine.vector_regime())
    }

    fn set_numa_placement(&mut self, placement: Option<NumaPlacement>) {
        self.engine.set_numa_placement(placement);
    }

    fn numa_placement(&self) -> Option<NumaPlacement> {
        Some(self.engine.numa_placement())
    }
}

/// The paper's Scalar backend (`#pragma novec` baseline) on a simulated
/// CPU platform.
pub struct ScalarSim {
    engine: CpuEngine,
    name: String,
}

impl ScalarSim {
    pub fn new(platform: &CpuPlatform) -> ScalarSim {
        ScalarSim::with_page_size(platform, PageSize::FourKB)
    }

    /// With an explicit translation page size.
    pub fn with_page_size(platform: &CpuPlatform, page: PageSize) -> ScalarSim {
        ScalarSim::configured(platform, Some(page), None)
    }

    /// Fully-configured constructor for the CLI knobs (`--page-size`,
    /// `--threads`); `None` keeps the platform defaults.
    pub fn configured(
        platform: &CpuPlatform,
        page: Option<PageSize>,
        threads: Option<usize>,
    ) -> ScalarSim {
        ScalarSim::configured_numa(platform, page, threads, None)
    }

    /// [`ScalarSim::configured`] plus the `--numa-placement` knob
    /// (restore target of [`Backend::set_numa_placement`]).
    pub fn configured_numa(
        platform: &CpuPlatform,
        page: Option<PageSize>,
        threads: Option<usize>,
        placement: Option<NumaPlacement>,
    ) -> ScalarSim {
        ScalarSim {
            engine: CpuEngine::with_options(
                platform,
                CpuSimOptions {
                    regime: Some(VectorRegime::Scalar),
                    page_size: page.unwrap_or(PageSize::FourKB),
                    threads,
                    numa_placement: placement.unwrap_or_default(),
                    ..Default::default()
                },
            ),
            name: format!("scalar:{}", platform.name),
        }
    }
}

impl Backend for ScalarSim {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, pattern: &Pattern, kernel: Kernel) -> Result<SimResult> {
        self.engine.run(pattern, kernel)
    }

    fn stream_gbs(&self) -> Option<f64> {
        Some(self.engine.platform().stream_gbs)
    }

    fn set_page_size(&mut self, page: Option<PageSize>) {
        self.engine.set_page_size(page);
    }

    fn page_size(&self) -> Option<PageSize> {
        Some(self.engine.page_size())
    }

    fn set_threads(&mut self, threads: Option<usize>) {
        self.engine.set_threads(threads);
    }

    fn threads(&self) -> Option<usize> {
        Some(self.engine.threads())
    }

    fn vector_regime(&self) -> Option<VectorRegime> {
        // The Scalar backend *is* the pinned scalar regime; the setter
        // stays the trait no-op, so per-run overrides cannot silently
        // re-vectorize a `#pragma novec` baseline.
        Some(VectorRegime::Scalar)
    }

    fn set_numa_placement(&mut self, placement: Option<NumaPlacement>) {
        self.engine.set_numa_placement(placement);
    }

    fn numa_placement(&self) -> Option<NumaPlacement> {
        Some(self.engine.numa_placement())
    }
}

/// The paper's CUDA backend on a simulated GPU platform.
pub struct CudaSim {
    engine: GpuEngine,
    name: String,
}

impl CudaSim {
    pub fn new(platform: &GpuPlatform) -> CudaSim {
        CudaSim {
            engine: GpuEngine::new(platform),
            name: format!("cuda:{}", platform.name),
        }
    }

    /// With an explicit translation page size (GPUs default to their
    /// native 64 KiB large page).
    pub fn with_page_size(platform: &GpuPlatform, page: PageSize) -> CudaSim {
        CudaSim {
            engine: GpuEngine::with_options(
                platform,
                GpuSimOptions {
                    page_size: page,
                    ..Default::default()
                },
            ),
            name: format!("cuda:{}", platform.name),
        }
    }
}

impl Backend for CudaSim {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&mut self, pattern: &Pattern, kernel: Kernel) -> Result<SimResult> {
        self.engine.run(pattern, kernel)
    }

    fn stream_gbs(&self) -> Option<f64> {
        Some(self.engine.platform().stream_gbs)
    }

    fn set_page_size(&mut self, page: Option<PageSize>) {
        self.engine.set_page_size(page);
    }

    fn page_size(&self) -> Option<PageSize> {
        Some(self.engine.page_size())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms;

    fn pat() -> Pattern {
        Pattern::parse("UNIFORM:8:2")
            .unwrap()
            .with_delta(16)
            .with_count(1 << 14)
    }

    #[test]
    fn openmp_backend_runs() {
        let p = platforms::by_name("skx").unwrap();
        let mut b = OpenMpSim::new(&p);
        let r = b.run(&pat(), Kernel::Gather).unwrap();
        assert!(r.bandwidth_gbs() > 0.0);
        assert_eq!(b.name(), "openmp:skx");
        assert_eq!(b.stream_gbs(), Some(p.stream_gbs));
    }

    #[test]
    fn scalar_backend_is_slower_on_simd_cpu() {
        let p = platforms::by_name("knl").unwrap();
        let mut omp = OpenMpSim::new(&p);
        let mut sca = ScalarSim::new(&p);
        let dense = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(1 << 16);
        let bo = omp.run(&dense, Kernel::Gather).unwrap().bandwidth_gbs();
        let bs = sca.run(&dense, Kernel::Gather).unwrap().bandwidth_gbs();
        assert!(bo > bs, "omp {bo:.1} vs scalar {bs:.1}");
    }

    #[test]
    fn cuda_backend_runs() {
        let p = platforms::gpu_by_name("p100").unwrap();
        let mut b = CudaSim::new(&p);
        let gpat = Pattern::parse("UNIFORM:256:1")
            .unwrap()
            .with_delta(256)
            .with_count(1 << 12);
        let r = b.run(&gpat, Kernel::Gather).unwrap();
        assert!(r.bandwidth_gbs() > 100.0);
        assert_eq!(b.name(), "cuda:p100");
    }

    #[test]
    fn nopf_variant_differs() {
        let p = platforms::by_name("bdw").unwrap();
        let mut on = OpenMpSim::new(&p);
        let mut off = OpenMpSim::without_prefetch(&p);
        let dense = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(1 << 17);
        let bon = on.run(&dense, Kernel::Gather).unwrap();
        let boff = off.run(&dense, Kernel::Gather).unwrap();
        // Without prefetch the demand misses pay full latency.
        assert!(
            boff.breakdown.latency_s > bon.breakdown.latency_s,
            "latency on={:.2e} off={:.2e}",
            bon.breakdown.latency_s,
            boff.breakdown.latency_s
        );
    }

    #[test]
    fn page_size_knob_through_the_trait() {
        let p = platforms::by_name("skx").unwrap();
        let mut b: Box<dyn Backend> = Box::new(OpenMpSim::new(&p));
        assert_eq!(b.page_size(), Some(PageSize::FourKB));
        b.set_page_size(Some(PageSize::TwoMB));
        assert_eq!(b.page_size(), Some(PageSize::TwoMB));
        b.set_page_size(None);
        assert_eq!(b.page_size(), Some(PageSize::FourKB));

        let g = platforms::gpu_by_name("p100").unwrap();
        let mut c: Box<dyn Backend> = Box::new(CudaSim::new(&g));
        assert_eq!(c.page_size(), Some(PageSize::SixtyFourKB));
        c.set_page_size(Some(PageSize::OneGB));
        assert_eq!(c.page_size(), Some(PageSize::OneGB));

        let s = ScalarSim::with_page_size(&p, PageSize::TwoMB);
        assert_eq!(s.page_size(), Some(PageSize::TwoMB));
    }

    #[test]
    fn threads_knob_through_the_trait() {
        let p = platforms::by_name("skx").unwrap();
        let mut b: Box<dyn Backend> = Box::new(OpenMpSim::new(&p));
        assert_eq!(b.threads(), Some(16));
        b.set_threads(Some(4));
        assert_eq!(b.threads(), Some(4));
        b.set_threads(None);
        assert_eq!(b.threads(), Some(16));

        // A CLI-level --threads value is the restore target, not a
        // transient override.
        let mut c: Box<dyn Backend> =
            Box::new(OpenMpSim::configured(&p, None, Some(2)));
        assert_eq!(c.threads(), Some(2));
        c.set_threads(Some(8));
        c.set_threads(None);
        assert_eq!(c.threads(), Some(2));

        let mut s: Box<dyn Backend> =
            Box::new(ScalarSim::configured(&p, Some(PageSize::TwoMB), Some(3)));
        assert_eq!(s.threads(), Some(3));
        assert_eq!(s.page_size(), Some(PageSize::TwoMB));
        s.set_threads(None);
        assert_eq!(s.threads(), Some(3));

        // GPUs have no thread knob: the setter is a no-op.
        let g = platforms::gpu_by_name("p100").unwrap();
        let mut cu: Box<dyn Backend> = Box::new(CudaSim::new(&g));
        assert_eq!(cu.threads(), None);
        cu.set_threads(Some(64));
        assert_eq!(cu.threads(), None);
    }

    #[test]
    fn vector_regime_knob_through_the_trait() {
        let p = platforms::by_name("skx").unwrap();
        let mut b: Box<dyn Backend> = Box::new(OpenMpSim::new(&p));
        assert_eq!(b.vector_regime(), Some(VectorRegime::HardwareGS));
        b.set_vector_regime(Some(VectorRegime::Scalar));
        assert_eq!(b.vector_regime(), Some(VectorRegime::Scalar));
        b.set_vector_regime(None);
        assert_eq!(b.vector_regime(), Some(VectorRegime::HardwareGS));

        // A CLI-level --vector-regime value is the restore target, not
        // a transient override.
        let mut c: Box<dyn Backend> = Box::new(OpenMpSim::configured_regime(
            &p,
            None,
            None,
            Some(VectorRegime::EmulatedGather),
        ));
        assert_eq!(c.vector_regime(), Some(VectorRegime::EmulatedGather));
        c.set_vector_regime(Some(VectorRegime::Scalar));
        c.set_vector_regime(None);
        assert_eq!(c.vector_regime(), Some(VectorRegime::EmulatedGather));

        // The Scalar backend pins the scalar regime; the setter is a
        // no-op through the trait.
        let mut s: Box<dyn Backend> = Box::new(ScalarSim::new(&p));
        assert_eq!(s.vector_regime(), Some(VectorRegime::Scalar));
        s.set_vector_regime(Some(VectorRegime::HardwareGS));
        assert_eq!(s.vector_regime(), Some(VectorRegime::Scalar));

        // GPUs have no regime model: getter None, setter no-op.
        let g = platforms::gpu_by_name("p100").unwrap();
        let mut cu: Box<dyn Backend> = Box::new(CudaSim::new(&g));
        assert_eq!(cu.vector_regime(), None);
        cu.set_vector_regime(Some(VectorRegime::Scalar));
        assert_eq!(cu.vector_regime(), None);
    }

    #[test]
    fn numa_placement_knob_through_the_trait() {
        let p = platforms::by_name("skx-2s").unwrap();
        let mut b: Box<dyn Backend> = Box::new(OpenMpSim::new(&p));
        assert_eq!(b.numa_placement(), Some(NumaPlacement::FirstTouch));
        b.set_numa_placement(Some(NumaPlacement::Interleave));
        assert_eq!(b.numa_placement(), Some(NumaPlacement::Interleave));
        b.set_numa_placement(None);
        assert_eq!(b.numa_placement(), Some(NumaPlacement::FirstTouch));

        // A CLI-level --numa-placement value is the restore target,
        // not a transient override.
        let mut c: Box<dyn Backend> = Box::new(OpenMpSim::configured_numa(
            &p,
            None,
            None,
            None,
            Some(NumaPlacement::Interleave),
        ));
        assert_eq!(c.numa_placement(), Some(NumaPlacement::Interleave));
        c.set_numa_placement(Some(NumaPlacement::FirstTouch));
        c.set_numa_placement(None);
        assert_eq!(c.numa_placement(), Some(NumaPlacement::Interleave));

        // The Scalar backend carries the same NUMA model.
        let mut s: Box<dyn Backend> = Box::new(ScalarSim::new(&p));
        assert_eq!(s.numa_placement(), Some(NumaPlacement::FirstTouch));
        s.set_numa_placement(Some(NumaPlacement::Interleave));
        assert_eq!(s.numa_placement(), Some(NumaPlacement::Interleave));

        // GPUs have no NUMA model: getter None, setter no-op.
        let g = platforms::gpu_by_name("p100").unwrap();
        let mut cu: Box<dyn Backend> = Box::new(CudaSim::new(&g));
        assert_eq!(cu.numa_placement(), None);
        cu.set_numa_placement(Some(NumaPlacement::Interleave));
        assert_eq!(cu.numa_placement(), None);
    }

    #[test]
    fn fewer_threads_lower_stream_bandwidth() {
        let p = platforms::by_name("skx").unwrap();
        let dense = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(1 << 16);
        let full = OpenMpSim::new(&p)
            .run(&dense, Kernel::Gather)
            .unwrap()
            .bandwidth_gbs();
        let one = OpenMpSim::configured(&p, None, Some(1))
            .run(&dense, Kernel::Gather)
            .unwrap()
            .bandwidth_gbs();
        assert!(one < full, "1 thread {one:.1} vs {} threads {full:.1}", p.threads);
    }
}
