//! Real-execution backend: runs the pattern's gather/scatter through
//! the AOT-compiled L1/L2 kernels on PJRT-CPU and reports measured
//! wall-clock bandwidth.
//!
//! This is the "does the tool actually move the right bytes on real
//! hardware" leg of the reproduction (DESIGN.md §2): the timing
//! simulators model the paper's ten platforms; this backend executes
//! for real on the machine we do have.
//!
//! Timing uses the *checksum* variants (gather + scalar reduce), so the
//! readback is one f64 and the measured time is the kernel's own data
//! motion. The throughput variants are the `ref` family — XLA fuses
//! the jnp oracle into a single tight loop — while the `pallas` family
//! exercises the L1 kernel end-to-end for validation.

use std::time::Instant;

use super::Backend;
use crate::error::{Error, Result};
use crate::pattern::{Kernel, Pattern};
use crate::runtime::{PjRtBuffer, Runtime};
use crate::sim::{SimCounters, SimResult, TimeBreakdown};
use crate::stats;

/// Backend that executes patterns on the PJRT CPU client.
pub struct PjrtBackend {
    runtime: Runtime,
    /// Runs per pattern (paper protocol: 10, report min).
    pub runs: usize,
}

impl PjrtBackend {
    pub fn new(runtime: Runtime) -> PjrtBackend {
        PjrtBackend {
            runtime,
            runs: stats::RUNS_PER_PATTERN,
        }
    }

    /// Open over the default artifact directory.
    pub fn open_default() -> Result<PjrtBackend> {
        Ok(PjrtBackend::new(Runtime::open_default()?))
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Validate numerics: execute the smoke gather through both the
    /// Pallas-kernel artifact and the jnp-oracle artifact and compare
    /// against a host-computed reference. Returns the checksum.
    pub fn validate(&mut self) -> Result<f64> {
        let v = self
            .runtime
            .manifest()
            .find("gather", "ref", 8, Some(64))
            .ok_or_else(|| {
                Error::Runtime("no smoke gather variant (v8/c64)".into())
            })?
            .clone();
        let src: Vec<f64> = (0..v.n).map(|i| ((i * 13) % 251) as f64).collect();
        let idx: Vec<i32> = vec![0, 2, 4, 6, 8, 10, 12, 14];
        let delta = vec![8i32];
        let host: f64 = (0..v.count)
            .flat_map(|i| idx.iter().map(move |&ix| 8 * i + ix as usize))
            .map(|a| src[a])
            .sum();
        let sb = self.runtime.stage_f64(&src)?;
        let ib = self.runtime.stage_i32(&idx)?;
        let db = self.runtime.stage_i32(&delta)?;

        let out = self
            .runtime
            .execute(&v.name, &[&sb, &ib, &db])?
            .to_vec::<f64>()
            .map_err(|e| Error::Xla(e.to_string()))?;
        let dev: f64 = out.iter().sum();
        if (dev - host).abs() > 1e-6 * host.abs().max(1.0) {
            return Err(Error::Runtime(format!(
                "PJRT validation failed: device {dev} vs host {host}"
            )));
        }
        // Cross-check the Pallas-kernel artifact when present.
        if let Some(vp) = self
            .runtime
            .manifest()
            .find("gather", "pallas", 8, Some(64))
            .cloned()
        {
            let outp = self
                .runtime
                .execute(&vp.name, &[&sb, &ib, &db])?
                .to_vec::<f64>()
                .map_err(|e| Error::Xla(e.to_string()))?;
            if outp != out {
                return Err(Error::Runtime(
                    "Pallas artifact disagrees with jnp oracle artifact".into(),
                ));
            }
        }
        Ok(dev)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt-cpu"
    }

    /// Real execution: timings vary run to run, so repeated configs
    /// must actually run — the coordinator's memo cache is bypassed.
    fn deterministic(&self) -> bool {
        false
    }

    fn run(&mut self, pattern: &Pattern, kernel: Kernel) -> Result<SimResult> {
        pattern.validate_for(kernel)?;
        // No AOT'd artifacts exist for the indexed copy or the dense
        // baseline family: those kernels are simulation-only for now.
        if kernel == Kernel::GS || kernel.is_baseline() {
            return Err(Error::Runtime(format!(
                "the {} kernel is not implemented on the pjrt backend; \
                 use a simulated backend (openmp|scalar|cuda)",
                kernel.name()
            )));
        }
        let v = pattern.vector_len();
        let (ck_kernel, family) = match kernel {
            Kernel::Gather => ("gather_checksum", "ref"),
            Kernel::Scatter => ("scatter_checksum", "ref"),
            _ => unreachable!("rejected above"),
        };
        let variant = self
            .runtime
            .manifest()
            .find_largest(ck_kernel, family, v)
            .ok_or_else(|| {
                let avail = self.runtime.manifest().available_v(ck_kernel, family);
                Error::Runtime(format!(
                    "no {ck_kernel} artifact for index length {v} \
                     (available: {avail:?}) — regenerate with `make artifacts`"
                ))
            })?
            .clone();

        // The artifact executes `variant.count` gathers per call; delta
        // is clamped so all addresses stay inside the artifact's source
        // array (XLA clamps OOB — keep the traffic honest instead).
        let max_delta = if variant.count > 1 {
            ((variant.n as i64 - 1 - pattern.max_index()).max(0))
                / (variant.count as i64 - 1)
        } else {
            pattern.delta
        };
        let delta_eff = pattern.delta.min(max_delta).max(0);
        let idx: Vec<i32> = pattern.indices.iter().map(|&i| i as i32).collect();
        let delta = vec![delta_eff as i32];

        // Stage inputs once; the 10 timed runs reuse device buffers.
        let src: Vec<f64> = (0..variant.n).map(|i| (i % 1021) as f64).collect();
        let sb = self.runtime.stage_f64(&src)?;
        let ib = self.runtime.stage_i32(&idx)?;
        let db = self.runtime.stage_i32(&delta)?;
        let vals; // scatter values buffer, staged lazily
        let dstb;
        let args: Vec<&PjRtBuffer> = match kernel {
            Kernel::Gather => vec![&sb, &ib, &db],
            Kernel::GS | Kernel::Stream(_) | Kernel::Gups => {
                unreachable!("rejected above")
            }
            Kernel::Scatter => {
                let v2: Vec<f64> =
                    (0..variant.count * v).map(|i| (i % 613) as f64).collect();
                vals = self.runtime.stage_f64_2d(&v2, variant.count, v)?;
                dstb = self.runtime.stage_f64(&src)?;
                vec![&vals, &ib, &db, &dstb]
            }
        };

        // Warmup (compile + first run), then the paper's 10-run min.
        let mut checksum = self.runtime.execute_scalar(&variant.name, &args)?;
        let mut times = Vec::with_capacity(self.runs);
        for _ in 0..self.runs {
            let t0 = Instant::now();
            checksum = self.runtime.execute_scalar(&variant.name, &args)?;
            times.push(t0.elapsed().as_secs_f64());
        }
        let summary = stats::RunSummary::from_times(&times)
            .ok_or_else(|| Error::Runtime("no timed runs".into()))?;

        // Scale measured per-execution time to the requested count.
        let scale = pattern.count as f64 / variant.count as f64;
        let _ = checksum; // numeric readback proves execution happened
        Ok(SimResult {
            seconds: summary.min_seconds * scale,
            useful_bytes: pattern.moved_bytes() as u64,
            counters: SimCounters {
                accesses: (variant.count * v) as u64,
                ..Default::default()
            },
            breakdown: TimeBreakdown::default(),
            simulated_iterations: variant.count,
            closed_at_iteration: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    fn have_artifacts() -> bool {
        cfg!(feature = "xla")
            && default_artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn validate_numerics() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let mut b = PjrtBackend::open_default().unwrap();
        let sum = b.validate().unwrap();
        assert!(sum.is_finite() && sum > 0.0);
    }

    #[test]
    fn gather_run_reports_positive_bandwidth() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let mut b = PjrtBackend::open_default().unwrap();
        b.runs = 3;
        let pat = Pattern::parse("UNIFORM:8:1")
            .unwrap()
            .with_delta(8)
            .with_count(1 << 16);
        let r = b.run(&pat, Kernel::Gather).unwrap();
        assert!(r.bandwidth_gbs() > 0.05, "{}", r.bandwidth_gbs());
    }

    #[test]
    fn scatter_run_works() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let mut b = PjrtBackend::open_default().unwrap();
        b.runs = 2;
        let pat = Pattern::parse("UNIFORM:16:2")
            .unwrap()
            .with_delta(32)
            .with_count(1 << 12);
        let r = b.run(&pat, Kernel::Scatter).unwrap();
        assert!(r.seconds > 0.0);
    }

    #[test]
    fn missing_vector_length_is_a_clear_error() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let mut b = PjrtBackend::open_default().unwrap();
        let pat = Pattern::from_indices("odd", vec![0, 1, 2]).with_count(10);
        let err = b.run(&pat, Kernel::Gather).unwrap_err();
        assert!(err.to_string().contains("index length 3"), "{err}");
    }
}
