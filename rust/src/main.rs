//! `spatter` — the benchmark CLI (paper §3 usage).
//!
//! ```text
//! spatter -k Gather -p UNIFORM:8:1 -d 8 -l 2^24 -a skx
//! spatter -j config.json -a bdw -b scalar
//! spatter --suite all --out bench_out
//! ```

use std::path::Path;
use std::process::ExitCode;

use spatter::backends::{Backend, CudaSim, OpenMpSim, PjrtBackend, ScalarSim};
use spatter::cli::{self, BackendKind, Command, CommonArgs};
use spatter::coordinator::{self, Aggregate, RunRecord};
use spatter::error::{Error, Result};
use spatter::json::{self, Value};
use spatter::pattern::table5;
use spatter::platforms;
use spatter::report::Table;
use spatter::suite;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("spatter: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    match cli::parse_args(args)? {
        Command::Help => {
            println!("{}", cli::USAGE);
            Ok(())
        }
        Command::ListPlatforms => {
            let mut t = Table::new(&["name", "type", "description", "STREAM GB/s"]);
            for p in platforms::all() {
                t.row(&[
                    p.name().to_string(),
                    if p.is_gpu() { "GPU" } else { "CPU" }.to_string(),
                    p.full_name().to_string(),
                    format!("{:.1}", p.stream_gbs()),
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
        Command::ListPatterns => {
            let mut t = Table::new(&["name", "kernel", "delta", "class", "index buffer (head)"]);
            for p in table5::all() {
                t.row(&[
                    p.name.to_string(),
                    p.kernel.name().to_string(),
                    p.delta.to_string(),
                    if p.class.is_empty() { "Complex" } else { p.class }.to_string(),
                    format!("{:?}...", &p.indices[..6.min(p.indices.len())]),
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
        Command::Suite { name, out_dir } => {
            let ctx = suite::SuiteContext::new(Path::new(&out_dir));
            let report = suite::run(&name, &ctx)?;
            println!("{report}");
            println!("CSV series written to {out_dir}/");
            Ok(())
        }
        Command::Run(r) => {
            let record = with_backend(&r.common, |backend| {
                coordinator::run_one(backend, &r.pattern.spec, &r.pattern, r.kernel)
            })?;
            emit(&[record], &r.common);
            Ok(())
        }
        Command::Json { path, common } => {
            let configs = coordinator::parse_config_file(Path::new(&path))?;
            let records = with_backend(&common, |backend| {
                coordinator::run_configs(backend, &configs)
            })?;
            emit(&records, &common);
            Ok(())
        }
    }
}

/// Build the selected backend and run `f` against it.
fn with_backend<T>(
    common: &CommonArgs,
    f: impl FnOnce(&mut dyn Backend) -> Result<T>,
) -> Result<T> {
    match common.backend {
        BackendKind::OpenMp => {
            let p = platforms::by_name(&common.platform)?;
            let mut b = match common.page_size {
                Some(page) => OpenMpSim::with_page_size(&p, page),
                None => OpenMpSim::new(&p),
            };
            f(&mut b)
        }
        BackendKind::Scalar => {
            let p = platforms::by_name(&common.platform)?;
            let mut b = match common.page_size {
                Some(page) => ScalarSim::with_page_size(&p, page),
                None => ScalarSim::new(&p),
            };
            f(&mut b)
        }
        BackendKind::Cuda => {
            let p = platforms::gpu_by_name(&common.platform).map_err(|_| {
                Error::Cli(format!(
                    "backend cuda needs a GPU platform (got '{}'); try k40c, \
                     titanxp, p100, v100",
                    common.platform
                ))
            })?;
            let mut b = match common.page_size {
                Some(page) => CudaSim::with_page_size(&p, page),
                None => CudaSim::new(&p),
            };
            f(&mut b)
        }
        BackendKind::Pjrt => {
            let mut b = PjrtBackend::open_default()?;
            if common.validate {
                b.validate()?;
            }
            b.runs = common.runs;
            f(&mut b)
        }
    }
}

/// Print records as a table (default) or JSON (--json-out), plus the
/// paper's aggregate stats for multi-run sets.
fn emit(records: &[RunRecord], common: &CommonArgs) {
    if common.json_out {
        let arr: Vec<Value> = records.iter().map(|r| r.to_json()).collect();
        let mut doc = vec![("runs".to_string(), Value::Array(arr))];
        if let Some(agg) = Aggregate::from_records(records) {
            doc.push(("aggregate".to_string(), agg.to_json()));
        }
        let obj = Value::Object(doc.into_iter().collect());
        println!("{}", json::to_string_pretty(&obj));
        return;
    }
    let mut t = Table::new(&[
        "name", "kernel", "V", "delta", "count", "page", "time (s)", "GB/s",
        "TLB hit%", "bound by",
    ]);
    for r in records {
        t.row(&[
            r.name.clone(),
            r.kernel.name().to_string(),
            r.vector_len.to_string(),
            r.delta.to_string(),
            r.count.to_string(),
            r.page_size.clone().unwrap_or_else(|| "-".to_string()),
            format!("{:.6}", r.seconds),
            format!("{:.2}", r.bandwidth_gbs),
            match r.tlb_hit_rate {
                Some(rate) => format!("{:.1}", rate * 100.0),
                None => "-".to_string(),
            },
            r.bottleneck.clone(),
        ]);
    }
    println!("{}", t.render());
    if records.len() > 1 {
        if let Some(agg) = Aggregate::from_records(records) {
            println!(
                "aggregate over {} configs: min {:.2} GB/s, max {:.2} GB/s, \
                 harmonic mean {:.2} GB/s",
                agg.runs, agg.min_gbs, agg.max_gbs, agg.harmonic_mean_gbs
            );
        }
    }
}

/// One gather kernel invocation used by tests to assert the binary
/// wiring stays intact.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_id_on_cli() {
        let args: Vec<String> = "-k Gather -p PENNANT-G4 -l 1024 -a skx"
            .split_whitespace()
            .map(|s| s.to_string())
            .collect();
        run(&args).unwrap();
    }

    #[test]
    fn run_invocation_end_to_end() {
        let args: Vec<String> = "-k Gather -p UNIFORM:8:2 -d 16 -l 4096 -a bdw"
            .split_whitespace()
            .map(|s| s.to_string())
            .collect();
        run(&args).unwrap();
    }

    #[test]
    fn bad_platform_is_error() {
        let args: Vec<String> = "-k Gather -p UNIFORM:8:2 -d 16 -a nope"
            .split_whitespace()
            .map(|s| s.to_string())
            .collect();
        assert!(run(&args).is_err());
    }
}
