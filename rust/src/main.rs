//! `spatter` — the benchmark CLI (paper §3 usage).
//!
//! ```text
//! spatter -k Gather -p UNIFORM:8:1 -d 8 -l 2^24 -a skx
//! spatter -j config.json -a bdw -b scalar
//! spatter --suite all --out bench_out
//! ```

use std::path::Path;
use std::process::ExitCode;
use std::time::Instant;

use spatter::backends::{Backend, CudaSim, OpenMpSim, PjrtBackend, ScalarSim};
use spatter::cli::{self, BackendKind, Command, CommonArgs};
use spatter::coordinator::{self, RunRecord};
use spatter::error::{Error, Result};
use spatter::pattern::table5;
use spatter::platforms;
use spatter::report::Table;
use spatter::suite;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("spatter: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    match cli::parse_args(args)? {
        Command::Help => {
            println!("{}", cli::USAGE);
            Ok(())
        }
        Command::ListPlatforms => {
            let mut t = Table::new(&["name", "type", "description", "STREAM GB/s"]);
            for p in platforms::all() {
                t.row(&[
                    p.name().to_string(),
                    if p.is_gpu() { "GPU" } else { "CPU" }.to_string(),
                    p.full_name().to_string(),
                    format!("{:.1}", p.stream_gbs()),
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
        Command::ListPatterns => {
            let mut t = Table::new(&["name", "kernel", "delta", "class", "index buffer (head)"]);
            for p in table5::all() {
                t.row(&[
                    p.name.to_string(),
                    p.kernel.name().to_string(),
                    p.delta.to_string(),
                    if p.class.is_empty() { "Complex" } else { p.class }.to_string(),
                    format!("{:?}...", &p.indices[..6.min(p.indices.len())]),
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
        Command::Suite {
            name,
            out_dir,
            jobs,
            fast,
        } => {
            let base = if fast {
                suite::SuiteContext::fast(Path::new(&out_dir))
            } else {
                suite::SuiteContext::new(Path::new(&out_dir))
            };
            let ctx = base.with_jobs(jobs);
            let sim0 = coordinator::sim_accesses_total();
            let t0 = Instant::now();
            let report = suite::run(&name, &ctx)?;
            println!("{report}");
            println!("CSV series written to {out_dir}/");
            eprintln!(
                "spatter: suite '{name}' ran on {} jobs in {:.3}s wall-clock",
                ctx.jobs,
                t0.elapsed().as_secs_f64()
            );
            report_sim_rate(sim0, t0.elapsed().as_secs_f64());
            Ok(())
        }
        Command::Run(r) => {
            let mut backend = build_backend(&r.common)?;
            let record = coordinator::run_one(
                backend.as_mut(),
                &r.pattern.spec,
                &r.pattern,
                r.kernel,
            )?;
            if let Some(i) = record.closed_at {
                eprintln!(
                    "spatter: sim-closure: steady state reached at iteration \
                     {i}; remaining iterations closed analytically"
                );
            }
            emit(&[record], &r.common);
            Ok(())
        }
        Command::Json { path, common } => {
            // Real execution measures wall-clock time: concurrent
            // workers would contend for the host's cores and depress
            // every reported bandwidth. Simulated backends are
            // contention-free, so only they fan out.
            let jobs = if common.backend == BackendKind::Pjrt {
                1
            } else {
                common.jobs
            };
            let memo_on = coordinator::memo_enabled_from_env();
            let sim0 = coordinator::sim_accesses_total();
            let t0 = Instant::now();
            if common.stream {
                let source =
                    coordinator::stream_config_file(Path::new(&path))?;
                let stdout = std::io::stdout();
                let mut out = stdout.lock();
                let summary = coordinator::run_configs_stream(
                    &|| build_backend(&common),
                    source,
                    jobs,
                    memo_on,
                    |chunk| {
                        use std::io::Write;
                        out.write_all(chunk.as_bytes()).map_err(Error::Io)
                    },
                )?;
                eprintln!(
                    "spatter: {} configs streamed on {} jobs in {:.3}s \
                     wall-clock",
                    summary.records,
                    jobs,
                    t0.elapsed().as_secs_f64()
                );
                report_sim_rate(sim0, t0.elapsed().as_secs_f64());
                report_memo(summary.memo, memo_on);
                return Ok(());
            }
            let configs = coordinator::parse_config_file(Path::new(&path))?;
            let (records, memo) = coordinator::run_configs_jobs_stats(
                &|| build_backend(&common),
                &configs,
                jobs,
            )?;
            eprintln!(
                "spatter: {} configs ran on {} jobs in {:.3}s wall-clock",
                configs.len(),
                jobs.min(configs.len().max(1)),
                t0.elapsed().as_secs_f64()
            );
            report_sim_rate(sim0, t0.elapsed().as_secs_f64());
            report_memo(memo, memo_on);
            emit(&records, &common);
            Ok(())
        }
    }
}

/// One stderr line with the sweep's host simulation throughput:
/// simulated accesses recorded since `before`, divided by the wall
/// clock. Campaign-level — memo-served records replay their run's
/// access counts — and host-dependent by design; the deterministic
/// per-run figure is the `"sim-rate"` JSON key. Silent when nothing
/// was simulated (real-execution backends report no access counts).
fn report_sim_rate(before: u64, secs: f64) {
    let accesses = coordinator::sim_accesses_total() - before;
    if accesses > 0 && secs > 0.0 {
        eprintln!(
            "spatter: sim-rate: {:.3e} simulated accesses/s \
             ({accesses} accesses in {secs:.3}s)",
            accesses as f64 / secs
        );
    }
}

/// One stderr line with the campaign's memo-cache economics. Silent
/// when the cache was disabled (SPATTER_NO_MEMO=1) or bypassed (real
/// execution performs no lookups).
fn report_memo(stats: coordinator::MemoStats, enabled: bool) {
    if enabled && stats.total() > 0 {
        eprintln!(
            "spatter: memo cache: {} hits / {} lookups ({:.0}% hit rate)",
            stats.hits,
            stats.total(),
            stats.hit_rate() * 100.0
        );
    }
}

/// Build the selected backend from the common CLI knobs. Called once
/// per worker by the parallel scheduler (engines are stateful, so
/// every worker owns its own).
fn build_backend(common: &CommonArgs) -> Result<Box<dyn Backend>> {
    match common.backend {
        BackendKind::OpenMp => {
            let p = platforms::by_name(&common.platform)?;
            // Reject an unsupported regime here, before any run: the
            // engine would error identically per run, but one eager
            // CLI-level message beats N per-config failures.
            if let Some(r) = common.vector_regime {
                if !p.supports_regime(r) {
                    return Err(Error::Cli(format!(
                        "platform '{}' does not support --vector-regime \
                         '{r}' (supported: {})",
                        p.name,
                        p.supported_regimes()
                            .iter()
                            .map(|r| r.name())
                            .collect::<Vec<_>>()
                            .join("|"),
                    )));
                }
            }
            Ok(Box::new(OpenMpSim::configured_numa(
                &p,
                common.page_size,
                common.threads,
                common.vector_regime,
                common.numa_placement,
            )))
        }
        BackendKind::Scalar => {
            if common.vector_regime.is_some() {
                return Err(Error::Cli(
                    "the scalar backend pins the scalar regime (#pragma \
                     novec baseline); use -b openmp --vector-regime ... to \
                     pick a regime"
                        .into(),
                ));
            }
            let p = platforms::by_name(&common.platform)?;
            Ok(Box::new(ScalarSim::configured_numa(
                &p,
                common.page_size,
                common.threads,
                common.numa_placement,
            )))
        }
        BackendKind::Cuda => {
            let p = platforms::gpu_by_name(&common.platform).map_err(|_| {
                Error::Cli(format!(
                    "backend cuda needs a GPU platform (got '{}'); try k40c, \
                     titanxp, p100, v100",
                    common.platform
                ))
            })?;
            if common.threads.is_some() {
                return Err(Error::Cli(
                    "--threads applies to CPU backends (openmp|scalar); the \
                     cuda backend has no thread knob"
                        .into(),
                ));
            }
            if common.vector_regime.is_some() {
                return Err(Error::Cli(
                    "--vector-regime applies to the openmp backend; the cuda \
                     backend models warp coalescing, not a vector ISA"
                        .into(),
                ));
            }
            if common.numa_placement.is_some() {
                return Err(Error::Cli(
                    "--numa-placement applies to the CPU simulation backends \
                     (openmp|scalar); the cuda backend models a single GPU \
                     device"
                        .into(),
                ));
            }
            let b = match common.page_size {
                Some(page) => CudaSim::with_page_size(&p, page),
                None => CudaSim::new(&p),
            };
            Ok(Box::new(b))
        }
        BackendKind::Pjrt => {
            if common.threads.is_some() {
                return Err(Error::Cli(
                    "--threads applies to CPU backends (openmp|scalar); pjrt \
                     executes with the host's real threads"
                        .into(),
                ));
            }
            if common.vector_regime.is_some() {
                return Err(Error::Cli(
                    "--vector-regime applies to the openmp backend; pjrt \
                     executes with the host's real vector units"
                        .into(),
                ));
            }
            if common.numa_placement.is_some() {
                return Err(Error::Cli(
                    "--numa-placement applies to the CPU simulation backends \
                     (openmp|scalar); pjrt executes on the host's real memory"
                        .into(),
                ));
            }
            let mut b = PjrtBackend::open_default()?;
            if common.validate {
                b.validate()?;
            }
            b.runs = common.runs;
            Ok(Box::new(b))
        }
    }
}

/// Print records as a table (default) or JSON (--json-out), through
/// the same renderers the suites and determinism tests use.
fn emit(records: &[RunRecord], common: &CommonArgs) {
    if common.json_out {
        print!("{}", coordinator::render_json(records));
    } else {
        print!("{}", coordinator::render_table(records));
    }
}

/// One gather kernel invocation used by tests to assert the binary
/// wiring stays intact.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_id_on_cli() {
        let args: Vec<String> = "-k Gather -p PENNANT-G4 -l 1024 -a skx"
            .split_whitespace()
            .map(|s| s.to_string())
            .collect();
        run(&args).unwrap();
    }

    #[test]
    fn run_invocation_end_to_end() {
        let args: Vec<String> = "-k Gather -p UNIFORM:8:2 -d 16 -l 4096 -a bdw"
            .split_whitespace()
            .map(|s| s.to_string())
            .collect();
        run(&args).unwrap();
    }

    #[test]
    fn gs_invocation_end_to_end() {
        let args: Vec<String> =
            "-k GS -g UNIFORM:8:4 -u UNIFORM:8:1 -d 32 -l 4096 -a skx"
                .split_whitespace()
                .map(|s| s.to_string())
                .collect();
        run(&args).unwrap();
        // And on the GPU backend.
        let args: Vec<String> =
            "-k GS -g UNIFORM:256:4 -u UNIFORM:256:1 -d 1024 -l 2048 -b cuda -a p100"
                .split_whitespace()
                .map(|s| s.to_string())
                .collect();
        run(&args).unwrap();
    }

    #[test]
    fn stream_invocation_end_to_end() {
        let path = std::env::temp_dir().join("spatter_stream_e2e_cfg.json");
        std::fs::write(
            &path,
            r#"[
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 4096},
              {"kernel": "Scatter", "pattern": "UNIFORM:8:2", "delta": 16,
               "count": 4096},
              {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
               "count": 4096}
            ]"#,
        )
        .unwrap();
        let args: Vec<String> =
            format!("-j {} --stream --json-out --jobs 2 -a skx", path.display())
                .split_whitespace()
                .map(|s| s.to_string())
                .collect();
        run(&args).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn vector_regime_invocations_end_to_end() {
        let argv = |s: &str| -> Vec<String> {
            s.split_whitespace().map(|t| t.to_string()).collect()
        };
        // A supported override runs; an ISA the platform lacks is an
        // eager CLI error, as are non-CPU-sim backends.
        run(&argv(
            "-k Gather -p UNIFORM:8:2 -d 16 -l 4096 -a skx \
             --vector-regime scalar",
        ))
        .unwrap();
        run(&argv(
            "-k Gather -p UNIFORM:8:2 -d 16 -l 4096 -a tx2 \
             --vector-regime masked-sve",
        ))
        .unwrap();
        let err = run(&argv(
            "-k Gather -p UNIFORM:8:1 -d 8 -l 64 -a tx2 \
             --vector-regime hardware-gs",
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("tx2"), "{err}");
        assert!(err.contains("masked-sve"), "{err}");
        assert!(run(&argv(
            "-k Gather -p UNIFORM:8:1 -d 8 -l 64 -a skx -b scalar \
             --vector-regime scalar"
        ))
        .is_err());
        assert!(run(&argv(
            "-k Gather -p UNIFORM:256:1 -d 256 -l 64 -a p100 -b cuda \
             --vector-regime scalar"
        ))
        .is_err());
    }

    #[test]
    fn numa_placement_invocations_end_to_end() {
        let argv = |s: &str| -> Vec<String> {
            s.split_whitespace().map(|t| t.to_string()).collect()
        };
        // Both placements run on a two-socket platform; the knob is
        // inert but accepted on single-socket CPUs.
        run(&argv(
            "-k Gather -p UNIFORM:8:2 -d 16 -l 4096 -a skx-2s \
             --numa-placement interleave",
        ))
        .unwrap();
        run(&argv(
            "-k Scatter -p UNIFORM:8:1 -d 8 -l 4096 -a skx-2s \
             --numa-placement first-touch -b scalar",
        ))
        .unwrap();
        run(&argv(
            "-k Gather -p UNIFORM:8:2 -d 16 -l 4096 -a skx \
             --numa-placement interleave",
        ))
        .unwrap();
        // Backends without a NUMA model reject the flag eagerly.
        assert!(run(&argv(
            "-k Gather -p UNIFORM:256:1 -d 256 -l 64 -a p100 -b cuda \
             --numa-placement interleave"
        ))
        .is_err());
    }

    #[test]
    fn bad_platform_is_error() {
        let args: Vec<String> = "-k Gather -p UNIFORM:8:2 -d 16 -a nope"
            .split_whitespace()
            .map(|s| s.to_string())
            .collect();
        assert!(run(&args).is_err());
    }
}
