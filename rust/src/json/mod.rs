//! Minimal JSON substrate (no `serde` in the offline vendor set).
//!
//! Spatter needs JSON in three places: multi-pattern run configs
//! (paper §3.3 “JSON Specification”), the AOT artifact manifest written
//! by `python/compile/aot.py`, and machine-readable result output.
//! This module provides a strict RFC-8259 parser, a value model, and a
//! writer — enough for all three, with real error positions.

mod parse;
mod write;

pub use parse::{parse, ArrayStream};
pub use write::{to_string, to_string_pretty, to_string_pretty_at};

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value. Objects use a BTreeMap so output is
/// deterministic (useful for golden-file tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Fetch `key` from an object, or a schema error naming the key.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Object(m) => m
                .get(key)
                .ok_or_else(|| Error::Json(format!("missing key '{key}'"))),
            _ => Err(Error::Json(format!(
                "expected object while looking up '{key}'"
            ))),
        }
    }

    /// Optional object lookup.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::String(s) => Ok(s),
            v => Err(Error::Json(format!("expected string, got {}", v.kind()))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Number(n) => Ok(*n),
            v => Err(Error::Json(format!("expected number, got {}", v.kind()))),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n.abs() > 9.0e15 {
            return Err(Error::Json(format!("expected integer, got {n}")));
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_i64()?;
        usize::try_from(n)
            .map_err(|_| Error::Json(format!("expected non-negative integer, got {n}")))
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => Err(Error::Json(format!("expected bool, got {}", v.kind()))),
        }
    }

    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            v => Err(Error::Json(format!("expected array, got {}", v.kind()))),
        }
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Ok(m),
            v => Err(Error::Json(format!("expected object, got {}", v.kind()))),
        }
    }

    /// Human-readable kind name for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Number(_) => "number",
            Value::String(_) => "string",
            Value::Array(_) => "array",
            Value::Object(_) => "object",
        }
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Number(n)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Number(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Number(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::String(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::String(s)
    }
}
impl From<Vec<Value>> for Value {
    fn from(a: Vec<Value>) -> Self {
        Value::Array(a)
    }
}

/// Convenience builder for objects: `obj(&[("k", v)])`.
pub fn obj(pairs: &[(&str, Value)]) -> Value {
    Value::Object(
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_roundtrip() {
        let v = obj(&[
            ("a", Value::from(1i64)),
            ("b", Value::from("x")),
            ("c", Value::from(true)),
            ("d", Value::Array(vec![Value::from(2i64)])),
        ]);
        assert_eq!(v.get("a").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x");
        assert!(v.get("c").unwrap().as_bool().unwrap());
        assert_eq!(v.get("d").unwrap().as_array().unwrap().len(), 1);
        assert!(v.get("missing").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
    }

    #[test]
    fn integer_bounds() {
        assert!(Value::Number(1.5).as_i64().is_err());
        assert!(Value::Number(-1.0).as_usize().is_err());
        assert_eq!(Value::Number(42.0).as_usize().unwrap(), 42);
    }

    #[test]
    fn kind_names() {
        assert_eq!(Value::Null.kind(), "null");
        assert_eq!(Value::Number(0.0).kind(), "number");
        assert_eq!(Value::Array(vec![]).kind(), "array");
    }
}
