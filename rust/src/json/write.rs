//! JSON serialization: compact and pretty writers.

use super::Value;

/// Serialize compactly (no whitespace).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

/// Serialize with 2-space indentation as a fragment sitting `depth`
/// nesting levels deep: continuation lines are indented as
/// [`to_string_pretty`] would indent them inside an enclosing document
/// (the first line carries no leading indent — the caller has already
/// emitted the surrounding punctuation). This is what lets a streaming
/// writer emit a large document chunk-by-chunk, byte-identical to the
/// batch renderer.
pub fn to_string_pretty_at(v: &Value, depth: usize) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), depth);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no Inf/NaN; emit null like most writers in lenient mode.
        out.push_str("null");
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{obj, parse, Value};
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let v = obj(&[
            ("name", Value::from("UNIFORM:8:1")),
            ("delta", Value::from(8i64)),
            ("bw", Value::from(43.885)),
            ("ok", Value::from(true)),
            (
                "series",
                Value::Array(vec![Value::from(1i64), Value::Null]),
            ),
        ]);
        let text = to_string(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = obj(&[("a", Value::Array(vec![Value::from(1i64)]))]);
        let text = to_string_pretty(&v);
        assert!(text.contains('\n'));
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_not_floats() {
        assert_eq!(to_string(&Value::Number(8.0)), "8");
        assert_eq!(to_string(&Value::Number(0.5)), "0.5");
    }

    #[test]
    fn string_escaping_roundtrip() {
        let s = "a\"b\\c\nd\te\u{0001}";
        let v = Value::String(s.to_string());
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(to_string(&Value::Array(vec![])), "[]");
        assert_eq!(to_string(&obj(&[])), "{}");
    }

    #[test]
    fn nonfinite_to_null() {
        assert_eq!(to_string(&Value::Number(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Number(f64::INFINITY)), "null");
    }

    #[test]
    fn pretty_at_fragments_reassemble_the_batch_document() {
        let r1 = obj(&[("a", Value::from(1i64))]);
        let r2 = obj(&[("b", Value::Array(vec![Value::from(2i64)]))]);
        let doc = obj(&[("runs", Value::Array(vec![r1.clone(), r2.clone()]))]);
        let mut streamed = String::from("{\n  \"runs\": [");
        streamed.push_str("\n    ");
        streamed.push_str(&to_string_pretty_at(&r1, 2));
        streamed.push(',');
        streamed.push_str("\n    ");
        streamed.push_str(&to_string_pretty_at(&r2, 2));
        streamed.push_str("\n  ]\n}");
        assert_eq!(streamed, to_string_pretty(&doc));
    }
}
