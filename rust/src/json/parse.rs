//! Recursive-descent JSON parser with line/column error reporting.

use std::collections::BTreeMap;

use super::Value;
use crate::error::{Error, Result};

/// Parse a complete JSON document. Trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser::new(input);
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if !p.at_end() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(input: &'a str) -> Self {
        Parser {
            bytes: input.as_bytes(),
            pos: 0,
        }
    }

    fn err(&self, msg: &str) -> Error {
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else if !(0x80..=0xBF).contains(&b) {
                // Columns count characters, not bytes: UTF-8
                // continuation bytes don't start a new character, so a
                // multibyte sequence advances the column exactly once.
                col += 1;
            }
        }
        Error::Json(format!("{msg} at line {line} col {col}"))
    }

    fn at_end(&self) -> bool {
        self.pos >= self.bytes.len()
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, want: u8) -> Result<()> {
        match self.bump() {
            Some(b) if b == want => Ok(()),
            Some(b) => Err(self.err(&format!(
                "expected '{}', found '{}'",
                want as char, b as char
            ))),
            None => Err(self.err(&format!("expected '{}', found EOF", want as char))),
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.err(&format!("unexpected character '{}'", b as char))),
            None => Err(self.err("unexpected EOF")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected '{word}')")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                Some(b) => {
                    return Err(self.err(&format!(
                        "expected ',' or '}}' in object, found '{}'",
                        b as char
                    )))
                }
                None => return Err(self.err("unterminated object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                Some(b) => {
                    return Err(self.err(&format!(
                        "expected ',' or ']' in array, found '{}'",
                        b as char
                    )))
                }
                None => return Err(self.err("unterminated array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle UTF-16 surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                    }
                    Some(b) => {
                        return Err(
                            self.err(&format!("invalid escape '\\{}'", b as char))
                        )
                    }
                    None => return Err(self.err("unterminated escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-for-byte.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b)
                            .ok_or_else(|| self.err("invalid UTF-8 lead byte"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8 sequence"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8 sequence"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
            }
            Some(b) if b.is_ascii_digit() => {
                while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // fraction
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !self.peek().is_some_and(|b| b.is_ascii_digit()) {
                return Err(self.err("digit required after decimal point"));
            }
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // exponent
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !self.peek().is_some_and(|b| b.is_ascii_digit()) {
                return Err(self.err("digit required in exponent"));
            }
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(lead: u8) -> Option<usize> {
    match lead {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

/// Read chunk for [`ArrayStream`] refills.
const STREAM_CHUNK: usize = 16 * 1024;

/// Incremental reader of a top-level JSON array: yields one parsed
/// element at a time without ever materializing the whole document —
/// the memory high-water mark is one chunk plus the largest single
/// element, independent of how many elements the array holds.
///
/// The element boundary scan is a byte-level automaton (string /
/// escape / bracket depth), so braces and brackets inside strings
/// never confuse it; each complete element slice then goes through the
/// ordinary strict [`parse`]. Element errors carry both the element
/// index and the element's absolute byte offset in the source ("config
/// stream element N (byte B): ..."); line/col inside the message stay
/// element-relative, since the document is never held in one piece.
pub struct ArrayStream<R: std::io::Read> {
    src: R,
    buf: Vec<u8>,
    /// First unconsumed byte of `buf`.
    start: usize,
    /// Bytes dropped from the front of `buf` by [`Self::compact`]:
    /// `buf[i]` sits at absolute source offset `consumed + i`.
    consumed: u64,
    /// `[` has been consumed.
    started: bool,
    /// Elements yielded so far.
    count: usize,
    /// `]` consumed and trailer validated, or a terminal error.
    finished: bool,
}

impl<R: std::io::Read> ArrayStream<R> {
    pub fn new(src: R) -> ArrayStream<R> {
        ArrayStream {
            src,
            buf: Vec::new(),
            start: 0,
            consumed: 0,
            started: false,
            count: 0,
            finished: false,
        }
    }

    /// Pull one more chunk off the source; `Ok(false)` at EOF.
    fn fill(&mut self) -> Result<bool> {
        let mut chunk = [0u8; STREAM_CHUNK];
        let n = self.src.read(&mut chunk).map_err(Error::Io)?;
        if n == 0 {
            return Ok(false);
        }
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(true)
    }

    /// Drop consumed bytes (called only between elements, so element
    /// ranges under scan are never invalidated).
    fn compact(&mut self) {
        if self.start > 0 {
            self.consumed += self.start as u64;
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    /// The next non-whitespace byte at/after `start` (not consumed),
    /// refilling as needed; `None` at EOF.
    fn next_non_ws(&mut self) -> Result<Option<u8>> {
        loop {
            while self.start < self.buf.len() {
                let b = self.buf[self.start];
                if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                    self.start += 1;
                } else {
                    return Ok(Some(b));
                }
            }
            self.compact();
            if !self.fill()? {
                return Ok(None);
            }
        }
    }

    /// Scan one element starting at `start` (known non-ws, not a
    /// delimiter), buffering until its top-level `,` or `]` delimiter
    /// is visible. Returns the element's byte range; the delimiter at
    /// the range's end is left unconsumed.
    fn scan_element(&mut self) -> Result<(usize, usize)> {
        let begin = self.start;
        let mut i = self.start;
        let mut depth = 0usize;
        let mut in_string = false;
        let mut escaped = false;
        loop {
            while i < self.buf.len() {
                let b = self.buf[i];
                if in_string {
                    if escaped {
                        escaped = false;
                    } else if b == b'\\' {
                        escaped = true;
                    } else if b == b'"' {
                        in_string = false;
                    }
                } else {
                    match b {
                        b'"' => in_string = true,
                        b'{' | b'[' => depth += 1,
                        b'}' | b']' if depth > 0 => depth -= 1,
                        b']' => return Ok((begin, i)),
                        b'}' => {
                            return Err(Error::Json(
                                "config stream: unbalanced '}'".into(),
                            ))
                        }
                        b',' if depth == 0 => return Ok((begin, i)),
                        _ => {}
                    }
                }
                i += 1;
            }
            if !self.fill()? {
                return Err(Error::Json(
                    "config stream: unterminated array element".into(),
                ));
            }
        }
    }

    /// Validate that only whitespace follows the closing `]`.
    fn finish_trailer(&mut self) -> Result<Option<Value>> {
        if let Some(b) = self.next_non_ws()? {
            return Err(Error::Json(format!(
                "config stream: trailing characters after array ('{}')",
                b as char
            )));
        }
        self.finished = true;
        Ok(None)
    }

    fn advance(&mut self) -> Result<Option<Value>> {
        if !self.started {
            match self.next_non_ws()? {
                Some(b'[') => {
                    self.start += 1;
                    self.started = true;
                }
                Some(b) => {
                    return Err(Error::Json(format!(
                        "config stream: expected '[' to open the config \
                         array, found '{}'",
                        b as char
                    )))
                }
                None => {
                    return Err(Error::Json(
                        "config stream: empty input (expected a JSON array)"
                            .into(),
                    ))
                }
            }
        }
        if self.count > 0 {
            // Consume the delimiter left behind by the last element.
            match self.next_non_ws()? {
                Some(b',') => self.start += 1,
                Some(b']') => {
                    self.start += 1;
                    return self.finish_trailer();
                }
                Some(b) => {
                    return Err(Error::Json(format!(
                        "config stream: expected ',' or ']' after element, \
                         found '{}'",
                        b as char
                    )))
                }
                None => {
                    return Err(Error::Json(
                        "config stream: unterminated array".into(),
                    ))
                }
            }
        } else if self.next_non_ws()? == Some(b']') {
            self.start += 1;
            return self.finish_trailer();
        }
        match self.next_non_ws()? {
            Some(b']') => {
                return Err(Error::Json(
                    "config stream: trailing ',' before ']'".into(),
                ))
            }
            Some(b',') => {
                return Err(Error::Json(
                    "config stream: unexpected ','".into(),
                ))
            }
            Some(_) => {}
            None => {
                return Err(Error::Json(
                    "config stream: unterminated array".into(),
                ))
            }
        }
        let (a, b) = self.scan_element()?;
        let text = std::str::from_utf8(&self.buf[a..b]).map_err(|_| {
            Error::Json("config stream: invalid UTF-8 in element".into())
        })?;
        let v = parse(text).map_err(|e| {
            let msg = match e {
                Error::Json(m) => m,
                other => other.to_string(),
            };
            Error::Json(format!(
                "config stream element {} (byte {}): {}",
                self.count,
                self.consumed + a as u64,
                msg
            ))
        })?;
        self.count += 1;
        self.start = b;
        Ok(Some(v))
    }
}

impl<R: std::io::Read> Iterator for ArrayStream<R> {
    type Item = Result<Value>;

    fn next(&mut self) -> Option<Result<Value>> {
        if self.finished {
            return None;
        }
        match self.advance() {
            Ok(v) => v.map(Ok),
            Err(e) => {
                self.finished = true;
                Some(Err(e))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::Value;
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-0.5e2").unwrap(), Value::Number(-50.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Value::Null);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "d");
    }

    #[test]
    fn spatter_config_shape() {
        // The paper's JSON multi-config format.
        let cfg = r#"[
            {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
             "count": 1024},
            {"kernel": "Scatter", "pattern": [0, 24, 48], "delta": 1,
             "count": 512}
        ]"#;
        let v = parse(cfg).unwrap();
        let arr = v.as_array().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("delta").unwrap().as_usize().unwrap(), 8);
        assert_eq!(
            arr[1].get("pattern").unwrap().as_array().unwrap()[2]
                .as_i64()
                .unwrap(),
            48
        );
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\n\t\"\\b""#).unwrap(),
            Value::String("a\n\t\"\\b".into())
        );
        assert_eq!(parse(r#""A""#).unwrap(), Value::String("A".into()));
        // surrogate pair: U+1F600
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Value::String("😀".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Value::String("héllo".into()));
    }

    #[test]
    fn errors_have_positions() {
        let e = parse("{\n  \"a\": nul\n}").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn error_columns_count_chars_not_bytes() {
        // 'é' is two bytes but one column wide: the bad literal after
        // {"héé":  starts at character column 9, not byte column 11.
        let e = parse("{\"héé\": nul}").unwrap_err().to_string();
        assert!(e.contains("col 9"), "{e}");
        // Pure-ASCII positions are unchanged.
        let e = parse("{\"haa\": nul}").unwrap_err().to_string();
        assert!(e.contains("col 9"), "{e}");
    }

    #[test]
    fn rejects_garbage() {
        for bad in [
            "", "{", "[", "\"", "{\"a\"}", "[1,]", "{\"a\":1,}", "01", "1.",
            "1e", "tru", "[1 2]", "{\"a\" 1}", "1 2", "\"\\x\"",
        ] {
            assert!(parse(bad).is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_ok() {
        let doc = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        assert!(parse(&doc).is_ok());
    }

    /// A reader that hands out one byte per `read` call — the worst
    /// possible chunking, so every element boundary crosses a refill.
    struct Trickle<'a>(&'a [u8]);

    impl std::io::Read for Trickle<'_> {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.0.is_empty() || out.is_empty() {
                return Ok(0);
            }
            out[0] = self.0[0];
            self.0 = &self.0[1..];
            Ok(1)
        }
    }

    fn collect_stream<R: std::io::Read>(s: ArrayStream<R>) -> Result<Vec<Value>> {
        s.collect()
    }

    #[test]
    fn array_stream_matches_batch_parse() {
        let doc = r#"[
            {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
             "count": 1024},
            {"kernel": "Scatter", "pattern": [0, 24, 48], "note": "a ] , } b"},
            [1, [2, {"x": "]"}]],
            "plain",
            42,
            true,
            null
        ]"#;
        let want = parse(doc).unwrap();
        let want = want.as_array().unwrap();
        let got =
            collect_stream(ArrayStream::new(std::io::Cursor::new(doc))).unwrap();
        assert_eq!(&got, want);
        // One-byte reads must produce the identical stream.
        let trickled =
            collect_stream(ArrayStream::new(Trickle(doc.as_bytes()))).unwrap();
        assert_eq!(&trickled, want);
    }

    #[test]
    fn array_stream_empty_array_yields_nothing() {
        let got =
            collect_stream(ArrayStream::new(std::io::Cursor::new("  [ ]  ")))
                .unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn array_stream_rejects_malformed_documents() {
        for bad in [
            "", "  ", "{\"a\": 1}", "1", "[1,]", "[1", "[1 2]", "[,1]",
            "[1] x", "[}",
        ] {
            let r = collect_stream(ArrayStream::new(std::io::Cursor::new(bad)));
            assert!(r.is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn array_stream_reports_element_relative_errors_and_stops() {
        let mut s = ArrayStream::new(std::io::Cursor::new("[1, nope, 3]"));
        assert_eq!(s.next().unwrap().unwrap(), Value::Number(1.0));
        let e = s.next().unwrap().unwrap_err().to_string();
        assert!(e.contains("element 1"), "{e}");
        // A terminal error ends the iterator.
        assert!(s.next().is_none());
    }

    #[test]
    fn array_stream_errors_carry_absolute_byte_offsets() {
        // "nope" starts at byte 4 of the document.
        let doc = "[1, nope, 3]";
        let mut s = ArrayStream::new(std::io::Cursor::new(doc));
        assert_eq!(s.next().unwrap().unwrap(), Value::Number(1.0));
        let e = s.next().unwrap().unwrap_err().to_string();
        assert!(e.contains("element 1 (byte 4)"), "{e}");
        // The offset must survive buffer compaction: the same document
        // through a one-byte-per-read source compacts after every
        // element, so a buffer-relative index would be wrong here.
        let mut s = ArrayStream::new(Trickle(doc.as_bytes()));
        assert_eq!(s.next().unwrap().unwrap(), Value::Number(1.0));
        let e = s.next().unwrap().unwrap_err().to_string();
        assert!(e.contains("element 1 (byte 4)"), "{e}");
    }

    #[test]
    fn array_stream_is_lazy_about_later_elements() {
        // Elements before a syntax error parse fine; the error only
        // surfaces when the stream reaches it.
        let mut s =
            ArrayStream::new(std::io::Cursor::new("[{\"a\": 1}, {\"b\": }]"));
        let first = s.next().unwrap().unwrap();
        assert_eq!(first.get("a").unwrap().as_i64().unwrap(), 1);
        assert!(s.next().unwrap().is_err());
    }
}
