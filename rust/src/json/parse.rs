//! Recursive-descent JSON parser with line/column error reporting.

use std::collections::BTreeMap;

use super::Value;
use crate::error::{Error, Result};

/// Parse a complete JSON document. Trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser::new(input);
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if !p.at_end() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(input: &'a str) -> Self {
        Parser {
            bytes: input.as_bytes(),
            pos: 0,
        }
    }

    fn err(&self, msg: &str) -> Error {
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::Json(format!("{msg} at line {line} col {col}"))
    }

    fn at_end(&self) -> bool {
        self.pos >= self.bytes.len()
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, want: u8) -> Result<()> {
        match self.bump() {
            Some(b) if b == want => Ok(()),
            Some(b) => Err(self.err(&format!(
                "expected '{}', found '{}'",
                want as char, b as char
            ))),
            None => Err(self.err(&format!("expected '{}', found EOF", want as char))),
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.err(&format!("unexpected character '{}'", b as char))),
            None => Err(self.err("unexpected EOF")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected '{word}')")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                Some(b) => {
                    return Err(self.err(&format!(
                        "expected ',' or '}}' in object, found '{}'",
                        b as char
                    )))
                }
                None => return Err(self.err("unterminated object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                Some(b) => {
                    return Err(self.err(&format!(
                        "expected ',' or ']' in array, found '{}'",
                        b as char
                    )))
                }
                None => return Err(self.err("unterminated array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle UTF-16 surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                    }
                    Some(b) => {
                        return Err(
                            self.err(&format!("invalid escape '\\{}'", b as char))
                        )
                    }
                    None => return Err(self.err("unterminated escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-for-byte.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b)
                            .ok_or_else(|| self.err("invalid UTF-8 lead byte"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8 sequence"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8 sequence"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
            }
            Some(b) if b.is_ascii_digit() => {
                while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // fraction
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !self.peek().is_some_and(|b| b.is_ascii_digit()) {
                return Err(self.err("digit required after decimal point"));
            }
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // exponent
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !self.peek().is_some_and(|b| b.is_ascii_digit()) {
                return Err(self.err("digit required in exponent"));
            }
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(lead: u8) -> Option<usize> {
    match lead {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::super::Value;
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-0.5e2").unwrap(), Value::Number(-50.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Value::Null);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "d");
    }

    #[test]
    fn spatter_config_shape() {
        // The paper's JSON multi-config format.
        let cfg = r#"[
            {"kernel": "Gather", "pattern": "UNIFORM:8:1", "delta": 8,
             "count": 1024},
            {"kernel": "Scatter", "pattern": [0, 24, 48], "delta": 1,
             "count": 512}
        ]"#;
        let v = parse(cfg).unwrap();
        let arr = v.as_array().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("delta").unwrap().as_usize().unwrap(), 8);
        assert_eq!(
            arr[1].get("pattern").unwrap().as_array().unwrap()[2]
                .as_i64()
                .unwrap(),
            48
        );
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\n\t\"\\b""#).unwrap(),
            Value::String("a\n\t\"\\b".into())
        );
        assert_eq!(parse(r#""A""#).unwrap(), Value::String("A".into()));
        // surrogate pair: U+1F600
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Value::String("😀".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Value::String("héllo".into()));
    }

    #[test]
    fn errors_have_positions() {
        let e = parse("{\n  \"a\": nul\n}").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn rejects_garbage() {
        for bad in [
            "", "{", "[", "\"", "{\"a\"}", "[1,]", "{\"a\":1,}", "01", "1.",
            "1e", "tru", "[1 2]", "{\"a\" 1}", "1 2", "\"\\x\"",
        ] {
            assert!(parse(bad).is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_ok() {
        let doc = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        assert!(parse(&doc).is_ok());
    }
}
